//! Long-context language modeling example (the Fig. 6 scenario): trains
//! sliding-window-only and sw+OVQ hybrids on the synthetic book corpus,
//! then compares loss-vs-position curves at 2x the train length — showing
//! the OVQ dictionary carrying information past the sliding window.
//!
//!     cargo run --release --example lm_long_context [STEPS]

use anyhow::Result;

use ovq::coordinator::{evaluator, trainer};
use ovq::runtime::Runtime;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let rt = Runtime::from_env()?;

    for name in ["lm-sw", "lm-sw-ovq"] {
        let (model, state) =
            trainer::ensure_trained(&rt, name, "lm", steps, "results")?;
        let prog = "eval_512";
        let curve = evaluator::nll_by_position(
            &model, &state.params, prog, "lm", 3, 13, 64,
        )?;
        println!("\n== {name} — NLL by position (T=512, trained at 256) ==");
        for (pos, nll, n) in &curve {
            let bar = "#".repeat((nll * 12.0) as usize);
            println!("  pos {pos:>4}  nll {nll:.3}  ({n:>5} tokens) {bar}");
        }
    }
    println!(
        "\n(expected shape: lm-sw flattens once the window saturates;\n\
         lm-sw-ovq keeps improving with position — the paper's Fig. 6)"
    );
    Ok(())
}
