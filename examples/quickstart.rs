//! Quickstart: load the `quickstart` artifact bundle, initialize a model,
//! take a few training steps on the basic in-context-recall task, and
//! evaluate — the minimal end-to-end tour of the runtime API.
//!
//!     make artifacts            # once (python, build-time only)
//!     cargo run --release --example quickstart

use anyhow::Result;

use ovq::data::batch::Batch;
use ovq::data::by_name;
use ovq::runtime::Runtime;
use ovq::util::rng::Rng;

fn main() -> Result<()> {
    // 1. the runtime: PJRT CPU client + artifact directory
    let rt = Runtime::from_env()?;
    println!("platform: {}", rt.client.platform_name());

    // 2. a model: manifest-driven (shapes, programs, config all from JSON)
    let model = rt.load_model("quickstart")?;
    println!(
        "model {} — {} parameters in {} leaves",
        model.manifest.name,
        model.manifest.total_param_elems(),
        model.manifest.param_count(),
    );

    // 3. fresh training state (params on device, optimizer zeroed)
    let mut state = model.init(42)?;

    // 4. a task generator (pure Rust, deterministic)
    let vocab = model.manifest.cfg_usize("vocab", 256);
    let gen = by_name("icr", vocab)?;
    let (b, t) = model.train_shape()?;
    let mut rng = Rng::new(7);

    // 5. train a few steps
    for _ in 0..10 {
        let batch = Batch::generate_train(gen.as_ref(), &mut rng, b, t);
        let m = model.train_step(&mut state, &batch.tokens, &batch.targets, &batch.mask)?;
        println!("step {:>2}  loss {:.4}  lr {:.2e}", m.step, m.loss, m.lr);
    }

    // 6. evaluate at the train length
    let batch = Batch::generate(gen.as_ref(), &mut rng, 2, 128);
    let ev = model.eval("eval_128", &state.params, &batch.tokens, &batch.targets, &batch.mask)?;
    println!("eval loss {:.4}  recall accuracy {:.3}", ev.loss, {
        let c: f32 = ev.correct.iter().sum();
        let m: f32 = batch.mask.iter().sum();
        c / m.max(1.0)
    });

    // 7. checkpoint round-trip
    model.save_checkpoint(&state, "/tmp/quickstart.ckpt")?;
    let restored = model.load_checkpoint("/tmp/quickstart.ckpt")?;
    assert_eq!(restored.step, state.step);
    println!("checkpoint round-trip OK (step {})", restored.step);
    Ok(())
}
