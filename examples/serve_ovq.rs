//! Serving example: the dynamic batcher + router from
//! coordinator::server, plus the multi-stream decode engine from
//! ovqcore::bank — demonstrating both halves of a serving deployment:
//!
//!  1. batched scoring through the compiled HLO program (throughput
//!     path; skipped with a notice when no PJRT backend/artifacts are
//!     available);
//!  2. multi-head, multi-stream streaming decode against constant-memory
//!     [`SeqMixer`] state through the sharded [`DecodeEngine`] (latency
//!     path) — per-stream state stays flat as context grows, which is
//!     the paper's deployment argument. See `examples/storm_ovq.rs` for
//!     the full traffic-replay + session-lifecycle storm.
//!
//!     cargo run --release --example serve_ovq
//!
//! [`SeqMixer`]: ovq::ovqcore::mixer::SeqMixer
//! [`DecodeEngine`]: ovq::coordinator::engine::DecodeEngine

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use ovq::coordinator::server::{run_decode_engine, serve_loop, DecodeConfig, ScoreRequest};
use ovq::runtime::Runtime;
use ovq::util::rng::Rng;

fn main() -> Result<()> {
    // ---- path 1: batched scoring through HLO --------------------------
    match Runtime::from_env().and_then(|rt| batched_scoring(&rt)) {
        Ok(()) => {}
        Err(e) => println!("== batched scoring (HLO path) skipped: {e} =="),
    }

    // ---- path 2: streaming decode through the sharded engine -----------
    println!("\n== streaming decode (SeqMixer/DecodeEngine path) ==");
    let mut cfg = DecodeConfig::new(256);
    cfg.streams = 4;
    cfg.heads = 4;
    cfg.d_head = 32;
    cfg.chunk = 32;
    cfg.tokens = 2048;
    cfg.threads = 2;
    let report = run_decode_engine(&cfg);
    report.print();
    println!(
        "  context grew 0 -> {} tokens per stream; total state held at {} bytes",
        cfg.tokens, report.state_bytes
    );
    Ok(())
}

fn batched_scoring(rt: &Runtime) -> Result<()> {
    let model = rt.load_model("quickstart")?;
    let prog = "eval_128";
    let t = 128usize;
    let vocab = model.manifest.cfg_usize("vocab", 256);

    let (tx, rx) = mpsc::channel::<ScoreRequest>();
    let producer = std::thread::spawn(move || {
        let gen = ovq::data::by_name("icr", vocab).expect("icr is a known task");
        let mut rng = Rng::new(1);
        let mut replies = Vec::new();
        for _ in 0..24 {
            let ex = gen.generate(&mut rng, t);
            let (rtx, rrx) = mpsc::channel();
            tx.send(ScoreRequest {
                tokens: ex.tokens[..t].to_vec(),
                targets: ex.tokens[1..t + 1].to_vec(),
                mask: ex.score.iter().map(|&s| if s { 1.0 } else { 0.0 }).collect(),
                reply: rtx,
                submitted: Instant::now(),
            })
            .unwrap();
            replies.push(rrx);
        }
        drop(tx);
        replies.into_iter().map(|r| r.recv().unwrap()).count()
    });
    let t0 = Instant::now();
    let stats = serve_loop(&model, prog, rx, Duration::from_millis(5))?;
    let served = producer.join().unwrap();
    println!("== batched scoring (HLO path) ==");
    stats.report(t0.elapsed());
    assert_eq!(served, 24);
    Ok(())
}
