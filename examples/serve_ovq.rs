//! Serving example: the dynamic batcher + router from
//! coordinator::server, plus the pure-Rust OVQ decode path from ovqcore —
//! demonstrating both halves of a serving deployment:
//!
//!  1. batched scoring through the compiled HLO program (throughput path);
//!  2. single-token streaming "decode" against the constant-memory
//!     OvqState (latency path) — state size stays flat as context grows,
//!     which is the paper's deployment argument.
//!
//!     cargo run --release --example serve_ovq

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use ovq::coordinator::server::{serve_loop, ScoreRequest};
use ovq::ovqcore::ovq::{OvqConfig, OvqState};
use ovq::runtime::Runtime;
use ovq::util::rng::Rng;

fn main() -> Result<()> {
    // ---- path 1: batched scoring through HLO --------------------------
    let rt = Runtime::from_env()?;
    let model = rt.load_model("quickstart")?;
    let prog = "eval_128";
    let t = 128usize;
    let vocab = model.manifest.cfg_usize("vocab", 256);

    let (tx, rx) = mpsc::channel::<ScoreRequest>();
    let producer = std::thread::spawn(move || {
        let gen = ovq::data::by_name("icr", vocab);
        let mut rng = Rng::new(1);
        let mut replies = Vec::new();
        for _ in 0..24 {
            let ex = gen.generate(&mut rng, t);
            let (rtx, rrx) = mpsc::channel();
            tx.send(ScoreRequest {
                tokens: ex.tokens[..t].to_vec(),
                targets: ex.tokens[1..t + 1].to_vec(),
                mask: ex.score.iter().map(|&s| if s { 1.0 } else { 0.0 }).collect(),
                reply: rtx,
                submitted: Instant::now(),
            })
            .unwrap();
            replies.push(rrx);
        }
        drop(tx);
        replies.into_iter().map(|r| r.recv().unwrap()).count()
    });
    let t0 = Instant::now();
    let stats = serve_loop(&model, prog, rx, Duration::from_millis(5))?;
    let served = producer.join().unwrap();
    println!("== batched scoring (HLO path) ==");
    stats.report(t0.elapsed());
    assert_eq!(served, 24);

    // ---- path 2: streaming decode against the constant-memory state ----
    println!("\n== streaming decode (ovqcore path) ==");
    let d = 32;
    let mut st = OvqState::new(OvqConfig::new(d, 256, 32));
    let mut rng = Rng::new(2);
    let mut lat = Vec::new();
    let chunk = 32;
    let mut q = vec![0.0f32; chunk * d];
    let mut k = vec![0.0f32; chunk * d];
    let mut v = vec![0.0f32; chunk * d];
    for step in 0..64 {
        for x in q.iter_mut().chain(k.iter_mut()).chain(v.iter_mut()) {
            *x = rng.normal() as f32;
        }
        let s = Instant::now();
        let out = st.process_chunk(&q, &k, &v);
        lat.push(s.elapsed().as_secs_f64() * 1e3);
        if step % 16 == 0 {
            println!(
                "  t={:>5}  state {:>8} B (constant)  chunk latency {:.2} ms  out[0]={:+.3}",
                st.t,
                st.state_bytes(),
                lat.last().unwrap(),
                out[0]
            );
        }
    }
    println!(
        "  context grew 0 -> {} tokens; state stayed {} bytes; mean chunk latency {:.2} ms",
        st.t,
        st.state_bytes(),
        lat.iter().sum::<f64>() / lat.len() as f64
    );
    Ok(())
}
