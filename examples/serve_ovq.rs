//! Serving example: the dynamic batcher + router from
//! coordinator::server, plus the multi-stream decode engine from
//! ovqcore::bank — demonstrating both halves of a serving deployment:
//!
//!  1. batched scoring through the compiled HLO program (throughput
//!     path; skipped with a notice when no PJRT backend/artifacts are
//!     available);
//!  2. multi-head, multi-stream streaming decode against constant-memory
//!     [`SeqMixer`] state through the sharded [`DecodeEngine`] (latency
//!     path) — per-stream state stays flat as context grows, which is
//!     the paper's deployment argument. See `examples/storm_ovq.rs` for
//!     the full traffic-replay + session-lifecycle storm;
//!  3. end-to-end autoregressive generation: token prompts prefill
//!     through a hybrid `ovq|kv` model stack, then each session
//!     self-feeds sampled tokens (greedy and the full
//!     temperature/top-k/top-p chain side by side) until its stop rule
//!     fires — prompt in, tokens out, with the engine's three-way
//!     decode/prefill/generate occupancy split.
//!
//!     cargo run --release --example serve_ovq
//!
//! [`SeqMixer`]: ovq::ovqcore::mixer::SeqMixer
//! [`DecodeEngine`]: ovq::coordinator::engine::DecodeEngine

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use ovq::coordinator::engine::{DecodeEngine, EngineConfig};
use ovq::coordinator::sampler::{SamplingParams, StopCriteria};
use ovq::coordinator::server::{run_decode_engine, serve_loop, DecodeConfig, ScoreRequest};
use ovq::coordinator::traffic;
use ovq::ovqcore::lm::LmConfig;
use ovq::ovqcore::memstate::MixerKind;
use ovq::ovqcore::stack::StackConfig;
use ovq::runtime::Runtime;
use ovq::util::rng::Rng;

fn main() -> Result<()> {
    // ---- path 1: batched scoring through HLO --------------------------
    match Runtime::from_env().and_then(|rt| batched_scoring(&rt)) {
        Ok(()) => {}
        Err(e) => println!("== batched scoring (HLO path) skipped: {e} =="),
    }

    // ---- path 2: streaming decode through the sharded engine -----------
    println!("\n== streaming decode (SeqMixer/DecodeEngine path) ==");
    let mut cfg = DecodeConfig::new(256);
    cfg.streams = 4;
    cfg.heads = 4;
    cfg.d_head = 32;
    cfg.chunk = 32;
    cfg.tokens = 2048;
    cfg.threads = 2;
    let report = run_decode_engine(&cfg);
    report.print();
    println!(
        "  context grew 0 -> {} tokens per stream; total state held at {} bytes",
        cfg.tokens, report.state_bytes
    );

    // ---- path 3: autoregressive generation ------------------------------
    generation_demo();
    Ok(())
}

/// Prompt in, sampled tokens out: four sessions over a 2-layer hybrid
/// `ovq|kv` stack, half greedy, half with the sampled chain, all
/// interleaved by the continuous-batching scheduler on 2 shard threads.
fn generation_demo() {
    println!("\n== autoregressive generation (LmModel/submit_generate path) ==");
    let vocab = 64usize;
    let lm = LmConfig::new(
        vocab,
        StackConfig::hybrid(
            32,
            64,
            2,
            16,
            16,
            vec![MixerKind::Ovq { n_max: 128 }, MixerKind::SlidingWindow { window: 64 }],
        ),
    );
    let mut ecfg = EngineConfig::for_lm(lm);
    ecfg.threads = 2;
    ecfg.prefill_quantum = 64;
    let engine = DecodeEngine::start(ecfg);
    for s in 0..4u64 {
        let prompt = traffic::synth_tokens(0xDE40, s, 96, vocab);
        let params = if s % 2 == 0 {
            SamplingParams::greedy()
        } else {
            SamplingParams::sampled(0x5A + s)
        };
        engine.submit_generate(s, prompt, params, StopCriteria::max_new(48));
    }
    let report = engine.finish();
    for g in &report.generations {
        let mode = if g.session % 2 == 0 { "greedy " } else { "sampled" };
        let shown: Vec<String> = g.tokens.iter().take(12).map(|t| t.to_string()).collect();
        println!(
            "  session {} ({mode}): {:>2} tokens  [{} ...]",
            g.session,
            g.tokens.len(),
            shown.join(" "),
        );
    }
    report.print();
}

fn batched_scoring(rt: &Runtime) -> Result<()> {
    let model = rt.load_model("quickstart")?;
    let prog = "eval_128";
    let t = 128usize;
    let vocab = model.manifest.cfg_usize("vocab", 256);

    let (tx, rx) = mpsc::channel::<ScoreRequest>();
    let producer = std::thread::spawn(move || {
        let gen = ovq::data::by_name("icr", vocab).expect("icr is a known task");
        let mut rng = Rng::new(1);
        let mut replies = Vec::new();
        for _ in 0..24 {
            let ex = gen.generate(&mut rng, t);
            let (rtx, rrx) = mpsc::channel();
            tx.send(ScoreRequest {
                tokens: ex.tokens[..t].to_vec(),
                targets: ex.tokens[1..t + 1].to_vec(),
                mask: ex.score.iter().map(|&s| if s { 1.0 } else { 0.0 }).collect(),
                reply: rtx,
                submitted: Instant::now(),
            })
            .unwrap();
            replies.push(rrx);
        }
        drop(tx);
        replies.into_iter().map(|r| r.recv().unwrap()).count()
    });
    let t0 = Instant::now();
    let stats = serve_loop(&model, prog, rx, Duration::from_millis(5))?;
    let served = producer.join().unwrap();
    println!("== batched scoring (HLO path) ==");
    stats.report(t0.elapsed());
    assert_eq!(served, 24);
    Ok(())
}
