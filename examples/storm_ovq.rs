//! Traffic storm against the sharded decode engine — the paper's "large
//! fixed state, many concurrent streams" regime, end to end:
//!
//!  1. generate a production-shaped open-loop trace (zipf session
//!     popularity, bursty arrivals, mixed chunk sizes, abandon/return);
//!  2. replay it through the engine at 1, 2 and 4 shard threads and
//!     watch aggregate tok/s scale while per-stream outputs stay
//!     bit-identical;
//!  3. re-run with a tight residency cap so sessions churn through LRU
//!     eviction -> snapshot blob -> restore, and show the accounting:
//!     an evicted session costs its blob, not its live state.
//!
//!     cargo run --release --example storm_ovq
//!
//! Runs everywhere: no artifacts, no PJRT backend, no third-party deps.

use ovq::coordinator::engine::{DecodeEngine, EngineConfig};
use ovq::coordinator::traffic::{self, TrafficConfig};
use ovq::ovqcore::memstate::MixerKind;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");

    // ---- 1. the storm: a zipf-popularity, bursty, churning trace -------
    let mut tcfg = TrafficConfig::new(96, if quick { 600 } else { 4000 });
    tcfg.zipf_s = 1.2;
    tcfg.burst_p = 0.65;
    tcfg.abandon_p = 0.08;
    tcfg.chunk_sizes = vec![1, 8, 32, 64];
    let events = traffic::generate(&tcfg);
    let shape = traffic::summarize(&events);
    println!("== traffic storm ==");
    println!(
        "  {} arrivals / {} tokens over {:.1} ms (open loop), {} distinct sessions",
        shape.events,
        shape.tokens,
        shape.span_us as f64 / 1e3,
        shape.distinct_sessions,
    );
    println!(
        "  hottest session takes {:.0}% of arrivals; longest burst {} chunks",
        100.0 * shape.hottest_share,
        shape.max_burst
    );

    // ---- 2. threads sweep: same trace, same outputs, more shards --------
    println!("\n== engine scaling: threads sweep on the same trace ==");
    let mut tps1 = 0.0f64;
    for threads in [1usize, 2, 4] {
        let mut ecfg = EngineConfig::new(MixerKind::Ovq { n_max: 1024 }, 4, 32, 32);
        ecfg.threads = threads;
        let engine = DecodeEngine::start(ecfg);
        let t0 = std::time::Instant::now();
        let tokens = traffic::replay(&engine, &events, tcfg.seed, None);
        engine.flush_all();
        let report = engine.finish();
        let tps = tokens as f64 / t0.elapsed().as_secs_f64();
        if threads == 1 {
            tps1 = tps;
        }
        println!(
            "  {threads} thread(s): {:>9.0} tok/s ({:.2}x)  p99 latency {:>9.1} us  \
             state {:.0} KiB",
            tps,
            tps / tps1,
            report.latency_us(99.0),
            report.state_bytes() as f64 / 1024.0,
        );
    }
    println!("  (per-stream outputs are bit-identical across thread counts — the");
    println!("   engine golden test in rust/tests/engine.rs enforces it)");

    // ---- 3. session churn: LRU eviction to snapshots + restore ----------
    println!("\n== session lifecycle: residency cap 6/shard on 2 shards ==");
    let mut ecfg = EngineConfig::new(MixerKind::Ovq { n_max: 1024 }, 4, 32, 32);
    ecfg.threads = 2;
    ecfg.max_resident = 6;
    let engine = DecodeEngine::start(ecfg);
    let t0 = std::time::Instant::now();
    let tokens = traffic::replay(&engine, &events, tcfg.seed, None);
    engine.flush_all();
    let report = engine.finish();
    let tps = tokens as f64 / t0.elapsed().as_secs_f64();
    println!("  {:>9.0} tok/s under churn ({:.2}x of uncapped 1-thread)", tps, tps / tps1);
    report.print();
    let frozen: usize = report.shards.iter().map(|s| s.snapshot_bytes).sum();
    let live: usize = report.shards.iter().map(|s| s.resident_bytes).sum();
    println!(
        "  at shutdown: {} resident sessions hold {:.0} KiB live state; {} evicted \
     sessions cost only their {:.0} KiB of snapshot blobs",
        report.shards.iter().map(|s| s.resident_sessions).sum::<usize>(),
        live as f64 / 1024.0,
        report.shards.iter().map(|s| s.evicted_sessions).sum::<usize>(),
        frozen as f64 / 1024.0,
    );
    println!("\nstorm complete: constant per-session state + exact snapshots are what");
    println!("make this lifecycle cheap — the paper's deployment argument, measured.");
}
