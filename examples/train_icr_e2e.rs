//! End-to-end validation driver (DESIGN.md §4): trains the sw-ovq hybrid
//! on basic in-context recall through the full Rust→PJRT→HLO path for a
//! few hundred steps, logs the loss curve, then runs the length-
//! extrapolation sweep including test-time dictionary scaling — the
//! repo-scale version of the paper's Fig. 4 protocol. Results are recorded
//! in EXPERIMENTS.md.
//!
//!     cargo run --release --example train_icr_e2e [STEPS]

use anyhow::Result;

use ovq::coordinator::{evaluator, trainer};
use ovq::runtime::Runtime;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(250);
    let rt = Runtime::from_env()?;

    let cfg = trainer::TrainConfig {
        model: "icr-sw-ovq".into(),
        task: "icr".into(),
        steps,
        seed: 42,
        log_every: 25,
        out_dir: "results".into(),
        resume: None,
    };
    let t0 = std::time::Instant::now();
    let summary = trainer::train(&rt, &cfg)?;
    println!(
        "\ntrained {} steps in {:.1}s ({:.2} s/step), final loss {:.4}",
        summary.steps,
        t0.elapsed().as_secs_f64(),
        summary.sec_per_step,
        summary.final_loss
    );

    let model = rt.load_model("icr-sw-ovq")?;
    let state = model.load_checkpoint(&summary.ckpt_path)?;
    let points = evaluator::length_sweep(&model, &state.params, "icr", 3, 7, None)?;
    evaluator::print_sweep("icr-sw-ovq", &points);

    // the paper's test-time memory scaling: accuracy should not DEGRADE
    // with a larger test-time dictionary (Fig. 4: it improves)
    let base: Vec<_> = points.iter().filter(|p| p.n_dict.is_none()).collect();
    println!("\ntrain-length accuracy: {:.3}", base[0].accuracy);
    println!("longest-length accuracy: {:.3}", base.last().unwrap().accuracy);
    Ok(())
}
