"""AOT compiler: lowers every registered model's init / train / eval
programs to HLO *text* + a JSON manifest the Rust runtime consumes.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Program signatures (flat-leaf convention, the contract with rust/src/runtime):
  init : (seed u32[2]) -> (P param leaves)
  train: (P params, P m, P v, step i32[], tokens i32[B,T], targets i32[B,T],
          mask f32[B,T]) -> (P params', P m', P v', step', loss, lr)
  eval : (P params, tokens, targets, mask) -> (loss, correct[B,T], nll[B,T])

Usage:  cd python && python -m compile.aot --out ../artifacts [--only a,b]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model, train


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32",
            "bfloat16": "bf16"}[jnp.dtype(dt).name]


def param_layout(cfg):
    """Flat leaf (name, ShapeDtypeStruct) list + treedef for config cfg."""
    shapes = jax.eval_shape(
        lambda k: model.init_params(k, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    names = [jax.tree_util.keystr(path) for path, _ in leaves_p]
    leaves = [leaf for _, leaf in leaves_p]
    return names, leaves, treedef


def spec(leaf):
    return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype)


def lower_init(cfg):
    def init_flat(seed):
        key = jax.random.wrap_key_data(seed, impl="threefry2x32")
        params = model.init_params(key, cfg)
        return tuple(jax.tree_util.tree_leaves(params))
    return jax.jit(init_flat).lower(jax.ShapeDtypeStruct((2,), jnp.uint32))


def lower_train(cfg, leaves, treedef, B, T):
    P = len(leaves)

    def train_flat(*args):
        params = jax.tree_util.tree_unflatten(treedef, args[:P])
        m = jax.tree_util.tree_unflatten(treedef, args[P:2 * P])
        v = jax.tree_util.tree_unflatten(treedef, args[2 * P:3 * P])
        step, tokens, targets, mask = args[3 * P:]
        p2, m2, v2, step2, loss, lr = train.train_step(
            params, m, v, step, tokens, targets, mask, cfg)
        return (tuple(jax.tree_util.tree_leaves(p2))
                + tuple(jax.tree_util.tree_leaves(m2))
                + tuple(jax.tree_util.tree_leaves(v2))
                + (step2, loss, lr))

    specs = ([spec(l) for l in leaves] * 3
             + [jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((B, T), jnp.int32),
                jax.ShapeDtypeStruct((B, T), jnp.int32),
                jax.ShapeDtypeStruct((B, T), jnp.float32)])
    return jax.jit(train_flat).lower(*specs)


def lower_eval(cfg, leaves, treedef, B, T):
    P = len(leaves)

    def eval_flat(*args):
        params = jax.tree_util.tree_unflatten(treedef, args[:P])
        tokens, targets, mask = args[P:]
        return model.eval_step(params, tokens, targets, mask, cfg)

    specs = ([spec(l) for l in leaves]
             + [jax.ShapeDtypeStruct((B, T), jnp.int32),
                jax.ShapeDtypeStruct((B, T), jnp.int32),
                jax.ShapeDtypeStruct((B, T), jnp.float32)])
    return jax.jit(eval_flat).lower(*specs)


def emit_entry(entry, out_dir, log=print):
    name = entry["name"]
    cfg = entry["config"]
    names, leaves, treedef = param_layout(cfg)
    B, T = entry["train_shape"]["batch"], entry["train_shape"]["seq"]
    eb = entry["eval_batch"]

    manifest = {
        "name": name,
        "config": cfg,
        "params": [
            {"name": n, "shape": list(l.shape), "dtype": _dtype_name(l.dtype)}
            for n, l in zip(names, leaves)
        ],
        "programs": {},
    }

    def emit(prog_name, lowered, extra):
        fname = f"{name}.{prog_name}.hlo.txt"
        t0 = time.time()
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["programs"][prog_name] = {"file": fname, **extra}
        log(f"  [{name}] {prog_name}: {len(text) / 1e6:.1f} MB "
            f"({time.time() - t0:.1f}s)")

    if "init" in entry["programs"]:
        emit("init", lower_init(cfg), {})
    if "train" in entry["programs"]:
        emit("train", lower_train(cfg, leaves, treedef, B, T),
             {"batch": B, "seq": T})
    if "eval" in entry["programs"]:
        for L in entry["eval_lens"]:
            emit(f"eval_{L}", lower_eval(cfg, leaves, treedef, eb, L),
                 {"batch": eb, "seq": L})
        for nd in entry["eval_n_dicts"]:
            if nd == cfg["n_dict"]:
                continue
            cfg_nd = dict(cfg, n_dict=nd)
            for L in entry["eval_lens"]:
                emit(f"eval_{L}_N{nd}",
                     lower_eval(cfg_nd, leaves, treedef, eb, L),
                     {"batch": eb, "seq": L, "n_dict": nd})

    with open(os.path.join(out_dir, f"{name}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default="",
                    help="comma-separated registry names (default: all)")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for n in configs.REGISTRY:
            print(n)
        return

    os.makedirs(args.out, exist_ok=True)
    wanted = [w for w in args.only.split(",") if w] or list(configs.REGISTRY)
    # merge with any models already present (partial --only runs)
    index_path = os.path.join(args.out, "index.json")
    index = []
    if os.path.exists(index_path):
        with open(index_path) as f:
            index = json.load(f).get("models", [])
    t0 = time.time()
    for name in wanted:
        entry = configs.REGISTRY[name]
        print(f"[aot] emitting {name} "
              f"(pattern={entry['config']['pattern']})", flush=True)
        emit_entry(entry, args.out)
        if name not in index:
            index.append(name)
    with open(index_path, "w") as f:
        json.dump({"models": index}, f, indent=1)
    print(f"[aot] done: {len(index)} models in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
