"""Experiment configuration registry.

Every entry maps a model name to (model config, program shapes). The Rust
coordinator is fully manifest-driven: adding an entry here and re-running
`make artifacts` is all it takes to expose a new model to the runtime.

Scaling note (DESIGN.md §3): the paper's 70M–480M models / 4k–64k contexts
are scaled to CPU-PJRT-trainable sizes. All *relative* quantities (window
vs chunk vs dictionary size vs train length ratios) follow the paper:
window 128 ≈ chunk 128, N ≈ 0.5–4× train length, test length up to 16×
train length.
"""

from __future__ import annotations

import copy

# ----------------------------------------------------------------- families

BASE = {
    "dim": 128,
    "heads": 4,
    "d_head": 32,
    "mlp_hidden": 256,
    "vocab": 512,
    "window": 32,
    "chunk": 32,
    "n_dict": 128,
    "tile_n": 128,
    "tile_r": 64,
    "aux_weight": 0.1,
    # optimizer (overridable per entry)
    "lr": 1e-3,
    "warmup": 40,
    "total_steps": 800,
    "min_lr": 1e-5,
    "weight_decay": 0.01,
}

TINY = dict(BASE, dim=64, heads=2, d_head=32, mlp_hidden=128, vocab=256,
            n_dict=64, total_steps=200)


def _cfg(pattern, **over):
    c = copy.deepcopy(BASE)
    c["pattern"] = list(pattern)
    c.update(over)
    return c


# Standard shapes: train [B=8, T=256]; eval at the train length and the
# length-extrapolation sweep. eval_n_dicts exposes the paper's test-time
# dictionary scaling (Fig. 4): same params, bigger N at eval.
TRAIN_SHAPE = {"batch": 4, "seq": 256}
EVAL_LENS = [256, 512, 1024, 2048]
EVAL_BATCH = 2

REGISTRY = {}


def register(name, cfg, train_shape=None, eval_lens=None, eval_batch=None,
             eval_n_dicts=None, programs=("init", "train", "eval")):
    REGISTRY[name] = {
        "name": name,
        "config": cfg,
        "train_shape": train_shape or dict(TRAIN_SHAPE),
        "eval_lens": list(eval_lens or EVAL_LENS),
        "eval_batch": eval_batch or EVAL_BATCH,
        "eval_n_dicts": list(eval_n_dicts or []),
        "programs": list(programs),
    }


# ------------------------------------------------------------ quickstart

register("quickstart",
         _cfg(["swa", "ovq"], **{k: TINY[k] for k in
                                 ("dim", "heads", "d_head", "mlp_hidden",
                                  "vocab", "n_dict", "total_steps")}),
         train_shape={"batch": 4, "seq": 128},
         eval_lens=[128, 256], eval_batch=2)

# ------------------------------------------- ICR family (Figs 1, 4, 7, 8)

_ICR = dict(total_steps=400)

register("icr-sw-nope", _cfg(["swa", "attn_nope", "swa", "attn_nope"], **_ICR))
register("icr-sw-ovq", _cfg(["swa", "ovq", "swa", "ovq"], **_ICR),
         eval_n_dicts=[64, 128, 256, 512])
for n in (32, 64, 128):
    register(f"icr-sw-vq{n}",
             _cfg(["swa", "vq", "swa", "vq"], n_dict=n, **_ICR))

# ablations (Fig 7): same parameter structure as icr-sw-ovq, different
# online-learning rules — flags only affect the forward dynamics.
register("icr-sw-ovq-randassign",
         _cfg(["swa", "ovq", "swa", "ovq"], rand_assign=True, **_ICR))
register("icr-sw-ovq-lineargrow",
         _cfg(["swa", "ovq", "swa", "ovq"], linear_growth=True, **_ICR))
register("icr-sw-ovq-constlr",
         _cfg(["swa", "ovq", "swa", "ovq"], const_lr=True, **_ICR))

# linear-attention / SSM baselines (Fig 8)
register("icr-gdn", _cfg(["gdn", "gdn", "gdn", "gdn"], **_ICR))
register("icr-ssd", _cfg(["ssd", "ssd", "ssd", "ssd"], **_ICR))
register("icr-linattn", _cfg(["linattn"] * 4, **_ICR))

# RoPE variant (Fig 10) + v-shift (Fig 13)
register("icr-ovq-rope", _cfg(["ovq_rope"] * 4, **_ICR))
register("icr-att-rope", _cfg(["attn_rope"] * 4, **_ICR))
register("icr-sw-ovq-vshift",
         _cfg(["swa", "ovq", "swa", "ovq"], vshift=True, **_ICR))

# ----------------------------------------------- ICL family (Figs 5, 8)

_ICL = dict(total_steps=500)
register("icl-sw-nope", _cfg(["swa", "attn_nope", "swa", "attn_nope"], **_ICL))
register("icl-sw-ovq", _cfg(["swa", "ovq", "swa", "ovq"], **_ICL),
         eval_n_dicts=[128, 256])
register("icl-sw-vq", _cfg(["swa", "vq", "swa", "vq"], **_ICL))
register("icl-gdn", _cfg(["gdn"] * 4, **_ICL))
register("icl-ssd", _cfg(["ssd"] * 4, **_ICL))

# ----------------------------------------------- LM family (Figs 6, 9, 12)

_LM = dict(total_steps=400, vocab=512)
register("lm-sw", _cfg(["swa", "swa", "swa", "swa"], **_LM),
         eval_lens=[256, 512, 1024])
register("lm-sw-nope", _cfg(["swa", "attn_nope", "swa", "attn_nope"], **_LM),
         eval_lens=[256, 512, 1024])
register("lm-sw-ovq", _cfg(["swa", "ovq", "swa", "ovq"], **_LM),
         eval_lens=[256, 512, 1024], eval_n_dicts=[128, 256])
register("lm-sw-vq", _cfg(["swa", "vq", "swa", "vq"], **_LM),
         eval_lens=[256, 512, 1024])
register("lm-gdn", _cfg(["gdn"] * 4, **_LM), eval_lens=[256, 512, 1024])
register("lm-gdn-ovq", _cfg(["gdn", "ovq", "gdn", "ovq"], **_LM),
         eval_lens=[256, 512, 1024])
register("lm-std-att", _cfg(["attn_rope"] * 4, **_LM),
         eval_lens=[256, 512, 1024])
register("lm-ovq-rope", _cfg(["ovq_rope"] * 4, **_LM),
         eval_lens=[256, 512, 1024])
# LM ablations (Fig 12)
register("lm-sw-ovq-lineargrow",
         _cfg(["swa", "ovq", "swa", "ovq"], linear_growth=True, **_LM),
         eval_lens=[256, 512])
register("lm-sw-ovq-constlr",
         _cfg(["swa", "ovq", "swa", "ovq"], const_lr=True, **_LM),
         eval_lens=[256, 512])
register("lm-sw-ovq-randassign",
         _cfg(["swa", "ovq", "swa", "ovq"], rand_assign=True, **_LM),
         eval_lens=[256, 512])

# ------------------------------------------- short-context family (Table 1)

_SC = dict(total_steps=300)
register("sc-std-att", _cfg(["attn_rope"] * 4, **_SC),
         train_shape={"batch": 4, "seq": 192}, eval_lens=[192])
register("sc-sw-nope", _cfg(["swa", "attn_nope", "swa", "attn_nope"], **_SC),
         train_shape={"batch": 4, "seq": 192}, eval_lens=[192])
register("sc-sw-ovq", _cfg(["swa", "ovq", "swa", "ovq"], **_SC),
         train_shape={"batch": 4, "seq": 192}, eval_lens=[192])
