"""Fig 14 reproduction: VQ dictionary-training methods compared by
commitment error (mean cosine similarity between keys and their nearest
centroid) over training iterations.

The paper compares DiVeq, SF-DiVeq and DiVeq + a "no-use penalty". DiVeq
itself is unavailable offline (DESIGN.md §2.3), so we compare the same
*failure mode* (dead centroids) across our substitutions:

  * ste        — classic VQ-VAE: STE + commitment + codebook loss
  * ste_pen    — ste + the paper's no-use penalty (a growing similarity
                 bonus for centroids that have not been selected recently)
  * ema        — exponential-moving-average codebook (VQ-VAE-2 style)

Usage: cd python && python -m compile.dict_training [--iters 300]
Writes results/f14_dict_training.csv with columns
  method,iter,commitment,dead_frac
"""

from __future__ import annotations

import argparse
import os

import numpy as np


def make_keys(rng, n, d, centers, drift=0.01):
    """Synthetic key stream: mixture of slowly-drifting clusters (what a
    real attention layer's keys look like: clustered, non-stationary).
    Drifts `centers` in place."""
    centers += drift * rng.normal(size=centers.shape)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    idx = rng.integers(0, centers.shape[0], n)
    k = centers[idx] + 0.05 * rng.normal(size=(n, d))
    return k / np.linalg.norm(k, axis=1, keepdims=True)


def commitment(keys, dic):
    sims = keys @ dic.T
    return float(np.mean(sims.max(axis=1)))


def train_dict(method, rng, iters, n_dict=64, d=32, batch=64, lr=0.1):
    dic = rng.normal(size=(n_dict, d))
    dic /= np.linalg.norm(dic, axis=1, keepdims=True)
    usage = np.zeros(n_dict)
    penalty = np.zeros(n_dict)
    ema_c = np.zeros((n_dict, d))
    ema_n = np.zeros(n_dict)
    centers = rng.normal(size=(8, d))
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    rows = []
    for it in range(iters):
        keys = make_keys(rng, batch, d, centers)
        sims = keys @ dic.T
        if method == "ste_pen":
            sims = sims + penalty[None, :]
        assign = sims.argmax(axis=1)

        if method in ("ste", "ste_pen"):
            # gradient of the codebook loss ||sg(k) - mu||^2 per assignment
            for s in np.unique(assign):
                sel = assign == s
                grad = dic[s] - keys[sel].mean(axis=0)
                dic[s] -= lr * grad
        elif method == "ema":
            decay = 0.95
            onehot = np.zeros((batch, n_dict))
            onehot[np.arange(batch), assign] = 1
            ema_n = decay * ema_n + (1 - decay) * onehot.sum(0)
            ema_c = decay * ema_c + (1 - decay) * (onehot.T @ keys)
            nz = ema_n > 1e-3
            dic[nz] = ema_c[nz] / ema_n[nz, None]
        dic /= np.maximum(np.linalg.norm(dic, axis=1, keepdims=True), 1e-9)

        used = np.zeros(n_dict, bool)
        used[np.unique(assign)] = True
        usage = 0.98 * usage + 0.02 * used
        if method == "ste_pen":
            # the paper's no-use penalty: grows while unused, resets on use
            penalty = np.where(used, 0.0, penalty + 0.0025)

        rows.append((it, commitment(keys, dic), float((usage < 0.005).mean())))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--out", default="../results/f14_dict_training.csv")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write("method,iter,commitment,dead_frac\n")
        for method in ("ste", "ste_pen", "ema"):
            rng = np.random.default_rng(0)
            rows = train_dict(method, rng, args.iters)
            for it, com, dead in rows:
                f.write(f"{method},{it},{com},{dead}\n")
            print(f"{method:8} final commitment {rows[-1][1]:.4f} "
                  f"dead {rows[-1][2] * 100:.1f}%")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
