"""Autodiff-capable wrappers around the Pallas kernels.

pallas_call (even with interpret=True) does not define general VJP rules, so
— exactly like production flash-attention kernels — we pair the Pallas
forward with a hand-wired backward derived from the pure-jnp reference via
jax.vjp. The forward that lands in the lowered HLO artifact is the Pallas
kernel; the backward recomputes the (cheap, chunk-sized) reference
attention. Numerically the two paths agree to float32 tolerance, which
python/tests/test_kernel_ad.py asserts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .ovq_attn import ovq_chunk_attn
from .swa_attn import swa_attn


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def ovq_chunk_attn_ad(q, ke, ve, bias, beta, n_dict, tile_n=128):
    """Differentiable OVQ chunk attention: Pallas fwd, reference-vjp bwd.

    Gradients flow into q, ke, ve and beta (not bias: counts are discrete
    statistics, matching the paper where the count vector is not a learned
    quantity).
    """
    return ovq_chunk_attn(q, ke, ve, bias, beta, n_dict=n_dict, tile_n=tile_n)


def _ovq_fwd(q, ke, ve, bias, beta, n_dict, tile_n):
    out = ovq_chunk_attn(q, ke, ve, bias, beta, n_dict=n_dict, tile_n=tile_n)
    return out, (q, ke, ve, bias, beta)


def _ovq_bwd(n_dict, tile_n, res, g):
    q, ke, ve, bias, beta = res
    def f(q_, ke_, ve_, beta_):
        return ref.ovq_chunk_attn_ref(q_, ke_, ve_, bias, beta_, n_dict)
    _, vjp = jax.vjp(f, q, ke, ve, beta)
    dq, dke, dve, dbeta = vjp(g)
    return dq, dke, dve, jnp.zeros_like(bias), dbeta


ovq_chunk_attn_ad.defvjp(_ovq_fwd, _ovq_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def swa_attn_ad(q, k, v, beta, window, tile_r=64):
    """Differentiable sliding-window attention: Pallas fwd, reference bwd."""
    return swa_attn(q, k, v, beta, window=window, tile_r=tile_r)


def _swa_fwd(q, k, v, beta, window, tile_r):
    out = swa_attn(q, k, v, beta, window=window, tile_r=tile_r)
    return out, (q, k, v, beta)


def _swa_bwd(window, tile_r, res, g):
    q, k, v, beta = res
    def f(q_, k_, v_, beta_):
        return ref.swa_attn_ref(q_, k_, v_, window, beta_)
    _, vjp = jax.vjp(f, q, k, v, beta)
    return vjp(g)


swa_attn_ad.defvjp(_swa_fwd, _swa_bwd)
