"""Pallas kernel for the OVQ chunk attention (paper eq. 15).

Computes, per (batch, head):

    O = softmax(beta * Q_c [D_k; K_c]^T + log[c; 1] + M) [D_v; V_c]

with a flash-attention-style streaming softmax over column tiles so the
logits matrix is never materialized at full [L, N+L] size. On a real TPU the
two matmuls per tile map onto the MXU and the running max/denominator updates
onto the VPU; the column-tile loop expresses the HBM->VMEM schedule the paper
did with CUDA threadblocks (see DESIGN.md #Hardware-Adaptation).

interpret=True is mandatory on this image: CPU PJRT cannot execute Mosaic
custom-calls. Numerics are identical to the TPU lowering.

Inputs (see kernels/ref.py for the shape conventions):
  q    [B, H, L, d]
  ke   [B, H, NT, d]   NT = n_dict + L, dictionary slots then raw chunk keys
  ve   [B, H, NT, d]
  bias [B, H, NT]      log-counts (NEG_INF for inactive slots), 0 for chunk
beta is traced (scalar array); n_dict and tile_n are static.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF


def _ovq_kernel(beta_ref, q_ref, ke_ref, ve_ref, bias_ref, o_ref, *, n_dict,
                n_total, tile_n):
    """One program instance handles one (batch, head) pair.

    Streaming softmax over column tiles of size tile_n:
      m   running row-max       [L, 1]
      s   running denominator   [L, 1]
      acc running weighted sum  [L, d]
    """
    L, d = q_ref.shape
    beta = beta_ref[0]
    q = q_ref[...]  # [L, d]

    n_tiles = pl.cdiv(n_total, tile_n)
    row = jax.lax.broadcasted_iota(jnp.int32, (L, tile_n), 0)

    def body(i, carry):
        m, s, acc = carry
        start = i * tile_n
        kt = pl.load(ke_ref, (pl.ds(start, tile_n), slice(None)))  # [tn, d]
        bt = pl.load(bias_ref, (pl.ds(start, tile_n),))            # [tn]
        logits = beta * jax.lax.dot_general(
            q, kt, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) + bt[None, :]  # [L, tn]
        # Dictionary columns are always visible; chunk column j only to
        # queries i >= j. The same predicate masks the cdiv padding tail
        # (global col >= n_total fails both branches).
        col = start + jax.lax.broadcasted_iota(jnp.int32, (L, tile_n), 1)
        visible = (col < n_dict) | ((col - n_dict <= row) & (col < n_total))
        logits = jnp.where(visible, logits, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(logits, axis=1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new)  # [L, tn]
        vt = pl.load(ve_ref, (pl.ds(start, tile_n), slice(None)))  # [tn, d]
        acc = acc * alpha + jax.lax.dot_general(
            p, vt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        s = s * alpha + jnp.sum(p, axis=1, keepdims=True)
        return m_new, s, acc

    m0 = jnp.full((L, 1), NEG_INF, jnp.float32)
    s0 = jnp.zeros((L, 1), jnp.float32)
    acc0 = jnp.zeros((L, d), jnp.float32)
    _, s, acc = jax.lax.fori_loop(0, n_tiles, body, (m0, s0, acc0))
    o_ref[...] = (acc / s).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_dict", "tile_n"))
def ovq_chunk_attn(q, ke, ve, bias, beta, *, n_dict, tile_n=128):
    """Pallas OVQ chunk attention. See module docstring for shapes."""
    B, H, L, d = q.shape
    n_total = ke.shape[2]
    tile_n = int(min(tile_n, max(8, n_total)))
    # Pad the column axis to a tile multiple: in-kernel dynamic slices must
    # never clamp (a clamped slice would desynchronize loaded data from the
    # global column indices used by the mask). The mask hides the pad tail.
    if n_total % tile_n != 0:
        cpad = tile_n - n_total % tile_n
        ke = jnp.pad(ke, ((0, 0), (0, 0), (0, cpad), (0, 0)))
        ve = jnp.pad(ve, ((0, 0), (0, 0), (0, cpad), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, 0), (0, cpad)))
    n_cols = ke.shape[2]
    beta_arr = jnp.asarray(beta, jnp.float32).reshape(1)

    kernel = functools.partial(
        _ovq_kernel, n_dict=n_dict, n_total=n_total, tile_n=tile_n
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h: (0,)),
            pl.BlockSpec((None, None, L, d), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, n_cols, d), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, n_cols, d), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, n_cols), lambda b, h: (b, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, L, d), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, L, d), q.dtype),
        interpret=True,
    )(beta_arr, q, ke, ve, bias)
