"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth for the whole stack: the Pallas
kernels (interpret=True) are checked against these under pytest/hypothesis,
and the lowered HLO artifacts inherit that guarantee.

Shapes follow the paper's chunk-parallel formulation (eq. 15):
  q     [B, H, L, d]      queries of the current chunk
  ke    [B, H, N + L, d]  [D_k ; K_c]   (dictionary then raw chunk keys)
  ve    [B, H, N + L, d]  [D_v ; V_c]
  bias  [B, H, N + L]     log-counts for dictionary slots (-inf = inactive),
                          zeros for the raw chunk positions
The causal structure: every query sees all N dictionary slots; query i sees
chunk position j iff j <= i.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30  # finite stand-in for -inf: keeps softmax NaN-free when a
# row has no visible key (cannot happen here: chunk key i is always visible
# to query i) and survives f32<->bf16 round trips.


def ovq_chunk_attn_ref(q, ke, ve, bias, beta, n_dict):
    """Reference for the OVQ chunk-attention kernel (paper eq. 15).

    softmax(beta * q @ ke^T + bias + M) @ ve   with the dictionary-vs-chunk
    causal mask M described in the module docstring.
    """
    B, H, L, d = q.shape
    n_total = ke.shape[2]
    logits = beta * jnp.einsum("bhld,bhnd->bhln", q, ke) + bias[:, :, None, :]
    # mask: columns < n_dict always visible; column n_dict + j visible iff j <= i
    col = jnp.arange(n_total)[None, :]
    row = jnp.arange(L)[:, None]
    visible = (col < n_dict) | ((col - n_dict) <= row)
    logits = jnp.where(visible[None, None], logits, NEG_INF)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhln,bhnd->bhld", p, ve)


def swa_attn_ref(q, k, v, window, beta):
    """Reference sliding-window causal attention.

    q,k,v [B, H, T, d]; query i attends to keys j with i-window < j <= i.
    """
    T = q.shape[2]
    logits = beta * jnp.einsum("bhtd,bhsd->bhts", q, k)
    row = jnp.arange(T)[:, None]
    col = jnp.arange(T)[None, :]
    visible = (col <= row) & (col > row - window)
    logits = jnp.where(visible[None, None], logits, NEG_INF)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhts,bhsd->bhtd", p, v)


def full_attn_ref(q, k, v, beta, causal=True):
    """Reference full (softmax) attention, optionally causal."""
    T, S = q.shape[2], k.shape[2]
    logits = beta * jnp.einsum("bhtd,bhsd->bhts", q, k)
    if causal:
        row = jnp.arange(T)[:, None]
        col = jnp.arange(S)[None, :]
        logits = jnp.where((col <= row)[None, None], logits, NEG_INF)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhts,bhsd->bhtd", p, v)
