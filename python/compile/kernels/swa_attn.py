"""Pallas kernel for sliding-window causal attention.

Query i attends to keys j with  i - window < j <= i.  The grid tiles rows;
each program loads the static-size column slab [row_start - window + 1,
row_start + tile_r) that covers every key its row tile can see (clamped to 0
with in-kernel masking for the left edge), so the work per program is
O(tile_r * (tile_r + window)) regardless of sequence length — the banded
structure of the paper's sw layers.

interpret=True — see ovq_attn.py for why.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF


def _swa_kernel(beta_ref, q_ref, k_ref, v_ref, o_ref, *, window, tile_r, seq_len):
    r = pl.program_id(2)
    row_start = r * tile_r
    L, d = q_ref.shape  # L == tile_r
    beta = beta_ref[0]
    q = q_ref[...]

    slab = tile_r + window  # static column slab size
    # Desired global start is row_start - window + 1; clamp to 0 and mask.
    start = jnp.maximum(row_start - window + 1, 0)
    # Keep the slab fully in-bounds: pl.ds with a dynamic start clamps like
    # lax.dynamic_slice, but we mask with *global* indices computed from the
    # same clamped start so logits always match their true positions.
    start = jnp.minimum(start, jnp.maximum(seq_len - slab, 0))
    kt = pl.load(k_ref, (pl.ds(start, slab), slice(None)))  # [slab, d]
    vt = pl.load(v_ref, (pl.ds(start, slab), slice(None)))

    logits = beta * jax.lax.dot_general(
        q, kt, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [L, slab]
    grow = row_start + jax.lax.broadcasted_iota(jnp.int32, (L, slab), 0)
    gcol = start + jax.lax.broadcasted_iota(jnp.int32, (L, slab), 1)
    visible = (gcol <= grow) & (gcol > grow - window) & (grow < seq_len)
    logits = jnp.where(visible, logits, NEG_INF)

    m = jnp.max(logits, axis=1, keepdims=True)
    p = jnp.exp(logits - m)
    s = jnp.sum(p, axis=1, keepdims=True)
    o = jax.lax.dot_general(
        p, vt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) / s
    o_ref[...] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "tile_r"))
def swa_attn(q, k, v, beta, *, window, tile_r=64):
    """Pallas sliding-window causal attention; q,k,v [B,H,T,d]."""
    B, H, T, d = q.shape
    tile_r = int(min(tile_r, T))
    if T % tile_r != 0:
        # pad rows to a tile multiple; masked out via grow < seq_len
        pad = tile_r - T % tile_r
        qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    else:
        pad = 0
        qp = q
    Tp = T + pad
    # K/V must be at least one column slab long so in-kernel dynamic slices
    # stay in bounds; masking handles the padded tail (gcol < seq_len).
    Tk = max(Tp, tile_r + int(window))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Tk - T), (0, 0))) if Tk > T else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Tk - T), (0, 0))) if Tk > T else v
    beta_arr = jnp.asarray(beta, jnp.float32).reshape(1)
    kernel = functools.partial(
        _swa_kernel, window=int(window), tile_r=tile_r, seq_len=T
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, Tp // tile_r),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, r: (0,)),
            pl.BlockSpec((None, None, tile_r, d), lambda b, h, r: (b, h, r, 0)),
            pl.BlockSpec((None, None, Tk, d), lambda b, h, r: (b, h, 0, 0)),
            pl.BlockSpec((None, None, Tk, d), lambda b, h, r: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, tile_r, d), lambda b, h, r: (b, h, r, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Tp, d), q.dtype),
        interpret=True,
    )(beta_arr, qp, kp, vp)
    return out[:, :, :T] if pad else out
