"""L2 layer library: mixers and building blocks for the hybrid transformer.

Every mixer follows the same functional convention:

    init_<mixer>(key, cfg)                  -> params (pytree of arrays)
    <mixer>_forward(params, x, cfg)         -> (y, aux_loss)

with x, y of shape [B, T, D]. aux_loss is a scalar (0.0 for mixers without
auxiliary objectives; VQ-attention returns its commitment/codebook loss).
"""

from . import common, attn, ovq, vq, gdn, linattn, ssd  # noqa: F401
