"""Softmax attention mixers: full attention (NoPE or RoPE) and
sliding-window attention (RoPE) backed by the Pallas SWA kernel."""

from __future__ import annotations

import jax.numpy as jnp

from ..kernels.ad import swa_attn_ad
from ..kernels.ref import full_attn_ref
from . import common


def init_full_attn(key, cfg):
    return common.qkv_init(key, cfg["dim"], cfg["heads"], cfg["d_head"])


def full_attn_forward(params, x, cfg):
    """Full causal attention. cfg['rope']: True -> RoPE, False -> NoPE.

    The quadratic form is intentional: this is the paper's *baseline*
    (std-att / the full-attention half of sw-nope), not the contribution.
    """
    heads, d_head = cfg["heads"], cfg["d_head"]
    q, k, v = common.project_qkv(params, x, heads, d_head)
    if cfg.get("rope", False):
        pos = jnp.arange(x.shape[1])
        q = common.apply_rope(q, pos)
        k = common.apply_rope(k, pos)
    o = full_attn_ref(q, k, v, 1.0, causal=True)  # beta pre-folded into q
    return common.merge_heads(params, o), jnp.zeros(())


def init_swa(key, cfg):
    return common.qkv_init(key, cfg["dim"], cfg["heads"], cfg["d_head"])


def swa_forward(params, x, cfg):
    """Sliding-window attention with RoPE (window cfg['window'])."""
    heads, d_head = cfg["heads"], cfg["d_head"]
    q, k, v = common.project_qkv(params, x, heads, d_head)
    pos = jnp.arange(x.shape[1])
    q = common.apply_rope(q, pos)
    k = common.apply_rope(k, pos)
    o = swa_attn_ad(q, k, v, jnp.float32(1.0), cfg["window"],
                    cfg.get("tile_r", 64))
    return common.merge_heads(params, o), jnp.zeros(())
