"""Shared building blocks: norms, MLP, RoPE, projections, initializers."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------- init utils

def dense_init(key, fan_in, fan_out, scale=1.0):
    """Truncated-normal-ish dense init (normal / sqrt(fan_in))."""
    return (scale / jnp.sqrt(fan_in)) * jax.random.normal(
        key, (fan_in, fan_out), jnp.float32
    )


def embed_init(key, vocab, dim):
    return 0.02 * jax.random.normal(key, (vocab, dim), jnp.float32)


# ---------------------------------------------------------------------- norm

def rmsnorm_init(dim):
    return {"g": jnp.ones((dim,), jnp.float32)}


def rmsnorm(params, x, eps=1e-6):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return params["g"] * x * jax.lax.rsqrt(ms + eps)


def unit_norm(x, eps=1e-6):
    """L2-normalize the last axis (paper: unit-norm queries/keys/centroids)."""
    n = jnp.sqrt(jnp.sum(jnp.square(x), axis=-1, keepdims=True) + eps)
    return x / n


# ----------------------------------------------------------------------- mlp

def mlp_init(key, dim, hidden):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, dim, hidden),
        "w_up": dense_init(k2, dim, hidden),
        "w_down": dense_init(k3, hidden, dim),
    }


def mlp(params, x):
    """SwiGLU MLP."""
    g = jax.nn.silu(x @ params["w_gate"])
    u = x @ params["w_up"]
    return (g * u) @ params["w_down"]


# ---------------------------------------------------------------------- rope

def rope_freqs(d_head, base=10000.0):
    inv = 1.0 / (base ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))
    return inv  # [d_head/2]


def apply_rope(x, positions, base=10000.0):
    """Rotate x [B, H, T, d] by per-position angles; positions [T] or [B,T]."""
    d = x.shape[-1]
    inv = rope_freqs(d, base)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * inv[None, :]  # [T, d/2]
        ang = ang[None, None]  # [1,1,T,d/2]
    else:
        ang = positions[:, :, None].astype(jnp.float32) * inv[None, None, :]
        ang = ang[:, None]  # [B,1,T,d/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    return jnp.stack([y1, y2], axis=-1).reshape(x.shape)


# ------------------------------------------------------------- qkv plumbing

def qkv_init(key, dim, heads, d_head, beta0=8.0):
    """Projections + learned per-head scale beta (paper 8.1/8.2/8.3).

    beta is stored as log(beta0) and exponentiated at use: keeps it positive
    and gives multiplicative learning dynamics.
    """
    k1, k2, k3, k4 = jax.random.split(key, 4)
    hd = heads * d_head
    return {
        "w_q": dense_init(k1, dim, hd),
        "w_k": dense_init(k2, dim, hd),
        "w_v": dense_init(k3, dim, hd),
        "w_o": dense_init(k4, hd, dim),
        "log_beta": jnp.full((heads,), jnp.log(beta0), jnp.float32),
    }


def project_qkv(params, x, heads, d_head, normalize_qk=True):
    """x [B,T,D] -> q,k,v [B,H,T,d]; q is pre-scaled by per-head beta.

    Pre-scaling q by beta is mathematically identical to passing a per-head
    beta into the attention kernels (which take a single scalar).
    """
    B, T, _ = x.shape

    def split(h):
        return h.reshape(B, T, heads, d_head).transpose(0, 2, 1, 3)

    q = split(x @ params["w_q"])
    k = split(x @ params["w_k"])
    v = split(x @ params["w_v"])
    if normalize_qk:
        q = unit_norm(q)
        k = unit_norm(k)
    beta = jnp.exp(params["log_beta"])  # [H]
    q = q * beta[None, :, None, None]
    return q, k, v


def merge_heads(params, o):
    """o [B,H,T,d] -> [B,T,D] through the output projection."""
    B, H, T, d = o.shape
    return o.transpose(0, 2, 1, 3).reshape(B, T, H * d) @ params["w_o"]


# ------------------------------------------------------- short conv / vshift

def conv_shift_init():
    """Learned mixing scalars for qk-conv + v-shift (paper App. C)."""
    return {"alpha_qk": jnp.zeros(()), "alpha_v": jnp.zeros(())}


def qk_short_conv(x, alpha):
    """Depthwise width-2 causal conv: x_t' = s*x_t + (1-s)*x_{t-1}."""
    s = jax.nn.sigmoid(alpha)
    prev = jnp.pad(x, ((0, 0), (0, 0), (1, 0), (0, 0)))[:, :, :-1]
    return s * x + (1.0 - s) * prev


def v_shift(v, alpha):
    """Associate k_t with a mix of v_t and v_{t+1}, then shift to keep
    causality (paper App. C: v_{t+1/2} construction, keys/values shifted)."""
    s = jax.nn.sigmoid(alpha)
    nxt = jnp.pad(v, ((0, 0), (0, 0), (0, 1), (0, 0)))[:, :, 1:]
    mixed = s * v + (1.0 - s) * nxt
    # shift one step so position t holds v_{t-1+1/2} (no future leakage)
    return jnp.pad(mixed, ((0, 0), (0, 0), (1, 0), (0, 0)))[:, :, :-1]


# ------------------------------------------------------------------- growth

def growth_schedule(n_max, chunk_len, n_chunks, linear=False):
    """Paper eqs. 17-18: number of new centroids added per chunk.

    Returns an int32 array [n_chunks]. Plateauing: N_t = t*N/(t+N); the
    linear ablation divides the same final total evenly across chunks.
    """
    import numpy as np

    t = np.arange(0, n_chunks + 1) * chunk_len
    n_t = np.floor(t * n_max / np.maximum(t + n_max, 1)).astype(np.int64)
    if linear:
        total = int(n_t[-1])
        base = total // n_chunks
        extra = total % n_chunks
        out = np.full(n_chunks, base, np.int64)
        out[:extra] += 1
    else:
        out = n_t[1:] - n_t[:-1]
    assert out.max() <= chunk_len, "growth cannot exceed chunk length"
    return jnp.asarray(out, jnp.int32)
