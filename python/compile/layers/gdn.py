"""Gated Delta Net (Yang et al. 2024a) — the strongest constant-memory
baseline in the paper (gdn / gdn-ovq interleaves, Figs. 6 and 8).

Recurrence per token (delta rule with a scalar forget gate per head):

    S_t = alpha_t * S_{t-1} + beta_t * k_t^T (v_t - k_t S_{t-1})
    o_t = q_t S_t

alpha_t = sigmoid(w_a x_t), beta_t = sigmoid(w_b x_t) are data-dependent.
Implemented as a token-level lax.scan: exact, simple, and fast enough at
this repo's scales (the chunkwise WY form is a pure-throughput optimization
that does not change numerics).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common


def init_gdn(key, cfg):
    p = common.qkv_init(key, cfg["dim"], cfg["heads"], cfg["d_head"])
    k1, k2 = jax.random.split(key, 2)
    p["w_alpha"] = common.dense_init(k1, cfg["dim"], cfg["heads"], scale=0.1)
    p["w_beta"] = common.dense_init(k2, cfg["dim"], cfg["heads"], scale=0.1)
    return p


def gdn_forward(params, x, cfg):
    B, T, D = x.shape
    heads, d_head = cfg["heads"], cfg["d_head"]

    q, k, v = common.project_qkv(params, x, heads, d_head)
    # gates: bias toward remembering (alpha near 1) at init
    alpha = jax.nn.sigmoid(x @ params["w_alpha"] + 4.0)  # [B,T,H]
    beta = jax.nn.sigmoid(x @ params["w_beta"])          # [B,T,H]

    qs = q.transpose(2, 0, 1, 3)  # [T,B,H,d]
    ks = k.transpose(2, 0, 1, 3)
    vs = v.transpose(2, 0, 1, 3)
    als = alpha.transpose(1, 0, 2)  # [T,B,H]
    bes = beta.transpose(1, 0, 2)

    def step(S, xs):
        qt, kt, vt, at, bt = xs  # [B,H,d], gates [B,H]
        pred = jnp.einsum("bhd,bhde->bhe", kt, S)          # k_t S
        S = at[..., None, None] * S + bt[..., None, None] * jnp.einsum(
            "bhd,bhe->bhde", kt, vt - pred)
        o = jnp.einsum("bhd,bhde->bhe", qt, S)
        return S, o

    S0 = jnp.zeros((B, heads, d_head, d_head), x.dtype)
    _, outs = jax.lax.scan(step, S0, (qs, ks, vs, als, bes))
    o = outs.transpose(1, 2, 0, 3)  # [B,H,T,d]
    return common.merge_heads(params, o), jnp.zeros(())
