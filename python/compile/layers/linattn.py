"""Vanilla linear attention (Katharopoulos et al. 2020) — constant-memory
baseline with the dense rank-1 state update the paper contrasts against
(Fig. 3 / §3.4).

Chunk-parallel form: carry S = sum phi(k)^T v and z = sum phi(k); per chunk
the intra-chunk causal part is a masked quadratic over the (small) chunk and
the inter-chunk part reads the carried state. phi = elu + 1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common


def init_linattn(key, cfg):
    return common.qkv_init(key, cfg["dim"], cfg["heads"], cfg["d_head"])


def _phi(x):
    return jax.nn.elu(x) + 1.0


def linattn_forward(params, x, cfg):
    B, T, D = x.shape
    heads, d_head = cfg["heads"], cfg["d_head"]
    L = cfg["chunk"]

    q, k, v = common.project_qkv(params, x, heads, d_head, normalize_qk=False)
    q, k = _phi(q), _phi(k)

    pad = (-T) % L
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    C = Tp // L

    def chunked(a):
        return a.reshape(B, heads, C, L, d_head).transpose(2, 0, 1, 3, 4)

    qs, ks, vs = chunked(q), chunked(k), chunked(v)
    mask = jnp.tril(jnp.ones((L, L), x.dtype))

    def step(carry, xs):
        S, z = carry  # S [B,H,d,d], z [B,H,d]
        qc, kc, vc = xs
        inter = jnp.einsum("bhld,bhde->bhle", qc, S)
        intra_w = jnp.einsum("bhld,bhmd->bhlm", qc, kc) * mask[None, None]
        intra = jnp.einsum("bhlm,bhme->bhle", intra_w, vc)
        den = jnp.einsum("bhld,bhd->bhl", qc, z) + jnp.sum(intra_w, axis=-1)
        o = (inter + intra) / jnp.maximum(den, 1e-6)[..., None]
        S = S + jnp.einsum("bhld,bhle->bhde", kc, vc)
        z = z + jnp.sum(kc, axis=2)
        return (S, z), o

    S0 = jnp.zeros((B, heads, d_head, d_head), x.dtype)
    z0 = jnp.zeros((B, heads, d_head), x.dtype)
    _, outs = jax.lax.scan(step, (S0, z0), (qs, ks, vs))
    o = outs.transpose(1, 2, 0, 3, 4).reshape(B, heads, Tp, d_head)[:, :, :T]
    return common.merge_heads(params, o), jnp.zeros(())
