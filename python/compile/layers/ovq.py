"""OVQ-attention — the paper's contribution (Section 3).

Chunk-parallel online Gaussian-mixture-regression layer:

  per chunk c (lax.scan):
    1. predict  (eq. 15): Pallas chunk-attention over [D_k; K_c] with
       log-count bias and causal in-chunk mask;
    2. grow     (eqs. 17-18): n_new spread-maximizing new centroids
       (lowest max-similarity items of the chunk);
    3. update   (eq. 19): merge remaining items into their nearest centroid
       with the adaptive 1/(c + c_chunk) learning rate — the online k-means
       / single-EM / Newton step of Appendix A.

State per (batch, head): D_k, D_v in R^{N x d}, counts in R^N, plus the
scalar active-size driven by the plateauing growth schedule N_t = tN/(t+N).
Inactive slots carry count 0 and are masked with a -inf bias; all shapes are
static (jit-friendly), exactly the trick a TPU implementation needs.

The scatter of the paper's pseudo-code (App. 8.3) is re-expressed as one-hot
matmuls (A^T K_c), which is both MXU-friendly and differentiable: gradients
flow into K_c/V_c through the weighted-sum merge — no straight-through
estimator, as the paper highlights.

Ablation flags (Fig. 7/11/12): cfg['rand_assign'], cfg['linear_growth'],
cfg['const_lr']. Extensions (App. C): cfg['rope'] (rotate current+previous
chunk, dictionary at position 0), cfg['vshift'] (v-shift + qk short conv).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.ad import ovq_chunk_attn_ad
from . import common
from .common import NEG_INF


def init_ovq(key, cfg):
    p = common.qkv_init(key, cfg["dim"], cfg["heads"], cfg["d_head"])
    if cfg.get("vshift", False):
        p["conv"] = common.conv_shift_init()
    return p


def _rank(values, ascending=True):
    """Rank of each element along the last axis (0 = smallest).

    The clustering decision is hard/non-differentiable (paper §3.2):
    stop_gradient keeps autodiff from tracing sort's JVP (gradients flow
    through the count-weighted merge, not the assignment)."""
    values = jax.lax.stop_gradient(values)
    order = jnp.argsort(values if ascending else -values, axis=-1)
    return jnp.argsort(order, axis=-1)


def nn_assignments(D_k, counts, kc):
    """Nearest active centroid for each chunk key: (best_idx, best_sim).

    Key-only similarity, not [k,v]-similarity — the paper found this works
    equally well at half the compute (App. 8.3)."""
    sims = jnp.einsum("bhld,bhnd->bhln", kc, D_k)
    sims = jnp.where((counts > 0)[:, :, None, :], sims, NEG_INF)
    return jnp.argmax(sims, axis=-1), jnp.max(sims, axis=-1)


def ovq_update(D_k, D_v, counts, n_active, kc, vc, n_new, best_idx, priority,
               cfg):
    """One online GMM update for chunk (kc, vc): grow + merge.

    best_idx: [B,H,L] nearest-centroid assignment from nn_assignments.
    priority: [B,H,L] values whose *lowest* n_new entries become the new
    centroids. The paper's scheme passes the max-similarity to the existing
    dictionary; the rand_assign ablation passes random values.
    Returns the new (D_k, D_v, counts, n_active).
    """
    B, H, L, d = kc.shape
    N = D_k.shape[2]

    # spread-maximizing growth: lowest-priority items become new centroids
    rank = _rank(priority, ascending=True)
    is_new = rank < n_new  # [B,H,L]
    new_ord = jnp.cumsum(is_new.astype(jnp.int32), axis=-1) - 1
    assign = jnp.where(is_new, n_active + new_ord, best_idx)  # [B,H,L]

    A = jax.nn.one_hot(assign, N, dtype=kc.dtype)  # [B,H,L,N]
    cc = jnp.sum(A, axis=2)  # [B,H,N] chunk counts
    sum_k = jnp.einsum("bhln,bhld->bhnd", A, kc)
    sum_v = jnp.einsum("bhln,bhld->bhnd", A, vc)

    counts_new = counts + cc
    denom = jnp.maximum(counts_new, 1.0)[..., None]
    touched = (cc > 0)[..., None]
    if cfg.get("const_lr", False):
        # first-order ablation: fixed-lr k-means step (gradient descent on
        # the k-means loss instead of the Newton/EM step). Fresh slots are
        # still seeded with the chunk mean (a zero vector is not a centroid).
        lr = cfg.get("const_lr_value", 0.025)
        fresh = ((counts == 0.0) & (cc > 0))[..., None]
        ccn = jnp.maximum(cc, 1.0)[..., None]
        seeded = sum_k / ccn
        stepped = D_k + lr * (sum_k - cc[..., None] * D_k)
        D_k_new = jnp.where(fresh, seeded, jnp.where(touched, stepped, D_k))
        seeded_v = sum_v / ccn
        stepped_v = D_v + lr * (sum_v - cc[..., None] * D_v)
        D_v_new = jnp.where(fresh, seeded_v, jnp.where(touched, stepped_v, D_v))
    else:
        # eq. 19 in exact batch form: the count-weighted mean merge
        # mu' = (c*mu + sum_x) / (c + c_chunk)  — adaptive lr 1/(c+cc).
        D_k_new = jnp.where(touched, (counts[..., None] * D_k + sum_k) / denom, D_k)
        D_v_new = jnp.where(touched, (counts[..., None] * D_v + sum_v) / denom, D_v)

    if cfg.get("norm_dict", False):
        D_k_new = jnp.where(counts_new[..., None] > 0,
                            common.unit_norm(D_k_new), D_k_new)

    return D_k_new, D_v_new, counts_new, n_active + n_new


def ovq_forward(params, x, cfg):
    """OVQ-attention over x [B,T,D]. Returns (y [B,T,D], aux_loss=0)."""
    B, T, D = x.shape
    heads, d_head = cfg["heads"], cfg["d_head"]
    L = cfg["chunk"]
    N = cfg["n_dict"]
    tile_n = cfg.get("tile_n", 128)
    use_rope = cfg.get("rope", False)

    q, k, v = common.project_qkv(params, x, heads, d_head)
    if cfg.get("vshift", False):
        q = common.qk_short_conv(q, params["conv"]["alpha_qk"])
        k = common.qk_short_conv(k, params["conv"]["alpha_qk"])
        k = jnp.pad(k, ((0, 0), (0, 0), (1, 0), (0, 0)))[:, :, :-1]
        v = common.v_shift(v, params["conv"]["alpha_v"])

    pad = (-T) % L
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    C = Tp // L

    n_new = common.growth_schedule(N, L, C, linear=cfg.get("linear_growth", False))
    if cfg.get("rand_assign", False):
        prio = jax.random.uniform(jax.random.PRNGKey(cfg.get("seed", 0)),
                                  (C, B, heads, L))
    else:
        prio = None

    # [C, B, H, L, d] chunked views as scan inputs
    def chunked(a):
        return a.reshape(B, heads, C, L, d_head).transpose(2, 0, 1, 3, 4)

    qs, ks, vs = chunked(q), chunked(k), chunked(v)

    D_k0 = jnp.zeros((B, heads, N, d_head), x.dtype)
    D_v0 = jnp.zeros((B, heads, N, d_head), x.dtype)
    counts0 = jnp.zeros((B, heads, N), jnp.float32)
    n_active0 = jnp.zeros((), jnp.int32)
    if use_rope:
        pk0 = jnp.zeros((B, heads, L, d_head), x.dtype)
        pv0 = jnp.zeros((B, heads, L, d_head), x.dtype)
        pbias0 = jnp.full((B, heads, L), NEG_INF, jnp.float32)
        carry0 = (D_k0, D_v0, counts0, n_active0, pk0, pv0, pbias0)
    else:
        carry0 = (D_k0, D_v0, counts0, n_active0)

    pos_prev = jnp.arange(1, L + 1)
    pos_cur = jnp.arange(L + 1, 2 * L + 1)

    def step(carry, xs):
        if cfg.get("rand_assign", False):
            qc, kc, vc, nn, pr = xs
        else:
            qc, kc, vc, nn = xs
            pr = None

        if use_rope:
            D_k, D_v, counts, n_active, pk, pv, pbias = carry
            # dictionary at position 0 (identity rotation); previous chunk
            # at positions 1..L; current chunk (and queries) at L+1..2L.
            qr = common.apply_rope(qc, pos_cur)
            kr = common.apply_rope(kc, pos_cur)
            pkr = common.apply_rope(pk, pos_prev)
            bias_d = jnp.where(counts > 0, jnp.log(jnp.maximum(counts, 1e-9)),
                               NEG_INF)
            ke = jnp.concatenate([D_k, pkr, kr], axis=2)
            ve = jnp.concatenate([D_v, pv, vc], axis=2)
            bias = jnp.concatenate(
                [bias_d, pbias, jnp.zeros((B, heads, L), jnp.float32)], axis=2)
            o = ovq_chunk_attn_ad(qr, ke, ve, bias, jnp.float32(1.0),
                                  N + L, tile_n)
        else:
            D_k, D_v, counts, n_active = carry
            bias_d = jnp.where(counts > 0, jnp.log(jnp.maximum(counts, 1e-9)),
                               NEG_INF)
            ke = jnp.concatenate([D_k, kc], axis=2)
            ve = jnp.concatenate([D_v, vc], axis=2)
            bias = jnp.concatenate(
                [bias_d, jnp.zeros((B, heads, L), jnp.float32)], axis=2)
            o = ovq_chunk_attn_ad(qc, ke, ve, bias, jnp.float32(1.0),
                                  N, tile_n)

        best_idx, best_sim = nn_assignments(D_k, counts, kc)
        pr_eff = best_sim if pr is None else pr
        D_k, D_v, counts, n_active = ovq_update(
            D_k, D_v, counts, n_active, kc, vc, nn, best_idx, pr_eff, cfg)

        if use_rope:
            new_carry = (D_k, D_v, counts, n_active, kc, vc,
                         jnp.zeros((B, heads, L), jnp.float32))
        else:
            new_carry = (D_k, D_v, counts, n_active)
        return new_carry, o

    xs = (qs, ks, vs, n_new) + ((prio,) if prio is not None else ())
    _, outs = jax.lax.scan(step, carry0, xs)
    o = outs.transpose(1, 2, 0, 3, 4).reshape(B, heads, Tp, d_head)[:, :, :T]
    return common.merge_heads(params, o), jnp.zeros(())
