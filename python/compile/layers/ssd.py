"""mamba2-lite: an SSD-style scalar-decay state-space mixer (Dao & Gu 2024).

    S_t = a_t * S_{t-1} + k_t^T v_t         a_t = sigmoid(w_a x_t + b)
    o_t = q_t S_t

i.e. gated linear attention with a data-dependent scalar decay — the
structured-state-space-duality core of mamba-2, without the conv/gating
trimmings (those are orthogonal to the memory-capacity question the paper's
Fig. 8 probes). Chunk-parallel implementation with exact intra-chunk decay
weighting.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common


def init_ssd(key, cfg):
    p = common.qkv_init(key, cfg["dim"], cfg["heads"], cfg["d_head"])
    k1 = jax.random.split(key, 1)[0]
    p["w_a"] = common.dense_init(k1, cfg["dim"], cfg["heads"], scale=0.1)
    return p


def ssd_forward(params, x, cfg):
    B, T, D = x.shape
    heads, d_head = cfg["heads"], cfg["d_head"]
    L = cfg["chunk"]

    q, k, v = common.project_qkv(params, x, heads, d_head)
    a = jax.nn.sigmoid(x @ params["w_a"] + 4.0)  # [B,T,H], decay near 1

    pad = (-T) % L
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    Tp = T + pad
    C = Tp // L

    def chunked(t):
        return t.reshape(B, heads, C, L, d_head).transpose(2, 0, 1, 3, 4)

    qs, ks, vs = chunked(q), chunked(k), chunked(v)
    as_ = a.transpose(0, 2, 1).reshape(B, heads, C, L).transpose(2, 0, 1, 3)

    def step(S, xs):
        qc, kc, vc, ac = xs  # ac [B,H,L]
        # cumulative decay within the chunk: g_i = prod_{j<=i} a_j
        g = jnp.cumprod(ac, axis=-1)  # [B,H,L]
        g_safe = jnp.maximum(g, 1e-20)
        # inter-chunk: q_i reads S decayed by g_i
        inter = g[..., None] * jnp.einsum("bhld,bhde->bhle", qc, S)
        # intra-chunk: weight between i,j is g_i / g_j for j <= i
        w = jnp.einsum("bhld,bhmd->bhlm", qc, kc)
        ratio = g_safe[..., :, None] / g_safe[..., None, :]
        mask = jnp.tril(jnp.ones((L, L), x.dtype))
        w = w * ratio * mask[None, None]
        intra = jnp.einsum("bhlm,bhme->bhle", w, vc)
        o = inter + intra
        # carry: decay whole chunk product, add decayed outer products
        gL = g[..., -1:]  # [B,H,1]
        S = gL[..., None] * S + jnp.einsum(
            "bhl,bhld,bhle->bhde", gL / g_safe, kc, vc)
        return S, o

    S0 = jnp.zeros((B, heads, d_head, d_head), x.dtype)
    _, outs = jax.lax.scan(step, S0, (qs, ks, vs, as_))
    o = outs.transpose(1, 2, 0, 3, 4).reshape(B, heads, Tp, d_head)[:, :, :T]
    return common.merge_heads(params, o), jnp.zeros(())
