"""VQ-attention (Lingle 2023) — the baseline OVQ improves upon.

Key dictionary D_k is a *pretrained parameter* (learned in the outer loop);
keys are replaced by their nearest centroid through a straight-through
estimator. The value dictionary D_v and counts are computed online, exactly
as in the original: the chunked linear form (paper eqs. 8-10) where chunk c
attends to

    [ D_k with counts through chunk c-2 | quantized chunk c-1 | quantized
      chunk c (causal) ]

which this implementation maps onto the same Pallas chunk-attention kernel
by treating [D_k ; K̂_{c-1}] as an extended always-visible "dictionary"
region with biases [log c_{c-2} ; 0].

Dictionary training substitution (DESIGN.md §2.3): instead of DiVeq we use
the classic VQ-VAE recipe — STE + commitment loss + codebook loss — plus a
dead-centroid reactivation penalty (a growing similarity bonus for unused
centroids, the paper's own "no-use penalty" from App. C Fig 14).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.ad import ovq_chunk_attn_ad
from . import common
from .common import NEG_INF


def init_vq(key, cfg):
    p = common.qkv_init(key, cfg["dim"], cfg["heads"], cfg["d_head"])
    kd = jax.random.split(key, 1)[0]
    # unit-norm centroids (paper 8.1: normalized centroids and keys)
    dk = jax.random.normal(kd, (cfg["heads"], cfg["n_dict"], cfg["d_head"]))
    p["dict_k"] = common.unit_norm(dk)
    # similarity bonus for rarely-used centroids (dead-centroid penalty);
    # not a trained weight: updated by the aux loss gradient only through
    # dict_k. Tracked as an EMA-free counter folded into the aux loss.
    return p


def quantize_keys(dict_k, k, penalty_scale=0.0, usage=None):
    """Nearest-centroid quantization with straight-through estimator.

    k [B,H,T,d]; dict_k [H,N,d] (unit-norm). Returns (k_q, idx, aux) where
    k_q carries gradients to both k (STE) and dict_k (codebook loss is
    returned separately in aux).
    """
    dk = common.unit_norm(dict_k)
    sims = jnp.einsum("bhtd,hnd->bhtn", k, dk)
    if usage is not None:
        sims = sims + penalty_scale * (1.0 / (1.0 + usage))[None, :, None, :]
    idx = jnp.argmax(sims, axis=-1)  # [B,H,T]
    k_hat = jnp.einsum(
        "bhtn,hnd->bhtd", jax.nn.one_hot(idx, dk.shape[1], dtype=k.dtype), dk)
    # straight-through: forward k_hat, backward identity to k
    k_q = k + jax.lax.stop_gradient(k_hat - k)
    commit = jnp.mean(jnp.square(k - jax.lax.stop_gradient(k_hat)))
    codebook = jnp.mean(jnp.square(jax.lax.stop_gradient(k) - k_hat))
    aux = 0.25 * commit + codebook
    return k_q, idx, aux


def vq_forward(params, x, cfg):
    """Chunked linear-time VQ-attention. Returns (y, aux_loss)."""
    B, T, D = x.shape
    heads, d_head = cfg["heads"], cfg["d_head"]
    L = cfg["chunk"]
    N = cfg["n_dict"]
    tile_n = cfg.get("tile_n", 128)

    q, k, v = common.project_qkv(params, x, heads, d_head)

    pad = (-T) % L
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    C = Tp // L

    k_q, idx, aux = quantize_keys(params["dict_k"], k)

    def chunked(a):
        return a.reshape(B, heads, C, L, d_head).transpose(2, 0, 1, 3, 4)

    qs, kqs, vs = chunked(q), chunked(k_q), chunked(v)
    idxs = idx.reshape(B, heads, C, L).transpose(2, 0, 1, 3)

    dk = common.unit_norm(params["dict_k"])
    Dk_bcast = jnp.broadcast_to(dk[None], (B, heads, N, d_head))

    # carry: online value dictionary + counts at level c-2, and the previous
    # chunk's quantized keys / values (level c-1), with a validity bias.
    D_v0 = jnp.zeros((B, heads, N, d_head), x.dtype)
    counts0 = jnp.zeros((B, heads, N), jnp.float32)
    pk0 = jnp.zeros((B, heads, L, d_head), x.dtype)
    pv0 = jnp.zeros((B, heads, L, d_head), x.dtype)
    pidx0 = jnp.zeros((B, heads, L), jnp.int32)
    pbias0 = jnp.full((B, heads, L), NEG_INF, jnp.float32)

    def step(carry, xs):
        D_v, counts, pk, pv, pidx, pbias = carry
        qc, kqc, vc, ic = xs
        bias_d = jnp.where(counts > 0, jnp.log(jnp.maximum(counts, 1e-9)),
                           NEG_INF)
        # extended dictionary region = [D_k (counts c-2) ; K̂_{c-1} (bias
        # from validity)] — fully visible; current chunk causal.
        ke = jnp.concatenate([Dk_bcast, pk, kqc], axis=2)
        ve = jnp.concatenate([D_v, pv, vc], axis=2)
        bias = jnp.concatenate(
            [bias_d, pbias, jnp.zeros((B, heads, L), jnp.float32)], axis=2)
        o = ovq_chunk_attn_ad(qc, ke, ve, bias, jnp.float32(1.0),
                              N + L, tile_n)

        # merge chunk c-1 into the online value dictionary (count-weighted
        # mean, same merge rule as the linear-form proof in Lingle 2023).
        # pbias == NEG_INF on the first step -> A masked to zero.
        valid = (pbias > NEG_INF / 2).astype(x.dtype)  # [B,H,L]
        A = jax.nn.one_hot(pidx, N, dtype=x.dtype) * valid[..., None]
        cc = jnp.sum(A, axis=2)
        sum_v = jnp.einsum("bhln,bhld->bhnd", A, pv)
        counts_new = counts + cc
        denom = jnp.maximum(counts_new, 1.0)[..., None]
        touched = (cc > 0)[..., None]
        D_v_new = jnp.where(touched,
                            (counts[..., None] * D_v + sum_v) / denom, D_v)
        new_carry = (D_v_new, counts_new, kqc, vc, ic,
                     jnp.zeros((B, heads, L), jnp.float32))
        return new_carry, o

    _, outs = jax.lax.scan(step, (D_v0, counts0, pk0, pv0, pidx0, pbias0),
                           (qs, kqs, vs, idxs))
    o = outs.transpose(1, 2, 0, 3, 4).reshape(B, heads, Tp, d_head)[:, :, :T]
    return common.merge_heads(params, o), aux
