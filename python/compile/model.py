"""L2 model: hybrid transformer interleaving sliding-window attention with
the paper's sequence mixers (OVQ / VQ / full attention / GDN / linear
attention / SSD), plus loss and eval heads.

A model is described by a plain JSON-serializable config dict (see
configs.py) whose 'pattern' lists the mixer of each block, e.g.
['swa', 'ovq', 'swa', 'ovq'] = the paper's sw-ovq interleave.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import attn, common, gdn, linattn, ovq, ssd, vq

# mixer name -> (init_fn, forward_fn, cfg overrides)
MIXERS = {
    "swa": (attn.init_swa, attn.swa_forward, {}),
    "attn_nope": (attn.init_full_attn, attn.full_attn_forward, {"rope": False}),
    "attn_rope": (attn.init_full_attn, attn.full_attn_forward, {"rope": True}),
    "ovq": (ovq.init_ovq, ovq.ovq_forward, {"rope": False}),
    "ovq_rope": (ovq.init_ovq, ovq.ovq_forward, {"rope": True}),
    "vq": (vq.init_vq, vq.vq_forward, {}),
    "gdn": (gdn.init_gdn, gdn.gdn_forward, {}),
    "linattn": (linattn.init_linattn, linattn.linattn_forward, {}),
    "ssd": (ssd.init_ssd, ssd.ssd_forward, {}),
}


def mixer_cfg(cfg, name):
    _, _, over = MIXERS[name]
    out = dict(cfg)
    out.update(over)
    return out


def init_params(key, cfg):
    """Initialize the full parameter pytree for config cfg."""
    keys = jax.random.split(key, len(cfg["pattern"]) + 3)
    blocks = []
    for i, name in enumerate(cfg["pattern"]):
        init_fn, _, _ = MIXERS[name]
        bk = jax.random.split(keys[i], 2)
        blocks.append({
            "norm1": common.rmsnorm_init(cfg["dim"]),
            "mixer": init_fn(bk[0], mixer_cfg(cfg, name)),
            "norm2": common.rmsnorm_init(cfg["dim"]),
            "mlp": common.mlp_init(bk[1], cfg["dim"], cfg["mlp_hidden"]),
        })
    return {
        "embed": common.embed_init(keys[-3], cfg["vocab"], cfg["dim"]),
        "blocks": blocks,
        "norm_f": common.rmsnorm_init(cfg["dim"]),
        "head": common.dense_init(keys[-2], cfg["dim"], cfg["vocab"]),
    }


def forward(params, tokens, cfg):
    """tokens [B,T] int32 -> (logits [B,T,V], aux_loss scalar)."""
    x = params["embed"][tokens]
    aux = jnp.zeros(())
    for blk, name in zip(params["blocks"], cfg["pattern"]):
        _, fwd, _ = MIXERS[name]
        h, a = fwd(blk["mixer"], common.rmsnorm(blk["norm1"], x),
                   mixer_cfg(cfg, name))
        x = x + h
        aux = aux + a
        x = x + common.mlp(blk["mlp"], common.rmsnorm(blk["norm2"], x))
    x = common.rmsnorm(params["norm_f"], x)
    return x @ params["head"], aux


def loss_fn(params, tokens, targets, mask, cfg):
    """Masked next-token cross-entropy + auxiliary mixer losses.

    Returns (total_loss, ce) — total includes e.g. VQ commitment losses.
    """
    logits, aux = forward(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll * mask) / denom
    return ce + cfg.get("aux_weight", 0.1) * aux, ce


def eval_step(params, tokens, targets, mask, cfg):
    """Returns (masked mean ce-loss, per-position correctness [B,T] f32,
    per-position masked nll [B,T] f32). correctness is 0 where mask is 0."""
    logits, _ = forward(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll * mask) / denom
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    correct = (pred == targets).astype(jnp.float32) * mask
    return ce, correct, nll * mask
