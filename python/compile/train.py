"""AdamW + cosine schedule, and the whole-train-step program that gets
AOT-lowered (the Rust trainer carries (params, m, v, step) as device
buffers and round-trips them through this one HLO executable)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model


def lr_schedule(step, cfg):
    """Linear warmup + cosine decay to min_lr (paper's setup)."""
    base = cfg.get("lr", 6e-4)
    warmup = cfg.get("warmup", 20)
    total = cfg.get("total_steps", 500)
    min_lr = cfg.get("min_lr", 1e-5)
    step_f = step.astype(jnp.float32)
    warm = base * (step_f + 1.0) / float(max(warmup, 1))
    prog = jnp.clip((step_f - warmup) / float(max(total - warmup, 1)), 0.0, 1.0)
    cos = min_lr + 0.5 * (base - min_lr) * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step_f < warmup, warm, cos)


def init_opt(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return zeros, jax.tree_util.tree_map(jnp.zeros_like, params)


def train_step(params, m, v, step, tokens, targets, mask, cfg):
    """One AdamW step. Returns (params', m', v', step+1, loss, lr)."""
    (loss, ce), grads = jax.value_and_grad(
        lambda p: model.loss_fn(p, tokens, targets, mask, cfg),
        has_aux=True)(params)

    b1, b2 = cfg.get("beta1", 0.9), cfg.get("beta2", 0.95)
    eps = cfg.get("adam_eps", 1e-8)
    wd = cfg.get("weight_decay", 0.01)
    lr = lr_schedule(step, cfg)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m_, v_):
        m_new = b1 * m_ + (1.0 - b1) * g
        v_new = b2 * v_ + (1.0 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        p_new = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(m)[0]
    flat_v = jax.tree_util.tree_flatten(v)[0]
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in
           zip(flat_p, flat_g, flat_m, flat_v)]
    params_new = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    m_new = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    v_new = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return params_new, m_new, v_new, step + 1, ce, lr
