"""Autodiff wrappers: Pallas-forward/custom-vjp kernels must match the
pure-jnp reference in BOTH the forward values and the gradients."""

import numpy as np
import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.ad import ovq_chunk_attn_ad, swa_attn_ad


def test_ovq_ad_forward_matches_ref(rng):
    B, H, L, d, N = 1, 2, 8, 16, 12
    q = jnp.asarray(rng.normal(size=(B, H, L, d)), jnp.float32)
    ke = jnp.asarray(rng.normal(size=(B, H, N + L, d)), jnp.float32)
    ve = jnp.asarray(rng.normal(size=(B, H, N + L, d)), jnp.float32)
    bias = jnp.zeros((B, H, N + L), jnp.float32)
    out = ovq_chunk_attn_ad(q, ke, ve, bias, jnp.float32(1.0), N, 8)
    want = ref.ovq_chunk_attn_ref(q, ke, ve, bias, 1.0, N)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_ovq_ad_grads_match_pure_jnp(rng):
    B, H, L, d, N = 1, 1, 4, 8, 6
    q = jnp.asarray(rng.normal(size=(B, H, L, d)), jnp.float32)
    ke = jnp.asarray(rng.normal(size=(B, H, N + L, d)), jnp.float32)
    ve = jnp.asarray(rng.normal(size=(B, H, N + L, d)), jnp.float32)
    bias = jnp.zeros((B, H, N + L), jnp.float32)

    def loss_pallas(q_, ke_, ve_):
        o = ovq_chunk_attn_ad(q_, ke_, ve_, bias, jnp.float32(0.8), N, 8)
        return jnp.sum(jnp.sin(o))

    def loss_ref(q_, ke_, ve_):
        o = ref.ovq_chunk_attn_ref(q_, ke_, ve_, bias, jnp.float32(0.8), N)
        return jnp.sum(jnp.sin(o))

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, ke, ve)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, ke, ve)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-5, rtol=1e-4)


def test_swa_ad_grads_match_pure_jnp(rng):
    B, H, T, d, W = 1, 1, 32, 8, 8
    q = jnp.asarray(rng.normal(size=(B, H, T, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, T, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, T, d)), jnp.float32)

    def loss_pallas(q_, k_, v_):
        return jnp.sum(jnp.tanh(swa_attn_ad(q_, k_, v_, jnp.float32(0.5), W, 16)))

    def loss_ref(q_, k_, v_):
        return jnp.sum(jnp.tanh(ref.swa_attn_ref(q_, k_, v_, W, jnp.float32(0.5))))

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-5, rtol=1e-4)
