"""AOT pipeline: manifests are schema-complete and the emitted HLO text
parses as HLO (smoke: contains an ENTRY computation with the right arity).
Full execution through PJRT is covered by the Rust integration tests."""

import json
import os

import jax.numpy as jnp
import pytest

from compile import aot, configs


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    entry = dict(configs.REGISTRY["quickstart"])
    entry = {**entry, "eval_lens": [128], "eval_n_dicts": []}
    manifest = aot.emit_entry(entry, out, log=lambda *a, **k: None)
    return out, manifest


def test_manifest_schema(emitted):
    out, manifest = emitted
    with open(os.path.join(out, "quickstart.manifest.json")) as f:
        m = json.load(f)
    assert m["name"] == "quickstart"
    assert {"init", "train", "eval_128"} <= set(m["programs"])
    for leaf in m["params"]:
        assert set(leaf) == {"name", "shape", "dtype"}
        assert leaf["dtype"] in ("f32", "i32", "u32", "bf16")
    tr = m["programs"]["train"]
    assert tr["batch"] == 4 and tr["seq"] == 128


def test_hlo_text_structure(emitted):
    out, manifest = emitted
    P = len(manifest["params"])
    text = open(os.path.join(out, "quickstart.train.hlo.txt")).read()
    assert "ENTRY" in text
    # train takes 3P + 4 inputs; each is a parameter instruction
    n_params = text.count("parameter(")
    assert n_params >= 3 * P + 4, (n_params, P)


def test_param_layout_stable_and_named():
    cfg = configs.REGISTRY["quickstart"]["config"]
    names, leaves, _ = aot.param_layout(cfg)
    assert len(names) == len(leaves)
    assert any("embed" in n for n in names)
    assert any("head" in n for n in names)
    # flat order is deterministic
    names2, _, _ = aot.param_layout(cfg)
    assert names == names2


def test_dtype_names():
    assert aot._dtype_name(jnp.float32) == "f32"
    assert aot._dtype_name(jnp.int32) == "i32"
    assert aot._dtype_name(jnp.uint32) == "u32"
