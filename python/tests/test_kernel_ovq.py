"""L1 correctness: Pallas OVQ chunk-attention kernel vs the pure-jnp oracle.

hypothesis sweeps shapes/tiles/dtypes; fixed-seed cases pin the edge
geometry (non-multiple tiles, single-column dictionaries, all-inactive
dictionaries, L=1).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import ovq_chunk_attn_ref, NEG_INF
from compile.kernels.ovq_attn import ovq_chunk_attn


def make_inputs(rng, B, H, L, d, N, frac_active=0.7, dtype=np.float32):
    q = rng.normal(size=(B, H, L, d)).astype(dtype)
    ke = rng.normal(size=(B, H, N + L, d)).astype(dtype)
    ve = rng.normal(size=(B, H, N + L, d)).astype(dtype)
    counts = rng.integers(0, 6, size=(B, H, N)).astype(np.float32)
    counts *= (rng.random(size=counts.shape) < frac_active)
    bias = np.where(counts > 0, np.log(np.maximum(counts, 1e-9)), NEG_INF)
    bias = np.concatenate([bias, np.zeros((B, H, L), np.float32)], axis=2)
    return map(jnp.asarray, (q, ke, ve, bias))


def check(q, ke, ve, bias, beta, n_dict, tile_n, atol=2e-5):
    got = ovq_chunk_attn(q, ke, ve, bias, beta, n_dict=n_dict, tile_n=tile_n)
    want = ovq_chunk_attn_ref(q, ke, ve, bias, beta, n_dict)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=atol, rtol=1e-4)


@settings(max_examples=12, deadline=None)
@given(
    B=st.integers(1, 2),
    H=st.integers(1, 3),
    L=st.sampled_from([1, 4, 16, 32]),
    d=st.sampled_from([8, 16, 32]),
    N=st.sampled_from([1, 5, 16, 40, 100]),
    tile_n=st.sampled_from([8, 16, 128]),
    beta=st.floats(0.1, 2.0),
)
def test_ovq_kernel_matches_ref_hypothesis(B, H, L, d, N, tile_n, beta):
    rng = np.random.default_rng(B * 1000 + H * 100 + L + N)
    q, ke, ve, bias = make_inputs(rng, B, H, L, d, N)
    check(q, ke, ve, bias, beta, N, tile_n)


def test_ovq_kernel_non_multiple_tiles(rng):
    q, ke, ve, bias = make_inputs(rng, 2, 3, 16, 32, 40)
    check(q, ke, ve, bias, 0.7, 40, 16)


def test_ovq_kernel_all_dictionary_inactive(rng):
    # Fresh state: every dictionary slot has count 0 -> attention must fall
    # back to the causal in-chunk part only and stay NaN-free.
    q, ke, ve, bias = make_inputs(rng, 1, 2, 8, 16, 24, frac_active=0.0)
    assert np.all(np.asarray(bias)[:, :, :24] == NEG_INF)
    check(q, ke, ve, bias, 1.0, 24, 8)


def test_ovq_kernel_single_query(rng):
    q, ke, ve, bias = make_inputs(rng, 1, 1, 1, 8, 7)
    check(q, ke, ve, bias, 1.3, 7, 8)


def test_ovq_kernel_first_query_sees_only_self_and_dict(rng):
    # Query 0 must not see chunk keys 1..L-1: perturbing them cannot change
    # row 0 of the output.
    q, ke, ve, bias = make_inputs(rng, 1, 1, 8, 16, 12)
    out1 = ovq_chunk_attn(q, ke, ve, bias, 1.0, n_dict=12, tile_n=8)
    ke2 = ke.at[:, :, 13:, :].add(100.0)
    ve2 = ve.at[:, :, 13:, :].add(-50.0)
    out2 = ovq_chunk_attn(q, ke2, ve2, bias, 1.0, n_dict=12, tile_n=8)
    np.testing.assert_allclose(np.asarray(out1)[0, 0, 0],
                               np.asarray(out2)[0, 0, 0], atol=2e-5)
    assert not np.allclose(np.asarray(out1)[0, 0, -1],
                           np.asarray(out2)[0, 0, -1], atol=1e-3)


def test_ovq_kernel_inactive_slot_is_ignored(rng):
    # Slot with count 0 must contribute nothing even with a huge key match.
    q, ke, ve, bias = make_inputs(rng, 1, 1, 4, 8, 6, frac_active=1.0)
    b = np.asarray(bias).copy()
    b[0, 0, 3] = NEG_INF  # deactivate slot 3
    ke_hot = ke.at[0, 0, 3].set(q[0, 0, 0] * 10.0)  # would dominate if active
    out = ovq_chunk_attn(q, ke_hot, ve, jnp.asarray(b), 1.0, n_dict=6, tile_n=8)
    want = ovq_chunk_attn_ref(q, ke_hot, ve, jnp.asarray(b), 1.0, 6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_ovq_kernel_rows_are_convex_combinations(rng):
    # Softmax output lies in the convex hull of ve rows: with all-equal
    # values the output equals that value exactly.
    B, H, L, d, N = 1, 2, 8, 16, 10
    q, ke, _, bias = make_inputs(rng, B, H, L, d, N)
    ve = jnp.ones((B, H, N + L, d), jnp.float32) * 3.25
    out = ovq_chunk_attn(q, ke, ve, bias, 1.0, n_dict=N, tile_n=8)
    np.testing.assert_allclose(np.asarray(out), 3.25, atol=1e-5)
