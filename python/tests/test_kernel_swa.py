"""L1 correctness: Pallas sliding-window attention kernel vs jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import swa_attn_ref, full_attn_ref
from compile.kernels.swa_attn import swa_attn


def make_qkv(rng, B, H, T, d, dtype=np.float32):
    return tuple(
        jnp.asarray(rng.normal(size=(B, H, T, d)).astype(dtype))
        for _ in range(3)
    )


def check(q, k, v, window, beta, tile_r, atol=2e-5):
    got = swa_attn(q, k, v, beta, window=window, tile_r=tile_r)
    want = swa_attn_ref(q, k, v, window, beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=atol, rtol=1e-4)


@settings(max_examples=12, deadline=None)
@given(
    B=st.integers(1, 2),
    H=st.integers(1, 2),
    T=st.sampled_from([1, 7, 32, 100, 130]),
    d=st.sampled_from([8, 32]),
    window=st.sampled_from([1, 8, 24, 64]),
    tile_r=st.sampled_from([16, 32, 64]),
)
def test_swa_kernel_matches_ref_hypothesis(B, H, T, d, window, tile_r):
    rng = np.random.default_rng(T * 31 + window)
    q, k, v = make_qkv(rng, B, H, T, d)
    check(q, k, v, window, 0.6, tile_r)


def test_swa_window_one_is_self_attention_identity(rng):
    # window=1: each token attends only to itself -> output == v.
    q, k, v = make_qkv(rng, 1, 2, 33, 16)
    out = swa_attn(q, k, v, 1.0, window=1, tile_r=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(v), atol=2e-5)


def test_swa_window_geq_T_equals_full_causal(rng):
    q, k, v = make_qkv(rng, 2, 2, 48, 16)
    out = swa_attn(q, k, v, 0.8, window=48, tile_r=16)
    want = full_attn_ref(q, k, v, 0.8, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_swa_causality(rng):
    # Future tokens cannot influence past outputs.
    q, k, v = make_qkv(rng, 1, 1, 64, 8)
    out1 = swa_attn(q, k, v, 1.0, window=16, tile_r=32)
    k2 = k.at[:, :, 40:, :].add(37.0)
    v2 = v.at[:, :, 40:, :].add(-11.0)
    out2 = swa_attn(q, k2, v2, 1.0, window=16, tile_r=32)
    np.testing.assert_allclose(np.asarray(out1)[:, :, :40],
                               np.asarray(out2)[:, :, :40], atol=2e-5)


def test_swa_locality(rng):
    # Tokens further back than the window cannot influence the output.
    q, k, v = make_qkv(rng, 1, 1, 64, 8)
    out1 = swa_attn(q, k, v, 1.0, window=8, tile_r=32)
    k2 = k.at[:, :, :40, :].add(19.0)
    v2 = v.at[:, :, :40, :].add(5.0)
    out2 = swa_attn(q, k2, v2, 1.0, window=8, tile_r=32)
    # rows >= 48 only see cols > 40 -> unaffected
    np.testing.assert_allclose(np.asarray(out1)[:, :, 48:],
                               np.asarray(out2)[:, :, 48:], atol=2e-5)
