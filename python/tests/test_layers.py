"""Layer semantics: the OVQ online-GMM update, the VQ quantizer, the
growth schedule, and the linear-time mixers against slow references."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.layers import common, ovq, vq, gdn, linattn, ssd
from compile.layers.common import NEG_INF


# --------------------------------------------------------------- growth

def test_growth_schedule_plateaus():
    n = common.growth_schedule(128, 32, 50)
    total = int(np.sum(np.asarray(n)))
    # N_t = t*N/(t+N) at t=1600, N=128 -> 118
    assert total == (1600 * 128) // (1600 + 128)
    assert int(np.max(np.asarray(n))) <= 32
    # front-loaded: first chunk adds more than the last
    assert int(n[0]) > int(n[-1])


def test_growth_schedule_linear_ablation():
    n = common.growth_schedule(128, 32, 50, linear=True)
    arr = np.asarray(n)
    assert abs(int(arr.max()) - int(arr.min())) <= 1  # spread evenly
    assert arr.sum() == (1600 * 128) // (1600 + 128)  # same total


# ----------------------------------------------------------- ovq update

def slow_update(D_k, D_v, counts, n_active, kc, vc, n_new):
    """Reference (loop) implementation of grow + merge for one head."""
    D_k, D_v, counts = D_k.copy(), D_v.copy(), counts.copy()
    L = kc.shape[0]
    sims = kc @ D_k.T
    sims[:, counts == 0] = NEG_INF
    best_idx = sims.argmax(1)
    best_sim = sims.max(1)
    order = np.argsort(best_sim)
    is_new = np.zeros(L, bool)
    is_new[order[:n_new]] = True
    next_slot = n_active
    assign = np.zeros(L, int)
    for i in range(L):
        if is_new[i]:
            assign[i] = next_slot
            next_slot += 1
        else:
            assign[i] = best_idx[i]
    for s in np.unique(assign):
        sel = assign == s
        cc = sel.sum()
        c_old = counts[s]
        D_k[s] = (c_old * D_k[s] + kc[sel].sum(0)) / (c_old + cc)
        D_v[s] = (c_old * D_v[s] + vc[sel].sum(0)) / (c_old + cc)
        counts[s] += cc
    return D_k, D_v, counts, next_slot


def test_ovq_update_matches_slow_reference(rng):
    B, H, L, d, N = 1, 1, 8, 4, 16
    D_k = rng.normal(size=(N, d)).astype(np.float32)
    D_v = rng.normal(size=(N, d)).astype(np.float32)
    counts = np.zeros(N, np.float32)
    counts[:5] = rng.integers(1, 4, 5)
    D_k[counts == 0] = 0
    D_v[counts == 0] = 0
    kc = rng.normal(size=(L, d)).astype(np.float32)
    vc = rng.normal(size=(L, d)).astype(np.float32)
    n_new = 3

    # fast path (jax, batched)
    best_idx, best_sim = ovq.nn_assignments(
        jnp.asarray(D_k)[None, None], jnp.asarray(counts)[None, None],
        jnp.asarray(kc)[None, None])
    Dk2, Dv2, c2, na2 = ovq.ovq_update(
        jnp.asarray(D_k)[None, None], jnp.asarray(D_v)[None, None],
        jnp.asarray(counts)[None, None], jnp.int32(5),
        jnp.asarray(kc)[None, None], jnp.asarray(vc)[None, None],
        jnp.int32(n_new), best_idx, best_sim, {})

    # slow path (numpy loops)
    Dk_ref, Dv_ref, c_ref, na_ref = slow_update(
        D_k, D_v, counts, 5, kc, vc, n_new)

    np.testing.assert_allclose(np.asarray(Dk2)[0, 0], Dk_ref, atol=1e-5)
    np.testing.assert_allclose(np.asarray(Dv2)[0, 0], Dv_ref, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c2)[0, 0], c_ref, atol=1e-6)
    assert int(na2) == na_ref


def test_ovq_counts_and_mass_conservation(rng):
    cfg = dict(dim=32, heads=2, d_head=16, chunk=8, n_dict=32, tile_n=32)
    p = ovq.init_ovq(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(1, 64, 32)), jnp.float32)
    y, aux = ovq.ovq_forward(p, x, cfg)
    assert y.shape == (1, 64, 32)
    assert not bool(jnp.any(jnp.isnan(y)))


def test_ovq_is_causal(rng):
    cfg = dict(dim=32, heads=2, d_head=16, chunk=8, n_dict=32, tile_n=32)
    p = ovq.init_ovq(jax.random.PRNGKey(0), cfg)
    x1 = jnp.asarray(rng.normal(size=(1, 64, 32)), jnp.float32)
    x2 = x1.at[:, 40:, :].add(3.0)  # perturb the future
    y1, _ = ovq.ovq_forward(p, x1, cfg)
    y2, _ = ovq.ovq_forward(p, x2, cfg)
    np.testing.assert_allclose(np.asarray(y1)[:, :40], np.asarray(y2)[:, :40],
                               atol=1e-4)
    assert not np.allclose(np.asarray(y1)[:, 40:], np.asarray(y2)[:, 40:],
                           atol=1e-3)


@pytest.mark.parametrize("flag", ["rand_assign", "linear_growth", "const_lr"])
def test_ovq_ablations_change_output(rng, flag):
    base = dict(dim=32, heads=2, d_head=16, chunk=8, n_dict=32, tile_n=32)
    p = ovq.init_ovq(jax.random.PRNGKey(0), base)
    x = jnp.asarray(rng.normal(size=(1, 64, 32)), jnp.float32)
    y0, _ = ovq.ovq_forward(p, x, base)
    y1, _ = ovq.ovq_forward(p, x, dict(base, **{flag: True}))
    assert not np.allclose(np.asarray(y0), np.asarray(y1), atol=1e-4), flag


def test_ovq_vshift_preserves_causality(rng):
    cfg = dict(dim=32, heads=2, d_head=16, chunk=8, n_dict=32, tile_n=32,
               vshift=True)
    p = ovq.init_ovq(jax.random.PRNGKey(0), cfg)
    x1 = jnp.asarray(rng.normal(size=(1, 64, 32)), jnp.float32)
    x2 = x1.at[:, 48:, :].add(5.0)
    y1, _ = ovq.ovq_forward(p, x1, cfg)
    y2, _ = ovq.ovq_forward(p, x2, cfg)
    # v-shift mixes v_t with v_{t+1} then shifts, so position t uses data
    # up to t; outputs before the perturbation must be identical
    np.testing.assert_allclose(np.asarray(y1)[:, :47], np.asarray(y2)[:, :47],
                               atol=1e-4)


# ------------------------------------------------------------------- vq

def test_vq_quantize_keys_ste(rng):
    dict_k = jnp.asarray(rng.normal(size=(2, 8, 4)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 6, 4)), jnp.float32)
    k_q, idx, aux = vq.quantize_keys(dict_k, k)
    assert k_q.shape == k.shape
    assert idx.shape == (1, 2, 6)
    assert float(aux) > 0
    # forward value equals the centroid (unit-normed dictionary)
    dk = common.unit_norm(dict_k)
    got = np.asarray(k_q)[0, 0, 0]
    want = np.asarray(dk)[0, np.asarray(idx)[0, 0, 0]]
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_vq_gradient_flows_through_ste(rng):
    dict_k = jnp.asarray(rng.normal(size=(1, 4, 4)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 3, 4)), jnp.float32)

    def f(k_):
        k_q, _, _ = vq.quantize_keys(dict_k, k_)
        return jnp.sum(k_q * k_q)

    g = jax.grad(f)(k)
    assert float(jnp.sum(jnp.abs(g))) > 0  # STE passes gradients to k


# ------------------------------------------------ linear-time baselines

def full_softmaxless_ref(q, k, v):
    """Quadratic reference for linear attention (phi = elu+1)."""
    qp = jax.nn.elu(q) + 1
    kp = jax.nn.elu(k) + 1
    T = q.shape[2]
    w = jnp.einsum("bhtd,bhsd->bhts", qp, kp)
    mask = jnp.tril(jnp.ones((T, T)))
    w = w * mask[None, None]
    den = jnp.maximum(w.sum(-1, keepdims=True), 1e-6)
    return jnp.einsum("bhts,bhsd->bhtd", w / den, v)


def test_linattn_matches_quadratic_reference(rng):
    cfg = dict(dim=32, heads=2, d_head=16, chunk=8)
    p = linattn.init_linattn(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(1, 32, 32)), jnp.float32)
    y, _ = linattn.linattn_forward(p, x, cfg)
    # recompute via the quadratic path on the same projections
    q, k, v = common.project_qkv(p, x, 2, 16, normalize_qk=False)
    want = full_softmaxless_ref(q, k, v)
    got_heads = common.merge_heads(p, want)
    np.testing.assert_allclose(np.asarray(y), np.asarray(got_heads),
                               atol=1e-3, rtol=1e-3)


def test_gdn_forward_shapes_and_grads(rng):
    cfg = dict(dim=32, heads=2, d_head=16, chunk=8)
    p = gdn.init_gdn(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 24, 32)), jnp.float32)
    y, _ = gdn.gdn_forward(p, x, cfg)
    assert y.shape == (2, 24, 32)
    g = jax.grad(lambda p_: jnp.sum(gdn.gdn_forward(p_, x, cfg)[0] ** 2))(p)
    assert float(jnp.sum(jnp.abs(g["w_alpha"]))) > 0


def test_ssd_decay_limits(rng):
    # with decay ~1 and all-equal values, ssd behaves like cumulative
    # linear attention: output converges toward the shared value direction
    cfg = dict(dim=16, heads=1, d_head=16, chunk=8)
    p = ssd.init_ssd(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(rng.normal(size=(1, 32, 16)), jnp.float32)
    y, _ = ssd.ssd_forward(p, x, cfg)
    assert y.shape == (1, 32, 16)
    assert not bool(jnp.any(jnp.isnan(y)))


# ------------------------------------------------------------------ rope

def test_rope_preserves_norm_and_relativity(rng):
    x = jnp.asarray(rng.normal(size=(1, 1, 8, 16)), jnp.float32)
    pos = jnp.arange(8)
    y = common.apply_rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # relative property: <R(a)q, R(b)k> depends only on (a - b)
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)
    def dot(pq, pk):
        qr = common.apply_rope(q, jnp.array([pq]))
        kr = common.apply_rope(k, jnp.array([pk]))
        return float(jnp.sum(qr * kr))
    assert abs(dot(3, 1) - dot(7, 5)) < 1e-4
    assert abs(dot(3, 1) - dot(3, 2)) > 1e-6
