"""Model-level: init/forward/loss/eval across mixer patterns, overfitting
a fixed batch, and mask semantics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import configs, model, train


def tiny(pattern, **over):
    cfg = dict(configs.TINY)
    cfg["pattern"] = pattern
    cfg.update(over)
    return cfg


@pytest.mark.parametrize("pattern", [
    ["swa", "ovq"],
    ["swa", "vq"],
    ["gdn", "ssd"],
    ["linattn", "attn_nope"],
    ["ovq_rope", "attn_rope"],
])
def test_forward_all_patterns(pattern, rng):
    cfg = tiny(pattern)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg["vocab"], (2, 64)), jnp.int32)
    logits, aux = model.forward(params, toks, cfg)
    assert logits.shape == (2, 64, cfg["vocab"])
    assert np.isfinite(np.asarray(logits)).all()


def test_loss_mask_semantics(rng):
    cfg = tiny(["swa"])
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg["vocab"], (1, 64)), jnp.int32)
    mask_all = jnp.ones((1, 64), jnp.float32)
    mask_half = mask_all.at[:, 32:].set(0.0)
    # loss over a masked region must not depend on the targets there
    tg1 = toks
    tg2 = toks.at[:, 32:].set(0)
    l1 = model.loss_fn(params, toks, tg1, mask_half, cfg)[1]
    l2 = model.loss_fn(params, toks, tg2, mask_half, cfg)[1]
    assert float(jnp.abs(l1 - l2)) < 1e-6
    l3 = model.loss_fn(params, toks, tg2, mask_all, cfg)[1]
    assert float(jnp.abs(l1 - l3)) > 1e-6


def test_eval_correct_matches_argmax(rng):
    cfg = tiny(["swa"])
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg["vocab"], (1, 32)), jnp.int32)
    mask = jnp.ones((1, 32), jnp.float32)
    ce, correct, nll = model.eval_step(params, toks, toks, mask, cfg)
    logits, _ = model.forward(params, toks, cfg)
    pred = jnp.argmax(logits, -1)
    want = (pred == toks).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(correct), np.asarray(want))
    assert np.all(np.asarray(nll) >= 0)


def test_overfit_fixed_batch(rng):
    # the canonical learning test: repeated steps on one batch -> loss -> 0
    cfg = tiny(["swa", "ovq"], total_steps=100, lr=3e-3, warmup=5)
    params = model.init_params(jax.random.PRNGKey(1), cfg)
    m, v = train.init_opt(params)
    ts = jax.jit(lambda p, m_, v_, s, a, b, c: train.train_step(
        p, m_, v_, s, a, b, c, cfg))
    toks = jnp.asarray(rng.integers(0, cfg["vocab"], (2, 64)), jnp.int32)
    mask = jnp.ones((2, 64), jnp.float32)
    step = jnp.zeros((), jnp.int32)
    first = None
    for i in range(60):
        params, m, v, step, loss, lr = ts(params, m, v, step, toks, toks, mask)
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.5, (first, float(loss))


def test_lr_schedule_shape():
    cfg = dict(lr=1e-3, warmup=10, total_steps=100, min_lr=1e-5)
    lrs = [float(train.lr_schedule(jnp.int32(s), cfg)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9          # warmup rises
    assert abs(max(lrs) - 1e-3) < 1e-4             # peaks at base
    assert lrs[-1] < 2e-4                          # decays
    assert min(lrs) >= 1e-5 - 1e-9                 # floored


def test_param_count_is_reasonable():
    cfg = dict(configs.REGISTRY["icr-sw-ovq"]["config"])
    params = jax.eval_shape(
        lambda k: model.init_params(k, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
    assert 5e5 < n < 5e6, n  # ~1M params at the scaled size
