#!/bin/sh
# Informational current-vs-baseline bench comparison. Run from rust/
# (where the fresh BENCH_*.json land); never fails the build — the perf
# trajectory is judged by humans reading the numbers, the gate is only
# that the benches ran and emitted well-formed JSON.
#
# The JSON is the repo's own single-line util::json output, so plain
# sed/grep is enough: extract (name, tok_per_s) pairs per file and join
# on name.
set -u

extract() {
    # one "name tok_per_s" pair per line
    tr '{' '\n' <"$1" | sed -n \
        's/.*"name": *"\([^"]*\)".*"tok_per_s": *\([0-9.eE+-]*\).*/\1 \2/p'
}

for bench in ovqcore server; do
    cur="BENCH_${bench}.json"
    base="benches/baseline/BENCH_${bench}.baseline.json"
    echo "== $bench: current vs committed baseline =="
    if [ ! -f "$cur" ]; then
        echo "  (no current $cur — bench did not run?)"
        continue
    fi
    if grep -q '"seeded": false' "$base" 2>/dev/null; then
        echo "  baseline unseeded — copy a CI bench-json artifact over $base to start the trajectory"
        extract "$cur" | while read -r name tps; do
            printf '  %-32s %14.0f tok/s (no baseline)\n' "$name" "$tps"
        done
        continue
    fi
    extract "$cur" | while read -r name tps; do
        btps=$(extract "$base" | awk -v n="$name" '$1 == n { print $2; exit }')
        if [ -n "${btps:-}" ]; then
            printf '  %-32s %14.0f tok/s   baseline %14.0f\n' "$name" "$tps" "$btps"
        else
            printf '  %-32s %14.0f tok/s   (new row)\n' "$name" "$tps"
        fi
    done
done
exit 0
