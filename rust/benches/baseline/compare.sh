#!/bin/sh
# Informational current-vs-baseline bench comparison. Run from rust/
# (where the fresh BENCH_*.json land); never fails the build — the perf
# trajectory is judged by humans reading the numbers, the gate is only
# that the benches ran and emitted well-formed JSON.
#
# The JSON is the repo's own single-line util::json output, so plain
# sed/grep is enough: extract (name, tok_per_s) pairs per file and join
# on name. Against a seeded baseline every shared row gets a signed
# delta-% column, and each bench ends with a one-line delta summary
# (mean / best / worst / new-row count) so a PR check log surfaces
# regressions without downloading the artifact. Under GitHub Actions the
# per-bench delta summaries are additionally appended to the job summary
# page ($GITHUB_STEP_SUMMARY), so the trajectory is one click away.
set -u

# append a line to the workflow job summary when running under Actions
summarize() {
    if [ -n "${GITHUB_STEP_SUMMARY:-}" ]; then
        printf '%s\n' "$1" >>"$GITHUB_STEP_SUMMARY"
    fi
}

extract() {
    # one "name tok_per_s" pair per line
    tr '{' '\n' <"$1" | sed -n \
        's/.*"name": *"\([^"]*\)".*"tok_per_s": *\([0-9.eE+-]*\).*/\1 \2/p'
}

for bench in ovqcore server; do
    cur="BENCH_${bench}.json"
    base="benches/baseline/BENCH_${bench}.baseline.json"
    echo "== $bench: current vs committed baseline =="
    if [ ! -f "$cur" ]; then
        echo "  (no current $cur — bench did not run?)"
        continue
    fi
    if grep -q '"seeded": false' "$base" 2>/dev/null; then
        echo "  baseline unseeded — copy a CI bench-json artifact over $base to start the trajectory"
        extract "$cur" | while read -r name tps; do
            printf '  %-34s %14.0f tok/s (no baseline)\n' "$name" "$tps"
        done
        continue
    fi
    basepairs=$(extract "$base")
    report=$(extract "$cur" | awk -v basepairs="$basepairs" '
        BEGIN {
            nb = split(basepairs, lines, "\n")
            for (i = 1; i <= nb; i++) {
                split(lines[i], f, " ")
                if (f[1] != "") b[f[1]] = f[2]
            }
        }
        {
            name = $1; tps = $2
            if (name in b && b[name] + 0 > 0) {
                d = (tps - b[name]) / b[name] * 100.0
                printf "  %-34s %14.0f tok/s   baseline %12.0f   %+7.1f%%\n", \
                    name, tps, b[name], d
                n++; sum += d
                if (n == 1 || d < worst) { worst = d; wname = name }
                if (n == 1 || d > best) { best = d; bname = name }
            } else {
                printf "  %-34s %14.0f tok/s   (new row)\n", name, tps
                newrows++
            }
        }
        END {
            printf "  -- delta summary: %d shared rows", n
            if (n > 0)
                printf ", mean %+.1f%%, best %+.1f%% (%s), worst %+.1f%% (%s)", \
                    sum / n, best, bname, worst, wname
            if (newrows > 0) printf ", %d new", newrows
            printf " --\n"
        }')
    printf '%s\n' "$report"
    summarize "\`$bench\`: $(printf '%s\n' "$report" | sed -n 's/^  -- delta summary: \(.*\) --$/\1/p')"
done
exit 0
