#!/bin/sh
# Re-pin the committed bench baselines from fresh bench output.
#
#   sh benches/baseline/repin.sh <dir-with-BENCH_*.json> "<runner note>"
#
# <dir> is a downloaded `bench-json` CI artifact (or anywhere the two
# quick-mode BENCH_ovqcore.json / BENCH_server.json files landed after a
# local `cargo bench ... -- --quick` run). The script copies each file
# over its `*.baseline.json` counterpart, forces `"seeded": true`, and
# rewrites the `note` field to the supplied runner description plus a
# pointer back to this procedure — so the provenance of every committed
# number is recorded in the file itself. Top-level summary scalars from
# the live run (speedups, trace shape) are dropped along with the old
# note; only `bench`, the identity fields, and `results` survive, which
# is exactly what compare.sh joins on.
#
# It does NOT commit: inspect the diff (compare.sh against the previous
# baseline is a good sanity pass) and commit with a message naming the
# runner class the numbers came from. The repin-baselines workflow runs
# this on a CI-class runner and uploads the result as an artifact.
set -eu

if [ $# -lt 2 ]; then
    echo "usage: sh benches/baseline/repin.sh <dir-with-BENCH_*.json> \"<runner note>\"" >&2
    exit 2
fi
src=$1
note=$2
here=$(dirname "$0")

for bench in ovqcore server; do
    cur="$src/BENCH_${bench}.json"
    base="$here/BENCH_${bench}.baseline.json"
    if [ ! -s "$cur" ]; then
        echo "repin: $cur missing or empty — run the quick benches first" >&2
        exit 1
    fi
    if ! grep -q '"results"' "$cur"; then
        echo "repin: $cur has no results array — not a bench JSON?" >&2
        exit 1
    fi
    # The bench emits one line of repo-idiom JSON: keep `bench` +
    # identity fields (backend/d/chunk on ovqcore), drop run-local
    # summary scalars, then splice in seeded/note ahead of results.
    # Reformat to the committed one-row-per-line layout so diffs stay
    # reviewable.
    tr -d '\n' <"$cur" | sed \
        -e 's/, *"\(fanout_speedup_4t\|speedup_4t_over_1t\|eviction_slowdown\|trace_events\|trace_sessions\)": *[0-9.eE+-]*//g' \
        -e 's/, *"note": *"[^"]*"//' \
        -e 's/, *"seeded": *\(true\|false\)//' \
        -e "s|\"results\":|\"seeded\": true, \"note\": \"quick-mode reference rows: ${note}. Re-pinned via benches/baseline/repin.sh (README.md has the procedure); re-pin whenever the runner class changes.\", \"results\":|" \
        | sed -e 's/"results": \[/"results": [\n  /' -e 's/}, {/},\n  {/g' \
              -e 's/\]}$/\n ]}/' >"$base.tmp"
    printf '\n' >>"$base.tmp"
    mv "$base.tmp" "$base"
    rows=$(grep -c '"name"' "$base" || true)
    echo "repin: wrote $base ($rows rows)"
done

echo "repin: done — review the diff, then commit (sh benches/baseline/compare.sh"
echo "       from rust/ with the fresh BENCH_*.json still present shows the deltas)"
