//! Task-generator throughput: the data pipeline must outrun the device
//! (one prefetch thread feeds the trainer), so generators are benched in
//! tokens/second at the training sequence length.

use ovq::data::by_name;
use ovq::util::bench::Bench;
use ovq::util::rng::Rng;

fn main() {
    let b = if std::env::args().any(|a| a == "--quick") {
        Bench::quick()
    } else {
        Bench::default()
    };
    let t = 256usize;
    for task in ["icr", "picr", "icl", "lm", "shortctx"] {
        let gen = by_name(task, 512).expect("bench tasks are known");
        let mut rng = Rng::new(1);
        b.run_throughput(&format!("gen_{task}_T{t}"), t as f64, "tok/s", || {
            gen.generate(&mut rng, t)
        });
    }
    // long-context generation (the eval sweep path)
    for t in [1024usize, 4096] {
        let gen = by_name("lm", 512).expect("lm is a known task");
        let mut rng = Rng::new(2);
        b.run_throughput(&format!("gen_lm_T{t}"), t as f64, "tok/s", || {
            gen.generate(&mut rng, t)
        });
    }
}
