//! Analytical-model evaluation speed (trivial, but keeps the App. D
//! sweep honest: the FLOPs model is called once per point per figure and
//! must stay O(chunks)) + prints the Fig. 15/16 crossover summary used in
//! EXPERIMENTS.md.

use ovq::analysis::flops::{attn_flops, gdn_flops, ovq_flops, Geom};
use ovq::util::bench::Bench;

fn main() {
    let b = if std::env::args().any(|a| a == "--quick") {
        Bench::quick()
    } else {
        Bench::default()
    };
    let g = Geom::default();
    b.run("flops_model_sweep_1k_128k", || {
        let mut acc = 0.0;
        for p in 10..=17 {
            let t = (1usize << p) as f64;
            acc += attn_flops(g, t, false)
                + ovq_flops(g, t, 8192, false)
                + gdn_flops(g, t, false);
        }
        acc
    });

    // report the crossover length (where OVQ FLOPs dip below attention)
    for n in [2048usize, 8192, 16384] {
        let mut cross = None;
        for t in (256..1 << 18).step_by(256) {
            if ovq_flops(g, t as f64, n, false) < attn_flops(g, t as f64, false) {
                cross = Some(t);
                break;
            }
        }
        println!(
            "crossover N={n}: OVQ cheaper than attention beyond T={}",
            cross.map(|t| t.to_string()).unwrap_or_else(|| ">256k".into())
        );
    }
}
