//! §3.4 systems claim, measured: the OVQ state-update throughput is
//! independent of dictionary size N, while linear attention's write cost
//! scales with the state. Also benches the forward (attend) path vs N —
//! which SHOULD scale with N (it's two matmuls) — and the KV-cache
//! baseline which scales with context length.
//!
//! Run: cargo bench --offline  (or: cargo bench --bench bench_ovqcore)

use ovq::ovqcore::linear_attn::LinearAttnState;
use ovq::ovqcore::kvcache::KvCache;
use ovq::ovqcore::ovq::{OvqConfig, OvqState};
use ovq::util::bench::Bench;
use ovq::util::rng::Rng;

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn main() {
    let b = if std::env::args().any(|a| a == "--quick") {
        Bench::quick()
    } else {
        Bench::default()
    };
    let d = 64;
    let chunk = 32;
    let mut rng = Rng::new(1);

    println!("\n-- OVQ state update: cost vs dictionary size N (claim: flat) --");
    for n in [256usize, 1024, 4096, 16384] {
        // pre-saturate the dictionary so the update hits the steady state
        let mut st = OvqState::new(OvqConfig::new(d, n, chunk));
        for _ in 0..(2 * n / chunk) {
            let k = randv(&mut rng, chunk * d);
            let v = randv(&mut rng, chunk * d);
            st.update_chunk(&k, &v);
        }
        let k = randv(&mut rng, chunk * d);
        let v = randv(&mut rng, chunk * d);
        // NOTE: nearest-neighbour search is O(N_active * d) — the paper
        // counts it as matmul FLOPs (K_c D_k^T). What must NOT grow with N
        // is the *write* footprint; see the memstate figures. We bench both
        // the full update and the write-only path.
        b.run_throughput(&format!("ovq_update_full_N{n}"), chunk as f64, "tok/s", || {
            let mut s2 = st.clone();
            s2.update_chunk(&k, &v);
            s2.counts[0]
        });
    }

    println!("\n-- linear attention write: cost vs state size (claim: grows) --");
    for dk in [64usize, 128, 256, 512] {
        let mut st = LinearAttnState::new(dk, d);
        let k = randv(&mut rng, dk);
        let v = randv(&mut rng, d);
        b.run_throughput(&format!("linattn_write_dk{dk}"), 1.0, "tok/s", || {
            st.write(&k, &v);
            st.s[0]
        });
    }

    println!("\n-- OVQ attend vs KV-cache read at long context --");
    let n = 1024;
    let mut st = OvqState::new(OvqConfig::new(d, n, chunk));
    let mut cache = KvCache::new(d);
    for _ in 0..(16 * 1024 / chunk) {
        let k = randv(&mut rng, chunk * d);
        let v = randv(&mut rng, chunk * d);
        st.update_chunk(&k, &v);
        for i in 0..chunk {
            cache.write(&k[i * d..(i + 1) * d], &v[i * d..(i + 1) * d]);
        }
    }
    let q = randv(&mut rng, d);
    let ck = randv(&mut rng, chunk * d);
    let cv = randv(&mut rng, chunk * d);
    let mut out = vec![0.0f32; d];
    b.run(&format!("ovq_attend_T16k_N{n}"), || {
        st.attend(&q, &ck, &cv, chunk, &mut out);
        out[0]
    });
    b.run("kvcache_read_T16k", || {
        cache.read(&q, &mut out);
        out[0]
    });
    println!("\n(expected: ovq_update flat in N modulo the NN matmul; linattn write\n grows ~linearly with dk; ovq attend is ~16x cheaper than the 16k kv read)");
}
