//! §3.4 systems claim, measured through the unified SeqMixer interface:
//!
//!  - blocked-kernel OVQ update+attend vs the seed's scalar loops
//!    (the `scalar_baseline` module preserves the pre-kernel
//!    implementation verbatim as the comparison floor);
//!  - single-token decode throughput per mixer x dictionary size N,
//!    via the MixerKind factory — every mixer measured through the trait;
//!  - multi-stream, multi-head decode through MixerBank across N and
//!    across context depth: per-token ΔS bytes are exactly flat in N
//!    (the paper's claim) and wall-clock per token stays flat as context
//!    grows, unlike the KV-cache baseline;
//!  - emits machine-readable BENCH_ovqcore.json so the perf trajectory is
//!    tracked across PRs.
//!
//! Run: cargo bench --offline  (or: cargo bench --bench bench_ovqcore)

use std::collections::BTreeMap;

use ovq::ovqcore::bank::{DecodeChunk, MixerBank};
use ovq::ovqcore::kernels;
use ovq::ovqcore::memstate::MixerKind;
use ovq::ovqcore::mixer::{Scratch, SeqMixer};
use ovq::ovqcore::ovq::{OvqConfig, OvqState};
use ovq::ovqcore::quant::{QuantMode, QuantTensor};
use ovq::util::bench::Bench;
use ovq::util::json::Json;
use ovq::util::rng::Rng;

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// The seed's scalar OVQ implementation, preserved verbatim as the
/// speedup baseline: one-element-at-a-time dots, a fresh logits Vec per
/// query, scalar nearest-centroid search, per-touched-slot chunk rescan
/// in the merge. Operates on its own copy of the state so the comparison
/// against the blocked-kernel path is apples-to-apples.
mod scalar_baseline {
    use ovq::ovqcore::growth_n_new;

    fn dot(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[derive(Clone)]
    pub struct ScalarOvq {
        pub d: usize,
        pub n_max: usize,
        pub chunk: usize,
        pub beta: f32,
        pub dk: Vec<f32>,
        pub dv: Vec<f32>,
        pub counts: Vec<f32>,
        pub n_active: usize,
        pub t: usize,
        chunk_idx: usize,
    }

    impl ScalarOvq {
        /// Copy a (saturated, flushed) state out of the real machine. The
        /// seed allocated the full n_max dictionary eagerly, so pad the
        /// (lazily-grown) live storage back out to capacity.
        pub fn from_state(st: &super::OvqState) -> ScalarOvq {
            let (d, n_max) = (st.cfg.d, st.cfg.n_max);
            let mut dk = st.dk.to_f32_vec();
            let mut dv = st.dv.to_f32_vec();
            let mut counts = st.counts.clone();
            dk.resize(n_max * d, 0.0);
            dv.resize(n_max * d, 0.0);
            counts.resize(n_max, 0.0);
            ScalarOvq {
                d,
                n_max,
                chunk: st.cfg.chunk,
                beta: st.cfg.beta,
                dk,
                dv,
                counts,
                n_active: st.n_active,
                t: st.t,
                chunk_idx: st.t / st.cfg.chunk,
            }
        }

        pub fn attend(
            &self,
            q: &[f32],
            chunk_k: &[f32],
            chunk_v: &[f32],
            upto: usize,
            out: &mut [f32],
        ) {
            let d = self.d;
            let beta = self.beta;
            let n = self.n_active;
            let mut m = f32::NEG_INFINITY;
            let mut logits: Vec<f32> = Vec::with_capacity(n + upto);
            for s in 0..n {
                if self.counts[s] > 0.0 {
                    let l = beta * dot(q, &self.dk[s * d..(s + 1) * d]) + self.counts[s].ln();
                    logits.push(l);
                    m = m.max(l);
                } else {
                    logits.push(f32::NEG_INFINITY);
                }
            }
            for j in 0..upto {
                let l = beta * dot(q, &chunk_k[j * d..(j + 1) * d]);
                logits.push(l);
                m = m.max(l);
            }
            out.iter_mut().for_each(|o| *o = 0.0);
            let mut z = 0.0f32;
            for (s, &l) in logits.iter().enumerate().take(n) {
                if l > f32::NEG_INFINITY {
                    let w = (l - m).exp();
                    z += w;
                    let row = &self.dv[s * d..(s + 1) * d];
                    for (o, &v) in out.iter_mut().zip(row) {
                        *o += w * v;
                    }
                }
            }
            for j in 0..upto {
                let w = (logits[n + j] - m).exp();
                z += w;
                let row = &chunk_v[j * d..(j + 1) * d];
                for (o, &v) in out.iter_mut().zip(row) {
                    *o += w * v;
                }
            }
            if z > 0.0 {
                out.iter_mut().for_each(|o| *o /= z);
            }
        }

        pub fn process_chunk(&mut self, queries: &[f32], keys: &[f32], values: &[f32]) -> Vec<f32> {
            let d = self.d;
            let len = keys.len() / d;
            let mut out = vec![0.0f32; len * d];
            for i in 0..len {
                let (head, tail) = out.split_at_mut(i * d);
                let _ = head;
                self.attend(&queries[i * d..(i + 1) * d], keys, values, i + 1, &mut tail[..d]);
            }
            self.update_chunk(keys, values);
            out
        }

        pub fn update_chunk(&mut self, keys: &[f32], values: &[f32]) {
            let d = self.d;
            let len = keys.len() / d;
            let mut best_idx = vec![0usize; len];
            let mut best_sim = vec![f32::NEG_INFINITY; len];
            for i in 0..len {
                let k = &keys[i * d..(i + 1) * d];
                for s in 0..self.n_active {
                    if self.counts[s] > 0.0 {
                        let sim = dot(k, &self.dk[s * d..(s + 1) * d]);
                        if sim > best_sim[i] {
                            best_sim[i] = sim;
                            best_idx[i] = s;
                        }
                    }
                }
            }
            let n_new = growth_n_new(self.chunk_idx, self.chunk, self.n_max)
                .min(self.n_max - self.n_active)
                .min(len);
            let mut order: Vec<usize> = (0..len).collect();
            order.sort_by(|&a, &b| best_sim[a].partial_cmp(&best_sim[b]).unwrap());
            let mut is_new = vec![false; len];
            for &i in order.iter().take(n_new) {
                is_new[i] = true;
            }
            let mut next_slot = self.n_active;
            let mut assign = vec![0usize; len];
            for i in 0..len {
                if is_new[i] {
                    assign[i] = next_slot;
                    next_slot += 1;
                } else if self.n_active > 0 {
                    assign[i] = best_idx[i];
                } else {
                    assign[i] = 0;
                }
            }
            self.n_active = next_slot;
            let mut touched: Vec<usize> = assign.clone();
            touched.sort_unstable();
            touched.dedup();
            for &s in &touched {
                let mut cc = 0.0f32;
                let mut sum_k = vec![0.0f32; d];
                let mut sum_v = vec![0.0f32; d];
                for i in 0..len {
                    if assign[i] == s {
                        cc += 1.0;
                        for j in 0..d {
                            sum_k[j] += keys[i * d + j];
                            sum_v[j] += values[i * d + j];
                        }
                    }
                }
                let c_old = self.counts[s];
                let denom = c_old + cc;
                for j in 0..d {
                    self.dk[s * d + j] = (c_old * self.dk[s * d + j] + sum_k[j]) / denom;
                    self.dv[s * d + j] = (c_old * self.dv[s * d + j] + sum_v[j]) / denom;
                }
                self.counts[s] = c_old + cc;
            }
            self.t += len;
            self.chunk_idx += 1;
        }
    }
}

struct Row {
    name: String,
    mixer: &'static str,
    n: usize,
    mean_ns: f64,
    tok_per_s: f64,
}

fn push_row(
    rows: &mut Vec<Row>,
    name: &str,
    mixer: &'static str,
    n: usize,
    mean_ns: f64,
    toks: f64,
) {
    rows.push(Row {
        name: name.to_string(),
        mixer,
        n,
        mean_ns,
        tok_per_s: toks / (mean_ns / 1e9),
    });
}

fn saturated_ovq(rng: &mut Rng, d: usize, n: usize, chunk: usize) -> OvqState {
    let mut st = OvqState::new(OvqConfig::new(d, n, chunk));
    for _ in 0..(2 * n / chunk).max(4) {
        let k = randv(rng, chunk * d);
        let v = randv(rng, chunk * d);
        st.update_chunk(&k, &v);
    }
    st
}

fn main() {
    let b = if std::env::args().any(|a| a == "--quick") {
        Bench::quick()
    } else {
        Bench::default()
    };
    let d = 64;
    let chunk = 32;
    let mut rng = Rng::new(1);
    let mut rows: Vec<Row> = Vec::new();

    // ---- blocked kernels vs the seed scalar path: update + attend ------
    println!("\n-- OVQ chunk update+attend: blocked kernels vs seed scalar (d={d}) --");
    let mut speedup_at_4096 = 0.0f64;
    for n in [256usize, 1024, 4096, 16384] {
        let st = saturated_ovq(&mut rng, d, n, chunk);
        let scalar = scalar_baseline::ScalarOvq::from_state(&st);
        let q = randv(&mut rng, chunk * d);
        let k = randv(&mut rng, chunk * d);
        let v = randv(&mut rng, chunk * d);
        let mut scratch = Scratch::new();
        let mut out = vec![0.0f32; chunk * d];

        // NOTE: nearest-neighbour search is O(N_active * d) — the paper
        // counts it as matmul FLOPs (K_c D_k^T). What must NOT grow with N
        // is the *write* footprint; see the memstate figures and the ΔS
        // column below. Both paths do identical work: attend every token
        // against dict+prefix, then merge the chunk.
        let name_new = format!("ovq_chunk_blocked_N{n}");
        let r_new = b.run_throughput(&name_new, chunk as f64, "tok/s", || {
            let mut s2 = st.clone();
            s2.process_chunk(&q, &k, &v, &mut out, &mut scratch);
            s2.flush();
            out[0]
        });
        push_row(&mut rows, &name_new, "ovq", n, r_new.mean_ns, chunk as f64);

        let name_old = format!("ovq_chunk_scalar_N{n}");
        let r_old = b.run_throughput(&name_old, chunk as f64, "tok/s", || {
            let mut s2 = scalar.clone();
            let o = s2.process_chunk(&q, &k, &v);
            o[0]
        });
        push_row(&mut rows, &name_old, "ovq_scalar", n, r_old.mean_ns, chunk as f64);
        let speedup = r_old.mean_ns / r_new.mean_ns;
        if n == 4096 {
            speedup_at_4096 = speedup;
        }
        println!("   N={n:>6}: blocked is {speedup:.2}x the scalar path");
    }

    // ---- kernel microbenches: scalar tiles vs dispatch x storage mode --
    // The dispatch rows measure whatever kernels::backend() resolves to
    // ("scalar" on a default build, "avx2" under --features simd on
    // supporting hardware); the scalar rows pin the always-available
    // fallback, so the pair IS the SIMD speedup when the feature is on.
    println!(
        "\n-- kernel microbenches (backend: {}) — rows=4096, d={d} --",
        kernels::backend()
    );
    {
        let nrows = 4096usize;
        let batch = 8usize;
        let m = randv(&mut rng, nrows * d);
        let x = randv(&mut rng, d);
        let xs = randv(&mut rng, batch * d);
        let mut outv = vec![0.0f32; nrows];
        let mut outm = vec![0.0f32; batch * nrows];
        let mut idx = vec![0usize; batch];
        let mut sim = vec![f32::NEG_INFINITY; batch];

        let r = b.run_throughput("kernel_matvec_scalar", nrows as f64, "row/s", || {
            kernels::scalar::matvec(&m, nrows, d, &x, &mut outv);
            outv[0]
        });
        push_row(&mut rows, "kernel_matvec_scalar", "kernel", nrows, r.mean_ns, nrows as f64);
        let r = b.run_throughput("kernel_matvec_dispatch", nrows as f64, "row/s", || {
            kernels::matvec(&m, nrows, d, &x, &mut outv);
            outv[0]
        });
        push_row(&mut rows, "kernel_matvec_dispatch", "kernel", nrows, r.mean_ns, nrows as f64);

        // quantized storage: fused dequant-dot rows (f32 accumulation)
        for quant in [QuantMode::F16, QuantMode::I8] {
            let qt = QuantTensor::from_f32(quant, nrows, d, &m);
            let name = format!("kernel_matvec_{}", quant.name());
            let r = b.run_throughput(&name, nrows as f64, "row/s", || {
                qt.matvec(&x, &mut outv);
                outv[0]
            });
            push_row(&mut rows, &name, "kernel", nrows, r.mean_ns, nrows as f64);
        }

        // the decode-read hot path's weighted row fold (GdnState::read and
        // the linear-attn numerator): out[j] = sum_i x[i] * m[i][j]
        let w = randv(&mut rng, nrows);
        let mut outd = vec![0.0f32; d];
        let r = b.run_throughput("kernel_vecmat_scalar", nrows as f64, "row/s", || {
            kernels::scalar::vecmat(&w, &m, nrows, d, &mut outd);
            outd[0]
        });
        push_row(&mut rows, "kernel_vecmat_scalar", "kernel", nrows, r.mean_ns, nrows as f64);
        let r = b.run_throughput("kernel_vecmat_dispatch", nrows as f64, "row/s", || {
            kernels::vecmat(&w, &m, nrows, d, &mut outd);
            outd[0]
        });
        push_row(&mut rows, "kernel_vecmat_dispatch", "kernel", nrows, r.mean_ns, nrows as f64);

        let dots = (batch * nrows) as f64;
        let r = b.run_throughput("kernel_matmul_rows_scalar", dots, "dot/s", || {
            kernels::scalar::matmul_rows(&m, nrows, d, &xs, batch, &mut outm);
            outm[0]
        });
        push_row(&mut rows, "kernel_matmul_rows_scalar", "kernel", nrows, r.mean_ns, dots);
        let r = b.run_throughput("kernel_matmul_rows_dispatch", dots, "dot/s", || {
            kernels::matmul_rows(&m, nrows, d, &xs, batch, &mut outm);
            outm[0]
        });
        push_row(&mut rows, "kernel_matmul_rows_dispatch", "kernel", nrows, r.mean_ns, dots);

        let r = b.run_throughput("kernel_nearest_scalar", dots, "dot/s", || {
            idx.iter_mut().for_each(|i| *i = 0);
            sim.iter_mut().for_each(|s| *s = f32::NEG_INFINITY);
            kernels::scalar::nearest_rows(&m, nrows, d, &xs, batch, &mut idx, &mut sim);
            idx[0]
        });
        push_row(&mut rows, "kernel_nearest_scalar", "kernel", nrows, r.mean_ns, dots);
        let r = b.run_throughput("kernel_nearest_dispatch", dots, "dot/s", || {
            idx.iter_mut().for_each(|i| *i = 0);
            sim.iter_mut().for_each(|s| *s = f32::NEG_INFINITY);
            kernels::nearest_rows(&m, nrows, d, &xs, batch, &mut idx, &mut sim);
            idx[0]
        });
        push_row(&mut rows, "kernel_nearest_dispatch", "kernel", nrows, r.mean_ns, dots);
    }

    // ---- single-token decode per mixer x N, through the trait ----------
    println!("\n-- single-token decode (write+read) per mixer x N, via SeqMixer --");
    let context = 2048usize;
    let mut kinds: Vec<(&'static str, usize, MixerKind)> = Vec::new();
    for n in [256usize, 1024, 4096] {
        kinds.push(("ovq", n, MixerKind::Ovq { n_max: n }));
        kinds.push(("vq", n, MixerKind::Vq { n }));
    }
    kinds.push(("linear_attn", 0, MixerKind::LinearAttention));
    kinds.push(("gdn", 0, MixerKind::Gdn));
    kinds.push(("sliding_window", 0, MixerKind::SlidingWindow { window: 128 }));
    kinds.push(("kv_cache", 0, MixerKind::FullAttention));
    for (label, n, kind) in kinds {
        let mut m = kind.build(d, chunk, 7);
        for _ in 0..context {
            let k = randv(&mut rng, d);
            let v = randv(&mut rng, d);
            m.write(&k, &v);
        }
        m.flush();
        let q = randv(&mut rng, d);
        let k = randv(&mut rng, d);
        let v = randv(&mut rng, d);
        let mut out = vec![0.0f32; m.d_out()];
        let mut scratch = Scratch::new();
        let name = if n > 0 {
            format!("decode_{label}_N{n}")
        } else {
            format!("decode_{label}_T{context}")
        };
        // full attention is benched read-only: a timed write would grow
        // the cache by one token per sample and the labeled context T
        // would be a lie by the end of the measure window. All other
        // mixers have constant (or saturating) state, so write+read is
        // the honest amortized decode cost.
        let r = if matches!(kind, MixerKind::FullAttention) {
            b.run_throughput(&name, 1.0, "tok/s", || {
                m.read(&q, &mut out, &mut scratch);
                out[0]
            })
        } else {
            b.run_throughput(&name, 1.0, "tok/s", || {
                m.write(&k, &v);
                m.read(&q, &mut out, &mut scratch);
                out[0]
            })
        };
        push_row(&mut rows, &name, label, n, r.mean_ns, 1.0);
    }

    // quantized dictionary storage through the same trait path: decode
    // cost with the OVQ dictionaries held in f16/i8 (fused dequant reads)
    for quant in [QuantMode::F16, QuantMode::I8] {
        let mut m = MixerKind::Ovq { n_max: 1024 }.build_quant(d, chunk, 7, quant);
        for _ in 0..context {
            let k = randv(&mut rng, d);
            let v = randv(&mut rng, d);
            m.write(&k, &v);
        }
        m.flush();
        let q = randv(&mut rng, d);
        let k = randv(&mut rng, d);
        let v = randv(&mut rng, d);
        let mut out = vec![0.0f32; m.d_out()];
        let mut scratch = Scratch::new();
        let name = format!("decode_ovq_N1024_{}", quant.name());
        let r = b.run_throughput(&name, 1.0, "tok/s", || {
            m.write(&k, &v);
            m.read(&q, &mut out, &mut scratch);
            out[0]
        });
        push_row(&mut rows, &name, "ovq", 1024, r.mean_ns, 1.0);
    }

    // ---- multi-stream multi-head decode through MixerBank --------------
    println!("\n-- MixerBank: 8 streams x 4 heads, d=32 — per-token cost vs N --");
    let (streams, heads, dh, blen) = (8usize, 4usize, 32usize, 32usize);
    for n in [256usize, 1024, 4096] {
        let mut bank = MixerBank::new(streams, heads, |_, _| {
            Box::new(OvqState::new(OvqConfig::new(dh, n, blen)))
        });
        // warm every stream to a steady serving context
        let hd = heads * dh;
        for _ in 0..(1024 / blen) {
            for s in 0..streams {
                bank.submit(
                    s,
                    DecodeChunk {
                        queries: randv(&mut rng, blen * hd),
                        keys: randv(&mut rng, blen * hd),
                        values: randv(&mut rng, blen * hd),
                    },
                );
            }
            bank.drain();
        }
        // pre-generate the measured chunk so the timed loop is pure decode
        let q = randv(&mut rng, blen * hd);
        let k = randv(&mut rng, blen * hd);
        let v = randv(&mut rng, blen * hd);
        let toks = (streams * blen) as f64;
        let dsu = bank.mixer(0, 0).update_bytes_per_chunk(blen) / blen;
        let r = b.run_throughput(&format!("bank_decode_N{n}"), toks, "tok/s", || {
            for s in 0..streams {
                bank.submit(
                    s,
                    DecodeChunk { queries: q.clone(), keys: k.clone(), values: v.clone() },
                );
            }
            bank.drain().len()
        });
        push_row(&mut rows, &format!("bank_decode_N{n}"), "ovq_bank", n, r.mean_ns, toks);
        println!(
            "   N={n:>5}: ΔS = {dsu} B/token (flat in N)  total state {} KiB",
            bank.state_bytes() / 1024
        );
    }

    // ---- per-token cost vs context depth: OVQ flat, KV cache grows -----
    println!("\n-- decode cost vs context depth (the deployment claim) --");
    for depth in [1024usize, 4096, 16384] {
        for kind in [MixerKind::Ovq { n_max: 1024 }, MixerKind::FullAttention] {
            let mut m = kind.build(d, chunk, 7);
            for _ in 0..depth {
                let k = randv(&mut rng, d);
                let v = randv(&mut rng, d);
                m.write(&k, &v);
            }
            m.flush();
            let q = randv(&mut rng, d);
            let k = randv(&mut rng, d);
            let v = randv(&mut rng, d);
            let mut out = vec![0.0f32; d];
            let mut scratch = Scratch::new();
            let label = m.kind_name();
            let name = format!("depth_{label}_T{depth}");
            // read-only for the kv cache, same reasoning as above: keep
            // the measured context pinned at the labeled depth
            let r = if matches!(kind, MixerKind::FullAttention) {
                b.run_throughput(&name, 1.0, "tok/s", || {
                    m.read(&q, &mut out, &mut scratch);
                    out[0]
                })
            } else {
                b.run_throughput(&name, 1.0, "tok/s", || {
                    m.write(&k, &v);
                    m.read(&q, &mut out, &mut scratch);
                    out[0]
                })
            };
            push_row(&mut rows, &name, label, depth, r.mean_ns, 1.0);
        }
    }

    // ---- machine-readable summary --------------------------------------
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut o = BTreeMap::new();
            o.insert("name".to_string(), Json::Str(r.name.clone()));
            o.insert("mixer".to_string(), Json::Str(r.mixer.to_string()));
            o.insert("n".to_string(), Json::Num(r.n as f64));
            o.insert("mean_ns".to_string(), Json::Num(r.mean_ns));
            o.insert("tok_per_s".to_string(), Json::Num(r.tok_per_s));
            Json::Obj(o)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("ovqcore".to_string()));
    top.insert("backend".to_string(), Json::Str(kernels::backend().to_string()));
    top.insert("d".to_string(), Json::Num(d as f64));
    top.insert("chunk".to_string(), Json::Num(chunk as f64));
    top.insert(
        "speedup_blocked_vs_scalar_N4096".to_string(),
        Json::Num(speedup_at_4096),
    );
    top.insert("results".to_string(), Json::Arr(json_rows));
    let path = "BENCH_ovqcore.json";
    match std::fs::write(path, format!("{}\n", Json::Obj(top))) {
        Ok(()) => println!("\nwrote {path} ({} rows)", rows.len()),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
    println!(
        "\n(expected: blocked >= 2x scalar at N=4096; ΔS flat in N; ovq decode\n flat in context depth while kv_cache grows ~linearly)"
    );
}
