//! Runtime hot-path latency: train-step and eval-step HLO execution on
//! the quickstart model, plus the literal-building overhead in isolation —
//! the L3 numbers for EXPERIMENTS.md §Perf.

use ovq::data::batch::Batch;
use ovq::data::by_name;
use ovq::runtime::{literal_f32, literal_i32, Runtime};
use ovq::util::bench::Bench;
use ovq::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bench::quick() } else { Bench::default() };

    let rt = Runtime::from_env()?;
    let model = rt.load_model("quickstart")?;
    let (bs, t) = model.train_shape()?;
    let vocab = model.manifest.cfg_usize("vocab", 256);
    let gen = by_name("icr", vocab)?;
    let mut rng = Rng::new(1);
    let batch = Batch::generate_train(gen.as_ref(), &mut rng, bs, t);

    // literal building overhead in isolation
    b.run_throughput("literal_build_batch", (bs * t) as f64, "tok/s", || {
        (
            literal_i32(&[bs, t], &batch.tokens),
            literal_i32(&[bs, t], &batch.targets),
            literal_f32(&[bs, t], &batch.mask),
        )
    });

    // full train step (params round-trip + execute)
    let mut state = model.init(3)?;
    b.run_throughput(
        &format!("train_step_{}x{}", bs, t),
        (bs * t) as f64,
        "tok/s",
        || {
            model
                .train_step(&mut state, &batch.tokens, &batch.targets, &batch.mask)
                .unwrap()
                .loss
        },
    );

    // eval step
    let eb = Batch::generate(gen.as_ref(), &mut rng, 2, 128);
    b.run_throughput("eval_step_2x128", (2 * 128) as f64, "tok/s", || {
        model
            .eval("eval_128", &state.params, &eb.tokens, &eb.targets, &eb.mask)
            .unwrap()
            .loss
    });

    // param host round-trip cost (the carry overhead per step)
    b.run("param_state_clone", || {
        state
            .params
            .iter()
            .map(|l| l.to_vec::<f32>().unwrap().len())
            .sum::<usize>()
    });
    Ok(())
}
