//! Serving-path throughput: dynamic batcher end-to-end (client -> queue ->
//! batched HLO execute -> reply) at different offered loads, on the
//! quickstart model.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use ovq::coordinator::server::{serve_loop, ScoreRequest};
use ovq::runtime::Runtime;
use ovq::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_env()?;
    let model = rt.load_model("quickstart")?;
    let prog = "eval_128";
    let t = 128usize;
    let vocab = model.manifest.cfg_usize("vocab", 256);

    for n_requests in [16usize, 64] {
        let (tx, rx) = mpsc::channel::<ScoreRequest>();
        let producer = std::thread::spawn(move || {
            let gen = ovq::data::by_name("icr", vocab);
            let mut rng = Rng::new(9);
            let mut replies = Vec::new();
            for _ in 0..n_requests {
                let ex = gen.generate(&mut rng, t);
                let (rtx, rrx) = mpsc::channel();
                tx.send(ScoreRequest {
                    tokens: ex.tokens[..t].to_vec(),
                    targets: ex.tokens[1..t + 1].to_vec(),
                    mask: ex
                        .score
                        .iter()
                        .map(|&s| if s { 1.0 } else { 0.0 })
                        .collect(),
                    reply: rtx,
                    submitted: Instant::now(),
                })
                .unwrap();
                replies.push(rrx);
            }
            drop(tx);
            replies.into_iter().filter_map(|r| r.recv().ok()).count()
        });
        let t0 = Instant::now();
        let stats = serve_loop(&model, prog, rx, Duration::from_millis(2))?;
        let done = producer.join().unwrap();
        print!("offered={n_requests:>3} completed={done:>3}  ");
        stats.report(t0.elapsed());
    }
    Ok(())
}
