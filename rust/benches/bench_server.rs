//! Serving-path throughput, both halves:
//!
//!  1. dynamic batcher end-to-end (client -> queue -> batched HLO execute
//!     -> reply) at different offered loads, on the quickstart model —
//!     skipped with a notice when no PJRT backend/artifacts are present;
//!  2. the streaming-decode engine: MixerBank multi-stream x multi-head
//!     sweeps over dictionary size N and engine shape, reporting
//!     aggregate tok/s and per-stream chunk-latency percentiles.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use ovq::coordinator::server::{run_decode_engine, serve_loop, DecodeConfig, ScoreRequest};
use ovq::runtime::Runtime;
use ovq::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    match Runtime::from_env().and_then(|rt| bench_batched(&rt)) {
        Ok(()) => {}
        Err(e) => println!("batched HLO serving bench skipped: {e}"),
    }

    println!("\n-- streaming decode: MixerBank sweeps --");
    // dictionary-size sweep at a fixed engine shape
    for n_max in [256usize, 1024, 4096] {
        let mut cfg = DecodeConfig::new(n_max);
        cfg.streams = 8;
        cfg.heads = 4;
        cfg.d_head = 32;
        cfg.tokens = 1024;
        let r = run_decode_engine(&cfg);
        println!(
            "N={n_max:>5}  8x4 d32: {:>10.0} tok/s  state {:>8} B  p99(stream0) {:>8.1} us",
            r.tokens_per_sec(),
            r.state_bytes,
            r.per_stream[0].p99_us
        );
    }
    // engine-shape sweep at a fixed dictionary
    for (streams, heads) in [(1usize, 1usize), (4, 4), (16, 4), (32, 8)] {
        let mut cfg = DecodeConfig::new(1024);
        cfg.streams = streams;
        cfg.heads = heads;
        cfg.d_head = 32;
        cfg.tokens = 512;
        let r = run_decode_engine(&cfg);
        let worst_p99 = r
            .per_stream
            .iter()
            .map(|s| s.p99_us)
            .fold(0.0f64, f64::max);
        println!(
            "{streams:>3} streams x {heads} heads: {:>10.0} tok/s aggregate  worst p99 {:>8.1} us",
            r.tokens_per_sec(),
            worst_p99
        );
    }
    Ok(())
}

fn bench_batched(rt: &Runtime) -> anyhow::Result<()> {
    let model = rt.load_model("quickstart")?;
    let prog = "eval_128";
    let t = 128usize;
    let vocab = model.manifest.cfg_usize("vocab", 256);

    for n_requests in [16usize, 64] {
        let (tx, rx) = mpsc::channel::<ScoreRequest>();
        let producer = std::thread::spawn(move || {
            let gen = ovq::data::by_name("icr", vocab);
            let mut rng = Rng::new(9);
            let mut replies = Vec::new();
            for _ in 0..n_requests {
                let ex = gen.generate(&mut rng, t);
                let (rtx, rrx) = mpsc::channel();
                tx.send(ScoreRequest {
                    tokens: ex.tokens[..t].to_vec(),
                    targets: ex.tokens[1..t + 1].to_vec(),
                    mask: ex
                        .score
                        .iter()
                        .map(|&s| if s { 1.0 } else { 0.0 })
                        .collect(),
                    reply: rtx,
                    submitted: Instant::now(),
                })
                .unwrap();
                replies.push(rrx);
            }
            drop(tx);
            replies.into_iter().filter_map(|r| r.recv().ok()).count()
        });
        let t0 = Instant::now();
        let stats = serve_loop(&model, prog, rx, Duration::from_millis(2))?;
        let done = producer.join().unwrap();
        print!("offered={n_requests:>3} completed={done:>3}  ");
        stats.report(t0.elapsed());
    }
    Ok(())
}
