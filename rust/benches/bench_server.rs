//! Serving-path throughput, all three layers:
//!
//!  1. dynamic batcher end-to-end (client -> queue -> batched HLO execute
//!     -> reply) at different offered loads, on the quickstart model —
//!     skipped with a notice when no PJRT backend/artifacts are present;
//!  2. the single-threaded streaming-decode path: MixerBank sweeps over
//!     dictionary size N and engine shape;
//!  3. the sharded multi-threaded engine on a zipf traffic-replay trace:
//!     threads sweep 1/2/4 (the tentpole's scaling claim) and the
//!     eviction overhead of running with a tight residency cap;
//!  4. autoregressive generation: sampled tok/s over prompt length x
//!     stack depth, plus the greedy-vs-sampled chain overhead;
//!  5. the HTTP edge: completions over a real localhost socket, blocking
//!     vs SSE-streamed, with first-token latency for the streamed path;
//!  6. observability: the identical decode workload at `--obs off` vs
//!     `--obs trace`, reporting full-span-capture overhead (`obs_overhead_pct`).
//!
//! Emits machine-readable BENCH_server.json alongside BENCH_ovqcore.json
//! so the perf trajectory covers serving, not just kernels.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use ovq::coordinator::engine::{DecodeEngine, EngineConfig};
use ovq::coordinator::http::{self, HttpConfig, HttpServer};
use ovq::coordinator::sampler::{SamplingParams, StopCriteria};
use ovq::coordinator::server::{run_decode_engine, serve_loop, DecodeConfig, ScoreRequest};
use ovq::coordinator::traffic::{self, TrafficConfig};
use ovq::ovqcore::lm::LmConfig;
use ovq::ovqcore::memstate::MixerKind;
use ovq::ovqcore::mixer::{PrefillMode, Scratch};
use ovq::ovqcore::stack::StackConfig;
use ovq::runtime::Runtime;
use ovq::util::json::Json;
use ovq::util::obs::{self, ObsLevel};
use ovq::util::rng::Rng;

struct Row {
    name: String,
    threads: usize,
    tok_per_s: f64,
    extra: BTreeMap<String, Json>,
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    match Runtime::from_env().and_then(|rt| bench_batched(&rt)) {
        Ok(()) => {}
        Err(e) => println!("batched HLO serving bench skipped: {e}"),
    }
    let mut rows: Vec<Row> = Vec::new();

    println!("\n-- streaming decode: MixerBank sweeps (single-threaded) --");
    // dictionary-size sweep at a fixed engine shape
    for n_max in [256usize, 1024, 4096] {
        let mut cfg = DecodeConfig::new(n_max);
        cfg.streams = 8;
        cfg.heads = 4;
        cfg.d_head = 32;
        cfg.tokens = if quick { 256 } else { 1024 };
        let r = run_decode_engine(&cfg);
        println!(
            "N={n_max:>5}  8x4 d32: {:>10.0} tok/s  state {:>8} B  p99(stream0) {:>8.1} us",
            r.tokens_per_sec(),
            r.state_bytes,
            r.per_stream[0].p99_us
        );
        rows.push(Row {
            name: format!("decode_1t_N{n_max}"),
            threads: 1,
            tok_per_s: r.tokens_per_sec(),
            extra: BTreeMap::from([(
                "state_bytes".to_string(),
                Json::Num(r.state_bytes as f64),
            )]),
        });
    }
    // engine-shape sweep at a fixed dictionary
    for (streams, heads) in [(1usize, 1usize), (4, 4), (16, 4), (32, 8)] {
        let mut cfg = DecodeConfig::new(1024);
        cfg.streams = streams;
        cfg.heads = heads;
        cfg.d_head = 32;
        cfg.tokens = if quick { 128 } else { 512 };
        let r = run_decode_engine(&cfg);
        let worst_p99 = r.per_stream.iter().map(|s| s.p99_us).fold(0.0f64, f64::max);
        println!(
            "{streams:>3} streams x {heads} heads: {:>10.0} tok/s aggregate  worst p99 {:>8.1} us",
            r.tokens_per_sec(),
            worst_p99
        );
    }

    // ---- the tentpole: threads sweep on the zipf traffic-replay trace ----
    println!("\n-- sharded engine: zipf traffic replay, threads sweep --");
    let mut tcfg = TrafficConfig::new(64, if quick { 800 } else { 6000 });
    tcfg.chunk_sizes = vec![8, 32, 64];
    let events = traffic::generate(&tcfg);
    let shape = traffic::summarize(&events);
    println!(
        "trace: {} events, {} tokens, {} distinct sessions, hottest {:.0}%, \
         max burst {}",
        shape.events,
        shape.tokens,
        shape.distinct_sessions,
        100.0 * shape.hottest_share,
        shape.max_burst
    );
    let mut tps_1t = 0.0f64;
    let mut speedup_4t = 0.0f64;
    for threads in [1usize, 2, 4] {
        let mut ecfg = EngineConfig::new(MixerKind::Ovq { n_max: 1024 }, 4, 32, 32);
        ecfg.threads = threads;
        ecfg.queue_depth = 64;
        let engine = DecodeEngine::start(ecfg);
        let t0 = Instant::now();
        let tokens = traffic::replay(&engine, &events, tcfg.seed, None);
        engine.flush_all();
        let report = engine.finish();
        let wall = t0.elapsed();
        let tps = tokens as f64 / wall.as_secs_f64();
        if threads == 1 {
            tps_1t = tps;
        }
        if threads == 4 {
            speedup_4t = tps / tps_1t;
        }
        println!(
            "threads={threads}: {:>10.0} tok/s  p50 {:>8.1} us  p99 {:>9.1} us  \
             util {:?}",
            tps,
            report.latency_us(50.0),
            report.latency_us(99.0),
            report
                .utilization()
                .iter()
                .map(|u| (u * 100.0).round() / 100.0)
                .collect::<Vec<_>>(),
        );
        rows.push(Row {
            name: format!("engine_zipf_{threads}t"),
            threads,
            tok_per_s: tps,
            extra: BTreeMap::from([
                ("p50_us".to_string(), Json::Num(report.latency_us(50.0))),
                ("p99_us".to_string(), Json::Num(report.latency_us(99.0))),
                ("state_bytes".to_string(), Json::Num(report.state_bytes() as f64)),
            ]),
        });
    }
    println!("4-thread speedup over 1 thread: {speedup_4t:.2}x");

    // ---- eviction overhead: tight residency cap vs uncapped ------------
    println!("\n-- eviction overhead: residency cap forces snapshot churn --");
    let mut tcfg2 = TrafficConfig::new(48, if quick { 400 } else { 2000 });
    tcfg2.burst_p = 0.2; // more session switching -> more LRU pressure
    let events2 = traffic::generate(&tcfg2);
    let mut evict_overhead = 0.0f64;
    let mut base_tps = 0.0f64;
    for (label, cap) in [("uncapped", usize::MAX / 2), ("cap4", 4)] {
        let mut ecfg = EngineConfig::new(MixerKind::Ovq { n_max: 1024 }, 4, 32, 32);
        ecfg.threads = 2;
        ecfg.max_resident = cap;
        let engine = DecodeEngine::start(ecfg);
        let t0 = Instant::now();
        let tokens = traffic::replay(&engine, &events2, tcfg2.seed, None);
        engine.flush_all();
        let report = engine.finish();
        let tps = tokens as f64 / t0.elapsed().as_secs_f64();
        println!(
            "{label:>9}: {:>10.0} tok/s  {} evictions, {} restores, snapshots \
             {:.1} KiB",
            tps,
            report.evictions(),
            report.restores(),
            report.shards.iter().map(|s| s.snapshot_bytes).sum::<usize>() as f64 / 1024.0,
        );
        if cap > 4 {
            base_tps = tps;
        } else {
            evict_overhead = base_tps / tps.max(1e-9);
            rows.push(Row {
                name: "engine_evict_cap4".to_string(),
                threads: 2,
                tok_per_s: tps,
                extra: BTreeMap::from([
                    ("evictions".to_string(), Json::Num(report.evictions() as f64)),
                    ("restores".to_string(), Json::Num(report.restores() as f64)),
                ]),
            });
        }
    }
    println!("eviction slowdown factor: {evict_overhead:.2}x");

    // ---- prefill: blocked long-prompt ingest vs prompt length ----------
    println!("\n-- prefill: blocked long-prompt ingest vs prompt length --");
    let prompt_lens: &[usize] = if quick { &[1024, 4096] } else { &[4096, 16384, 65536] };
    let (pheads, pd) = (2usize, 32usize);
    let phd = pheads * pd;
    let mut prefill_tps_at = BTreeMap::new();
    for &plen in prompt_lens {
        let mut ecfg = EngineConfig::new(MixerKind::Ovq { n_max: 256 }, pheads, pd, 32);
        ecfg.threads = 1;
        ecfg.prefill_quantum = 512;
        let engine = DecodeEngine::start(ecfg);
        let prompt = traffic::synth_chunk(0xFEED, 1, 0, plen, phd);
        let t0 = Instant::now();
        engine.submit_prefill(1, prompt);
        let report = engine.finish();
        let tps = plen as f64 / t0.elapsed().as_secs_f64();
        prefill_tps_at.insert(plen, tps);
        println!(
            "L={plen:>6}: {tps:>10.0} tok/s  ttft {:>9.2} ms",
            report.ttft_us(50.0) / 1e3
        );
        rows.push(Row {
            name: format!("prefill_L{plen}"),
            threads: 1,
            tok_per_s: tps,
            extra: BTreeMap::from([(
                "ttft_us".to_string(),
                Json::Num(report.ttft_us(50.0)),
            )]),
        });
    }
    // baseline: the same prompt through the decode path in 32-token chunks
    // (per-arrival dispatch, no batched kernels) — the amortization factor
    let blen = if quick { 4096usize } else { 16384 };
    {
        let mut ecfg = EngineConfig::new(MixerKind::Ovq { n_max: 256 }, pheads, pd, 32);
        ecfg.threads = 1;
        let engine = DecodeEngine::start(ecfg);
        let prompt = traffic::synth_chunk(0xFEED, 1, 0, blen, phd);
        let t0 = Instant::now();
        let mut i = 0;
        while i < blen {
            let (a, b) = (i * phd, (i + 32) * phd);
            engine.submit(
                1,
                ovq::ovqcore::bank::DecodeChunk {
                    queries: prompt.queries[a..b].to_vec(),
                    keys: prompt.keys[a..b].to_vec(),
                    values: prompt.values[a..b].to_vec(),
                },
            );
            i += 32;
        }
        engine.finish();
        let tps = blen as f64 / t0.elapsed().as_secs_f64();
        let speedup = prefill_tps_at.get(&blen).copied().unwrap_or(0.0) / tps.max(1e-9);
        println!("L={blen:>6} via decode chunks: {tps:>10.0} tok/s  (prefill is {speedup:.2}x)");
        rows.push(Row {
            name: format!("prefill_baseline_decode_L{blen}"),
            threads: 1,
            tok_per_s: tps,
            extra: BTreeMap::new(),
        });
    }

    // ---- parallel prefill: intra-request fan-out, 64k-TTFT sweep -------
    println!("\n-- parallel prefill: one 64k OVQ prompt, worker-count sweep --");
    let fan_len = 65_536usize;
    let (fheads, fd) = (2usize, 32usize);
    let fan_prompt = traffic::synth_chunk(0xFA57, 1, 0, fan_len, fheads * fd);
    let mut fan_tps_1t = 0.0f64;
    let mut fanout_speedup_4t = 0.0f64;
    for threads in [1usize, 2, 4] {
        let mut ecfg = EngineConfig::new(MixerKind::Ovq { n_max: 256 }, fheads, fd, 32);
        ecfg.threads = threads;
        ecfg.prefill_quantum = 512;
        let engine = DecodeEngine::start(ecfg);
        let t0 = Instant::now();
        engine.submit_prefill(1, fan_prompt.clone());
        let report = engine.finish();
        let tps = fan_len as f64 / t0.elapsed().as_secs_f64();
        if threads == 1 {
            fan_tps_1t = tps;
        }
        if threads == 4 {
            fanout_speedup_4t = tps / fan_tps_1t;
        }
        let ttft = report.ttft_us(50.0);
        println!("threads={threads}: {tps:>10.0} tok/s  ttft {:>9.2} ms", ttft / 1e3);
        rows.push(Row {
            name: format!("ttft64k_ovq_t{threads}"),
            threads,
            tok_per_s: tps,
            extra: BTreeMap::from([("ttft_us".to_string(), Json::Num(ttft))]),
        });
    }
    println!("fan-out speedup at 4 threads: {fanout_speedup_4t:.2}x");

    // ---- chunkwise scan forms: tolerance-mode prefill vs exact serial --
    println!("\n-- chunkwise prefill: scan mixers, tolerance mode vs exact serial --");
    let scan_len = if quick { 4096usize } else { 16384 };
    let scan_d = 64usize;
    for (label, kind) in [("gdn", MixerKind::Gdn), ("lin", MixerKind::LinearAttention)] {
        let mut srng = Rng::new(0x5CA7);
        let mut mk = || -> Vec<f32> {
            (0..scan_len * scan_d).map(|_| srng.normal() as f32).collect()
        };
        let (q, k, v) = (mk(), mk(), mk());
        let mut out = vec![0.0f32; scan_len * scan_d];
        let mut scratch = Scratch::new();
        let mut measure = |chunk: Option<usize>| -> f64 {
            let mut m = kind.build(scan_d, 64, 3);
            if let Some(c) = chunk {
                m.set_prefill_mode(PrefillMode::Chunkwise { chunk: c });
            }
            let t0 = Instant::now();
            m.process_prefill(&q, &k, &v, &mut out, &mut scratch);
            scan_len as f64 / t0.elapsed().as_secs_f64()
        };
        let serial_tps = measure(None);
        let par_tps = measure(Some(64));
        println!(
            "{label}: serial {serial_tps:>10.0} tok/s  chunkwise(C=64) {par_tps:>10.0} tok/s  \
             ({:.2}x)",
            par_tps / serial_tps.max(1e-9)
        );
        rows.push(Row {
            name: format!("prefill_serial_{label}"),
            threads: 1,
            tok_per_s: serial_tps,
            extra: BTreeMap::new(),
        });
        rows.push(Row {
            name: format!("prefill_par_{label}"),
            threads: 1,
            tok_per_s: par_tps,
            extra: BTreeMap::from([("chunk".to_string(), Json::Num(64.0))]),
        });
    }

    // ---- stack depth sweep: full model stacks through the engine -------
    println!("\n-- stack depth sweep: multi-layer model stacks (L x mixer kind) --");
    let stack_tokens_per_stream = if quick { 128usize } else { 512 };
    let (sd_model, sd_ff, sheads, sd_head, schunk) = (32usize, 64usize, 2usize, 16usize, 32usize);
    for (label, kind) in [
        ("ovq", MixerKind::Ovq { n_max: 256 }),
        ("kv", MixerKind::SlidingWindow { window: 128 }),
    ] {
        for layers in [1usize, 4, 8] {
            let stack =
                StackConfig::uniform(layers, sd_model, sd_ff, sheads, sd_head, schunk, kind);
            let mut ecfg = EngineConfig::for_stack(stack);
            ecfg.threads = 2;
            let engine = DecodeEngine::start(ecfg);
            let t0 = Instant::now();
            let mut tokens = 0usize;
            for seq in 0..stack_tokens_per_stream / schunk {
                for s in 0..4u64 {
                    engine.submit(s, traffic::synth_chunk(0x57AC, s, seq, schunk, sd_model));
                    tokens += schunk;
                }
            }
            engine.flush_all();
            let report = engine.finish();
            let tps = tokens as f64 / t0.elapsed().as_secs_f64();
            println!(
                "L={layers} x {label:>3}: {tps:>10.0} tok/s  state {:>9} B  \
                 decode p99 {:>8.1} us",
                report.state_bytes(),
                report.latency_us(99.0),
            );
            rows.push(Row {
                name: format!("stack_L{layers}_{label}"),
                threads: 2,
                tok_per_s: tps,
                extra: BTreeMap::from([
                    ("layers".to_string(), Json::Num(layers as f64)),
                    ("state_bytes".to_string(), Json::Num(report.state_bytes() as f64)),
                    ("p99_us".to_string(), Json::Num(report.latency_us(99.0))),
                ]),
            });
        }
    }

    // ---- continuous batching: long-prompt admissions inside live traffic
    println!("\n-- continuous batching: prompt-mix trace (prefill + decode) --");
    let mut tcfg3 = TrafficConfig::new(16, if quick { 200 } else { 400 })
        .with_prompts(if quick { vec![1024, 4096] } else { vec![4096, 16384] }, 0.4);
    tcfg3.chunk_sizes = vec![8, 32];
    let events3 = traffic::generate(&tcfg3);
    let shape3 = traffic::summarize(&events3);
    {
        let mut ecfg = EngineConfig::new(MixerKind::Ovq { n_max: 256 }, pheads, pd, 32);
        ecfg.threads = 2;
        ecfg.prefill_quantum = 512;
        let engine = DecodeEngine::start(ecfg);
        let t0 = Instant::now();
        let tokens = traffic::replay(&engine, &events3, tcfg3.seed, None);
        engine.flush_all();
        let report = engine.finish();
        let tps = tokens as f64 / t0.elapsed().as_secs_f64();
        println!(
            "{} prompts / {} prompt tokens amid {} events: {:>9.0} tok/s  \
             decode p99 {:>8.1} us  ttft p50 {:>9.2} ms",
            shape3.prompts,
            shape3.prompt_tokens,
            shape3.events,
            tps,
            report.latency_us(99.0),
            report.ttft_us(50.0) / 1e3,
        );
        rows.push(Row {
            name: "engine_prompt_mix_2t".to_string(),
            threads: 2,
            tok_per_s: tps,
            extra: BTreeMap::from([
                ("decode_p99_us".to_string(), Json::Num(report.latency_us(99.0))),
                ("ttft_p50_us".to_string(), Json::Num(report.ttft_us(50.0))),
                ("prompts".to_string(), Json::Num(report.prefill_chunks() as f64)),
            ]),
        });
    }

    // ---- generation: self-feeding decode, prompt length x stack depth --
    println!("\n-- generation: sampled tok/s vs prompt length x stack depth --");
    let gen_vocab = 256usize;
    let gen_max_new = if quick { 32usize } else { 96 };
    let gen_sessions = 4u64;
    let gen_lens: &[usize] = if quick { &[64, 256] } else { &[256, 1024] };
    let mk_lm = |layers: usize| {
        LmConfig::new(
            gen_vocab,
            StackConfig::uniform(layers, 32, 64, 2, 16, 32, MixerKind::Ovq { n_max: 256 }),
        )
    };
    let mut run_gen = |lm: LmConfig, plen: usize, params: SamplingParams, name: String| {
        let mut ecfg = EngineConfig::for_lm(lm);
        ecfg.threads = 2;
        ecfg.prefill_quantum = 512;
        let engine = DecodeEngine::start(ecfg);
        let t0 = Instant::now();
        for s in 0..gen_sessions {
            engine.submit_generate(
                s,
                traffic::synth_tokens(0x6E6, s, plen, gen_vocab),
                params.clone(),
                StopCriteria::max_new(gen_max_new),
            );
        }
        let report = engine.finish();
        let wall = t0.elapsed().as_secs_f64();
        let gen_tps = report.gen_tokens() as f64 / wall;
        let e2e_tps = report.tokens as f64 / wall;
        println!(
            "{name:>24}: {gen_tps:>9.0} sampled tok/s  ({e2e_tps:>9.0} incl. prefill)  \
             completion p50 {:>9.2} ms",
            report.completion_us(50.0) / 1e3,
        );
        rows.push(Row {
            name,
            threads: 2,
            tok_per_s: gen_tps,
            extra: BTreeMap::from([
                ("e2e_tok_per_s".to_string(), Json::Num(e2e_tps)),
                ("completions".to_string(), Json::Num(report.completions() as f64)),
                ("completion_p50_us".to_string(), Json::Num(report.completion_us(50.0))),
            ]),
        });
    };
    for layers in [1usize, 4] {
        for &plen in gen_lens {
            run_gen(
                mk_lm(layers),
                plen,
                SamplingParams::greedy(),
                format!("gen_L{plen}_D{layers}"),
            );
        }
    }
    // greedy-vs-sampled overhead at a fixed shape: the full chain
    // (penalty + temperature + top-k + top-p + categorical) vs argmax
    let overhead_len = 256usize;
    run_gen(mk_lm(2), overhead_len, SamplingParams::greedy(), "gen_greedy".to_string());
    run_gen(mk_lm(2), overhead_len, SamplingParams::sampled(0xCAFE), "gen_sampled".to_string());

    // ---- HTTP edge: completions over a real localhost socket -----------
    println!("\n-- HTTP edge: socket completions, blocking vs SSE-streamed --");
    let http_max_new = if quick { 24usize } else { 64 };
    let http_reqs = if quick { 6usize } else { 16 };
    {
        let mut ecfg = EngineConfig::for_lm(mk_lm(2));
        ecfg.threads = 2;
        ecfg.prefill_quantum = 512;
        let engine = DecodeEngine::start(ecfg);
        let server = HttpServer::start(HttpConfig::default(), engine.handle())?;
        let addr = server.addr();
        for (name, stream) in [("http_gen_blocking", false), ("http_gen_stream", true)] {
            let mut tokens = 0usize;
            let t0 = Instant::now();
            for i in 0..http_reqs {
                let prompt = traffic::synth_tokens(0x1177, i as u64, 64, gen_vocab);
                let body = http::completion_body(
                    None,
                    &prompt,
                    &SamplingParams::greedy(),
                    &StopCriteria::max_new(http_max_new),
                    stream,
                )
                .to_string();
                let resp = http::http_post(addr, "/v1/completions", &[], body.as_bytes())?;
                assert_eq!(resp.status, 200, "bench completion failed: {}", resp.status);
                tokens += if stream {
                    // token events only: drop the done record and [DONE]
                    resp.sse_data().len().saturating_sub(2)
                } else {
                    token_count(&resp.json()?)
                };
            }
            let wall = t0.elapsed().as_secs_f64();
            let tps = tokens as f64 / wall;
            let mut extra = BTreeMap::from([(
                "req_per_s".to_string(),
                Json::Num(http_reqs as f64 / wall),
            )]);
            if stream {
                let probe = http::completion_body(
                    None,
                    &traffic::synth_tokens(0x1177, 99, 64, gen_vocab),
                    &SamplingParams::greedy(),
                    &StopCriteria::max_new(http_max_new),
                    true,
                )
                .to_string();
                let ttft = sse_ttft_us(addr, probe.as_bytes())?;
                println!(
                    "{name:>17}: {tps:>9.0} tok/s over the wire  ttft {:>9.2} ms",
                    ttft / 1e3
                );
                extra.insert("ttft_us".to_string(), Json::Num(ttft));
            } else {
                println!("{name:>17}: {tps:>9.0} tok/s over the wire");
            }
            rows.push(Row { name: name.to_string(), threads: 2, tok_per_s: tps, extra });
        }
        server.stop();
        engine.finish();
    }

    // ---- tiered memory: shared-prefix fork TTFT ------------------------
    println!("\n-- tiered memory: shared-prefix fork, cold vs warm TTFT --");
    let prefix_len = if quick { 4096usize } else { 65_536 };
    let mut warm_speedup = 0.0f64;
    {
        let mut ecfg = EngineConfig::for_lm(mk_lm(2));
        ecfg.threads = 1;
        ecfg.prefill_quantum = 512;
        let engine = DecodeEngine::start(ecfg);
        let handle = engine.handle();
        let prefix = traffic::synth_tokens(0x5EED, u64::MAX, prefix_len, gen_vocab);
        let mut ttfts = BTreeMap::new();
        // cold: the first request to name the prefix prefills it and
        // freezes the template; warm: the next session forks the frozen
        // snapshot and pays only its own 16-token suffix before sampling
        for (name, session) in [("ttft64k_prefix_cold", 1u64), ("ttft64k_prefix_warm", 2)] {
            let mut prompt = prefix.clone();
            prompt.extend(traffic::synth_tokens(0x5EED, session, 16, gen_vocab));
            let (tx, rx) = mpsc::channel();
            let t0 = Instant::now();
            handle
                .try_submit_generate_prefixed(
                    session,
                    prompt,
                    prefix_len,
                    None,
                    SamplingParams::greedy(),
                    StopCriteria::max_new(8),
                    Some(tx),
                )
                .expect("idle engine must admit");
            rx.recv().expect("a first streamed token");
            let ttft_us = t0.elapsed().as_secs_f64() * 1e6;
            while rx.recv().is_ok() {} // drain to completion
            ttfts.insert(name, ttft_us);
            println!("{name:>22}: ttft {:>9.2} ms", ttft_us / 1e3);
            rows.push(Row {
                name: name.to_string(),
                threads: 1,
                tok_per_s: prefix_len as f64 / (ttft_us / 1e6),
                extra: BTreeMap::from([
                    ("ttft_us".to_string(), Json::Num(ttft_us)),
                    ("prefix_tokens".to_string(), Json::Num(prefix_len as f64)),
                ]),
            });
        }
        warm_speedup =
            ttfts["ttft64k_prefix_cold"] / ttfts["ttft64k_prefix_warm"].max(1e-9);
        println!("prefix-fork warm TTFT speedup: {warm_speedup:.1}x");
        drop(handle);
        engine.finish();
    }

    // ---- disk tier: spill/restore churn under a tight residency cap ----
    println!("\n-- disk tier: async spill + restore on the eviction trace --");
    {
        use ovq::ovqcore::store::TempDir;
        let dir = TempDir::new("bench-spill");
        let mut ecfg = EngineConfig::new(MixerKind::Ovq { n_max: 1024 }, 4, 32, 32);
        ecfg.threads = 2;
        ecfg.max_resident = 4;
        ecfg.spill_dir = Some(dir.path().to_path_buf());
        ecfg.ram_blob_budget = 0; // every frozen blob heads to disk
        let engine = DecodeEngine::start(ecfg);
        let t0 = Instant::now();
        let tokens = traffic::replay(&engine, &events2, tcfg2.seed, None);
        engine.flush_all();
        let report = engine.finish();
        let tps = tokens as f64 / t0.elapsed().as_secs_f64();
        let disk_sessions = report.disk_sessions();
        let ram_sessions = report.sessions.len().saturating_sub(disk_sessions);
        println!(
            "cap4 + spill: {tps:>10.0} tok/s  {} spills, {} disk restores, \
             {:.1} KiB on disk; at shutdown {ram_sessions} sessions in RAM, \
             {disk_sessions} on disk",
            report.spills(),
            report.disk_restores(),
            report.disk_bytes() as f64 / 1024.0,
        );
        rows.push(Row {
            name: "spill_restore".to_string(),
            threads: 2,
            tok_per_s: tps,
            extra: BTreeMap::from([
                ("spills".to_string(), Json::Num(report.spills() as f64)),
                ("disk_restores".to_string(), Json::Num(report.disk_restores() as f64)),
                ("disk_bytes".to_string(), Json::Num(report.disk_bytes() as f64)),
            ]),
        });
        // capacity gauges: how the trace's sessions split across the two
        // tiers at shutdown (counts, not rates)
        rows.push(Row {
            name: "resident_sessions_ram".to_string(),
            threads: 2,
            tok_per_s: ram_sessions as f64,
            extra: BTreeMap::new(),
        });
        rows.push(Row {
            name: "resident_sessions_disk".to_string(),
            threads: 2,
            tok_per_s: disk_sessions as f64,
            extra: BTreeMap::new(),
        });
    }

    // ---- observability: span-capture cost on the decode hot path -------
    println!("\n-- observability: decode-path overhead of full span capture --");
    // the same decode workload at --obs off vs --obs trace. Histograms
    // and counters record at every level (they back the reports), so the
    // delta isolates what trace capture adds per chunk: one relaxed
    // level load plus a bounded ring push. Best-of-3 per level damps
    // scheduler noise; the acceptance target is < 2% overhead.
    let obs_tokens = if quick { 512usize } else { 2048 };
    let mut obs_tps: BTreeMap<&str, f64> = BTreeMap::new();
    for (name, level) in [("obs_off", ObsLevel::Off), ("obs_trace", ObsLevel::Trace)] {
        obs::set_level(level);
        let mut best = 0.0f64;
        for _ in 0..3 {
            let mut ecfg = EngineConfig::new(MixerKind::Ovq { n_max: 1024 }, 4, 32, 32);
            ecfg.threads = 2;
            let engine = DecodeEngine::start(ecfg);
            let t0 = Instant::now();
            let mut tokens = 0usize;
            for seq in 0..obs_tokens / 32 {
                for s in 0..8u64 {
                    engine.submit(s, traffic::synth_chunk(0x0B5, s, seq, 32, 128));
                    tokens += 32;
                }
            }
            engine.flush_all();
            engine.finish();
            best = best.max(tokens as f64 / t0.elapsed().as_secs_f64());
        }
        obs_tps.insert(name, best);
        println!("{name:>10}: {best:>10.0} tok/s  (level {})", level.as_str());
        rows.push(Row {
            name: name.to_string(),
            threads: 2,
            tok_per_s: best,
            extra: BTreeMap::from([(
                "obs_level".to_string(),
                Json::Str(level.as_str().to_string()),
            )]),
        });
    }
    obs::set_level(ObsLevel::Metrics);
    let obs_overhead_pct = (obs_tps["obs_off"] / obs_tps["obs_trace"].max(1e-9) - 1.0) * 100.0;
    println!("full-trace decode overhead: {obs_overhead_pct:+.2}%  (target < 2%)");

    // ---- machine-readable summary --------------------------------------
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut o = BTreeMap::new();
            o.insert("name".to_string(), Json::Str(r.name.clone()));
            o.insert("threads".to_string(), Json::Num(r.threads as f64));
            o.insert("tok_per_s".to_string(), Json::Num(r.tok_per_s));
            for (k, v) in &r.extra {
                o.insert(k.clone(), v.clone());
            }
            Json::Obj(o)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("server".to_string()));
    top.insert("trace_events".to_string(), Json::Num(shape.events as f64));
    top.insert("trace_sessions".to_string(), Json::Num(shape.distinct_sessions as f64));
    top.insert("speedup_4t_over_1t".to_string(), Json::Num(speedup_4t));
    top.insert("fanout_speedup_4t".to_string(), Json::Num(fanout_speedup_4t));
    top.insert("eviction_slowdown".to_string(), Json::Num(evict_overhead));
    top.insert("prefix_warm_speedup".to_string(), Json::Num(warm_speedup));
    top.insert("obs_overhead_pct".to_string(), Json::Num(obs_overhead_pct));
    top.insert("results".to_string(), Json::Arr(json_rows));
    let path = "BENCH_server.json";
    match std::fs::write(path, format!("{}\n", Json::Obj(top))) {
        Ok(()) => println!("\nwrote {path} ({} rows)", rows.len()),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
    println!(
        "\n(expected: >= 1.5x aggregate tok/s at 4 threads on the zipf trace; eviction\n \
         churn and long-prompt admissions cost bounded factors, not blowups; blocked\n \
         prefill beats decode-path ingestion of the same prompt; the 64k-TTFT sweep\n \
         improves with worker count — >= 2x at 4 threads via intra-request fan-out;\n \
         chunkwise (tolerance-mode) prefill beats the serial scan forms on gdn/lin;\n \
         stack tok/s falls roughly linearly in depth L at fixed dims, with per-layer\n \
         state flat; sampled tok/s falls roughly linearly in depth too, prompt length\n \
         moves only the e2e rate, and the sampled chain costs a small factor over\n \
         greedy; the HTTP edge delivers the same tokens at a modest factor under\n \
         in-process generation, with streamed time-to-first-token well under the\n \
         blocking path's full-completion latency; a warm shared-prefix fork cuts\n \
         TTFT >= 5x vs the cold build of the same prefix; the disk tier trades a\n \
         bounded tok/s factor for RAM that no longer grows with cold sessions; full\n \
         span capture (--obs trace) costs < 2% decode throughput vs --obs off)"
    );
    Ok(())
}

fn token_count(completion: &Json) -> usize {
    match completion.get("tokens") {
        Some(Json::Arr(a)) => a.len(),
        _ => 0,
    }
}

/// Time-to-first-token over a raw socket: send a streamed completion and
/// measure until the first `data: ` frame lands (the JSON client dechunks
/// the whole body first, so it cannot observe this).
fn sse_ttft_us(addr: std::net::SocketAddr, payload: &[u8]) -> anyhow::Result<f64> {
    use std::io::{Read, Write};
    let t0 = Instant::now();
    let mut s = std::net::TcpStream::connect(addr)?;
    let head = format!(
        "POST /v1/completions HTTP/1.1\r\nhost: {addr}\r\n\
         content-type: application/json\r\ncontent-length: {}\r\n\
         connection: close\r\n\r\n",
        payload.len()
    );
    s.write_all(head.as_bytes())?;
    s.write_all(payload)?;
    let mut buf = Vec::new();
    let mut tmp = [0u8; 4096];
    let ttft = loop {
        let n = s.read(&mut tmp)?;
        if n == 0 {
            anyhow::bail!("stream closed before the first SSE frame");
        }
        buf.extend_from_slice(&tmp[..n]);
        if buf.windows(6).any(|w| w == &b"data: "[..]) {
            break t0.elapsed();
        }
    };
    // drain the remaining frames so the handler's writes don't hit a reset
    while s.read(&mut tmp).map(|n| n > 0).unwrap_or(false) {}
    Ok(ttft.as_secs_f64() * 1e6)
}

fn bench_batched(rt: &Runtime) -> anyhow::Result<()> {
    let model = rt.load_model("quickstart")?;
    let prog = "eval_128";
    let t = 128usize;
    let vocab = model.manifest.cfg_usize("vocab", 256);

    for n_requests in [16usize, 64] {
        let (tx, rx) = mpsc::channel::<ScoreRequest>();
        let producer = std::thread::spawn(move || {
            let gen = ovq::data::by_name("icr", vocab).expect("icr is a known task");
            let mut rng = Rng::new(9);
            let mut replies = Vec::new();
            for _ in 0..n_requests {
                let ex = gen.generate(&mut rng, t);
                let (rtx, rrx) = mpsc::channel();
                tx.send(ScoreRequest {
                    tokens: ex.tokens[..t].to_vec(),
                    targets: ex.tokens[1..t + 1].to_vec(),
                    mask: ex
                        .score
                        .iter()
                        .map(|&s| if s { 1.0 } else { 0.0 })
                        .collect(),
                    reply: rtx,
                    submitted: Instant::now(),
                })
                .unwrap();
                replies.push(rrx);
            }
            drop(tx);
            replies.into_iter().filter_map(|r| r.recv().ok()).count()
        });
        let t0 = Instant::now();
        let stats = serve_loop(&model, prog, rx, Duration::from_millis(2))?;
        let done = producer.join().unwrap();
        print!("offered={n_requests:>3} completed={done:>3}  ");
        stats.report(t0.elapsed());
    }
    Ok(())
}
