//! Appendix D FLOPs model — exact implementation of Tables 7-8 and
//! eqs. 55-58, regenerating Figs. 15 (FLOPs vs context length) and 16
//! (FLOPs ratio vs standard attention).

use crate::util::cli::Args;
use crate::util::csv::CsvWriter;

use crate::ovqcore::growth_n_t;
use crate::ovqcore::memstate::MixerKind;

/// Shared workload geometry (paper Table 6 notation).
#[derive(Debug, Clone, Copy)]
pub struct Geom {
    pub b: f64,     // batch
    pub h: f64,     // heads
    pub d: f64,     // head dim
    pub l: f64,     // chunk size
}

impl Default for Geom {
    fn default() -> Self {
        // paper's setup: H=8, d=128, L=128 (App. D plots)
        Geom { b: 1.0, h: 8.0, d: 128.0, l: 128.0 }
    }
}

/// Causal self-attention FLOPs (paper Table 7).
pub fn attn_flops(g: Geom, t: f64, train: bool) -> f64 {
    // inference total: 2 B H T^2 d / 2 = B H T^2 d   (QK^T causal) plus AV
    // (B H T^2 d); the paper's table folds to: infer = 2BHT^2d/2, train 3x.
    let infer = 2.0 * g.b * g.h * t * t * g.d / 2.0  // S = QK^T (causal)
        + g.b * g.h * t * t * g.d; // AV
    if train {
        3.0 * infer
    } else {
        infer
    }
}

/// OVQ-attention FLOPs (paper Table 8 / eqs. 55-56): sum over chunks of
/// BHLd(6N_c + 2L) at inference, BHLd(12N_c + 6L) in training.
pub fn ovq_flops(g: Geom, t: f64, n_max: usize, train: bool) -> f64 {
    let l = g.l as usize;
    let chunks = (t as usize).div_ceil(l);
    let mut total = 0.0;
    for c in 0..chunks {
        let n_c = growth_n_t(c * l, n_max) as f64;
        let per = if train {
            g.b * g.h * g.l * g.d * (12.0 * n_c + 6.0 * g.l)
        } else {
            g.b * g.h * g.l * g.d * (6.0 * n_c + 2.0 * g.l)
        };
        total += per;
    }
    total
}

/// Gated delta net FLOPs (paper eqs. 57-58, following Lufkin et al. /
/// Yang et al. accounting).
pub fn gdn_flops(g: Geom, t: f64, train: bool) -> f64 {
    let infer = 6.0 * g.b * t * g.h * g.d * g.d
        + g.b * t * g.h * (6.0 * g.d * g.d + 2.0 * g.l * 5.0 * g.d + g.l * g.l / 3.0);
    if train {
        18.0 * g.b * t * g.h * g.d * g.d
            + 3.0 * g.b
                * t
                * g.h
                * (6.0 * g.d * g.d + 2.0 * g.l * 5.0 * g.d + g.l * g.l / 3.0)
    } else {
        infer
    }
}

/// Inference FLOPs of one sequence-mixer *layer* of the given kind over
/// a T-token pass — the per-kind term the whole-stack model sums. Dense
/// recurrences (linear attention / GDN) share the GDN accounting;
/// sliding-window attention is full attention truncated to the window.
pub fn mixer_flops(kind: MixerKind, g: Geom, t: f64) -> f64 {
    match kind {
        MixerKind::FullAttention => attn_flops(g, t, false),
        MixerKind::SlidingWindow { window } => {
            let w = (window as f64).min(t);
            // per token: QK^T over <= w cached rows + AV gather
            3.0 * g.b * g.h * t * w * g.d
        }
        MixerKind::Ovq { n_max } => ovq_flops(g, t, n_max, false),
        // constant-N dictionary: the OVQ per-chunk cost with N_c pinned
        MixerKind::Vq { n } => g.b * g.h * t * g.d * (6.0 * n as f64 + 2.0 * g.l),
        MixerKind::LinearAttention | MixerKind::Gdn => gdn_flops(g, t, false),
    }
}

/// Dense per-token FLOPs of one stack layer outside the mixer: q/k/v and
/// output projections plus the gated MLP (2mn per matmul) and the norm /
/// gate elementwise work.
pub fn stack_dense_flops_per_token(d_model: f64, d_ff: f64, g: Geom) -> f64 {
    let hd = g.h * g.d;
    let proj = 2.0 * (3.0 * hd * d_model) + 2.0 * (d_model * hd);
    let mlp = 2.0 * (2.0 * d_ff * d_model) + 2.0 * (d_model * d_ff);
    let pointwise = 6.0 * d_model + 3.0 * d_ff; // norms, residuals, silu-gate
    g.b * (proj + mlp + pointwise)
}

/// Whole-stack inference FLOPs for a T-token pass over a per-layer mixer
/// schedule: each layer pays the dense cost (linear in T) plus its own
/// mixer term — the model the ROADMAP's serving trade-offs live in,
/// where projection/MLP FLOPs and per-layer mixer state compete.
pub fn stack_flops(kinds: &[MixerKind], g: Geom, d_model: f64, d_ff: f64, t: f64) -> f64 {
    let dense = kinds.len() as f64 * t * stack_dense_flops_per_token(d_model, d_ff, g);
    let mixers: f64 = kinds.iter().map(|&k| mixer_flops(k, g, t)).sum();
    dense + mixers
}

/// One row of the Fig. 15/16 sweep.
#[derive(Debug, Clone)]
pub struct FlopsRow {
    pub t: usize,
    pub attn: f64,
    pub ovq: f64,
    pub gdn: f64,
}

pub fn sweep(g: Geom, n_max: usize, lengths: &[usize], train: bool) -> Vec<FlopsRow> {
    lengths
        .iter()
        .map(|&t| FlopsRow {
            t,
            attn: attn_flops(g, t as f64, train),
            ovq: ovq_flops(g, t as f64, n_max, train),
            gdn: gdn_flops(g, t as f64, train),
        })
        .collect()
}

/// `ovq flops` CLI: prints Fig. 15 (absolute) and Fig. 16 (ratio) series
/// and writes CSVs under --out (default results/).
pub fn cmd_flops(args: &Args) -> anyhow::Result<()> {
    let out_dir = args.opt_or("out", "results");
    let n_max = args.opt_usize("n-dict", 8192)?;
    let g = Geom::default();
    let lengths: Vec<usize> =
        (10..=17).map(|p| 1usize << p).collect(); // 1k .. 128k

    for (label, train) in [("inference", false), ("training", true)] {
        let rows = sweep(g, n_max, &lengths, train);
        println!(
            "\n== Fig 15 ({label}) — FLOPs vs context length (H=8 d=128 L=128 N={n_max}) =="
        );
        println!(
            "{:>8} {:>14} {:>14} {:>14} | {:>10} {:>10}",
            "T", "attn", "ovq", "gdn", "ovq/attn", "gdn/attn"
        );
        let mut csv = CsvWriter::create(
            format!("{out_dir}/flops_{label}.csv"),
            &["T", "attn", "ovq", "gdn", "ovq_ratio", "gdn_ratio"],
        )?;
        for r in &rows {
            let ro = r.ovq / r.attn;
            let rg = r.gdn / r.attn;
            println!(
                "{:>8} {:>14.3e} {:>14.3e} {:>14.3e} | {:>10.4} {:>10.4}",
                r.t, r.attn, r.ovq, r.gdn, ro, rg
            );
            csv.rowf(&[r.t as f64, r.attn, r.ovq, r.gdn, ro, rg])?;
        }
        csv.flush()?;
    }
    // whole-stack accounting: uniform full-attention stack vs a hybrid
    // ovq/sliding-window schedule at the same dense geometry — the
    // model-level trade-off the serving stack (ovqcore::stack) realizes
    let layers = 8usize;
    let d_model = g.h * g.d;
    let d_ff = 4.0 * d_model;
    let uniform: Vec<MixerKind> = vec![MixerKind::FullAttention; layers];
    let hybrid: Vec<MixerKind> = (0..layers)
        .map(|l| {
            if l % 2 == 0 {
                MixerKind::Ovq { n_max }
            } else {
                MixerKind::SlidingWindow { window: 1024 }
            }
        })
        .collect();
    println!(
        "\n== whole-stack inference FLOPs ({layers} layers, d_model={d_model} \
         d_ff={d_ff}, hybrid = ovq:{n_max}/kv:win1024) =="
    );
    println!("{:>8} {:>14} {:>14} {:>14}", "T", "attn_stack", "hybrid_stack", "ratio");
    let mut csv = CsvWriter::create(
        format!("{out_dir}/flops_stack.csv"),
        &["T", "attn_stack", "hybrid_stack", "ratio"],
    )?;
    for &t in &lengths {
        let a = stack_flops(&uniform, g, d_model, d_ff, t as f64);
        let h = stack_flops(&hybrid, g, d_model, d_ff, t as f64);
        println!("{:>8} {:>14.3e} {:>14.3e} {:>14.4}", t, a, h, h / a);
        csv.rowf(&[t as f64, a, h, h / a])?;
    }
    csv.flush()?;
    println!("\n(Fig 16 = the ratio columns; csv written to {out_dir}/)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const G: Geom = Geom { b: 1.0, h: 8.0, d: 128.0, l: 128.0 };

    #[test]
    fn attention_is_quadratic() {
        let a = attn_flops(G, 1024.0, false);
        let b = attn_flops(G, 2048.0, false);
        assert!((b / a - 4.0).abs() < 1e-9);
    }

    #[test]
    fn ovq_is_asymptotically_linear() {
        // once the dictionary saturates, doubling T doubles FLOPs
        let n = 2048;
        let a = ovq_flops(G, (1u32 << 16) as f64, n, false);
        let b = ovq_flops(G, (1u32 << 17) as f64, n, false);
        let ratio = b / a;
        assert!((ratio - 2.0).abs() < 0.1, "ratio {ratio}");
    }

    #[test]
    fn crossover_exists() {
        // the paper's headline: OVQ beats attention beyond some length
        let n = 8192;
        let short = 1usize << 10;
        let long = 1usize << 17;
        assert!(ovq_flops(G, short as f64, n, false) > attn_flops(G, short as f64, false) * 0.5);
        assert!(ovq_flops(G, long as f64, n, false) < attn_flops(G, long as f64, false));
    }

    #[test]
    fn train_is_3x_inference_for_attention() {
        let t = 4096.0;
        assert!((attn_flops(G, t, true) / attn_flops(G, t, false) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn ovq_train_ratio_matches_table8() {
        // per chunk: train/infer = (12N + 6L)/(6N + 2L); at saturation with
        // N >> L this tends to 2
        let n = 1 << 14;
        let t = 1 << 18;
        let r = ovq_flops(G, t as f64, n, true) / ovq_flops(G, t as f64, n, false);
        assert!(r > 1.9 && r < 3.01, "ratio {r}");
    }

    #[test]
    fn gdn_is_linear() {
        let a = gdn_flops(G, 1024.0, false);
        let b = gdn_flops(G, 2048.0, false);
        assert!((b / a - 2.0).abs() < 1e-9);
    }

    #[test]
    fn stack_dense_cost_is_linear_in_depth_and_length() {
        let kinds4 = vec![MixerKind::Gdn; 4];
        let kinds8 = vec![MixerKind::Gdn; 8];
        let (dm, dff) = (1024.0, 4096.0);
        let a4 = stack_flops(&kinds4, G, dm, dff, 4096.0);
        let a8 = stack_flops(&kinds8, G, dm, dff, 4096.0);
        assert!((a8 / a4 - 2.0).abs() < 1e-9, "depth doubling must double cost");
        let long = stack_flops(&kinds4, G, dm, dff, 8192.0);
        assert!((long / a4 - 2.0).abs() < 1e-9, "gdn stacks are linear in T");
    }

    #[test]
    fn sliding_window_layer_is_cheaper_than_full_attention() {
        let t = 1 << 16;
        let full = mixer_flops(MixerKind::FullAttention, G, t as f64);
        let sw = mixer_flops(MixerKind::SlidingWindow { window: 1024 }, G, t as f64);
        assert!(sw < full / 10.0, "sw {sw} vs full {full}");
        // below the window they coincide in order of magnitude
        let short = mixer_flops(MixerKind::SlidingWindow { window: 1 << 20 }, G, 512.0);
        let full_short = mixer_flops(MixerKind::FullAttention, G, 512.0);
        assert!(short < full_short * 2.0 && short > full_short / 2.0);
    }

    #[test]
    fn hybrid_stack_beats_attention_stack_at_long_context() {
        // the whole-model version of the paper's crossover: at 128k a
        // hybrid ovq/sw schedule costs a fraction of uniform attention,
        // while at short context the dense projections/MLP dominate and
        // the two stacks are comparable
        let layers = 8usize;
        let (dm, dff) = (G.h * G.d, 4.0 * G.h * G.d);
        let uniform = vec![MixerKind::FullAttention; layers];
        let hybrid: Vec<MixerKind> = (0..layers)
            .map(|l| {
                if l % 2 == 0 {
                    MixerKind::Ovq { n_max: 8192 }
                } else {
                    MixerKind::SlidingWindow { window: 1024 }
                }
            })
            .collect();
        let t_long = (1u32 << 17) as f64;
        let a = stack_flops(&uniform, G, dm, dff, t_long);
        let h = stack_flops(&hybrid, G, dm, dff, t_long);
        assert!(h < a / 2.0, "hybrid {h} vs attn {a} at 128k");
        let t_short = 512.0;
        let a = stack_flops(&uniform, G, dm, dff, t_short);
        let h = stack_flops(&hybrid, G, dm, dff, t_short);
        assert!(h / a > 0.3 && h / a < 3.0, "short-context ratio {}", h / a);
    }

    #[test]
    fn vq_layer_is_linear_in_t_at_constant_n() {
        let a = mixer_flops(MixerKind::Vq { n: 512 }, G, 4096.0);
        let b = mixer_flops(MixerKind::Vq { n: 512 }, G, 8192.0);
        assert!((b / a - 2.0).abs() < 1e-9);
    }
}
