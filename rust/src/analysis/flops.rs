//! Appendix D FLOPs model — exact implementation of Tables 7-8 and
//! eqs. 55-58, regenerating Figs. 15 (FLOPs vs context length) and 16
//! (FLOPs ratio vs standard attention).

use crate::util::cli::Args;
use crate::util::csv::CsvWriter;

use crate::ovqcore::growth_n_t;

/// Shared workload geometry (paper Table 6 notation).
#[derive(Debug, Clone, Copy)]
pub struct Geom {
    pub b: f64,     // batch
    pub h: f64,     // heads
    pub d: f64,     // head dim
    pub l: f64,     // chunk size
}

impl Default for Geom {
    fn default() -> Self {
        // paper's setup: H=8, d=128, L=128 (App. D plots)
        Geom { b: 1.0, h: 8.0, d: 128.0, l: 128.0 }
    }
}

/// Causal self-attention FLOPs (paper Table 7).
pub fn attn_flops(g: Geom, t: f64, train: bool) -> f64 {
    // inference total: 2 B H T^2 d / 2 = B H T^2 d   (QK^T causal) plus AV
    // (B H T^2 d); the paper's table folds to: infer = 2BHT^2d/2, train 3x.
    let infer = 2.0 * g.b * g.h * t * t * g.d / 2.0  // S = QK^T (causal)
        + g.b * g.h * t * t * g.d; // AV
    if train {
        3.0 * infer
    } else {
        infer
    }
}

/// OVQ-attention FLOPs (paper Table 8 / eqs. 55-56): sum over chunks of
/// BHLd(6N_c + 2L) at inference, BHLd(12N_c + 6L) in training.
pub fn ovq_flops(g: Geom, t: f64, n_max: usize, train: bool) -> f64 {
    let l = g.l as usize;
    let chunks = (t as usize).div_ceil(l);
    let mut total = 0.0;
    for c in 0..chunks {
        let n_c = growth_n_t(c * l, n_max) as f64;
        let per = if train {
            g.b * g.h * g.l * g.d * (12.0 * n_c + 6.0 * g.l)
        } else {
            g.b * g.h * g.l * g.d * (6.0 * n_c + 2.0 * g.l)
        };
        total += per;
    }
    total
}

/// Gated delta net FLOPs (paper eqs. 57-58, following Lufkin et al. /
/// Yang et al. accounting).
pub fn gdn_flops(g: Geom, t: f64, train: bool) -> f64 {
    let infer = 6.0 * g.b * t * g.h * g.d * g.d
        + g.b * t * g.h * (6.0 * g.d * g.d + 2.0 * g.l * 5.0 * g.d + g.l * g.l / 3.0);
    if train {
        18.0 * g.b * t * g.h * g.d * g.d
            + 3.0 * g.b
                * t
                * g.h
                * (6.0 * g.d * g.d + 2.0 * g.l * 5.0 * g.d + g.l * g.l / 3.0)
    } else {
        infer
    }
}

/// One row of the Fig. 15/16 sweep.
#[derive(Debug, Clone)]
pub struct FlopsRow {
    pub t: usize,
    pub attn: f64,
    pub ovq: f64,
    pub gdn: f64,
}

pub fn sweep(g: Geom, n_max: usize, lengths: &[usize], train: bool) -> Vec<FlopsRow> {
    lengths
        .iter()
        .map(|&t| FlopsRow {
            t,
            attn: attn_flops(g, t as f64, train),
            ovq: ovq_flops(g, t as f64, n_max, train),
            gdn: gdn_flops(g, t as f64, train),
        })
        .collect()
}

/// `ovq flops` CLI: prints Fig. 15 (absolute) and Fig. 16 (ratio) series
/// and writes CSVs under --out (default results/).
pub fn cmd_flops(args: &Args) -> anyhow::Result<()> {
    let out_dir = args.opt_or("out", "results");
    let n_max = args.opt_usize("n-dict", 8192);
    let g = Geom::default();
    let lengths: Vec<usize> =
        (10..=17).map(|p| 1usize << p).collect(); // 1k .. 128k

    for (label, train) in [("inference", false), ("training", true)] {
        let rows = sweep(g, n_max, &lengths, train);
        println!(
            "\n== Fig 15 ({label}) — FLOPs vs context length (H=8 d=128 L=128 N={n_max}) =="
        );
        println!(
            "{:>8} {:>14} {:>14} {:>14} | {:>10} {:>10}",
            "T", "attn", "ovq", "gdn", "ovq/attn", "gdn/attn"
        );
        let mut csv = CsvWriter::create(
            format!("{out_dir}/flops_{label}.csv"),
            &["T", "attn", "ovq", "gdn", "ovq_ratio", "gdn_ratio"],
        )?;
        for r in &rows {
            let ro = r.ovq / r.attn;
            let rg = r.gdn / r.attn;
            println!(
                "{:>8} {:>14.3e} {:>14.3e} {:>14.3e} | {:>10.4} {:>10.4}",
                r.t, r.attn, r.ovq, r.gdn, ro, rg
            );
            csv.rowf(&[r.t as f64, r.attn, r.ovq, r.gdn, ro, rg])?;
        }
        csv.flush()?;
    }
    println!("\n(Fig 16 = the ratio columns; csv written to {out_dir}/)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const G: Geom = Geom { b: 1.0, h: 8.0, d: 128.0, l: 128.0 };

    #[test]
    fn attention_is_quadratic() {
        let a = attn_flops(G, 1024.0, false);
        let b = attn_flops(G, 2048.0, false);
        assert!((b / a - 4.0).abs() < 1e-9);
    }

    #[test]
    fn ovq_is_asymptotically_linear() {
        // once the dictionary saturates, doubling T doubles FLOPs
        let n = 2048;
        let a = ovq_flops(G, (1u32 << 16) as f64, n, false);
        let b = ovq_flops(G, (1u32 << 17) as f64, n, false);
        let ratio = b / a;
        assert!((ratio - 2.0).abs() < 0.1, "ratio {ratio}");
    }

    #[test]
    fn crossover_exists() {
        // the paper's headline: OVQ beats attention beyond some length
        let n = 8192;
        let short = 1usize << 10;
        let long = 1usize << 17;
        assert!(ovq_flops(G, short as f64, n, false) > attn_flops(G, short as f64, false) * 0.5);
        assert!(ovq_flops(G, long as f64, n, false) < attn_flops(G, long as f64, false));
    }

    #[test]
    fn train_is_3x_inference_for_attention() {
        let t = 4096.0;
        assert!((attn_flops(G, t, true) / attn_flops(G, t, false) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn ovq_train_ratio_matches_table8() {
        // per chunk: train/infer = (12N + 6L)/(6N + 2L); at saturation with
        // N >> L this tends to 2
        let n = 1 << 14;
        let t = 1 << 18;
        let r = ovq_flops(G, t as f64, n, true) / ovq_flops(G, t as f64, n, false);
        assert!(r > 1.9 && r < 3.01, "ratio {r}");
    }

    #[test]
    fn gdn_is_linear() {
        let a = gdn_flops(G, 1024.0, false);
        let b = gdn_flops(G, 2048.0, false);
        assert!((b / a - 2.0).abs() < 1e-9);
    }
}
