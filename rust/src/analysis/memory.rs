//! Memory-growth model — Fig. 4 (right): how the per-layer memory state
//! (kv-cache / dictionary / fast-weight matrix) grows with context length
//! for each mixer family, using the exact byte accounting in
//! ovqcore::memstate.

use crate::ovqcore::memstate::{MixerGeom, MixerKind};
use crate::ovqcore::quant::QuantMode;
use crate::util::csv::CsvWriter;

#[derive(Debug, Clone)]
pub struct MemRow {
    pub t: usize,
    pub bytes: Vec<(String, usize)>,
}

pub fn sweep(g: MixerGeom, kinds: &[(&str, MixerKind)], lengths: &[usize]) -> Vec<MemRow> {
    lengths
        .iter()
        .map(|&t| MemRow {
            t,
            bytes: kinds
                .iter()
                .map(|(n, k)| (n.to_string(), k.state_bytes(g, t)))
                .collect(),
        })
        .collect()
}

/// The Fig. 4-right reproduction: full attention vs sw vs OVQ at several N.
pub fn fig4_right(out_dir: &str) -> anyhow::Result<()> {
    let g = MixerGeom { heads: 8, d_head: 128 };
    let kinds: Vec<(&str, MixerKind)> = vec![
        ("full_attn", MixerKind::FullAttention),
        ("sw128", MixerKind::SlidingWindow { window: 128 }),
        ("ovq_N2k", MixerKind::Ovq { n_max: 2048 }),
        ("ovq_N8k", MixerKind::Ovq { n_max: 8192 }),
        ("ovq_N16k", MixerKind::Ovq { n_max: 16384 }),
        ("gdn", MixerKind::Gdn),
    ];
    let lengths: Vec<usize> = (9..=16).map(|p| 1usize << p).collect();
    let rows = sweep(g, &kinds, &lengths);

    let mut header: Vec<&str> = vec!["T"];
    header.extend(kinds.iter().map(|(n, _)| *n));
    let mut csv = CsvWriter::create(format!("{out_dir}/fig4_right_memory.csv"), &header)?;
    println!("\n== Fig 4 (right) — memory state bytes vs context length ==");
    print!("{:>8}", "T");
    for (n, _) in &kinds {
        print!(" {n:>12}");
    }
    println!();
    for r in &rows {
        print!("{:>8}", r.t);
        let mut fields = vec![r.t as f64];
        for (_, b) in &r.bytes {
            print!(" {:>12}", human(*b));
            fields.push(*b as f64);
        }
        println!();
        csv.rowf(&fields)?;
    }
    csv.flush()?;

    // the paper's compression headline: OVQ at 64k ~ 10-25% of full attn
    let t = 65536;
    let full = MixerKind::FullAttention.state_bytes(g, t);
    let ovq = MixerKind::Ovq { n_max: 16384 }.state_bytes(g, t);
    println!(
        "\nat T=64k: ovq_N16k/full = {:.1}% (paper: state 10-25% of self-attention)",
        100.0 * ovq as f64 / full as f64
    );
    Ok(())
}

/// Whole-stack mixer-state bytes at context length `t`: the sum of each
/// layer's kind accounting (every layer of a [`crate::ovqcore::stack::
/// LayerStack`] sees every token). Cross-checked against the live
/// stack's `state_bytes()` below — the serving path and this analytic
/// model cannot drift apart.
pub fn stack_state_bytes(kinds: &[MixerKind], g: MixerGeom, t: usize) -> usize {
    stack_state_bytes_quant(kinds, g, t, QuantMode::None)
}

/// [`stack_state_bytes`] with the dictionary tensors held in `quant`
/// storage — the analytic twin of a stack built with
/// [`crate::ovqcore::stack::StackConfig::with_quant`]. Hot per-token
/// state (kv rings, fast-weight matrices, counts, pending buffers)
/// stays f32 in every mode, exactly as the live mixers keep it.
pub fn stack_state_bytes_quant(
    kinds: &[MixerKind],
    g: MixerGeom,
    t: usize,
    quant: QuantMode,
) -> usize {
    kinds.iter().map(|k| k.state_bytes_quant(g, t, quant)).sum()
}

/// Dense-weight bytes of a full stack (per layer: q/k/v projections
/// `[H*d, d_model]`, output projection `[d_model, H*d]`, two RMSNorm
/// gains, gated MLP `2 x [d_ff, d_model]` + `[d_model, d_ff]`; f32).
/// This is shared model cost — deterministic in the init seed, rebuilt
/// on snapshot restore — kept separate from the per-session
/// [`stack_state_bytes`] the eviction contract bills for.
pub fn stack_param_bytes(layers: usize, d_model: usize, d_ff: usize, g: MixerGeom) -> usize {
    stack_param_bytes_quant(layers, d_model, d_ff, g, QuantMode::None)
}

/// [`stack_param_bytes`] with the weight matrices held in `quant`
/// storage: each matrix costs `rows * QuantMode::row_bytes(cols)`
/// (per-row i8 scales included); the RMSNorm gains stay f32.
pub fn stack_param_bytes_quant(
    layers: usize,
    d_model: usize,
    d_ff: usize,
    g: MixerGeom,
    quant: QuantMode,
) -> usize {
    let hd = g.heads * g.d_head;
    let per_layer = 3 * hd * quant.row_bytes(d_model) // q/k/v projections
        + d_model * quant.row_bytes(hd) // output projection
        + 2 * d_model * 4 // norm gains (always f32)
        + 2 * d_ff * quant.row_bytes(d_model) // MLP gate + up
        + d_model * quant.row_bytes(d_ff); // MLP down
    layers * per_layer
}

pub fn human(b: usize) -> String {
    if b < 1 << 10 {
        format!("{b} B")
    } else if b < 1 << 20 {
        format!("{:.1} KiB", b as f64 / 1024.0)
    } else if b < 1 << 30 {
        format!("{:.1} MiB", b as f64 / (1 << 20) as f64)
    } else {
        format!("{:.2} GiB", b as f64 / (1 << 30) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ovq_compresses_at_64k() {
        let g = MixerGeom { heads: 8, d_head: 128 };
        let full = MixerKind::FullAttention.state_bytes(g, 65536);
        let ovq = MixerKind::Ovq { n_max: 16384 }.state_bytes(g, 65536);
        let frac = ovq as f64 / full as f64;
        assert!(frac > 0.05 && frac < 0.30, "fraction {frac} out of the paper's band");
    }

    #[test]
    fn human_formatting() {
        assert_eq!(human(512), "512 B");
        assert_eq!(human(2048), "2.0 KiB");
    }

    #[test]
    fn stack_accounting_matches_live_layer_stack_exactly() {
        // the whole-model analogue of memstate's accounting_matches_live:
        // a hybrid 4-layer stack's live state_bytes() and param_bytes()
        // must equal the analytic counts bit-for-bit after t tokens
        use crate::ovqcore::mixer::{Scratch, SeqMixer};
        use crate::ovqcore::stack::{LayerStack, StackConfig};
        use crate::util::rng::Rng;
        let g = MixerGeom { heads: 2, d_head: 4 };
        let (d_model, d_ff, chunk, t) = (8usize, 16usize, 8usize, 64usize);
        let kinds = vec![
            MixerKind::Ovq { n_max: 16 },
            MixerKind::SlidingWindow { window: 24 },
            MixerKind::Ovq { n_max: 16 },
            MixerKind::FullAttention,
        ];
        for quant in [QuantMode::None, QuantMode::F16, QuantMode::I8] {
            let cfg = StackConfig::hybrid(d_model, d_ff, g.heads, g.d_head, chunk, kinds.clone())
                .with_quant(quant);
            let mut st = LayerStack::new(cfg, 99);
            let mut rng = Rng::new(21);
            let x: Vec<f32> = (0..t * d_model).map(|_| rng.normal() as f32).collect();
            let mut out = vec![0.0f32; t * d_model];
            let mut scratch = Scratch::new();
            st.process_chunk(&x, &x, &x, &mut out, &mut scratch);
            st.flush(); // merge OVQ pending tails so growth is at N_t(t)
            assert_eq!(
                st.state_bytes(),
                stack_state_bytes_quant(&kinds, g, t, quant),
                "{quant:?}: live stack state diverged from the analytic accounting"
            );
            assert_eq!(
                st.param_bytes(),
                stack_param_bytes_quant(4, d_model, d_ff, g, quant),
                "{quant:?}: live stack weights diverged from the analytic parameter count"
            );
        }
        // the f32 paths still go through the plain entry points
        assert_eq!(
            stack_state_bytes(&kinds, g, t),
            stack_state_bytes_quant(&kinds, g, t, QuantMode::None)
        );
        assert_eq!(
            stack_param_bytes(4, d_model, d_ff, g),
            stack_param_bytes_quant(4, d_model, d_ff, g, QuantMode::None)
        );
        // and the analytic split is per-layer additive
        let per_layer: usize = kinds.iter().map(|k| k.state_bytes(g, t)).sum();
        assert_eq!(per_layer, stack_state_bytes(&kinds, g, t));
    }
}
