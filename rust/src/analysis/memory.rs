//! Memory-growth model — Fig. 4 (right): how the per-layer memory state
//! (kv-cache / dictionary / fast-weight matrix) grows with context length
//! for each mixer family, using the exact byte accounting in
//! ovqcore::memstate.

use crate::ovqcore::memstate::{MixerGeom, MixerKind};
use crate::util::csv::CsvWriter;

#[derive(Debug, Clone)]
pub struct MemRow {
    pub t: usize,
    pub bytes: Vec<(String, usize)>,
}

pub fn sweep(g: MixerGeom, kinds: &[(&str, MixerKind)], lengths: &[usize]) -> Vec<MemRow> {
    lengths
        .iter()
        .map(|&t| MemRow {
            t,
            bytes: kinds
                .iter()
                .map(|(n, k)| (n.to_string(), k.state_bytes(g, t)))
                .collect(),
        })
        .collect()
}

/// The Fig. 4-right reproduction: full attention vs sw vs OVQ at several N.
pub fn fig4_right(out_dir: &str) -> anyhow::Result<()> {
    let g = MixerGeom { heads: 8, d_head: 128 };
    let kinds: Vec<(&str, MixerKind)> = vec![
        ("full_attn", MixerKind::FullAttention),
        ("sw128", MixerKind::SlidingWindow { window: 128 }),
        ("ovq_N2k", MixerKind::Ovq { n_max: 2048 }),
        ("ovq_N8k", MixerKind::Ovq { n_max: 8192 }),
        ("ovq_N16k", MixerKind::Ovq { n_max: 16384 }),
        ("gdn", MixerKind::Gdn),
    ];
    let lengths: Vec<usize> = (9..=16).map(|p| 1usize << p).collect();
    let rows = sweep(g, &kinds, &lengths);

    let mut header: Vec<&str> = vec!["T"];
    header.extend(kinds.iter().map(|(n, _)| *n));
    let mut csv = CsvWriter::create(format!("{out_dir}/fig4_right_memory.csv"), &header)?;
    println!("\n== Fig 4 (right) — memory state bytes vs context length ==");
    print!("{:>8}", "T");
    for (n, _) in &kinds {
        print!(" {n:>12}");
    }
    println!();
    for r in &rows {
        print!("{:>8}", r.t);
        let mut fields = vec![r.t as f64];
        for (_, b) in &r.bytes {
            print!(" {:>12}", human(*b));
            fields.push(*b as f64);
        }
        println!();
        csv.rowf(&fields)?;
    }
    csv.flush()?;

    // the paper's compression headline: OVQ at 64k ~ 10-25% of full attn
    let t = 65536;
    let full = MixerKind::FullAttention.state_bytes(g, t);
    let ovq = MixerKind::Ovq { n_max: 16384 }.state_bytes(g, t);
    println!(
        "\nat T=64k: ovq_N16k/full = {:.1}% (paper: state 10-25% of self-attention)",
        100.0 * ovq as f64 / full as f64
    );
    Ok(())
}

pub fn human(b: usize) -> String {
    if b < 1 << 10 {
        format!("{b} B")
    } else if b < 1 << 20 {
        format!("{:.1} KiB", b as f64 / 1024.0)
    } else if b < 1 << 30 {
        format!("{:.1} MiB", b as f64 / (1 << 20) as f64)
    } else {
        format!("{:.2} GiB", b as f64 / (1 << 30) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ovq_compresses_at_64k() {
        let g = MixerGeom { heads: 8, d_head: 128 };
        let full = MixerKind::FullAttention.state_bytes(g, 65536);
        let ovq = MixerKind::Ovq { n_max: 16384 }.state_bytes(g, 65536);
        let frac = ovq as f64 / full as f64;
        assert!(frac > 0.05 && frac < 0.30, "fraction {frac} out of the paper's band");
    }

    #[test]
    fn human_formatting() {
        assert_eq!(human(512), "512 B");
        assert_eq!(human(2048), "2.0 KiB");
    }
}
