//! Analytical models from the paper's Appendix D (FLOPs) and Fig. 4-right
//! (memory growth) — exact reimplementations of the published formulas.

pub mod flops;
pub mod memory;
