//! The sharded, multi-threaded decode engine — the serving layer the
//! paper's "large fixed state, many concurrent streams" regime needs.
//!
//! Architecture:
//!
//! ```text
//!   submit(session, chunk)
//!        │  session id ──hash──▶ shard
//!        ▼
//!   bounded sync_channel (depth = queue_depth, backpressure by blocking)
//!        ▼
//!   worker thread s ∈ 0..threads, each owning one ShardBank:
//!     admission (factory) · LRU eviction → snapshot blobs · restore
//!        ▼
//!   unbounded output channel (optional) + per-shard telemetry
//! ```
//!
//! Determinism contract: a session's outputs are **bit-identical across
//! thread counts**. Sessions are pinned to shards by id hash, each
//! shard's channel preserves per-session chunk order, the mixer factory
//! seeds on (session, head) only, and eviction/restore round-trips are
//! bit-exact ([`crate::ovqcore::snapshot`]) — so rescheduling across 1,
//! 2 or 4 workers cannot change any stream's tokens. The engine golden
//! test (rust/tests/engine.rs) cross-checks this.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::ovqcore::bank::{ring_push, DecodeChunk, ShardBank, StreamStats};
use crate::ovqcore::memstate::MixerKind;
use crate::ovqcore::mixer::SeqMixer;
use crate::util::stats;

/// Engine shape and policy. `threads` is the shard count (one worker
/// thread per shard); `max_resident` and `queue_depth` are per shard.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub kind: MixerKind,
    pub heads: usize,
    pub d_head: usize,
    /// mixer chunk length (OVQ merge granularity), not the arrival size
    pub chunk: usize,
    pub threads: usize,
    /// admission cap: resident sessions per shard before LRU eviction
    pub max_resident: usize,
    /// bounded per-shard queue: `submit` blocks when full (backpressure)
    pub queue_depth: usize,
    pub seed: u64,
    /// keep per-chunk outputs for the caller (golden cross-checks); off
    /// for load runs so output buffers don't grow unboundedly
    pub collect_outputs: bool,
}

impl EngineConfig {
    pub fn new(kind: MixerKind, heads: usize, d_head: usize, chunk: usize) -> EngineConfig {
        EngineConfig {
            kind,
            heads,
            d_head,
            chunk,
            threads: 1,
            max_resident: usize::MAX / 2,
            queue_depth: 64,
            seed: 0xE6617E,
            collect_outputs: false,
        }
    }
}

/// Deterministic per-(session, head) mixer seed — must not depend on the
/// shard or thread count (see the determinism contract above).
pub fn session_seed(seed: u64, session: u64, head: usize) -> u64 {
    let mut z = seed
        ^ session.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (head as u64 + 1).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Which shard serves a session — a splitmix-style hash of the id, so
/// consecutive ids spread instead of striping.
pub fn shard_of(session: u64, threads: usize) -> usize {
    let mut z = session.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    ((z ^ (z >> 31)) % threads as u64) as usize
}

enum EngineMsg {
    Chunk { session: u64, chunk: DecodeChunk, submitted: Instant },
    Evict { session: u64 },
    FlushAll,
}

/// One completed chunk, tagged with the session's chunk sequence number
/// (1-based, eviction-transparent) so outputs can be ordered per session
/// regardless of cross-shard completion order.
pub struct EngineOut {
    pub session: u64,
    pub seq: usize,
    pub out: Vec<f32>,
}

/// Telemetry of one shard over the engine's lifetime.
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub shard: usize,
    /// distinct sessions this shard ever served
    pub sessions: usize,
    /// sessions still resident (live mixers) at shutdown
    pub resident_sessions: usize,
    /// sessions frozen to snapshot blobs at shutdown
    pub evicted_sessions: usize,
    pub chunks: usize,
    pub tokens: usize,
    /// time spent inside chunk processing (utilization = busy / wall)
    pub busy: Duration,
    pub evictions: usize,
    pub restores: usize,
    /// high-water mark of queued + in-service (+ one blocked submitter)
    pub max_queue: usize,
    /// chunks dropped because the session failed to admit/restore (e.g. a
    /// corrupt snapshot blob) — the session is discarded, the shard lives
    pub failed_chunks: usize,
    /// live mixer bytes of resident sessions at shutdown
    pub resident_bytes: usize,
    /// snapshot blob bytes of evicted sessions at shutdown
    pub snapshot_bytes: usize,
    /// submit→completion wall latency of the most recent
    /// [`crate::ovqcore::bank::LATENCY_WINDOW`] chunks, nanoseconds (ring)
    pub latency_ns: Vec<f64>,
}

/// Aggregate result of an engine run.
pub struct EngineReport {
    pub threads: usize,
    pub wall: Duration,
    pub tokens: usize,
    pub chunks: usize,
    pub shards: Vec<ShardReport>,
    /// per-session telemetry, sorted by session id
    pub sessions: Vec<(u64, StreamStats)>,
    /// per-chunk outputs (only when `collect_outputs` was set)
    pub outputs: Vec<EngineOut>,
}

impl EngineReport {
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.wall.as_secs_f64()
    }

    pub fn evictions(&self) -> usize {
        self.shards.iter().map(|s| s.evictions).sum()
    }

    pub fn restores(&self) -> usize {
        self.shards.iter().map(|s| s.restores).sum()
    }

    /// Chunks dropped on failed session admit/restore across all shards.
    pub fn failed_chunks(&self) -> usize {
        self.shards.iter().map(|s| s.failed_chunks).sum()
    }

    /// Total state at shutdown: live mixers + evicted snapshot blobs.
    pub fn state_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.resident_bytes + s.snapshot_bytes).sum()
    }

    /// Cross-shard submit→completion latency percentile, microseconds.
    pub fn latency_us(&self, p: f64) -> f64 {
        let all: Vec<f64> =
            self.shards.iter().flat_map(|s| s.latency_ns.iter().copied()).collect();
        stats::percentile(&all, p) / 1e3
    }

    /// Per-shard busy fraction of the run's wall clock.
    pub fn utilization(&self) -> Vec<f64> {
        let w = self.wall.as_secs_f64().max(1e-12);
        self.shards.iter().map(|s| s.busy.as_secs_f64() / w).collect()
    }

    pub fn print(&self) {
        println!(
            "engine: {} threads, {} sessions, {} chunks -> {:.0} tok/s aggregate \
             ({} tokens in {:.2}s)",
            self.threads,
            self.sessions.len(),
            self.chunks,
            self.tokens_per_sec(),
            self.tokens,
            self.wall.as_secs_f64(),
        );
        println!(
            "  latency p50 {:.1} us  p99 {:.1} us  |  {} evictions, {} restores, \
             state {:.1} KiB",
            self.latency_us(50.0),
            self.latency_us(99.0),
            self.evictions(),
            self.restores(),
            self.state_bytes() as f64 / 1024.0,
        );
        if self.failed_chunks() > 0 {
            println!("  WARNING: {} chunks dropped on failed restores", self.failed_chunks());
        }
        for (s, u) in self.shards.iter().zip(self.utilization()) {
            println!(
                "  shard {:>2}: {:>4} sessions {:>7} tokens  util {:>5.1}%  \
                 max queue {:>3}  evict/restore {}/{}  resident {:.1} KiB + \
                 snapshots {:.1} KiB",
                s.shard,
                s.sessions,
                s.tokens,
                100.0 * u,
                s.max_queue,
                s.evictions,
                s.restores,
                s.resident_bytes as f64 / 1024.0,
                s.snapshot_bytes as f64 / 1024.0,
            );
        }
    }
}

/// The running engine. Dropping it without [`DecodeEngine::finish`]
/// detaches the workers (they exit once their queues drain).
pub struct DecodeEngine {
    cfg: EngineConfig,
    txs: Vec<SyncSender<EngineMsg>>,
    handles: Vec<thread::JoinHandle<(ShardReport, Vec<(u64, StreamStats)>)>>,
    out_rx: Receiver<EngineOut>,
    /// per-shard (gauge, high-water) of queued + in-service chunks
    queue_gauge: Vec<Arc<AtomicUsize>>,
    queue_high: Vec<Arc<AtomicUsize>>,
    t0: Instant,
}

impl DecodeEngine {
    /// Start with the standard [`MixerKind`] factory.
    pub fn start(cfg: EngineConfig) -> DecodeEngine {
        let (kind, d_head, chunk, seed) = (cfg.kind, cfg.d_head, cfg.chunk, cfg.seed);
        Self::start_with(cfg, move |session, head| {
            kind.build(d_head, chunk, session_seed(seed, session, head))
        })
    }

    /// Start with a custom per-(session, head) mixer factory. The factory
    /// must be deterministic in its arguments (see module docs); one clone
    /// runs on every worker thread.
    pub fn start_with(
        cfg: EngineConfig,
        factory: impl Fn(u64, usize) -> Box<dyn SeqMixer> + Send + Clone + 'static,
    ) -> DecodeEngine {
        assert!(cfg.threads > 0 && cfg.heads > 0 && cfg.queue_depth > 0);
        let (out_tx, out_rx) = mpsc::channel::<EngineOut>();
        let mut txs = Vec::with_capacity(cfg.threads);
        let mut handles = Vec::with_capacity(cfg.threads);
        let mut queue_gauge = Vec::with_capacity(cfg.threads);
        let mut queue_high = Vec::with_capacity(cfg.threads);
        for shard in 0..cfg.threads {
            let (tx, rx) = mpsc::sync_channel::<EngineMsg>(cfg.queue_depth);
            let gauge = Arc::new(AtomicUsize::new(0));
            let high = Arc::new(AtomicUsize::new(0));
            let worker_out = cfg.collect_outputs.then(|| out_tx.clone());
            let worker_gauge = Arc::clone(&gauge);
            let worker_high = Arc::clone(&high);
            let factory = factory.clone();
            let (heads, max_resident, hd) =
                (cfg.heads, cfg.max_resident, cfg.heads * cfg.d_head);
            handles.push(thread::spawn(move || {
                shard_worker(shard, heads, max_resident, hd, factory, rx, worker_out, worker_gauge, worker_high)
            }));
            txs.push(tx);
            queue_gauge.push(gauge);
            queue_high.push(high);
        }
        drop(out_tx); // workers hold the only senders
        DecodeEngine { cfg, txs, handles, out_rx, queue_gauge, queue_high, t0: Instant::now() }
    }

    pub fn threads(&self) -> usize {
        self.cfg.threads
    }

    pub fn heads(&self) -> usize {
        self.cfg.heads
    }

    pub fn d_head(&self) -> usize {
        self.cfg.d_head
    }

    /// Enqueue one packed `[len, heads, d]` chunk for a session. Blocks
    /// while the session's shard queue is full — open-loop producers feel
    /// backpressure here instead of growing an unbounded buffer.
    pub fn submit(&self, session: u64, chunk: DecodeChunk) {
        let s = shard_of(session, self.cfg.threads);
        let submitted = Instant::now();
        let v = self.queue_gauge[s].fetch_add(1, Ordering::SeqCst) + 1;
        self.queue_high[s].fetch_max(v, Ordering::SeqCst);
        self.txs[s]
            .send(EngineMsg::Chunk { session, chunk, submitted })
            .expect("shard worker died");
    }

    /// Ask a session's shard to evict it to a snapshot blob (client
    /// abandon). Queued chunks for the session are processed first (the
    /// message travels the same ordered queue).
    pub fn evict(&self, session: u64) {
        let s = shard_of(session, self.cfg.threads);
        self.txs[s].send(EngineMsg::Evict { session }).expect("shard worker died");
    }

    /// Merge every resident session's buffered chunk tail (end-of-run).
    pub fn flush_all(&self) {
        for tx in &self.txs {
            tx.send(EngineMsg::FlushAll).expect("shard worker died");
        }
    }

    /// Non-blocking drain of completed outputs (empty unless
    /// `collect_outputs` is set). Call periodically during long
    /// collect-mode runs to keep memory bounded.
    pub fn try_outputs(&self) -> Vec<EngineOut> {
        self.out_rx.try_iter().collect()
    }

    /// Shut down: close the queues, join the workers, gather telemetry
    /// and any remaining outputs.
    pub fn finish(self) -> EngineReport {
        let DecodeEngine { cfg, txs, handles, out_rx, t0, .. } = self;
        drop(txs); // workers exit when their queues drain
        let mut shards = Vec::with_capacity(handles.len());
        let mut sessions: Vec<(u64, StreamStats)> = Vec::new();
        for h in handles {
            let (report, mut stats) = h.join().expect("shard worker panicked");
            shards.push(report);
            sessions.append(&mut stats);
        }
        let wall = t0.elapsed();
        // session ids are disjoint across shards (hash-pinned), so a plain
        // sort yields one global, deterministic ordering
        sessions.sort_by_key(|&(id, _)| id);
        let outputs: Vec<EngineOut> = out_rx.try_iter().collect();
        let tokens = shards.iter().map(|s| s.tokens).sum();
        let chunks = shards.iter().map(|s| s.chunks).sum();
        EngineReport { threads: cfg.threads, wall, tokens, chunks, shards, sessions, outputs }
    }
}

#[allow(clippy::too_many_arguments)]
fn shard_worker(
    shard: usize,
    heads: usize,
    max_resident: usize,
    hd: usize,
    factory: impl Fn(u64, usize) -> Box<dyn SeqMixer> + Send + 'static,
    rx: Receiver<EngineMsg>,
    out_tx: Option<Sender<EngineOut>>,
    gauge: Arc<AtomicUsize>,
    high: Arc<AtomicUsize>,
) -> (ShardReport, Vec<(u64, StreamStats)>) {
    let mut bank = ShardBank::new(heads, max_resident, factory);
    let mut busy = Duration::ZERO;
    let mut latency_ns: Vec<f64> = Vec::new();
    let mut latency_i = 0usize;
    let (mut chunks, mut tokens) = (0usize, 0usize);
    let mut failed_chunks = 0usize;
    while let Ok(msg) = rx.recv() {
        match msg {
            EngineMsg::Chunk { session, chunk, submitted } => {
                let t0 = Instant::now();
                let processed = bank.process(session, &chunk);
                busy += t0.elapsed();
                gauge.fetch_sub(1, Ordering::SeqCst);
                let (out, seq) = match processed {
                    Ok(r) => r,
                    Err(e) => {
                        // a bad blob must cost one session, not the shard:
                        // drop the chunk (the broken blob was consumed by
                        // the restore attempt, so a re-arrival starts the
                        // session fresh) and keep serving everyone else
                        failed_chunks += 1;
                        eprintln!(
                            "shard {shard}: dropping chunk for session {session}: {e}"
                        );
                        continue;
                    }
                };
                ring_push(&mut latency_ns, latency_i, submitted.elapsed().as_nanos() as f64);
                latency_i += 1;
                chunks += 1;
                tokens += chunk.keys.len() / hd;
                if let Some(tx) = &out_tx {
                    let _ = tx.send(EngineOut { session, seq, out });
                }
            }
            EngineMsg::Evict { session } => bank.evict(session),
            EngineMsg::FlushAll => bank.flush_all(),
        }
    }
    let report = ShardReport {
        shard,
        sessions: bank.sessions(),
        resident_sessions: bank.resident_sessions(),
        evicted_sessions: bank.evicted_sessions(),
        chunks,
        tokens,
        busy,
        evictions: bank.evictions,
        restores: bank.restores,
        max_queue: high.load(Ordering::SeqCst),
        failed_chunks,
        resident_bytes: bank.resident_bytes(),
        snapshot_bytes: bank.snapshot_bytes(),
        latency_ns,
    };
    (report, bank.take_stats())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn chunk_of(rng: &mut Rng, len: usize, hd: usize) -> DecodeChunk {
        DecodeChunk {
            queries: (0..len * hd).map(|_| rng.normal() as f32).collect(),
            keys: (0..len * hd).map(|_| rng.normal() as f32).collect(),
            values: (0..len * hd).map(|_| rng.normal() as f32).collect(),
        }
    }

    #[test]
    fn shard_hash_covers_and_is_stable() {
        let mut seen = vec![false; 4];
        for id in 0..256u64 {
            let s = shard_of(id, 4);
            assert!(s < 4);
            assert_eq!(s, shard_of(id, 4), "stable");
            seen[s] = true;
        }
        assert!(seen.iter().all(|&s| s), "all shards reachable");
        assert_eq!(shard_of(1234, 1), 0);
    }

    #[test]
    fn session_seed_depends_on_session_and_head_only() {
        assert_eq!(session_seed(1, 2, 3), session_seed(1, 2, 3));
        assert_ne!(session_seed(1, 2, 3), session_seed(1, 2, 4));
        assert_ne!(session_seed(1, 2, 3), session_seed(1, 3, 3));
        assert_ne!(session_seed(0, 2, 3), session_seed(1, 2, 3));
    }

    #[test]
    fn engine_counts_tokens_and_joins_cleanly() {
        let mut cfg = EngineConfig::new(MixerKind::Ovq { n_max: 32 }, 2, 8, 16);
        cfg.threads = 2;
        let engine = DecodeEngine::start(cfg);
        let hd = engine.heads() * engine.d_head();
        let mut rng = Rng::new(11);
        for session in 0..6u64 {
            for _ in 0..3 {
                engine.submit(session, chunk_of(&mut rng, 16, hd));
            }
        }
        engine.flush_all();
        let r = engine.finish();
        assert_eq!(r.tokens, 6 * 3 * 16);
        assert_eq!(r.chunks, 18);
        assert_eq!(r.sessions.len(), 6);
        for (_, st) in &r.sessions {
            assert_eq!(st.tokens, 48);
            assert_eq!(st.chunks, 3);
        }
        assert_eq!(r.shards.len(), 2);
        assert!(r.state_bytes() > 0);
        assert!(r.latency_us(99.0) >= r.latency_us(50.0) * 0.5);
    }

    #[test]
    fn outputs_are_collected_and_sequenced_when_asked() {
        let mut cfg = EngineConfig::new(MixerKind::Gdn, 1, 4, 8);
        cfg.threads = 2;
        cfg.collect_outputs = true;
        let engine = DecodeEngine::start(cfg);
        let mut rng = Rng::new(12);
        for session in [3u64, 5] {
            for _ in 0..4 {
                engine.submit(session, chunk_of(&mut rng, 8, 4));
            }
        }
        let r = engine.finish();
        assert_eq!(r.outputs.len(), 8);
        for session in [3u64, 5] {
            let mut seqs: Vec<usize> =
                r.outputs.iter().filter(|o| o.session == session).map(|o| o.seq).collect();
            seqs.sort_unstable();
            assert_eq!(seqs, vec![1, 2, 3, 4]);
        }
    }
}
