//! The sharded, multi-threaded decode engine — the serving layer the
//! paper's "large fixed state, many concurrent streams" regime needs.
//!
//! Architecture:
//!
//! ```text
//!   submit(session, chunk)
//!        │  session id ──hash──▶ shard
//!        ▼
//!   bounded sync_channel (depth = queue_depth, backpressure by blocking)
//!        ▼
//!   worker thread s ∈ 0..threads, each owning one ShardBank:
//!     admission (factory) · LRU eviction → snapshot blobs · restore
//!        ▼
//!   unbounded output channel (optional) + per-shard telemetry
//! ```
//!
//! Determinism contract: a session's outputs are **bit-identical across
//! thread counts**. Sessions are pinned to shards by id hash, each
//! shard's channel preserves per-session chunk order, the mixer factory
//! seeds on (session, head) only, and eviction/restore round-trips are
//! bit-exact ([`crate::ovqcore::snapshot`]) — so rescheduling across 1,
//! 2 or 4 workers cannot change any stream's tokens. The engine golden
//! test (rust/tests/engine.rs) cross-checks this.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use super::sampler::{SamplerStack, SamplingParams, StopCriteria};
use crate::ovqcore::bank::{
    process_packed_prefill, ring_push, unpack_session, DecodeChunk, ShardBank, StreamStats,
};
use crate::ovqcore::lm::{LmConfig, LmModel, TokenId};
use crate::ovqcore::memstate::MixerKind;
use crate::ovqcore::mixer::{
    merge_layer_stats, print_layer_split, LayerStat, PrefillMode, Scratch, SeqMixer,
};
use crate::ovqcore::quant::QuantMode;
use crate::ovqcore::stack::{LayerStack, StackConfig};
use crate::ovqcore::store::{prefix_key, PrefixCache, PrefixReport, StoreConfig, TierStats};
use crate::util::obs::{self, HistSnapshot, Registry, Span, Stage, Timing, Trace};

/// Engine shape and policy. `threads` is the shard count (one worker
/// thread per shard); `max_resident` and `queue_depth` are per shard.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub kind: MixerKind,
    pub heads: usize,
    pub d_head: usize,
    /// mixer chunk length (OVQ merge granularity), not the arrival size
    pub chunk: usize,
    pub threads: usize,
    /// admission cap: resident sessions per shard before LRU eviction
    pub max_resident: usize,
    /// bounded per-shard queue: `submit` blocks when full (backpressure)
    pub queue_depth: usize,
    /// continuous batching: a prompt submitted via
    /// [`DecodeEngine::submit_prefill`] is ingested `prefill_quantum`
    /// tokens at a time, with queued decode chunks (for other sessions)
    /// interleaved between quanta — so a 64k arrival delays a live decode
    /// by at most one quantum plus its own queue wait, never by the whole
    /// prompt
    pub prefill_quantum: usize,
    pub seed: u64,
    /// keep per-chunk outputs for the caller (golden cross-checks); off
    /// for load runs so output buffers don't grow unboundedly
    pub collect_outputs: bool,
    /// serve full multi-layer model stacks instead of bare per-head
    /// mixers: each session admits one [`LayerStack`] (norms, q/k/v and
    /// output projections, mixer heads, gated MLP) seeded per session.
    /// When set, `heads` is 1 and `d_head` is the stack's d_model — the
    /// packed row IS the embedding stream ([`EngineConfig::for_stack`]
    /// keeps the invariant).
    pub stack: Option<StackConfig>,
    /// serve token-in/logits-out language models: each session admits one
    /// seeded [`LmModel`] (embedding table + stack + tied unembedding),
    /// which enables [`DecodeEngine::submit_generate`] — the self-feeding
    /// generation path. Implies the stack row-width invariant (build with
    /// [`EngineConfig::for_lm`]); f32 decode/prefill submissions still
    /// work against LM sessions through the trait.
    pub lm: Option<LmConfig>,
    /// self-feeding generation: tokens sampled for one session per
    /// scheduling round before the worker rotates to other work — the
    /// continuous-batching granularity of the generate path (the analogue
    /// of `prefill_quantum` for the decode phase of a generation)
    pub gen_quantum: usize,
    /// cold-tensor storage for bare-mixer sessions (dictionary tensors);
    /// stack/LM engines carry the mode inside [`StackConfig`]`::quant`
    /// instead ([`EngineConfig::for_stack`] mirrors it here so telemetry
    /// reads one place)
    pub quant: QuantMode,
    /// prefill numerics policy applied to every session
    /// ([`ShardBank::set_prefill_mode`]). `Exact` (the default) keeps the
    /// serial, bit-pinned forms; `Chunkwise` opts the scan mixers
    /// (gdn / linear attention) into their chunkwise-parallel prefill
    /// forms, whose outputs match serial within a relative tolerance
    /// (the `--prefill-tolerance` serving mode)
    pub prefill_mode: PrefillMode,
    /// intra-request fan-out: idle shard workers replay a long prompt's
    /// output segments from per-quantum state snapshots while the owner
    /// advances state through the writes-only path. Outputs stay
    /// bit-identical to the serial path at any worker count (segmentation
    /// is always at `prefill_quantum` boundaries). Only bare-mixer
    /// engines with `threads > 1` actually fan out — stack/LM sessions
    /// gain nothing from writes-only prefill, so they keep the serial
    /// path regardless
    pub prefill_fanout: bool,
    /// disk tier for eviction blobs: when set, each shard writes cold
    /// snapshot blobs to `<spill_dir>/shard<N>/` through an async
    /// writeback thread once its RAM blob cache exceeds
    /// [`EngineConfig::ram_blob_budget`]. A spilled session's RAM cost
    /// drops to an index entry; restores verify length + checksum and
    /// route corruption through the typed
    /// [`crate::ovqcore::snapshot::SnapshotError`] path (a torn file
    /// costs one request, never the shard). `None` keeps the pure-RAM
    /// store
    pub spill_dir: Option<PathBuf>,
    /// per-shard byte budget for the RAM blob cache — only meaningful
    /// with [`EngineConfig::spill_dir`] set (a RAM-only store is
    /// unbounded, the pre-tier behaviour)
    pub ram_blob_budget: usize,
    /// shared-prefix caching on the generate path: the first LM session
    /// to prefill a given prompt prefix freezes its snapshot as an
    /// immutable copy-on-write template; later sessions whose request
    /// names the same prefix fork from it bit-identically instead of
    /// re-running the prefill ([`EngineHandle::submit_generate_prefixed`])
    pub prefix_cache: bool,
}

impl EngineConfig {
    pub fn new(kind: MixerKind, heads: usize, d_head: usize, chunk: usize) -> EngineConfig {
        EngineConfig {
            kind,
            heads,
            d_head,
            chunk,
            threads: 1,
            max_resident: usize::MAX / 2,
            queue_depth: 64,
            prefill_quantum: 512,
            seed: 0xE6617E,
            collect_outputs: false,
            stack: None,
            lm: None,
            gen_quantum: 16,
            quant: QuantMode::None,
            prefill_mode: PrefillMode::Exact,
            prefill_fanout: true,
            spill_dir: None,
            ram_blob_budget: usize::MAX / 2,
            prefix_cache: true,
        }
    }

    /// An engine serving whole model stacks: one [`LayerStack`] session
    /// state machine per session, one packed `[len, d_model]` embedding
    /// row per token.
    pub fn for_stack(stack: StackConfig) -> EngineConfig {
        let kind = stack.kinds.first().copied().unwrap_or(MixerKind::Gdn);
        let mut cfg = EngineConfig::new(kind, 1, stack.d_model, stack.chunk);
        cfg.quant = stack.quant;
        cfg.stack = Some(stack);
        cfg
    }

    /// An engine serving language models: one seeded [`LmModel`] per
    /// session, with the generation path armed.
    pub fn for_lm(lm: LmConfig) -> EngineConfig {
        let mut cfg = EngineConfig::for_stack(lm.stack.clone());
        cfg.lm = Some(lm);
        cfg
    }
}

/// Deterministic per-(session, head) mixer seed — must not depend on the
/// shard or thread count (see the determinism contract above).
pub fn session_seed(seed: u64, session: u64, head: usize) -> u64 {
    let mut z = seed
        ^ session.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (head as u64 + 1).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Which shard serves a session — a splitmix-style hash of the id, so
/// consecutive ids spread instead of striping.
pub fn shard_of(session: u64, threads: usize) -> usize {
    let mut z = session.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    ((z ^ (z >> 31)) % threads as u64) as usize
}

enum EngineMsg {
    Chunk { session: u64, chunk: DecodeChunk, submitted: Instant },
    Prefill { session: u64, chunk: DecodeChunk, submitted: Instant },
    Generate {
        session: u64,
        /// request id for trace spans and the response `timing` echo —
        /// minted at submit (or carried in from the HTTP edge)
        req: u64,
        prompt: Vec<TokenId>,
        params: SamplingParams,
        stop: StopCriteria,
        submitted: Instant,
        /// per-request streaming channel: every sampled token is delivered
        /// the moment it exists, then a terminal Done/Failed event. `None`
        /// keeps the engine-wide [`GenOut`] completion channel as the only
        /// output path (the pre-streaming behavior).
        stream: Option<Sender<GenEvent>>,
        /// leading tokens of `prompt` shared with other requests — the
        /// prefix-cache candidate span (0 = no shared prefix)
        prefix_len: usize,
        /// the prefix-cache key for those tokens (caller-supplied
        /// `prefix_id`, or hashed from the tokens at submit)
        prefix_key: u64,
    },
    Evict { session: u64 },
    FlushAll,
}

/// One fanned-out output segment of a long prompt: replay tokens
/// `[start, end)` of the prompt against the owner's session-state
/// snapshot at the segment boundary, and deliver the packed outputs back
/// to the owner. Segments are independent given their snapshots, so any
/// idle worker (or the owner itself, stealing at completion time) can
/// run one.
struct SegmentTask {
    /// owner-unique job id (shard in the high bits) — the owner's key
    /// for stealing back its own unclaimed segments
    job: u64,
    /// segment index in prompt order (the merge key)
    seg: usize,
    /// [`crate::ovqcore::bank::pack_session`] blob of the session at the
    /// segment start
    blob: Arc<Vec<u8>>,
    chunk: Arc<DecodeChunk>,
    /// token range [start, end) of the prompt
    start: usize,
    end: usize,
    heads: usize,
    /// packed row width, heads * d_head
    hd: usize,
    /// blobs thaw in Exact mode; the replay re-applies the engine policy
    mode: PrefillMode,
    tx: Sender<SegResult>,
}

struct SegResult {
    seg: usize,
    out: Vec<f32>,
    /// segment compute time, folded into the prompt's telemetry
    busy_ns: f64,
}

/// The engine-wide queue of fanned-out prefill segments, shared by every
/// shard worker. Plain FIFO under one mutex: segments are quantum-sized
/// (hundreds of microseconds of compute each), so contention on the
/// queue is negligible next to the work it hands out.
#[derive(Default)]
struct PrefillPool {
    tasks: Mutex<VecDeque<SegmentTask>>,
}

impl PrefillPool {
    fn push(&self, t: SegmentTask) {
        self.tasks.lock().unwrap().push_back(t);
    }

    fn pop(&self) -> Option<SegmentTask> {
        self.tasks.lock().unwrap().pop_front()
    }

    /// Remove one still-unclaimed segment belonging to `job` (owner
    /// steal-back at completion time).
    fn steal(&self, job: u64) -> Option<SegmentTask> {
        let mut q = self.tasks.lock().unwrap();
        let i = q.iter().position(|t| t.job == job)?;
        q.remove(i)
    }
}

/// Execute one fanned-out segment: thaw the boundary snapshot, re-apply
/// the engine's prefill mode (blobs always thaw in Exact), replay the
/// token range through the full blocked prefill, and deliver the packed
/// outputs. The thawed state is discarded afterwards — the owner shard
/// advances the real session state through the writes-only path, which
/// lands on the identical state by the [`SeqMixer::prefill_writes`]
/// contract. Returns the segment's compute time.
fn run_segment(task: SegmentTask, scratch: &mut Scratch, panel: &mut Vec<f32>) -> Duration {
    let t0 = Instant::now();
    let mut mixers = unpack_session(&task.blob, task.heads)
        .expect("fan-out snapshot must round-trip (pack_session/unpack_session)");
    for m in &mut mixers {
        m.set_prefill_mode(task.mode);
    }
    let (a, b) = (task.start * task.hd, task.end * task.hd);
    let out = process_packed_prefill(
        &mut mixers,
        &task.chunk.queries[a..b],
        &task.chunk.keys[a..b],
        &task.chunk.values[a..b],
        scratch,
        panel,
    );
    let el = t0.elapsed();
    // the owner may already have dropped the job (failed writes path) —
    // a dead receiver just discards the segment
    let _ = task.tx.send(SegResult { seg: task.seg, out, busy_ns: el.as_nanos() as f64 });
    el
}

/// One completed chunk, tagged with the session's chunk sequence number
/// (1-based, eviction-transparent) so outputs can be ordered per session
/// regardless of cross-shard completion order.
pub struct EngineOut {
    pub session: u64,
    pub seq: usize,
    pub out: Vec<f32>,
}

/// One completed generation request: the sampled completion (stop token
/// included when one fired), tagged like [`EngineOut`] with the session's
/// sequence number. Always collected — the tokens ARE the product of a
/// generate request, and their size is bounded by `max_new`.
pub struct GenOut {
    pub session: u64,
    pub seq: usize,
    pub tokens: Vec<TokenId>,
}

/// Per-request streaming events of one generation, delivered over the
/// channel passed to [`EngineHandle::submit_generate_streamed`]. Tokens
/// arrive in sampling order the moment the sampler produces them — the
/// feed behind SSE token streaming at the HTTP edge. The stream is purely
/// observational: whether one is attached cannot change what the engine
/// computes, so streamed completions are bit-identical to unstreamed ones
/// (and to [`GenOut`], which is still emitted on completion either way).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GenEvent {
    /// one sampled token, sent before the model steps it
    Token(TokenId),
    /// the request completed; `tokens` is the full completion, identical
    /// to the concatenation of the preceding [`GenEvent::Token`] events
    /// and to the [`GenOut`] for this request. `timing` is the request's
    /// wall-clock split (queue / prefill / decode / total) — observational
    /// only, it never feeds computation
    Done { seq: usize, tokens: Vec<TokenId>, timing: Timing },
    /// the request was dropped (non-LM engine, corrupt snapshot restore);
    /// the reason mirrors the engine's `failed_chunks` diagnostics
    Failed(String),
}

/// Non-blocking admission refused: the session's shard queue is full.
/// The caller decides the shedding policy — the HTTP edge maps this to
/// `429 Too Many Requests` with a `Retry-After` hint instead of letting
/// the accept loop block on a saturated shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueFull;

impl std::fmt::Display for QueueFull {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "shard queue full")
    }
}

impl std::error::Error for QueueFull {}

/// Telemetry of one shard over the engine's lifetime.
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub shard: usize,
    /// distinct sessions this shard ever served
    pub sessions: usize,
    /// sessions still resident (live mixers) at shutdown
    pub resident_sessions: usize,
    /// sessions frozen to snapshot blobs at shutdown
    pub evicted_sessions: usize,
    /// completed decode chunks (prompts are counted in `prefill_chunks`)
    pub chunks: usize,
    /// all tokens ingested: decode chunks + prefilled prompts
    pub tokens: usize,
    /// time spent inside chunk/quantum processing (utilization = busy /
    /// wall); `prefill_busy` is the prefill share of it
    pub busy: Duration,
    /// busy time spent ingesting prefill quanta (including the prompt
    /// phase of generate requests) — with `gen_busy`, splits shard
    /// occupancy three ways: decode = `busy - prefill_busy - gen_busy`
    pub prefill_busy: Duration,
    /// busy time spent in the self-feeding generation loop (sampling +
    /// token steps)
    pub gen_busy: Duration,
    /// completed prefill prompts
    pub prefill_chunks: usize,
    /// prompt tokens ingested through the prefill path
    pub prefill_tokens: usize,
    /// tokens sampled by completed generation requests
    pub gen_tokens: usize,
    /// completed generation requests
    pub completions: usize,
    /// submit→last-token wall latency of recent completions, ns (ring)
    pub completion_ns: Vec<f64>,
    /// submit→prefill-complete wall latency (prompt time-to-first-token)
    /// of the most recent prompts, nanoseconds (ring)
    pub ttft_ns: Vec<f64>,
    pub evictions: usize,
    pub restores: usize,
    /// eviction blobs written back to the disk tier
    pub spills: usize,
    /// sessions restored from the disk tier
    pub disk_restores: usize,
    /// sessions frozen on the disk tier at shutdown
    pub disk_sessions: usize,
    /// blob payload bytes on the disk tier at shutdown
    pub disk_bytes: usize,
    /// generate requests that forked their prompt prefix from a cached
    /// template instead of prefilling it
    pub prefix_forks: usize,
    /// prompt tokens those forks skipped (the prefill work saved)
    pub prefix_fork_tokens: usize,
    /// high-water mark of in-flight work the gauge saw: channel-queued +
    /// in-service (+ one blocked submitter), plus — when prompts are in
    /// play — admitted-but-unfinished prefill jobs and order-deferred
    /// messages (both bounded by queue_depth; see the worker drain gate)
    pub max_queue: usize,
    /// chunks dropped because the session failed to admit/restore (e.g. a
    /// corrupt snapshot blob) — the session is discarded, the shard lives
    pub failed_chunks: usize,
    /// live mixer bytes of resident sessions at shutdown
    pub resident_bytes: usize,
    /// RAM held for frozen sessions at shutdown: RAM-tier blobs in full
    /// plus an index entry per disk-spilled session (disk payload bytes
    /// are in `disk_bytes`)
    pub snapshot_bytes: usize,
    /// submit→completion wall latency of the most recent
    /// [`crate::ovqcore::bank::LATENCY_WINDOW`] chunks, nanoseconds (ring)
    pub latency_ns: Vec<f64>,
    /// per-layer telemetry split over the shard's resident sessions at
    /// shutdown — one row per model layer when serving stacks, one row
    /// total for bare mixers ([`ShardBank::layer_stats`])
    pub layers: Vec<LayerStat>,
}

/// Aggregate result of an engine run.
pub struct EngineReport {
    pub threads: usize,
    pub wall: Duration,
    pub tokens: usize,
    pub chunks: usize,
    pub shards: Vec<ShardReport>,
    /// per-session telemetry, sorted by session id
    pub sessions: Vec<(u64, StreamStats)>,
    /// per-chunk outputs (only when `collect_outputs` was set)
    pub outputs: Vec<EngineOut>,
    /// completed generations, sorted by (session, seq) — always collected
    pub generations: Vec<GenOut>,
    /// engine-wide prefix-cache statistics at shutdown
    pub prefix: PrefixReport,
    /// merged submit→completion decode-chunk latency histogram, ns —
    /// the registry view the percentile methods read (bounded memory
    /// over the whole run, unlike the windowed `ShardReport` rings)
    pub latency_hist: HistSnapshot,
    /// merged submit→first-token latency histogram, ns
    pub ttft_hist: HistSnapshot,
    /// merged submit→last-token generation latency histogram, ns
    pub completion_hist: HistSnapshot,
}

impl EngineReport {
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.wall.as_secs_f64()
    }

    pub fn evictions(&self) -> usize {
        self.shards.iter().map(|s| s.evictions).sum()
    }

    pub fn restores(&self) -> usize {
        self.shards.iter().map(|s| s.restores).sum()
    }

    /// Eviction blobs written back to the disk tier, all shards.
    pub fn spills(&self) -> usize {
        self.shards.iter().map(|s| s.spills).sum()
    }

    /// Sessions restored from the disk tier, all shards.
    pub fn disk_restores(&self) -> usize {
        self.shards.iter().map(|s| s.disk_restores).sum()
    }

    /// Sessions frozen on the disk tier at shutdown, all shards.
    pub fn disk_sessions(&self) -> usize {
        self.shards.iter().map(|s| s.disk_sessions).sum()
    }

    /// Blob payload bytes on the disk tier at shutdown, all shards.
    pub fn disk_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.disk_bytes).sum()
    }

    /// Generate requests that forked their prefix from a cached
    /// template, all shards.
    pub fn prefix_forks(&self) -> usize {
        self.shards.iter().map(|s| s.prefix_forks).sum()
    }

    /// Prompt tokens skipped by prefix forks, all shards.
    pub fn prefix_fork_tokens(&self) -> usize {
        self.shards.iter().map(|s| s.prefix_fork_tokens).sum()
    }

    /// Chunks dropped on failed session admit/restore across all shards.
    pub fn failed_chunks(&self) -> usize {
        self.shards.iter().map(|s| s.failed_chunks).sum()
    }

    /// Total RAM state at shutdown: live mixers + the RAM cost of
    /// frozen sessions (disk-spilled blobs count their index entry
    /// only; the payload is in [`EngineReport::disk_bytes`]).
    pub fn state_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.resident_bytes + s.snapshot_bytes).sum()
    }

    /// Cross-shard submit→completion latency percentile, microseconds.
    /// Read from the run-lifetime log-bucketed histogram (within one
    /// bucket width, ~26%, of the exact sample percentile); 0 when no
    /// chunks completed.
    pub fn latency_us(&self, p: f64) -> f64 {
        self.latency_hist.percentile(p) / 1e3
    }

    /// Prompt time-to-first-token percentile, microseconds (submit →
    /// first token; histogram view, like [`EngineReport::latency_us`]).
    /// 0 when no prompts ran.
    pub fn ttft_us(&self, p: f64) -> f64 {
        self.ttft_hist.percentile(p) / 1e3
    }

    /// Prompt tokens ingested through the prefill path, all shards.
    pub fn prefill_tokens(&self) -> usize {
        self.shards.iter().map(|s| s.prefill_tokens).sum()
    }

    /// Completed prefill prompts, all shards.
    pub fn prefill_chunks(&self) -> usize {
        self.shards.iter().map(|s| s.prefill_chunks).sum()
    }

    /// Tokens sampled by completed generation requests, all shards.
    pub fn gen_tokens(&self) -> usize {
        self.shards.iter().map(|s| s.gen_tokens).sum()
    }

    /// Completed generation requests, all shards.
    pub fn completions(&self) -> usize {
        self.shards.iter().map(|s| s.completions).sum()
    }

    /// End-to-end completion latency percentile (submit → last sampled
    /// token), microseconds (histogram view). 0 when nothing generated.
    pub fn completion_us(&self, p: f64) -> f64 {
        self.completion_hist.percentile(p) / 1e3
    }

    /// Aggregate generation throughput: sampled tokens per wall second.
    pub fn gen_tokens_per_sec(&self) -> f64 {
        self.gen_tokens() as f64 / self.wall.as_secs_f64()
    }

    /// Per-shard busy fraction of the run's wall clock.
    pub fn utilization(&self) -> Vec<f64> {
        let w = self.wall.as_secs_f64().max(1e-12);
        self.shards.iter().map(|s| s.busy.as_secs_f64() / w).collect()
    }

    /// Cross-shard per-layer telemetry: one merged row per model layer
    /// (state bytes, busy time, tokens). Single-row for bare mixers;
    /// one row per transformer layer when the engine serves stacks.
    pub fn layer_split(&self) -> Vec<LayerStat> {
        let mut acc = Vec::new();
        for s in &self.shards {
            merge_layer_stats(&mut acc, &s.layers);
        }
        acc
    }

    /// Per-shard (decode, prefill, generate) occupancy — each shard's
    /// busy time split three ways by path, as fractions of the run's
    /// wall clock.
    pub fn occupancy(&self) -> Vec<(f64, f64, f64)> {
        let w = self.wall.as_secs_f64().max(1e-12);
        self.shards
            .iter()
            .map(|s| {
                let p = s.prefill_busy.as_secs_f64() / w;
                let g = s.gen_busy.as_secs_f64() / w;
                (s.busy.as_secs_f64() / w - p - g, p, g)
            })
            .collect()
    }

    pub fn print(&self) {
        println!(
            "engine: {} threads, {} sessions, {} chunks -> {:.0} tok/s aggregate \
             ({} tokens in {:.2}s)",
            self.threads,
            self.sessions.len(),
            self.chunks,
            self.tokens_per_sec(),
            self.tokens,
            self.wall.as_secs_f64(),
        );
        println!(
            "  latency p50 {:.1} us  p99 {:.1} us  |  {} evictions, {} restores, \
             state {:.1} KiB",
            self.latency_us(50.0),
            self.latency_us(99.0),
            self.evictions(),
            self.restores(),
            self.state_bytes() as f64 / 1024.0,
        );
        if self.prefill_chunks() > 0 {
            println!(
                "  prefill: {} prompts / {} tokens  ttft p50 {:.1} us  p99 {:.1} us",
                self.prefill_chunks(),
                self.prefill_tokens(),
                self.ttft_us(50.0),
                self.ttft_us(99.0),
            );
        }
        if self.completions() > 0 {
            println!(
                "  generate: {} completions / {} tokens ({:.0} tok/s sampled)  \
                 completion p50 {:.1} us  p99 {:.1} us",
                self.completions(),
                self.gen_tokens(),
                self.gen_tokens_per_sec(),
                self.completion_us(50.0),
                self.completion_us(99.0),
            );
        }
        if self.spills() > 0 || self.disk_restores() > 0 {
            println!(
                "  disk tier: {} spills, {} restores  |  {} sessions / {:.1} KiB on disk at exit",
                self.spills(),
                self.disk_restores(),
                self.disk_sessions(),
                self.disk_bytes() as f64 / 1024.0,
            );
        }
        if self.prefix.hits + self.prefix.misses > 0 {
            println!(
                "  prefix cache: {} hits / {} misses  |  {} forks skipped {} prompt tokens  \
                 |  {} templates / {:.1} KiB resident",
                self.prefix.hits,
                self.prefix.misses,
                self.prefix_forks(),
                self.prefix_fork_tokens(),
                self.prefix.entries,
                self.prefix.bytes as f64 / 1024.0,
            );
        }
        if self.failed_chunks() > 0 {
            println!("  WARNING: {} chunks dropped on failed restores", self.failed_chunks());
        }
        print_layer_split(&self.layer_split(), self.wall * self.threads as u32);
        for (s, (du, pu, gu)) in self.shards.iter().zip(self.occupancy()) {
            println!(
                "  shard {:>2}: {:>4} sessions {:>7} tokens  occupancy {:>5.1}% decode \
                 + {:>5.1}% prefill + {:>5.1}% generate  max queue {:>3}  \
                 evict/restore {}/{}  resident {:.1} KiB + snapshots {:.1} KiB",
                s.shard,
                s.sessions,
                s.tokens,
                100.0 * du,
                100.0 * pu,
                100.0 * gu,
                s.max_queue,
                s.evictions,
                s.restores,
                s.resident_bytes as f64 / 1024.0,
                s.snapshot_bytes as f64 / 1024.0,
            );
        }
    }
}

/// Shared observability state of one engine: the metrics registry the
/// report views and `GET /metrics` read, the per-shard trace rings
/// `GET /v1/trace` dumps, and the pre-registered hot-path handles
/// (histograms, counters) the shard workers record into. Owned per
/// engine — never process-global — so concurrent engines (and tests)
/// cannot contaminate each other's metrics.
pub struct EngineObs {
    registry: Arc<Registry>,
    trace: Arc<Trace>,
    /// submit→completion latency of decode chunks, nanoseconds
    latency: obs::Histogram,
    /// submit→first-token latency of prompts and generations, ns
    ttft: obs::Histogram,
    /// submit→last-token latency of completed generations, ns
    completion: obs::Histogram,
    /// all tokens ingested (decode + prefill + sampled)
    tokens: obs::Counter,
    /// completed generation requests
    completions: obs::Counter,
}

impl EngineObs {
    fn new(shards: usize) -> EngineObs {
        let registry = Arc::new(Registry::new());
        EngineObs {
            trace: Arc::new(Trace::new(shards, obs::TRACE_RING_CAP)),
            latency: registry.histogram("ovq_decode_latency_ns", &[]),
            ttft: registry.histogram("ovq_ttft_ns", &[]),
            completion: registry.histogram("ovq_completion_ns", &[]),
            tokens: registry.counter("ovq_tokens_total", &[]),
            completions: registry.counter("ovq_completions_total", &[]),
            registry,
        }
    }

    /// The metrics registry (render with
    /// [`Registry::render_prometheus`] for `GET /metrics`; edges
    /// register their own counters here too).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// The trace rings (`GET /v1/trace` dumps them).
    pub fn trace(&self) -> &Arc<Trace> {
        &self.trace
    }
}

/// A cheap, cloneable submission handle onto a running [`DecodeEngine`].
///
/// The engine itself is not `Sync` (it owns the output `Receiver`s), so a
/// network edge cannot share `&DecodeEngine` across connection threads.
/// The handle carries only the `Send + Sync` half — the bounded shard
/// senders and the queue gauges — and every submit path of the engine is
/// available on it, plus the non-blocking [`EngineHandle::try_submit_generate`]
/// the overload-shedding edge needs. Clone one per connection thread.
///
/// Shutdown contract: shard workers exit when their queues drain AND
/// every sender is gone — the engine's own plus **every live handle
/// clone**. [`DecodeEngine::finish`] drops the engine's copy; callers
/// must drop their handles (e.g. stop the HTTP server) before `finish`
/// can join the workers.
#[derive(Clone)]
pub struct EngineHandle {
    txs: Vec<SyncSender<EngineMsg>>,
    /// per-shard (gauge, high-water) of queued + in-service work items
    queue_gauge: Vec<Arc<AtomicUsize>>,
    queue_high: Vec<Arc<AtomicUsize>>,
    queue_depth: usize,
    threads: usize,
    lm_vocab: Option<usize>,
    /// live disk-tier gauges mirrored by every shard's TieredStore —
    /// `/v1/stats` reads these while the engine runs
    tier: Arc<TierStats>,
    /// the engine-wide prefix template cache (shared with every shard)
    prefix: Arc<PrefixCache>,
    /// metrics registry + trace rings shared with every shard worker
    obs: Arc<EngineObs>,
}

impl EngineHandle {
    /// Gauge bump + send on a session's shard (the shared submit core).
    fn send_counted(&self, s: usize, msg: EngineMsg) {
        let v = self.queue_gauge[s].fetch_add(1, Ordering::SeqCst) + 1;
        self.queue_high[s].fetch_max(v, Ordering::SeqCst);
        self.txs[s].send(msg).expect("shard worker died");
    }

    /// See [`DecodeEngine::submit`].
    pub fn submit(&self, session: u64, chunk: DecodeChunk) {
        let s = shard_of(session, self.threads);
        self.send_counted(s, EngineMsg::Chunk { session, chunk, submitted: Instant::now() });
    }

    /// See [`DecodeEngine::submit_prefill`].
    pub fn submit_prefill(&self, session: u64, chunk: DecodeChunk) {
        let s = shard_of(session, self.threads);
        self.send_counted(s, EngineMsg::Prefill { session, chunk, submitted: Instant::now() });
    }

    /// See [`DecodeEngine::submit_generate`].
    pub fn submit_generate(
        &self,
        session: u64,
        prompt: Vec<TokenId>,
        params: SamplingParams,
        stop: StopCriteria,
    ) {
        self.submit_generate_prefixed(session, prompt, 0, None, params, stop);
    }

    /// [`EngineHandle::submit_generate`] naming a shared prompt prefix:
    /// the first `prefix_len` prompt tokens are a prefix-cache
    /// candidate. On a cache hit the session forks bit-identically from
    /// the cached template instead of prefilling those tokens (TTFT
    /// drops from O(prefix) to O(restore)); on a miss the session
    /// prefills normally and freezes its state at the prefix boundary
    /// as the template for later requests. `prefix_id` overrides the
    /// cache key (callers that already name their system prompts);
    /// `None` hashes the prefix tokens. Outputs are bit-identical
    /// either way — hit, miss, or cache disabled — which the golden
    /// tests pin. `prefix_len` must leave at least one non-prefix
    /// prompt token (the fork needs a fresh token to compute logits
    /// from); oversized values are ignored, not errors, at this level.
    pub fn submit_generate_prefixed(
        &self,
        session: u64,
        prompt: Vec<TokenId>,
        prefix_len: usize,
        prefix_id: Option<u64>,
        params: SamplingParams,
        stop: StopCriteria,
    ) {
        let s = shard_of(session, self.threads);
        let key = prefix_id.unwrap_or_else(|| prefix_key(&prompt[..prefix_len.min(prompt.len())]));
        let msg = EngineMsg::Generate {
            session,
            req: obs::next_request_id(),
            prompt,
            params,
            stop,
            submitted: Instant::now(),
            stream: None,
            prefix_len,
            prefix_key: key,
        };
        self.send_counted(s, msg);
    }

    /// [`EngineHandle::submit_generate`] with a per-request streaming
    /// channel: each sampled token arrives as [`GenEvent::Token`] the
    /// moment it exists, followed by a terminal [`GenEvent::Done`] (or
    /// [`GenEvent::Failed`]). Blocks on the shard queue like every
    /// submit; pair with [`EngineHandle::try_submit_generate`] when the
    /// caller must not block.
    pub fn submit_generate_streamed(
        &self,
        session: u64,
        prompt: Vec<TokenId>,
        params: SamplingParams,
        stop: StopCriteria,
        stream: Sender<GenEvent>,
    ) {
        let s = shard_of(session, self.threads);
        let msg = EngineMsg::Generate {
            session,
            req: obs::next_request_id(),
            prompt,
            params,
            stop,
            submitted: Instant::now(),
            stream: Some(stream),
            prefix_len: 0,
            prefix_key: 0,
        };
        self.send_counted(s, msg);
    }

    /// Non-blocking generate admission: like
    /// [`EngineHandle::submit_generate_streamed`] (with `stream: None`
    /// degrading to the plain completion path), but when the session's
    /// shard queue is full it returns [`QueueFull`] immediately instead
    /// of blocking the caller — the engine-backpressure signal the HTTP
    /// edge turns into `429 Retry-After`.
    pub fn try_submit_generate(
        &self,
        session: u64,
        prompt: Vec<TokenId>,
        params: SamplingParams,
        stop: StopCriteria,
        stream: Option<Sender<GenEvent>>,
    ) -> Result<(), QueueFull> {
        self.try_submit_generate_prefixed(session, prompt, 0, None, params, stop, stream)
    }

    /// [`EngineHandle::try_submit_generate`] naming a shared prompt
    /// prefix (see [`EngineHandle::submit_generate_prefixed`]) — the
    /// HTTP edge's admission path for requests carrying `prefix_len` /
    /// `prefix_id`.
    #[allow(clippy::too_many_arguments)]
    pub fn try_submit_generate_prefixed(
        &self,
        session: u64,
        prompt: Vec<TokenId>,
        prefix_len: usize,
        prefix_id: Option<u64>,
        params: SamplingParams,
        stop: StopCriteria,
        stream: Option<Sender<GenEvent>>,
    ) -> Result<(), QueueFull> {
        self.try_submit_generate_traced(
            obs::next_request_id(),
            session,
            prompt,
            prefix_len,
            prefix_id,
            params,
            stop,
            stream,
        )
    }

    /// [`EngineHandle::try_submit_generate_prefixed`] with a
    /// caller-supplied request id — the HTTP edge mints (or hashes from
    /// the client's `x-request-id` header) the id before admission, so
    /// the trace spans carry the same id the response echoes.
    #[allow(clippy::too_many_arguments)]
    pub fn try_submit_generate_traced(
        &self,
        req: u64,
        session: u64,
        prompt: Vec<TokenId>,
        prefix_len: usize,
        prefix_id: Option<u64>,
        params: SamplingParams,
        stop: StopCriteria,
        stream: Option<Sender<GenEvent>>,
    ) -> Result<(), QueueFull> {
        let s = shard_of(session, self.threads);
        let v = self.queue_gauge[s].fetch_add(1, Ordering::SeqCst) + 1;
        let key = prefix_id.unwrap_or_else(|| prefix_key(&prompt[..prefix_len.min(prompt.len())]));
        let msg = EngineMsg::Generate {
            session,
            req,
            prompt,
            params,
            stop,
            submitted: Instant::now(),
            stream,
            prefix_len,
            prefix_key: key,
        };
        match self.txs[s].try_send(msg) {
            Ok(()) => {
                self.queue_high[s].fetch_max(v, Ordering::SeqCst);
                Ok(())
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.queue_gauge[s].fetch_sub(1, Ordering::SeqCst);
                Err(QueueFull)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => panic!("shard worker died"),
        }
    }

    /// See [`DecodeEngine::evict`].
    pub fn evict(&self, session: u64) {
        let s = shard_of(session, self.threads);
        self.txs[s].send(EngineMsg::Evict { session }).expect("shard worker died");
    }

    /// See [`DecodeEngine::flush_all`].
    pub fn flush_all(&self) {
        for tx in &self.txs {
            tx.send(EngineMsg::FlushAll).expect("shard worker died");
        }
    }

    /// Shard worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Bounded per-shard queue depth (the backpressure threshold).
    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// The LM vocabulary when the engine serves language models.
    pub fn lm_vocab(&self) -> Option<usize> {
        self.lm_vocab
    }

    /// Live per-shard queue gauges: channel-queued + in-service work
    /// items right now — the telemetry `/v1/stats` reports while the
    /// engine runs (the [`EngineReport`] equivalents exist only at
    /// [`DecodeEngine::finish`]).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.queue_gauge.iter().map(|g| g.load(Ordering::SeqCst)).collect()
    }

    /// Live disk-tier counters across every shard, in order: (spills,
    /// disk restores, sessions on disk now, payload bytes on disk now).
    /// The monotonic pair lags writeback completion by at most the
    /// writer thread's in-flight job.
    pub fn tier_counters(&self) -> (usize, usize, usize, usize) {
        (
            self.tier.spills.load(Ordering::Relaxed),
            self.tier.disk_restores.load(Ordering::Relaxed),
            self.tier.disk_sessions.load(Ordering::Relaxed),
            self.tier.disk_bytes.load(Ordering::Relaxed),
        )
    }

    /// Live prefix-cache statistics (hits, misses, resident template
    /// bytes, entries).
    pub fn prefix_stats(&self) -> PrefixReport {
        self.prefix.stats()
    }

    /// The engine's observability hub (metrics registry + trace rings)
    /// — what the HTTP edge serves `/metrics` and `/v1/trace` from.
    pub fn obs(&self) -> &Arc<EngineObs> {
        &self.obs
    }

    /// Merged live snapshot of a request-latency histogram by registry
    /// name — the `/v1/stats` percentile source while the engine runs.
    pub fn histogram_snapshot(&self, name: &str) -> HistSnapshot {
        self.obs.registry.histogram_snapshot(name)
    }
}

/// The running engine. Dropping it without [`DecodeEngine::finish`]
/// detaches the workers (they exit once their queues drain).
pub struct DecodeEngine {
    cfg: EngineConfig,
    handle: EngineHandle,
    handles: Vec<thread::JoinHandle<(ShardReport, Vec<(u64, StreamStats)>)>>,
    out_rx: Receiver<EngineOut>,
    gen_rx: Receiver<GenOut>,
    t0: Instant,
}

impl DecodeEngine {
    /// Start with the standard factory: bare [`MixerKind`] per-head
    /// machines, or — when [`EngineConfig::stack`] is set — one seeded
    /// [`LayerStack`] per session, served unchanged through the trait.
    pub fn start(cfg: EngineConfig) -> DecodeEngine {
        let seed = cfg.seed;
        if let Some(lm) = cfg.lm.clone() {
            assert!(
                cfg.heads == 1 && cfg.d_head == lm.stack.d_model,
                "lm engines pack one [len, d_model] row per token \
                 (build the config with EngineConfig::for_lm)"
            );
            // one shared weight seed for every session: a served model is
            // ONE set of weights, and shared weights are what make a
            // prefix-cache fork bit-identical to running the prefill
            // locally (per-session weights would make the template's
            // state meaningless to any other session). Sampling stays
            // per-session — the generation RNG seeds on (engine seed,
            // request seed, session) at dispatch, not here.
            let wseed = session_seed(seed, 0, 0);
            return Self::start_with(cfg, move |_session, _head| {
                Box::new(LmModel::new(lm.clone(), wseed)) as Box<dyn SeqMixer>
            });
        }
        if let Some(stack) = cfg.stack.clone() {
            assert!(
                cfg.heads == 1 && cfg.d_head == stack.d_model,
                "stack engines pack one [len, d_model] row per token \
                 (build the config with EngineConfig::for_stack)"
            );
            return Self::start_with(cfg, move |session, _head| {
                Box::new(LayerStack::new(stack.clone(), session_seed(seed, session, 0)))
                    as Box<dyn SeqMixer>
            });
        }
        let (kind, d_head, chunk, quant) = (cfg.kind, cfg.d_head, cfg.chunk, cfg.quant);
        Self::start_with(cfg, move |session, head| {
            kind.build_quant(d_head, chunk, session_seed(seed, session, head), quant)
        })
    }

    /// Start with a custom per-(session, head) mixer factory. The factory
    /// must be deterministic in its arguments (see module docs); one clone
    /// runs on every worker thread.
    pub fn start_with(
        cfg: EngineConfig,
        factory: impl Fn(u64, usize) -> Box<dyn SeqMixer> + Send + Clone + 'static,
    ) -> DecodeEngine {
        assert!(cfg.threads > 0 && cfg.heads > 0 && cfg.queue_depth > 0);
        let (out_tx, out_rx) = mpsc::channel::<EngineOut>();
        let (gen_tx, gen_rx) = mpsc::channel::<GenOut>();
        let mut txs = Vec::with_capacity(cfg.threads);
        let mut handles = Vec::with_capacity(cfg.threads);
        let mut queue_gauge = Vec::with_capacity(cfg.threads);
        let mut queue_high = Vec::with_capacity(cfg.threads);
        // fan-out only pays when there are helpers to take segments and
        // the writes-only path is actually cheaper than the full prefill
        // (bare mixers; stack/LM prefill_writes is the full forward pass)
        let fanout = cfg.prefill_fanout && cfg.stack.is_none() && cfg.threads > 1;
        let pool = Arc::new(PrefillPool::default());
        let tier = Arc::new(TierStats::default());
        // prefix forking requires the shared-weight LM factory above:
        // only LM engines arm it (a bare-mixer template would smuggle
        // one session's per-session dictionary seeds into another)
        let prefix = Arc::new(PrefixCache::new(cfg.prefix_cache && cfg.lm.is_some()));
        let obs = Arc::new(EngineObs::new(cfg.threads));
        // report structs that already own atomics join the registry as
        // render-time views instead of duplicating their storage
        tier.register_metrics(&obs.registry);
        prefix.register_metrics(&obs.registry);
        for shard in 0..cfg.threads {
            let (tx, rx) = mpsc::sync_channel::<EngineMsg>(cfg.queue_depth);
            let gauge = Arc::new(AtomicUsize::new(0));
            let high = Arc::new(AtomicUsize::new(0));
            let worker_out = cfg.collect_outputs.then(|| out_tx.clone());
            let worker_gen = gen_tx.clone();
            let worker_gauge = Arc::clone(&gauge);
            let worker_high = Arc::clone(&high);
            let factory = factory.clone();
            let wcfg = WorkerCfg {
                shard,
                heads: cfg.heads,
                max_resident: cfg.max_resident,
                hd: cfg.heads * cfg.d_head,
                queue_depth: cfg.queue_depth,
                prefill_quantum: cfg.prefill_quantum.max(1),
                gen_quantum: cfg.gen_quantum.max(1),
                vocab: cfg.lm.as_ref().map_or(0, |l| l.vocab),
                seed: cfg.seed,
                prefill_mode: cfg.prefill_mode,
                fanout,
                // shards never share blob files: each gets a subdir
                spill_dir: cfg.spill_dir.as_ref().map(|d| d.join(format!("shard{shard}"))),
                ram_blob_budget: cfg.ram_blob_budget,
            };
            let worker_pool = Arc::clone(&pool);
            let worker_tier = Arc::clone(&tier);
            let worker_prefix = Arc::clone(&prefix);
            let worker_obs = Arc::clone(&obs);
            let view_gauge = Arc::clone(&gauge);
            obs.registry.gauge_fn(
                "ovq_queue_depth",
                &[("shard", &format!("{shard}"))],
                move || view_gauge.load(Ordering::SeqCst) as f64,
            );
            handles.push(thread::spawn(move || {
                shard_worker(
                    wcfg,
                    factory,
                    rx,
                    worker_out,
                    worker_gen,
                    worker_gauge,
                    worker_high,
                    worker_pool,
                    worker_tier,
                    worker_prefix,
                    worker_obs,
                )
            }));
            txs.push(tx);
            queue_gauge.push(gauge);
            queue_high.push(high);
        }
        drop(out_tx); // workers hold the only senders
        drop(gen_tx);
        let handle = EngineHandle {
            txs,
            queue_gauge,
            queue_high,
            queue_depth: cfg.queue_depth,
            threads: cfg.threads,
            lm_vocab: cfg.lm.as_ref().map(|l| l.vocab),
            tier,
            prefix,
            obs,
        };
        DecodeEngine { cfg, handle, handles, out_rx, gen_rx, t0: Instant::now() }
    }

    /// A cloneable `Send + Sync` submission handle — share one per
    /// connection thread at a network edge (see [`EngineHandle`] for the
    /// shutdown contract).
    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    pub fn threads(&self) -> usize {
        self.cfg.threads
    }

    pub fn heads(&self) -> usize {
        self.cfg.heads
    }

    pub fn d_head(&self) -> usize {
        self.cfg.d_head
    }

    /// Enqueue one packed `[len, heads, d]` chunk for a session. Blocks
    /// while the session's shard queue is full — open-loop producers feel
    /// backpressure here instead of growing an unbounded buffer.
    pub fn submit(&self, session: u64, chunk: DecodeChunk) {
        self.handle.submit(session, chunk);
    }

    /// Enqueue a whole prompt for a session — the long-prompt admission
    /// path. The shard worker slices it into
    /// [`EngineConfig::prefill_quantum`]-token quanta ingested through the
    /// blocked [`crate::ovqcore::mixer::SeqMixer::process_prefill`] path,
    /// interleaving queued decode chunks of *other* sessions between
    /// quanta (continuous batching); messages for the *same* session
    /// submitted after the prompt are deferred behind it, so per-session
    /// order — and therefore bit-identity with a serial run — holds.
    /// When outputs are collected, the whole prompt completes as ONE
    /// [`EngineOut`] sequenced like a single chunk.
    pub fn submit_prefill(&self, session: u64, chunk: DecodeChunk) {
        self.handle.submit_prefill(session, chunk);
    }

    /// Enqueue a generation request: the prompt token ids are routed
    /// through the session's [`LmModel`] prefill (in
    /// [`EngineConfig::prefill_quantum`]-token quanta, continuous-batched
    /// like any prompt), then the shard worker runs a self-feeding decode
    /// loop — sample with `params` through the
    /// [`SamplerStack`] chain, step the model, repeat, at most
    /// [`EngineConfig::gen_quantum`] tokens per scheduling round so other
    /// sessions' decode chunks, prompts and generations interleave.
    /// Completion (per `stop`) emits a [`GenOut`]. Requires an LM engine
    /// ([`EngineConfig::for_lm`]); on a non-LM engine the request is
    /// dropped and counted under `failed_chunks`. Blocks on the shard
    /// queue like every submit (backpressure).
    pub fn submit_generate(
        &self,
        session: u64,
        prompt: Vec<TokenId>,
        params: SamplingParams,
        stop: StopCriteria,
    ) {
        self.handle.submit_generate(session, prompt, params, stop);
    }

    /// The LM vocabulary when this engine serves language models.
    pub fn lm_vocab(&self) -> Option<usize> {
        self.cfg.lm.as_ref().map(|l| l.vocab)
    }

    /// Live per-shard queue gauges (see [`EngineHandle::queue_depths`]).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.handle.queue_depths()
    }

    /// Ask a session's shard to evict it to a snapshot blob (client
    /// abandon). Queued chunks for the session are processed first (the
    /// message travels the same ordered queue).
    pub fn evict(&self, session: u64) {
        self.handle.evict(session);
    }

    /// Merge every resident session's buffered chunk tail (end-of-run).
    pub fn flush_all(&self) {
        self.handle.flush_all();
    }

    /// Non-blocking drain of completed outputs (empty unless
    /// `collect_outputs` is set). Call periodically during long
    /// collect-mode runs to keep memory bounded.
    pub fn try_outputs(&self) -> Vec<EngineOut> {
        self.out_rx.try_iter().collect()
    }

    /// Non-blocking drain of completed generations — the streaming
    /// consumption path for long generate runs.
    pub fn try_generations(&self) -> Vec<GenOut> {
        self.gen_rx.try_iter().collect()
    }

    /// Shut down: close the queues, join the workers, gather telemetry
    /// and any remaining outputs. Blocks until every [`EngineHandle`]
    /// clone has dropped too (handles hold queue senders — see
    /// [`EngineHandle`]'s shutdown contract).
    pub fn finish(self) -> EngineReport {
        let DecodeEngine { cfg, handle, handles, out_rx, gen_rx, t0 } = self;
        // keep the cache stats and registry alive past the handle drop;
        // read them only after the joins below so every worker's counts
        // are final
        let prefix_cache = Arc::clone(&handle.prefix);
        let obs = Arc::clone(&handle.obs);
        drop(handle); // workers exit when their queues drain and all handles drop
        let mut shards = Vec::with_capacity(handles.len());
        let mut sessions: Vec<(u64, StreamStats)> = Vec::new();
        for h in handles {
            let (report, mut stats) = h.join().expect("shard worker panicked");
            shards.push(report);
            sessions.append(&mut stats);
        }
        let wall = t0.elapsed();
        // session ids are disjoint across shards (hash-pinned), so a plain
        // sort yields one global, deterministic ordering
        sessions.sort_by_key(|&(id, _)| id);
        let outputs: Vec<EngineOut> = out_rx.try_iter().collect();
        let mut generations: Vec<GenOut> = gen_rx.try_iter().collect();
        generations.sort_by_key(|g| (g.session, g.seq));
        let tokens = shards.iter().map(|s| s.tokens).sum();
        let chunks = shards.iter().map(|s| s.chunks).sum();
        let prefix = prefix_cache.stats();
        EngineReport {
            threads: cfg.threads,
            wall,
            tokens,
            chunks,
            shards,
            sessions,
            outputs,
            generations,
            prefix,
            latency_hist: obs.latency.snapshot(),
            ttft_hist: obs.ttft.snapshot(),
            completion_hist: obs.completion.snapshot(),
        }
    }
}

/// Static per-worker shape (one struct so the spawn site stays readable).
#[derive(Debug, Clone)]
struct WorkerCfg {
    shard: usize,
    heads: usize,
    max_resident: usize,
    /// packed row width, heads * d_head
    hd: usize,
    queue_depth: usize,
    prefill_quantum: usize,
    /// tokens sampled per generate-job scheduling round
    gen_quantum: usize,
    /// LM vocabulary (0 when the engine does not serve language models)
    vocab: usize,
    /// engine seed, mixed into per-request generation-RNG seeds
    seed: u64,
    /// prefill numerics policy, applied to the shard's bank at startup
    prefill_mode: PrefillMode,
    /// intra-request fan-out armed for this engine (see EngineConfig)
    fanout: bool,
    /// this shard's private disk-spill directory (None = RAM-only store)
    spill_dir: Option<PathBuf>,
    /// RAM budget for frozen snapshot blobs, bytes (only with spill_dir)
    ram_blob_budget: usize,
}

/// An in-flight long-prompt admission, ingested one quantum at a time.
struct PrefillJob {
    session: u64,
    /// shared with fanned-out segment tasks (zero-copy slicing)
    chunk: Arc<DecodeChunk>,
    /// tokens ingested so far / total prompt tokens
    done: usize,
    total: usize,
    submitted: Instant,
    /// processing time across this job's quanta, nanoseconds — for a
    /// fanned-out prompt this is the total across every thread that
    /// touched it (owner writes + all segment replays)
    busy_ns: f64,
    /// accumulated packed outputs (only in collect mode)
    out: Option<Vec<f32>>,
    /// Some when this prompt's output segments go through the fan-out
    /// pool instead of the serial process_prefill path
    fan: Option<FanState>,
}

/// Fan-out bookkeeping of one prompt: the owner-unique job id, how many
/// segments were published, and the result channel the segments deliver
/// into (in any order; the owner merges by segment index).
struct FanState {
    job: u64,
    segs: usize,
    tx: Sender<SegResult>,
    rx: Receiver<SegResult>,
}

/// An in-flight generation request: prompt ingestion (quantized, like a
/// prefill), then the self-feeding sample/step loop. The job carries the
/// request *config* (sampler chain, stop rule) and pure data (the prompt,
/// the last-position logits, the output tokens); the state that must
/// survive LRU eviction — history ring, sampling RNG, produced count —
/// lives inside the session's [`LmModel`] snapshot.
struct GenJob {
    session: u64,
    /// request id carried into trace spans and the `timing` echo
    req: u64,
    /// submit→dispatch wall time, nanoseconds (the `timing` queue share)
    queue_ns: f64,
    /// busy time ingesting the prompt (incl. a prefix-fork restore), ns
    prefill_ns: f64,
    prompt: Vec<TokenId>,
    /// prompt tokens ingested so far
    done: usize,
    /// leading prompt tokens eligible for prefix-cache fork/registration
    /// (0 = plain request; forced to 0 when forking cannot apply)
    prefix_len: usize,
    /// cache key of the prefix (caller-supplied id or prefix-token hash)
    prefix_key: u64,
    /// the prefix decision (fork / build / disable) has been made
    prefix_armed: bool,
    /// this job is the one computing the template: snapshot and register
    /// the session state when ingestion reaches prefix_len
    prefix_build: bool,
    sampler: SamplerStack,
    /// deterministic sampling-RNG seed (engine seed x params seed x
    /// session — never the shard or thread count)
    gen_seed: u64,
    rep_window: usize,
    submitted: Instant,
    busy_ns: f64,
    /// begin_gen has run (exactly once per request, after the prompt)
    started: bool,
    /// logits of the last ingested/stepped position, `[vocab]`
    logits: Vec<f32>,
    out: Vec<TokenId>,
    /// per-request streaming channel (see [`GenEvent`]); observational
    /// only — attaching one cannot change the sampled tokens
    stream: Option<Sender<GenEvent>>,
}

/// One slot of the worker's continuous-batching job queue. Jobs advance
/// one quantum per scheduling round and rotate to the back, so prompts
/// and generations of different sessions make interleaved progress.
enum Job {
    Prefill(PrefillJob),
    Generate(GenJob),
}

impl Job {
    fn session(&self) -> u64 {
        match self {
            Job::Prefill(j) => j.session,
            Job::Generate(j) => j.session,
        }
    }
}

/// Everything one shard worker mutates while scheduling. The worker
/// interleaves two sources of work: messages from the bounded queue
/// (processed immediately unless ordering forces a deferral) and the
/// job queue, whose front advances one quantum per scheduling round and
/// rotates to the back — continuous batching across decode chunks,
/// prompts, and self-feeding generations, so no path can starve another.
struct WorkerState {
    cfg: WorkerCfg,
    bank: ShardBank,
    /// round-robin queue of admitted prompts and generation requests;
    /// the front advances one quantum, then rotates behind the others,
    /// so concurrent long jobs share the shard fairly (per-session
    /// outputs stay deterministic — scheduling order never touches a
    /// session's own state sequence)
    jobs: VecDeque<Job>,
    /// messages that must wait to preserve ordering: anything for a
    /// session with a queued/in-flight prompt, anything behind a deferred
    /// message for its session, and global flushes behind everything.
    /// Re-dispatched in order whenever a job completes. Growth is bounded:
    /// the main loop stops draining the channel while `jobs` + `deferred`
    /// already hold queue_depth entries, so overflow stays in the bounded
    /// sync_channel and blocks the submitter (the backpressure contract).
    deferred: VecDeque<EngineMsg>,
    out_tx: Option<Sender<EngineOut>>,
    gen_tx: Sender<GenOut>,
    /// engine-wide fan-out segment queue (shared with every worker)
    pool: Arc<PrefillPool>,
    /// per-shard fan-out job counter (combined with the shard id into
    /// engine-unique job ids)
    fan_seq: u64,
    /// scratch/panel for running pooled segments — separate from the
    /// bank's own buffers, which stay private to its sessions
    helper_scratch: Scratch,
    helper_panel: Vec<f32>,
    gauge: Arc<AtomicUsize>,
    busy: Duration,
    prefill_busy: Duration,
    gen_busy: Duration,
    latency_ns: Vec<f64>,
    latency_i: usize,
    ttft_ns: Vec<f64>,
    ttft_i: usize,
    completion_ns: Vec<f64>,
    completion_i: usize,
    chunks: usize,
    tokens: usize,
    failed_chunks: usize,
    prefill_chunks: usize,
    prefill_tokens: usize,
    gen_tokens: usize,
    completions: usize,
    /// engine-wide copy-on-write shared-prefix template cache
    prefix: Arc<PrefixCache>,
    /// sessions admitted by forking a cached prefix template
    prefix_forks: usize,
    /// prompt tokens skipped by those forks
    prefix_fork_tokens: usize,
    /// engine-wide metrics registry + trace rings (histogram recording
    /// is always on; span capture is gated on [`obs::trace_enabled`])
    obs: Arc<EngineObs>,
}

impl WorkerState {
    /// Record a stage span ending *now* with duration `dur_us` into this
    /// shard's trace ring. One relaxed load when tracing is off; paths
    /// without a real request id (raw chunk/prompt submits) pass the
    /// session id as `req`.
    fn span(&self, stage: Stage, req: u64, session: u64, dur_us: f64) {
        if !obs::trace_enabled() {
            return;
        }
        let dur = dur_us as u64;
        let now = self.obs.trace.now_us();
        self.obs.trace.push(
            self.cfg.shard,
            Span {
                req,
                session,
                stage,
                shard: self.cfg.shard as u32,
                start_us: now.saturating_sub(dur),
                dur_us: dur,
            },
        );
    }

    /// Would processing a message for `session` now break per-session
    /// (or flush) ordering?
    fn session_blocked(&self, session: u64) -> bool {
        self.jobs.iter().any(|j| j.session() == session)
            || self.deferred.iter().any(|m| match m {
                EngineMsg::Chunk { session: s, .. }
                | EngineMsg::Prefill { session: s, .. }
                | EngineMsg::Generate { session: s, .. }
                | EngineMsg::Evict { session: s } => *s == session,
                EngineMsg::FlushAll => true,
            })
    }

    /// Process a message now if ordering allows, defer it otherwise.
    fn dispatch(&mut self, msg: EngineMsg) {
        let blocked = match &msg {
            EngineMsg::Chunk { session, .. }
            | EngineMsg::Prefill { session, .. }
            | EngineMsg::Generate { session, .. }
            | EngineMsg::Evict { session } => self.session_blocked(*session),
            EngineMsg::FlushAll => !self.jobs.is_empty() || !self.deferred.is_empty(),
        };
        if blocked {
            self.deferred.push_back(msg);
            return;
        }
        match msg {
            EngineMsg::Chunk { session, chunk, submitted } => {
                self.process_decode(session, chunk, submitted)
            }
            EngineMsg::Prefill { session, chunk, submitted } => {
                self.span(
                    Stage::Queue,
                    session,
                    session,
                    submitted.elapsed().as_nanos() as f64 / 1e3,
                );
                let total = chunk.keys.len() / self.cfg.hd;
                let out = self.out_tx.is_some().then(|| Vec::with_capacity(chunk.values.len()));
                // fan out only when the prompt spans at least two quanta —
                // a single-segment job has nothing to parallelize and
                // would pay a snapshot for no one
                let fan = (self.cfg.fanout && total >= 2 * self.cfg.prefill_quantum).then(|| {
                    let (tx, rx) = mpsc::channel();
                    self.fan_seq += 1;
                    FanState {
                        job: ((self.cfg.shard as u64) << 32) | self.fan_seq,
                        segs: 0,
                        tx,
                        rx,
                    }
                });
                self.jobs.push_back(Job::Prefill(PrefillJob {
                    session,
                    chunk: Arc::new(chunk),
                    done: 0,
                    total,
                    submitted,
                    busy_ns: 0.0,
                    out,
                    fan,
                }));
            }
            EngineMsg::Generate {
                session,
                req,
                prompt,
                prefix_len,
                prefix_key,
                params,
                stop,
                submitted,
                stream,
            } => {
                let queue_ns = submitted.elapsed().as_nanos() as f64;
                self.span(Stage::Queue, req, session, queue_ns / 1e3);
                // the sampling-RNG seed mixes engine seed, request seed
                // and session id — never the shard or thread count, so
                // generation is bit-identical across engine shapes. The
                // head slot (1 << 20) is outside any real head index, so
                // it cannot collide with a model seed.
                let gen_seed =
                    session_seed(self.cfg.seed ^ params.seed.rotate_left(17), session, 1 << 20);
                self.jobs.push_back(Job::Generate(GenJob {
                    session,
                    req,
                    queue_ns,
                    prefill_ns: 0.0,
                    prompt,
                    done: 0,
                    prefix_len,
                    prefix_key,
                    prefix_armed: false,
                    prefix_build: false,
                    gen_seed,
                    rep_window: params.rep_window,
                    sampler: SamplerStack::new(&params, stop),
                    submitted,
                    busy_ns: 0.0,
                    started: false,
                    logits: vec![0.0; self.cfg.vocab.max(1)],
                    out: Vec::new(),
                    stream,
                }));
            }
            EngineMsg::Evict { session } => self.bank.evict(session),
            EngineMsg::FlushAll => self.bank.flush_all(),
        }
    }

    fn process_decode(&mut self, session: u64, chunk: DecodeChunk, submitted: Instant) {
        let t0 = Instant::now();
        let processed = self.bank.process(session, &chunk);
        let el = t0.elapsed();
        self.busy += el;
        self.gauge.fetch_sub(1, Ordering::SeqCst);
        let (out, seq) = match processed {
            Ok(r) => r,
            Err(e) => {
                // a bad blob must cost one session, not the shard: drop
                // the chunk (the broken blob was consumed by the restore
                // attempt, so a re-arrival starts the session fresh) and
                // keep serving everyone else
                self.failed_chunks += 1;
                eprintln!("shard {}: dropping chunk for session {session}: {e}", self.cfg.shard);
                return;
            }
        };
        let lat = submitted.elapsed().as_nanos() as f64;
        ring_push(&mut self.latency_ns, self.latency_i, lat);
        self.latency_i += 1;
        let toks = chunk.keys.len() / self.cfg.hd;
        // the decode hot path's entire obs cost: one histogram record
        // (binary search + 3 relaxed adds), one counter add, and — only
        // at trace level — a span push into the shard-local ring
        self.obs.latency.record(lat);
        self.obs.tokens.add(toks as u64);
        self.span(Stage::Decode, session, session, el.as_nanos() as f64 / 1e3);
        self.chunks += 1;
        self.tokens += toks;
        if let Some(tx) = &self.out_tx {
            let _ = tx.send(EngineOut { session, seq, out });
        }
    }

    /// Advance the front job by one quantum, then rotate it behind the
    /// other jobs (continuous batching across sessions); on completion,
    /// account the request, emit its output, and re-dispatch deferred
    /// messages that were waiting on it.
    fn run_quantum(&mut self) {
        let Some(job) = self.jobs.pop_front() else {
            // unreachable by the deferral invariant (deferred non-empty
            // implies a queued job), but never risk a spin
            if !self.deferred.is_empty() {
                self.redispatch();
            }
            return;
        };
        match job {
            Job::Prefill(j) => self.advance_prefill(j),
            Job::Generate(j) => self.advance_generate(j),
        }
    }

    fn advance_prefill(&mut self, mut job: PrefillJob) {
        if job.fan.is_some() {
            self.advance_prefill_fanout(job);
            return;
        }
        let hd = self.cfg.hd;
        let take = self.cfg.prefill_quantum.min(job.total - job.done);
        let (a, b) = (job.done * hd, (job.done + take) * hd);
        let t0 = Instant::now();
        let res = self.bank.process_prefill(
            job.session,
            &job.chunk.queries[a..b],
            &job.chunk.keys[a..b],
            &job.chunk.values[a..b],
        );
        let el = t0.elapsed();
        self.busy += el;
        self.prefill_busy += el;
        job.busy_ns += el.as_nanos() as f64;
        self.span(Stage::Prefill, job.session, job.session, el.as_nanos() as f64 / 1e3);
        let failed = match res {
            Ok(out) => {
                if let Some(acc) = &mut job.out {
                    acc.extend_from_slice(&out);
                }
                job.done += take;
                false
            }
            Err(e) => {
                eprintln!(
                    "shard {}: dropping prompt for session {}: {e}",
                    self.cfg.shard, job.session
                );
                true
            }
        };
        if failed || job.done >= job.total {
            self.gauge.fetch_sub(1, Ordering::SeqCst);
            if failed {
                self.failed_chunks += 1;
            } else {
                let ttft = job.submitted.elapsed().as_nanos() as f64;
                ring_push(&mut self.ttft_ns, self.ttft_i, ttft);
                self.ttft_i += 1;
                self.obs.ttft.record(ttft);
                self.obs.tokens.add(job.total as u64);
                self.prefill_chunks += 1;
                self.prefill_tokens += job.total;
                self.tokens += job.total;
                let seq = self.bank.record_prefill(job.session, job.total, job.busy_ns);
                if let (Some(tx), Some(out)) = (&self.out_tx, job.out) {
                    let _ = tx.send(EngineOut { session: job.session, seq, out });
                }
            }
            self.redispatch();
        } else {
            self.jobs.push_back(Job::Prefill(job));
        }
    }

    /// One scheduling round of a fanned-out prompt: snapshot the session
    /// at the quantum boundary, publish the quantum's output replay to
    /// the pool as a [`SegmentTask`], and advance the real state through
    /// the writes-only path (bit-identical state at roughly the write
    /// half of the cost). On the last quantum, collect every segment's
    /// outputs — stealing back whatever the idle workers never claimed —
    /// merge them in segment order, and complete exactly like the serial
    /// path. Segmentation is always at `prefill_quantum` boundaries,
    /// independent of worker count, so the merged outputs are
    /// bit-identical to the serial path at any thread count, in Exact
    /// AND Chunkwise modes (chunkwise blocking restarts per quantum on
    /// both paths).
    fn advance_prefill_fanout(&mut self, mut job: PrefillJob) {
        let hd = self.cfg.hd;
        let take = self.cfg.prefill_quantum.min(job.total - job.done);
        let (a, b) = (job.done * hd, (job.done + take) * hd);
        let t0 = Instant::now();
        let res = match self.bank.snapshot_session(job.session) {
            Ok(blob) => {
                let fan = job.fan.as_mut().expect("fan-out job");
                self.pool.push(SegmentTask {
                    job: fan.job,
                    seg: fan.segs,
                    blob: Arc::new(blob),
                    chunk: Arc::clone(&job.chunk),
                    start: job.done,
                    end: job.done + take,
                    heads: self.cfg.heads,
                    hd,
                    mode: self.bank.prefill_mode(),
                    tx: fan.tx.clone(),
                });
                fan.segs += 1;
                self.bank.process_prefill_writes(
                    job.session,
                    &job.chunk.keys[a..b],
                    &job.chunk.values[a..b],
                )
            }
            Err(e) => Err(e),
        };
        let el = t0.elapsed();
        self.busy += el;
        self.prefill_busy += el;
        job.busy_ns += el.as_nanos() as f64;
        self.span(Stage::Prefill, job.session, job.session, el.as_nanos() as f64 / 1e3);
        match res {
            Ok(()) => job.done += take,
            Err(e) => {
                eprintln!(
                    "shard {}: dropping prompt for session {}: {e}",
                    self.cfg.shard, job.session
                );
                // dropping the job drops the result receiver; in-flight
                // segments deliver into a dead channel and are discarded
                self.gauge.fetch_sub(1, Ordering::SeqCst);
                self.failed_chunks += 1;
                self.redispatch();
                return;
            }
        }
        if job.done < job.total {
            self.jobs.push_back(Job::Prefill(job));
            return;
        }

        // every quantum written: merge the output segments in order
        let fan = job.fan.take().expect("fan-out job");
        let mut outs: Vec<Option<Vec<f32>>> = (0..fan.segs).map(|_| None).collect();
        let mut received = 0;
        while received < fan.segs {
            // steal back everything the idle workers never claimed —
            // the owner must finish even if every other shard is busy
            while let Some(task) = self.pool.steal(fan.job) {
                self.help_segment(task);
            }
            // collect one result; this blocks only while a helper is
            // mid-segment (the pool holds nothing of ours), and helpers
            // never block while holding a segment — so this terminates
            match fan.rx.recv() {
                Ok(r) => {
                    job.busy_ns += r.busy_ns;
                    outs[r.seg] = Some(r.out);
                    received += 1;
                }
                Err(_) => unreachable!("fan state holds a live sender"),
            }
        }
        self.gauge.fetch_sub(1, Ordering::SeqCst);
        let ttft = job.submitted.elapsed().as_nanos() as f64;
        ring_push(&mut self.ttft_ns, self.ttft_i, ttft);
        self.ttft_i += 1;
        self.obs.ttft.record(ttft);
        self.obs.tokens.add(job.total as u64);
        self.prefill_chunks += 1;
        self.prefill_tokens += job.total;
        self.tokens += job.total;
        let seq = self.bank.record_prefill(job.session, job.total, job.busy_ns);
        if let (Some(tx), Some(mut acc)) = (&self.out_tx, job.out) {
            for seg in outs.into_iter().flatten() {
                acc.extend_from_slice(&seg);
            }
            let _ = tx.send(EngineOut { session: job.session, seq, out: acc });
        }
        self.redispatch();
    }

    /// Run one pooled fan-out segment on this worker. The compute is
    /// accounted to THIS shard's busy/prefill time (it occupied this
    /// core); the owner additionally folds the reported nanoseconds into
    /// the prompt's own telemetry.
    fn help_segment(&mut self, task: SegmentTask) {
        let fan_job = task.job;
        let el = run_segment(task, &mut self.helper_scratch, &mut self.helper_panel);
        self.busy += el;
        self.prefill_busy += el;
        // fan-out segments carry the owner's job id, not a request id;
        // the span still shows which shard ran the segment and when
        self.span(Stage::Segment, fan_job, fan_job, el.as_nanos() as f64 / 1e3);
    }

    /// One scheduling round of a generation request: a prompt quantum
    /// while the prompt lasts, then up to `gen_quantum` sample/step
    /// iterations of the self-feeding loop. The session is reached
    /// through [`ShardBank::with_lm`], so LRU eviction between rounds is
    /// transparent — the history ring, RNG and produced count thaw from
    /// the `"lm"` blob and the stream continues bit-identically.
    fn advance_generate(&mut self, mut job: GenJob) {
        if !job.prefix_armed {
            job.prefix_armed = true;
            self.arm_prefix(&mut job);
        }
        if job.done < job.prompt.len() {
            let mut take = self.cfg.prefill_quantum.min(job.prompt.len() - job.done);
            if job.prefix_build && job.done < job.prefix_len {
                // never ingest across the prefix boundary: the template
                // snapshot must capture exactly prefix_len tokens, so a
                // fork lands bit-identically regardless of quantum size
                take = take.min(job.prefix_len - job.done);
            }
            let (a, b) = (job.done, job.done + take);
            let (prompt, logits) = (&job.prompt, &mut job.logits);
            let t0 = Instant::now();
            let res = self
                .bank
                .with_lm(job.session, |lm, sc| lm.prefill_tokens(&prompt[a..b], logits, sc));
            let el = t0.elapsed();
            self.busy += el;
            self.prefill_busy += el;
            job.busy_ns += el.as_nanos() as f64;
            job.prefill_ns += el.as_nanos() as f64;
            self.span(Stage::Prefill, job.req, job.session, el.as_nanos() as f64 / 1e3);
            if let Err(e) = res {
                let stream = job.stream.take();
                self.drop_generate(job.session, stream, &e);
                return;
            }
            job.done = b;
            if job.prefix_build && job.done == job.prefix_len {
                job.prefix_build = false;
                // freeze the stack/LM state as an immutable copy-on-write
                // template; later requests with the same key fork from it
                // instead of re-ingesting the prefix. A snapshot failure
                // only loses the cache entry, never the request.
                match self.bank.snapshot_session(job.session) {
                    Ok(blob) => self.prefix.register(job.prefix_key, blob),
                    Err(e) => eprintln!(
                        "shard {}: prefix template snapshot failed for session {}: {e}",
                        self.cfg.shard, job.session
                    ),
                }
            }
            if job.done < job.prompt.len() {
                self.jobs.push_back(Job::Generate(job));
                return;
            }
            // prompt fully ingested — fall through and sample this same
            // round, so TTFT means time to the first sampled token
        }

        let GenJob { session, sampler, started, logits, out, gen_seed, rep_window, stream, .. } =
            &mut job;
        let quantum = self.cfg.gen_quantum;
        let first_round = out.is_empty();
        let mut finished = false;
        let t0 = Instant::now();
        let res = self.bank.with_lm(*session, |lm, scratch| {
            if !*started {
                // exactly once per request — a mid-generation restore
                // thaws the core instead of re-arming it
                lm.begin_gen(*gen_seed, *rep_window);
                *started = true;
            }
            for _ in 0..quantum {
                let tok = {
                    let g = lm.gen_mut().expect("generation armed");
                    // cap met before sampling (max_new 0 emits nothing)
                    if sampler.exhausted(g.produced) {
                        finished = true;
                        break;
                    }
                    let (hist, rng) = g.split();
                    sampler.next_token(hist, logits, rng)
                };
                let g = lm.gen_mut().expect("generation armed");
                g.push(tok);
                let produced = g.produced;
                out.push(tok);
                if let Some(tx) = stream.as_ref() {
                    // a dead receiver (client hung up mid-stream) just
                    // stops the delivery; the generation itself finishes
                    // so the session state stays on its deterministic path
                    let _ = tx.send(GenEvent::Token(tok));
                }
                if sampler.should_stop(tok, produced) {
                    finished = true;
                    break;
                }
                lm.step_token(tok, logits, scratch);
            }
        });
        let el = t0.elapsed();
        self.busy += el;
        self.gen_busy += el;
        job.busy_ns += el.as_nanos() as f64;
        self.span(Stage::Sample, job.req, job.session, el.as_nanos() as f64 / 1e3);
        if let Err(e) = res {
            let stream = job.stream.take();
            self.drop_generate(job.session, stream, &e);
            return;
        }
        if first_round && !job.out.is_empty() {
            let ttft = job.submitted.elapsed().as_nanos() as f64;
            ring_push(&mut self.ttft_ns, self.ttft_i, ttft);
            self.ttft_i += 1;
            self.obs.ttft.record(ttft);
        }
        if finished {
            self.gauge.fetch_sub(1, Ordering::SeqCst);
            self.completions += 1;
            self.gen_tokens += job.out.len();
            self.prefill_tokens += job.prompt.len();
            self.tokens += job.prompt.len() + job.out.len();
            let done_ns = job.submitted.elapsed().as_nanos() as f64;
            ring_push(&mut self.completion_ns, self.completion_i, done_ns);
            self.completion_i += 1;
            self.obs.completion.record(done_ns);
            self.obs.completions.inc();
            self.obs.tokens.add((job.prompt.len() + job.out.len()) as u64);
            // wall-clock split echoed on the completion: queue until
            // dispatch, busy prefill, busy decode/sampling, total. Busy
            // shares are measured on this thread and disjoint from the
            // queue wait, so (floored to integer µs) the parts never
            // exceed the total.
            let timing = Timing {
                queue_us: (job.queue_ns / 1e3) as u64,
                prefill_us: (job.prefill_ns / 1e3) as u64,
                decode_us: ((job.busy_ns - job.prefill_ns).max(0.0) / 1e3) as u64,
                total_us: (done_ns / 1e3) as u64,
            };
            let seq = self.bank.record_generate(job.session, job.prompt.len(), job.out.len());
            // drop the sampler core so the session's state bytes and any
            // later eviction blob shrink back to mixer state
            let _ = self.bank.with_lm(job.session, |lm, _| lm.end_gen());
            if let Some(tx) = job.stream.take() {
                let _ = tx.send(GenEvent::Done { seq, tokens: job.out.clone(), timing });
            }
            let _ = self.gen_tx.send(GenOut { session: job.session, seq, tokens: job.out });
            self.redispatch();
        } else {
            self.jobs.push_back(Job::Generate(job));
        }
    }

    /// Decide, once per generate job, how the shared-prefix cache applies:
    /// fork from a cached template (skip ingesting the prefix), build the
    /// template (this job snapshots at the boundary), or disable. Runs
    /// before the first prompt quantum. Every branch preserves the
    /// determinism contract: a fork restores the bit-exact state the
    /// builder had at prefix_len, and the LM factory seeds weights
    /// identically for every session, so cache hit/miss timing changes
    /// only the work done, never the sampled tokens.
    fn arm_prefix(&mut self, job: &mut GenJob) {
        if job.prefix_len == 0 {
            return;
        }
        // a fork needs at least one non-prefix prompt token to compute
        // fresh logits from (logits are job-local, not in the template);
        // a session with existing state must keep its own history.
        // Oversized values are ignored, not errors, at this level — the
        // HTTP edge rejects them loudly.
        if !self.prefix.enabled()
            || job.prefix_len >= job.prompt.len()
            || self.bank.has_state(job.session)
        {
            job.prefix_len = 0;
            return;
        }
        match self.prefix.lookup(job.prefix_key) {
            Some(blob) => {
                let t0 = Instant::now();
                match self.bank.admit_from_blob(job.session, &blob) {
                    Ok(()) => {
                        // the restore is the fork's prefill cost: charge it
                        // to the job's timing split (the shard-level busy
                        // accounting is unchanged) and span it
                        let ns = t0.elapsed().as_nanos() as f64;
                        job.busy_ns += ns;
                        job.prefill_ns += ns;
                        self.span(Stage::PrefixFork, job.req, job.session, ns / 1e3);
                        job.done = job.prefix_len;
                        self.prefix_forks += 1;
                        self.prefix_fork_tokens += job.prefix_len;
                    }
                    Err(e) => {
                        // fail open: ingest the whole prompt locally
                        eprintln!(
                            "shard {}: prefix fork failed for session {}: {e}",
                            self.cfg.shard, job.session
                        );
                        job.prefix_len = 0;
                    }
                }
            }
            None => job.prefix_build = true,
        }
    }

    /// A generate request that cannot proceed (non-LM engine, corrupt
    /// restore) costs that request, not the shard. A streaming client
    /// learns why through a terminal [`GenEvent::Failed`].
    fn drop_generate(&mut self, session: u64, stream: Option<Sender<GenEvent>>, e: &anyhow::Error) {
        if let Some(tx) = stream {
            let _ = tx.send(GenEvent::Failed(format!("{e:#}")));
        }
        self.gauge.fetch_sub(1, Ordering::SeqCst);
        self.failed_chunks += 1;
        eprintln!(
            "shard {}: dropping generate request for session {session}: {e}",
            self.cfg.shard
        );
        self.redispatch();
    }

    /// Re-dispatch every deferred message in order; messages still blocked
    /// (e.g. behind the next queued prompt) re-defer, preserving order.
    fn redispatch(&mut self) {
        let pending: Vec<EngineMsg> = self.deferred.drain(..).collect();
        for msg in pending {
            self.dispatch(msg);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn shard_worker(
    cfg: WorkerCfg,
    factory: impl Fn(u64, usize) -> Box<dyn SeqMixer> + Send + 'static,
    rx: Receiver<EngineMsg>,
    out_tx: Option<Sender<EngineOut>>,
    gen_tx: Sender<GenOut>,
    gauge: Arc<AtomicUsize>,
    high: Arc<AtomicUsize>,
    pool: Arc<PrefillPool>,
    tier: Arc<TierStats>,
    prefix: Arc<PrefixCache>,
    obs: Arc<EngineObs>,
) -> (ShardReport, Vec<(u64, StreamStats)>) {
    let mut bank = ShardBank::new(cfg.heads, cfg.max_resident, factory);
    bank.set_prefill_mode(cfg.prefill_mode);
    if cfg.spill_dir.is_some() {
        bank.configure_store(StoreConfig {
            spill_dir: cfg.spill_dir.clone(),
            ram_budget: cfg.ram_blob_budget,
            shared: Some(tier),
        });
    }
    let mut st = WorkerState {
        cfg,
        bank,
        jobs: VecDeque::new(),
        deferred: VecDeque::new(),
        out_tx,
        gen_tx,
        pool,
        fan_seq: 0,
        helper_scratch: Scratch::new(),
        helper_panel: Vec::new(),
        gauge,
        busy: Duration::ZERO,
        prefill_busy: Duration::ZERO,
        gen_busy: Duration::ZERO,
        latency_ns: Vec::new(),
        latency_i: 0,
        ttft_ns: Vec::new(),
        ttft_i: 0,
        completion_ns: Vec::new(),
        completion_i: 0,
        chunks: 0,
        tokens: 0,
        failed_chunks: 0,
        prefill_chunks: 0,
        prefill_tokens: 0,
        gen_tokens: 0,
        completions: 0,
        prefix,
        prefix_forks: 0,
        prefix_fork_tokens: 0,
        obs,
    };
    let mut open = true;
    loop {
        if st.jobs.is_empty() && st.deferred.is_empty() {
            if !open {
                // our channel closed and our own work drained: lend the
                // thread to any still-unclaimed fan-out segments before
                // exiting (owners steal back whatever is left after this)
                while let Some(task) = st.pool.pop() {
                    st.help_segment(task);
                }
                break;
            }
            if st.cfg.fanout {
                // idle with fan-out armed: alternate between helping
                // with pooled segments and polling for traffic. The
                // short timeout bounds how stale an idle worker's view
                // of the pool can get; it costs one wakeup per 500us
                // only while a shard is fully idle.
                if let Some(task) = st.pool.pop() {
                    st.help_segment(task);
                } else {
                    match rx.recv_timeout(Duration::from_micros(500)) {
                        Ok(msg) => st.dispatch(msg),
                        Err(mpsc::RecvTimeoutError::Timeout) => continue,
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            open = false;
                            continue;
                        }
                    }
                }
            } else {
                // fully idle: block for the next message
                match rx.recv() {
                    Ok(msg) => st.dispatch(msg),
                    Err(_) => break,
                }
            }
        }
        if open {
            // opportunistic bounded drain between quanta: decode chunks
            // interleave with the in-flight prompt, but at most
            // queue_depth of them per quantum so a decode flood cannot
            // starve prefill progress either. The drain also stops while
            // the worker already holds queue_depth queued prompts +
            // deferred messages — beyond that, messages stay in the
            // bounded sync_channel where the submitter blocks, so the
            // backpressure contract survives deferral (the in-worker
            // buffers cannot grow past ~2x queue_depth, which also keeps
            // the O(jobs + deferred) ordering scans effectively O(1))
            let mut budget = st.cfg.queue_depth.max(1);
            while budget > 0 && st.jobs.len() + st.deferred.len() < st.cfg.queue_depth.max(1) {
                match rx.try_recv() {
                    Ok(msg) => {
                        st.dispatch(msg);
                        budget -= 1;
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
        }
        st.run_quantum();
    }
    // park the writeback thread cleanly so disk gauges are final before
    // the report reads them
    st.bank.sync_store();
    let report = ShardReport {
        shard: st.cfg.shard,
        sessions: st.bank.sessions(),
        resident_sessions: st.bank.resident_sessions(),
        evicted_sessions: st.bank.evicted_sessions(),
        chunks: st.chunks,
        tokens: st.tokens,
        busy: st.busy,
        prefill_busy: st.prefill_busy,
        gen_busy: st.gen_busy,
        prefill_chunks: st.prefill_chunks,
        prefill_tokens: st.prefill_tokens,
        gen_tokens: st.gen_tokens,
        completions: st.completions,
        completion_ns: st.completion_ns,
        ttft_ns: st.ttft_ns,
        evictions: st.bank.evictions,
        restores: st.bank.restores,
        spills: st.bank.spills(),
        disk_restores: st.bank.disk_restores(),
        disk_sessions: st.bank.disk_sessions(),
        disk_bytes: st.bank.disk_bytes(),
        prefix_forks: st.prefix_forks,
        prefix_fork_tokens: st.prefix_fork_tokens,
        max_queue: high.load(Ordering::SeqCst),
        failed_chunks: st.failed_chunks,
        resident_bytes: st.bank.resident_bytes(),
        snapshot_bytes: st.bank.snapshot_bytes(),
        latency_ns: st.latency_ns,
        layers: st.bank.layer_stats(),
    };
    (report, st.bank.take_stats())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn chunk_of(rng: &mut Rng, len: usize, hd: usize) -> DecodeChunk {
        DecodeChunk {
            queries: (0..len * hd).map(|_| rng.normal() as f32).collect(),
            keys: (0..len * hd).map(|_| rng.normal() as f32).collect(),
            values: (0..len * hd).map(|_| rng.normal() as f32).collect(),
        }
    }

    #[test]
    fn shard_hash_covers_and_is_stable() {
        let mut seen = vec![false; 4];
        for id in 0..256u64 {
            let s = shard_of(id, 4);
            assert!(s < 4);
            assert_eq!(s, shard_of(id, 4), "stable");
            seen[s] = true;
        }
        assert!(seen.iter().all(|&s| s), "all shards reachable");
        assert_eq!(shard_of(1234, 1), 0);
    }

    #[test]
    fn session_seed_depends_on_session_and_head_only() {
        assert_eq!(session_seed(1, 2, 3), session_seed(1, 2, 3));
        assert_ne!(session_seed(1, 2, 3), session_seed(1, 2, 4));
        assert_ne!(session_seed(1, 2, 3), session_seed(1, 3, 3));
        assert_ne!(session_seed(0, 2, 3), session_seed(1, 2, 3));
    }

    #[test]
    fn engine_counts_tokens_and_joins_cleanly() {
        let mut cfg = EngineConfig::new(MixerKind::Ovq { n_max: 32 }, 2, 8, 16);
        cfg.threads = 2;
        let engine = DecodeEngine::start(cfg);
        let hd = engine.heads() * engine.d_head();
        let mut rng = Rng::new(11);
        for session in 0..6u64 {
            for _ in 0..3 {
                engine.submit(session, chunk_of(&mut rng, 16, hd));
            }
        }
        engine.flush_all();
        let r = engine.finish();
        assert_eq!(r.tokens, 6 * 3 * 16);
        assert_eq!(r.chunks, 18);
        assert_eq!(r.sessions.len(), 6);
        for (_, st) in &r.sessions {
            assert_eq!(st.tokens, 48);
            assert_eq!(st.chunks, 3);
        }
        assert_eq!(r.shards.len(), 2);
        assert!(r.state_bytes() > 0);
        assert!(r.latency_us(99.0) >= r.latency_us(50.0) * 0.5);
    }

    #[test]
    fn engine_serves_model_stacks_with_per_layer_split() {
        // full 3-layer stacks as ordinary sessions: correct accounting,
        // one telemetry row per layer, state split covering the total
        let stack = StackConfig::uniform(3, 8, 16, 2, 4, 8, MixerKind::Ovq { n_max: 16 });
        let mut cfg = EngineConfig::for_stack(stack);
        cfg.threads = 2;
        let engine = DecodeEngine::start(cfg);
        let hd = engine.heads() * engine.d_head();
        assert_eq!(hd, 8, "stack engines pack one d_model row per token");
        let mut rng = Rng::new(13);
        for session in 0..4u64 {
            for _ in 0..3 {
                engine.submit(session, chunk_of(&mut rng, 8, hd));
            }
        }
        engine.flush_all();
        let r = engine.finish();
        assert_eq!(r.tokens, 4 * 3 * 8);
        assert_eq!(r.sessions.len(), 4);
        let layers = r.layer_split();
        assert_eq!(layers.len(), 3, "one merged telemetry row per layer");
        assert!(layers.iter().all(|l| l.kind == "ovq"));
        assert!(layers.iter().all(|l| l.tokens == 4 * 24), "every layer sees every token");
        assert_eq!(
            layers.iter().map(|l| l.state_bytes).sum::<usize>(),
            r.state_bytes(),
            "per-layer split must cover the engine's total state"
        );
        assert!(layers.iter().all(|l| l.busy_ns > 0.0));
    }

    #[test]
    fn engine_generates_greedy_completions_with_three_way_occupancy() {
        let lm = LmConfig::new(
            24,
            StackConfig::uniform(2, 8, 16, 2, 4, 8, MixerKind::Ovq { n_max: 16 }),
        );
        let mut cfg = EngineConfig::for_lm(lm);
        cfg.threads = 2;
        cfg.gen_quantum = 4;
        let engine = DecodeEngine::start(cfg);
        assert_eq!(engine.lm_vocab(), Some(24));
        for s in 0..4u64 {
            engine.submit_generate(
                s,
                vec![1, 2, 3, 4, 5],
                SamplingParams::greedy(),
                StopCriteria::max_new(12),
            );
        }
        let r = engine.finish();
        assert_eq!(r.completions(), 4);
        assert_eq!(r.gen_tokens(), 4 * 12);
        assert_eq!(r.generations.len(), 4);
        for g in &r.generations {
            assert_eq!(g.tokens.len(), 12, "session {} under-generated", g.session);
            assert!(g.tokens.iter().all(|&t| (t as usize) < 24));
            assert_eq!(g.seq, 1);
        }
        assert_eq!(r.tokens, 4 * (5 + 12), "prompt + sampled tokens both count");
        assert_eq!(r.prefill_tokens(), 4 * 5);
        assert!(r.shards.iter().any(|s| s.gen_busy > Duration::ZERO));
        assert!(r.completion_us(50.0) > 0.0);
        // the sampler core was dropped at completion: session state is
        // back to pure mixer state, so no blob carries generation bytes
        let (du, pu, gu) = r.occupancy()[0];
        assert!(du >= 0.0 && pu >= 0.0 && gu >= 0.0);
    }

    #[test]
    fn max_new_zero_completes_with_no_sampled_tokens() {
        let lm = LmConfig::new(24, StackConfig::uniform(1, 8, 16, 2, 4, 8, MixerKind::Gdn));
        let engine = DecodeEngine::start(EngineConfig::for_lm(lm));
        let stop = StopCriteria::max_new(0);
        engine.submit_generate(1, vec![1, 2, 3], SamplingParams::greedy(), stop);
        let r = engine.finish();
        assert_eq!(r.completions(), 1);
        assert_eq!(r.gen_tokens(), 0, "max_new 0 must sample nothing");
        assert!(r.generations[0].tokens.is_empty());
        assert_eq!(r.tokens, 3, "the prompt is still ingested and counted");
    }

    #[test]
    fn prefix_forked_generations_match_plain_ones_bit_exactly() {
        // six requests sharing a 9-token system prefix: the first builds
        // the copy-on-write template, the other five fork from it — and
        // every sampled token must match the no-prefix-hint run
        let mk = || {
            let lm = LmConfig::new(
                24,
                StackConfig::uniform(2, 8, 16, 2, 4, 8, MixerKind::Ovq { n_max: 16 }),
            );
            EngineConfig::for_lm(lm)
        };
        let prefix: Vec<TokenId> = (0..9u32).map(|i| (i * 5 + 3) % 24).collect();
        let prompt_of = |s: u64| {
            let mut p = prefix.clone();
            p.extend([s as TokenId % 24, (s as TokenId + 7) % 24]);
            p
        };
        let plain = {
            let engine = DecodeEngine::start(mk());
            for s in 0..6u64 {
                engine.submit_generate(
                    s,
                    prompt_of(s),
                    SamplingParams::greedy(),
                    StopCriteria::max_new(10),
                );
            }
            let r = engine.finish();
            assert_eq!(r.prefix_forks(), 0, "no hints, no forks");
            r.generations.iter().map(|g| (g.session, g.tokens.clone())).collect::<Vec<_>>()
        };
        let engine = DecodeEngine::start(mk());
        for s in 0..6u64 {
            engine.submit_generate_prefixed(
                s,
                prompt_of(s),
                prefix.len(),
                None,
                SamplingParams::greedy(),
                StopCriteria::max_new(10),
            );
        }
        let r = engine.finish();
        let forked: Vec<_> =
            r.generations.iter().map(|g| (g.session, g.tokens.clone())).collect();
        assert_eq!(plain, forked, "prefix forking must not change sampled tokens");
        // single shard, round-robin quanta: the first job registers the
        // template at the prefix boundary before any other job arms
        assert_eq!(r.prefix_forks(), 5);
        assert_eq!(r.prefix_fork_tokens(), 5 * prefix.len());
        assert_eq!(r.prefix.hits, 5);
        assert_eq!(r.prefix.misses, 1);
        assert!(r.prefix.bytes > 0);
        assert_eq!(r.prefix.entries, 1);
    }

    #[test]
    fn prefix_fork_disabled_when_prefix_covers_the_whole_prompt() {
        // a fork needs one non-prefix token for fresh logits; an
        // oversized prefix_len silently degrades to a plain request
        let lm = LmConfig::new(24, StackConfig::uniform(1, 8, 16, 2, 4, 8, MixerKind::Gdn));
        let engine = DecodeEngine::start(EngineConfig::for_lm(lm));
        for s in 0..2u64 {
            engine.submit_generate_prefixed(
                s,
                vec![1, 2, 3],
                3,
                None,
                SamplingParams::greedy(),
                StopCriteria::max_new(4),
            );
        }
        let r = engine.finish();
        assert_eq!(r.completions(), 2);
        assert_eq!(r.prefix_forks(), 0);
        assert_eq!(r.prefix.hits + r.prefix.misses, 0, "cache never consulted");
        for g in &r.generations {
            assert_eq!(g.tokens.len(), 4);
        }
    }

    #[test]
    fn spilled_engine_matches_ram_only_engine_bit_exactly() {
        use crate::ovqcore::store::TempDir;
        // max_resident=1 with a zero RAM blob budget churns every session
        // through the disk tier; outputs must match the pure-RAM engine
        let run = |spill: Option<&TempDir>| {
            let lm = LmConfig::new(24, StackConfig::uniform(1, 8, 16, 2, 4, 8, MixerKind::Gdn));
            let mut cfg = EngineConfig::for_lm(lm);
            cfg.max_resident = 1;
            if let Some(td) = spill {
                cfg.spill_dir = Some(td.path().to_path_buf());
                cfg.ram_blob_budget = 0;
            }
            let engine = DecodeEngine::start(cfg);
            for round in 0..3u32 {
                for s in 0..3u64 {
                    engine.submit_generate(
                        s,
                        vec![(round + s as TokenId) % 24, 5, 9],
                        SamplingParams::greedy(),
                        StopCriteria::max_new(6),
                    );
                }
                // let the async writebacks land between rounds, so the
                // next round's restores deterministically hit the disk
                // tier instead of racing the still-pending RAM copy
                // (either way the outputs are identical — this only
                // pins the disk_restores counter assertion below)
                thread::sleep(Duration::from_millis(150));
            }
            engine.finish()
        };
        let td = TempDir::new("engine-spill");
        let ram = run(None);
        let disk = run(Some(&td));
        let key = |r: &EngineReport| {
            r.generations
                .iter()
                .map(|g| (g.session, g.seq, g.tokens.clone()))
                .collect::<Vec<_>>()
        };
        assert_eq!(key(&ram), key(&disk), "disk tier must be invisible to outputs");
        assert_eq!(disk.completions(), 9);
        assert!(disk.spills() >= 1, "zero budget must spill");
        assert!(disk.disk_restores() >= 1, "churn must restore from disk");
        assert_eq!(ram.spills(), 0);
        assert_eq!(ram.disk_restores(), 0);
    }

    #[test]
    fn generate_on_a_non_lm_engine_fails_the_request_not_the_shard() {
        let mut cfg = EngineConfig::new(MixerKind::Gdn, 1, 4, 8);
        cfg.threads = 1;
        let engine = DecodeEngine::start(cfg);
        engine.submit_generate(1, vec![0, 1], SamplingParams::greedy(), StopCriteria::max_new(4));
        // the shard survives and keeps serving decode traffic
        let mut rng = Rng::new(5);
        engine.submit(2, chunk_of(&mut rng, 8, 4));
        let r = engine.finish();
        assert_eq!(r.failed_chunks(), 1);
        assert_eq!(r.completions(), 0);
        assert_eq!(r.chunks, 1);
    }

    #[test]
    fn fanned_out_prefill_matches_serial_bit_exactly() {
        // one long OVQ prompt through a 4-thread fan-out engine must
        // reproduce the 1-thread serial outputs to the bit, and decode
        // submitted behind the prompt must still be ordered after it
        let (heads, d, total) = (2usize, 8usize, 600usize);
        let hd = heads * d;
        let mut rng = Rng::new(21);
        let prompt = chunk_of(&mut rng, total, hd);
        let tail = chunk_of(&mut rng, 8, hd);
        let run = |threads: usize, fanout: bool| {
            let mut cfg = EngineConfig::new(MixerKind::Ovq { n_max: 32 }, heads, d, 16);
            cfg.threads = threads;
            cfg.prefill_fanout = fanout;
            cfg.prefill_quantum = 64;
            cfg.collect_outputs = true;
            let engine = DecodeEngine::start(cfg);
            engine.submit_prefill(
                7,
                DecodeChunk {
                    queries: prompt.queries.clone(),
                    keys: prompt.keys.clone(),
                    values: prompt.values.clone(),
                },
            );
            engine.submit(
                7,
                DecodeChunk {
                    queries: tail.queries.clone(),
                    keys: tail.keys.clone(),
                    values: tail.values.clone(),
                },
            );
            let r = engine.finish();
            let mut outs: Vec<(usize, Vec<f32>)> =
                r.outputs.into_iter().map(|o| (o.seq, o.out)).collect();
            outs.sort_by_key(|&(seq, _)| seq);
            outs
        };
        let serial = run(1, false);
        let fanned = run(4, true);
        assert_eq!(serial.len(), 2);
        assert_eq!(fanned.len(), 2);
        for ((s_seq, s_out), (f_seq, f_out)) in serial.iter().zip(&fanned) {
            assert_eq!(s_seq, f_seq);
            assert_eq!(s_out.len(), f_out.len());
            assert!(
                s_out.iter().zip(f_out).all(|(a, b)| a.to_bits() == b.to_bits()),
                "fan-out diverged from the serial path"
            );
        }
    }

    #[test]
    fn streamed_generate_matches_the_completion_channel() {
        // a per-request stream must deliver exactly the GenOut tokens, in
        // order, Token-by-Token, with a terminal Done carrying the same
        // vector — and attaching it must not change what is sampled
        let lm = LmConfig::new(
            24,
            StackConfig::uniform(2, 8, 16, 2, 4, 8, MixerKind::Ovq { n_max: 16 }),
        );
        let mut cfg = EngineConfig::for_lm(lm);
        cfg.threads = 2;
        cfg.gen_quantum = 3;
        let engine = DecodeEngine::start(cfg);
        let handle = engine.handle();
        let (tx, rx) = mpsc::channel();
        handle.submit_generate_streamed(
            5,
            vec![1, 2, 3],
            SamplingParams::sampled(0xF00D),
            StopCriteria::max_new(10),
            tx,
        );
        // an identical unstreamed request on a different session with the
        // same params seed: same request-level determinism contract
        handle.submit_generate(
            5 + 64, // maps to whichever shard; independence is the point
            vec![1, 2, 3],
            SamplingParams::sampled(0xF00D),
            StopCriteria::max_new(10),
        );
        let events: Vec<GenEvent> = rx.iter().collect();
        drop(handle);
        let r = engine.finish();
        let done = events.last().expect("stream must end with a terminal event");
        let streamed: Vec<TokenId> = events
            .iter()
            .filter_map(|e| match e {
                GenEvent::Token(t) => Some(*t),
                _ => None,
            })
            .collect();
        match done {
            GenEvent::Done { seq, tokens, timing } => {
                assert_eq!(*seq, 1);
                assert_eq!(tokens, &streamed, "Done must replay the Token events");
                // the timing split is wall-clock/busy measured on one
                // thread: parts (floored to µs) can never exceed total
                assert!(
                    timing.queue_us + timing.prefill_us + timing.decode_us <= timing.total_us,
                    "timing parts {timing:?} exceed the total"
                );
            }
            other => panic!("expected Done, got {other:?}"),
        }
        let gen_out =
            r.generations.iter().find(|g| g.session == 5).expect("GenOut still emitted");
        assert_eq!(gen_out.tokens, streamed, "stream and completion channel must agree");
        assert_eq!(r.completions(), 2);
    }

    #[test]
    fn trace_spans_cover_the_generate_pipeline_and_reports_read_histograms() {
        let _guard = crate::util::obs::test_level_lock();
        let prev = obs::level();
        obs::set_level(obs::ObsLevel::Trace);
        let lm = LmConfig::new(24, StackConfig::uniform(1, 8, 16, 2, 4, 8, MixerKind::Gdn));
        let engine = DecodeEngine::start(EngineConfig::for_lm(lm));
        let hub = Arc::clone(engine.handle().obs());
        engine.submit_generate(
            3,
            vec![1, 2, 3],
            SamplingParams::greedy(),
            StopCriteria::max_new(6),
        );
        let r = engine.finish();
        obs::set_level(prev);
        assert_eq!(r.completions(), 1);
        // the report percentiles are views over the registry histograms
        assert_eq!(r.completion_hist.count, 1);
        assert!(r.ttft_hist.count >= 1);
        assert!(r.completion_us(50.0) > 0.0);
        assert!(r.completion_us(99.0) >= r.completion_us(50.0));
        // every pipeline stage of the request left a span, all carrying
        // the request id minted at submit, ordered by start time
        let spans = hub.trace().dump(usize::MAX);
        let stages: Vec<&str> = spans.iter().map(|s| s.stage.as_str()).collect();
        for want in ["queue", "prefill", "sample"] {
            assert!(stages.contains(&want), "missing {want} span in {stages:?}");
        }
        let req = spans.iter().find(|s| s.stage == Stage::Queue).expect("queue span").req;
        assert!(req > 0);
        assert!(spans.iter().filter(|s| s.session == 3).all(|s| s.req == req));
        for w in spans.windows(2) {
            assert!(w[0].start_us <= w[1].start_us);
        }
        // the registry renders all of it as Prometheus text
        let text = hub.registry().render_prometheus();
        assert!(text.contains("# TYPE ovq_completion_ns histogram"));
        assert!(text.contains("ovq_completions_total 1"));
        assert!(text.contains("ovq_queue_depth{shard=\"0\"} 0"));
    }

    #[test]
    fn failed_streamed_generate_reports_through_the_stream() {
        // generate against a non-LM engine: the request dies, the stream
        // learns why, the shard keeps serving
        let engine = DecodeEngine::start(EngineConfig::new(MixerKind::Gdn, 1, 4, 8));
        let handle = engine.handle();
        let (tx, rx) = mpsc::channel();
        handle
            .try_submit_generate(
                1,
                vec![0, 1],
                SamplingParams::greedy(),
                StopCriteria::max_new(4),
                Some(tx),
            )
            .expect("empty queue must admit");
        let events: Vec<GenEvent> = rx.iter().collect();
        assert_eq!(events.len(), 1);
        assert!(matches!(events[0], GenEvent::Failed(_)));
        drop(handle);
        let r = engine.finish();
        assert_eq!(r.failed_chunks(), 1);
    }

    #[test]
    fn try_submit_generate_sheds_on_a_full_queue() {
        // a 1-thread LM engine with a depth-1 queue: hold the worker busy
        // with a long generation, then try_submit until the bounded queue
        // refuses — the call must return QueueFull, never block. The
        // refused request costs nothing (gauge restored), and accepted
        // requests all complete after the jam clears.
        let lm = LmConfig::new(
            24,
            StackConfig::uniform(1, 8, 16, 2, 4, 8, MixerKind::Ovq { n_max: 16 }),
        );
        let mut cfg = EngineConfig::for_lm(lm);
        cfg.threads = 1;
        cfg.queue_depth = 1;
        let engine = DecodeEngine::start(cfg);
        let handle = engine.handle();
        let mut admitted: Vec<u64> = Vec::new();
        let mut shed = 0usize;
        for session in 0..32u64 {
            let r = handle.try_submit_generate(
                session,
                vec![1, 2, 3, 4, 5, 6, 7, 8],
                SamplingParams::greedy(),
                StopCriteria::max_new(32),
                None,
            );
            match r {
                Ok(()) => admitted.push(session),
                Err(QueueFull) => shed += 1,
            }
        }
        assert!(shed > 0, "32 instant submits must overrun a depth-1 queue");
        assert!(!admitted.is_empty());
        drop(handle);
        let r = engine.finish();
        assert_eq!(r.completions(), admitted.len(), "every admitted request completes");
        assert_eq!(r.failed_chunks(), 0, "shedding is not a failure");
    }

    #[test]
    fn outputs_are_collected_and_sequenced_when_asked() {
        let mut cfg = EngineConfig::new(MixerKind::Gdn, 1, 4, 8);
        cfg.threads = 2;
        cfg.collect_outputs = true;
        let engine = DecodeEngine::start(cfg);
        let mut rng = Rng::new(12);
        for session in [3u64, 5] {
            for _ in 0..4 {
                engine.submit(session, chunk_of(&mut rng, 8, 4));
            }
        }
        let r = engine.finish();
        assert_eq!(r.outputs.len(), 8);
        for session in [3u64, 5] {
            let mut seqs: Vec<usize> =
                r.outputs.iter().filter(|o| o.session == session).map(|o| o.seq).collect();
            seqs.sort_unstable();
            assert_eq!(seqs, vec![1, 2, 3, 4]);
        }
    }
}
