//! Length-sweep evaluator: runs every eval_* program of a model on freshly
//! generated task batches and aggregates accuracy / loss, including the
//! paper's test-time dictionary scaling (eval_{T}_N{n} programs) and the
//! per-position curves for Fig. 5 / Fig. 6.

use anyhow::Result;

use crate::data::batch::Batch;
use crate::data::{by_name, icl};
use crate::ovqcore::memstate::{MixerGeom, MixerKind};
use crate::runtime::Model;
use crate::util::rng::Rng;
use crate::util::stats;

use super::metrics::Accuracy;

/// One point of the sweep: an eval program evaluated on n batches.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub program: String,
    pub seq: usize,
    pub n_dict: Option<usize>,
    pub loss: f64,
    pub accuracy: f64,
    pub n_scored: f64,
    /// decode-time OVQ mixer state at this (N, T), bytes per layer —
    /// computed through the unified memstate/SeqMixer accounting. Only
    /// populated for dictionary-scaled eval programs (`eval_{T}_N{n}`),
    /// which are the paper's OVQ test-time dictionary-scaling sweep; the
    /// column is labeled accordingly in [`print_sweep`].
    pub decode_state_bytes: Option<usize>,
}

/// Geometry of the model's sequence-mixing heads, from the manifest.
/// Prefers the explicit `d_head` config key (the projections may be
/// rectangular); falls back to dim/heads.
pub fn mixer_geom(model: &Model<'_>) -> MixerGeom {
    let heads = model.manifest.cfg_usize("heads", 1).max(1);
    let dim = model.manifest.cfg_usize("dim", 64);
    let d_head = model.manifest.cfg_usize("d_head", (dim / heads).max(1));
    MixerGeom { heads, d_head }
}

/// Filter predicate over program names; None = all eval programs.
pub type ProgFilter<'a> = Option<&'a dyn Fn(&str) -> bool>;

pub fn length_sweep(
    model: &Model<'_>,
    params: &[xla::Literal],
    task: &str,
    n_batches: usize,
    seed: u64,
    filter: ProgFilter<'_>,
) -> Result<Vec<EvalPoint>> {
    let vocab = model.manifest.cfg_usize("vocab", 512);
    let gen = by_name(task, vocab)?;
    let mut points = Vec::new();
    let evals: Vec<(String, crate::runtime::ProgramSpec)> = model
        .manifest
        .eval_programs()
        .into_iter()
        .map(|(k, v)| (k.clone(), v.clone()))
        .collect();
    for (name, spec) in evals {
        if let Some(f) = filter {
            if !f(&name) {
                continue;
            }
        }
        let (b, t) = (spec.batch.unwrap_or(2), spec.seq.unwrap_or(256));
        let mut rng = Rng::new(seed ^ (t as u64) << 8);
        let mut acc = Accuracy::default();
        let mut losses = Vec::new();
        for _ in 0..n_batches {
            let batch = Batch::generate(gen.as_ref(), &mut rng, b, t);
            let out = model.eval(&name, params, &batch.tokens, &batch.targets, &batch.mask)?;
            acc.add(&out.correct, &batch.mask);
            losses.push(out.loss as f64);
        }
        let geom = mixer_geom(model);
        points.push(EvalPoint {
            program: name.clone(),
            seq: t,
            n_dict: spec.n_dict,
            loss: stats::mean(&losses),
            accuracy: acc.value(),
            n_scored: acc.total,
            decode_state_bytes: spec
                .n_dict
                .map(|n| MixerKind::Ovq { n_max: n }.state_bytes(geom, t)),
        });
    }
    Ok(points)
}

pub fn print_sweep(model_name: &str, points: &[EvalPoint]) {
    println!("\n== {model_name} length sweep ==");
    println!(
        "{:>20} {:>6} {:>6} {:>9} {:>9} {:>8} {:>10}",
        "program", "T", "N", "loss", "acc", "scored", "ovq st/lyr"
    );
    for p in points {
        println!(
            "{:>20} {:>6} {:>6} {:>9.4} {:>9.4} {:>8} {:>10}",
            p.program,
            p.seq,
            p.n_dict.map(|n| n.to_string()).unwrap_or_else(|| "-".into()),
            p.loss,
            p.accuracy,
            p.n_scored,
            p.decode_state_bytes
                .map(|b| format!("{:.1}K", b as f64 / 1024.0))
                .unwrap_or_else(|| "-".into()),
        );
    }
}

/// Per-position curves: mean nll (LM, Fig. 6) binned by position.
pub fn nll_by_position(
    model: &Model<'_>,
    params: &[xla::Literal],
    prog: &str,
    task: &str,
    n_batches: usize,
    seed: u64,
    bin: usize,
) -> Result<Vec<(usize, f64, usize)>> {
    let vocab = model.manifest.cfg_usize("vocab", 512);
    let gen = by_name(task, vocab)?;
    let spec = model.manifest.programs.get(prog).unwrap().clone();
    let (b, t) = (spec.batch.unwrap_or(2), spec.seq.unwrap_or(256));
    let mut rng = Rng::new(seed);
    let mut pairs: Vec<(usize, f64)> = Vec::new();
    for _ in 0..n_batches {
        let batch = Batch::generate(gen.as_ref(), &mut rng, b, t);
        let out = model.eval(prog, params, &batch.tokens, &batch.targets, &batch.mask)?;
        for row in 0..b {
            for pos in 0..t {
                let i = row * t + pos;
                if batch.mask[i] > 0.0 {
                    pairs.push((pos, out.nll[i] as f64));
                }
            }
        }
    }
    Ok(stats::binned_means(&pairs, bin, t))
}

/// Per-example-ordinal accuracy for the ICL task (Fig. 5): accuracy of the
/// n-th example of each function, averaged over functions and batches.
pub fn icl_accuracy_by_ordinal(
    model: &Model<'_>,
    params: &[xla::Literal],
    prog: &str,
    n_funcs: usize,
    n_batches: usize,
    seed: u64,
) -> Result<Vec<(usize, f64, usize)>> {
    let vocab = model.manifest.cfg_usize("vocab", 512);
    let gen = icl::IclTask::new(vocab, n_funcs);
    let spec = model.manifest.programs.get(prog).unwrap().clone();
    let (b, t) = (spec.batch.unwrap_or(2), spec.seq.unwrap_or(256));
    let mut rng = Rng::new(seed);
    let mut sums: Vec<(f64, usize)> = Vec::new();
    for _ in 0..n_batches {
        let examples: Vec<crate::data::Example> = (0..b)
            .map(|_| crate::data::TaskGen::generate(&gen, &mut rng, t))
            .collect();
        let batch = Batch::from_examples(&examples, t);
        let out = model.eval(prog, params, &batch.tokens, &batch.targets, &batch.mask)?;
        for (row, ex) in examples.iter().enumerate() {
            for (pos, ord) in icl::example_ordinals(&ex.tokens, &ex.score) {
                if sums.len() <= ord {
                    sums.resize(ord + 1, (0.0, 0));
                }
                sums[ord].0 += out.correct[row * t + pos] as f64;
                sums[ord].1 += 1;
            }
        }
    }
    Ok(sums
        .into_iter()
        .enumerate()
        .filter(|(_, (_, n))| *n > 0)
        .map(|(ord, (c, n))| (ord, c / n as f64, n))
        .collect())
}
