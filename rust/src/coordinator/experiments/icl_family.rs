//! Fig 5: long in-context learning — per-example-ordinal accuracy curves
//! with varying numbers of in-context functions.

use anyhow::Result;

use crate::coordinator::{evaluator, trainer};
use crate::util::csv::CsvWriter;

use super::ExpCtx;

pub fn exp_f5(ctx: &ExpCtx) -> Result<()> {
    // models trained on the 4-function ICL mix (paper: trained w/ 16 fns at
    // 2k; scaled per DESIGN.md §3), tested at 1/4/8/16 functions.
    let models = ["icl-sw-nope", "icl-sw-ovq", "icl-sw-vq"];
    let fn_counts = if ctx.quick { vec![1, 4] } else { vec![1, 4, 8, 16] };

    let mut csv = CsvWriter::create(
        format!("{}/f5_icl_ordinal.csv", ctx.out_dir),
        &["model", "n_funcs", "T", "ordinal", "accuracy", "count"],
    )?;

    for model in models {
        let (m, st) =
            trainer::ensure_trained(&ctx.rt, model, "icl", ctx.steps, &ctx.out_dir)?;
        // evaluate on the longest available eval program: the function
        // count controls the spacing between same-function examples.
        let prog = m
            .manifest
            .eval_programs()
            .iter()
            .filter(|(k, p)| !k.contains("_N") && p.seq.unwrap_or(0) <= 1024)
            .map(|(k, _)| k.to_string())
            .next_back()
            .expect("no eval program");
        println!("\n== Fig 5 — {model} on {prog} ==");
        println!("{:>8} {:>8} {:>10} {:>8}", "n_funcs", "ordinal", "accuracy", "count");
        for &nf in &fn_counts {
            let curve = evaluator::icl_accuracy_by_ordinal(
                &m, &st.params, &prog, nf, ctx.eval_batches, 11,
            )?;
            let t = m.manifest.programs[&prog].seq.unwrap_or(0);
            for (ord, acc, n) in &curve {
                if *ord <= 12 {
                    println!("{:>8} {:>8} {:>10.3} {:>8}", nf, ord, acc, n);
                }
                csv.row(&[
                    model.to_string(),
                    nf.to_string(),
                    t.to_string(),
                    ord.to_string(),
                    format!("{acc}"),
                    n.to_string(),
                ])?;
            }
        }
    }
    csv.flush()?;
    println!(
        "\n(paper shape: sw-nope learns every function; sw-ovq matches it;\n\
         sw-vq fails to learn even one — accuracy should rise with ordinal\n\
         for nope/ovq and stay flat for vq)"
    );
    Ok(())
}
