//! In-context-recall experiments: Fig 1 (prelim VQ), Fig 4 (basic +
//! positional ICR, test-time N scaling), Fig 7 (ablations), Fig 8 (linear
//! baselines), Fig 10 (RoPE variant), Fig 13 (v-shift), §3.4 (s34).

use anyhow::Result;

use crate::ovqcore::memstate::{MixerGeom, MixerKind};
use crate::util::csv::CsvWriter;

use super::{sweep_models, write_matrix, ExpCtx};

/// Fig 1: sw-vq with growing dictionaries vs sw-nope on basic ICR.
/// Paper shape: baseline near-perfect with length extrapolation; VQ decays
/// before train length; more centroids give diminishing returns.
pub fn exp_f1(ctx: &ExpCtx) -> Result<()> {
    let pairs = [
        ("icr-sw-nope", "icr"),
        ("icr-sw-vq32", "icr"),
        ("icr-sw-vq64", "icr"),
        ("icr-sw-vq128", "icr"),
    ];
    let results = sweep_models(ctx, &pairs)?;
    write_matrix(
        &format!("{}/f1_prelim_icr.csv", ctx.out_dir),
        &results,
        |p| p.accuracy,
    )?;
    println!("\n== Fig 1 — per-token recall accuracy vs test length ==");
    summary_table(&results);
    Ok(())
}

/// Fig 4 (left, middle): basic + positional ICR with sw-nope / sw-vq /
/// sw-ovq, including sw-ovq evaluated at larger test-time dictionaries.
pub fn exp_f4(ctx: &ExpCtx) -> Result<()> {
    println!("\n######## basic ICR (Fig 4 left) ########");
    let basic = sweep_models(
        ctx,
        &[
            ("icr-sw-nope", "icr"),
            ("icr-sw-vq128", "icr"),
            ("icr-sw-ovq", "icr"),
        ],
    )?;
    write_matrix(&format!("{}/f4_basic_icr.csv", ctx.out_dir), &basic, |p| {
        p.accuracy
    })?;
    summary_table(&basic);

    println!("\n######## positional ICR (Fig 4 middle) ########");
    let pos = sweep_models(
        ctx,
        &[
            ("icr-sw-nope", "picr"),
            ("icr-sw-vq128", "picr"),
            ("icr-sw-ovq", "picr"),
        ],
    )?;
    write_matrix(&format!("{}/f4_positional_icr.csv", ctx.out_dir), &pos, |p| {
        p.accuracy
    })?;
    summary_table(&pos);
    println!("(right panel = `ovq exp f4r`, analytical memory growth)");
    Ok(())
}

/// Fig 7: ablations on basic ICR (random assignment / linear growth /
/// constant lr) — each should underperform full OVQ beyond train length.
pub fn exp_f7(ctx: &ExpCtx) -> Result<()> {
    let results = sweep_models(
        ctx,
        &[
            ("icr-sw-ovq", "icr"),
            ("icr-sw-ovq-randassign", "icr"),
            ("icr-sw-ovq-lineargrow", "icr"),
            ("icr-sw-ovq-constlr", "icr"),
        ],
    )?;
    write_matrix(&format!("{}/f7_ablations_icr.csv", ctx.out_dir), &results, |p| {
        p.accuracy
    })?;
    println!("\n== Fig 7 — OVQ ablations on basic ICR ==");
    summary_table(&results);
    Ok(())
}

/// Fig 8: equal-parameter linear-attention/SSM baselines on ICR + ICL.
pub fn exp_f8(ctx: &ExpCtx) -> Result<()> {
    println!("\n######## basic ICR (Fig 8 right) ########");
    let icr = sweep_models(
        ctx,
        &[
            ("icr-sw-ovq", "icr"),
            ("icr-gdn", "icr"),
            ("icr-ssd", "icr"),
            ("icr-linattn", "icr"),
        ],
    )?;
    write_matrix(&format!("{}/f8_linear_icr.csv", ctx.out_dir), &icr, |p| {
        p.accuracy
    })?;
    summary_table(&icr);

    println!("\n######## ICL (Fig 8 left) ########");
    let icl = sweep_models(
        ctx,
        &[
            ("icl-sw-ovq", "icl"),
            ("icl-gdn", "icl"),
            ("icl-ssd", "icl"),
        ],
    )?;
    write_matrix(&format!("{}/f8_linear_icl.csv", ctx.out_dir), &icl, |p| {
        p.accuracy
    })?;
    summary_table(&icl);
    Ok(())
}

/// Fig 10 (App C): OVQ w/ RoPE length generalization on basic ICR.
pub fn exp_f10(ctx: &ExpCtx) -> Result<()> {
    let results = sweep_models(
        ctx,
        &[
            ("icr-ovq-rope", "icr"),
            ("icr-att-rope", "icr"),
            ("icr-sw-ovq", "icr"),
        ],
    )?;
    write_matrix(&format!("{}/f10_rope_icr.csv", ctx.out_dir), &results, |p| {
        p.accuracy
    })?;
    println!("\n== Fig 10 — RoPE variants on basic ICR ==");
    summary_table(&results);
    Ok(())
}

/// Fig 13 (App C): v-shift + qk-conv on positional ICR.
pub fn exp_f13(ctx: &ExpCtx) -> Result<()> {
    let results = sweep_models(
        ctx,
        &[
            ("icr-sw-ovq", "picr"),
            ("icr-sw-ovq-vshift", "picr"),
        ],
    )?;
    write_matrix(&format!("{}/f13_vshift_picr.csv", ctx.out_dir), &results, |p| {
        p.accuracy
    })?;
    println!("\n== Fig 13 — v-shift/qk-conv on positional ICR ==");
    summary_table(&results);
    Ok(())
}

/// §3.4 / Fig 3: state-update footprint — ΔS bytes per chunk as the state
/// grows; OVQ's is constant in N, linear attention's scales with d_k*d_v.
pub fn exp_s34(out_dir: &str) -> Result<()> {
    let g = MixerGeom { heads: 8, d_head: 128 };
    let l = 128;
    let mut csv = CsvWriter::create(
        format!("{out_dir}/s34_update_footprint.csv"),
        &["mixer", "param", "state_bytes", "update_bytes_per_chunk"],
    )?;
    println!("\n== §3.4 — state size vs state-update footprint (chunk L={l}) ==");
    println!("{:>16} {:>10} {:>14} {:>16}", "mixer", "param", "state", "update/chunk");
    for n in [1024usize, 4096, 16384, 65536] {
        let k = MixerKind::Ovq { n_max: n };
        let s = k.state_bytes(g, usize::MAX / 2);
        let u = k.update_bytes(g, l);
        println!("{:>16} {:>10} {:>14} {:>16}", "ovq", format!("N={n}"), s, u);
        csv.row(&["ovq".into(), format!("N={n}"), s.to_string(), u.to_string()])?;
    }
    for d in [64usize, 128, 256] {
        let g2 = MixerGeom { heads: 8, d_head: d };
        let k = MixerKind::LinearAttention;
        let s = k.state_bytes(g2, usize::MAX / 2);
        let u = k.update_bytes(g2, l);
        println!("{:>16} {:>10} {:>14} {:>16}", "linear-attn", format!("d={d}"), s, u);
        csv.row(&["linear-attn".into(), format!("d={d}"), s.to_string(), u.to_string()])?;
    }
    csv.flush()?;
    println!(
        "\nOVQ update footprint is INDEPENDENT of N (sparse row writes);\n\
         linear attention's grows with the state (dense [L,dk,dv] tensor).\n\
         This is the paper's §3.4 claim, verified as exact byte accounting\n\
         and as measured throughput in benches/bench_ovqcore.rs."
    );
    Ok(())
}

/// Compact model-by-length accuracy table.
fn summary_table(results: &[(String, Vec<crate::coordinator::evaluator::EvalPoint>)]) {
    // columns = distinct (seq, n_dict)
    let mut cols: Vec<(usize, Option<usize>)> = results
        .iter()
        .flat_map(|(_, ps)| ps.iter().map(|p| (p.seq, p.n_dict)))
        .collect();
    cols.sort();
    cols.dedup();
    print!("{:>26}", "model");
    for (t, n) in &cols {
        let label = match n {
            Some(n) => format!("{t}/N{n}"),
            None => format!("{t}"),
        };
        print!(" {label:>10}");
    }
    println!();
    for (model, ps) in results {
        print!("{model:>26}");
        for (t, n) in &cols {
            let v = ps
                .iter()
                .find(|p| p.seq == *t && p.n_dict == *n)
                .map(|p| format!("{:.3}", p.accuracy))
                .unwrap_or_else(|| "-".into());
            print!(" {v:>10}");
        }
        println!();
    }
}
