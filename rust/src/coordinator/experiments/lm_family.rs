//! Long-context language modeling: Fig 6 (sw/gdn interleaves on the
//! synthetic book corpus), Fig 9 (OVQ w/ RoPE), Fig 12 (LM ablations).

use anyhow::Result;

use crate::coordinator::{evaluator, trainer};
use crate::util::csv::CsvWriter;

use super::ExpCtx;

fn lm_curves(ctx: &ExpCtx, models: &[&str], tag: &str) -> Result<()> {
    let mut csv = CsvWriter::create(
        format!("{}/{tag}_lm_position.csv", ctx.out_dir),
        &["model", "T", "position", "nll", "count"],
    )?;
    let mut finals: Vec<(String, usize, f64)> = Vec::new();
    for model in models {
        let (m, st) =
            trainer::ensure_trained(&ctx.rt, model, "lm", ctx.steps, &ctx.out_dir)?;
        // loss-vs-position on the longest eval program (the paper's plot),
        // plus the summary loss at each length
        let progs: Vec<String> = m
            .manifest
            .eval_programs()
            .iter()
            .filter(|(k, _)| !k.contains("_N"))
            .map(|(k, _)| k.to_string())
            .collect();
        for prog in &progs {
            let t = m.manifest.programs[prog].seq.unwrap_or(0);
            let curve = evaluator::nll_by_position(
                &m, &st.params, prog, "lm", ctx.eval_batches, 13, (t / 8).max(32),
            )?;
            for (pos, nll, n) in &curve {
                csv.row(&[
                    model.to_string(),
                    t.to_string(),
                    pos.to_string(),
                    format!("{nll}"),
                    n.to_string(),
                ])?;
            }
            if let Some((_, nll, _)) = curve.last() {
                finals.push((model.to_string(), t, *nll));
            }
        }
    }
    csv.flush()?;
    println!("\n== {tag} — mean NLL in the final position bin, per test length ==");
    println!("{:>26} {:>6} {:>9}", "model", "T", "nll");
    for (m, t, nll) in &finals {
        println!("{m:>26} {t:>6} {nll:>9.4}");
    }
    Ok(())
}

/// Fig 6: sliding-window and GDN interleaves on long-context LM.
pub fn exp_f6(ctx: &ExpCtx) -> Result<()> {
    let models: Vec<&str> = if ctx.quick {
        vec!["lm-sw", "lm-sw-ovq"]
    } else {
        vec!["lm-sw", "lm-sw-nope", "lm-sw-ovq", "lm-sw-vq", "lm-gdn", "lm-gdn-ovq"]
    };
    lm_curves(ctx, &models, "f6")?;
    println!(
        "\n(paper shape: adding OVQ layers to sw and gdn models drastically\n\
         improves long-context LM; sw-ovq ~ sw-nope > sw ~ gdn alone)"
    );
    Ok(())
}

/// Fig 9 (App C): pure OVQ w/ RoPE vs std-att w/ RoPE vs pure GDN.
pub fn exp_f9(ctx: &ExpCtx) -> Result<()> {
    let models = ["lm-ovq-rope", "lm-std-att", "lm-gdn"];
    lm_curves(ctx, &models, "f9")
}

/// Fig 12 (App C): LM ablations.
pub fn exp_f12(ctx: &ExpCtx) -> Result<()> {
    let models = [
        "lm-sw-ovq",
        "lm-sw-ovq-lineargrow",
        "lm-sw-ovq-constlr",
        "lm-sw-ovq-randassign",
    ];
    lm_curves(ctx, &models, "f12")
}
