//! Paper-experiment drivers: `ovq exp <id>` regenerates each table/figure
//! (DESIGN.md §4 maps ids to the paper). Every driver trains (or reuses)
//! the models it needs, runs the evaluation protocol, prints the paper-
//! style rows and writes a CSV under --out (default results/).
//!
//! `--quick` shrinks step counts/batches for CI-style smoke runs.

mod icr_family;
mod icl_family;
mod lm_family;
mod shortctx_t1;

use anyhow::Result;

use crate::analysis::{flops, memory};
use crate::util::cli::Args;

pub struct ExpCtx {
    pub rt: crate::runtime::Runtime,
    pub out_dir: String,
    pub quick: bool,
    pub steps: usize,
    pub eval_batches: usize,
}

impl ExpCtx {
    pub fn from_args(args: &Args) -> Result<ExpCtx> {
        let quick = args.has_flag("quick");
        Ok(ExpCtx {
            rt: super::runtime_from(args)?,
            out_dir: args.opt_or("out", "results"),
            quick,
            steps: args.opt_usize("steps", if quick { 120 } else { 0 })?,
            eval_batches: args.opt_usize("batches", if quick { 2 } else { 4 })?,
        })
    }
}

pub fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("")
        .to_lowercase();
    match id.as_str() {
        // analytical experiments need no runtime/training
        "f15" | "f16" => return flops::cmd_flops(args),
        "f4r" => return memory::fig4_right(&args.opt_or("out", "results")),
        "s34" => return icr_family::exp_s34(&args.opt_or("out", "results")),
        _ => {}
    }
    let ctx = ExpCtx::from_args(args)?;
    match id.as_str() {
        "f1" => icr_family::exp_f1(&ctx),
        "f4" => icr_family::exp_f4(&ctx),
        "f7" => icr_family::exp_f7(&ctx),
        "f8" => icr_family::exp_f8(&ctx),
        "f10" => icr_family::exp_f10(&ctx),
        "f13" => icr_family::exp_f13(&ctx),
        "f5" => icl_family::exp_f5(&ctx),
        "f6" => lm_family::exp_f6(&ctx),
        "f9" => lm_family::exp_f9(&ctx),
        "f12" => lm_family::exp_f12(&ctx),
        "t1" => shortctx_t1::exp_t1(&ctx),
        "all" => {
            for id in [
                "f15", "f4r", "s34", "f1", "f4", "f7", "f8", "f10", "f13",
                "f5", "f6", "f9", "f12", "t1",
            ] {
                crate::info!("=== exp {id} ===");
                let mut sub_args = args.clone();
                sub_args.positional = vec![id.to_string()];
                cmd_exp(&sub_args)?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown experiment '{other}' (f1 f4 f4r f5 f6 f7 f8 f9 f10 f12 f13 f15 f16 t1 s34 all)"
        ),
    }
}

/// Shared: train-or-reuse a set of (model, task) pairs and length-sweep
/// each; returns (label, sweep points) per model.
pub fn sweep_models(
    ctx: &ExpCtx,
    pairs: &[(&str, &str)],
) -> Result<Vec<(String, Vec<super::evaluator::EvalPoint>)>> {
    let mut out = Vec::new();
    for (model, task) in pairs {
        let (m, st) = super::trainer::ensure_trained(
            &ctx.rt, model, task, ctx.steps, &ctx.out_dir,
        )?;
        let points = super::evaluator::length_sweep(
            &m, &st.params, task, ctx.eval_batches, 7, None,
        )?;
        super::evaluator::print_sweep(model, &points);
        out.push((model.to_string(), points));
    }
    Ok(out)
}

/// Shared: write a (model x program) accuracy matrix CSV.
pub fn write_matrix(
    path: &str,
    results: &[(String, Vec<super::evaluator::EvalPoint>)],
    metric: impl Fn(&super::evaluator::EvalPoint) -> f64,
) -> Result<()> {
    use crate::util::csv::CsvWriter;
    let mut csv = CsvWriter::create(path, &["model", "program", "T", "N", "value"])?;
    for (model, points) in results {
        for p in points {
            csv.row(&[
                model.clone(),
                p.program.clone(),
                p.seq.to_string(),
                p.n_dict.map(|n| n.to_string()).unwrap_or_default(),
                format!("{}", metric(p)),
            ])?;
        }
    }
    csv.flush()?;
    Ok(())
}
