//! Table 1: short-context parity — std-att vs sw-nope vs sw-ovq on the
//! short-context probe suite (the PIQA/HellaSwag/... substitution; the
//! claim under test is that all three models score within noise of each
//! other at short context).

use anyhow::Result;

use crate::coordinator::{evaluator, trainer};
use crate::util::csv::CsvWriter;
use crate::util::stats;

use super::ExpCtx;

pub fn exp_t1(ctx: &ExpCtx) -> Result<()> {
    let models = ["sc-std-att", "sc-sw-nope", "sc-sw-ovq"];
    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    let mut csv = CsvWriter::create(
        format!("{}/t1_shortctx.csv", ctx.out_dir),
        &["model", "accuracy", "std"],
    )?;

    for model in models {
        let (m, st) = trainer::ensure_trained(
            &ctx.rt, model, "shortctx", ctx.steps, &ctx.out_dir,
        )?;
        // several independent eval draws -> mean +/- std (the paper
        // averages the last three checkpoints; we average eval seeds)
        let mut accs = Vec::new();
        for seed in 0..5u64 {
            let pts = evaluator::length_sweep(
                &m, &st.params, "shortctx", ctx.eval_batches, 100 + seed, None,
            )?;
            accs.push(pts[0].accuracy);
        }
        let mean = stats::mean(&accs);
        let sd = stats::std_dev(&accs);
        rows.push((model.to_string(), mean, sd));
        csv.row(&[model.to_string(), format!("{mean}"), format!("{sd}")])?;
    }
    csv.flush()?;

    println!("\n== Table 1 — short-context probe accuracy (mean ± std over eval seeds) ==");
    println!("{:>14} {:>12}", "model", "accuracy");
    for (m, acc, sd) in &rows {
        println!("{m:>14} {:>8.3}±{:.3}", acc, sd);
    }
    let accs: Vec<f64> = rows.iter().map(|r| r.1).collect();
    let spread = accs.iter().cloned().fold(f64::MIN, f64::max)
        - accs.iter().cloned().fold(f64::MAX, f64::min);
    let mean_sd = stats::mean(&rows.iter().map(|r| r.2).collect::<Vec<_>>());
    println!(
        "\nspread across models = {spread:.4}; mean per-model std = {mean_sd:.4}\n\
         (paper claim: parity — spread should be within ~1-2 stds)"
    );
    Ok(())
}
