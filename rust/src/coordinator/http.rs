//! The network edge: a dependency-light HTTP/1.1 server over the decode
//! engine (`std::net` + the hand-rolled [`crate::util::json`] tree — no
//! new crates, per the repo's offline dependency constraint).
//!
//! Endpoints (API.md is the client-facing reference):
//!
//! - `POST /v1/completions` — OpenAI-style generation over
//!   [`EngineHandle::try_submit_generate`]: a blocking JSON completion,
//!   or `"stream": true` for SSE token streaming over chunked
//!   transfer-encoding, every sampled token forwarded the moment the
//!   engine emits it on the request's [`GenEvent`] channel;
//! - `GET /v1/health` — liveness;
//! - `GET /v1/stats` — edge counters, live engine queue gauges, and the
//!   memory-tier counters (disk spills/restores, prefix-cache hit rate);
//! - `GET /metrics` — the engine's metrics registry in Prometheus text
//!   exposition format (counters, gauges, latency histograms);
//! - `GET /v1/trace` — the last N trace spans (`?n=` caps them) from the
//!   per-shard span rings, populated at `--obs trace`.
//!
//! Every response carries an `x-request-id` header: a client-supplied id
//! is echoed verbatim (and FNV-hashed to the u64 the trace spans carry);
//! otherwise the edge mints one and echoes it in hex. The same id flows
//! through admission → shard queue → prefill → decode → sampling spans,
//! and the completion response carries a `timing` object (queue /
//! prefill / decode / total microseconds) sourced from the engine's
//! per-request accounting.
//!
//! Production concerns are the point of this module:
//!
//! - **Admission control**: per-tenant token buckets ([`TenantGate`],
//!   keyed by the `x-tenant` header) → `429 rate_limited`; a global
//!   inflight cap → `429 overloaded`; and engine backpressure — a full
//!   shard queue surfaces as [`super::engine::QueueFull`] from the
//!   non-blocking submit
//!   and maps to `429 overloaded` with `Retry-After`, so saturation
//!   sheds load instead of blocking the accept loop or hanging clients.
//! - **Determinism**: the edge is observational. Token sampling depends
//!   only on (engine seed, sampling params, session id, prompt) — never
//!   on the transport — so a completion served over the socket is
//!   bit-identical to the same request through in-process
//!   `submit_generate`, at any thread count (the golden test in
//!   `tests/http.rs`; DESIGN.md "Network edge" has the argument).
//! - **Robustness**: every malformed input — bad framing, truncated or
//!   oversized bodies, invalid JSON, out-of-range params — maps to a
//!   typed [`ApiError`] with a stable code and a clean 4xx, never a
//!   panic or a hung connection (read timeouts bound slow clients).
//!
//! The module also ships a minimal client ([`http_post`] / [`http_get`]
//! + chunked/SSE decoding in [`HttpResponse`]) so traffic replay
//! (`--over-http`), the golden tests, and the benches can drive a real
//! socket without new dependencies.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::engine::{DecodeEngine, EngineConfig, EngineHandle, GenEvent};
use super::router::{parse_completion, route, ApiError, CompletionLimits, Route};
use super::sampler::{SamplingParams, StopCriteria};
use super::traffic;
use crate::ovqcore::lm::{LmConfig, TokenId};
use crate::ovqcore::memstate::parse_schedule;
use crate::ovqcore::quant::QuantMode;
use crate::ovqcore::stack::StackConfig;
use crate::util::cli::Args;
use crate::util::json::{parse as json_parse, Json};
use crate::util::obs::{self, ObsLevel, Span, Stage};

/// Edge configuration (`serve-http` flags map 1:1; README has the
/// consolidated table).
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// listen port on 127.0.0.1 (0 = ephemeral, for tests/benches)
    pub port: u16,
    /// global cap on concurrently served completions (`--max-inflight`)
    pub max_inflight: usize,
    /// per-tenant admitted requests/second (`--tenant-rate`, 0 = off)
    pub tenant_rate: f64,
    /// token-bucket capacity per tenant (`--tenant-burst`)
    pub tenant_burst: f64,
    /// request-body cap in bytes — larger is `413 body_too_large`
    pub max_body: usize,
    /// longest accepted prompt, tokens
    pub max_prompt: usize,
    /// largest accepted `max_tokens`
    pub max_new_cap: usize,
    /// per-connection read timeout: a stalled or truncated request is a
    /// clean 400 after this long, not a leaked thread
    pub read_timeout_ms: u64,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig {
            port: 0,
            max_inflight: 256,
            tenant_rate: 0.0,
            tenant_burst: 8.0,
            max_body: 1 << 20,
            max_prompt: 1 << 16,
            max_new_cap: 4096,
            read_timeout_ms: 2000,
        }
    }
}

/// Edge counters, all monotonic except the `inflight` gauge. Served as
/// JSON by `GET /v1/stats`.
#[derive(Debug, Default)]
pub struct EdgeStats {
    /// HTTP requests successfully parsed (any endpoint)
    pub requests: AtomicUsize,
    /// completions finished and delivered (blocking + streamed)
    pub completions: AtomicUsize,
    /// subset of `completions` that streamed over SSE
    pub streamed: AtomicUsize,
    /// generated tokens delivered to clients
    pub tokens_out: AtomicUsize,
    /// 429s from the per-tenant token bucket
    pub shed_rate_limited: AtomicUsize,
    /// 429s from the global inflight cap
    pub shed_overloaded: AtomicUsize,
    /// 429s from engine shard-queue backpressure
    /// ([`super::engine::QueueFull`])
    pub shed_backpressure: AtomicUsize,
    /// non-429 4xx responses (validation, routing, framing)
    pub client_errors: AtomicUsize,
    /// 5xx responses (engine-side failures after admission)
    pub failed: AtomicUsize,
    /// completions in service right now
    pub inflight: AtomicUsize,
}

/// Per-tenant token-bucket rate limiter: `rate` admissions/second
/// refilling up to `burst`. `rate <= 0` disables the gate entirely.
pub struct TenantGate {
    rate: f64,
    burst: f64,
    buckets: Mutex<HashMap<String, Bucket>>,
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

impl TenantGate {
    pub fn new(rate: f64, burst: f64) -> TenantGate {
        TenantGate { rate, burst: burst.max(1.0), buckets: Mutex::new(HashMap::new()) }
    }

    /// Admit one request for `tenant`, or return the `Retry-After`
    /// seconds until the bucket holds a full token again.
    pub fn admit(&self, tenant: &str) -> std::result::Result<(), u64> {
        if self.rate <= 0.0 {
            return Ok(());
        }
        let mut m = self.buckets.lock().expect("tenant gate poisoned");
        let now = Instant::now();
        let b = m
            .entry(tenant.to_string())
            .or_insert_with(|| Bucket { tokens: self.burst, last: now });
        let refill = now.duration_since(b.last).as_secs_f64() * self.rate;
        b.tokens = (b.tokens + refill).min(self.burst);
        b.last = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            Ok(())
        } else {
            Err(((1.0 - b.tokens) / self.rate).ceil().max(1.0) as u64)
        }
    }
}

/// Everything a connection handler needs, shared behind one `Arc`: the
/// engine handle, limits, admission state, and counters.
struct Edge {
    cfg: HttpConfig,
    handle: EngineHandle,
    lim: CompletionLimits,
    gate: TenantGate,
    stats: EdgeStats,
    /// server-assigned session ids for requests that don't pin one;
    /// starts far above trace/client ids so the spaces never collide
    next_session: AtomicU64,
    t0: Instant,
}

/// Decrements the inflight gauge when the completion handler exits on
/// any path (success, refusal, panic unwind).
struct InflightGuard<'a>(&'a AtomicUsize);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

// ----------------------------------------------------------- request IO

const MAX_HEADER_BYTES: usize = 16 * 1024;
const MAX_DRAIN_BYTES: usize = 4 * 1024 * 1024;

struct Request {
    method: String,
    path: String,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl Request {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// The per-request trace identity: the u64 the spans carry plus the
/// exact string echoed back as `x-request-id` on every response.
struct ReqId {
    num: u64,
    text: String,
}

impl ReqId {
    /// Honor a client-supplied `x-request-id` (echoed verbatim, hashed
    /// via [`obs::hash_request_id`] for span correlation); otherwise
    /// mint a fresh id and echo its hex form.
    fn derive(req: &Request) -> ReqId {
        match req.header("x-request-id") {
            Some(h) if !h.is_empty() => {
                ReqId { num: obs::hash_request_id(h), text: h.to_string() }
            }
            _ => {
                let n = obs::next_request_id();
                ReqId { num: n, text: format!("{n:x}") }
            }
        }
    }
}

/// The `x-request-id` echo, in the shape `write_response` extras take.
fn rid_header(rid: &ReqId) -> [(&'static str, String); 1] {
    [("x-request-id", rid.text.clone())]
}

/// `key`'s value in the request path's query string, if any.
fn query_param<'a>(path: &'a str, key: &str) -> Option<&'a str> {
    let q = path.split_once('?')?.1;
    q.split('&').find_map(|kv| {
        let (k, v) = kv.split_once('=')?;
        (k == key).then_some(v)
    })
}

fn find_seq(hay: &[u8], needle: &[u8]) -> Option<usize> {
    if needle.len() > hay.len() {
        return None;
    }
    (0..=hay.len() - needle.len()).find(|&i| &hay[i..i + needle.len()] == needle)
}

/// Read one request: header block (bounded), then exactly
/// `Content-Length` body bytes (bounded by the body cap). Every failure
/// mode is a typed [`ApiError`], not a panic or a hang.
fn read_request(
    stream: &mut TcpStream,
    max_body: usize,
) -> std::result::Result<Request, ApiError> {
    let mut buf = Vec::new();
    let mut tmp = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = find_seq(&buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEADER_BYTES {
            return Err(ApiError::BadRequest("header block too large".to_string()));
        }
        let n = stream
            .read(&mut tmp)
            .map_err(|e| ApiError::BadRequest(format!("read failed: {e}")))?;
        if n == 0 {
            return Err(ApiError::BadRequest("connection closed mid-request".to_string()));
        }
        buf.extend_from_slice(&tmp[..n]);
    };

    let head = std::str::from_utf8(&buf[..header_end])
        .map_err(|_| ApiError::BadRequest("header block is not UTF-8".to_string()))?;
    let mut lines = head.split("\r\n");
    let req_line = lines.next().unwrap_or("");
    let mut parts = req_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || !path.starts_with('/') {
        return Err(ApiError::BadRequest(format!("malformed request line '{req_line}'")));
    }
    let mut headers = Vec::new();
    for l in lines {
        if let Some((k, v)) = l.split_once(':') {
            headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
    }
    let req = Request { method, path, headers, body: Vec::new() };

    let clen = match req.header("content-length") {
        None => 0usize,
        Some(v) => v
            .parse()
            .map_err(|_| ApiError::BadRequest(format!("bad content-length '{v}'")))?,
    };
    if clen > max_body {
        // refuse before buffering the body — but discard what the client
        // already committed to sending (bounded; the read timeout caps a
        // staller), so closing the socket doesn't reset the connection
        // with unread data in flight and eat the 413 on its way out
        let got = buf.len() - (header_end + 4);
        let mut left = clen.saturating_sub(got).min(MAX_DRAIN_BYTES);
        while left > 0 {
            match stream.read(&mut tmp) {
                Ok(0) | Err(_) => break,
                Ok(n) => left = left.saturating_sub(n),
            }
        }
        return Err(ApiError::BodyTooLarge { limit: max_body });
    }
    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < clen {
        let n = stream
            .read(&mut tmp)
            .map_err(|e| ApiError::BadRequest(format!("body read failed: {e}")))?;
        if n == 0 {
            return Err(ApiError::BadRequest("body shorter than content-length".to_string()));
        }
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(clen);
    Ok(Request { body, ..req })
}

// ---------------------------------------------------------- response IO

fn write_response(
    w: &mut TcpStream,
    status: u16,
    reason: &str,
    extra: &[(&str, String)],
    body: &[u8],
) -> std::io::Result<()> {
    write_response_typed(w, status, reason, "application/json", extra, body)
}

/// [`write_response`] with an explicit content type — `GET /metrics`
/// serves Prometheus text, everything else JSON.
fn write_response_typed(
    w: &mut TcpStream,
    status: u16,
    reason: &str,
    ctype: &str,
    extra: &[(&str, String)],
    body: &[u8],
) -> std::io::Result<()> {
    let mut head = format!("HTTP/1.1 {status} {reason}\r\n");
    head.push_str(&format!("content-type: {ctype}\r\n"));
    head.push_str(&format!("content-length: {}\r\n", body.len()));
    for (k, v) in extra {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("connection: close\r\n\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(body)
}

fn write_error(w: &mut TcpStream, e: &ApiError, rid: Option<&ReqId>) -> std::io::Result<()> {
    let mut extra: Vec<(&str, String)> = Vec::new();
    if let Some(r) = rid {
        extra.push(("x-request-id", r.text.clone()));
    }
    if let Some(s) = e.retry_after() {
        extra.push(("retry-after", s.to_string()));
    }
    if let ApiError::MethodNotAllowed { allow } = e {
        extra.push(("allow", allow.to_string()));
    }
    write_response(w, e.status(), e.reason(), &extra, e.body().to_string().as_bytes())
}

fn write_sse_head(w: &mut TcpStream, rid: &ReqId) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 200 OK\r\ncontent-type: text/event-stream\r\ncache-control: no-store\r\n\
         x-request-id: {}\r\ntransfer-encoding: chunked\r\nconnection: close\r\n\r\n",
        rid.text,
    );
    w.write_all(head.as_bytes())
}

fn write_chunk(w: &mut TcpStream, data: &[u8]) -> std::io::Result<()> {
    write!(w, "{:x}\r\n", data.len())?;
    w.write_all(data)?;
    w.write_all(b"\r\n")
}

/// One SSE event as one HTTP chunk: `data: <payload>\n\n`.
fn write_sse_event(w: &mut TcpStream, data: &str) -> std::io::Result<()> {
    write_chunk(w, format!("data: {data}\n\n").as_bytes())
}

fn finish_chunks(w: &mut TcpStream) -> std::io::Result<()> {
    w.write_all(b"0\r\n\r\n")
}

// ------------------------------------------------------------- handlers

fn tokens_json(tokens: &[TokenId]) -> Json {
    Json::Arr(tokens.iter().map(|t| Json::Num(*t as f64)).collect())
}

/// Extract a token-id array from a completion response or SSE `done`
/// event (client side of the wire format).
pub fn token_ids(j: &Json) -> Option<Vec<TokenId>> {
    j.as_arr()?
        .iter()
        .map(|t| t.as_u64().map(|v| v as TokenId))
        .collect()
}

fn finish_reason(tokens: &[TokenId], stop: &StopCriteria) -> &'static str {
    if tokens.last().is_some_and(|t| stop.stop_tokens.contains(t)) {
        "stop"
    } else {
        "length"
    }
}

fn completion_json(session: u64, seq: usize, tokens: &[TokenId], stop: &StopCriteria) -> Json {
    Json::obj([
        ("object", Json::Str("ovq.completion".to_string())),
        ("session", Json::Num(session as f64)),
        ("seq", Json::Num(seq as f64)),
        ("tokens", tokens_json(tokens)),
        ("n_tokens", Json::Num(tokens.len() as f64)),
        ("finish_reason", Json::Str(finish_reason(tokens, stop).to_string())),
    ])
}

fn handle_conn(edge: &Arc<Edge>, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(edge.cfg.read_timeout_ms)));
    let _ = stream.set_nodelay(true);
    let req = match read_request(&mut stream, edge.cfg.max_body) {
        Ok(r) => r,
        Err(e) => {
            // framing failed before headers parsed — no request id yet
            edge.stats.client_errors.fetch_add(1, Ordering::Relaxed);
            let _ = write_error(&mut stream, &e, None);
            return;
        }
    };
    edge.stats.requests.fetch_add(1, Ordering::Relaxed);
    let rid = ReqId::derive(&req);
    let result = match route(&req.method, &req.path) {
        Ok(Route::Health) => handle_health(edge, &rid, &mut stream),
        Ok(Route::Stats) => handle_stats(edge, &rid, &mut stream),
        Ok(Route::Metrics) => handle_metrics(edge, &rid, &mut stream),
        Ok(Route::Trace) => handle_trace(edge, &req, &rid, &mut stream),
        Ok(Route::Completions) => handle_completion(edge, &req, &rid, &mut stream),
        Err(e) => Err(e),
    };
    if let Err(e) = result {
        match e.status() {
            429 => {} // counted at the shed site, by kind
            500 => {
                edge.stats.failed.fetch_add(1, Ordering::Relaxed);
            }
            _ => {
                edge.stats.client_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
        let _ = write_error(&mut stream, &e, Some(&rid));
    }
}

fn handle_health(
    edge: &Arc<Edge>,
    rid: &ReqId,
    w: &mut TcpStream,
) -> std::result::Result<(), ApiError> {
    let body = Json::obj([
        ("status", Json::Str("ok".to_string())),
        ("threads", Json::Num(edge.handle.threads() as f64)),
        ("vocab", Json::Num(edge.lim.vocab as f64)),
        ("uptime_s", Json::Num(edge.t0.elapsed().as_secs_f64())),
    ]);
    let _ = write_response(w, 200, "OK", &rid_header(rid), body.to_string().as_bytes());
    Ok(())
}

/// `GET /metrics` — every counter, gauge, and histogram in the engine's
/// registry (edge counters included, registered at server start) in
/// Prometheus text exposition format 0.0.4.
fn handle_metrics(
    edge: &Arc<Edge>,
    rid: &ReqId,
    w: &mut TcpStream,
) -> std::result::Result<(), ApiError> {
    let text = edge.handle.obs().registry().render_prometheus();
    let _ = write_response_typed(
        w,
        200,
        "OK",
        "text/plain; version=0.0.4",
        &rid_header(rid),
        text.as_bytes(),
    );
    Ok(())
}

/// `GET /v1/trace[?n=N]` — the last N spans (default 256) merged across
/// the per-shard rings, start-time ordered. Empty below `--obs trace`.
fn handle_trace(
    edge: &Arc<Edge>,
    req: &Request,
    rid: &ReqId,
    w: &mut TcpStream,
) -> std::result::Result<(), ApiError> {
    let n = match query_param(&req.path, "n") {
        None => 256,
        Some(v) => v.parse::<usize>().map_err(|_| ApiError::InvalidParam {
            field: "n",
            reason: format!("'{v}' is not a non-negative integer"),
        })?,
    };
    let spans = edge.handle.obs().trace().dump(n);
    let body = Json::obj([
        ("object", Json::Str("ovq.trace".to_string())),
        ("level", Json::Str(obs::level().as_str().to_string())),
        ("n", Json::Num(spans.len() as f64)),
        ("spans", Json::Arr(spans.iter().map(Span::to_json).collect())),
    ]);
    let _ = write_response(w, 200, "OK", &rid_header(rid), body.to_string().as_bytes());
    Ok(())
}

fn handle_stats(
    edge: &Arc<Edge>,
    rid: &ReqId,
    w: &mut TcpStream,
) -> std::result::Result<(), ApiError> {
    let s = &edge.stats;
    let n = |a: &AtomicUsize| Json::Num(a.load(Ordering::Relaxed) as f64);
    let mut queues = Vec::new();
    for d in edge.handle.queue_depths() {
        queues.push(Json::Num(d as f64));
    }
    let body = Json::obj([
        ("uptime_s", Json::Num(edge.t0.elapsed().as_secs_f64())),
        ("requests", n(&s.requests)),
        ("completions", n(&s.completions)),
        ("streamed", n(&s.streamed)),
        ("tokens_out", n(&s.tokens_out)),
        ("inflight", n(&s.inflight)),
        ("client_errors", n(&s.client_errors)),
        ("failed", n(&s.failed)),
        (
            "shed",
            Json::obj([
                ("rate_limited", n(&s.shed_rate_limited)),
                ("overloaded", n(&s.shed_overloaded)),
                ("backpressure", n(&s.shed_backpressure)),
            ]),
        ),
        (
            "engine",
            Json::obj([
                ("threads", Json::Num(edge.handle.threads() as f64)),
                ("queue_depth", Json::Num(edge.handle.queue_depth() as f64)),
                ("queues", Json::Arr(queues)),
            ]),
        ),
        ("tiers", {
            let (spills, disk_restores, disk_sessions, disk_bytes) =
                edge.handle.tier_counters();
            let p = edge.handle.prefix_stats();
            Json::obj([
                ("spills", Json::Num(spills as f64)),
                ("disk_restores", Json::Num(disk_restores as f64)),
                ("disk_sessions", Json::Num(disk_sessions as f64)),
                ("disk_bytes", Json::Num(disk_bytes as f64)),
                ("prefix_hits", Json::Num(p.hits as f64)),
                ("prefix_misses", Json::Num(p.misses as f64)),
                ("prefix_bytes", Json::Num(p.bytes as f64)),
                ("prefix_entries", Json::Num(p.entries as f64)),
            ])
        }),
    ]);
    let _ = write_response(w, 200, "OK", &rid_header(rid), body.to_string().as_bytes());
    Ok(())
}

/// The completions path: validate → admit (tenant bucket, inflight cap,
/// engine queue) → submit with a per-request [`GenEvent`] channel →
/// deliver blocking JSON or SSE. Every refusal happens before the
/// engine sees the request. At `--obs trace` the validate-and-admit
/// interval is recorded as an `admission` span under the request's id.
fn handle_completion(
    edge: &Arc<Edge>,
    req: &Request,
    rid: &ReqId,
    w: &mut TcpStream,
) -> std::result::Result<(), ApiError> {
    let t_adm = Instant::now();
    let text = std::str::from_utf8(&req.body)
        .map_err(|_| ApiError::BadJson("body is not UTF-8".to_string()))?;
    let body = json_parse(text).map_err(ApiError::BadJson)?;
    let creq = parse_completion(&body, &edge.lim)?;

    let tenant = req.header("x-tenant").unwrap_or("anon");
    edge.gate.admit(tenant).map_err(|retry_after| {
        edge.stats.shed_rate_limited.fetch_add(1, Ordering::Relaxed);
        ApiError::RateLimited { retry_after }
    })?;

    let inflight = edge.stats.inflight.fetch_add(1, Ordering::SeqCst);
    let _guard = InflightGuard(&edge.stats.inflight);
    if inflight >= edge.cfg.max_inflight {
        edge.stats.shed_overloaded.fetch_add(1, Ordering::Relaxed);
        return Err(ApiError::Overloaded { retry_after: 1 });
    }

    let session = match creq.session {
        Some(s) => s,
        None => edge.next_session.fetch_add(1, Ordering::Relaxed),
    };
    if obs::trace_enabled() {
        // edge-side spans land in ring 0 — the edge has no shard of its own
        let tr = edge.handle.obs().trace();
        let dur_us = t_adm.elapsed().as_micros() as u64;
        let now = tr.now_us();
        tr.push(
            0,
            Span {
                req: rid.num,
                session,
                stage: Stage::Admission,
                shard: 0,
                start_us: now.saturating_sub(dur_us),
                dur_us,
            },
        );
    }
    let (tx, rx) = mpsc::channel();
    edge.handle
        .try_submit_generate_traced(
            rid.num,
            session,
            creq.prompt,
            creq.prefix_len,
            creq.prefix_id,
            creq.params,
            creq.stop.clone(),
            Some(tx),
        )
        .map_err(|_| {
            edge.stats.shed_backpressure.fetch_add(1, Ordering::Relaxed);
            ApiError::Overloaded { retry_after: 1 }
        })?;

    if creq.stream {
        stream_completion(edge, w, rid, session, &creq.stop, rx)
    } else {
        blocking_completion(edge, w, rid, session, &creq.stop, rx)
    }
}

fn blocking_completion(
    edge: &Arc<Edge>,
    w: &mut TcpStream,
    rid: &ReqId,
    session: u64,
    stop: &StopCriteria,
    rx: mpsc::Receiver<GenEvent>,
) -> std::result::Result<(), ApiError> {
    loop {
        match rx.recv() {
            Ok(GenEvent::Token(_)) => continue,
            Ok(GenEvent::Done { seq, tokens, timing }) => {
                edge.stats.completions.fetch_add(1, Ordering::Relaxed);
                edge.stats.tokens_out.fetch_add(tokens.len(), Ordering::Relaxed);
                let mut body = completion_json(session, seq, &tokens, stop);
                if let Json::Obj(m) = &mut body {
                    m.insert("timing".to_string(), timing.to_json());
                }
                crate::debug_req!(
                    &rid.text,
                    "completion session={session} tokens={} total_us={}",
                    tokens.len(),
                    timing.total_us,
                );
                let _ =
                    write_response(w, 200, "OK", &rid_header(rid), body.to_string().as_bytes());
                return Ok(());
            }
            Ok(GenEvent::Failed(m)) => return Err(ApiError::Internal(m)),
            Err(_) => {
                return Err(ApiError::Internal("engine dropped the request".to_string()))
            }
        }
    }
}

/// SSE delivery: one `data:` event per sampled token as it arrives, a
/// final `done` record carrying the full completion, then `[DONE]`.
/// Engine failures after the head is written surface as an in-stream
/// `error` event (the status line is already on the wire). A client
/// that disconnects mid-stream only detaches its observer — sampling
/// already happened engine-side, so determinism is unaffected.
fn stream_completion(
    edge: &Arc<Edge>,
    w: &mut TcpStream,
    rid: &ReqId,
    session: u64,
    stop: &StopCriteria,
    rx: mpsc::Receiver<GenEvent>,
) -> std::result::Result<(), ApiError> {
    if write_sse_head(w, rid).is_err() {
        return Ok(()); // client gone before the head — nothing to deliver
    }
    let mut index = 0usize;
    loop {
        let terminal = match rx.recv() {
            Ok(GenEvent::Token(t)) => {
                let ev = Json::obj([
                    ("token", Json::Num(t as f64)),
                    ("index", Json::Num(index as f64)),
                ]);
                index += 1;
                if write_sse_event(w, &ev.to_string()).is_err() {
                    return Ok(()); // client disconnected mid-stream
                }
                continue;
            }
            Ok(GenEvent::Done { seq, tokens, timing }) => {
                edge.stats.completions.fetch_add(1, Ordering::Relaxed);
                edge.stats.streamed.fetch_add(1, Ordering::Relaxed);
                edge.stats.tokens_out.fetch_add(tokens.len(), Ordering::Relaxed);
                let mut done = completion_json(session, seq, &tokens, stop);
                if let Json::Obj(m) = &mut done {
                    m.insert("done".to_string(), Json::Bool(true));
                    m.insert("timing".to_string(), timing.to_json());
                }
                done
            }
            Ok(GenEvent::Failed(m)) => {
                edge.stats.failed.fetch_add(1, Ordering::Relaxed);
                ApiError::Internal(m).body()
            }
            Err(_) => {
                edge.stats.failed.fetch_add(1, Ordering::Relaxed);
                ApiError::Internal("engine dropped the request".to_string()).body()
            }
        };
        let _ = write_sse_event(w, &terminal.to_string());
        let _ = write_sse_event(w, "[DONE]");
        let _ = finish_chunks(w);
        return Ok(());
    }
}

/// Join the edge counters to the engine's metrics registry as render-time
/// views over the [`EdgeStats`] atomics — `GET /metrics` then exposes
/// them without a second store, and `GET /v1/stats` keeps its JSON shape
/// over the very same values. Idempotent by metric name, so restarting
/// the edge over a live engine re-points the views at the new stats.
///
/// The closures hold a `Weak<Edge>`: the registry lives inside the
/// engine's `EngineObs`, which the shard workers reference, so a strong
/// `Arc<Edge>` here would cycle back through the edge's `EngineHandle`
/// (and its queue senders) and keep the workers from ever seeing
/// disconnect — `finish()` would join forever. A stopped edge's gauges
/// render 0 instead.
fn register_edge_metrics(edge: &Arc<Edge>) {
    let views: &[(&str, fn(&EdgeStats) -> usize)] = &[
        ("ovq_http_requests_total", |s| s.requests.load(Ordering::Relaxed)),
        ("ovq_http_completions_total", |s| s.completions.load(Ordering::Relaxed)),
        ("ovq_http_streamed_total", |s| s.streamed.load(Ordering::Relaxed)),
        ("ovq_http_tokens_out_total", |s| s.tokens_out.load(Ordering::Relaxed)),
        ("ovq_http_shed_rate_limited_total", |s| {
            s.shed_rate_limited.load(Ordering::Relaxed)
        }),
        ("ovq_http_shed_overloaded_total", |s| s.shed_overloaded.load(Ordering::Relaxed)),
        ("ovq_http_shed_backpressure_total", |s| {
            s.shed_backpressure.load(Ordering::Relaxed)
        }),
        ("ovq_http_client_errors_total", |s| s.client_errors.load(Ordering::Relaxed)),
        ("ovq_http_failed_total", |s| s.failed.load(Ordering::Relaxed)),
        ("ovq_http_inflight", |s| s.inflight.load(Ordering::Relaxed)),
    ];
    let reg = Arc::clone(edge.handle.obs().registry());
    for &(name, read) in views {
        let me = Arc::downgrade(edge);
        reg.gauge_fn(name, &[], move || {
            me.upgrade().map_or(0.0, |e| read(&e.stats) as f64)
        });
    }
}

// --------------------------------------------------------------- server

/// The running edge. [`HttpServer::stop`] (or drop) shuts the accept
/// loop down; connection handlers hold [`EngineHandle`] clones, so stop
/// the server **before** [`DecodeEngine::finish`] — the engine joins
/// its workers only once every handle is gone.
pub struct HttpServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `127.0.0.1:port` (0 = ephemeral) and start the accept loop:
    /// one detached handler thread per connection, so a slow client
    /// never blocks admission. Requires an LM engine (the completions
    /// endpoint samples tokens).
    pub fn start(cfg: HttpConfig, handle: EngineHandle) -> Result<HttpServer> {
        let vocab = handle
            .lm_vocab()
            .context("serve-http needs an LM engine (vocab + layer stack)")?;
        let listener = TcpListener::bind(("127.0.0.1", cfg.port))
            .with_context(|| format!("binding 127.0.0.1:{}", cfg.port))?;
        let addr = listener.local_addr()?;
        let lim = CompletionLimits {
            vocab,
            max_prompt: cfg.max_prompt,
            max_new: cfg.max_new_cap,
        };
        let edge = Arc::new(Edge {
            gate: TenantGate::new(cfg.tenant_rate, cfg.tenant_burst),
            cfg,
            handle,
            lim,
            stats: EdgeStats::default(),
            next_session: AtomicU64::new(1 << 48),
            t0: Instant::now(),
        });
        register_edge_metrics(&edge);
        let shutdown = Arc::new(AtomicBool::new(false));
        let accept = {
            let (edge, shutdown) = (Arc::clone(&edge), Arc::clone(&shutdown));
            thread::spawn(move || {
                for conn in listener.incoming() {
                    if shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    if let Ok(stream) = conn {
                        let edge = Arc::clone(&edge);
                        thread::spawn(move || handle_conn(&edge, stream));
                    }
                }
            })
        };
        Ok(HttpServer { addr, shutdown, accept: Some(accept) })
    }

    /// The bound address (resolves the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    fn shutdown_now(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // unblock the accept loop with a dummy connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting and join the accept loop. In-service handlers
    /// drain on their own (they hold no listener state).
    pub fn stop(mut self) {
        self.shutdown_now();
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown_now();
    }
}

// --------------------------------------------------------------- client

/// A parsed HTTP response from the minimal client: status, lowercased
/// headers, and the body with chunked transfer-encoding already decoded.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// Parse the body as JSON.
    pub fn json(&self) -> Result<Json> {
        let text = std::str::from_utf8(&self.body).context("body is not UTF-8")?;
        json_parse(text).map_err(anyhow::Error::msg)
    }

    /// The `data:` payloads of an SSE body, in order (`[DONE]` included).
    pub fn sse_data(&self) -> Vec<String> {
        let text = String::from_utf8_lossy(&self.body);
        text.lines()
            .filter_map(|l| l.strip_prefix("data: "))
            .map(|s| s.to_string())
            .collect()
    }
}

/// `POST path` with a JSON body over one `connection: close` exchange.
pub fn http_post(
    addr: SocketAddr,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> Result<HttpResponse> {
    request(addr, "POST", path, headers, body)
}

/// `GET path` over one `connection: close` exchange.
pub fn http_get(addr: SocketAddr, path: &str) -> Result<HttpResponse> {
    request(addr, "GET", path, &[], &[])
}

fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> Result<HttpResponse> {
    let mut s = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let mut head = format!("{method} {path} HTTP/1.1\r\nhost: {addr}\r\n");
    head.push_str("content-type: application/json\r\n");
    head.push_str(&format!("content-length: {}\r\n", body.len()));
    for (k, v) in headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("connection: close\r\n\r\n");
    s.write_all(head.as_bytes())?;
    s.write_all(body)?;
    let mut raw = Vec::new();
    s.read_to_end(&mut raw)?;
    parse_response(&raw)
}

fn parse_response(raw: &[u8]) -> Result<HttpResponse> {
    let pos = find_seq(raw, b"\r\n\r\n").context("no header terminator in response")?;
    let head = std::str::from_utf8(&raw[..pos]).context("response head is not UTF-8")?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .with_context(|| format!("bad status line '{status_line}'"))?
        .parse()
        .with_context(|| format!("bad status in '{status_line}'"))?;
    let mut headers = Vec::new();
    for l in lines {
        if let Some((k, v)) = l.split_once(':') {
            headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
    }
    let mut body = raw[pos + 4..].to_vec();
    let chunked = headers
        .iter()
        .any(|(k, v)| k == "transfer-encoding" && v.contains("chunked"));
    if chunked {
        body = dechunk(&body)?;
    }
    Ok(HttpResponse { status, headers, body })
}

fn dechunk(b: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    let mut i = 0usize;
    loop {
        let rel = find_seq(&b[i..], b"\r\n").context("unterminated chunk-size line")?;
        let size_txt = std::str::from_utf8(&b[i..i + rel]).context("chunk size not UTF-8")?;
        let size = usize::from_str_radix(size_txt.trim(), 16)
            .with_context(|| format!("bad chunk size '{size_txt}'"))?;
        i += rel + 2;
        if size == 0 {
            return Ok(out);
        }
        anyhow::ensure!(i + size <= b.len(), "chunk overruns the body");
        out.extend_from_slice(&b[i..i + size]);
        i += size + 2; // past the chunk's trailing CRLF
    }
}

/// Build a `POST /v1/completions` body for a generate request — the
/// wire twin of in-process `submit_generate(session, prompt, params,
/// stop)`. [`super::router::parse_completion`] reverses it exactly
/// (round-trip pinned by a test), which is what makes socket replay
/// bit-identical to in-process replay. Note `params.seed` crosses the
/// wire as a JSON number: exact up to 2^53 (API.md documents the bound).
pub fn completion_body(
    session: Option<u64>,
    prompt: &[TokenId],
    params: &SamplingParams,
    stop: &StopCriteria,
    stream: bool,
) -> Json {
    completion_body_prefixed(session, prompt, params, stop, stream, 0, None)
}

/// [`completion_body`] naming a shared prompt prefix: the wire twin of
/// `submit_generate_prefixed`. `prefix_len` 0 omits both prefix fields
/// (byte-identical to the pre-prefix wire format).
pub fn completion_body_prefixed(
    session: Option<u64>,
    prompt: &[TokenId],
    params: &SamplingParams,
    stop: &StopCriteria,
    stream: bool,
    prefix_len: usize,
    prefix_id: Option<u64>,
) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![
        ("prompt", tokens_json(prompt)),
        ("max_tokens", Json::Num(stop.max_new as f64)),
        ("temperature", Json::Num(params.temperature as f64)),
        ("top_k", Json::Num(params.top_k as f64)),
        ("top_p", Json::Num(params.top_p as f64)),
        ("repetition_penalty", Json::Num(params.rep_penalty as f64)),
        ("repetition_window", Json::Num(params.rep_window as f64)),
        ("seed", Json::Num(params.seed as f64)),
        ("stream", Json::Bool(stream)),
    ];
    if let Some(s) = session {
        pairs.push(("session", Json::Num(s as f64)));
    }
    if let Some(t) = stop.stop_tokens.first() {
        pairs.push(("stop_token", Json::Num(*t as f64)));
    }
    if prefix_len > 0 {
        pairs.push(("prefix_len", Json::Num(prefix_len as f64)));
        if let Some(id) = prefix_id {
            pairs.push(("prefix_id", Json::Num(id as f64)));
        }
    }
    Json::obj(pairs)
}

// ------------------------------------------------------------------ CLI

/// `ovq serve-http [--port P] [--max-inflight N] [--tenant-rate R]
///                 [--tenant-burst B] [--max-body BYTES]
///                 [--max-prompt T] [--max-new-cap T]
///                 [--vocab V] [--layers L] [--d-model D] [--d-ff F]
///                 [--heads H] [--dhead D] [--chunk C] [--schedule S]
///                 [--quant none|f16|i8] [--threads W] [--queue-depth Q]
///                 [--max-resident R] [--prefill-quantum Q]
///                 [--gen-quantum G] [--seed S]
///                 [--spill-dir DIR] [--ram-blob-budget B]
///                 [--no-prefix-cache] [--obs off|metrics|trace]
///                 [--replay N [--over-http] [--stream] [--sessions S]
///                  [--data-seed D] [--prefix-tokens P]]`
///
/// `--obs` sets the process observability level (default `metrics`):
/// `trace` additionally captures per-stage spans for `GET /v1/trace`,
/// `off` silences the request-id log field and span capture. Metrics
/// recording itself is always on — it backs the end-of-run reports.
///
/// Start the HTTP edge over a seeded LM engine (same model surface as
/// `generate`). With `--replay N` it instead generates an N-event
/// deterministic zipf trace, drives its generate requests through the
/// engine — over a real localhost socket with `--over-http` (optionally
/// SSE-streamed with `--stream`), in-process otherwise — prints the
/// edge stats and the engine report, and exits; without it the server
/// runs until killed. README has the walkthrough.
pub fn cmd_serve_http(args: &Args) -> Result<()> {
    crate::util::log::init();
    let level = ObsLevel::parse(&args.opt_or("obs", "metrics")).map_err(anyhow::Error::msg)?;
    obs::set_level(level);
    let vocab = args.opt_usize("vocab", 256)?;
    let layers = args.opt_usize("layers", 2)?;
    let heads = args.opt_usize("heads", 2)?;
    let d_head = args.opt_usize("dhead", 16)?;
    let d_model = args.opt_usize("d-model", heads * d_head)?;
    let d_ff = args.opt_usize("d-ff", 4 * d_model)?;
    let chunk = args.opt_usize("chunk", 32)?;
    let schedule = args.opt_or("schedule", "ovq:256,kv:win128");
    let kinds = parse_schedule(&schedule, layers)?;
    let quant = QuantMode::parse(&args.opt_or("quant", "none"))?;
    let lm = LmConfig::new(
        vocab,
        StackConfig::hybrid(d_model, d_ff, heads, d_head, chunk, kinds).with_quant(quant),
    );
    lm.validate()?;

    let mut ecfg = EngineConfig::for_lm(lm);
    ecfg.threads = args.opt_usize("threads", 2)?;
    ecfg.max_resident = args.opt_usize("max-resident", usize::MAX / 2)?;
    ecfg.queue_depth = args.opt_usize("queue-depth", 64)?;
    ecfg.prefill_quantum = args.opt_usize("prefill-quantum", 512)?;
    ecfg.gen_quantum = args.opt_usize("gen-quantum", 16)?;
    ecfg.seed = args.opt_u64("seed", 0x6E6E)?;
    ecfg.spill_dir = args.opt("spill-dir").map(std::path::PathBuf::from);
    ecfg.ram_blob_budget = args.opt_usize("ram-blob-budget", ecfg.ram_blob_budget)?;
    ecfg.prefix_cache = !args.has_flag("no-prefix-cache");

    let replay_events = args.opt_usize("replay", 0)?;
    // demo (--replay) mode defaults to an ephemeral port so repeated
    // runs never clash; a served deployment defaults to 8080
    let default_port = if replay_events > 0 { 0 } else { 8080 };
    let d = HttpConfig::default();
    let hcfg = HttpConfig {
        port: args.opt_u16("port", default_port)?,
        max_inflight: args.opt_usize("max-inflight", d.max_inflight)?,
        tenant_rate: args.opt_f64("tenant-rate", d.tenant_rate)?,
        tenant_burst: args.opt_f64("tenant-burst", d.tenant_burst)?,
        max_body: args.opt_usize("max-body", d.max_body)?,
        max_prompt: args.opt_usize("max-prompt", d.max_prompt)?,
        max_new_cap: args.opt_usize("max-new-cap", d.max_new_cap)?,
        read_timeout_ms: d.read_timeout_ms,
    };

    let engine = DecodeEngine::start(ecfg);
    let server = HttpServer::start(hcfg, engine.handle())?;
    crate::info!(
        "serving http://{}  (POST /v1/completions, GET /v1/health, GET /v1/stats, \
         GET /metrics, GET /v1/trace; obs={}; [{schedule}] x {layers} layers, \
         vocab {vocab}, {} shard threads)",
        server.addr(),
        level.as_str(),
        engine.threads(),
    );

    if replay_events == 0 {
        loop {
            thread::park(); // serve until the process is killed
        }
    }

    let sessions = args.opt_usize("sessions", 32)?;
    let data_seed = args.opt_u64("data-seed", 0xDA7A)?;
    let over_http = args.has_flag("over-http") || args.opt("over-http").is_some();
    let stream = args.has_flag("stream") || args.opt("stream").is_some();
    // --prefix-tokens P arms the shared-system-prompt mix: half the
    // generate requests open with the same P-token prefix, exercising
    // the engine's copy-on-write prefix cache over the wire
    let prefix_tokens = args.opt_usize("prefix-tokens", 0)?;
    let tcfg = traffic::TrafficConfig::new(sessions, replay_events)
        .with_generates(vec![16, 64], vec![8, 16, 32], 0.9, 0.5)
        .with_prefix(prefix_tokens, 0.5);
    let events = traffic::generate(&tcfg);
    let t0 = Instant::now();
    let served = if over_http {
        traffic::replay_over_http(server.addr(), &events, data_seed, vocab, stream)?.len()
    } else {
        traffic::replay(&engine, &events, data_seed, None);
        events.iter().filter(|e| e.generate).count()
    };
    let wall = t0.elapsed();
    let stats = http_get(server.addr(), "/v1/stats")?;
    crate::info!(
        "replayed {replay_events} events ({served} completions, {}) in {:.2}s",
        if over_http { "over the socket" } else { "in-process" },
        wall.as_secs_f64(),
    );
    println!("{}", String::from_utf8_lossy(&stats.body));
    server.stop();
    engine.finish().print();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_lm_engine(threads: usize) -> DecodeEngine {
        let kinds = parse_schedule("ovq:16", 1).unwrap();
        let lm = LmConfig::new(32, StackConfig::hybrid(8, 16, 2, 4, 8, kinds));
        let mut cfg = EngineConfig::for_lm(lm);
        cfg.threads = threads;
        cfg.seed = 0x6E6E;
        DecodeEngine::start(cfg)
    }

    #[test]
    fn tenant_gate_enforces_rate_with_a_retry_hint() {
        let g = TenantGate::new(2.0, 2.0);
        assert!(g.admit("a").is_ok());
        assert!(g.admit("a").is_ok());
        let retry = g.admit("a").expect_err("burst of 2 must refuse the 3rd");
        assert!(retry >= 1, "retry hint {retry}");
        assert!(g.admit("b").is_ok(), "tenants are isolated");
        let off = TenantGate::new(0.0, 0.0);
        for _ in 0..100 {
            assert!(off.admit("a").is_ok(), "rate 0 disables the gate");
        }
    }

    #[test]
    fn completion_body_round_trips_through_the_validator() {
        let params = SamplingParams::sampled(0xDA7A ^ 5);
        let mut stop = StopCriteria::max_new(17);
        stop.stop_tokens.push(9);
        let body = completion_body(Some(5), &[1, 2, 3], &params, &stop, true);
        let lim = CompletionLimits { vocab: 32, max_prompt: 64, max_new: 64 };
        let wire = json_parse(&body.to_string()).unwrap();
        let req = parse_completion(&wire, &lim).unwrap();
        assert_eq!(req.prompt, vec![1, 2, 3]);
        assert_eq!(req.params, params, "sampling params must survive the wire");
        assert_eq!(req.stop.max_new, 17);
        assert_eq!(req.stop.stop_tokens, vec![9]);
        assert_eq!(req.session, Some(5));
        assert!(req.stream);
    }

    #[test]
    fn chunked_sse_bodies_decode() {
        let mut wire = Vec::new();
        wire.extend_from_slice(b"HTTP/1.1 200 OK\r\ntransfer-encoding: chunked\r\n\r\n");
        for ev in ["{\"token\":4,\"index\":0}", "[DONE]"] {
            let data = format!("data: {ev}\n\n");
            wire.extend_from_slice(format!("{:x}\r\n", data.len()).as_bytes());
            wire.extend_from_slice(data.as_bytes());
            wire.extend_from_slice(b"\r\n");
        }
        wire.extend_from_slice(b"0\r\n\r\n");
        let resp = parse_response(&wire).unwrap();
        assert_eq!(resp.status, 200);
        let data = resp.sse_data();
        assert_eq!(data, vec!["{\"token\":4,\"index\":0}".to_string(), "[DONE]".to_string()]);
        let ev = json_parse(&data[0]).unwrap();
        assert_eq!(ev.get("token").unwrap().as_u64(), Some(4));
    }

    #[test]
    fn server_serves_health_completions_stats_and_404() {
        let engine = tiny_lm_engine(2);
        let server = HttpServer::start(HttpConfig::default(), engine.handle()).unwrap();
        let addr = server.addr();

        let h = http_get(addr, "/v1/health").unwrap();
        assert_eq!(h.status, 200);
        assert_eq!(h.json().unwrap().get("status").unwrap().as_str(), Some("ok"));

        // a blocking completion over the socket ...
        let prompt = traffic::synth_tokens(0xDA7A, 7, 12, 32);
        let stop = StopCriteria::max_new(6);
        let body = completion_body(Some(7), &prompt, &SamplingParams::greedy(), &stop, false);
        let r = http_post(addr, "/v1/completions", &[], body.to_string().as_bytes()).unwrap();
        assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
        let j = r.json().unwrap();
        let served = token_ids(j.get("tokens").unwrap()).unwrap();
        assert_eq!(served.len(), 6);
        assert_eq!(j.get("finish_reason").unwrap().as_str(), Some("length"));

        // ... is bit-identical to the same request in-process
        let local = tiny_lm_engine(1);
        local.submit_generate(7, prompt, SamplingParams::greedy(), stop);
        let report = local.finish();
        assert_eq!(report.generations[0].tokens, served, "socket vs in-process");

        let s = http_get(addr, "/v1/stats").unwrap();
        assert_eq!(s.status, 200);
        let sj = s.json().unwrap();
        assert!(sj.get("completions").unwrap().as_u64().unwrap() >= 1);
        assert_eq!(sj.at(&["engine", "threads"]).unwrap().as_u64(), Some(2));

        let nf = http_get(addr, "/nope").unwrap();
        assert_eq!(nf.status, 404);
        let nfj = nf.json().unwrap();
        assert_eq!(nfj.at(&["error", "code"]).unwrap().as_str(), Some("not_found"));

        server.stop();
        engine.finish();
    }

    #[test]
    fn metrics_trace_and_request_id_serve_over_the_socket() {
        let engine = tiny_lm_engine(2);
        let server = HttpServer::start(HttpConfig::default(), engine.handle()).unwrap();
        let addr = server.addr();

        // a minted request id echoes as hex on every endpoint
        let h = http_get(addr, "/v1/health").unwrap();
        let minted = h.header("x-request-id").expect("health echoes a request id");
        assert!(
            !minted.is_empty() && minted.chars().all(|c| c.is_ascii_hexdigit()),
            "minted id '{minted}' should be hex",
        );

        // a client-supplied id echoes verbatim, and the completion
        // carries a timing object with consistent parts
        let stop = StopCriteria::max_new(4);
        let body = completion_body(Some(3), &[1, 2, 3], &SamplingParams::greedy(), &stop, false);
        let r = http_post(
            addr,
            "/v1/completions",
            &[("x-request-id", "req-abc-123")],
            body.to_string().as_bytes(),
        )
        .unwrap();
        assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
        assert_eq!(r.header("x-request-id"), Some("req-abc-123"));
        let j = r.json().unwrap();
        let total = j.at(&["timing", "total_us"]).unwrap().as_u64().unwrap();
        let parts: u64 = ["queue_us", "prefill_us", "decode_us"]
            .into_iter()
            .map(|k| j.at(&["timing", k]).unwrap().as_u64().unwrap())
            .sum();
        assert!(parts <= total, "timing parts {parts} exceed total {total}");

        // /metrics speaks Prometheus text and includes engine + edge rows
        let m = http_get(addr, "/metrics").unwrap();
        assert_eq!(m.status, 200);
        assert!(
            m.header("content-type").unwrap().starts_with("text/plain"),
            "metrics content type",
        );
        let text = String::from_utf8_lossy(&m.body);
        assert!(text.contains("# TYPE ovq_completion_ns histogram"), "{text}");
        assert!(text.contains("ovq_http_completions_total 1"), "{text}");

        // /v1/trace serves the span list (empty unless --obs trace —
        // the level is process-global, so this test doesn't flip it)
        let tr = http_get(addr, "/v1/trace?n=8").unwrap();
        assert_eq!(tr.status, 200);
        let tj = tr.json().unwrap();
        assert!(tj.get("spans").unwrap().as_arr().is_some(), "spans is an array");
        let bad = http_get(addr, "/v1/trace?n=zap").unwrap();
        assert_eq!(bad.status, 400, "non-numeric ?n= is a clean 400");

        server.stop();
        engine.finish();
    }
}
