//! Metrics logging: per-step CSV + simple aggregation helpers.

use anyhow::Result;

use crate::util::csv::CsvWriter;

pub struct MetricsLog {
    csv: CsvWriter,
    keys: Vec<String>,
}

impl MetricsLog {
    pub fn create(path: &str) -> Result<MetricsLog> {
        let csv = CsvWriter::create(path, &["step", "key", "value"])?;
        Ok(MetricsLog { csv, keys: Vec::new() })
    }

    pub fn record(&mut self, step: usize, kv: &[(&str, f64)]) -> Result<()> {
        for (k, v) in kv {
            if !self.keys.iter().any(|x| x == k) {
                self.keys.push(k.to_string());
            }
            self.csv
                .row(&[step.to_string(), k.to_string(), format!("{v}")])?;
        }
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.csv.flush()?;
        Ok(())
    }
}

/// Accuracy accumulator over eval batches: sum(correct)/sum(mask).
#[derive(Debug, Clone, Default)]
pub struct Accuracy {
    pub correct: f64,
    pub total: f64,
}

impl Accuracy {
    pub fn add(&mut self, correct: &[f32], mask: &[f32]) {
        self.correct += correct.iter().map(|&c| c as f64).sum::<f64>();
        self.total += mask.iter().map(|&m| m as f64).sum::<f64>();
    }

    pub fn value(&self) -> f64 {
        if self.total > 0.0 {
            self.correct / self.total
        } else {
            f64::NAN
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_accumulates() {
        let mut a = Accuracy::default();
        a.add(&[1.0, 0.0, 1.0], &[1.0, 1.0, 1.0]);
        a.add(&[0.0, 0.0], &[1.0, 0.0]);
        assert!((a.value() - 0.5).abs() < 1e-12);
    }
}
