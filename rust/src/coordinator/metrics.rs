//! Metrics logging: per-step CSV + simple aggregation helpers.

use std::collections::BTreeSet;

use anyhow::Result;

use crate::util::csv::CsvWriter;

pub struct MetricsLog {
    csv: CsvWriter,
    keys: BTreeSet<String>,
}

impl MetricsLog {
    pub fn create(path: &str) -> Result<MetricsLog> {
        let csv = CsvWriter::create(path, &["step", "key", "value"])?;
        Ok(MetricsLog { csv, keys: BTreeSet::new() })
    }

    pub fn record(&mut self, step: usize, kv: &[(&str, f64)]) -> Result<()> {
        for (k, v) in kv {
            if !self.keys.contains(*k) {
                self.keys.insert(k.to_string());
            }
            self.csv
                .row(&[step.to_string(), k.to_string(), format!("{v}")])?;
        }
        Ok(())
    }

    /// Distinct keys seen so far, in sorted order.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.keys.iter().map(|s| s.as_str())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.csv.flush()?;
        Ok(())
    }
}

/// Accuracy accumulator over eval batches: sum(correct)/sum(mask).
#[derive(Debug, Clone, Default)]
pub struct Accuracy {
    pub correct: f64,
    pub total: f64,
}

impl Accuracy {
    pub fn add(&mut self, correct: &[f32], mask: &[f32]) {
        self.correct += correct.iter().map(|&c| c as f64).sum::<f64>();
        self.total += mask.iter().map(|&m| m as f64).sum::<f64>();
    }

    pub fn value(&self) -> f64 {
        if self.total > 0.0 {
            self.correct / self.total
        } else {
            f64::NAN
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_accumulates() {
        let mut a = Accuracy::default();
        a.add(&[1.0, 0.0, 1.0], &[1.0, 1.0, 1.0]);
        a.add(&[0.0, 0.0], &[1.0, 0.0]);
        assert!((a.value() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn record_pins_the_csv_shape_and_dedups_keys() {
        let dir = std::env::temp_dir().join(format!("ovq-metrics-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.csv");
        let mut log = MetricsLog::create(path.to_str().unwrap()).unwrap();
        log.record(0, &[("loss", 2.5), ("lr", 0.1)]).unwrap();
        log.record(1, &[("loss", 2.0), ("lr", 0.1)]).unwrap();
        log.flush().unwrap();
        assert_eq!(log.keys().collect::<Vec<_>>(), ["loss", "lr"]);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "step,key,value");
        assert_eq!(lines[1], "0,loss,2.5");
        assert_eq!(lines[2], "0,lr,0.1");
        assert_eq!(lines[3], "1,loss,2");
        assert_eq!(lines[4], "1,lr,0.1");
        assert_eq!(lines.len(), 5);
        std::fs::remove_dir_all(&dir).ok();
    }
}
