//! L3 coordinator: the training loop, length-sweep evaluator, experiment
//! drivers (one per paper figure/table), the batched scoring server, and
//! the serving stack's decode side — the sharded multi-threaded decode
//! [`engine`] with session lifecycle (decode, prefill, and self-feeding
//! generation via the [`sampler`] stack), the [`traffic`] load generator
//! that drives it, and the network edge: typed routing/validation in
//! [`router`] under the [`http`] server (`serve-http`) with SSE token
//! streaming, per-tenant admission control, and overload shedding.
//! Observability threads through the whole stack: the engine records
//! into a lock-free registry ([`crate::util::obs`]) that the edge serves
//! as Prometheus text (`GET /metrics`), with per-request trace spans
//! (`GET /v1/trace`) and `x-request-id` correlation at `--obs trace`.

pub mod engine;
pub mod evaluator;
pub mod experiments;
pub mod http;
pub mod metrics;
pub mod router;
pub mod sampler;
pub mod server;
pub mod trainer;
pub mod traffic;

use anyhow::{Context, Result};

use crate::runtime::Runtime;
use crate::util::cli::Args;

pub fn runtime_from(args: &Args) -> Result<Runtime> {
    match args.opt("artifacts") {
        Some(dir) => Runtime::new(dir),
        None => Runtime::from_env(),
    }
}

/// `ovq train --model M --task T [--steps N] [--seed S] [--out DIR]`
pub fn cmd_train(args: &Args) -> Result<()> {
    let rt = runtime_from(args)?;
    let model = args.opt("model").context("--model required (usage: ovq train --model M)")?;
    let task = args.opt("task").context("--task required (usage: ovq train --task T)")?;
    let cfg = trainer::TrainConfig {
        model: model.to_string(),
        task: task.to_string(),
        steps: args.opt_usize("steps", 0)?, // 0 = manifest total_steps
        seed: args.opt_u64("seed", 42)?,
        log_every: args.opt_usize("log-every", 25)?,
        out_dir: args.opt_or("out", "results"),
        resume: args.opt("ckpt").map(String::from),
    };
    let summary = trainer::train(&rt, &cfg)?;
    println!(
        "trained {model} on {task}: final loss {:.4} ({} steps, {:.2} s/step) -> {}",
        summary.final_loss, summary.steps, summary.sec_per_step, summary.ckpt_path
    );
    Ok(())
}

/// `ovq eval --model M --task T --ckpt F [--batches N]`
pub fn cmd_eval(args: &Args) -> Result<()> {
    let rt = runtime_from(args)?;
    let model_name = args.opt("model").context("--model required (usage: ovq eval --model M)")?;
    let task = args.opt("task").context("--task required (usage: ovq eval --task T)")?;
    let ckpt = args.opt("ckpt").context("--ckpt required (usage: ovq eval --ckpt F)")?;
    let model = rt.load_model(model_name)?;
    let state = model.load_checkpoint(ckpt)?;
    let points = evaluator::length_sweep(
        &model,
        &state.params,
        task,
        args.opt_usize("batches", 4)?,
        args.opt_u64("seed", 7)?,
        None,
    )?;
    evaluator::print_sweep(model_name, &points);
    Ok(())
}
