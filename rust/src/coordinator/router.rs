//! Typed routing and request validation for the HTTP serving edge.
//!
//! The edge's contract with clients lives here, split from the socket
//! plumbing in [`super::http`] so it is testable without a listener:
//!
//! - [`route`] — method + path dispatch with the correct failure split
//!   (`404 not_found` for unknown paths, `405 method_not_allowed` with an
//!   `Allow` hint for known paths hit with the wrong verb);
//! - [`parse_completion`] — the `POST /v1/completions` body schema:
//!   typed extraction of every field, bounds from [`CompletionLimits`],
//!   and sampling-parameter validation through
//!   [`SamplingParams::validate`];
//! - [`ApiError`] — the full error taxonomy: every way a request can be
//!   refused, each with a stable machine-readable `code`, an HTTP
//!   status, and a retryability bit. API.md documents the table; the
//!   tests here pin every variant's code and status so the documented
//!   surface cannot drift silently.

use std::collections::BTreeMap;
use std::fmt;

use super::sampler::{SamplingParams, StopCriteria};
use crate::ovqcore::lm::TokenId;
use crate::util::json::Json;

/// The endpoints of the serving edge (API.md has the reference).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// `GET /v1/health` — liveness probe
    Health,
    /// `GET /v1/stats` — edge + engine telemetry as JSON
    Stats,
    /// `GET /metrics` — the registry in Prometheus text exposition
    Metrics,
    /// `GET /v1/trace` — recent trace spans as JSON (`?n=` caps them)
    Trace,
    /// `POST /v1/completions` — blocking or SSE-streamed generation
    Completions,
}

/// Method + path dispatch. Query strings are ignored for matching.
pub fn route(method: &str, path: &str) -> Result<Route, ApiError> {
    let path = path.split('?').next().unwrap_or(path);
    let allow = |m: &str, allow: &'static str, r: Route| {
        if method == m {
            Ok(r)
        } else {
            Err(ApiError::MethodNotAllowed { allow })
        }
    };
    match path {
        "/v1/health" => allow("GET", "GET", Route::Health),
        "/v1/stats" => allow("GET", "GET", Route::Stats),
        "/metrics" => allow("GET", "GET", Route::Metrics),
        "/v1/trace" => allow("GET", "GET", Route::Trace),
        "/v1/completions" => allow("POST", "POST", Route::Completions),
        _ => Err(ApiError::NotFound(path.to_string())),
    }
}

/// Everything that can refuse an API request, with a stable
/// machine-readable code and HTTP status per variant (the taxonomy table
/// in API.md). Construction sites: HTTP framing ([`super::http`]),
/// routing ([`route`]), body validation ([`parse_completion`]), and the
/// admission path (rate limit / inflight cap / engine backpressure).
#[derive(Debug, Clone, PartialEq)]
pub enum ApiError {
    /// malformed HTTP framing: bad request line, unreadable headers, a
    /// body shorter than its `Content-Length`
    BadRequest(String),
    /// the request body is not valid JSON (parser error attached)
    BadJson(String),
    /// a required field is absent (`field` names it)
    MissingField(&'static str),
    /// a field is present but out of range / of the wrong type
    InvalidParam { field: &'static str, reason: String },
    /// the declared `Content-Length` exceeds the configured body cap
    BodyTooLarge { limit: usize },
    /// no such endpoint
    NotFound(String),
    /// known endpoint, wrong verb (`allow` is the `Allow` header value)
    MethodNotAllowed { allow: &'static str },
    /// the tenant's token bucket is empty — per-tenant rate limit
    RateLimited { retry_after: u64 },
    /// the edge or the engine is saturated (inflight cap reached, or the
    /// session's shard queue refused the request) — overload shedding
    Overloaded { retry_after: u64 },
    /// the engine dropped the request after admission (e.g. a corrupt
    /// session restore) — the only 5xx in the taxonomy
    Internal(String),
}

impl ApiError {
    /// Stable machine-readable code, the `error.code` field of every
    /// error body. Codes are API surface: never renamed, only added.
    pub fn code(&self) -> &'static str {
        match self {
            ApiError::BadRequest(_) => "bad_request",
            ApiError::BadJson(_) => "bad_json",
            ApiError::MissingField(_) => "missing_field",
            ApiError::InvalidParam { .. } => "invalid_param",
            ApiError::BodyTooLarge { .. } => "body_too_large",
            ApiError::NotFound(_) => "not_found",
            ApiError::MethodNotAllowed { .. } => "method_not_allowed",
            ApiError::RateLimited { .. } => "rate_limited",
            ApiError::Overloaded { .. } => "overloaded",
            ApiError::Internal(_) => "internal",
        }
    }

    /// HTTP status code.
    pub fn status(&self) -> u16 {
        match self {
            ApiError::BadRequest(_)
            | ApiError::BadJson(_)
            | ApiError::MissingField(_)
            | ApiError::InvalidParam { .. } => 400,
            ApiError::NotFound(_) => 404,
            ApiError::MethodNotAllowed { .. } => 405,
            ApiError::BodyTooLarge { .. } => 413,
            ApiError::RateLimited { .. } | ApiError::Overloaded { .. } => 429,
            ApiError::Internal(_) => 500,
        }
    }

    /// HTTP reason phrase for the status line.
    pub fn reason(&self) -> &'static str {
        match self.status() {
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            413 => "Payload Too Large",
            429 => "Too Many Requests",
            _ => "Internal Server Error",
        }
    }

    /// Whether an identical retry can succeed without changing the
    /// request: true for load-dependent refusals (and transient engine
    /// failures), false for anything the client must fix first.
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            ApiError::RateLimited { .. } | ApiError::Overloaded { .. } | ApiError::Internal(_)
        )
    }

    /// `Retry-After` seconds for the 429 variants.
    pub fn retry_after(&self) -> Option<u64> {
        match self {
            ApiError::RateLimited { retry_after } | ApiError::Overloaded { retry_after } => {
                Some(*retry_after)
            }
            _ => None,
        }
    }

    /// The JSON error body:
    /// `{"error":{"code":..,"message":..,"retryable":..[,"retry_after_s":..]}}`.
    pub fn body(&self) -> Json {
        let mut e = BTreeMap::new();
        e.insert("code".to_string(), Json::Str(self.code().to_string()));
        e.insert("message".to_string(), Json::Str(self.to_string()));
        e.insert("retryable".to_string(), Json::Bool(self.retryable()));
        if let Some(s) = self.retry_after() {
            e.insert("retry_after_s".to_string(), Json::Num(s as f64));
        }
        Json::Obj(BTreeMap::from([("error".to_string(), Json::Obj(e))]))
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ApiError::BadRequest(m) => write!(f, "malformed HTTP request: {m}"),
            ApiError::BadJson(m) => write!(f, "request body is not valid JSON: {m}"),
            ApiError::MissingField(k) => write!(f, "required field '{k}' is missing"),
            ApiError::InvalidParam { field, reason } => write!(f, "invalid '{field}': {reason}"),
            ApiError::BodyTooLarge { limit } => {
                write!(f, "request body exceeds the {limit}-byte limit")
            }
            ApiError::NotFound(p) => write!(f, "no such endpoint: {p}"),
            ApiError::MethodNotAllowed { allow } => {
                write!(f, "method not allowed (allowed: {allow})")
            }
            ApiError::RateLimited { retry_after } => {
                write!(f, "tenant rate limit exceeded; retry in {retry_after}s")
            }
            ApiError::Overloaded { retry_after } => {
                write!(f, "server overloaded; retry in {retry_after}s")
            }
            ApiError::Internal(m) => write!(f, "request failed in the engine: {m}"),
        }
    }
}

impl std::error::Error for ApiError {}

/// Validation bounds of the completions endpoint, set by the server
/// config (`--max-prompt` / `--max-new-cap` on `serve-http`).
#[derive(Debug, Clone, Copy)]
pub struct CompletionLimits {
    /// LM vocabulary: every prompt/stop token id must be below it
    pub vocab: usize,
    /// longest accepted prompt, tokens
    pub max_prompt: usize,
    /// largest accepted `max_tokens`
    pub max_new: usize,
}

/// A validated `POST /v1/completions` request, ready for
/// [`super::engine::EngineHandle::try_submit_generate`].
#[derive(Debug, Clone)]
pub struct CompletionRequest {
    /// client-pinned session id (`None` = the server assigns one).
    /// Pinning matters for reproducibility: the generation RNG seeds on
    /// (engine seed, sampling seed, session), so a replayed request only
    /// reproduces bit-identically under the same session id.
    pub session: Option<u64>,
    pub prompt: Vec<TokenId>,
    pub params: SamplingParams,
    pub stop: StopCriteria,
    /// SSE token streaming instead of a blocking JSON response
    pub stream: bool,
    /// leading prompt tokens shared with other requests — the prefix-cache
    /// candidate span. The first request computes them once and freezes a
    /// copy-on-write template; later requests fork from it bit-identically.
    /// Must leave at least one non-prefix prompt token. 0 = no sharing.
    pub prefix_len: usize,
    /// explicit prefix-cache key. Defaults to a hash of the prefix tokens,
    /// so requests that share tokens share the template automatically;
    /// setting it lets clients namespace templates instead.
    pub prefix_id: Option<u64>,
}

fn f64_field(j: &Json, field: &'static str) -> Result<Option<f64>, ApiError> {
    match j.get(field) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Num(n)) => Ok(Some(*n)),
        Some(_) => Err(ApiError::InvalidParam { field, reason: "must be a number".to_string() }),
    }
}

fn uint_field(j: &Json, field: &'static str) -> Result<Option<u64>, ApiError> {
    match f64_field(j, field)? {
        None => Ok(None),
        Some(n) if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 => Ok(Some(n as u64)),
        Some(_) => Err(ApiError::InvalidParam {
            field,
            reason: "must be a non-negative integer".to_string(),
        }),
    }
}

fn bool_field(j: &Json, field: &'static str) -> Result<Option<bool>, ApiError> {
    match j.get(field) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Bool(b)) => Ok(Some(*b)),
        Some(_) => Err(ApiError::InvalidParam { field, reason: "must be a boolean".to_string() }),
    }
}

/// Validate a parsed `POST /v1/completions` body against `lim`. Unknown
/// fields are ignored (additive API evolution); every known field is
/// type- and range-checked, and the assembled [`SamplingParams`] passes
/// through [`SamplingParams::validate`] so the CLI and the HTTP edge
/// refuse exactly the same parameter space.
pub fn parse_completion(j: &Json, lim: &CompletionLimits) -> Result<CompletionRequest, ApiError> {
    if j.as_obj().is_none() {
        return Err(ApiError::InvalidParam {
            field: "body",
            reason: "must be a JSON object".to_string(),
        });
    }
    let prompt_json = match j.get("prompt") {
        None | Some(Json::Null) => return Err(ApiError::MissingField("prompt")),
        Some(Json::Arr(a)) => a,
        Some(_) => {
            return Err(ApiError::InvalidParam {
                field: "prompt",
                reason: "must be an array of token ids".to_string(),
            })
        }
    };
    if prompt_json.len() > lim.max_prompt {
        let n = prompt_json.len();
        return Err(ApiError::InvalidParam {
            field: "prompt",
            reason: format!("{n} tokens exceeds the {}-token limit", lim.max_prompt),
        });
    }
    let mut prompt = Vec::with_capacity(prompt_json.len());
    for t in prompt_json {
        match t {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && (*n as usize) < lim.vocab => {
                prompt.push(*n as TokenId)
            }
            _ => {
                return Err(ApiError::InvalidParam {
                    field: "prompt",
                    reason: format!("token ids must be integers in [0, {})", lim.vocab),
                })
            }
        }
    }

    let max_tokens = uint_field(j, "max_tokens")?.unwrap_or(64);
    if max_tokens as usize > lim.max_new {
        return Err(ApiError::InvalidParam {
            field: "max_tokens",
            reason: format!("{} exceeds the cap of {}", max_tokens, lim.max_new),
        });
    }

    let params = SamplingParams {
        temperature: f64_field(j, "temperature")?.unwrap_or(0.0) as f32,
        top_k: uint_field(j, "top_k")?.unwrap_or(0) as usize,
        top_p: f64_field(j, "top_p")?.unwrap_or(1.0) as f32,
        rep_penalty: f64_field(j, "repetition_penalty")?.unwrap_or(1.0) as f32,
        rep_window: uint_field(j, "repetition_window")?.unwrap_or(64) as usize,
        seed: uint_field(j, "seed")?.unwrap_or(0x5EED),
    };
    params.validate().map_err(|e| ApiError::InvalidParam {
        field: "sampling",
        reason: format!("{e:#}"),
    })?;

    let mut stop = StopCriteria::max_new(max_tokens as usize);
    if let Some(t) = uint_field(j, "stop_token")? {
        if (t as usize) >= lim.vocab {
            return Err(ApiError::InvalidParam {
                field: "stop_token",
                reason: format!("token ids must be below the vocab of {}", lim.vocab),
            });
        }
        stop.stop_tokens.push(t as TokenId);
    }

    let prefix_len = uint_field(j, "prefix_len")?.unwrap_or(0) as usize;
    if prefix_len > 0 && prefix_len >= prompt.len() {
        return Err(ApiError::InvalidParam {
            field: "prefix_len",
            reason: format!(
                "must leave at least one non-prefix prompt token ({} prefix tokens \
                 for a {}-token prompt)",
                prefix_len,
                prompt.len()
            ),
        });
    }

    Ok(CompletionRequest {
        session: uint_field(j, "session")?,
        prompt,
        params,
        stop,
        stream: bool_field(j, "stream")?.unwrap_or(false),
        prefix_len,
        prefix_id: uint_field(j, "prefix_id")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn lim() -> CompletionLimits {
        CompletionLimits { vocab: 32, max_prompt: 16, max_new: 128 }
    }

    #[test]
    fn routes_dispatch_with_the_right_failure_split() {
        assert_eq!(route("GET", "/v1/health").unwrap(), Route::Health);
        assert_eq!(route("GET", "/v1/stats?pretty=1").unwrap(), Route::Stats);
        assert_eq!(route("GET", "/metrics").unwrap(), Route::Metrics);
        assert_eq!(route("GET", "/v1/trace?n=32").unwrap(), Route::Trace);
        assert_eq!(route("POST", "/v1/completions").unwrap(), Route::Completions);
        // wrong verb on a known path is 405 with an Allow hint, not 404
        let e = route("POST", "/v1/health").unwrap_err();
        assert_eq!(e.status(), 405);
        assert_eq!(e, ApiError::MethodNotAllowed { allow: "GET" });
        assert_eq!(
            route("POST", "/metrics").unwrap_err(),
            ApiError::MethodNotAllowed { allow: "GET" }
        );
        assert_eq!(
            route("POST", "/v1/trace").unwrap_err(),
            ApiError::MethodNotAllowed { allow: "GET" }
        );
        let e = route("GET", "/v1/completions").unwrap_err();
        assert_eq!(e, ApiError::MethodNotAllowed { allow: "POST" });
        // unknown path is 404 regardless of verb
        assert_eq!(route("GET", "/v2/completions").unwrap_err().status(), 404);
        assert_eq!(route("DELETE", "/").unwrap_err().status(), 404);
    }

    #[test]
    fn every_error_variant_has_a_stable_code_status_and_retryability() {
        // the documented taxonomy (API.md): one row per variant. A change
        // here is an API break and must update API.md in the same PR.
        let rows: Vec<(ApiError, &str, u16, bool)> = vec![
            (ApiError::BadRequest("x".into()), "bad_request", 400, false),
            (ApiError::BadJson("x".into()), "bad_json", 400, false),
            (ApiError::MissingField("prompt"), "missing_field", 400, false),
            (
                ApiError::InvalidParam { field: "top_p", reason: "r".into() },
                "invalid_param",
                400,
                false,
            ),
            (ApiError::NotFound("/x".into()), "not_found", 404, false),
            (ApiError::MethodNotAllowed { allow: "GET" }, "method_not_allowed", 405, false),
            (ApiError::BodyTooLarge { limit: 4096 }, "body_too_large", 413, false),
            (ApiError::RateLimited { retry_after: 2 }, "rate_limited", 429, true),
            (ApiError::Overloaded { retry_after: 1 }, "overloaded", 429, true),
            (ApiError::Internal("x".into()), "internal", 500, true),
        ];
        for (e, code, status, retryable) in rows {
            assert_eq!(e.code(), code, "{e:?}");
            assert_eq!(e.status(), status, "{e:?}");
            assert_eq!(e.retryable(), retryable, "{e:?}");
            // serialization round-trips through the JSON layer with the
            // machine fields present
            let body = parse(&e.body().to_string()).unwrap();
            assert_eq!(body.at(&["error", "code"]).unwrap().as_str(), Some(code));
            assert_eq!(
                body.at(&["error", "retryable"]).unwrap().as_bool(),
                Some(retryable),
                "{e:?}"
            );
            assert!(body.at(&["error", "message"]).unwrap().as_str().is_some());
            assert_eq!(
                body.at(&["error", "retry_after_s"]).and_then(|v| v.as_u64()),
                e.retry_after(),
                "{e:?}"
            );
        }
    }

    #[test]
    fn parse_completion_happy_path_and_defaults() {
        let j = parse(r#"{"prompt":[1,2,3]}"#).unwrap();
        let r = parse_completion(&j, &lim()).unwrap();
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.stop.max_new, 64, "default max_tokens");
        assert!(r.params.is_greedy(), "default sampling is greedy");
        assert!(!r.stream);
        assert_eq!(r.session, None);

        let j = parse(
            r#"{"prompt":[0],"max_tokens":5,"temperature":0.8,"top_k":4,"top_p":0.9,
                "repetition_penalty":1.1,"repetition_window":8,"seed":7,"stop_token":9,
                "session":42,"stream":true,"unknown_field":"ignored"}"#,
        )
        .unwrap();
        let r = parse_completion(&j, &lim()).unwrap();
        assert_eq!(r.stop.max_new, 5);
        assert_eq!(r.stop.stop_tokens, vec![9]);
        assert_eq!(r.session, Some(42));
        assert!(r.stream);
        assert!(!r.params.is_greedy());
        assert_eq!(r.params.seed, 7);
        assert_eq!(r.prefix_len, 0, "default: no shared prefix");
        assert_eq!(r.prefix_id, None);
    }

    #[test]
    fn parse_completion_accepts_and_bounds_prefix_fields() {
        let j = parse(r#"{"prompt":[1,2,3,4],"prefix_len":3,"prefix_id":99}"#).unwrap();
        let r = parse_completion(&j, &lim()).unwrap();
        assert_eq!(r.prefix_len, 3);
        assert_eq!(r.prefix_id, Some(99));
        // prefix_len must leave >= 1 non-prefix token for fresh logits
        for body in [
            r#"{"prompt":[1,2,3],"prefix_len":3}"#,
            r#"{"prompt":[1,2,3],"prefix_len":4}"#,
            r#"{"prompt":[1],"prefix_len":-1}"#,
            r#"{"prompt":[1,2],"prefix_id":1.5}"#,
        ] {
            let e = parse_completion(&parse(body).unwrap(), &lim()).unwrap_err();
            assert_eq!(e.code(), "invalid_param", "body {body} -> {e:?}");
            assert_eq!(e.status(), 400, "body {body}");
        }
    }

    #[test]
    fn parse_completion_refuses_each_bad_field_cleanly() {
        let cases = [
            (r#"{}"#, "missing_field"),
            (r#"{"prompt":"abc"}"#, "invalid_param"),
            (r#"{"prompt":[1,2,99]}"#, "invalid_param"),      // out of vocab
            (r#"{"prompt":[1.5]}"#, "invalid_param"),          // non-integer id
            (r#"{"prompt":[-1]}"#, "invalid_param"),           // negative id
            (r#"{"prompt":[1],"max_tokens":100000}"#, "invalid_param"), // over cap
            (r#"{"prompt":[1],"temperature":-1}"#, "invalid_param"),
            (r#"{"prompt":[1],"top_p":0}"#, "invalid_param"),
            (r#"{"prompt":[1],"stop_token":32}"#, "invalid_param"), // = vocab
            (r#"{"prompt":[1],"stream":"yes"}"#, "invalid_param"),
            (r#"{"prompt":[1],"session":-3}"#, "invalid_param"),
            (r#"[1,2,3]"#, "invalid_param"),                   // body not an object
        ];
        for (body, code) in cases {
            let e = parse_completion(&parse(body).unwrap(), &lim()).unwrap_err();
            assert_eq!(e.code(), code, "body {body} -> {e:?}");
            assert_eq!(e.status(), 400, "body {body}");
        }
        // a 17-token prompt overruns the 16-token limit
        let long: Vec<String> = (0..17).map(|_| "1".to_string()).collect();
        let body = format!("{{\"prompt\":[{}]}}", long.join(","));
        let e = parse_completion(&parse(&body).unwrap(), &lim()).unwrap_err();
        assert_eq!(e.code(), "invalid_param");
    }
}
