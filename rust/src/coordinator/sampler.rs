//! The sampling side of the generation subsystem: a composable
//! [`LogitsProcessor`] chain (repetition penalty → temperature → top-k →
//! top-p, the mistral.rs-style split of "shape the distribution" from
//! "draw from it") feeding a deterministic seeded [`Sampler`].
//!
//! Everything here is a pure function of (logits, history, RNG state):
//! the processors own only scratch buffers, the RNG lives in the model's
//! [`GenCore`](crate::ovqcore::lm::GenCore) (so it snapshots with the
//! session), and every tie-break is explicit — a fixed seed replays the
//! same token stream on any platform, thread count, or eviction schedule.
//! [`SamplingParams`] is per-request *config* (it travels with the engine
//! job, not the snapshot); [`StopCriteria`] ends the self-feeding loop.

use anyhow::{bail, Result};

use crate::ovqcore::kernels;
use crate::ovqcore::lm::TokenId;
use crate::util::rng::Rng;

/// Per-request sampling configuration. `temperature == 0` selects greedy
/// decoding (the processors still apply — a repetition penalty shifts
/// the argmax too); the other knobs deactivate at their neutral values
/// (`top_k == 0`, `top_p >= 1`, `rep_penalty == 1`).
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    pub temperature: f32,
    /// keep only the k highest logits (0 = off)
    pub top_k: usize,
    /// nucleus sampling: keep the smallest prefix of the sorted
    /// distribution with cumulative probability >= top_p (>= 1 = off)
    pub top_p: f32,
    /// divide (positive) / multiply (negative) the logits of recently
    /// emitted tokens (1 = off; > 1 discourages repeats)
    pub rep_penalty: f32,
    /// how many recent tokens the penalty ring retains
    pub rep_window: usize,
    /// sampling-stream seed; mixed with the engine seed and session id so
    /// concurrent sessions draw independent, replayable streams
    pub seed: u64,
}

impl SamplingParams {
    /// Greedy decoding: argmax, no masking, no penalty.
    pub fn greedy() -> SamplingParams {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            rep_penalty: 1.0,
            rep_window: 0,
            seed: 0,
        }
    }

    /// A standard sampled mix: temperature 0.8, top-k 40, top-p 0.95,
    /// mild repetition penalty over a 64-token window.
    pub fn sampled(seed: u64) -> SamplingParams {
        SamplingParams {
            temperature: 0.8,
            top_k: 40,
            top_p: 0.95,
            rep_penalty: 1.1,
            rep_window: 64,
            seed,
        }
    }

    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }

    pub fn validate(&self) -> Result<()> {
        if !self.temperature.is_finite() || self.temperature < 0.0 {
            bail!("--temp must be a finite value >= 0 (0 = greedy), got {}", self.temperature);
        }
        if !self.top_p.is_finite() || self.top_p <= 0.0 {
            bail!("--top-p must be in (0, 1] (1 = off), got {}", self.top_p);
        }
        if !self.rep_penalty.is_finite() || self.rep_penalty <= 0.0 {
            bail!("--rep-penalty must be > 0 (1 = off), got {}", self.rep_penalty);
        }
        // the generation ring must stay under the snapshot-restore bound
        // (GenCore rejects caps > 2^20 as corrupt), so an accepted request
        // can always thaw mid-generation
        if self.rep_window > (1 << 20) {
            bail!("--rep-window must be <= {} (got {})", 1 << 20, self.rep_window);
        }
        Ok(())
    }
}

/// When the self-feeding loop ends: a hard cap on new tokens plus an
/// optional stop-token set (the stop token is emitted, then the request
/// completes — the usual EOS convention).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StopCriteria {
    pub max_new: usize,
    pub stop_tokens: Vec<TokenId>,
}

impl StopCriteria {
    pub fn max_new(n: usize) -> StopCriteria {
        StopCriteria { max_new: n, stop_tokens: Vec::new() }
    }

    pub fn with_stop_tokens(mut self, toks: Vec<TokenId>) -> StopCriteria {
        self.stop_tokens = toks;
        self
    }

    /// Has the request finished after emitting `tok` as token number
    /// `produced` (1-based)?
    pub fn should_stop(&self, tok: TokenId, produced: usize) -> bool {
        produced >= self.max_new || self.stop_tokens.contains(&tok)
    }
}

/// One link of the logits chain: reshape the distribution in place,
/// given the session's recent-token history. Mutable so processors can
/// own scratch (the top-k keep-buffer, the nucleus sort) without
/// per-token allocation.
pub trait LogitsProcessor: Send {
    fn name(&self) -> &'static str;
    fn process(&mut self, history: &[TokenId], logits: &mut [f32]);
}

/// CTRL-style repetition penalty: each *distinct* token in the history
/// window has its logit divided (if positive) or multiplied (if
/// negative) by the penalty.
pub struct RepetitionPenalty {
    pub penalty: f32,
}

impl LogitsProcessor for RepetitionPenalty {
    fn name(&self) -> &'static str {
        "repetition_penalty"
    }

    fn process(&mut self, history: &[TokenId], logits: &mut [f32]) {
        for (i, &t) in history.iter().enumerate() {
            // once per distinct token: skip later duplicates (the window
            // is small — rep_window — so the quadratic scan is cheap)
            if history[..i].contains(&t) {
                continue;
            }
            let Some(l) = logits.get_mut(t as usize) else { continue };
            if *l > 0.0 {
                *l /= self.penalty;
            } else {
                *l *= self.penalty;
            }
        }
    }
}

/// Divide every logit by the temperature (> 0, != 1 when active).
pub struct Temperature {
    pub t: f32,
}

impl LogitsProcessor for Temperature {
    fn name(&self) -> &'static str {
        "temperature"
    }

    fn process(&mut self, _history: &[TokenId], logits: &mut [f32]) {
        let inv = 1.0 / self.t;
        for l in logits.iter_mut() {
            *l *= inv;
        }
    }
}

/// Keep the k highest logits, mask the rest to -inf. Threshold via the
/// partial select in [`kernels::top_k_threshold`]; logits tied with the
/// k-th value all survive (deterministic, order-free).
pub struct TopK {
    pub k: usize,
    keep: Vec<f32>,
}

impl TopK {
    pub fn new(k: usize) -> TopK {
        TopK { k, keep: Vec::new() }
    }
}

impl LogitsProcessor for TopK {
    fn name(&self) -> &'static str {
        "top_k"
    }

    fn process(&mut self, _history: &[TokenId], logits: &mut [f32]) {
        let thr = kernels::top_k_threshold(logits, self.k, &mut self.keep);
        if thr == f32::NEG_INFINITY {
            return; // k == 0 or k >= vocab: nothing to mask
        }
        for l in logits.iter_mut() {
            if *l < thr {
                *l = f32::NEG_INFINITY;
            }
        }
    }
}

/// Nucleus (top-p) masking: keep the smallest set of tokens whose
/// softmax probabilities sum to >= p, mask the rest. Ties sort by index
/// (ascending) so the kept set is a pure function of the logits.
pub struct TopP {
    pub p: f32,
    order: Vec<(f32, u32)>,
}

impl TopP {
    pub fn new(p: f32) -> TopP {
        TopP { p, order: Vec::new() }
    }
}

impl LogitsProcessor for TopP {
    fn name(&self) -> &'static str {
        "top_p"
    }

    fn process(&mut self, _history: &[TokenId], logits: &mut [f32]) {
        if self.p >= 1.0 {
            return;
        }
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        if m == f32::NEG_INFINITY {
            return;
        }
        let mut z = 0.0f32;
        self.order.clear();
        for (i, &l) in logits.iter().enumerate() {
            let w = if l > f32::NEG_INFINITY { (l - m).exp() } else { 0.0 };
            z += w;
            // zero-weight entries (masked by an earlier processor, or
            // underflowed) can never be sampled and are already outside
            // the nucleus — keep the sort at O(live log live), not
            // O(vocab log vocab), on the per-token hot path
            if w > 0.0 {
                self.order.push((w, i as u32));
            }
        }
        self.order.sort_unstable_by(|a, b| {
            b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
        });
        // walk the sorted mass until the nucleus is covered; everything
        // after the crossing entry is masked
        let target = self.p * z;
        let mut acc = 0.0f32;
        let mut cut = self.order.len();
        for (rank, &(w, _)) in self.order.iter().enumerate() {
            acc += w;
            if acc >= target {
                cut = rank + 1;
                break;
            }
        }
        for &(_, i) in &self.order[cut..] {
            logits[i as usize] = f32::NEG_INFINITY;
        }
    }
}

/// Build the processor chain a request's params call for, in the fixed
/// order penalty → temperature → top-k → top-p. Neutral knobs are
/// omitted, so greedy-with-defaults runs an empty chain.
pub fn chain_for(params: &SamplingParams) -> Vec<Box<dyn LogitsProcessor>> {
    let mut chain: Vec<Box<dyn LogitsProcessor>> = Vec::new();
    if params.rep_penalty != 1.0 && params.rep_window > 0 {
        chain.push(Box::new(RepetitionPenalty { penalty: params.rep_penalty }));
    }
    if !params.is_greedy() && params.temperature != 1.0 {
        chain.push(Box::new(Temperature { t: params.temperature }));
    }
    if params.top_k > 0 {
        chain.push(Box::new(TopK::new(params.top_k)));
    }
    if params.top_p < 1.0 {
        chain.push(Box::new(TopP::new(params.top_p)));
    }
    chain
}

/// The terminal draw: greedy argmax, or a categorical draw over the
/// softmax of the (processed) logits through the seeded
/// [`Rng::categorical`] — one uniform per token, fully replayable.
pub struct Sampler {
    greedy: bool,
    probs: Vec<f32>,
}

impl Sampler {
    pub fn for_params(params: &SamplingParams) -> Sampler {
        Sampler { greedy: params.is_greedy(), probs: Vec::new() }
    }

    pub fn sample(&mut self, logits: &[f32], rng: &mut Rng) -> TokenId {
        if self.greedy {
            return kernels::argmax(logits) as TokenId;
        }
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        if m == f32::NEG_INFINITY {
            return 0; // degenerate: no live logit (matches argmax's fallback)
        }
        self.probs.clear();
        self.probs.extend(logits.iter().map(|&l| {
            if l > f32::NEG_INFINITY {
                (l - m).exp()
            } else {
                0.0
            }
        }));
        rng.categorical(&self.probs) as TokenId
    }
}

/// One request's complete sampler stack: the processor chain, the
/// terminal sampler, and the stop rule. Owned by the engine's generate
/// job (config + scratch — the *state* that must survive eviction lives
/// in the model's `GenCore`).
pub struct SamplerStack {
    chain: Vec<Box<dyn LogitsProcessor>>,
    sampler: Sampler,
    stop: StopCriteria,
}

impl SamplerStack {
    pub fn new(params: &SamplingParams, stop: StopCriteria) -> SamplerStack {
        SamplerStack { chain: chain_for(params), sampler: Sampler::for_params(params), stop }
    }

    /// Run the chain over `logits` in place and draw the next token.
    pub fn next_token(
        &mut self,
        history: &[TokenId],
        logits: &mut [f32],
        rng: &mut Rng,
    ) -> TokenId {
        for p in &mut self.chain {
            p.process(history, logits);
        }
        self.sampler.sample(logits, rng)
    }

    pub fn should_stop(&self, tok: TokenId, produced: usize) -> bool {
        self.stop.should_stop(tok, produced)
    }

    /// True when `produced` tokens already meet the cap — checked BEFORE
    /// sampling, so `max_new == 0` emits nothing at all.
    pub fn exhausted(&self, produced: usize) -> bool {
        produced >= self.stop.max_new
    }

    /// Chain link names, for reports and tests.
    pub fn chain_names(&self) -> Vec<&'static str> {
        self.chain.iter().map(|p| p.name()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn softmax(logits: &[f32]) -> Vec<f32> {
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let w: Vec<f32> = logits
            .iter()
            .map(|&l| if l > f32::NEG_INFINITY { (l - m).exp() } else { 0.0 })
            .collect();
        let z: f32 = w.iter().sum();
        w.iter().map(|&x| x / z).collect()
    }

    #[test]
    fn greedy_is_argmax_and_ignores_monotone_knobs() {
        let logits = [0.1f32, 2.5, -1.0, 2.4];
        let mut rng = Rng::new(1);
        let mut stack = SamplerStack::new(&SamplingParams::greedy(), StopCriteria::max_new(4));
        assert!(stack.chain_names().is_empty(), "neutral knobs build an empty chain");
        let mut l = logits.to_vec();
        assert_eq!(stack.next_token(&[], &mut l, &mut rng), 1);
        // top-k masking cannot change the argmax
        let mut p = SamplingParams::greedy();
        p.top_k = 2;
        let mut stack = SamplerStack::new(&p, StopCriteria::max_new(4));
        let mut l = logits.to_vec();
        assert_eq!(stack.next_token(&[], &mut l, &mut rng), 1);
    }

    #[test]
    fn top_k_masks_all_but_k() {
        let mut tk = TopK::new(2);
        let mut l = vec![0.5f32, 3.0, 1.0, 2.0, -4.0];
        tk.process(&[], &mut l);
        assert_eq!(l[1], 3.0);
        assert_eq!(l[3], 2.0);
        for i in [0usize, 2, 4] {
            assert_eq!(l[i], f32::NEG_INFINITY, "index {i} must be masked");
        }
        // k >= len is a no-op
        let mut l = vec![1.0f32, 2.0];
        TopK::new(5).process(&[], &mut l);
        assert_eq!(l, vec![1.0, 2.0]);
    }

    #[test]
    fn top_p_keeps_the_minimal_nucleus() {
        // probs ~ [0.643, 0.236, 0.087, 0.032, ...]: p=0.8 keeps exactly
        // the top two (0.643 < 0.8 <= 0.879)
        let mut tp = TopP::new(0.8);
        let mut l = vec![4.0f32, 3.0, 2.0, 1.0, 0.0];
        tp.process(&[], &mut l);
        assert!(l[0].is_finite() && l[1].is_finite());
        for i in 2..5 {
            assert_eq!(l[i], f32::NEG_INFINITY, "index {i} must be outside the nucleus");
        }
        // p >= 1 is a no-op; the top token alone always survives
        let mut l = vec![9.0f32, 0.0];
        TopP::new(1.0).process(&[], &mut l);
        assert!(l.iter().all(|x| x.is_finite()));
        let mut l = vec![9.0f32, 0.0];
        TopP::new(0.01).process(&[], &mut l);
        assert!(l[0].is_finite());
        assert_eq!(l[1], f32::NEG_INFINITY);
    }

    #[test]
    fn repetition_penalty_applies_once_per_distinct_token() {
        let mut rp = RepetitionPenalty { penalty: 2.0 };
        let mut l = vec![4.0f32, -2.0, 1.0];
        // token 0 appears twice in history: still one division
        rp.process(&[0, 1, 0], &mut l);
        assert_eq!(l[0], 2.0, "positive logit divided once");
        assert_eq!(l[1], -4.0, "negative logit multiplied once");
        assert_eq!(l[2], 1.0, "unseen token untouched");
        // out-of-vocab history ids are ignored, not a panic
        rp.process(&[99], &mut l);
        assert_eq!(l, vec![2.0, -4.0, 1.0]);
    }

    #[test]
    fn sampled_stream_is_seed_deterministic_and_in_support() {
        let params = SamplingParams::sampled(11);
        let logits = [1.0f32, 0.5, 3.0, 2.0, -1.0, 0.0];
        let draw = |seed: u64| -> Vec<TokenId> {
            let mut rng = Rng::new(seed);
            let mut stack = SamplerStack::new(&params, StopCriteria::max_new(64));
            let mut hist: Vec<TokenId> = Vec::new();
            (0..64)
                .map(|_| {
                    let mut l = logits.to_vec();
                    let t = stack.next_token(&hist, &mut l, &mut rng);
                    hist.push(t);
                    if hist.len() > 8 {
                        hist.remove(0);
                    }
                    t
                })
                .collect()
        };
        let a = draw(5);
        assert_eq!(a, draw(5), "same seed must replay the same stream");
        assert_ne!(a, draw(6), "different seeds must diverge");
        assert!(a.iter().all(|&t| (t as usize) < logits.len()));
        assert!(a.iter().any(|&t| t != a[0]), "temperature 0.8 should mix tokens");
    }

    #[test]
    fn chain_for_composes_in_order() {
        let names = SamplerStack::new(&SamplingParams::sampled(0), StopCriteria::max_new(1))
            .chain_names();
        assert_eq!(names, vec!["repetition_penalty", "temperature", "top_k", "top_p"]);
        let mut p = SamplingParams::greedy();
        p.rep_penalty = 1.3;
        p.rep_window = 16;
        let names = SamplerStack::new(&p, StopCriteria::max_new(1)).chain_names();
        assert_eq!(names, vec!["repetition_penalty"]);
    }

    #[test]
    fn stop_criteria() {
        let s = StopCriteria::max_new(3).with_stop_tokens(vec![7]);
        assert!(!s.should_stop(1, 1));
        assert!(s.should_stop(7, 1), "stop token fires immediately");
        assert!(s.should_stop(1, 3), "max_new caps the loop");
        // exhaustion is checked BEFORE sampling: max_new 0 emits nothing
        let stack = SamplerStack::new(&SamplingParams::greedy(), StopCriteria::max_new(0));
        assert!(stack.exhausted(0));
        let stack = SamplerStack::new(&SamplingParams::greedy(), StopCriteria::max_new(2));
        assert!(!stack.exhausted(1));
        assert!(stack.exhausted(2));
    }

    #[test]
    fn params_validation() {
        assert!(SamplingParams::greedy().validate().is_ok());
        assert!(SamplingParams::sampled(1).validate().is_ok());
        let mut p = SamplingParams::greedy();
        p.temperature = -1.0;
        assert!(p.validate().is_err());
        let mut p = SamplingParams::greedy();
        p.top_p = 0.0;
        assert!(p.validate().is_err());
        let mut p = SamplingParams::greedy();
        p.rep_penalty = 0.0;
        assert!(p.validate().is_err());
    }

    #[test]
    fn categorical_respects_the_shaped_distribution() {
        // with a huge mass gap, the sampled stream should almost always
        // pick the heavy token — a smoke check that the probs wiring is
        // not inverted
        let mut params = SamplingParams::sampled(0);
        params.top_k = 0;
        params.top_p = 1.0;
        params.rep_penalty = 1.0;
        params.temperature = 1.0;
        let mut stack = SamplerStack::new(&params, StopCriteria::max_new(1));
        let mut rng = Rng::new(2);
        let mut heavy = 0usize;
        for _ in 0..200 {
            let mut l = vec![0.0f32, 8.0, 0.0];
            if stack.next_token(&[], &mut l, &mut rng) == 1 {
                heavy += 1;
            }
        }
        assert!(heavy > 190, "heavy token drawn only {heavy}/200 times");
        let probs = softmax(&[0.0, 8.0, 0.0]);
        assert!(probs[1] > 0.99);
    }
}
