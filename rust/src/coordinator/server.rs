//! Serving layer, both halves of a deployment:
//!
//!  1. **Batched scoring** (vLLM-router-style, scaled to this repo):
//!     clients submit sequences to score; a dynamic batcher groups them up
//!     to the eval program's batch size or a timeout, executes one HLO
//!     call per group, and returns per-request results. Reports latency
//!     percentiles, throughput and batch-slot utilization.
//!  2. **Streaming decode**: the sharded multi-threaded
//!     [`DecodeEngine`](super::engine::DecodeEngine) — H heads x S
//!     concurrent sessions of constant-memory mixer state spread over
//!     worker-thread shards with bounded queues, LRU eviction to snapshot
//!     blobs, and transparent restore. This is the path where the paper's
//!     flat-in-N update cost pays off; it needs no compiled artifacts and
//!     runs everywhere. [`run_decode_engine`] keeps the old single-call
//!     API on top of it.
//!
//! Architecture (path 1): N client threads -> mpsc request queue ->
//! batcher loop (single device owner) -> per-request oneshot-style
//! channels back.

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::engine::{DecodeEngine, EngineConfig, ShardReport};
use super::sampler::{SamplingParams, StopCriteria};
use crate::ovqcore::bank::DecodeChunk;
use crate::ovqcore::kernels;
use crate::ovqcore::lm::LmConfig;
use crate::ovqcore::memstate::{parse_schedule, MixerKind};
use crate::ovqcore::mixer::{print_layer_split, LayerStat, PrefillMode};
use crate::ovqcore::quant::QuantMode;
use crate::ovqcore::stack::StackConfig;
use crate::runtime::Model;
use crate::util::cli::Args;
use crate::util::rng::Rng;
use crate::util::stats;

pub struct ScoreRequest {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
    pub reply: mpsc::Sender<ScoreResponse>,
    pub submitted: Instant,
}

#[derive(Debug, Clone)]
pub struct ScoreResponse {
    pub loss: f32,
    pub accuracy: f64,
    pub latency: Duration,
}

/// The dynamic batcher: drains the queue up to `max_batch` requests or
/// `max_wait`, pads the batch with repeats of the last request, executes,
/// and fans results back out.
pub fn serve_loop(
    model: &Model<'_>,
    prog: &str,
    rx: mpsc::Receiver<ScoreRequest>,
    max_wait: Duration,
) -> Result<ServeStats> {
    let spec = model.manifest.programs[prog].clone();
    let (bmax, t) = (spec.batch.unwrap_or(2), spec.seq.unwrap_or(256));
    let state = model.init(1)?; // serving demo scores under fresh params
    let params = state.params;

    let mut stats_out = ServeStats::default();
    'outer: loop {
        // collect up to bmax requests (blocking on the first)
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break 'outer, // all clients done
        };
        let mut group = vec![first];
        let deadline = Instant::now() + max_wait;
        while group.len() < bmax {
            let left = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(left) {
                Ok(r) => group.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // assemble the padded batch
        let n = group.len();
        let mut tokens = Vec::with_capacity(bmax * t);
        let mut targets = Vec::with_capacity(bmax * t);
        let mut mask = Vec::with_capacity(bmax * t);
        for r in &group {
            assert_eq!(r.tokens.len(), t, "request length must match program");
            tokens.extend_from_slice(&r.tokens);
            targets.extend_from_slice(&r.targets);
            mask.extend_from_slice(&r.mask);
        }
        for _ in n..bmax {
            tokens.extend_from_slice(&group[n - 1].tokens);
            targets.extend_from_slice(&group[n - 1].targets);
            mask.extend(std::iter::repeat_n(0.0, t));
        }

        let out = model.eval(prog, &params, &tokens, &targets, &mask)?;
        let now = Instant::now();
        stats_out.batches += 1;
        stats_out.padded_slots += bmax - n;
        for (i, r) in group.into_iter().enumerate() {
            let row = &out.correct[i * t..(i + 1) * t];
            let mrow = &r.mask;
            let correct: f64 = row.iter().map(|&c| c as f64).sum();
            let total: f64 = mrow.iter().map(|&m| m as f64).sum();
            let resp = ScoreResponse {
                loss: out.loss,
                accuracy: if total > 0.0 { correct / total } else { 0.0 },
                latency: now.duration_since(r.submitted),
            };
            stats_out.latencies_ns.push(resp.latency.as_nanos() as f64);
            stats_out.served += 1;
            let _ = r.reply.send(resp);
        }
    }
    Ok(stats_out)
}

#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub served: usize,
    pub batches: usize,
    /// batch slots filled with padding (wasted device work)
    pub padded_slots: usize,
    pub latencies_ns: Vec<f64>,
}

impl ServeStats {
    /// Fraction of executed batch slots that carried a real request.
    pub fn utilization(&self) -> f64 {
        let total = self.served + self.padded_slots;
        if total == 0 {
            return 0.0;
        }
        self.served as f64 / total as f64
    }

    pub fn report(&self, wall: Duration) {
        println!(
            "served {} requests in {} batches over {:.2}s  ({:.1} req/s, mean batch {:.2}, \
             {} padded slots -> {:.0}% batch utilization)",
            self.served,
            self.batches,
            wall.as_secs_f64(),
            self.served as f64 / wall.as_secs_f64(),
            self.served as f64 / self.batches.max(1) as f64,
            self.padded_slots,
            100.0 * self.utilization(),
        );
        println!(
            "latency p50 {:.1} ms  p90 {:.1} ms  p99 {:.1} ms",
            stats::percentile(&self.latencies_ns, 50.0) / 1e6,
            stats::percentile(&self.latencies_ns, 90.0) / 1e6,
            stats::percentile(&self.latencies_ns, 99.0) / 1e6,
        );
    }
}

// --------------------------------------------------------------- decode

/// Configuration of the streaming-decode engine demo/bench.
#[derive(Debug, Clone)]
pub struct DecodeConfig {
    pub kind: MixerKind,
    pub heads: usize,
    pub streams: usize,
    pub d_head: usize,
    pub chunk: usize,
    /// tokens decoded per stream
    pub tokens: usize,
    pub seed: u64,
    /// shard worker threads (1 = the old single-threaded behavior, same
    /// outputs — per-stream decode is bit-identical across thread counts)
    pub threads: usize,
    /// resident-session cap per shard before LRU eviction to snapshots
    pub max_resident: usize,
    /// bounded per-shard queue depth (submit blocks when full)
    pub queue_depth: usize,
    /// long-prompt tokens ingested per stream before decoding starts
    /// (0 = decode-only, the legacy behavior)
    pub prompt_tokens: usize,
    /// prefill quantum: prompt tokens ingested per scheduling round, with
    /// decode chunks interleaved between quanta
    pub prefill_quantum: usize,
    /// serve full multi-layer model stacks instead of bare per-head
    /// mixers (`--layers`/`--d-model`/`--d-ff`/`--schedule`); the packed
    /// row width becomes d_model and `kind`/`heads`/`d_head` describe the
    /// per-layer attention inside the stack
    pub stack: Option<StackConfig>,
    /// cold-tensor storage mode (`--quant none|f16|i8`): dictionary
    /// tensors for bare mixers, plus weights/embedding when serving
    /// stacks or LMs
    pub quant: QuantMode,
    /// prefill numerics policy (`--prefill-tolerance [--prefill-chunk C]`
    /// opts into the chunkwise-parallel scan forms; default stays the
    /// bit-pinned serial forms)
    pub prefill_mode: PrefillMode,
    /// intra-request fan-out of long prompts across idle shard workers
    /// (`--no-prefill-fanout` disables it)
    pub prefill_fanout: bool,
    /// disk tier for eviction blobs (`--spill-dir DIR`): cold snapshot
    /// blobs write back asynchronously to per-shard subdirectories once
    /// the RAM blob cache exceeds `ram_blob_budget`; a spilled session's
    /// RAM cost drops to an index entry. `None` keeps the pure-RAM store
    pub spill_dir: Option<PathBuf>,
    /// per-shard RAM budget for frozen snapshot blobs, bytes
    /// (`--ram-blob-budget B`; only meaningful with `spill_dir`)
    pub ram_blob_budget: usize,
    /// copy-on-write shared-prefix templates on the LM generate path
    /// (`--no-prefix-cache` disables forking)
    pub prefix_cache: bool,
}

impl DecodeConfig {
    pub fn new(n_max: usize) -> DecodeConfig {
        DecodeConfig {
            kind: MixerKind::Ovq { n_max },
            heads: 4,
            streams: 8,
            d_head: 32,
            chunk: 32,
            tokens: 512,
            seed: 0xDEC0DE,
            threads: 1,
            max_resident: usize::MAX / 2,
            queue_depth: 64,
            prompt_tokens: 0,
            prefill_quantum: 512,
            stack: None,
            quant: QuantMode::None,
            prefill_mode: PrefillMode::Exact,
            prefill_fanout: true,
            spill_dir: None,
            ram_blob_budget: usize::MAX / 2,
            prefix_cache: true,
        }
    }

    /// Packed row width per token: the embedding width for stacks, the
    /// fused-head width for bare mixers.
    pub fn row_width(&self) -> usize {
        match &self.stack {
            Some(s) => s.d_model,
            None => self.heads * self.d_head,
        }
    }

    fn engine_config(&self) -> EngineConfig {
        let mut e = match &self.stack {
            Some(s) => EngineConfig::for_stack(s.clone().with_quant(self.quant)),
            None => EngineConfig::new(self.kind, self.heads, self.d_head, self.chunk),
        };
        e.quant = self.quant;
        e.threads = self.threads;
        e.max_resident = self.max_resident;
        e.queue_depth = self.queue_depth;
        e.prefill_quantum = self.prefill_quantum;
        e.prefill_mode = self.prefill_mode;
        e.prefill_fanout = self.prefill_fanout;
        e.spill_dir = self.spill_dir.clone();
        e.ram_blob_budget = self.ram_blob_budget;
        e.prefix_cache = self.prefix_cache;
        e.seed = self.seed;
        e
    }
}

/// Per-stream chunk-latency percentiles.
#[derive(Debug, Clone)]
pub struct StreamLatency {
    pub stream: usize,
    pub tokens: usize,
    pub p50_us: f64,
    pub p99_us: f64,
}

/// Aggregate result of a decode run.
#[derive(Debug, Clone)]
pub struct DecodeReport {
    pub cfg: DecodeConfig,
    pub wall: Duration,
    pub tokens_total: usize,
    pub state_bytes: usize,
    pub per_stream: Vec<StreamLatency>,
    /// per-shard utilization, queue high-water, eviction/restore counts
    pub shards: Vec<ShardReport>,
    /// cross-shard submit→completion latency percentiles, microseconds
    pub p50_us: f64,
    pub p99_us: f64,
    /// prompt tokens ingested through the prefill path
    pub prefill_tokens: usize,
    /// prompt time-to-first-token percentiles, microseconds (NaN when the
    /// run had no prompts)
    pub ttft_p50_us: f64,
    pub ttft_p99_us: f64,
    pub evictions: usize,
    pub restores: usize,
    /// cross-shard per-layer telemetry (one row per model layer when
    /// serving stacks; a single row for bare mixers)
    pub layers: Vec<LayerStat>,
}

impl DecodeReport {
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_total as f64 / self.wall.as_secs_f64()
    }

    pub fn print(&self) {
        match &self.cfg.stack {
            Some(s) => println!(
                "decode engine: {}-layer stack (d_model={} d_ff={} {} heads x d{})  \
                 {} streams  chunk={}  {} threads",
                s.layers,
                s.d_model,
                s.d_ff,
                s.heads,
                s.d_head,
                self.cfg.streams,
                self.cfg.chunk,
                self.cfg.threads,
            ),
            None => println!(
                "decode engine: {:?}  {} streams x {} heads, d={}  chunk={}  {} threads",
                self.cfg.kind,
                self.cfg.streams,
                self.cfg.heads,
                self.cfg.d_head,
                self.cfg.chunk,
                self.cfg.threads,
            ),
        }
        println!(
            "  kernels: {} backend  |  cold-tensor quant: {}",
            kernels::backend(),
            self.cfg.quant.name(),
        );
        println!(
            "  {} tokens in {:.2}s -> {:.0} tok/s aggregate  ({:.1} KiB total mixer state)",
            self.tokens_total,
            self.wall.as_secs_f64(),
            self.tokens_per_sec(),
            self.state_bytes as f64 / 1024.0,
        );
        println!(
            "  cross-shard latency p50 {:.1} us  p99 {:.1} us  |  {} evictions, {} restores",
            self.p50_us, self.p99_us, self.evictions, self.restores,
        );
        if self.prefill_tokens > 0 {
            println!(
                "  prefill: {} prompt tokens/stream (quantum {})  ttft p50 {:.1} us  \
                 p99 {:.1} us",
                self.cfg.prompt_tokens,
                self.cfg.prefill_quantum,
                self.ttft_p50_us,
                self.ttft_p99_us,
            );
        }
        print_layer_split(&self.layers, self.wall * self.cfg.threads as u32);
        let wall = self.wall.as_secs_f64().max(1e-12);
        for s in &self.shards {
            println!(
                "  shard {:>2}: {:>4} sessions  util {:>5.1}%  max queue {:>3}  \
                 evict/restore {}/{}",
                s.shard,
                s.sessions,
                100.0 * s.busy.as_secs_f64() / wall,
                s.max_queue,
                s.evictions,
                s.restores,
            );
        }
        for s in &self.per_stream {
            println!(
                "  stream {:>3}: {:>6} tokens  chunk latency p50 {:>8.1} us  p99 {:>8.1} us",
                s.stream, s.tokens, s.p50_us, s.p99_us
            );
        }
    }
}

/// Run the multi-stream decode engine: every stream decodes `cfg.tokens`
/// synthetic tokens in `cfg.chunk`-sized chunks through the sharded
/// [`DecodeEngine`], one chunk per stream per round (the steady-state
/// arrival pattern of concurrent sessions). The old single-call API,
/// now backed by `cfg.threads` shard workers — per-stream outputs are
/// bit-identical for any thread count.
pub fn run_decode_engine(cfg: &DecodeConfig) -> DecodeReport {
    let engine = DecodeEngine::start(cfg.engine_config());
    let hd = cfg.row_width();
    let rounds = cfg.tokens.div_ceil(cfg.chunk);
    // pre-generate one full chunk of synthetic activations so the timed
    // region below is pure decode work (same methodology as the benches)
    let mut rng = Rng::new(cfg.seed);
    let mut mk = || -> Vec<f32> { (0..cfg.chunk * hd).map(|_| rng.normal() as f32).collect() };
    let (q, k, v) = (mk(), mk(), mk());
    let t0 = Instant::now();
    if cfg.prompt_tokens > 0 {
        // long-prompt admission: every stream opens with a prompt that the
        // engine ingests in prefill quanta, interleaved with the decode
        // chunks submitted below
        let mut mkp =
            || -> Vec<f32> { (0..cfg.prompt_tokens * hd).map(|_| rng.normal() as f32).collect() };
        let (pq, pk, pv) = (mkp(), mkp(), mkp());
        for s in 0..cfg.streams as u64 {
            engine.submit_prefill(
                s,
                DecodeChunk { queries: pq.clone(), keys: pk.clone(), values: pv.clone() },
            );
        }
    }
    for round in 0..rounds {
        let len = cfg.chunk.min(cfg.tokens - round * cfg.chunk);
        for s in 0..cfg.streams as u64 {
            engine.submit(
                s,
                DecodeChunk {
                    queries: q[..len * hd].to_vec(),
                    keys: k[..len * hd].to_vec(),
                    values: v[..len * hd].to_vec(),
                },
            );
        }
    }
    engine.flush_all();
    let report = engine.finish();
    let wall = t0.elapsed();

    let per_stream = report
        .sessions
        .iter()
        .map(|(id, st)| StreamLatency {
            stream: *id as usize,
            tokens: st.tokens,
            p50_us: st.chunk_p_us(50.0),
            p99_us: st.chunk_p_us(99.0),
        })
        .collect();
    DecodeReport {
        cfg: cfg.clone(),
        wall,
        tokens_total: report.tokens,
        state_bytes: report.state_bytes(),
        per_stream,
        p50_us: report.latency_us(50.0),
        p99_us: report.latency_us(99.0),
        prefill_tokens: report.prefill_tokens(),
        ttft_p50_us: report.ttft_us(50.0),
        ttft_p99_us: report.ttft_us(99.0),
        evictions: report.evictions(),
        restores: report.restores(),
        layers: report.layer_split(),
        shards: report.shards,
    }
}

// ------------------------------------------------------------------ CLI

/// `ovq serve --model M [--requests N] [--clients C] [--task T]
///            [--streams S] [--heads H] [--dhead D] [--nmax N]
///            [--decode-tokens T] [--threads W] [--max-resident R]
///            [--queue-depth Q] [--prompt-tokens P] [--prefill-quantum Q]
///            [--quant none|f16|i8] [--prefill-tolerance]
///            [--prefill-chunk C] [--no-prefill-fanout]
///            [--spill-dir DIR] [--ram-blob-budget B]
///            [--layers L --d-model D --d-ff F --schedule S]`
/// Demo driver: phase 1 runs the batched scorer against the compiled HLO
/// program (skipped with a notice when no backend/artifacts are
/// available); phase 2 runs the sharded streaming-decode engine — over
/// bare mixers by default, or over full multi-layer model stacks when
/// `--layers` is set. `--schedule` is a comma-separated per-layer mixer
/// list cycled over the depth (e.g. `ovq:1024` uniform, or
/// `ovq:1024,kv:win256` for a hybrid stack). `--prefill-tolerance` opts
/// the scan mixers (gdn/lin) into the chunkwise-parallel prefill forms
/// (`--prefill-chunk` tokens per block, default 64) — faster prompt
/// ingestion within the documented error tolerance instead of the
/// bit-pinned serial forms. Long prompts additionally fan out across
/// idle shard workers whenever `--threads > 1`; `--no-prefill-fanout`
/// pins prompt ingestion back onto the owner shard.
pub fn cmd_serve(args: &Args) -> Result<()> {
    crate::util::log::init();
    match super::runtime_from(args) {
        Ok(rt) => serve_batched(&rt, args)?,
        Err(e) => {
            crate::info!("skipping batched-scoring phase (no runtime): {e}");
        }
    }

    let n_max = args.opt_usize("nmax", 1024)?;
    let mut dcfg = DecodeConfig::new(n_max);
    dcfg.streams = args.opt_usize("streams", dcfg.streams)?;
    dcfg.heads = args.opt_usize("heads", dcfg.heads)?;
    dcfg.d_head = args.opt_usize("dhead", dcfg.d_head)?;
    dcfg.chunk = args.opt_usize("chunk", dcfg.chunk)?;
    dcfg.tokens = args.opt_usize("decode-tokens", dcfg.tokens)?;
    dcfg.threads = args.opt_usize("threads", dcfg.threads)?;
    dcfg.max_resident = args.opt_usize("max-resident", dcfg.max_resident)?;
    dcfg.queue_depth = args.opt_usize("queue-depth", dcfg.queue_depth)?;
    dcfg.prompt_tokens = args.opt_usize("prompt-tokens", dcfg.prompt_tokens)?;
    dcfg.prefill_quantum = args.opt_usize("prefill-quantum", dcfg.prefill_quantum)?;
    // accept `--prefill-tolerance` both as a bare flag and as an option
    // (the bare form swallows a following non-`--` token, so also honor
    // `--prefill-tolerance=1` placements)
    if args.has_flag("prefill-tolerance") || args.opt("prefill-tolerance").is_some() {
        dcfg.prefill_mode = PrefillMode::Chunkwise { chunk: args.opt_usize("prefill-chunk", 64)? };
    }
    dcfg.prefill_fanout = !args.has_flag("no-prefill-fanout");
    dcfg.spill_dir = args.opt("spill-dir").map(PathBuf::from);
    dcfg.ram_blob_budget = args.opt_usize("ram-blob-budget", dcfg.ram_blob_budget)?;
    dcfg.quant = QuantMode::parse(&args.opt_or("quant", "none"))?;
    let layers = args.opt_usize("layers", 0)?;
    if layers > 0 {
        let d_model = args.opt_usize("d-model", dcfg.heads * dcfg.d_head)?;
        let d_ff = args.opt_usize("d-ff", 4 * d_model)?;
        let schedule = args.opt_or("schedule", &format!("ovq:{n_max}"));
        let kinds = parse_schedule(&schedule, layers)?;
        let stack =
            StackConfig::hybrid(d_model, d_ff, dcfg.heads, dcfg.d_head, dcfg.chunk, kinds);
        stack.validate()?;
        crate::info!(
            "streaming decode: {layers}-layer stack [{schedule}] d_model={d_model} \
             d_ff={d_ff} ({} heads x d{}), {} streams over {} shard threads",
            dcfg.heads,
            dcfg.d_head,
            dcfg.streams,
            dcfg.threads
        );
        dcfg.stack = Some(stack);
    } else {
        crate::info!(
            "streaming decode: {} streams x {} heads, d={} N={} over {} shard threads \
             ({} prompt tokens, prefill quantum {})",
            dcfg.streams,
            dcfg.heads,
            dcfg.d_head,
            n_max,
            dcfg.threads,
            dcfg.prompt_tokens,
            dcfg.prefill_quantum
        );
    }
    run_decode_engine(&dcfg).print();
    Ok(())
}

/// `ovq generate [--vocab V] [--sessions N] [--prompt-tokens P]
///               [--max-new M] [--temp T] [--top-k K] [--top-p P]
///               [--rep-penalty R] [--rep-window W] [--stop-token T]
///               [--layers L] [--d-model D] [--d-ff F] [--heads H]
///               [--dhead D] [--chunk C] [--schedule S] [--threads W]
///               [--max-resident R] [--prefill-quantum Q]
///               [--gen-quantum G] [--quant none|f16|i8] [--seed S]
///               [--spill-dir DIR] [--ram-blob-budget B]
///               [--no-prefix-cache]`
///
/// End-to-end autoregressive generation: every session submits a
/// deterministic synthetic token prompt; the engine prefills it in
/// quanta, then self-feeds sampled tokens (greedy at the default
/// `--temp 0`, categorical otherwise) until `--max-new` or the stop
/// token fires. Prints each completion's token ids plus the engine
/// report with the decode/prefill/generate occupancy split. The model
/// is a seeded `--layers`-deep hybrid stack under a `--vocab` embedding
/// (`--schedule` as in `serve`).
pub fn cmd_generate(args: &Args) -> Result<()> {
    crate::util::log::init();
    let vocab = args.opt_usize("vocab", 256)?;
    let sessions = args.opt_usize("sessions", 4)?;
    let prompt_tokens = args.opt_usize("prompt-tokens", 128)?;
    let layers = args.opt_usize("layers", 2)?;
    let heads = args.opt_usize("heads", 2)?;
    let d_head = args.opt_usize("dhead", 16)?;
    let d_model = args.opt_usize("d-model", heads * d_head)?;
    let d_ff = args.opt_usize("d-ff", 4 * d_model)?;
    let chunk = args.opt_usize("chunk", 32)?;
    let schedule = args.opt_or("schedule", "ovq:256,kv:win128");
    let kinds = parse_schedule(&schedule, layers)?;
    let quant = QuantMode::parse(&args.opt_or("quant", "none"))?;
    let lm = LmConfig::new(
        vocab,
        StackConfig::hybrid(d_model, d_ff, heads, d_head, chunk, kinds).with_quant(quant),
    );
    lm.validate()?;

    let params = SamplingParams {
        temperature: args.opt_f64("temp", 0.0)? as f32,
        top_k: args.opt_usize("top-k", 0)?,
        top_p: args.opt_f64("top-p", 1.0)? as f32,
        rep_penalty: args.opt_f64("rep-penalty", 1.0)? as f32,
        rep_window: args.opt_usize("rep-window", 64)?,
        seed: args.opt_u64("sample-seed", 0x5EED)?,
    };
    params.validate()?;
    let mut stop = StopCriteria::max_new(args.opt_usize("max-new", 64)?);
    // --stop-token takes a token id < vocab; the default (= vocab) disables it
    let stop_token = args.opt_usize("stop-token", vocab)?;
    if stop_token < vocab {
        stop.stop_tokens.push(stop_token as u32);
    }

    let mut ecfg = EngineConfig::for_lm(lm);
    ecfg.threads = args.opt_usize("threads", 1)?;
    ecfg.max_resident = args.opt_usize("max-resident", usize::MAX / 2)?;
    ecfg.prefill_quantum = args.opt_usize("prefill-quantum", 512)?;
    ecfg.gen_quantum = args.opt_usize("gen-quantum", 16)?;
    ecfg.seed = args.opt_u64("seed", 0x6E6E)?;
    ecfg.spill_dir = args.opt("spill-dir").map(PathBuf::from);
    ecfg.ram_blob_budget = args.opt_usize("ram-blob-budget", ecfg.ram_blob_budget)?;
    ecfg.prefix_cache = !args.has_flag("no-prefix-cache");
    crate::info!(
        "generate: {sessions} sessions x {prompt_tokens}-token prompts -> up to {} new tokens \
         ({} sampling, [{schedule}] x {layers} layers, vocab {vocab}, quant {}, {} kernels) \
         over {} shard threads",
        stop.max_new,
        if params.is_greedy() { "greedy" } else { "categorical" },
        quant.name(),
        kernels::backend(),
        ecfg.threads
    );

    let data_seed = args.opt_u64("data-seed", 0xDA7A)?;
    let engine = DecodeEngine::start(ecfg);
    let t0 = Instant::now();
    for s in 0..sessions as u64 {
        let prompt = super::traffic::synth_tokens(data_seed, s, prompt_tokens, vocab);
        engine.submit_generate(s, prompt, params.clone(), stop.clone());
    }
    let report = engine.finish();
    let wall = t0.elapsed();
    for g in &report.generations {
        let shown: Vec<String> = g.tokens.iter().take(16).map(|t| t.to_string()).collect();
        println!(
            "  session {:>3}: {:>4} tokens  [{}{}]",
            g.session,
            g.tokens.len(),
            shown.join(" "),
            if g.tokens.len() > 16 { " ..." } else { "" },
        );
    }
    report.print();
    println!(
        "  end-to-end: {} completions in {:.2}s -> {:.0} sampled tok/s",
        report.completions(),
        wall.as_secs_f64(),
        report.gen_tokens() as f64 / wall.as_secs_f64().max(1e-12),
    );
    Ok(())
}

/// Phase 1: spin up client threads that generate and submit task
/// sequences, run the batcher until all are served, report stats.
fn serve_batched(rt: &crate::runtime::Runtime, args: &Args) -> Result<()> {
    let model_name = args.opt_or("model", "quickstart");
    let task = args.opt_or("task", "icr");
    let n_requests = args.opt_usize("requests", 32)?;
    let n_clients = args.opt_usize("clients", 4)?;
    let model = rt.load_model(&model_name)?;
    let prog = model
        .manifest
        .eval_programs()
        .first()
        .map(|(k, _)| k.to_string())
        .expect("model has no eval programs");
    let t = model.manifest.programs[&prog].seq.unwrap_or(256);
    let vocab = model.manifest.cfg_usize("vocab", 512);
    // validate the task name once, before any client thread spawns — a
    // typo'd --task is a clean CLI error, not a thread panic
    crate::data::by_name(&task, vocab)?;

    crate::info!(
        "serving {model_name}/{prog} (T={t}) with {n_clients} clients x {} requests",
        n_requests / n_clients
    );

    let (tx, rx) = mpsc::channel::<ScoreRequest>();
    let mut client_handles = Vec::new();
    for c in 0..n_clients {
        let tx = tx.clone();
        let task = task.clone();
        let per = n_requests / n_clients;
        client_handles.push(std::thread::spawn(move || {
            let gen = crate::data::by_name(&task, vocab).expect("task validated before spawn");
            let mut rng = Rng::new(0xC11E07 + c as u64);
            let mut responses = Vec::new();
            for _ in 0..per {
                let ex = gen.generate(&mut rng, t);
                let (rtx, rrx) = mpsc::channel();
                let req = ScoreRequest {
                    tokens: ex.tokens[..t].to_vec(),
                    targets: ex.tokens[1..t + 1].to_vec(),
                    mask: ex.score.iter().map(|&s| if s { 1.0 } else { 0.0 }).collect(),
                    reply: rtx,
                    submitted: Instant::now(),
                };
                tx.send(req).unwrap();
                responses.push(rrx.recv().unwrap());
            }
            responses
        }));
    }
    drop(tx);

    let t0 = Instant::now();
    let stats_out = serve_loop(&model, &prog, rx, Duration::from_millis(5))?;
    let wall = t0.elapsed();
    for h in client_handles {
        h.join().unwrap();
    }
    stats_out.report(wall);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_counts_padding() {
        let s = ServeStats { served: 6, batches: 2, padded_slots: 2, latencies_ns: vec![] };
        assert!((s.utilization() - 0.75).abs() < 1e-12);
        let empty = ServeStats::default();
        assert_eq!(empty.utilization(), 0.0);
    }

    #[test]
    fn decode_engine_round_trip() {
        // small end-to-end decode: correct token accounting, flat state,
        // populated per-stream percentiles
        let mut cfg = DecodeConfig::new(64);
        cfg.streams = 3;
        cfg.heads = 2;
        cfg.d_head = 8;
        cfg.chunk = 16;
        cfg.tokens = 48;
        let r = run_decode_engine(&cfg);
        assert_eq!(r.tokens_total, 3 * 48);
        assert_eq!(r.per_stream.len(), 3);
        for s in &r.per_stream {
            assert_eq!(s.tokens, 48);
            assert!(s.p50_us > 0.0);
            assert!(s.p99_us >= s.p50_us * 0.5);
        }
        assert!(r.state_bytes > 0);
    }

    #[test]
    fn decode_engine_multithreaded_accounts_all_streams() {
        let mut cfg = DecodeConfig::new(64);
        cfg.streams = 6;
        cfg.heads = 2;
        cfg.d_head = 8;
        cfg.chunk = 16;
        cfg.tokens = 64;
        cfg.threads = 4;
        let r = run_decode_engine(&cfg);
        assert_eq!(r.tokens_total, 6 * 64);
        assert_eq!(r.per_stream.len(), 6);
        for s in &r.per_stream {
            assert_eq!(s.tokens, 64, "stream {} short-served", s.stream);
        }
        assert_eq!(r.shards.len(), 4);
        assert_eq!(r.evictions, 0, "uncapped run must not evict");
        // every stream landed on exactly one shard and none were lost
        assert_eq!(r.shards.iter().map(|s| s.sessions).sum::<usize>(), 6);
        assert!(r.p99_us >= r.p50_us * 0.5);
    }

    #[test]
    fn decode_engine_with_prompts_reports_ttft() {
        // every stream opens with a 256-token prompt ingested in 64-token
        // quanta; accounting must cover prompt + decode and surface ttft
        let mut cfg = DecodeConfig::new(64);
        cfg.streams = 2;
        cfg.heads = 1;
        cfg.d_head = 8;
        cfg.chunk = 16;
        cfg.tokens = 32;
        cfg.prompt_tokens = 256;
        cfg.prefill_quantum = 64;
        let r = run_decode_engine(&cfg);
        assert_eq!(r.prefill_tokens, 2 * 256);
        assert_eq!(r.tokens_total, 2 * (256 + 32));
        assert!(r.ttft_p50_us > 0.0);
        assert!(r.ttft_p99_us >= r.ttft_p50_us * 0.5);
        for s in &r.per_stream {
            assert_eq!(s.tokens, 256 + 32, "stream {} accounting", s.stream);
        }
    }

    #[test]
    fn decode_engine_serves_hybrid_stacks_end_to_end() {
        // the serve path over a 2-layer hybrid model stack: full token
        // accounting and a per-layer telemetry split in the report
        let mut cfg = DecodeConfig::new(64);
        cfg.streams = 2;
        cfg.heads = 2;
        cfg.d_head = 4;
        cfg.chunk = 8;
        cfg.tokens = 32;
        cfg.stack = Some(StackConfig::hybrid(
            8,
            16,
            2,
            4,
            8,
            vec![MixerKind::Ovq { n_max: 16 }, MixerKind::SlidingWindow { window: 12 }],
        ));
        assert_eq!(cfg.row_width(), 8);
        let r = run_decode_engine(&cfg);
        assert_eq!(r.tokens_total, 2 * 32);
        assert_eq!(r.per_stream.len(), 2);
        assert_eq!(r.layers.len(), 2, "per-layer split in the decode report");
        assert_eq!(r.layers[0].kind, "ovq");
        assert_eq!(r.layers[1].kind, "sliding_window");
        assert!(r.state_bytes > 0);
        assert_eq!(
            r.layers.iter().map(|l| l.state_bytes).sum::<usize>(),
            r.state_bytes
        );
    }

    #[test]
    fn cmd_generate_runs_end_to_end_with_tiny_shape() {
        let argv: Vec<String> = [
            "generate", "--vocab", "32", "--sessions", "2", "--prompt-tokens", "16",
            "--max-new", "8", "--layers", "1", "--d-model", "8", "--d-ff", "16", "--heads",
            "2", "--dhead", "4", "--chunk", "8", "--schedule", "ovq:16", "--threads", "2",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        cmd_generate(&Args::parse(&argv)).expect("tiny generate run must succeed");
        // bad sampling params surface as clean CLI errors
        let argv: Vec<String> =
            ["generate", "--temp", "-1"].iter().map(|s| s.to_string()).collect();
        assert!(cmd_generate(&Args::parse(&argv)).is_err());
    }

    #[test]
    fn decode_engine_tolerance_mode_serves_scan_mixers() {
        // chunkwise-parallel prefill (--prefill-tolerance) through the
        // whole serve path for a scan mixer: full token accounting, and
        // two runs with the same fixed chunk size agree bit-for-bit on
        // per-stream token counts and state bytes (reproducibility of the
        // blocked schedule — the numerics contract is pinned by the mixer
        // tolerance tests)
        let mut cfg = DecodeConfig::new(64);
        cfg.kind = MixerKind::Gdn;
        cfg.streams = 2;
        cfg.heads = 1;
        cfg.d_head = 8;
        cfg.chunk = 16;
        cfg.tokens = 32;
        cfg.prompt_tokens = 200;
        cfg.prefill_quantum = 64;
        cfg.prefill_mode = PrefillMode::Chunkwise { chunk: 32 };
        let a = run_decode_engine(&cfg);
        assert_eq!(a.prefill_tokens, 2 * 200);
        assert_eq!(a.tokens_total, 2 * (200 + 32));
        let b = run_decode_engine(&cfg);
        assert_eq!(a.tokens_total, b.tokens_total);
        assert_eq!(a.state_bytes, b.state_bytes);
    }

    #[test]
    fn decode_engine_serves_quantized_dictionaries() {
        // --quant i8 through the whole serve path: same token accounting,
        // smaller mixer state (the dictionary grows on the same
        // deterministic schedule in every storage mode)
        let mut cfg = DecodeConfig::new(64);
        cfg.streams = 2;
        cfg.heads = 1;
        cfg.d_head = 8;
        cfg.chunk = 16;
        cfg.tokens = 64;
        let f32_run = run_decode_engine(&cfg);
        cfg.quant = QuantMode::I8;
        let i8_run = run_decode_engine(&cfg);
        assert_eq!(i8_run.tokens_total, 2 * 64);
        assert!(
            i8_run.state_bytes < f32_run.state_bytes,
            "i8 dictionaries must shrink engine state ({} vs {})",
            i8_run.state_bytes,
            f32_run.state_bytes
        );
    }

    #[test]
    fn decode_engine_state_flat_in_context() {
        // decoding 4x more tokens must not grow OVQ mixer state (beyond
        // the saturating dictionary)
        let mut cfg = DecodeConfig::new(32);
        cfg.streams = 2;
        cfg.heads = 1;
        cfg.d_head = 8;
        cfg.chunk = 16;
        cfg.tokens = 2048; // deep enough that the N=32 dictionary saturates
        let short = run_decode_engine(&cfg);
        cfg.tokens = 8192;
        let long = run_decode_engine(&cfg);
        assert_eq!(short.state_bytes, long.state_bytes, "state must saturate");
    }
}
