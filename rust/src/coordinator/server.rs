//! Batched scoring server — the serving-side demonstration of the stack
//! (vLLM-router-style, scaled to this repo): clients submit sequences to
//! score; a dynamic batcher groups them up to the eval program's batch
//! size or a timeout, executes one HLO call per group, and returns
//! per-request results. Reports latency percentiles + throughput.
//!
//! Architecture: N client threads -> mpsc request queue -> batcher loop
//! (single device owner) -> per-request oneshot-style channels back.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::Model;
use crate::util::cli::Args;
use crate::util::rng::Rng;
use crate::util::stats;

pub struct ScoreRequest {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
    pub reply: mpsc::Sender<ScoreResponse>,
    pub submitted: Instant,
}

#[derive(Debug, Clone)]
pub struct ScoreResponse {
    pub loss: f32,
    pub accuracy: f64,
    pub latency: Duration,
}

/// The dynamic batcher: drains the queue up to `max_batch` requests or
/// `max_wait`, pads the batch with repeats of the last request, executes,
/// and fans results back out.
pub fn serve_loop(
    model: &Model<'_>,
    prog: &str,
    rx: mpsc::Receiver<ScoreRequest>,
    max_wait: Duration,
) -> Result<ServeStats> {
    let spec = model.manifest.programs[prog].clone();
    let (bmax, t) = (spec.batch.unwrap_or(2), spec.seq.unwrap_or(256));
    let state = model.init(1)?; // serving demo scores under fresh params
    let params = state.params;

    let mut stats_out = ServeStats::default();
    'outer: loop {
        // collect up to bmax requests (blocking on the first)
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break 'outer, // all clients done
        };
        let mut group = vec![first];
        let deadline = Instant::now() + max_wait;
        while group.len() < bmax {
            let left = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(left) {
                Ok(r) => group.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // assemble the padded batch
        let n = group.len();
        let mut tokens = Vec::with_capacity(bmax * t);
        let mut targets = Vec::with_capacity(bmax * t);
        let mut mask = Vec::with_capacity(bmax * t);
        for r in &group {
            assert_eq!(r.tokens.len(), t, "request length must match program");
            tokens.extend_from_slice(&r.tokens);
            targets.extend_from_slice(&r.targets);
            mask.extend_from_slice(&r.mask);
        }
        for _ in n..bmax {
            tokens.extend_from_slice(&group[n - 1].tokens);
            targets.extend_from_slice(&group[n - 1].targets);
            mask.extend(std::iter::repeat(0.0).take(t));
        }

        let out = model.eval(prog, &params, &tokens, &targets, &mask)?;
        let now = Instant::now();
        for (i, r) in group.into_iter().enumerate() {
            let row = &out.correct[i * t..(i + 1) * t];
            let mrow = &r.mask;
            let correct: f64 = row.iter().map(|&c| c as f64).sum();
            let total: f64 = mrow.iter().map(|&m| m as f64).sum();
            let resp = ScoreResponse {
                loss: out.loss,
                accuracy: if total > 0.0 { correct / total } else { 0.0 },
                latency: now.duration_since(r.submitted),
            };
            stats_out.latencies_ns.push(resp.latency.as_nanos() as f64);
            stats_out.served += 1;
            stats_out.batches += 1 * usize::from(i == 0);
            let _ = r.reply.send(resp);
        }
    }
    Ok(stats_out)
}

#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub served: usize,
    pub batches: usize,
    pub latencies_ns: Vec<f64>,
}

impl ServeStats {
    pub fn report(&self, wall: Duration) {
        println!(
            "served {} requests in {} batches over {:.2}s  ({:.1} req/s, mean batch {:.2})",
            self.served,
            self.batches,
            wall.as_secs_f64(),
            self.served as f64 / wall.as_secs_f64(),
            self.served as f64 / self.batches.max(1) as f64,
        );
        println!(
            "latency p50 {:.1} ms  p90 {:.1} ms  p99 {:.1} ms",
            stats::percentile(&self.latencies_ns, 50.0) / 1e6,
            stats::percentile(&self.latencies_ns, 90.0) / 1e6,
            stats::percentile(&self.latencies_ns, 99.0) / 1e6,
        );
    }
}

/// `ovq serve --model M [--requests N] [--clients C] [--task T]`
/// Demo driver: spins up client threads that generate and submit task
/// sequences, runs the batcher until all are served, reports stats.
pub fn cmd_serve(args: &Args) -> Result<()> {
    let rt = super::runtime_from(args)?;
    let model_name = args.opt_or("model", "quickstart");
    let task = args.opt_or("task", "icr");
    let n_requests = args.opt_usize("requests", 32);
    let n_clients = args.opt_usize("clients", 4);
    let model = rt.load_model(&model_name)?;
    let prog = model
        .manifest
        .eval_programs()
        .first()
        .map(|(k, _)| k.to_string())
        .expect("model has no eval programs");
    let t = model.manifest.programs[&prog].seq.unwrap_or(256);
    let vocab = model.manifest.cfg_usize("vocab", 512);

    crate::info!(
        "serving {model_name}/{prog} (T={t}) with {n_clients} clients x {} requests",
        n_requests / n_clients
    );

    let (tx, rx) = mpsc::channel::<ScoreRequest>();
    let mut client_handles = Vec::new();
    for c in 0..n_clients {
        let tx = tx.clone();
        let task = task.clone();
        let per = n_requests / n_clients;
        client_handles.push(std::thread::spawn(move || {
            let gen = crate::data::by_name(&task, vocab);
            let mut rng = Rng::new(0xC11E07 + c as u64);
            let mut responses = Vec::new();
            for _ in 0..per {
                let ex = gen.generate(&mut rng, t);
                let (rtx, rrx) = mpsc::channel();
                let req = ScoreRequest {
                    tokens: ex.tokens[..t].to_vec(),
                    targets: ex.tokens[1..t + 1].to_vec(),
                    mask: ex.score.iter().map(|&s| if s { 1.0 } else { 0.0 }).collect(),
                    reply: rtx,
                    submitted: Instant::now(),
                };
                tx.send(req).unwrap();
                responses.push(rrx.recv().unwrap());
            }
            responses
        }));
    }
    drop(tx);

    let t0 = Instant::now();
    let stats_out = serve_loop(&model, &prog, rx, Duration::from_millis(5))?;
    let wall = t0.elapsed();
    for h in client_handles {
        h.join().unwrap();
    }
    stats_out.report(wall);
    Ok(())
}
