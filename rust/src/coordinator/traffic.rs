//! Traffic-replay load generation for the decode engine — synthetic but
//! production-shaped arrival traces, so the serving layer is measured
//! under the regime the paper argues for (many concurrent sessions with
//! constant per-session state), not a lockstep round-robin drill.
//!
//! A trace is an **open-loop** sequence of [`TrafficEvent`]s: each event
//! says "at offset `at_us`, session S submits a chunk of L tokens",
//! independent of how fast the server drains (arrivals don't wait for
//! completions; the bounded engine queues convert overload into
//! backpressure). The generator models:
//!
//! - **zipf session popularity** ([`crate::util::rng::Rng::zipf`]): a few
//!   hot sessions dominate, a long tail trickles;
//! - **bursty arrivals**: with probability `burst_p` the next chunk
//!   continues the same session back-to-back (gap 0) — think token
//!   streaming — otherwise an exponential inter-arrival gap;
//! - **mixed chunk sizes**: drawn uniformly from `chunk_sizes`;
//! - **session abandon/return**: after any event the session may go
//!   dormant (`abandon_p`); dormant sessions re-enter only when re-drawn
//!   and a `return_p` coin allows it — producing the long-gap
//!   depart-then-return pattern that exercises eviction + restore.
//!
//! Traces are deterministic in the seed, and [`replay`] synthesizes every
//! chunk's activations from (session, sequence) alone — so the same trace
//! replayed against engines with different thread counts feeds each
//! session bit-identical inputs (the engine golden test depends on this).

use std::collections::HashMap;
use std::net::SocketAddr;

use anyhow::{Context, Result};

use crate::coordinator::engine::DecodeEngine;
use crate::coordinator::http;
use crate::coordinator::sampler::{SamplingParams, StopCriteria};
use crate::ovqcore::bank::DecodeChunk;
use crate::ovqcore::lm::TokenId;
use crate::util::rng::Rng;

/// Shape of a synthetic workload.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    /// session population (ids 0..sessions)
    pub sessions: usize,
    /// total chunk-arrival events in the trace
    pub events: usize,
    /// zipf popularity exponent (>1 = heavier head)
    pub zipf_s: f64,
    /// mean inter-arrival gap between bursts, microseconds
    pub mean_gap_us: f64,
    /// probability the next event continues the current burst (same
    /// session, zero gap)
    pub burst_p: f64,
    /// probability a session goes dormant after an event
    pub abandon_p: f64,
    /// probability a dormant session is allowed back when re-drawn
    pub return_p: f64,
    /// chunk lengths to mix (uniform draw)
    pub chunk_sizes: Vec<usize>,
    /// long-prompt admission: when non-empty, a session's FIRST arrival
    /// is, with probability `prompt_p`, a prefill event whose length is
    /// drawn uniformly from here (e.g. the 4k/16k/64k mix) — the
    /// long-context workload the paper's §4 claims target. Empty by
    /// default, which leaves legacy traces untouched.
    pub prompt_sizes: Vec<usize>,
    /// probability a fresh session opens with a long prompt (only
    /// consulted when `prompt_sizes` is non-empty)
    pub prompt_p: f64,
    /// generation requests: when non-empty, a fresh session's first
    /// arrival is, with probability `gen_p`, a generate request whose
    /// prompt length is drawn uniformly from here — the autoregressive
    /// workload (requires replaying into an LM engine). Empty by
    /// default, leaving legacy traces untouched.
    pub gen_prompt_sizes: Vec<usize>,
    /// probability a fresh session opens with a generate request (only
    /// consulted when `gen_prompt_sizes` is non-empty; checked before
    /// the plain-prompt coin)
    pub gen_p: f64,
    /// completion-length distribution: each generate request's max_new
    /// is drawn uniformly from here
    pub gen_max_new: Vec<usize>,
    /// share of generate requests using the sampled (temperature/top-k/
    /// top-p/repetition-penalty) parameter mix instead of greedy
    pub gen_sampled_p: f64,
    /// shared-system-prompt mix: when > 0, a generate request opens,
    /// with probability `prefix_p`, with this many shared prefix tokens
    /// prepended to its own prompt and named as a prefix-cache candidate
    /// (the multi-tenant "same system prompt, different user turn"
    /// workload). 0 leaves legacy traces untouched.
    pub prefix_tokens: usize,
    /// probability a generate request uses the shared prefix (only
    /// consulted when `prefix_tokens > 0`)
    pub prefix_p: f64,
    pub seed: u64,
}

impl TrafficConfig {
    pub fn new(sessions: usize, events: usize) -> TrafficConfig {
        TrafficConfig {
            sessions,
            events,
            zipf_s: 1.1,
            mean_gap_us: 50.0,
            burst_p: 0.6,
            abandon_p: 0.05,
            return_p: 0.3,
            chunk_sizes: vec![1, 8, 32],
            prompt_sizes: Vec::new(),
            prompt_p: 0.0,
            gen_prompt_sizes: Vec::new(),
            gen_p: 0.0,
            gen_max_new: Vec::new(),
            gen_sampled_p: 0.0,
            prefix_tokens: 0,
            prefix_p: 0.0,
            seed: 0x7AFF1C,
        }
    }

    /// Enable long-prompt admissions: every fresh session opens, with
    /// probability `p`, with a prompt drawn from `sizes` (the paper's
    /// long-context regime; 4k/16k/64k is the canonical mix).
    pub fn with_prompts(mut self, sizes: Vec<usize>, p: f64) -> TrafficConfig {
        self.prompt_sizes = sizes;
        self.prompt_p = p;
        self
    }

    /// Enable generation requests: a fresh session opens, with
    /// probability `p`, with a generate request (prompt length from
    /// `prompt_sizes`, completion cap from `max_new`, and a
    /// `sampled_p`-share using the sampled parameter mix over greedy).
    pub fn with_generates(
        mut self,
        prompt_sizes: Vec<usize>,
        max_new: Vec<usize>,
        p: f64,
        sampled_p: f64,
    ) -> TrafficConfig {
        assert!(!max_new.is_empty(), "generate traffic needs a completion-length mix");
        self.gen_prompt_sizes = prompt_sizes;
        self.gen_max_new = max_new;
        self.gen_p = p;
        self.gen_sampled_p = sampled_p;
        self
    }

    /// Enable the shared-system-prompt mix: a `p`-share of generate
    /// requests prepend the same `tokens`-long synthetic system prefix
    /// to their own prompt and name it for the engine's prefix cache.
    pub fn with_prefix(mut self, tokens: usize, p: f64) -> TrafficConfig {
        self.prefix_tokens = tokens;
        self.prefix_p = p;
        self
    }
}

/// The reserved synthetic stream id of the shared system prefix —
/// outside the session-id space, so [`synth_tokens`] derives prefix
/// tokens no real session's prompt can collide with.
pub const SHARED_PREFIX_STREAM: u64 = u64::MAX;

/// One open-loop arrival: session `session` submits `len` tokens at trace
/// offset `at_us`. `abandon` marks the client departing right after this
/// chunk — the replayer turns it into an explicit engine eviction, so the
/// freeze path is driven by the workload, not only by LRU pressure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TrafficEvent {
    pub at_us: u64,
    pub session: u64,
    pub len: usize,
    pub abandon: bool,
    /// long-prompt admission: the replayer submits this event through the
    /// engine's quantized prefill path instead of the decode path
    pub prefill: bool,
    /// generation request: `len` is the token-prompt length; the replayer
    /// routes it through `submit_generate` on an LM engine
    pub generate: bool,
    /// completion cap of a generate event (0 otherwise)
    pub max_new: usize,
    /// generate event uses the sampled parameter mix (greedy otherwise)
    pub sampled: bool,
    /// shared-system-prompt tokens prepended to this generate request's
    /// prompt and named as a prefix-cache candidate (0 = none)
    pub prefix_len: usize,
}

/// Generate a deterministic arrival trace.
pub fn generate(cfg: &TrafficConfig) -> Vec<TrafficEvent> {
    assert!(cfg.sessions > 0 && !cfg.chunk_sizes.is_empty());
    let mut rng = Rng::new(cfg.seed);
    let mut dormant = vec![false; cfg.sessions];
    let mut seen = vec![false; cfg.sessions];
    let mut events = Vec::with_capacity(cfg.events);
    let mut t_us = 0u64;
    let mut burst: Option<u64> = None;
    for _ in 0..cfg.events {
        let session = match burst {
            Some(s) if rng.bool(cfg.burst_p) => s, // continue the burst, gap 0
            _ => {
                // exponential inter-arrival gap, then a zipf session draw;
                // dormant sessions need a return coin, else re-draw (the
                // retry cap keeps the loop total even if everyone sleeps)
                let u = rng.f64().max(1e-12);
                t_us += (-u.ln() * cfg.mean_gap_us) as u64;
                let mut s = rng.zipf(cfg.sessions, cfg.zipf_s) as u64;
                for _ in 0..8 {
                    if !dormant[s as usize] || rng.bool(cfg.return_p) {
                        break;
                    }
                    s = rng.zipf(cfg.sessions, cfg.zipf_s) as u64;
                }
                dormant[s as usize] = false; // (re)joined
                s
            }
        };
        // a session's first-ever arrival may be a generate request or a
        // long prompt (guard every rng draw so configs without these
        // features keep their legacy streams byte-identical)
        let fresh = !seen[session as usize];
        let generate = fresh && !cfg.gen_prompt_sizes.is_empty() && rng.bool(cfg.gen_p);
        let prefill =
            !generate && fresh && !cfg.prompt_sizes.is_empty() && rng.bool(cfg.prompt_p);
        seen[session as usize] = true;
        let len = if generate {
            cfg.gen_prompt_sizes[rng.usize_below(cfg.gen_prompt_sizes.len())]
        } else if prefill {
            cfg.prompt_sizes[rng.usize_below(cfg.prompt_sizes.len())]
        } else {
            cfg.chunk_sizes[rng.usize_below(cfg.chunk_sizes.len())]
        };
        let (max_new, sampled) = if generate {
            (
                cfg.gen_max_new[rng.usize_below(cfg.gen_max_new.len())],
                rng.bool(cfg.gen_sampled_p),
            )
        } else {
            (0, false)
        };
        // the shared-system-prompt coin is likewise guarded: configs with
        // prefix_tokens == 0 draw nothing and keep their legacy streams
        let prefix_len = if generate && cfg.prefix_tokens > 0 && rng.bool(cfg.prefix_p) {
            cfg.prefix_tokens
        } else {
            0
        };
        let abandon = rng.bool(cfg.abandon_p);
        events.push(TrafficEvent {
            at_us: t_us,
            session,
            len,
            abandon,
            prefill,
            generate,
            max_new,
            sampled,
            prefix_len,
        });
        if abandon {
            dormant[session as usize] = true;
            burst = None;
        } else {
            burst = Some(session);
        }
    }
    events
}

/// Shape summary of a trace (for reports and sanity checks).
#[derive(Debug, Clone)]
pub struct TraceSummary {
    pub events: usize,
    pub distinct_sessions: usize,
    pub tokens: usize,
    /// long-prompt admissions in the trace
    pub prompts: usize,
    /// tokens arriving as prompts (subset of `tokens`)
    pub prompt_tokens: usize,
    /// generation requests in the trace
    pub generates: usize,
    /// completion-cap tokens requested by generate events (not part of
    /// `tokens` — the completion is produced by the engine, not offered)
    pub gen_max_new_total: usize,
    /// generate requests that open with the shared system prefix
    pub prefix_generates: usize,
    /// share of all events going to the single hottest session
    pub hottest_share: f64,
    /// longest same-session back-to-back run
    pub max_burst: usize,
    pub span_us: u64,
}

pub fn summarize(events: &[TrafficEvent]) -> TraceSummary {
    let mut per_session: HashMap<u64, usize> = HashMap::new();
    let mut tokens = 0usize;
    let (mut prompts, mut prompt_tokens) = (0usize, 0usize);
    let (mut generates, mut gen_max_new_total) = (0usize, 0usize);
    let mut prefix_generates = 0usize;
    let (mut max_burst, mut cur_burst) = (0usize, 0usize);
    let mut last: Option<u64> = None;
    for e in events {
        *per_session.entry(e.session).or_default() += 1;
        tokens += e.len;
        if e.prefill {
            prompts += 1;
            prompt_tokens += e.len;
        }
        if e.generate {
            generates += 1;
            gen_max_new_total += e.max_new;
            if e.prefix_len > 0 {
                prefix_generates += 1;
            }
        }
        cur_burst = if last == Some(e.session) { cur_burst + 1 } else { 1 };
        max_burst = max_burst.max(cur_burst);
        last = Some(e.session);
    }
    let hottest = per_session.values().copied().max().unwrap_or(0);
    TraceSummary {
        events: events.len(),
        distinct_sessions: per_session.len(),
        tokens,
        prompts,
        prompt_tokens,
        generates,
        gen_max_new_total,
        prefix_generates,
        hottest_share: hottest as f64 / events.len().max(1) as f64,
        max_burst,
        span_us: events.last().map_or(0, |e| e.at_us),
    }
}

/// Deterministic per-(session, seq) chunk activations: the replay-side
/// twin of the engine's per-(session, head) mixer seeding. Thread count,
/// shard layout and interleaving cannot change what any session sees.
pub fn synth_chunk(data_seed: u64, session: u64, seq: usize, len: usize, hd: usize) -> DecodeChunk {
    let mut rng = Rng::new(
        data_seed
            ^ session.wrapping_mul(0xA076_1D64_78BD_642F)
            ^ (seq as u64 + 1).wrapping_mul(0xE703_7ED1_A0B4_28DB),
    );
    let mut mk = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.normal() as f32).collect() };
    DecodeChunk { queries: mk(len * hd), keys: mk(len * hd), values: mk(len * hd) }
}

/// Deterministic token prompt for a generate request — the token-id twin
/// of [`synth_chunk`]: a pure function of (data_seed, session), so any
/// thread count replays the same prompt to the same session.
pub fn synth_tokens(data_seed: u64, session: u64, len: usize, vocab: usize) -> Vec<TokenId> {
    let mut rng = Rng::new(
        data_seed ^ session.wrapping_mul(0xA076_1D64_78BD_642F) ^ 0x7E4E_6E5E_ED01_C0DE,
    );
    (0..len).map(|_| rng.below(vocab as u64) as TokenId).collect()
}

/// Full prompt of a generate event: the shared system prefix (when the
/// event carries one) followed by the session's own suffix. Both halves
/// are [`synth_tokens`] streams, so the assembly is a pure function of
/// (data_seed, event) — the engine-side and HTTP-side replayers build
/// bit-identical prompts.
pub fn prefixed_prompt(data_seed: u64, e: &TrafficEvent, vocab: usize) -> Vec<TokenId> {
    let mut prompt = synth_tokens(data_seed, SHARED_PREFIX_STREAM, e.prefix_len, vocab);
    prompt.extend(synth_tokens(data_seed, e.session, e.len, vocab));
    prompt
}

/// Number of distinct payload variants the replay pool keeps per chunk
/// length. Small on purpose: the submit thread then pays a memcpy per
/// chunk instead of a Box-Muller synthesis, keeping the measured regime
/// decode-bound even at 4 worker threads.
const REPLAY_POOL_VARIANTS: u64 = 8;

/// Variants kept per PROMPT length — prompts run to 64k tokens, so the
/// pool would otherwise hold hundreds of MB of synthetic activations.
const REPLAY_PROMPT_VARIANTS: u64 = 2;

/// Replay a trace into the engine as fast as the bounded queues accept it
/// (closed only by backpressure — the measured regime for aggregate
/// tok/s). Returns total submitted tokens. Outputs are drained
/// opportunistically so collect-mode replays stay bounded; drained
/// outputs are appended to `sink` when one is provided.
///
/// Payloads come from a small pool of [`synth_chunk`] prototypes indexed
/// by (chunk length, variant), with the variant a deterministic function
/// of (session, sequence) — so a session still sees the same inputs under
/// any thread count (the engine golden test's requirement) while the
/// submit side stays cheap.
pub fn replay(
    engine: &DecodeEngine,
    events: &[TrafficEvent],
    data_seed: u64,
    mut sink: Option<&mut Vec<crate::coordinator::engine::EngineOut>>,
) -> usize {
    let hd = engine.heads() * engine.d_head();
    let mut seq: HashMap<u64, usize> = HashMap::new();
    let mut pool: HashMap<(usize, u64), DecodeChunk> = HashMap::new();
    let mut tokens = 0usize;
    for e in events {
        if e.generate {
            // autoregressive request: a deterministic token prompt routed
            // through the generation path (greedy or the sampled mix per
            // the event's coin). Offered tokens count the prompt only —
            // the completion is produced, not offered.
            let vocab = engine
                .lm_vocab()
                .expect("trace has generate events but the engine is not in LM mode");
            // a prefixed event prepends the one shared system prompt (a
            // reserved token stream no session id can produce) to its own
            // suffix and names the boundary for the engine's prefix cache
            let prompt = prefixed_prompt(data_seed, e, vocab);
            let offered = prompt.len();
            let params = if e.sampled {
                SamplingParams::sampled(data_seed ^ e.session)
            } else {
                SamplingParams::greedy()
            };
            engine.submit_generate_prefixed(
                e.session,
                prompt,
                e.prefix_len,
                None,
                params,
                StopCriteria::max_new(e.max_new),
            );
            *seq.entry(e.session).or_insert(0) += 1;
            tokens += offered;
            if e.abandon {
                engine.evict(e.session);
            }
            if let Some(out) = sink.as_mut() {
                out.extend(engine.try_outputs());
            }
            continue;
        }
        let s = seq.entry(e.session).or_insert(0);
        let variants = if e.prefill { REPLAY_PROMPT_VARIANTS } else { REPLAY_POOL_VARIANTS };
        let variant = e
            .session
            .wrapping_mul(0x9E37_79B9)
            .wrapping_add(*s as u64)
            % variants;
        let proto = pool
            .entry((e.len, variant))
            .or_insert_with(|| synth_chunk(data_seed, variant, e.len, e.len, hd));
        let payload = DecodeChunk {
            queries: proto.queries.clone(),
            keys: proto.keys.clone(),
            values: proto.values.clone(),
        };
        if e.prefill {
            engine.submit_prefill(e.session, payload);
        } else {
            engine.submit(e.session, payload);
        }
        *s += 1;
        tokens += e.len;
        if e.abandon {
            // client departed: freeze the session now rather than waiting
            // for LRU pressure (restore on return is bit-exact either way)
            engine.evict(e.session);
        }
        if let Some(out) = sink.as_mut() {
            out.extend(engine.try_outputs());
        }
    }
    tokens
}

/// Drive a trace's **generate events** over a real localhost socket
/// (`--over-http`): the socket twin of the generate arm of [`replay`].
/// Each event becomes a `POST /v1/completions` built by
/// [`http::completion_body`] from the same deterministic (prompt,
/// params, session) triple the in-process replayer submits — with
/// `stream` choosing SSE delivery over blocking JSON. Returns the
/// per-session completions sorted by session id.
///
/// Only generate events cross the wire — decode/prefill events carry
/// raw activations, which the HTTP edge intentionally does not expose.
/// The outputs still match a full in-process replay bit-for-bit: a
/// generate is always its session's *first* arrival (the trace
/// generator only opens fresh sessions with one), later same-session
/// work defers behind the running generation, and sampling depends only
/// on (engine seed, params, session, prompt) — never on co-resident
/// load or transport (the golden test in `tests/http.rs` pins this).
pub fn replay_over_http(
    addr: SocketAddr,
    events: &[TrafficEvent],
    data_seed: u64,
    vocab: usize,
    stream: bool,
) -> Result<Vec<(u64, Vec<TokenId>)>> {
    let mut out = Vec::new();
    for e in events.iter().filter(|e| e.generate) {
        let prompt = prefixed_prompt(data_seed, e, vocab);
        let params = if e.sampled {
            SamplingParams::sampled(data_seed ^ e.session)
        } else {
            SamplingParams::greedy()
        };
        let stop = StopCriteria::max_new(e.max_new);
        let body = http::completion_body_prefixed(
            Some(e.session),
            &prompt,
            &params,
            &stop,
            stream,
            e.prefix_len,
            None,
        );
        // a deterministic per-event request id, so trace spans from a
        // replay correlate back to trace events without a lookup table
        let rid = format!("replay-{}-{}", e.session, e.at_us);
        let resp = http::http_post(
            addr,
            "/v1/completions",
            &[("x-request-id", &rid)],
            body.to_string().as_bytes(),
        )?;
        anyhow::ensure!(
            resp.status == 200,
            "session {} got HTTP {}: {}",
            e.session,
            resp.status,
            String::from_utf8_lossy(&resp.body),
        );
        anyhow::ensure!(
            resp.header("x-request-id") == Some(rid.as_str()),
            "session {} response did not echo x-request-id '{rid}'",
            e.session,
        );
        let tokens = if stream {
            // the terminal `done` record is the last data event before
            // the [DONE] sentinel and carries the full completion
            let events = resp.sse_data();
            let done = events
                .iter()
                .rev()
                .find(|d| *d != "[DONE]")
                .context("SSE stream has no done event")?;
            let j = crate::util::json::parse(done).map_err(anyhow::Error::msg)?;
            http::token_ids(j.get("tokens").context("done event lacks tokens")?)
                .context("done event tokens are not ids")?
        } else {
            let j = resp.json()?;
            http::token_ids(j.get("tokens").context("completion lacks tokens")?)
                .context("completion tokens are not ids")?
        };
        out.push((e.session, tokens));
    }
    out.sort_by_key(|(s, _)| *s);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic() {
        let cfg = TrafficConfig::new(64, 500);
        assert_eq!(generate(&cfg), generate(&cfg));
        let mut cfg2 = cfg.clone();
        cfg2.seed ^= 1;
        assert_ne!(generate(&cfg), generate(&cfg2));
    }

    #[test]
    fn trace_is_zipf_skewed_and_bursty() {
        let cfg = TrafficConfig::new(256, 4000);
        let t = summarize(&generate(&cfg));
        assert_eq!(t.events, 4000);
        assert!(t.hottest_share > 0.05, "hottest share {}", t.hottest_share);
        assert!(t.max_burst >= 3, "max burst {}", t.max_burst);
        assert!(t.distinct_sessions > 16, "tail too thin: {}", t.distinct_sessions);
        assert!(t.tokens >= 4000);
        assert!(t.span_us > 0);
        let events = generate(&cfg);
        assert!(
            events.iter().any(|e| e.abandon),
            "abandon/return must appear in a 4000-event trace"
        );
    }

    #[test]
    fn trace_mixes_chunk_sizes_and_times_are_monotone() {
        let cfg = TrafficConfig::new(32, 1000);
        let events = generate(&cfg);
        let mut seen: Vec<usize> = events.iter().map(|e| e.len).collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen, cfg.chunk_sizes, "all configured sizes should appear");
        for w in events.windows(2) {
            assert!(w[1].at_us >= w[0].at_us, "open-loop times must be monotone");
        }
    }

    #[test]
    fn prompt_arrivals_open_sessions_and_stay_first() {
        let cfg = TrafficConfig::new(64, 2000).with_prompts(vec![4096, 16384, 65536], 0.8);
        let events = generate(&cfg);
        let t = summarize(&events);
        assert!(t.prompts > 10, "expected prompt admissions, got {}", t.prompts);
        assert!(t.prompt_tokens >= t.prompts * 4096);
        // a prompt is only ever a session's first arrival, and its length
        // comes from the prompt mix
        let mut seen = std::collections::HashSet::new();
        for e in &events {
            if e.prefill {
                assert!(seen.insert(e.session), "session {} prefilled twice", e.session);
                assert!(cfg.prompt_sizes.contains(&e.len), "bad prompt len {}", e.len);
            } else {
                assert!(cfg.chunk_sizes.contains(&e.len));
                seen.insert(e.session);
            }
        }
        // prompt-free configs are byte-for-byte what they were before
        let plain = TrafficConfig::new(64, 2000);
        assert!(generate(&plain).iter().all(|e| !e.prefill));
    }

    #[test]
    fn generate_arrivals_open_sessions_with_caps_and_mixes() {
        let cfg =
            TrafficConfig::new(64, 2000).with_generates(vec![64, 256], vec![16, 64], 0.7, 0.5);
        let events = generate(&cfg);
        let t = summarize(&events);
        assert!(t.generates > 10, "expected generate admissions, got {}", t.generates);
        assert!(t.gen_max_new_total >= t.generates * 16);
        let mut seen = std::collections::HashSet::new();
        let (mut greedy, mut sampled) = (0usize, 0usize);
        for e in &events {
            if e.generate {
                assert!(seen.insert(e.session), "session {} generated twice", e.session);
                assert!(cfg.gen_prompt_sizes.contains(&e.len), "bad gen prompt len {}", e.len);
                assert!(cfg.gen_max_new.contains(&e.max_new));
                assert!(!e.prefill, "an event is one path, not both");
                if e.sampled {
                    sampled += 1;
                } else {
                    greedy += 1;
                }
            } else {
                assert_eq!(e.max_new, 0);
                assert!(!e.sampled);
                seen.insert(e.session);
            }
        }
        assert!(greedy > 0 && sampled > 0, "both parameter mixes must appear");
        // generate-free configs keep their legacy streams
        let plain = TrafficConfig::new(64, 2000);
        assert!(generate(&plain).iter().all(|e| !e.generate));
    }

    #[test]
    fn shared_prefix_rides_generate_arrivals_and_guards_legacy_streams() {
        let base = TrafficConfig::new(64, 2000).with_generates(vec![64, 256], vec![16, 64], 0.7, 0.5);
        let cfg = base.clone().with_prefix(128, 0.6);
        let events = generate(&cfg);
        let t = summarize(&events);
        assert!(t.prefix_generates > 5, "expected prefixed generates, got {}", t.prefix_generates);
        assert!(t.prefix_generates < t.generates, "both prefix mixes must appear");
        for e in &events {
            if e.prefix_len > 0 {
                assert!(e.generate, "the shared prefix only rides generate arrivals");
                assert_eq!(e.prefix_len, 128, "one shared prefix, one length");
            }
        }
        // a zero-length prefix draws no coins: the stream is byte-for-byte
        // the prefix-free one (the guarded-coin contract every mix keeps)
        assert_eq!(generate(&base), generate(&base.clone().with_prefix(0, 0.6)));
    }

    #[test]
    fn prefixed_prompt_prepends_the_shared_stream() {
        let e = TrafficEvent {
            at_us: 0,
            session: 7,
            len: 8,
            abandon: false,
            prefill: false,
            generate: true,
            max_new: 4,
            sampled: false,
            prefix_len: 5,
        };
        let p = prefixed_prompt(0x5EED, &e, 24);
        assert_eq!(p.len(), 13);
        assert_eq!(p[..5], synth_tokens(0x5EED, SHARED_PREFIX_STREAM, 5, 24));
        assert_eq!(p[5..], synth_tokens(0x5EED, 7, 8, 24));
        let plain = TrafficEvent { prefix_len: 0, ..e };
        assert_eq!(prefixed_prompt(0x5EED, &plain, 24), synth_tokens(0x5EED, 7, 8, 24));
    }

    #[test]
    fn shared_prefix_replay_forks_and_matches_uncached_engine() {
        use crate::coordinator::engine::{EngineConfig, EngineReport};
        use crate::ovqcore::lm::LmConfig;
        use crate::ovqcore::memstate::MixerKind;
        use crate::ovqcore::stack::StackConfig;
        let cfg = TrafficConfig::new(8, 40)
            .with_generates(vec![8, 16], vec![4, 8], 0.9, 0.5)
            .with_prefix(32, 0.7);
        let events = generate(&cfg);
        let shape = summarize(&events);
        assert!(shape.prefix_generates >= 2, "trace must reuse the shared prefix");
        let run = |prefix_cache: bool| -> EngineReport {
            let lm = LmConfig::new(
                24,
                StackConfig::uniform(1, 8, 16, 2, 4, 8, MixerKind::Ovq { n_max: 16 }),
            );
            let mut ecfg = EngineConfig::for_lm(lm);
            ecfg.threads = 1;
            ecfg.prefix_cache = prefix_cache;
            let engine = DecodeEngine::start(ecfg);
            replay(&engine, &events, 0x5EED, None);
            engine.finish()
        };
        let (cached, plain) = (run(true), run(false));
        // one thread, one shard: the first prefixed generate builds the
        // template inside its first 512-token quantum, so every later one
        // forks — the count is exact, not a lower bound
        assert_eq!(cached.prefix_forks(), shape.prefix_generates - 1);
        assert_eq!(cached.prefix_fork_tokens(), (shape.prefix_generates - 1) * 32);
        assert_eq!(cached.prefix.misses, 1);
        assert_eq!(plain.prefix_forks(), 0);
        let toks = |r: &EngineReport| {
            let mut g: Vec<(u64, usize, Vec<TokenId>)> =
                r.generations.iter().map(|o| (o.session, o.seq, o.tokens.clone())).collect();
            g.sort();
            g
        };
        assert_eq!(toks(&cached), toks(&plain), "forking must not change a single sampled token");
    }

    #[test]
    fn synth_tokens_is_deterministic_and_in_vocab() {
        let a = synth_tokens(3, 7, 50, 24);
        assert_eq!(a, synth_tokens(3, 7, 50, 24));
        assert_eq!(a.len(), 50);
        assert!(a.iter().all(|&t| (t as usize) < 24));
        assert_ne!(a, synth_tokens(3, 8, 50, 24), "session must matter");
    }

    #[test]
    fn replay_routes_generate_events_through_the_lm_engine() {
        use crate::coordinator::engine::EngineConfig;
        use crate::ovqcore::lm::LmConfig;
        use crate::ovqcore::memstate::MixerKind;
        use crate::ovqcore::stack::StackConfig;
        let cfg = TrafficConfig::new(8, 60).with_generates(vec![8, 16], vec![4, 8], 0.9, 0.5);
        let events = generate(&cfg);
        let shape = summarize(&events);
        assert!(shape.generates > 0, "trace must contain generate events");
        let lm = LmConfig::new(
            24,
            StackConfig::uniform(1, 8, 16, 2, 4, 8, MixerKind::Ovq { n_max: 16 }),
        );
        let mut ecfg = EngineConfig::for_lm(lm);
        ecfg.threads = 2;
        let engine = DecodeEngine::start(ecfg);
        let tokens = replay(&engine, &events, 0x9, None);
        let report = engine.finish();
        assert_eq!(tokens, shape.tokens, "offered tokens count prompts, not completions");
        assert_eq!(report.completions(), shape.generates, "every request must complete");
        assert!(report.gen_tokens() > 0);
        assert_eq!(report.generations.len(), shape.generates);
    }

    #[test]
    fn synth_chunk_is_deterministic_and_shaped() {
        let a = synth_chunk(9, 4, 2, 8, 12);
        let b = synth_chunk(9, 4, 2, 8, 12);
        assert_eq!(a.queries, b.queries);
        assert_eq!(a.keys, b.keys);
        assert_eq!(a.values, b.values);
        assert_eq!(a.queries.len(), 8 * 12);
        let c = synth_chunk(9, 4, 3, 8, 12);
        assert_ne!(a.keys, c.keys, "seq must matter");
    }
}
