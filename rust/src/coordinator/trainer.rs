//! The training orchestrator: owns the loop, the threaded data pipeline,
//! metrics and checkpointing. One `train()` call = one model x task run.
//!
//! Hot-loop structure (see EXPERIMENTS.md §Perf):
//!   [prefetch thread] --batch--> [train_step HLO execute] --metrics-->
//! Data generation runs strictly ahead of the device so the step time is
//! the XLA execute time, not generator time.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::data::batch::Prefetcher;
use crate::runtime::{Model, Runtime, TrainState};
use crate::util::stats::Ema;

use super::metrics::MetricsLog;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    pub task: String,
    /// 0 = use the manifest's total_steps
    pub steps: usize,
    pub seed: u64,
    pub log_every: usize,
    pub out_dir: String,
    /// optional checkpoint to resume from
    pub resume: Option<String>,
}

#[derive(Debug, Clone)]
pub struct TrainSummary {
    pub steps: usize,
    pub final_loss: f32,
    pub ema_loss: f64,
    pub sec_per_step: f64,
    pub ckpt_path: String,
}

/// Canonical checkpoint path for a (model, task) pair.
pub fn ckpt_path(out_dir: &str, model: &str, task: &str) -> String {
    format!("{out_dir}/ckpt/{model}--{task}.ckpt")
}

pub fn train(rt: &Runtime, cfg: &TrainConfig) -> Result<TrainSummary> {
    let model = rt.load_model(&cfg.model)?;
    let (b, t) = model.train_shape()?;
    let vocab = model.manifest.cfg_usize("vocab", 512);
    let total_steps = if cfg.steps > 0 {
        cfg.steps
    } else {
        model.manifest.cfg_usize("total_steps", 400)
    };

    let mut state = match &cfg.resume {
        Some(p) => model
            .load_checkpoint(p)
            .with_context(|| format!("resuming from {p}"))?,
        None => model.init(cfg.seed)?,
    };

    let gen = crate::data::by_name(&cfg.task, vocab)?;
    let prefetch = Prefetcher::spawn(gen, cfg.seed ^ 0xDA7A, b, t, 4);

    std::fs::create_dir_all(format!("{}/ckpt", cfg.out_dir))?;
    let mut log = MetricsLog::create(&format!(
        "{}/train_{}_{}.csv",
        cfg.out_dir, cfg.model, cfg.task
    ))?;

    let mut ema = Ema::new(0.05);
    let mut final_loss = f32::NAN;
    let t0 = Instant::now();
    let start_step = state.step as usize;
    crate::info!(
        "training {} on {} [{}x{}] for {} steps",
        cfg.model, cfg.task, b, t, total_steps
    );
    while (state.step as usize) < total_steps {
        let batch = prefetch
            .next()
            .ok_or_else(|| anyhow::anyhow!("batch prefetcher exited at step {}", state.step))?;
        let m = model
            .train_step(&mut state, &batch.tokens, &batch.targets, &batch.mask)
            .with_context(|| format!("train step {}", state.step))?;
        final_loss = m.loss;
        let e = ema.update(m.loss as f64);
        log.record(m.step as usize, &[("loss", m.loss as f64), ("lr", m.lr as f64)])?;
        if (m.step as usize) % cfg.log_every == 0 || (m.step as usize) == total_steps {
            crate::info!(
                "  {} step {:>5} loss {:.4} (ema {:.4}) lr {:.2e}",
                cfg.model, m.step, m.loss, e, m.lr
            );
        }
        if !m.loss.is_finite() {
            anyhow::bail!("loss diverged (NaN/inf) at step {}", m.step);
        }
    }
    let steps_done = state.step as usize - start_step;
    let sec_per_step = t0.elapsed().as_secs_f64() / steps_done.max(1) as f64;

    let path = ckpt_path(&cfg.out_dir, &cfg.model, &cfg.task);
    model.save_checkpoint(&state, &path)?;
    log.flush()?;

    Ok(TrainSummary {
        steps: steps_done,
        final_loss,
        ema_loss: ema.value.unwrap_or(f64::NAN),
        sec_per_step,
        ckpt_path: path,
    })
}

/// Train-if-needed: reuse an existing checkpoint when present (experiments
/// share trained models; delete results/ckpt to retrain).
pub fn ensure_trained<'rt>(
    rt: &'rt Runtime,
    model: &str,
    task: &str,
    steps: usize,
    out_dir: &str,
) -> Result<(Model<'rt>, TrainState)> {
    let path = ckpt_path(out_dir, model, task);
    let m = rt.load_model(model)?;
    if std::path::Path::new(&path).exists() {
        crate::info!("reusing checkpoint {path}");
        let st = m.load_checkpoint(&path)?;
        return Ok((m, st));
    }
    let cfg = TrainConfig {
        model: model.to_string(),
        task: task.to_string(),
        steps,
        seed: 42,
        log_every: 50,
        out_dir: out_dir.to_string(),
        resume: None,
    };
    train(rt, &cfg)?;
    let st = m.load_checkpoint(&path)?;
    Ok((m, st))
}
