//! Batch assembly: [`Example`]s -> the (tokens, targets, mask) triple the
//! HLO programs take, plus a threaded prefetching pipeline so data
//! generation overlaps device execution (the L3 hot-loop optimization).

use std::sync::mpsc;
use std::thread;

use crate::util::rng::Rng;

use super::{Example, TaskGen};

/// A dense batch in the layout the artifacts expect (row-major [B, T]).
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
}

impl Batch {
    /// Assemble from examples; every example must match seq_len.
    /// Scored positions get mask 1.0, the rest 0.0 — the strict evaluation
    /// mask (per-token accuracy over answers only).
    pub fn from_examples(examples: &[Example], seq_len: usize) -> Batch {
        Batch::from_examples_aux(examples, seq_len, 0.0)
    }

    /// Training variant: unscored positions get a small auxiliary LM
    /// weight so the model also learns the task's surface structure — the
    /// scored positions are a tiny fraction of the sequence and carry too
    /// little gradient on their own at this scale.
    pub fn from_examples_aux(examples: &[Example], seq_len: usize, aux: f32) -> Batch {
        let b = examples.len();
        let mut tokens = Vec::with_capacity(b * seq_len);
        let mut targets = Vec::with_capacity(b * seq_len);
        let mut mask = Vec::with_capacity(b * seq_len);
        for ex in examples {
            assert_eq!(ex.tokens.len(), seq_len + 1);
            tokens.extend_from_slice(&ex.tokens[..seq_len]);
            targets.extend_from_slice(&ex.tokens[1..seq_len + 1]);
            mask.extend(ex.score.iter().map(|&s| if s { 1.0 } else { aux }));
        }
        Batch { tokens, targets, mask, batch: b, seq: seq_len }
    }

    pub fn generate(gen: &dyn TaskGen, rng: &mut Rng, b: usize, t: usize) -> Batch {
        let examples: Vec<Example> =
            (0..b).map(|_| gen.generate(rng, t)).collect();
        Batch::from_examples(&examples, t)
    }

    /// Training batch with the auxiliary LM weight.
    pub fn generate_train(gen: &dyn TaskGen, rng: &mut Rng, b: usize, t: usize) -> Batch {
        let examples: Vec<Example> =
            (0..b).map(|_| gen.generate(rng, t)).collect();
        Batch::from_examples_aux(&examples, t, 0.1)
    }
}

/// Background batch producer: a worker thread keeps a bounded channel of
/// ready batches so the trainer never waits on data generation.
///
/// Shutdown is graceful in both directions: the worker exits when the
/// consumer is dropped (its `send` fails), and [`Prefetcher::next`]
/// returns `None` instead of panicking if the worker exits first (e.g. a
/// generator panic). `Drop` closes the channel and joins the worker, so
/// no thread outlives the handle.
pub struct Prefetcher {
    rx: Option<mpsc::Receiver<Batch>>,
    handle: Option<thread::JoinHandle<()>>,
}

impl Prefetcher {
    pub fn spawn(
        gen: Box<dyn TaskGen>,
        seed: u64,
        batch: usize,
        seq: usize,
        depth: usize,
    ) -> Prefetcher {
        let (tx, rx) = mpsc::sync_channel(depth);
        let handle = thread::spawn(move || {
            let mut rng = Rng::new(seed);
            loop {
                let b = Batch::generate_train(gen.as_ref(), &mut rng, batch, seq);
                if tx.send(b).is_err() {
                    break; // consumer dropped
                }
            }
        });
        Prefetcher { rx: Some(rx), handle: Some(handle) }
    }

    /// Next ready batch, or `None` if the worker has exited.
    pub fn next(&self) -> Option<Batch> {
        self.rx.as_ref().and_then(|rx| rx.recv().ok())
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // closing the receiver makes the worker's next send fail, which
        // breaks its loop; then reap the thread (a panic in the worker is
        // already the error path — don't double-panic while unwinding)
        drop(self.rx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::icr::BasicIcr;

    #[test]
    fn batch_layout() {
        let g = BasicIcr::new(512);
        let mut rng = Rng::new(1);
        let b = Batch::generate(&g, &mut rng, 3, 128);
        assert_eq!(b.tokens.len(), 3 * 128);
        assert_eq!(b.targets.len(), 3 * 128);
        assert_eq!(b.mask.len(), 3 * 128);
        // targets are tokens shifted by one within each row
        for row in 0..3 {
            for t in 0..127 {
                assert_eq!(
                    b.targets[row * 128 + t],
                    b.tokens[row * 128 + t + 1]
                );
            }
        }
    }

    #[test]
    fn prefetcher_delivers() {
        let p = Prefetcher::spawn(Box::new(BasicIcr::new(512)), 7, 2, 128, 2);
        let a = p.next().expect("worker alive");
        let b = p.next().expect("worker alive");
        assert_eq!(a.tokens.len(), 2 * 128);
        assert_ne!(a.tokens, b.tokens, "successive batches should differ");
    }

    #[test]
    fn prefetcher_drop_joins_worker() {
        // dropping mid-stream must not hang (worker breaks on send error)
        // and must not leave a detached thread; run a few times to chase
        // the channel-full and channel-empty interleavings
        for i in 0..5 {
            let p = Prefetcher::spawn(Box::new(BasicIcr::new(512)), i, 2, 64, 2);
            if i % 2 == 0 {
                let _ = p.next();
            }
            drop(p); // Drop joins; a deadlock here fails the test by timeout
        }
    }
}
