//! Basic in-context recall (paper §4.1 / App. 8.5).
//!
//! The context is filled with unique key→value pairs (`K ASSIGN V SEP`);
//! after a QUERY marker, a random sample of pairs reappears and the model
//! must reproduce the value tokens. Scored positions are exactly the value
//! tokens of the query section (per-token accuracy, as in Fig. 4 left).
//!
//! Scaling: the paper uses 8-token keys/values over vocab 10k; we use
//! 4-token keys/values over the item range of vocab 512.

use std::collections::HashSet;

use crate::util::rng::Rng;

use super::vocab::{self, ASSIGN, QUERY, SEP};
use super::{Example, TaskGen};

pub struct BasicIcr {
    pub vocab: usize,
    pub key_len: usize,
    pub val_len: usize,
    pub n_queries: usize,
    /// item tokens are drawn from a pool of this size: a small pool makes
    /// the task learnable in few steps at this repo's scale (DESIGN.md §3)
    pub item_pool: usize,
}

impl BasicIcr {
    pub fn new(vocab: usize) -> BasicIcr {
        BasicIcr { vocab, key_len: 2, val_len: 2, n_queries: 6, item_pool: 64 }
    }

    fn fresh_tuple(
        &self,
        rng: &mut Rng,
        len: usize,
        used: &mut HashSet<Vec<i32>>,
        n_items: usize,
    ) -> Vec<i32> {
        loop {
            let t: Vec<i32> = (0..len)
                .map(|_| vocab::item(rng.usize_below(n_items)))
                .collect();
            if used.insert(t.clone()) {
                return t;
            }
        }
    }
}

impl TaskGen for BasicIcr {
    fn name(&self) -> &'static str {
        "icr"
    }

    fn generate(&self, rng: &mut Rng, seq_len: usize) -> Example {
        let n_items = vocab::item_count(self.vocab).min(self.item_pool);
        let pair_len = self.key_len + self.val_len + 2; // K → V |
        let query_len = self.n_queries * pair_len + 1; // QUERY marker
        assert!(
            seq_len > query_len + pair_len,
            "seq_len {seq_len} too short for ICR"
        );
        let n_pairs = (seq_len - query_len) / pair_len;

        let mut used = HashSet::new();
        let mut keys = Vec::with_capacity(n_pairs);
        let mut vals = Vec::with_capacity(n_pairs);
        for _ in 0..n_pairs {
            keys.push(self.fresh_tuple(rng, self.key_len, &mut used, n_items));
            vals.push(self.fresh_tuple(rng, self.val_len, &mut used, n_items));
        }

        let mut tokens = Vec::with_capacity(seq_len + 1);
        for i in 0..n_pairs {
            tokens.extend_from_slice(&keys[i]);
            tokens.push(ASSIGN);
            tokens.extend_from_slice(&vals[i]);
            tokens.push(SEP);
        }
        tokens.push(QUERY);

        // query section: sample distinct pairs to probe
        let probes = rng.sample_indices(n_pairs, self.n_queries.min(n_pairs));
        let mut value_spans = Vec::new(); // (start, len) of value tokens
        for &p in &probes {
            tokens.extend_from_slice(&keys[p]);
            tokens.push(ASSIGN);
            value_spans.push((tokens.len(), self.val_len));
            tokens.extend_from_slice(&vals[p]);
            tokens.push(SEP);
        }
        // pad front if short (keep the query section at the end)
        while tokens.len() < seq_len + 1 {
            tokens.insert(0, SEP);
            for s in &mut value_spans {
                s.0 += 1;
            }
        }
        tokens.truncate(seq_len + 1);

        // score the prediction of each value token: position t predicts
        // tokens[t+1], so a value token at index i is scored at t = i-1.
        let mut score = vec![false; seq_len];
        for (start, len) in value_spans {
            for i in start..start + len {
                if i >= 1 && i - 1 < seq_len {
                    score[i - 1] = true;
                }
            }
        }
        Example { tokens, score }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;

    #[test]
    fn generates_valid_examples() {
        let g = BasicIcr::new(512);
        let mut rng = Rng::new(1);
        for t in [128, 256, 512] {
            let ex = g.generate(&mut rng, t);
            ex.assert_valid(t, 512);
            let scored = ex.score.iter().filter(|&&s| s).count();
            assert_eq!(scored, g.n_queries * g.val_len);
        }
    }

    #[test]
    fn queried_values_exist_in_context() {
        let g = BasicIcr::new(512);
        let mut rng = Rng::new(2);
        let ex = g.generate(&mut rng, 256);
        let qpos = ex.tokens.iter().position(|&t| t == QUERY).unwrap();
        // every scored target token must also appear before the query marker
        for t in 0..ex.score.len() {
            if ex.score[t] {
                let tok = ex.tokens[t + 1];
                assert!(
                    ex.tokens[..qpos].contains(&tok),
                    "scored token {tok} not in context"
                );
            }
        }
    }

    #[test]
    fn prop_score_only_after_query_marker() {
        Prop::new(3).cases(24).check(|c| {
            let g = BasicIcr::new(512);
            let t = 128 + c.rng.usize_below(256);
            let ex = g.generate(&mut c.rng, t);
            let qpos = ex.tokens.iter().position(|&x| x == QUERY).unwrap();
            for (i, &s) in ex.score.iter().enumerate() {
                if s && i < qpos {
                    return Err(format!("scored position {i} before query at {qpos}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let g = BasicIcr::new(512);
        let a = g.generate(&mut Rng::new(7), 256);
        let b = g.generate(&mut Rng::new(7), 256);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.score, b.score);
    }
}
