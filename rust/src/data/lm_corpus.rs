//! Synthetic "book" corpus — the PG19 substitution (DESIGN.md §3).
//!
//! PG19's property that matters for Fig. 6 is long-range reuse: a book
//! introduces entities and topic vocabulary early and keeps reusing them,
//! so a reader with long memory predicts later text better than one
//! without. The generator reproduces exactly that structure:
//!
//!  * per document: 2 topics (disjoint word subsets), 6 named entities
//!    (unique 2-token names sampled per document);
//!  * sentences mix Zipfian function words, Zipfian topic words and entity
//!    mentions; EOS-terminated;
//!  * the second token of an entity name is deterministic given the first
//!    within a document, and topic words are drawn from the document's
//!    small subset — both predictable only by remembering the document
//!    history (constant-memory mixers forget; attention/OVQ does not).

use crate::util::rng::Rng;

use super::vocab::{self, EOS};
use super::{Example, TaskGen};

pub struct BookCorpus {
    pub vocab: usize,
    pub n_topics: usize,
    pub topic_size: usize,
    pub n_function_words: usize,
    pub n_entities: usize,
}

impl BookCorpus {
    pub fn new(vocab: usize) -> BookCorpus {
        BookCorpus {
            vocab,
            n_topics: 16,
            topic_size: 20,
            n_function_words: 24,
            n_entities: 6,
        }
    }

    fn layout(&self) -> (usize, usize, usize) {
        let items = vocab::item_count(self.vocab);
        let fw = self.n_function_words;
        let tw = self.n_topics * self.topic_size;
        assert!(fw + tw + 64 <= items, "vocab too small for corpus layout");
        // [0,fw) function words, [fw, fw+tw) topic words, rest = name pool
        (fw, tw, items - fw - tw)
    }
}

impl TaskGen for BookCorpus {
    fn name(&self) -> &'static str {
        "lm"
    }

    fn generate(&self, rng: &mut Rng, seq_len: usize) -> Example {
        let (fw, _tw, names) = self.layout();
        let name_base = fw + self.n_topics * self.topic_size;

        // document-level state
        let t1 = rng.usize_below(self.n_topics);
        let t2 = (t1 + 1 + rng.usize_below(self.n_topics - 1)) % self.n_topics;
        let entities: Vec<(usize, usize)> = (0..self.n_entities)
            .map(|_| {
                (
                    name_base + rng.usize_below(names),
                    name_base + rng.usize_below(names),
                )
            })
            .collect();

        let mut tokens = Vec::with_capacity(seq_len + 1);
        while tokens.len() < seq_len + 1 {
            // one sentence: 6..14 content slots then EOS
            let slots = 6 + rng.usize_below(9);
            for _ in 0..slots {
                let r = rng.f64();
                if r < 0.35 {
                    tokens.push(vocab::item(rng.zipf(fw, 1.2)));
                } else if r < 0.80 {
                    let topic = if rng.bool(0.5) { t1 } else { t2 };
                    let w = fw + topic * self.topic_size
                        + rng.zipf(self.topic_size, 1.1);
                    tokens.push(vocab::item(w));
                } else {
                    let (a, b) = entities[rng.usize_below(self.n_entities)];
                    tokens.push(vocab::item(a));
                    tokens.push(vocab::item(b));
                }
            }
            tokens.push(EOS);
        }
        tokens.truncate(seq_len + 1);

        // language modeling scores every position
        Example { tokens, score: vec![true; seq_len] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn valid_and_fully_scored() {
        let g = BookCorpus::new(512);
        let mut rng = Rng::new(1);
        let ex = g.generate(&mut rng, 1024);
        ex.assert_valid(1024, 512);
        assert!(ex.score.iter().all(|&s| s));
    }

    #[test]
    fn entity_second_token_is_predictable() {
        // within one document, each entity first-token maps to exactly one
        // second-token (the long-range signal the corpus is built around)
        let g = BookCorpus::new(512);
        let mut rng = Rng::new(2);
        let ex = g.generate(&mut rng, 2048);
        let name_base = vocab::item(
            g.n_function_words + g.n_topics * g.topic_size,
        );
        let mut map: HashMap<i32, i32> = HashMap::new();
        let toks = &ex.tokens;
        let mut consistent = 0;
        for i in 0..toks.len() - 1 {
            if toks[i] >= name_base {
                if let Some(&b) = map.get(&toks[i]) {
                    if b == toks[i + 1] {
                        consistent += 1;
                    }
                } else {
                    map.insert(toks[i], toks[i + 1]);
                }
            }
        }
        // most entity repeats should be consistent (collisions between the
        // name pool and second tokens can add noise but must be rare)
        assert!(consistent > 10, "too few entity repeats: {consistent}");
    }

    #[test]
    fn documents_use_topic_subsets() {
        let g = BookCorpus::new(512);
        let mut rng = Rng::new(3);
        let ex = g.generate(&mut rng, 2048);
        let fw = g.n_function_words;
        let tw_lo = vocab::item(fw);
        let tw_hi = vocab::item(fw + g.n_topics * g.topic_size);
        let mut topics_seen = std::collections::HashSet::new();
        for &t in &ex.tokens {
            if t >= tw_lo && t < tw_hi {
                topics_seen.insert((t - tw_lo) as usize / g.topic_size);
            }
        }
        assert!(
            topics_seen.len() <= 2,
            "document used {} topics, expected <= 2",
            topics_seen.len()
        );
    }
}
