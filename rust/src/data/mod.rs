//! Task generators — the paper's synthetic workload suite, generated in
//! Rust on the request path (no Python involvement).
//!
//! Every generator implements [`TaskGen`]: it produces a token sequence of
//! length T+1 plus a boolean "score" mask of length T, where `score[t]`
//! means "the prediction of `tokens[t+1]` at position t counts toward the
//! metric".
//! [`batch::Batch`] assembles these into the (tokens, targets, mask) triple
//! the train/eval HLO programs take.

pub mod batch;
pub mod icl;
pub mod icr;
pub mod lm_corpus;
pub mod picr;
pub mod shortctx;
pub mod vocab;

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// A generated example: tokens has length seq_len + 1 (so every position
/// has a next-token target), score has length seq_len.
#[derive(Debug, Clone)]
pub struct Example {
    pub tokens: Vec<i32>,
    pub score: Vec<bool>,
}

impl Example {
    pub fn assert_valid(&self, seq_len: usize, vocab: i32) {
        assert_eq!(self.tokens.len(), seq_len + 1, "tokens length");
        assert_eq!(self.score.len(), seq_len, "score length");
        assert!(
            self.tokens.iter().all(|&t| t >= 0 && t < vocab),
            "token out of vocab range"
        );
    }
}

/// A task generator. Implementations must be deterministic in (rng, seq_len).
pub trait TaskGen: Send + Sync {
    fn name(&self) -> &'static str;
    fn generate(&self, rng: &mut Rng, seq_len: usize) -> Example;
}

/// Construct a generator by task name (the CLI contract). An unknown
/// name is a user error, not a bug: it returns a descriptive `Err` with
/// the accepted names instead of panicking.
pub fn by_name(task: &str, vocab: usize) -> Result<Box<dyn TaskGen>> {
    Ok(match task {
        "icr" => Box::new(icr::BasicIcr::new(vocab)),
        "picr" => Box::new(picr::PositionalIcr::new(vocab)),
        "icl" => Box::new(icl::IclTask::new(vocab, 4)),
        "icl1" => Box::new(icl::IclTask::new(vocab, 1)),
        "icl8" => Box::new(icl::IclTask::new(vocab, 8)),
        "icl16" => Box::new(icl::IclTask::new(vocab, 16)),
        "lm" => Box::new(lm_corpus::BookCorpus::new(vocab)),
        "shortctx" => Box::new(shortctx::ShortCtx::new(vocab)),
        other => bail!(
            "unknown task '{other}' (usage: --task one of \
             icr|picr|icl|icl1|icl8|icl16|lm|shortctx)"
        ),
    })
}

#[cfg(test)]
mod tests {
    #[test]
    fn by_name_errors_on_unknown_task_with_hint() {
        assert!(super::by_name("icr", 64).is_ok());
        let e = super::by_name("nope", 64).unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("unknown task 'nope'"), "{msg}");
        assert!(msg.contains("usage"), "{msg}");
    }
}
