//! Positional in-context recall (paper §4.1, Fig. 4 middle).
//!
//! Same layout as basic ICR except each key appears `n_copies` (=4) times
//! in the context, each copy bound to a distinct value. The query presents
//! the copies of one key in order and the model must emit the values in
//! their order of first appearance — requiring global relative-position
//! information, the regime where OVQ lags slightly (Fig. 4 middle).

use std::collections::HashSet;

use crate::util::rng::Rng;

use super::vocab::{self, ASSIGN, QUERY, SEP};
use super::{Example, TaskGen};

pub struct PositionalIcr {
    pub vocab: usize,
    pub key_len: usize,
    pub val_len: usize,
    pub n_copies: usize,
    pub item_pool: usize,
}

impl PositionalIcr {
    pub fn new(vocab: usize) -> PositionalIcr {
        PositionalIcr { vocab, key_len: 2, val_len: 2, n_copies: 4, item_pool: 64 }
    }
}

impl TaskGen for PositionalIcr {
    fn name(&self) -> &'static str {
        "picr"
    }

    fn generate(&self, rng: &mut Rng, seq_len: usize) -> Example {
        let n_items = vocab::item_count(self.vocab).min(self.item_pool);
        let pair_len = self.key_len + self.val_len + 2;
        let query_len = self.n_copies * pair_len + 1;
        assert!(seq_len > query_len + self.n_copies * pair_len, "seq too short");
        let n_groups = (seq_len - query_len) / (pair_len * self.n_copies);
        let n_groups = n_groups.max(1);

        let mut used = HashSet::new();
        let mut fresh = |rng: &mut Rng, len: usize| -> Vec<i32> {
            loop {
                let t: Vec<i32> = (0..len)
                    .map(|_| vocab::item(rng.usize_below(n_items)))
                    .collect();
                if used.insert(t.clone()) {
                    return t;
                }
            }
        };

        // one key per group, n_copies distinct values per key
        let keys: Vec<Vec<i32>> =
            (0..n_groups).map(|_| fresh(rng, self.key_len)).collect();
        let vals: Vec<Vec<Vec<i32>>> = (0..n_groups)
            .map(|_| (0..self.n_copies).map(|_| fresh(rng, self.val_len)).collect())
            .collect();

        // interleave the copies of all groups in random order, but the
        // c-th copy of a key is always bound to its c-th value (order of
        // appearance defines the binding, as in the paper).
        let mut slots: Vec<usize> = (0..n_groups)
            .flat_map(|g| std::iter::repeat_n(g, self.n_copies))
            .collect();
        rng.shuffle(&mut slots);
        let mut copy_counter = vec![0usize; n_groups];

        let mut tokens = Vec::with_capacity(seq_len + 1);
        for &g in &slots {
            let c = copy_counter[g];
            copy_counter[g] += 1;
            tokens.extend_from_slice(&keys[g]);
            tokens.push(ASSIGN);
            tokens.extend_from_slice(&vals[g][c]);
            tokens.push(SEP);
        }
        tokens.push(QUERY);

        // probe one key: all copies in order
        let probe = rng.usize_below(n_groups);
        let mut value_spans = Vec::new();
        for c in 0..self.n_copies {
            tokens.extend_from_slice(&keys[probe]);
            tokens.push(ASSIGN);
            value_spans.push((tokens.len(), self.val_len));
            tokens.extend_from_slice(&vals[probe][c]);
            tokens.push(SEP);
        }

        while tokens.len() < seq_len + 1 {
            tokens.insert(0, SEP);
            for s in &mut value_spans {
                s.0 += 1;
            }
        }
        tokens.truncate(seq_len + 1);

        let mut score = vec![false; seq_len];
        for (start, len) in value_spans {
            for i in start..start + len {
                if i >= 1 && i - 1 < seq_len {
                    score[i - 1] = true;
                }
            }
        }
        Example { tokens, score }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_and_scores_all_copies() {
        let g = PositionalIcr::new(512);
        let mut rng = Rng::new(1);
        let ex = g.generate(&mut rng, 512);
        ex.assert_valid(512, 512);
        let scored = ex.score.iter().filter(|&&s| s).count();
        assert_eq!(scored, g.n_copies * g.val_len);
    }

    #[test]
    fn probe_values_appear_in_context_in_order() {
        let g = PositionalIcr::new(512);
        let mut rng = Rng::new(3);
        let ex = g.generate(&mut rng, 512);
        let qpos = ex.tokens.iter().position(|&t| t == QUERY).unwrap();
        // collect the scored spans (the probe's values, in query order)
        let mut spans: Vec<Vec<i32>> = Vec::new();
        let mut cur = Vec::new();
        for t in 0..ex.score.len() {
            if ex.score[t] {
                cur.push(ex.tokens[t + 1]);
                if cur.len() == g.val_len {
                    spans.push(std::mem::take(&mut cur));
                }
            }
        }
        assert_eq!(spans.len(), g.n_copies);
        // their first occurrences in the context must be strictly increasing
        let ctx = &ex.tokens[..qpos];
        let mut last = 0usize;
        for span in &spans {
            let pos = ctx
                .windows(g.val_len)
                .position(|w| w == span.as_slice())
                .expect("probe value not found in context");
            assert!(pos >= last, "values out of appearance order");
            last = pos;
        }
    }
}
