//! Short-context probe suite — the Table 1 substitution (DESIGN.md §3).
//!
//! Table 1's claim is *parity*: at short context, sw-ovq matches std-att
//! and sw-nope because OVQ barely compresses. We probe that with a mixed
//! short-context task: LM-style filler plus a small recall probe, scored
//! like the paper's benchmarks (accuracy over answer tokens).

use crate::util::rng::Rng;

use super::icr::BasicIcr;
use super::lm_corpus::BookCorpus;
use super::{Example, TaskGen};

pub struct ShortCtx {
    icr: BasicIcr,
    lm: BookCorpus,
}

impl ShortCtx {
    pub fn new(vocab: usize) -> ShortCtx {
        let mut icr = BasicIcr::new(vocab);
        icr.key_len = 2;
        icr.val_len = 2;
        icr.n_queries = 3;
        ShortCtx { icr, lm: BookCorpus::new(vocab) }
    }
}

impl TaskGen for ShortCtx {
    fn name(&self) -> &'static str {
        "shortctx"
    }

    fn generate(&self, rng: &mut Rng, seq_len: usize) -> Example {
        // half LM filler, half recall probe, concatenated
        let lm_len = seq_len / 2;
        let icr_len = seq_len - lm_len;
        let lm_ex = self.lm.generate(rng, lm_len);
        let icr_ex = self.icr.generate(rng, icr_len);

        let mut tokens = lm_ex.tokens[..lm_len].to_vec();
        tokens.extend_from_slice(&icr_ex.tokens);
        tokens.truncate(seq_len + 1);

        // score only the probe answers (benchmark-style accuracy)
        let mut score = vec![false; seq_len];
        for (i, &s) in icr_ex.score.iter().enumerate() {
            let t = lm_len + i;
            if s && t < seq_len {
                score[t] = true;
            }
        }
        Example { tokens, score }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_example() {
        let g = ShortCtx::new(512);
        let mut rng = Rng::new(1);
        let ex = g.generate(&mut rng, 192);
        ex.assert_valid(192, 512);
        let scored = ex.score.iter().filter(|&&s| s).count();
        assert_eq!(scored, 3 * 2); // n_queries * val_len (set below)
    }
}
