//! Shared token-id conventions across all synthetic tasks.
//!
//! The layout is scaled from the paper's vocab-10k setup to the repo's
//! default vocab of 512 (DESIGN.md §3): a handful of structural specials,
//! a block of function-identifier tokens for the ICL task, then the item
//! range used for keys/values/words/integers.

/// Padding / "don't care" token.
pub const PAD: i32 = 0;
/// Next-pair separator ('|' in the paper's diagrams).
pub const SEP: i32 = 1;
/// Key->value assignment marker ('→' in the paper's diagrams).
pub const ASSIGN: i32 = 2;
/// Start-of-query-section marker.
pub const QUERY: i32 = 3;
/// End-of-sentence marker for the LM corpus.
pub const EOS: i32 = 4;
/// Function-identifier tokens for ICL: FUNC_BASE..FUNC_BASE+MAX_FUNCS.
pub const FUNC_BASE: i32 = 8;
pub const MAX_FUNCS: usize = 32;
/// First free token usable as task content.
pub const ITEM_BASE: i32 = FUNC_BASE + MAX_FUNCS as i32; // = 40

/// Number of item tokens available for a given model vocab size.
pub fn item_count(vocab: usize) -> usize {
    assert!(
        vocab as i32 > ITEM_BASE + 64,
        "vocab {vocab} too small for the task token layout"
    );
    vocab - ITEM_BASE as usize
}

/// Map an item index to its token id.
pub fn item(idx: usize) -> i32 {
    ITEM_BASE + idx as i32
}

/// ICL function-identifier token.
pub fn func_token(f: usize) -> i32 {
    assert!(f < MAX_FUNCS);
    FUNC_BASE + f as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_disjoint() {
        assert!(PAD < SEP && SEP < ASSIGN && ASSIGN < QUERY && QUERY < EOS);
        assert!(EOS < FUNC_BASE);
        assert_eq!(ITEM_BASE, FUNC_BASE + MAX_FUNCS as i32);
        assert_eq!(item(0), ITEM_BASE);
        assert_eq!(func_token(0), FUNC_BASE);
    }

    #[test]
    #[should_panic]
    fn tiny_vocab_rejected() {
        item_count(64);
    }
}
