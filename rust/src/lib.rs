//! OVQ-attention: a Rust + JAX + Pallas reproduction of
//! "Online Vector Quantized Attention" (Alonso, Figliolia, Millidge 2026).
//!
//! Layer map (DESIGN.md):
//!  - [`runtime`]     — PJRT client + manifest-driven HLO execution
//!  - [`coordinator`] — training/eval/serving orchestration, incl. the
//!    sharded multi-threaded [`coordinator::engine::DecodeEngine`] with
//!    session lifecycle, the [`coordinator::traffic`] load generator,
//!    and the HTTP network edge ([`coordinator::http`] +
//!    [`coordinator::router`]): `/v1/completions` with SSE token
//!    streaming, admission control, and overload shedding (API.md)
//!  - [`data`]        — task generators (ICR, positional ICR, ICL, LM, ...)
//!  - [`ovqcore`]     — pure-Rust OVQ + baseline state machines behind the
//!    [`ovqcore::mixer::SeqMixer`] trait, blocked microkernels, the
//!    bit-exact [`ovqcore::snapshot`] format, and the decode banks
//!    ([`ovqcore::bank`])
//!  - [`analysis`]    — analytical FLOPs / memory models (App. D)
//!  - [`util`]        — zero-dependency JSON/RNG/CLI/bench/prop utilities

pub mod analysis;
pub mod coordinator;
pub mod data;
pub mod ovqcore;
pub mod runtime;
pub mod util;
