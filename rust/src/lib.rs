//! OVQ-attention: a Rust + JAX + Pallas reproduction of
//! "Online Vector Quantized Attention" (Alonso, Figliolia, Millidge 2026).
//!
//! Layer map (DESIGN.md):
//!  - [`runtime`]     — PJRT client + manifest-driven HLO execution
//!  - [`coordinator`] — training/eval/serving orchestration
//!  - [`data`]        — task generators (ICR, positional ICR, ICL, LM, ...)
//!  - [`ovqcore`]     — pure-Rust OVQ + baseline state machines behind the
//!    [`ovqcore::mixer::SeqMixer`] trait, blocked microkernels, and the
//!    [`ovqcore::bank::MixerBank`] multi-stream decode engine
//!  - [`analysis`]    — analytical FLOPs / memory models (App. D)
//!  - [`util`]        — zero-dependency JSON/RNG/CLI/bench/prop utilities

pub mod analysis;
pub mod coordinator;
pub mod data;
pub mod ovqcore;
pub mod runtime;
pub mod util;
