//! `ovq` — the leader binary: training, evaluation, serving and
//! paper-experiment drivers, all through AOT-compiled XLA artifacts.

use anyhow::Result;

use ovq::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "usage: ovq <subcommand> [options]\n\
         \n\
         subcommands:\n\
           smoke                        PJRT round-trip check on the quickstart artifact\n\
           models                       list models available in artifacts/\n\
           train   --model M --task T   train a model on a task [--steps N] [--seed S]\n\
           eval    --model M --task T --ckpt F   length-sweep evaluation\n\
           exp <id>                     reproduce a paper figure/table (f1 f4 f4r f5 f6\n\
                                        t1 f7 f8 f9 f10 f12 f13 f14 f15 f16 s34) [--quick]\n\
           serve   --model M --ckpt F   batched scoring + streaming decode demo\n\
                                        [--streams S --threads W --prompt-tokens P\n\
                                         --prefill-quantum Q --max-resident R]\n\
                                        [--layers L --d-model D --d-ff F --schedule S]\n\
                                        (--schedule: per-layer mixers, e.g.\n\
                                         'ovq:1024,kv:win256' cycled over L)\n\
           generate                     autoregressive generation through the engine:\n\
                                        prompt prefill -> sampler stack -> self-feeding\n\
                                        decode [--vocab V --sessions N --prompt-tokens P\n\
                                        --max-new M --temp T --top-k K --top-p P\n\
                                        --rep-penalty R --stop-token T --threads W]\n\
                                        plus the serve stack flags (--layers --d-model\n\
                                        --d-ff --schedule); --temp 0 = greedy\n\
           serve-http                   HTTP edge over the engine (API.md): OpenAI-style\n\
                                        POST /v1/completions with SSE streaming, /v1/health,\n\
                                        /v1/stats, Prometheus /metrics, and /v1/trace spans\n\
                                        [--port P --max-inflight N --tenant-rate R]\n\
                                        [--obs off|metrics|trace] (span capture level)\n\
                                        plus the generate model flags and the tiered-memory\n\
                                        flags [--spill-dir DIR --ram-blob-budget B\n\
                                        --no-prefix-cache]; --replay N [--over-http --stream\n\
                                        --prefix-tokens P] drives a zipf trace and exits\n\
           flops                        print the App. D FLOPs tables\n\
         \n\
         options: --artifacts DIR (or $OVQ_ARTIFACTS), --out DIR (results)\n"
    );
    std::process::exit(2);
}

fn main() -> Result<()> {
    // pin the log epoch before any work, so `[elapsed]` stamps measure
    // from process start rather than from the first log call
    ovq::util::log::init();
    let args = Args::from_env();
    match args.subcommand.as_str() {
        "smoke" => cmd_smoke(&args),
        "models" => cmd_models(&args),
        "train" => ovq::coordinator::cmd_train(&args),
        "eval" => ovq::coordinator::cmd_eval(&args),
        "exp" => ovq::coordinator::experiments::cmd_exp(&args),
        "serve" => ovq::coordinator::server::cmd_serve(&args),
        "generate" => ovq::coordinator::server::cmd_generate(&args),
        "serve-http" => ovq::coordinator::http::cmd_serve_http(&args),
        "flops" => ovq::analysis::flops::cmd_flops(&args),
        _ => usage(),
    }
}

fn runtime_from(args: &Args) -> Result<ovq::runtime::Runtime> {
    match args.opt("artifacts") {
        Some(dir) => ovq::runtime::Runtime::new(dir),
        None => ovq::runtime::Runtime::from_env(),
    }
}

fn cmd_models(args: &Args) -> Result<()> {
    let rt = runtime_from(args)?;
    for name in rt.list_models()? {
        let m = rt.load_model(&name)?;
        println!(
            "{:28} {:>9} params in {:>3} leaves  programs: {}",
            name,
            m.manifest.total_param_elems(),
            m.manifest.param_count(),
            m.manifest
                .programs
                .keys()
                .cloned()
                .collect::<Vec<_>>()
                .join(",")
        );
    }
    Ok(())
}

fn cmd_smoke(args: &Args) -> Result<()> {
    let rt = runtime_from(args)?;
    let model = rt.load_model("quickstart")?;
    println!("platform = {}", rt.client.platform_name());
    println!(
        "model    = {} ({} param leaves)",
        model.manifest.name,
        model.manifest.param_count()
    );

    let mut state = model.init(42)?;
    let (b, t) = model.train_shape()?;
    let tokens: Vec<i32> = (0..(b * t) as i32).map(|i| i % 17).collect();
    let mask = vec![1.0f32; b * t];
    let m0 = model.train_step(&mut state, &tokens, &tokens, &mask)?;
    let m1 = model.train_step(&mut state, &tokens, &tokens, &mask)?;
    println!("step {} loss {:.4} lr {:.2e}", m0.step, m0.loss, m0.lr);
    println!("step {} loss {:.4} lr {:.2e}", m1.step, m1.loss, m1.lr);
    assert!(m1.loss.is_finite());

    let et = 128.min(t) * 2;
    let ev = model.eval(
        "eval_128",
        &state.params,
        &tokens[..et],
        &tokens[..et],
        &mask[..et],
    )?;
    println!("eval_128 loss {:.4}", ev.loss);
    println!("smoke OK");
    Ok(())
}
