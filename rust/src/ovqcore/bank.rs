//! Multi-stream, multi-head decode engine over [`SeqMixer`] — the serving
//! counterpart of a batched attention layer. A [`MixerBank`] owns
//! `streams x heads` mixer states in one flat slab (index
//! `stream * heads + head`), a shared kernel [`Scratch`], and per-stream
//! chunk queues drained by a round-robin scheduler. Inputs and outputs
//! use the packed head-interleaved layout `[len, heads, d]` (one row per
//! token holding every head's slice, the layout a fused QKV projection
//! emits); the bank de-interleaves into contiguous per-head panels so
//! each mixer's blocked kernels see unit-stride rows.
//!
//! This is the layer the paper's systems claim cashes out at: per-token
//! decode cost through an OVQ bank is flat in the dictionary size N while
//! state stays constant, so one engine sustains many concurrent streams.

use std::collections::VecDeque;

use super::mixer::{Scratch, SeqMixer};

/// One queued decode chunk for a stream, packed `[len, heads, d]`.
pub struct DecodeChunk {
    pub queries: Vec<f32>,
    pub keys: Vec<f32>,
    pub values: Vec<f32>,
}

/// Completed chunk: which stream, its packed outputs, and the engine-side
/// processing latency.
pub struct DecodeOut {
    pub stream: usize,
    pub out: Vec<f32>,
    pub elapsed_ns: f64,
}

/// Latency samples retained per stream — a bounded ring so telemetry
/// stays O(1) per stream no matter how long the session decodes (the
/// engine's whole point is constant-memory serving).
pub const LATENCY_WINDOW: usize = 4096;

/// Per-stream serving telemetry.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    pub tokens: usize,
    pub chunks: usize,
    /// engine latency of the most recent [`LATENCY_WINDOW`] processed
    /// chunks, nanoseconds (ring-buffered; percentiles are over this
    /// window)
    pub chunk_ns: Vec<f64>,
}

pub struct MixerBank {
    heads: usize,
    d_in: usize,
    d_out: usize,
    /// slab of streams x heads mixer states, [stream * heads + head]
    mixers: Vec<Box<dyn SeqMixer>>,
    queues: Vec<VecDeque<DecodeChunk>>,
    pub stats: Vec<StreamStats>,
    scratch: Scratch,
    /// de-interleave staging: per-head q/k/v/out panels
    panel: Vec<f32>,
    /// round-robin cursor (next stream to serve)
    rr: usize,
}

impl MixerBank {
    /// Build a bank of `streams x heads` mixers from a factory; the
    /// factory receives `(stream, head)` so callers can vary per-head
    /// state (e.g. per-head VQ dictionaries) — but every mixer must
    /// share the same d_in/d_out (asserted).
    pub fn new(
        streams: usize,
        heads: usize,
        mk: impl Fn(usize, usize) -> Box<dyn SeqMixer>,
    ) -> MixerBank {
        assert!(streams > 0 && heads > 0);
        let mut mixers = Vec::with_capacity(streams * heads);
        for s in 0..streams {
            for h in 0..heads {
                mixers.push(mk(s, h));
            }
        }
        let d_in = mixers[0].d_in();
        let d_out = mixers[0].d_out();
        // hard assert: process() strides every head's panel with these
        // dims, so a mismatched factory would silently corrupt outputs
        assert!(
            mixers.iter().all(|m| m.d_in() == d_in && m.d_out() == d_out),
            "all mixers in a bank must share d_in/d_out"
        );
        MixerBank {
            heads,
            d_in,
            d_out,
            mixers,
            queues: (0..streams).map(|_| VecDeque::new()).collect(),
            stats: vec![StreamStats::default(); streams],
            scratch: Scratch::new(),
            panel: Vec::new(),
            rr: 0,
        }
    }

    pub fn streams(&self) -> usize {
        self.queues.len()
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    pub fn d_in(&self) -> usize {
        self.d_in
    }

    pub fn d_out(&self) -> usize {
        self.d_out
    }

    pub fn mixer(&self, stream: usize, head: usize) -> &dyn SeqMixer {
        self.mixers[stream * self.heads + head].as_ref()
    }

    /// Total live state across every stream and head.
    pub fn state_bytes(&self) -> usize {
        self.mixers.iter().map(|m| m.state_bytes()).sum()
    }

    /// Queued chunks across all streams.
    pub fn pending_chunks(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Enqueue one packed `[len, heads, d]` chunk for a stream.
    pub fn submit(&mut self, stream: usize, chunk: DecodeChunk) {
        let hd = self.heads * self.d_in;
        debug_assert_eq!(chunk.queries.len() % hd, 0);
        debug_assert_eq!(chunk.keys.len(), chunk.queries.len());
        debug_assert_eq!(chunk.values.len() / (self.heads * self.d_out), chunk.keys.len() / hd);
        self.queues[stream].push_back(chunk);
    }

    /// Process one chunk from the next non-empty stream queue in
    /// round-robin order. Returns None when every queue is empty.
    pub fn step(&mut self) -> Option<DecodeOut> {
        let n = self.streams();
        for probe in 0..n {
            let s = (self.rr + probe) % n;
            if let Some(chunk) = self.queues[s].pop_front() {
                self.rr = (s + 1) % n;
                let t0 = std::time::Instant::now();
                let out = self.process(s, &chunk);
                let elapsed_ns = t0.elapsed().as_nanos() as f64;
                let len = chunk.keys.len() / (self.heads * self.d_in);
                let st = &mut self.stats[s];
                st.tokens += len;
                st.chunks += 1;
                if st.chunk_ns.len() < LATENCY_WINDOW {
                    st.chunk_ns.push(elapsed_ns);
                } else {
                    st.chunk_ns[(st.chunks - 1) % LATENCY_WINDOW] = elapsed_ns;
                }
                return Some(DecodeOut { stream: s, out, elapsed_ns });
            }
        }
        None
    }

    /// Drain every queue to completion, returning outputs in completion
    /// (scheduling) order.
    pub fn drain(&mut self) -> Vec<DecodeOut> {
        let mut done = Vec::new();
        while let Some(o) = self.step() {
            done.push(o);
        }
        done
    }

    /// Force every stream's buffered chunk tail into long-term state.
    pub fn flush_all(&mut self) {
        for m in &mut self.mixers {
            m.flush();
        }
    }

    /// Batched per-chunk attend/update across this stream's heads: packed
    /// `[len, heads, d]` in, packed out. Heads are processed back-to-back
    /// against contiguous per-head panels so the whole chunk for one head
    /// (and its dictionary tile) stays cache-resident.
    fn process(&mut self, stream: usize, chunk: &DecodeChunk) -> Vec<f32> {
        let (h, di, dv) = (self.heads, self.d_in, self.d_out);
        let len = chunk.keys.len() / (h * di);
        let mut out = vec![0.0f32; len * h * dv];

        // panel layout: q [len*di] | k [len*di] | v [len*dv] | o [len*dv]
        let need = len * (2 * di + 2 * dv);
        if self.panel.len() < need {
            self.panel.resize(need, 0.0);
        }
        for head in 0..h {
            let panel = &mut self.panel[..need];
            let (pq, rest) = panel.split_at_mut(len * di);
            let (pk, rest) = rest.split_at_mut(len * di);
            let (pv, po) = rest.split_at_mut(len * dv);
            let po = &mut po[..len * dv];
            // gather this head's strided rows into contiguous panels
            for i in 0..len {
                let qrow = (i * h + head) * di;
                pq[i * di..(i + 1) * di].copy_from_slice(&chunk.queries[qrow..qrow + di]);
                pk[i * di..(i + 1) * di].copy_from_slice(&chunk.keys[qrow..qrow + di]);
                let vrow = (i * h + head) * dv;
                pv[i * dv..(i + 1) * dv].copy_from_slice(&chunk.values[vrow..vrow + dv]);
            }
            let mixer = &mut self.mixers[stream * h + head];
            mixer.process_chunk(pq, pk, pv, po, &mut self.scratch);
            // scatter back
            for i in 0..len {
                let orow = (i * h + head) * dv;
                out[orow..orow + dv].copy_from_slice(&po[i * dv..(i + 1) * dv]);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ovqcore::ovq::{OvqConfig, OvqState};
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn ovq_bank(streams: usize, heads: usize, d: usize, n: usize, chunk: usize) -> MixerBank {
        MixerBank::new(streams, heads, |_, _| {
            Box::new(OvqState::new(OvqConfig::new(d, n, chunk)))
        })
    }

    #[test]
    fn bank_matches_single_mixer_per_head() {
        // a 2-head 1-stream bank must produce, per head, exactly what a
        // standalone mixer fed that head's slice produces
        let (d, n, chunk, len) = (8, 64, 16, 16);
        let mut rng = Rng::new(1);
        let mut bank = ovq_bank(1, 2, d, n, chunk);
        let mut solo0 = OvqState::new(OvqConfig::new(d, n, chunk));
        let mut solo1 = OvqState::new(OvqConfig::new(d, n, chunk));
        let mut scratch = Scratch::new();

        for _ in 0..3 {
            let q = randv(&mut rng, len * 2 * d);
            let k = randv(&mut rng, len * 2 * d);
            let v = randv(&mut rng, len * 2 * d);
            bank.submit(
                0,
                DecodeChunk { queries: q.clone(), keys: k.clone(), values: v.clone() },
            );
            let got = bank.step().unwrap();
            assert_eq!(got.stream, 0);

            // reference: de-interleave by hand, run each solo mixer
            for (head, solo) in [(0usize, &mut solo0), (1usize, &mut solo1)] {
                let mut hq = vec![0.0; len * d];
                let mut hk = vec![0.0; len * d];
                let mut hv = vec![0.0; len * d];
                for i in 0..len {
                    let row = (i * 2 + head) * d;
                    hq[i * d..(i + 1) * d].copy_from_slice(&q[row..row + d]);
                    hk[i * d..(i + 1) * d].copy_from_slice(&k[row..row + d]);
                    hv[i * d..(i + 1) * d].copy_from_slice(&v[row..row + d]);
                }
                let mut want = vec![0.0; len * d];
                solo.process_chunk(&hq, &hk, &hv, &mut want, &mut scratch);
                for i in 0..len {
                    let row = (i * 2 + head) * d;
                    for j in 0..d {
                        assert!(
                            (got.out[row + j] - want[i * d + j]).abs() < 1e-6,
                            "head {head} token {i} dim {j}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn round_robin_interleaves_streams() {
        let (d, len) = (4, 8);
        let mut rng = Rng::new(2);
        let mut bank = ovq_bank(3, 1, d, 32, 8);
        // two chunks per stream
        for s in 0..3 {
            for _ in 0..2 {
                bank.submit(
                    s,
                    DecodeChunk {
                        queries: randv(&mut rng, len * d),
                        keys: randv(&mut rng, len * d),
                        values: randv(&mut rng, len * d),
                    },
                );
            }
        }
        assert_eq!(bank.pending_chunks(), 6);
        let order: Vec<usize> = bank.drain().iter().map(|o| o.stream).collect();
        assert_eq!(order, vec![0, 1, 2, 0, 1, 2], "round-robin order");
        assert_eq!(bank.pending_chunks(), 0);
        for s in 0..3 {
            assert_eq!(bank.stats[s].tokens, 2 * len);
            assert_eq!(bank.stats[s].chunks, 2);
        }
    }

    #[test]
    fn state_is_flat_across_long_decode() {
        let mut rng = Rng::new(3);
        let mut bank = ovq_bank(2, 2, 8, 32, 16);
        let mut cap = 0usize;
        for round in 0..20 {
            for s in 0..2 {
                bank.submit(
                    s,
                    DecodeChunk {
                        queries: randv(&mut rng, 16 * 2 * 8),
                        keys: randv(&mut rng, 16 * 2 * 8),
                        values: randv(&mut rng, 16 * 2 * 8),
                    },
                );
            }
            bank.drain();
            if round == 10 {
                cap = bank.state_bytes();
            }
        }
        // OVQ state saturates: late-decode state is no bigger than mid-decode
        assert!(bank.state_bytes() <= cap + 2 * 2 * 16 * 2 * 8 * 4, "state must plateau");
        assert_eq!(bank.stats[0].tokens, 20 * 16);
    }

    #[test]
    fn skewed_queues_still_drain_fairly() {
        let (d, len) = (4, 4);
        let mut rng = Rng::new(4);
        let mut bank = ovq_bank(2, 1, d, 16, 4);
        for _ in 0..3 {
            bank.submit(
                0,
                DecodeChunk {
                    queries: randv(&mut rng, len * d),
                    keys: randv(&mut rng, len * d),
                    values: randv(&mut rng, len * d),
                },
            );
        }
        bank.submit(
            1,
            DecodeChunk {
                queries: randv(&mut rng, len * d),
                keys: randv(&mut rng, len * d),
                values: randv(&mut rng, len * d),
            },
        );
        let order: Vec<usize> = bank.drain().iter().map(|o| o.stream).collect();
        // stream 1's single chunk is served second, not last
        assert_eq!(order, vec![0, 1, 0, 0]);
    }
}
