//! Multi-stream, multi-head decode banks over [`SeqMixer`] — the serving
//! counterpart of a batched attention layer. Two tiers:
//!
//! - [`MixerBank`]: a fixed set of `streams x heads` mixer states in one
//!   flat slab with per-stream chunk queues drained by a round-robin
//!   scheduler. The single-threaded engine the benches and the simple
//!   decode demo drive directly.
//! - [`ShardBank`]: the per-shard session store of the multi-threaded
//!   decode engine (`coordinator::engine`). Sessions are keyed by id,
//!   admitted on first arrival, LRU-evicted to [`snapshot`] blobs when the
//!   shard exceeds its residency cap, and transparently restored
//!   (bit-identically) when an evicted session re-arrives.
//!
//! Both tiers share one chunk-processing core ([`process_packed`]): inputs
//! and outputs use the packed head-interleaved layout `[len, heads, d]`
//! (one row per token holding every head's slice, the layout a fused QKV
//! projection emits); the core de-interleaves into contiguous per-head
//! panels so each mixer's blocked kernels see unit-stride rows.
//!
//! This is the layer the paper's systems claim cashes out at: per-token
//! decode cost through an OVQ bank is flat in the dictionary size N while
//! state stays constant, so one engine sustains many concurrent streams —
//! and constant state is what makes eviction/restore cheap enough to give
//! every user a resident session.

use std::collections::{HashMap, VecDeque};

use anyhow::{Context, Result};

use super::lm::LmModel;
use super::mixer::{merge_layer_stats, LayerStat, PrefillMode, Scratch, SeqMixer};
use super::snapshot;
use super::store::{StoreConfig, TieredStore};

/// One queued decode chunk for a stream, packed `[len, heads, d]`.
#[derive(Debug, Clone)]
pub struct DecodeChunk {
    pub queries: Vec<f32>,
    pub keys: Vec<f32>,
    pub values: Vec<f32>,
}

/// Completed chunk: which stream, its packed outputs, and the engine-side
/// processing latency.
pub struct DecodeOut {
    pub stream: usize,
    pub out: Vec<f32>,
    pub elapsed_ns: f64,
}

/// Latency samples retained per stream — a bounded ring so telemetry
/// stays O(1) per stream no matter how long the session decodes (the
/// engine's whole point is constant-memory serving).
pub const LATENCY_WINDOW: usize = 4096;

/// Per-stream serving telemetry.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    /// all tokens ingested (decode chunks + prefilled prompts)
    pub tokens: usize,
    /// completed units — decode chunks and whole prompts both count one,
    /// so this doubles as the stream's sequence counter
    pub chunks: usize,
    /// engine latency of the most recent [`LATENCY_WINDOW`] processed
    /// decode chunks, nanoseconds (ring-buffered; percentiles are over
    /// this window)
    pub chunk_ns: Vec<f64>,
    /// prompt tokens ingested through the prefill path (subset of `tokens`)
    pub prefill_tokens: usize,
    /// completed prefill prompts (subset of `chunks`)
    pub prefill_chunks: usize,
    /// per-prompt prefill processing latency ring, nanoseconds — kept
    /// apart from `chunk_ns` so a 64k prompt doesn't drown the decode
    /// percentiles
    pub prefill_ns: Vec<f64>,
    /// tokens produced by the self-feeding generation loop (subset of
    /// `tokens`; a generate request's prompt counts under `prefill_tokens`)
    pub gen_tokens: usize,
    /// completed generation requests (subset of `chunks`)
    pub gen_chunks: usize,
}

impl StreamStats {
    /// Account one processed chunk of `tokens` tokens that took
    /// `elapsed_ns`. Returns the stream's chunk sequence number (1-based).
    pub fn record(&mut self, tokens: usize, elapsed_ns: f64) -> usize {
        self.tokens += tokens;
        self.chunks += 1;
        ring_push(&mut self.chunk_ns, self.chunks - 1, elapsed_ns);
        self.chunks
    }

    /// Account one completed prefill prompt of `tokens` tokens whose
    /// quanta took `elapsed_ns` of processing in total. Returns the
    /// stream's sequence number (shared with decode chunks, so a
    /// prompt-then-decode stream orders globally).
    pub fn record_prefill(&mut self, tokens: usize, elapsed_ns: f64) -> usize {
        self.tokens += tokens;
        self.chunks += 1;
        self.prefill_tokens += tokens;
        self.prefill_chunks += 1;
        ring_push(&mut self.prefill_ns, self.prefill_chunks - 1, elapsed_ns);
        self.chunks
    }

    /// Account one completed generation request: `prompt_tokens` ingested
    /// through the prefill path, then `new_tokens` sampled by the
    /// self-feeding loop. One sequence unit, like a prompt — returns the
    /// stream's sequence number.
    pub fn record_generate(&mut self, prompt_tokens: usize, new_tokens: usize) -> usize {
        self.tokens += prompt_tokens + new_tokens;
        self.chunks += 1;
        self.prefill_tokens += prompt_tokens;
        self.gen_tokens += new_tokens;
        self.gen_chunks += 1;
        self.chunks
    }

    /// Decode-chunk latency percentile over the recent window,
    /// microseconds (NaN while the stream has no processed chunks) —
    /// the per-session view `/v1/stats` reports.
    pub fn chunk_p_us(&self, p: f64) -> f64 {
        crate::util::stats::percentile(&self.chunk_ns, p) / 1e3
    }

    /// Per-prompt prefill latency percentile over the recent window,
    /// microseconds (NaN while the stream has no completed prompts).
    pub fn prefill_p_us(&self, p: f64) -> f64 {
        crate::util::stats::percentile(&self.prefill_ns, p) / 1e3
    }
}

/// Push a sample into a [`LATENCY_WINDOW`]-bounded ring. `count` is how
/// many samples were pushed before this one — the single copy of the
/// wrap arithmetic shared by stream telemetry and the engine's per-shard
/// latency rings.
pub fn ring_push(ring: &mut Vec<f64>, count: usize, x: f64) {
    if ring.len() < LATENCY_WINDOW {
        ring.push(x);
    } else {
        ring[count % LATENCY_WINDOW] = x;
    }
}

/// The shared per-chunk attend/update core: batched across one stream's
/// heads, packed `[len, heads, d]` in, packed out. Heads are processed
/// back-to-back against contiguous per-head panels so the whole chunk for
/// one head (and its dictionary tile) stays cache-resident. `panel` is a
/// caller-owned staging buffer, grown as needed and reused across calls.
pub fn process_packed(
    mixers: &mut [Box<dyn SeqMixer>],
    chunk: &DecodeChunk,
    scratch: &mut Scratch,
    panel: &mut Vec<f32>,
) -> Vec<f32> {
    process_packed_inner(mixers, &chunk.queries, &chunk.keys, &chunk.values, scratch, panel, false)
}

/// [`process_packed`] through each mixer's blocked
/// [`SeqMixer::process_prefill`] path — same layout, same de-interleave,
/// bit-identical outputs, amortized kernels. Takes raw slices so the
/// engine can feed quantum-sized sub-views of a long prompt without
/// copying it apart.
pub fn process_packed_prefill(
    mixers: &mut [Box<dyn SeqMixer>],
    queries: &[f32],
    keys: &[f32],
    values: &[f32],
    scratch: &mut Scratch,
    panel: &mut Vec<f32>,
) -> Vec<f32> {
    process_packed_inner(mixers, queries, keys, values, scratch, panel, true)
}

/// The writes-only half of [`process_packed_prefill`]: advance every
/// head's state over the packed keys/values without computing any
/// outputs. Post-call mixer state is bit-identical to the full prefill
/// over the same slice ([`SeqMixer::prefill_writes`] contract). This is
/// what the fan-out engine runs on the owner shard while helper threads
/// compute the (state-independent-given-a-snapshot) output segments.
pub fn process_packed_prefill_writes(
    mixers: &mut [Box<dyn SeqMixer>],
    keys: &[f32],
    values: &[f32],
    scratch: &mut Scratch,
    panel: &mut Vec<f32>,
) {
    let h = mixers.len();
    let (di, dv) = (mixers[0].d_in(), mixers[0].d_out());
    let len = keys.len() / (h * di);
    debug_assert_eq!(values.len(), len * h * dv);
    // panel layout: k [len*di] | v [len*dv]
    let need = len * (di + dv);
    if panel.len() < need {
        panel.resize(need, 0.0);
    }
    for (head, mixer) in mixers.iter_mut().enumerate() {
        let (pk, pv) = panel[..need].split_at_mut(len * di);
        for i in 0..len {
            let krow = (i * h + head) * di;
            pk[i * di..(i + 1) * di].copy_from_slice(&keys[krow..krow + di]);
            let vrow = (i * h + head) * dv;
            pv[i * dv..(i + 1) * dv].copy_from_slice(&values[vrow..vrow + dv]);
        }
        mixer.prefill_writes(pk, pv, scratch);
    }
}

fn process_packed_inner(
    mixers: &mut [Box<dyn SeqMixer>],
    queries: &[f32],
    keys: &[f32],
    values: &[f32],
    scratch: &mut Scratch,
    panel: &mut Vec<f32>,
    prefill: bool,
) -> Vec<f32> {
    let h = mixers.len();
    let (di, dv) = (mixers[0].d_in(), mixers[0].d_out());
    let len = keys.len() / (h * di);
    debug_assert_eq!(queries.len(), len * h * di);
    debug_assert_eq!(values.len(), len * h * dv);
    let mut out = vec![0.0f32; len * h * dv];

    // panel layout: q [len*di] | k [len*di] | v [len*dv] | o [len*dv]
    let need = len * (2 * di + 2 * dv);
    if panel.len() < need {
        panel.resize(need, 0.0);
    }
    for (head, mixer) in mixers.iter_mut().enumerate() {
        let panel = &mut panel[..need];
        let (pq, rest) = panel.split_at_mut(len * di);
        let (pk, rest) = rest.split_at_mut(len * di);
        let (pv, po) = rest.split_at_mut(len * dv);
        let po = &mut po[..len * dv];
        // gather this head's strided rows into contiguous panels
        for i in 0..len {
            let qrow = (i * h + head) * di;
            pq[i * di..(i + 1) * di].copy_from_slice(&queries[qrow..qrow + di]);
            pk[i * di..(i + 1) * di].copy_from_slice(&keys[qrow..qrow + di]);
            let vrow = (i * h + head) * dv;
            pv[i * dv..(i + 1) * dv].copy_from_slice(&values[vrow..vrow + dv]);
        }
        if prefill {
            mixer.process_prefill(pq, pk, pv, po, scratch);
        } else {
            mixer.process_chunk(pq, pk, pv, po, scratch);
        }
        // scatter back
        for i in 0..len {
            let orow = (i * h + head) * dv;
            out[orow..orow + dv].copy_from_slice(&po[i * dv..(i + 1) * dv]);
        }
    }
    out
}

// =============================================================== MixerBank

pub struct MixerBank {
    heads: usize,
    d_in: usize,
    d_out: usize,
    /// slab of streams x heads mixer states, [stream * heads + head]
    mixers: Vec<Box<dyn SeqMixer>>,
    queues: Vec<VecDeque<DecodeChunk>>,
    pub stats: Vec<StreamStats>,
    scratch: Scratch,
    /// de-interleave staging: per-head q/k/v/out panels
    panel: Vec<f32>,
    /// round-robin cursor (next stream to serve)
    rr: usize,
}

impl MixerBank {
    /// Build a bank of `streams x heads` mixers from a factory; the
    /// factory receives `(stream, head)` so callers can vary per-head
    /// state (e.g. per-head VQ dictionaries) — but every mixer must
    /// share the same d_in/d_out (asserted).
    pub fn new(
        streams: usize,
        heads: usize,
        mk: impl Fn(usize, usize) -> Box<dyn SeqMixer>,
    ) -> MixerBank {
        assert!(streams > 0 && heads > 0);
        let mut mixers = Vec::with_capacity(streams * heads);
        for s in 0..streams {
            for h in 0..heads {
                mixers.push(mk(s, h));
            }
        }
        let d_in = mixers[0].d_in();
        let d_out = mixers[0].d_out();
        // hard assert: process_packed strides every head's panel with these
        // dims, so a mismatched factory would silently corrupt outputs
        assert!(
            mixers.iter().all(|m| m.d_in() == d_in && m.d_out() == d_out),
            "all mixers in a bank must share d_in/d_out"
        );
        MixerBank {
            heads,
            d_in,
            d_out,
            mixers,
            queues: (0..streams).map(|_| VecDeque::new()).collect(),
            stats: vec![StreamStats::default(); streams],
            scratch: Scratch::new(),
            panel: Vec::new(),
            rr: 0,
        }
    }

    pub fn streams(&self) -> usize {
        self.queues.len()
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    pub fn d_in(&self) -> usize {
        self.d_in
    }

    pub fn d_out(&self) -> usize {
        self.d_out
    }

    pub fn mixer(&self, stream: usize, head: usize) -> &dyn SeqMixer {
        self.mixers[stream * self.heads + head].as_ref()
    }

    /// Total live state across every stream and head.
    pub fn state_bytes(&self) -> usize {
        self.mixers.iter().map(|m| m.state_bytes()).sum()
    }

    /// Queued chunks across all streams.
    pub fn pending_chunks(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Enqueue one packed `[len, heads, d]` chunk for a stream.
    pub fn submit(&mut self, stream: usize, chunk: DecodeChunk) {
        let hd = self.heads * self.d_in;
        debug_assert_eq!(chunk.queries.len() % hd, 0);
        debug_assert_eq!(chunk.keys.len(), chunk.queries.len());
        debug_assert_eq!(chunk.values.len() / (self.heads * self.d_out), chunk.keys.len() / hd);
        self.queues[stream].push_back(chunk);
    }

    /// Process one chunk from the next non-empty stream queue in
    /// round-robin order. Returns None when every queue is empty.
    pub fn step(&mut self) -> Option<DecodeOut> {
        let n = self.streams();
        for probe in 0..n {
            let s = (self.rr + probe) % n;
            if let Some(chunk) = self.queues[s].pop_front() {
                self.rr = (s + 1) % n;
                let t0 = std::time::Instant::now();
                let h = self.heads;
                let out = process_packed(
                    &mut self.mixers[s * h..(s + 1) * h],
                    &chunk,
                    &mut self.scratch,
                    &mut self.panel,
                );
                let elapsed_ns = t0.elapsed().as_nanos() as f64;
                let len = chunk.keys.len() / (h * self.d_in);
                self.stats[s].record(len, elapsed_ns);
                return Some(DecodeOut { stream: s, out, elapsed_ns });
            }
        }
        None
    }

    /// Ingest a long prompt for one stream through the blocked prefill
    /// path, immediately (the single-threaded bank has no scheduler to
    /// interleave with — quantum slicing and decode interleaving live in
    /// `coordinator::engine`). Outputs are bit-identical to submitting
    /// the same tokens as decode chunks.
    pub fn prefill(&mut self, stream: usize, chunk: &DecodeChunk) -> DecodeOut {
        let h = self.heads;
        let t0 = std::time::Instant::now();
        let out = process_packed_prefill(
            &mut self.mixers[stream * h..(stream + 1) * h],
            &chunk.queries,
            &chunk.keys,
            &chunk.values,
            &mut self.scratch,
            &mut self.panel,
        );
        let elapsed_ns = t0.elapsed().as_nanos() as f64;
        let len = chunk.keys.len() / (h * self.d_in);
        self.stats[stream].record_prefill(len, elapsed_ns);
        DecodeOut { stream, out, elapsed_ns }
    }

    /// Drain every queue to completion, returning outputs in completion
    /// (scheduling) order.
    pub fn drain(&mut self) -> Vec<DecodeOut> {
        let mut done = Vec::new();
        while let Some(o) = self.step() {
            done.push(o);
        }
        done
    }

    /// Force every stream's buffered chunk tail into long-term state.
    pub fn flush_all(&mut self) {
        for m in &mut self.mixers {
            m.flush();
        }
    }
}

// =============================================================== ShardBank

/// A resident decode session: one mixer per head plus LRU metadata.
struct Resident {
    id: u64,
    mixers: Vec<Box<dyn SeqMixer>>,
    last_used: u64,
}

/// Per-(session, head) mixer factory used by session admission.
pub type MixerFactory = Box<dyn Fn(u64, usize) -> Box<dyn SeqMixer> + Send>;

/// Per-shard session store with admission, LRU eviction to snapshot
/// blobs, and transparent restore. Owned by exactly one engine worker
/// thread; completely single-threaded itself, so it is also directly
/// unit-testable without spawning anything.
pub struct ShardBank {
    heads: usize,
    /// uniform per-head dims, learned from the first admitted session and
    /// enforced on every later admit/restore (0 = none admitted yet) —
    /// process_packed strides every panel with one session's head-0 dims,
    /// so a mismatch would silently corrupt outputs
    d_in: usize,
    d_out: usize,
    max_resident: usize,
    factory: MixerFactory,
    resident: Vec<Resident>,
    /// frozen sessions: a tiered (RAM + optional disk) blob store keyed
    /// by session id — see [`super::store::TieredStore`]
    store: TieredStore,
    /// telemetry for every session ever seen — survives eviction (stats
    /// are engine state, not mixer state, so they are not in the blob)
    stats: HashMap<u64, StreamStats>,
    /// logical LRU clock, bumped once per processed chunk
    clock: u64,
    pub evictions: usize,
    pub restores: usize,
    scratch: Scratch,
    panel: Vec<f32>,
    /// prefill policy applied to every admitted or restored session.
    /// Runtime-only: snapshots never carry it (a thawed mixer is Exact
    /// until the shard re-applies its policy here).
    prefill_mode: PrefillMode,
}

impl ShardBank {
    /// `factory(session, head)` builds one head's mixer for a newly
    /// admitted session. It must be deterministic in (session, head) —
    /// the multi-thread vs single-thread bit-identity of the engine
    /// depends on it (shard assignment changes with thread count; the
    /// session's mixers must not).
    pub fn new(
        heads: usize,
        max_resident: usize,
        factory: impl Fn(u64, usize) -> Box<dyn SeqMixer> + Send + 'static,
    ) -> ShardBank {
        assert!(heads > 0 && max_resident > 0);
        ShardBank {
            heads,
            d_in: 0,
            d_out: 0,
            max_resident,
            factory: Box::new(factory),
            resident: Vec::new(),
            store: TieredStore::in_ram(),
            stats: HashMap::new(),
            clock: 0,
            evictions: 0,
            restores: 0,
            scratch: Scratch::new(),
            panel: Vec::new(),
            prefill_mode: PrefillMode::Exact,
        }
    }

    /// Set the shard's prefill policy. Applied to sessions already
    /// resident and to every future admit/restore. Call before serving
    /// traffic — mid-stream switches are well-defined (the mode only
    /// gates how `process_prefill` blocks its math) but make outputs a
    /// mixture of the two forms.
    pub fn set_prefill_mode(&mut self, mode: PrefillMode) {
        self.prefill_mode = mode;
        for r in &mut self.resident {
            for m in &mut r.mixers {
                m.set_prefill_mode(mode);
            }
        }
    }

    pub fn prefill_mode(&self) -> PrefillMode {
        self.prefill_mode
    }

    /// Replace the frozen-session store with a configured tiered store
    /// (disk spill dir, RAM blob budget, shared gauges). Call before
    /// serving traffic: any blobs in the old store are dropped.
    pub fn configure_store(&mut self, cfg: StoreConfig) {
        self.store = TieredStore::new(cfg);
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    pub fn resident_sessions(&self) -> usize {
        self.resident.len()
    }

    pub fn evicted_sessions(&self) -> usize {
        self.store.frozen_sessions()
    }

    /// Frozen sessions whose blob sits on the disk tier.
    pub fn disk_sessions(&self) -> usize {
        self.store.disk_sessions()
    }

    /// Blob payload bytes on the disk tier.
    pub fn disk_bytes(&self) -> usize {
        self.store.disk_bytes()
    }

    /// Blobs written back to the disk tier so far.
    pub fn spills(&self) -> usize {
        self.store.spills as usize
    }

    /// Blobs read back from the disk tier so far.
    pub fn disk_restores(&self) -> usize {
        self.store.disk_restores as usize
    }

    /// True if the bank holds any state for `id` — resident or frozen
    /// in either tier. The prefix-fork path uses this to refuse forking
    /// into a session that already has history.
    pub fn has_state(&self, id: u64) -> bool {
        self.resident.iter().any(|r| r.id == id) || self.store.contains(id)
    }

    /// Block until every queued disk writeback has landed, so spill
    /// counters and tier byte gauges are exact (end-of-run reports).
    pub fn sync_store(&mut self) {
        self.store.sync();
    }

    /// Every session this shard has ever served.
    pub fn sessions(&self) -> usize {
        self.stats.len()
    }

    /// Live mixer bytes across resident sessions.
    pub fn resident_bytes(&self) -> usize {
        self.resident
            .iter()
            .map(|r| r.mixers.iter().map(|m| m.state_bytes()).sum::<usize>())
            .sum()
    }

    /// RAM held for frozen sessions: snapshot blobs still in the RAM
    /// tier in full, plus one index entry per disk-spilled session —
    /// a spilled session costs ~nothing in RAM, which is the point of
    /// the disk tier. Disk payload bytes are reported separately by
    /// [`ShardBank::disk_bytes`].
    pub fn snapshot_bytes(&self) -> usize {
        self.store.ram_footprint()
    }

    /// Per-layer telemetry aggregated over every *resident* session
    /// (evicted sessions are frozen byte blobs — their per-layer split is
    /// already in `snapshot_bytes`). A bare multi-head session folds to
    /// one layer-0 row (state/busy summed across its heads, tokens
    /// counted once per session — every head sees the same tokens);
    /// [`crate::ovqcore::stack::LayerStack`] sessions contribute one row
    /// per transformer layer. Either way, a row's `tokens` is the total
    /// tokens that passed through that layer across sessions.
    pub fn layer_stats(&self) -> Vec<LayerStat> {
        let mut acc: Vec<LayerStat> = Vec::new();
        for r in &self.resident {
            let mut session: Vec<LayerStat> = Vec::new();
            for m in &r.mixers {
                let rows = m.layer_stats();
                if session.is_empty() {
                    session = rows;
                } else {
                    // further per-head mixers of the same session: same
                    // layers, same tokens — sum only state and busy time
                    for (a, b) in session.iter_mut().zip(&rows) {
                        a.state_bytes += b.state_bytes;
                        a.busy_ns += b.busy_ns;
                    }
                }
            }
            merge_layer_stats(&mut acc, &session);
        }
        acc
    }

    /// What one session costs in RAM right now: live mixer bytes while
    /// resident, the snapshot blob size while frozen in the RAM tier,
    /// one index entry once spilled to disk, None if never seen.
    pub fn session_state_bytes(&self, id: u64) -> Option<usize> {
        if let Some(r) = self.resident.iter().find(|r| r.id == id) {
            return Some(r.mixers.iter().map(|m| m.state_bytes()).sum());
        }
        self.store.session_ram_bytes(id)
    }

    pub fn session_stats(&self, id: u64) -> Option<&StreamStats> {
        self.stats.get(&id)
    }

    /// Drain all per-session telemetry, sorted by session id.
    pub fn take_stats(&mut self) -> Vec<(u64, StreamStats)> {
        let mut v: Vec<(u64, StreamStats)> = std::mem::take(&mut self.stats).into_iter().collect();
        v.sort_by_key(|&(id, _)| id);
        v
    }

    /// Process one packed chunk for `id`, admitting or restoring the
    /// session first if needed. Returns the packed outputs and the
    /// session's chunk sequence number (1-based, restore-transparent).
    pub fn process(&mut self, id: u64, chunk: &DecodeChunk) -> Result<(Vec<f32>, usize)> {
        let t0 = std::time::Instant::now();
        let slot = self.ensure_resident(id)?;
        self.clock += 1;
        self.resident[slot].last_used = self.clock;
        let len = chunk.keys.len() / (self.heads * self.resident[slot].mixers[0].d_in());
        let out = process_packed(
            &mut self.resident[slot].mixers,
            chunk,
            &mut self.scratch,
            &mut self.panel,
        );
        let elapsed_ns = t0.elapsed().as_nanos() as f64;
        let seq = self.stats.entry(id).or_default().record(len, elapsed_ns);
        Ok((out, seq))
    }

    /// Process one prefill quantum (a packed `[len, heads, d]` slice of a
    /// longer prompt) for `id` through the blocked prefill path. Same
    /// admission/restore/LRU machinery as [`ShardBank::process`] — a
    /// session evicted between quanta by interleaved decode pressure is
    /// restored transparently, pending chunk tail and all, so the prompt
    /// continues bit-identically. Stats are NOT recorded here; the caller
    /// accounts the whole prompt once via [`ShardBank::record_prefill`].
    pub fn process_prefill(
        &mut self,
        id: u64,
        queries: &[f32],
        keys: &[f32],
        values: &[f32],
    ) -> Result<Vec<f32>> {
        let slot = self.ensure_resident(id)?;
        self.clock += 1;
        self.resident[slot].last_used = self.clock;
        Ok(process_packed_prefill(
            &mut self.resident[slot].mixers,
            queries,
            keys,
            values,
            &mut self.scratch,
            &mut self.panel,
        ))
    }

    /// Advance session `id`'s state over one prefill quantum WITHOUT
    /// computing outputs — the owner-shard half of fanned-out prefill
    /// (helper threads produce the output segments from state snapshots).
    /// Post-call state is bit-identical to [`ShardBank::process_prefill`]
    /// over the same slice; admission/restore/LRU behave identically.
    pub fn process_prefill_writes(&mut self, id: u64, keys: &[f32], values: &[f32]) -> Result<()> {
        let slot = self.ensure_resident(id)?;
        self.clock += 1;
        self.resident[slot].last_used = self.clock;
        process_packed_prefill_writes(
            &mut self.resident[slot].mixers,
            keys,
            values,
            &mut self.scratch,
            &mut self.panel,
        );
        Ok(())
    }

    /// Capture session `id`'s full state as a [`pack_session`] blob
    /// without disturbing residency — the fan-out engine hands these to
    /// helper threads so they can replay output segments against the
    /// exact state the owner had at the segment boundary. Admits or
    /// restores the session first if needed (a snapshot of a
    /// never-seen session is its factory-fresh state). Pending chunk
    /// tails ride inside the blob; nothing is flushed.
    pub fn snapshot_session(&mut self, id: u64) -> Result<Vec<u8>> {
        let slot = self.ensure_resident(id)?;
        self.clock += 1;
        self.resident[slot].last_used = self.clock;
        Ok(pack_session(&self.resident[slot].mixers))
    }

    /// Account one completed prefill prompt (all quanta processed) of
    /// `tokens` tokens that took `elapsed_ns` of processing; returns the
    /// session's sequence number, shared with decode chunks.
    pub fn record_prefill(&mut self, id: u64, tokens: usize, elapsed_ns: f64) -> usize {
        self.stats.entry(id).or_default().record_prefill(tokens, elapsed_ns)
    }

    /// Account one completed generation request (prompt ingested +
    /// completion sampled); returns the session's sequence number.
    pub fn record_generate(&mut self, id: u64, prompt_tokens: usize, new_tokens: usize) -> usize {
        self.stats.entry(id).or_default().record_generate(prompt_tokens, new_tokens)
    }

    /// Run `f` against the resident [`LmModel`] of session `id` — the
    /// token-level access path of the generation engine. Admission,
    /// restore and the LRU clock behave exactly as for
    /// [`ShardBank::process`], so a generating session LRU-evicted
    /// between scheduling rounds thaws transparently (generation state
    /// rides inside the `"lm"` snapshot frame) and keeps sampling the
    /// same stream. Errors if the session's machine is not an LM — the
    /// engine was not started in LM mode — costing that request, not the
    /// shard.
    pub fn with_lm<R>(
        &mut self,
        id: u64,
        f: impl FnOnce(&mut LmModel, &mut Scratch) -> R,
    ) -> Result<R> {
        let slot = self.ensure_resident(id)?;
        self.clock += 1;
        self.resident[slot].last_used = self.clock;
        let resident = &mut self.resident[slot];
        let lm = resident
            .mixers
            .first_mut()
            .and_then(|m| m.as_lm_mut())
            .ok_or_else(|| anyhow::anyhow!("session {id} is not a language-model session"))?;
        Ok(f(lm, &mut self.scratch))
    }

    /// Make `id` resident (create / restore), evicting LRU sessions if the
    /// cap would be exceeded. Returns the resident slot index.
    fn ensure_resident(&mut self, id: u64) -> Result<usize> {
        if let Some(i) = self.resident.iter().position(|r| r.id == id) {
            return Ok(i);
        }
        while self.resident.len() >= self.max_resident {
            self.evict_lru();
        }
        let mixers = match self.store.take(id) {
            Ok(Some(blob)) => {
                // the blob is consumed either way: on a decode failure the
                // session is discarded and a re-arrival starts it fresh
                let m = unpack_session(&blob, self.heads)
                    .with_context(|| format!("restoring session {id}"))?;
                self.restores += 1;
                m
            }
            Ok(None) => (0..self.heads).map(|h| (self.factory)(id, h)).collect(),
            // torn/corrupt/missing disk blob: a typed, recoverable error
            // that costs this request only — the entry is consumed, so a
            // re-arrival starts the session fresh and the shard keeps
            // serving everyone else
            Err(e) => {
                return Err(anyhow::Error::new(e)
                    .context(format!("restoring session {id} from the disk tier")))
            }
        };
        self.admit_mixers(id, mixers)
    }

    /// The shared admission tail: re-apply the shard prefill policy,
    /// enforce the dim invariants, and push the session resident.
    fn admit_mixers(&mut self, id: u64, mut mixers: Vec<Box<dyn SeqMixer>>) -> Result<usize> {
        // the shard's prefill policy is runtime state, not session state:
        // snapshots thaw in Exact mode and the policy is re-applied here,
        // on admission and on every restore
        if self.prefill_mode != PrefillMode::Exact {
            for m in &mut mixers {
                m.set_prefill_mode(self.prefill_mode);
            }
        }
        // the dim invariant MixerBank hard-asserts, as a recoverable error
        // here: a mismatched factory or cross-shape blob must cost this
        // session (failed chunk), never corrupt panels or kill the shard
        let (di, dv) = (mixers[0].d_in(), mixers[0].d_out());
        anyhow::ensure!(
            mixers.iter().all(|m| m.d_in() == di && m.d_out() == dv),
            "session {id}: heads disagree on d_in/d_out"
        );
        if self.d_in == 0 {
            self.d_in = di;
            self.d_out = dv;
        } else {
            anyhow::ensure!(
                self.d_in == di && self.d_out == dv,
                "session {id}: dims {di}x{dv} mismatch the shard's {}x{}",
                self.d_in,
                self.d_out
            );
        }
        self.resident.push(Resident { id, mixers, last_used: self.clock });
        Ok(self.resident.len() - 1)
    }

    /// Admit session `id` directly from a packed-session blob — the
    /// prefix-fork path: the blob is an immutable template captured by
    /// [`ShardBank::snapshot_session`] after prefilling a shared prefix,
    /// and forking from it is bit-identical to having run that prefill
    /// (snapshot restore is bit-exact). Refuses if the bank already
    /// holds any state for `id`: forking must never clobber history.
    pub fn admit_from_blob(&mut self, id: u64, blob: &[u8]) -> Result<()> {
        anyhow::ensure!(
            !self.has_state(id),
            "session {id} already has state; refusing prefix fork"
        );
        while self.resident.len() >= self.max_resident {
            self.evict_lru();
        }
        let mixers = unpack_session(blob, self.heads)
            .with_context(|| format!("forking session {id} from prefix template"))?;
        self.admit_mixers(id, mixers)?;
        Ok(())
    }

    /// Evict the least-recently-used resident session to a snapshot blob.
    fn evict_lru(&mut self) {
        let Some(i) = self
            .resident
            .iter()
            .enumerate()
            .min_by_key(|(_, r)| r.last_used)
            .map(|(i, _)| i)
        else {
            return;
        };
        let r = self.resident.swap_remove(i);
        self.store.insert(r.id, pack_session(&r.mixers));
        self.evictions += 1;
    }

    /// Explicitly evict one session (e.g. on client abandon). No-op if the
    /// session is not resident.
    pub fn evict(&mut self, id: u64) {
        if let Some(i) = self.resident.iter().position(|r| r.id == id) {
            let r = self.resident.swap_remove(i);
            self.store.insert(r.id, pack_session(&r.mixers));
            self.evictions += 1;
        }
    }

    /// Force every resident session's buffered chunk tail into long-term
    /// state (evicted sessions carry their tails inside the blob and merge
    /// on their next chunk after restore).
    pub fn flush_all(&mut self) {
        for r in &mut self.resident {
            for m in &mut r.mixers {
                m.flush();
            }
        }
    }
}

/// Pack a session's per-head mixers into one blob: head count, then one
/// length-prefixed [`snapshot::save`] blob per head.
pub fn pack_session(mixers: &[Box<dyn SeqMixer>]) -> Vec<u8> {
    let mut w = snapshot::Writer::new();
    w.u32(mixers.len() as u32);
    for m in mixers {
        w.bytes(&snapshot::save(m.as_ref()));
    }
    w.into_bytes()
}

/// Inverse of [`pack_session`]; `heads` cross-checks the blob.
pub fn unpack_session(blob: &[u8], heads: usize) -> Result<Vec<Box<dyn SeqMixer>>> {
    let mut r = snapshot::Reader::new(blob);
    let n = r.u32()? as usize;
    anyhow::ensure!(n == heads, "session blob has {n} heads, shard expects {heads}");
    let mut mixers = Vec::with_capacity(n);
    for h in 0..n {
        mixers.push(snapshot::restore(r.bytes()?).with_context(|| format!("head {h}"))?);
    }
    anyhow::ensure!(
        r.remaining() == 0,
        "session blob has {} trailing bytes after {n} heads",
        r.remaining()
    );
    Ok(mixers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ovqcore::ovq::{OvqConfig, OvqState};
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn ovq_bank(streams: usize, heads: usize, d: usize, n: usize, chunk: usize) -> MixerBank {
        MixerBank::new(streams, heads, |_, _| {
            Box::new(OvqState::new(OvqConfig::new(d, n, chunk)))
        })
    }

    fn chunk_of(rng: &mut Rng, len: usize, hd: usize) -> DecodeChunk {
        DecodeChunk {
            queries: randv(rng, len * hd),
            keys: randv(rng, len * hd),
            values: randv(rng, len * hd),
        }
    }

    #[test]
    fn bank_matches_single_mixer_per_head() {
        // a 2-head 1-stream bank must produce, per head, exactly what a
        // standalone mixer fed that head's slice produces
        let (d, n, chunk, len) = (8, 64, 16, 16);
        let mut rng = Rng::new(1);
        let mut bank = ovq_bank(1, 2, d, n, chunk);
        let mut solo0 = OvqState::new(OvqConfig::new(d, n, chunk));
        let mut solo1 = OvqState::new(OvqConfig::new(d, n, chunk));
        let mut scratch = Scratch::new();

        for _ in 0..3 {
            let q = randv(&mut rng, len * 2 * d);
            let k = randv(&mut rng, len * 2 * d);
            let v = randv(&mut rng, len * 2 * d);
            bank.submit(
                0,
                DecodeChunk { queries: q.clone(), keys: k.clone(), values: v.clone() },
            );
            let got = bank.step().unwrap();
            assert_eq!(got.stream, 0);

            // reference: de-interleave by hand, run each solo mixer
            for (head, solo) in [(0usize, &mut solo0), (1usize, &mut solo1)] {
                let mut hq = vec![0.0; len * d];
                let mut hk = vec![0.0; len * d];
                let mut hv = vec![0.0; len * d];
                for i in 0..len {
                    let row = (i * 2 + head) * d;
                    hq[i * d..(i + 1) * d].copy_from_slice(&q[row..row + d]);
                    hk[i * d..(i + 1) * d].copy_from_slice(&k[row..row + d]);
                    hv[i * d..(i + 1) * d].copy_from_slice(&v[row..row + d]);
                }
                let mut want = vec![0.0; len * d];
                solo.process_chunk(&hq, &hk, &hv, &mut want, &mut scratch);
                for i in 0..len {
                    let row = (i * 2 + head) * d;
                    for j in 0..d {
                        assert!(
                            (got.out[row + j] - want[i * d + j]).abs() < 1e-6,
                            "head {head} token {i} dim {j}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn bank_prefill_matches_queued_decode_bit_exactly() {
        // the same packed tokens through prefill() and through
        // submit()+step() must agree to the bit, and the stats must
        // attribute them to the prefill path
        let (d, len) = (8usize, 40usize);
        let mut rng = Rng::new(9);
        let mut a = ovq_bank(1, 2, d, 32, 16);
        let mut b = ovq_bank(1, 2, d, 32, 16);
        let chunk = chunk_of(&mut rng, len, 2 * d);
        let got = a.prefill(0, &chunk);
        b.submit(
            0,
            DecodeChunk {
                queries: chunk.queries.clone(),
                keys: chunk.keys.clone(),
                values: chunk.values.clone(),
            },
        );
        let want = b.step().unwrap();
        assert_eq!(got.out.len(), want.out.len());
        assert!(
            got.out.iter().zip(&want.out).all(|(x, y)| x.to_bits() == y.to_bits()),
            "prefill path diverged from the decode path"
        );
        assert_eq!(a.stats[0].prefill_tokens, len);
        assert_eq!(a.stats[0].prefill_chunks, 1);
        assert_eq!(a.stats[0].tokens, len);
        assert_eq!(b.stats[0].prefill_tokens, 0);
    }

    #[test]
    fn shard_prefill_survives_eviction_between_quanta() {
        // a prompt ingested in two quanta with a freeze/thaw in between
        // must equal one uninterrupted prefill — the property that lets
        // the engine LRU-evict a half-prefilled session under pressure
        let (heads, d, total, cut) = (2usize, 8usize, 50usize, 23usize);
        let mut rng = Rng::new(10);
        let mut shard = ovq_shard(heads, d, 32, 16, 4);
        let mut mirror = ovq_shard(heads, d, 32, 16, 4);
        let c = chunk_of(&mut rng, total, heads * d);
        let hd = heads * d;

        let mut got = shard
            .process_prefill(8, &c.queries[..cut * hd], &c.keys[..cut * hd], &c.values[..cut * hd])
            .unwrap();
        shard.evict(8); // freeze mid-prompt, pending tail and all
        assert_eq!(shard.evictions, 1);
        let (q2, k2, v2) = (&c.queries[cut * hd..], &c.keys[cut * hd..], &c.values[cut * hd..]);
        got.extend_from_slice(&shard.process_prefill(8, q2, k2, v2).unwrap());
        assert_eq!(shard.restores, 1);
        let seq = shard.record_prefill(8, total, 1.0);
        assert_eq!(seq, 1);
        assert_eq!(shard.session_stats(8).unwrap().prefill_tokens, total);

        let want = mirror.process_prefill(8, &c.queries, &c.keys, &c.values).unwrap();
        assert!(
            got.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits()),
            "mid-prompt eviction changed the prefill outputs"
        );
    }

    #[test]
    fn shard_prefill_writes_matches_full_prefill_state_bit_exactly() {
        // the fan-out contract: advancing state through the writes-only
        // path must land on exactly the state the full prefill produces,
        // and snapshot_session must capture it without evicting
        let (heads, d, total) = (2usize, 8usize, 50usize);
        let mut rng = Rng::new(13);
        let mut shard = ovq_shard(heads, d, 32, 16, 4);
        let mut mirror = ovq_shard(heads, d, 32, 16, 4);
        let c = chunk_of(&mut rng, total, heads * d);

        shard.process_prefill_writes(4, &c.keys, &c.values).unwrap();
        mirror.process_prefill(4, &c.queries, &c.keys, &c.values).unwrap();

        let a = shard.snapshot_session(4).unwrap();
        let b = mirror.snapshot_session(4).unwrap();
        assert_eq!(a, b, "writes-only prefill state diverged from full prefill");
        // snapshot_session is non-destructive: the session stays resident
        assert_eq!(shard.resident_sessions(), 1);
        assert_eq!(shard.evictions, 0);
        // and a snapshot of a never-seen session is its factory state
        let factory_fresh: Vec<Box<dyn SeqMixer>> = (0..heads)
            .map(|_| Box::new(OvqState::new(OvqConfig::new(d, 32, 16))) as Box<dyn SeqMixer>)
            .collect();
        let fresh = shard.snapshot_session(77).unwrap();
        assert_eq!(fresh, pack_session(&factory_fresh));
    }

    #[test]
    fn round_robin_interleaves_streams() {
        let (d, len) = (4, 8);
        let mut rng = Rng::new(2);
        let mut bank = ovq_bank(3, 1, d, 32, 8);
        // two chunks per stream
        for s in 0..3 {
            for _ in 0..2 {
                bank.submit(s, chunk_of(&mut rng, len, d));
            }
        }
        assert_eq!(bank.pending_chunks(), 6);
        let order: Vec<usize> = bank.drain().iter().map(|o| o.stream).collect();
        assert_eq!(order, vec![0, 1, 2, 0, 1, 2], "round-robin order");
        assert_eq!(bank.pending_chunks(), 0);
        for s in 0..3 {
            assert_eq!(bank.stats[s].tokens, 2 * len);
            assert_eq!(bank.stats[s].chunks, 2);
        }
    }

    #[test]
    fn state_is_flat_across_long_decode() {
        let mut rng = Rng::new(3);
        let mut bank = ovq_bank(2, 2, 8, 32, 16);
        let mut cap = 0usize;
        for round in 0..20 {
            for s in 0..2 {
                bank.submit(s, chunk_of(&mut rng, 16, 2 * 8));
            }
            bank.drain();
            if round == 10 {
                cap = bank.state_bytes();
            }
        }
        // OVQ state saturates: late-decode state is no bigger than mid-decode
        assert!(bank.state_bytes() <= cap + 2 * 2 * 16 * 2 * 8 * 4, "state must plateau");
        assert_eq!(bank.stats[0].tokens, 20 * 16);
    }

    #[test]
    fn skewed_queues_still_drain_fairly() {
        let (d, len) = (4, 4);
        let mut rng = Rng::new(4);
        let mut bank = ovq_bank(2, 1, d, 16, 4);
        for _ in 0..3 {
            bank.submit(0, chunk_of(&mut rng, len, d));
        }
        bank.submit(1, chunk_of(&mut rng, len, d));
        let order: Vec<usize> = bank.drain().iter().map(|o| o.stream).collect();
        // stream 1's single chunk is served second, not last
        assert_eq!(order, vec![0, 1, 0, 0]);
    }

    // ----------------------------------------------------------- ShardBank

    fn ovq_shard(heads: usize, d: usize, n: usize, chunk: usize, cap: usize) -> ShardBank {
        ShardBank::new(heads, cap, move |_, _| {
            Box::new(OvqState::new(OvqConfig::new(d, n, chunk)))
        })
    }

    #[test]
    fn shard_admits_processes_and_tracks_stats() {
        let (heads, d, len) = (2usize, 8usize, 16usize);
        let mut rng = Rng::new(5);
        let mut shard = ovq_shard(heads, d, 32, 16, 8);
        for (id, rounds) in [(7u64, 3usize), (9, 1)] {
            for r in 0..rounds {
                let (out, seq) = shard.process(id, &chunk_of(&mut rng, len, heads * d)).unwrap();
                assert_eq!(out.len(), len * heads * d);
                assert_eq!(seq, r + 1);
            }
        }
        assert_eq!(shard.resident_sessions(), 2);
        assert_eq!(shard.sessions(), 2);
        assert_eq!(shard.session_stats(7).unwrap().tokens, 3 * len);
        assert_eq!(shard.session_stats(9).unwrap().chunks, 1);
        assert_eq!(shard.evictions, 0);
        assert!(shard.resident_bytes() > 0);
        assert_eq!(shard.snapshot_bytes(), 0);
    }

    #[test]
    fn shard_evicts_lru_and_restores_bit_identically() {
        // cap 2, three sessions: admitting the third must evict the LRU
        // (session 1, idle since its chunk); a re-arrival of session 1 must
        // restore it and continue exactly where it left off
        let (heads, d, len) = (2usize, 8usize, 16usize);
        let mut rng = Rng::new(6);
        let mut shard = ovq_shard(heads, d, 32, 16, 2);
        // a mirror session in an uncapped shard gives the golden outputs
        let mut mirror = ovq_shard(heads, d, 32, 16, 8);

        let c1a = chunk_of(&mut rng, len, heads * d);
        let c1b = chunk_of(&mut rng, len, heads * d);
        let c2 = chunk_of(&mut rng, len, heads * d);
        let c3 = chunk_of(&mut rng, len, heads * d);

        shard.process(1, &c1a).unwrap();
        shard.process(2, &c2).unwrap();
        shard.process(3, &c3).unwrap(); // evicts session 1
        assert_eq!(shard.evictions, 1);
        assert_eq!(shard.resident_sessions(), 2);
        assert_eq!(shard.evicted_sessions(), 1);

        // accounting: the evicted session now costs exactly its blob
        let blob_bytes = shard.session_state_bytes(1).unwrap();
        assert_eq!(blob_bytes, shard.snapshot_bytes());
        assert!(blob_bytes > 0);

        // re-arrival: restore + continue must equal the uninterrupted run
        let (got, seq) = shard.process(1, &c1b).unwrap();
        assert_eq!(seq, 2, "chunk sequence survives eviction");
        assert_eq!(shard.restores, 1);
        mirror.process(1, &c1a).unwrap();
        let (want, _) = mirror.process(1, &c1b).unwrap();
        assert_eq!(got, want, "restore must be bit-identical");
        // stats survived the round trip
        assert_eq!(shard.session_stats(1).unwrap().tokens, 2 * len);
    }

    #[test]
    fn shard_explicit_evict_then_flush_accounting() {
        let (heads, d, len) = (1usize, 8usize, 10usize);
        let mut rng = Rng::new(7);
        let mut shard = ovq_shard(heads, d, 32, 16, 4);
        shard.process(42, &chunk_of(&mut rng, len, heads * d)).unwrap();
        let live = shard.session_state_bytes(42).unwrap();
        shard.evict(42);
        assert_eq!(shard.resident_sessions(), 0);
        let frozen = shard.session_state_bytes(42).unwrap();
        assert_eq!(frozen, shard.snapshot_bytes());
        // the blob carries the pending tail (10 tokens, not yet merged) +
        // framing, so it is within the same order as the live state
        assert!(frozen > 0 && live > 0);
        assert!(shard.session_state_bytes(99).is_none());
        shard.flush_all(); // no resident sessions: must be a no-op
        assert_eq!(shard.evictions, 1);
    }

    #[test]
    fn shard_serves_layer_stacks_and_splits_telemetry_per_layer() {
        // a full 2-layer hybrid model stack admitted as an ordinary
        // session (bank heads = 1, row width = d_model): processing works
        // through the trait and the per-layer telemetry split surfaces
        use crate::ovqcore::memstate::MixerKind;
        use crate::ovqcore::stack::{LayerStack, StackConfig};
        let cfg = StackConfig::hybrid(
            8,
            16,
            2,
            4,
            8,
            vec![MixerKind::Ovq { n_max: 16 }, MixerKind::Gdn],
        );
        let mut shard = ShardBank::new(1, 4, move |id, _| {
            Box::new(LayerStack::new(cfg.clone(), id)) as Box<dyn SeqMixer>
        });
        let mut rng = Rng::new(12);
        let (out, seq) = shard.process(3, &chunk_of(&mut rng, 10, 8)).unwrap();
        assert_eq!(out.len(), 10 * 8);
        assert_eq!(seq, 1);
        let stats = shard.layer_stats();
        assert_eq!(stats.len(), 2, "one telemetry row per stack layer");
        assert_eq!(stats[0].kind, "ovq");
        assert_eq!(stats[1].kind, "gdn");
        assert!(stats.iter().all(|s| s.tokens == 10));
        assert_eq!(
            stats.iter().map(|s| s.state_bytes).sum::<usize>(),
            shard.resident_bytes(),
            "layer split must cover the resident bytes"
        );
        // freeze/thaw through the container frame keeps serving
        shard.evict(3);
        assert!(shard.layer_stats().is_empty(), "no resident sessions, no split");
        let (out2, seq2) = shard.process(3, &chunk_of(&mut rng, 4, 8)).unwrap();
        assert_eq!(out2.len(), 4 * 8);
        assert_eq!(seq2, 2);
        assert_eq!(shard.restores, 1);
    }

    #[test]
    fn shard_with_lm_freezes_and_thaws_generation_state() {
        // the generation engine's access path: an LM session reached
        // through with_lm, explicitly evicted mid-generation, must thaw
        // with history ring, RNG stream and token counts intact
        use crate::ovqcore::lm::{LmConfig, LmModel};
        use crate::ovqcore::memstate::MixerKind;
        use crate::ovqcore::stack::StackConfig;
        let cfg = LmConfig::new(
            24,
            StackConfig::hybrid(8, 16, 2, 4, 8, vec![MixerKind::Ovq { n_max: 16 }]),
        );
        let mut shard = ShardBank::new(1, 4, move |id, _| {
            Box::new(LmModel::new(cfg.clone(), id)) as Box<dyn SeqMixer>
        });
        let mut logits = vec![0.0f32; 24];
        shard
            .with_lm(5, |lm, scratch| {
                lm.prefill_tokens(&[1, 2, 3, 4, 5], &mut logits, scratch);
                lm.begin_gen(0xAB, 8);
                lm.gen_mut().unwrap().push(7);
            })
            .unwrap();
        let draw_before = shard.with_lm(5, |lm, _| lm.gen_mut().unwrap().rng.next_u64()).unwrap();
        shard.evict(5);
        assert_eq!(shard.evictions, 1);
        let (recent, produced, draw_after) = shard
            .with_lm(5, |lm, _| {
                let g = lm.gen_mut().unwrap();
                (g.recent().to_vec(), g.produced, g.rng.next_u64())
            })
            .unwrap();
        assert_eq!(shard.restores, 1);
        assert_eq!(recent, vec![7]);
        assert_eq!(produced, 1);
        assert_ne!(draw_before, draw_after, "rng must continue, not restart");
        let seq = shard.record_generate(5, 5, 1);
        assert_eq!(seq, 1);
        let st = shard.session_stats(5).unwrap();
        assert_eq!(st.tokens, 6);
        assert_eq!(st.prefill_tokens, 5);
        assert_eq!(st.gen_tokens, 1);
        assert_eq!(st.gen_chunks, 1);
    }

    #[test]
    fn with_lm_on_a_plain_mixer_session_errs_cleanly() {
        let mut shard = ovq_shard(1, 8, 32, 16, 4);
        let err = shard.with_lm(9, |_, _| ()).unwrap_err();
        assert!(format!("{err}").contains("not a language-model"), "{err}");
    }

    #[test]
    fn pack_unpack_session_round_trip() {
        let mut rng = Rng::new(8);
        let mixers: Vec<Box<dyn SeqMixer>> = (0..3)
            .map(|_| {
                let mut m: Box<dyn SeqMixer> =
                    Box::new(OvqState::new(OvqConfig::new(4, 16, 8)));
                for _ in 0..5 {
                    let k = randv(&mut rng, 4);
                    let v = randv(&mut rng, 4);
                    m.write(&k, &v);
                }
                m
            })
            .collect();
        let blob = pack_session(&mixers);
        let back = unpack_session(&blob, 3).unwrap();
        assert_eq!(back.len(), 3);
        for (a, b) in mixers.iter().zip(&back) {
            assert_eq!(a.tokens(), b.tokens());
            assert_eq!(a.state_bytes(), b.state_bytes());
        }
        assert!(unpack_session(&blob, 2).is_err(), "head-count mismatch must fail");
    }

    // ---------------------------------------------------- tiered store

    use crate::ovqcore::store::{StoreConfig, TempDir, INDEX_ENTRY_BYTES};

    fn disk_shard(dir: &std::path::Path, cap: usize, budget: usize) -> ShardBank {
        let mut shard = ovq_shard(2, 8, 32, 16, cap);
        shard.configure_store(StoreConfig {
            spill_dir: Some(dir.to_path_buf()),
            ram_budget: budget,
            shared: None,
        });
        shard
    }

    #[test]
    fn shard_spills_to_disk_and_restores_bit_identically() {
        // budget 0: every eviction blob goes straight to disk. Serving
        // through the disk tier must stay bit-identical to an uncapped
        // RAM-only shard.
        let (heads, d, len) = (2usize, 8usize, 16usize);
        let td = TempDir::new("bank-spill");
        let mut rng = Rng::new(21);
        let mut shard = disk_shard(td.path(), 1, 0);
        let mut mirror = ovq_shard(heads, d, 32, 16, 8);

        let chunks: Vec<(u64, DecodeChunk)> = [1u64, 2, 1, 2, 1]
            .iter()
            .map(|&id| (id, chunk_of(&mut rng, len, heads * d)))
            .collect();
        for (id, c) in &chunks {
            let (got, _) = shard.process(*id, c).unwrap();
            let (want, _) = mirror.process(*id, c).unwrap();
            assert_eq!(got, want, "disk-tier churn diverged for session {id}");
        }
        shard.sync_store();
        assert!(shard.spills() >= 1, "cap 1 + budget 0 must have spilled");
        assert!(shard.disk_restores() >= 1, "revisits must have restored from disk");
        assert_eq!(shard.resident_sessions(), 1);
        assert_eq!(shard.disk_sessions(), 1);
    }

    #[test]
    fn tier_accounting_charges_spilled_sessions_an_index_entry_only() {
        // satellite: a disk-spilled session costs ~0 RAM. Cross-check the
        // reported numbers exactly against live bank state.
        let (heads, d, len) = (2usize, 8usize, 16usize);
        let td = TempDir::new("bank-acct");
        let mut rng = Rng::new(22);
        let mut shard = disk_shard(td.path(), 1, 0);
        shard.process(1, &chunk_of(&mut rng, len, heads * d)).unwrap();
        shard.process(2, &chunk_of(&mut rng, len, heads * d)).unwrap(); // evicts 1
        shard.sync_store();
        assert_eq!(shard.evictions, 1);
        assert_eq!(shard.spills(), 1);
        // Frozen session 1 sits on disk: its RAM cost is one index entry,
        // and the bank-wide snapshot accounting says exactly that.
        assert_eq!(shard.session_state_bytes(1), Some(INDEX_ENTRY_BYTES));
        assert_eq!(shard.snapshot_bytes(), INDEX_ENTRY_BYTES);
        assert!(shard.disk_bytes() > 0, "the payload lives on disk");
        // Resident session 2 is charged its live mixer bytes; layer_stats
        // covers residents only and must sum to resident_bytes.
        assert_eq!(
            shard.layer_stats().iter().map(|s| s.state_bytes).sum::<usize>(),
            shard.resident_bytes()
        );
        // Pull 1 back: the disk entry disappears, RAM accounting follows.
        shard.process(1, &chunk_of(&mut rng, len, heads * d)).unwrap();
        shard.sync_store();
        assert_eq!(shard.disk_restores(), 1);
        assert_eq!(shard.session_state_bytes(2), Some(INDEX_ENTRY_BYTES));
        assert_eq!(shard.snapshot_bytes(), INDEX_ENTRY_BYTES);
    }

    #[test]
    fn corrupt_disk_blob_costs_one_request_not_the_shard() {
        let (heads, d, len) = (2usize, 8usize, 16usize);
        let td = TempDir::new("bank-corrupt");
        let mut rng = Rng::new(23);
        let mut shard = disk_shard(td.path(), 1, 0);
        shard.process(1, &chunk_of(&mut rng, len, heads * d)).unwrap();
        shard.process(2, &chunk_of(&mut rng, len, heads * d)).unwrap(); // spills 1
        shard.sync_store();
        // Flip a payload bit in session 1's spilled frame.
        let p = td.path().join(format!("s{:016x}.blob", 1u64));
        let mut raw = std::fs::read(&p).unwrap();
        let last = raw.len() - 1;
        raw[last] ^= 1;
        std::fs::write(&p, &raw).unwrap();
        // The torn blob is a clean typed error on the victim...
        let err = shard.process(1, &chunk_of(&mut rng, len, heads * d)).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        // ...the shard keeps serving other sessions...
        shard.process(2, &chunk_of(&mut rng, len, heads * d)).unwrap();
        // ...and a re-arrival of the victim starts fresh instead of
        // hitting the same corpse again.
        let (out, seq) = shard.process(1, &chunk_of(&mut rng, len, heads * d)).unwrap();
        assert_eq!(out.len(), len * heads * d);
        assert_eq!(seq, 2, "stats survive; state restarted");
    }

    #[test]
    fn missing_disk_blob_is_a_clean_error() {
        let (heads, d, len) = (2usize, 8usize, 16usize);
        let td = TempDir::new("bank-missing");
        let mut rng = Rng::new(24);
        let mut shard = disk_shard(td.path(), 1, 0);
        shard.process(1, &chunk_of(&mut rng, len, heads * d)).unwrap();
        shard.process(2, &chunk_of(&mut rng, len, heads * d)).unwrap();
        shard.sync_store();
        std::fs::remove_file(td.path().join(format!("s{:016x}.blob", 1u64))).unwrap();
        let err = shard.process(1, &chunk_of(&mut rng, len, heads * d)).unwrap_err();
        assert!(format!("{err:#}").contains("unreadable"), "{err:#}");
        shard.process(2, &chunk_of(&mut rng, len, heads * d)).unwrap();
    }

    #[test]
    fn prefix_fork_admits_template_bit_identically() {
        // freeze a prefilled session as a template, fork a fresh id from
        // it, and the fork's packed state must equal the template's.
        let (heads, d, total) = (2usize, 8usize, 40usize);
        let mut rng = Rng::new(25);
        let mut shard = ovq_shard(heads, d, 32, 16, 4);
        let c = chunk_of(&mut rng, total, heads * d);
        shard.process_prefill(1, &c.queries, &c.keys, &c.values).unwrap();
        let template = shard.snapshot_session(1).unwrap();

        shard.admit_from_blob(9, &template).unwrap();
        assert_eq!(shard.snapshot_session(9).unwrap(), template, "fork must be bit-identical");
        // Forking into a session that already has state must refuse.
        let err = shard.admit_from_blob(1, &template).unwrap_err();
        assert!(format!("{err}").contains("already has state"), "{err}");
        shard.evict(9);
        let err = shard.admit_from_blob(9, &template).unwrap_err();
        assert!(format!("{err}").contains("already has state"), "{err}");
    }
}
