//! Gated-delta-net state machine (Yang et al. 2024a), token recurrence
//!    S_t = a_t S_{t-1} + b_t k_t^T (v_t - k_t S_{t-1}),  o_t = q_t S_t.
//! Used for serving-side decode and memory accounting, through
//! [`SeqMixer`]. The trait's ungated `write` applies the configured
//! default gates (`alpha`, `beta`); [`GdnState::write_gated`] exposes the
//! full per-token recurrence.

use anyhow::Result;

use super::kernels;
use super::mixer::{PrefillMode, Scratch, SeqMixer};
use super::snapshot;

#[derive(Debug, Clone)]
pub struct GdnState {
    pub d: usize,
    /// [d, d] row-major fast-weight matrix
    pub s: Vec<f32>,
    pub t: usize,
    /// default decay gate used by the trait-level `write`
    pub alpha: f32,
    /// default write-strength gate used by the trait-level `write`
    pub beta: f32,
    /// prefill policy (runtime-only — never serialized, snapshots thaw
    /// in `Exact` and the serving layer re-applies its configured mode)
    pub mode: PrefillMode,
}

/// Reusable per-prefill-call workspace for the chunkwise scan form —
/// allocated once per `process_prefill`/`prefill_writes` call and reused
/// across every block of the slice.
#[derive(Default)]
struct ChunkWs {
    /// `[L, L]` intra-block key Gram matrix `k_i . k_j`
    kk: Vec<f32>,
    /// `[L, L]` query-key similarities `q_i . k_j`
    qk: Vec<f32>,
    /// `[L, d]` solved pseudo-values `u_i`
    u: Vec<f32>,
    /// `[L, d]` state-carry rows `k_i S_0`
    carry: Vec<f32>,
    /// `[L]` per-row combination weights
    w: Vec<f32>,
    /// `[L + 1]` decay powers `alpha^0 .. alpha^L`
    apow: Vec<f32>,
}

fn grow(buf: &mut Vec<f32>, n: usize) -> &mut [f32] {
    if buf.len() < n {
        buf.resize(n, 0.0);
    }
    &mut buf[..n]
}

impl GdnState {
    pub fn new(d: usize) -> GdnState {
        GdnState { d, s: vec![0.0; d * d], t: 0, alpha: 1.0, beta: 1.0, mode: PrefillMode::Exact }
    }

    /// Rebuild from a [`snapshot::save`] payload.
    pub fn from_snapshot(r: &mut snapshot::Reader<'_>) -> Result<GdnState> {
        let d = r.usize()?;
        // bound d BEFORE GdnState::new allocates the [d, d] state — a
        // corrupt blob must err cleanly, never overflow d * d or demand
        // a wild allocation (snapshot's no-panics-on-untrusted-bytes)
        anyhow::ensure!(
            d > 0 && d <= (1 << 12),
            "gdn snapshot claims an implausible width (d={d})"
        );
        let mut st = GdnState::new(d);
        st.t = r.usize()?;
        st.alpha = r.f32()?;
        st.beta = r.f32()?;
        st.s = r.f32s()?;
        anyhow::ensure!(st.s.len() == st.d * st.d, "gdn snapshot has inconsistent shapes");
        Ok(st)
    }

    pub fn write_gated(&mut self, k: &[f32], v: &[f32], alpha: f32, beta: f32) {
        let mut pred = vec![0.0f32; self.d];
        self.write_gated_into(k, v, alpha, beta, &mut pred);
    }

    /// [`GdnState::write_gated`] with a caller-owned `pred` buffer (length
    /// `d`, any contents — it is overwritten), so the prefill path absorbs
    /// a whole prompt without one heap allocation per token.
    pub fn write_gated_into(
        &mut self,
        k: &[f32],
        v: &[f32],
        alpha: f32,
        beta: f32,
        pred: &mut [f32],
    ) {
        let d = self.d;
        // pred = k S (length d) — the dispatched transpose-matvec, whose
        // scalar tile is bit-identical to the historical hand-rolled loop
        let pred = &mut pred[..d];
        kernels::vecmat(&k[..d], &self.s, d, d, pred);
        for i in 0..d {
            let row = &mut self.s[i * d..(i + 1) * d];
            let ki = beta * k[i];
            for j in 0..d {
                row[j] = alpha * row[j] + ki * (v[j] - pred[j]);
            }
        }
        self.t += 1;
    }

    /// One chunkwise-blocked gated-delta block of `l` tokens with the
    /// CONSTANT gates the prefill path uses (`alpha`, `beta`). Instead of
    /// materializing the `[L, d, d]` ΔS tensor (the paper's §3.4 cost),
    /// the block is reduced to `[L, L]` similarity matrices plus an
    /// `[L, d]` forward substitution:
    ///
    /// ```text
    ///   u_i = v_i − αⁱ (k_i S₀) − Σ_{j<i} β α^{i−1−j} (k_i·k_j) u_j
    ///   o_i = α^{i+1} (q_i S₀) + Σ_{j≤i} β α^{i−j} (q_i·k_j) u_j
    ///   S_L = α^L S₀ + Σ_j β α^{L−1−j} k_jᵀ u_j
    /// ```
    ///
    /// Every heavy sweep is a tiled kernel ([`kernels::matmul_rows`] for
    /// the Gram matrices, [`kernels::axpy_rows`] for the combinations).
    /// This reassociates the FP accumulation relative to the serial
    /// recurrence, so it only runs in `Chunkwise` mode under the
    /// documented tolerance. `queries`/`out` are optional: `None` skips
    /// the output half entirely (the fanned-out owner advance).
    fn chunkwise_block(
        &mut self,
        queries: Option<&[f32]>,
        keys: &[f32],
        values: &[f32],
        out: Option<&mut [f32]>,
        ws: &mut ChunkWs,
    ) {
        let d = self.d;
        let l = keys.len() / d;
        let (a, b) = (self.alpha, self.beta);
        ws.apow.clear();
        ws.apow.reserve(l + 1);
        let mut p = 1.0f32;
        for _ in 0..=l {
            ws.apow.push(p);
            p *= a;
        }
        let kk = grow(&mut ws.kk, l * l);
        kernels::matmul_rows(keys, l, d, keys, l, kk);
        let carry = grow(&mut ws.carry, l * d);
        for i in 0..l {
            let ci = &mut carry[i * d..(i + 1) * d];
            kernels::vecmat(&keys[i * d..(i + 1) * d], &self.s, d, d, ci);
        }
        grow(&mut ws.u, l * d);
        grow(&mut ws.w, l);
        for i in 0..l {
            let (head, tail) = ws.u.split_at_mut(i * d);
            let ui = &mut tail[..d];
            for j in 0..d {
                ui[j] = values[i * d + j] - ws.apow[i] * ws.carry[i * d + j];
            }
            if i > 0 {
                for j in 0..i {
                    ws.w[j] = -b * ws.apow[i - 1 - j] * ws.kk[i * l + j];
                }
                kernels::axpy_rows(head, i, d, &ws.w[..i], ui);
            }
        }
        if let (Some(queries), Some(out)) = (queries, out) {
            let qk = grow(&mut ws.qk, l * l);
            kernels::matmul_rows(keys, l, d, queries, l, qk);
            for i in 0..l {
                let oi = &mut out[i * d..(i + 1) * d];
                kernels::vecmat(&queries[i * d..(i + 1) * d], &self.s, d, d, oi);
                let ai = ws.apow[i + 1];
                for x in oi.iter_mut() {
                    *x *= ai;
                }
                for j in 0..=i {
                    ws.w[j] = b * ws.apow[i - j] * ws.qk[i * l + j];
                }
                kernels::axpy_rows(&ws.u[..(i + 1) * d], i + 1, d, &ws.w[..=i], oi);
            }
        }
        if ws.apow[l] != 1.0 {
            for x in self.s.iter_mut() {
                *x *= ws.apow[l];
            }
        }
        for r in 0..d {
            for j in 0..l {
                ws.w[j] = b * ws.apow[l - 1 - j] * keys[j * d + r];
            }
            kernels::axpy_rows(&ws.u[..l * d], l, d, &ws.w[..l], &mut self.s[r * d..(r + 1) * d]);
        }
        self.t += l;
    }

    /// Cut a prompt slice into `chunk`-token blocks and run each through
    /// [`GdnState::chunkwise_block`]; blocks compose left-to-right through
    /// the live state.
    fn chunkwise_prefill(
        &mut self,
        queries: Option<&[f32]>,
        keys: &[f32],
        values: &[f32],
        mut out: Option<&mut [f32]>,
        chunk: usize,
    ) {
        let d = self.d;
        let len = keys.len() / d;
        let c = chunk.max(1);
        let mut ws = ChunkWs::default();
        let mut i = 0;
        while i < len {
            let l = c.min(len - i);
            let (lo, hi) = (i * d, (i + l) * d);
            self.chunkwise_block(
                queries.map(|q| &q[lo..hi]),
                &keys[lo..hi],
                &values[lo..hi],
                out.as_deref_mut().map(|o| &mut o[lo..hi]),
                &mut ws,
            );
            i += l;
        }
    }
}

impl SeqMixer for GdnState {
    fn kind_name(&self) -> &'static str {
        "gdn"
    }

    fn d_in(&self) -> usize {
        self.d
    }

    fn d_out(&self) -> usize {
        self.d
    }

    fn tokens(&self) -> usize {
        self.t
    }

    fn state_bytes(&self) -> usize {
        self.s.len() * 4
    }

    fn update_bytes_per_chunk(&self, l: usize) -> usize {
        l * self.d * self.d * 4
    }

    fn write(&mut self, k: &[f32], v: &[f32]) {
        let (a, b) = (self.alpha, self.beta);
        self.write_gated(k, v, a, b);
    }

    fn read(&self, q: &[f32], out: &mut [f32], _scratch: &mut Scratch) {
        // o = q S — the dispatched transpose-matvec (scalar tile is
        // bit-identical to the historical loop; AVX2 applies when built)
        let d = self.d;
        kernels::vecmat(&q[..d], &self.s, d, d, out);
    }

    fn set_prefill_mode(&mut self, mode: PrefillMode) {
        self.mode = mode;
    }

    /// Prompt ingestion. The delta-rule recurrence is dense and strictly
    /// sequential (S_t depends on S_{t-1} through the prediction term), so
    /// the default `Exact` mode keeps the serial token loop — bit-identical
    /// to decode, with the per-token `pred` scratch coming from the shared
    /// [`Scratch`] instead of a heap allocation per token. Opting into
    /// `Chunkwise` mode switches to the blocked scan form
    /// ([`GdnState::chunkwise_block`]): tiled `[L, L]` similarity sweeps +
    /// an `[L, d]` forward substitution instead of the §3.4 `[L, d, d]` ΔS
    /// tensor. That reassociates FP accumulation, so chunkwise outputs are
    /// tolerance-tested, never golden-pinned.
    fn process_prefill(
        &mut self,
        queries: &[f32],
        keys: &[f32],
        values: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        let d = self.d;
        let len = keys.len() / d;
        debug_assert_eq!(queries.len(), len * d);
        debug_assert_eq!(values.len(), len * d);
        debug_assert_eq!(out.len(), len * d);
        if let PrefillMode::Chunkwise { chunk } = self.mode {
            self.chunkwise_prefill(Some(queries), keys, values, Some(out), chunk);
            return;
        }
        if scratch.buf.len() < d {
            scratch.buf.resize(d, 0.0);
        }
        let (a, b) = (self.alpha, self.beta);
        for i in 0..len {
            {
                let pred = &mut scratch.buf[..d];
                let (k, v) = (&keys[i * d..(i + 1) * d], &values[i * d..(i + 1) * d]);
                self.write_gated_into(k, v, a, b, pred);
            }
            self.read(&queries[i * d..(i + 1) * d], &mut out[i * d..(i + 1) * d], scratch);
        }
    }

    /// State-only prompt advance (the owner half of fanned-out prefill):
    /// identical state evolution to [`GdnState::process_prefill`] in both
    /// modes, without computing any output row.
    fn prefill_writes(&mut self, keys: &[f32], values: &[f32], scratch: &mut Scratch) {
        let d = self.d;
        let len = keys.len() / d;
        debug_assert_eq!(values.len(), len * d);
        if let PrefillMode::Chunkwise { chunk } = self.mode {
            self.chunkwise_prefill(None, keys, values, None, chunk);
            return;
        }
        if scratch.buf.len() < d {
            scratch.buf.resize(d, 0.0);
        }
        let (a, b) = (self.alpha, self.beta);
        for i in 0..len {
            let pred = &mut scratch.buf[..d];
            let (k, v) = (&keys[i * d..(i + 1) * d], &values[i * d..(i + 1) * d]);
            self.write_gated_into(k, v, a, b, pred);
        }
    }

    fn snapshot(&self, w: &mut snapshot::Writer) {
        w.usize(self.d);
        w.usize(self.t);
        w.f32(self.alpha);
        w.f32(self.beta);
        w.f32s(&self.s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_rule_stores_association() {
        // after writing (k, v) with beta=1 into empty state, reading with
        // q=k (unit norm) returns v exactly
        let d = 8;
        let mut st = GdnState::new(d);
        let norm = (d as f32).sqrt().recip();
        let k: Vec<f32> = vec![norm; d];
        let v: Vec<f32> = (0..d).map(|i| i as f32).collect();
        st.write_gated(&k, &v, 1.0, 1.0);
        let mut out = vec![0.0; d];
        let mut scratch = Scratch::new();
        st.read(&k, &mut out, &mut scratch);
        for (o, &vi) in out.iter().zip(&v) {
            assert!((o - vi).abs() < 1e-4);
        }
    }

    #[test]
    fn rewrite_overwrites_not_accumulates() {
        // writing a new value under the same key replaces the old one —
        // the delta rule's advantage over plain linear attention. The
        // trait-level write uses the default gates alpha=1, beta=1.
        let d = 4;
        let mut st = GdnState::new(d);
        let k = vec![0.5; d];
        st.write(&k, &[1.0, 1.0, 1.0, 1.0]);
        st.write(&k, &[9.0, 9.0, 9.0, 9.0]);
        let mut out = vec![0.0; d];
        let mut scratch = Scratch::new();
        st.read(&k, &mut out, &mut scratch);
        for &o in &out {
            assert!((o - 9.0).abs() < 1e-3, "expected overwrite, got {o}");
        }
    }

    /// Tolerance band for the chunkwise scan form (documented FP
    /// reassociation — same idiom as the kernel `simd_tests`).
    const EPS_REL: f32 = 1e-3;

    fn close(got: f32, want: f32) -> bool {
        (got - want).abs() <= EPS_REL * (1.0 + want.abs())
    }

    fn stream(seed: u64, n: usize, d: usize, scale: f32) -> Vec<f32> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..n * d).map(|_| rng.normal() as f32 * scale).collect()
    }

    #[test]
    fn chunkwise_prefill_matches_serial_within_eps() {
        // the tolerance family: odd lengths, exact block multiples, and
        // lengths that leave a short tail block
        let d = 16;
        let kscale = 1.0 / (d as f32).sqrt(); // keep |k| ~ 1 so the delta rule is stable
        for &(total, chunk) in
            &[(1usize, 4usize), (3, 4), (8, 4), (9, 4), (37, 8), (64, 16), (65, 16)]
        {
            let q = stream(100 + total as u64, total, d, kscale);
            let k = stream(200 + total as u64, total, d, kscale);
            let v = stream(300 + total as u64, total, d, 1.0);
            let mut scratch = Scratch::new();

            let mut serial = GdnState::new(d);
            serial.alpha = 0.95;
            serial.beta = 0.7;
            let mut par = serial.clone();
            par.set_prefill_mode(PrefillMode::Chunkwise { chunk });

            let mut want = vec![0.0f32; total * d];
            serial.process_prefill(&q, &k, &v, &mut want, &mut scratch);
            let mut got = vec![0.0f32; total * d];
            par.process_prefill(&q, &k, &v, &mut got, &mut scratch);
            for i in 0..total * d {
                assert!(
                    close(got[i], want[i]),
                    "total={total} chunk={chunk} flat={i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
            for i in 0..d * d {
                assert!(close(par.s[i], serial.s[i]), "state total={total} chunk={chunk} i={i}");
            }
            assert_eq!(par.t, serial.t);

            // writes-only advance leaves the chunkwise state bit-identical
            // to the full chunkwise prefill (the fan-out owner contract)
            let mut wr = GdnState::new(d);
            wr.alpha = 0.95;
            wr.beta = 0.7;
            wr.set_prefill_mode(PrefillMode::Chunkwise { chunk });
            wr.prefill_writes(&k, &v, &mut scratch);
            for i in 0..d * d {
                assert_eq!(
                    wr.s[i].to_bits(),
                    par.s[i].to_bits(),
                    "prefill_writes state diverged (total={total} chunk={chunk} i={i})"
                );
            }
            assert_eq!(wr.t, par.t);
        }
    }

    #[test]
    fn chunkwise_mid_block_cuts_stay_within_eps() {
        // a prompt delivered in two prefill calls cut mid-block restarts
        // the blocking at the cut — a different (still valid) chunkwise
        // evaluation order that must stay within the same band of serial
        let d = 8;
        let (total, chunk, cut) = (29usize, 8usize, 13usize);
        let kscale = 1.0 / (d as f32).sqrt();
        let q = stream(1, total, d, kscale);
        let k = stream(2, total, d, kscale);
        let v = stream(3, total, d, 1.0);
        let mut scratch = Scratch::new();

        let mut serial = GdnState::new(d);
        serial.alpha = 0.9;
        serial.beta = 0.6;
        let mut par = serial.clone();
        par.set_prefill_mode(PrefillMode::Chunkwise { chunk });

        let mut want = vec![0.0f32; total * d];
        serial.process_prefill(&q, &k, &v, &mut want, &mut scratch);
        let mut got = vec![0.0f32; total * d];
        let at = cut * d;
        par.process_prefill(&q[..at], &k[..at], &v[..at], &mut got[..at], &mut scratch);
        par.process_prefill(&q[at..], &k[at..], &v[at..], &mut got[at..], &mut scratch);
        for i in 0..total * d {
            assert!(close(got[i], want[i]), "flat={i}: {} vs {}", got[i], want[i]);
        }
        for i in 0..d * d {
            assert!(close(par.s[i], serial.s[i]), "state i={i}");
        }
    }

    #[test]
    fn exact_mode_prefill_writes_matches_process_prefill_state() {
        let d = 8;
        let total = 21;
        let q = stream(7, total, d, 0.3);
        let k = stream(8, total, d, 0.3);
        let v = stream(9, total, d, 1.0);
        let mut scratch = Scratch::new();
        let mut full = GdnState::new(d);
        full.alpha = 0.9;
        full.beta = 0.5;
        let mut wr = full.clone();
        let mut out = vec![0.0f32; total * d];
        full.process_prefill(&q, &k, &v, &mut out, &mut scratch);
        wr.prefill_writes(&k, &v, &mut scratch);
        for i in 0..d * d {
            assert_eq!(wr.s[i].to_bits(), full.s[i].to_bits(), "i={i}");
        }
        assert_eq!(wr.t, full.t);
    }

    #[test]
    fn alpha_decays_memory() {
        let d = 4;
        let mut st = GdnState::new(d);
        let k = vec![0.5; d];
        st.write_gated(&k, &[4.0; 4], 1.0, 1.0);
        // decay-only steps (beta=0 write with zero k/v contribution)
        for _ in 0..10 {
            st.write_gated(&[0.0; 4], &[0.0; 4], 0.5, 0.0);
        }
        let mut out = vec![0.0; d];
        let mut scratch = Scratch::new();
        st.read(&k, &mut out, &mut scratch);
        assert!(out[0].abs() < 4.0 * 0.5f32.powi(9));
    }
}
