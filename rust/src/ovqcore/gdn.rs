//! Gated-delta-net state machine (Yang et al. 2024a), token recurrence
//!    S_t = a_t S_{t-1} + b_t k_t^T (v_t - k_t S_{t-1}),  o_t = q_t S_t.
//! Used for serving-side decode and memory accounting, through
//! [`SeqMixer`]. The trait's ungated `write` applies the configured
//! default gates (`alpha`, `beta`); [`GdnState::write_gated`] exposes the
//! full per-token recurrence.

use anyhow::Result;

use super::mixer::{Scratch, SeqMixer};
use super::snapshot;

#[derive(Debug, Clone)]
pub struct GdnState {
    pub d: usize,
    /// [d, d] row-major fast-weight matrix
    pub s: Vec<f32>,
    pub t: usize,
    /// default decay gate used by the trait-level `write`
    pub alpha: f32,
    /// default write-strength gate used by the trait-level `write`
    pub beta: f32,
}

impl GdnState {
    pub fn new(d: usize) -> GdnState {
        GdnState { d, s: vec![0.0; d * d], t: 0, alpha: 1.0, beta: 1.0 }
    }

    /// Rebuild from a [`snapshot::save`] payload.
    pub fn from_snapshot(r: &mut snapshot::Reader<'_>) -> Result<GdnState> {
        let d = r.usize()?;
        // bound d BEFORE GdnState::new allocates the [d, d] state — a
        // corrupt blob must err cleanly, never overflow d * d or demand
        // a wild allocation (snapshot's no-panics-on-untrusted-bytes)
        anyhow::ensure!(
            d > 0 && d <= (1 << 12),
            "gdn snapshot claims an implausible width (d={d})"
        );
        let mut st = GdnState::new(d);
        st.t = r.usize()?;
        st.alpha = r.f32()?;
        st.beta = r.f32()?;
        st.s = r.f32s()?;
        anyhow::ensure!(st.s.len() == st.d * st.d, "gdn snapshot has inconsistent shapes");
        Ok(st)
    }

    pub fn write_gated(&mut self, k: &[f32], v: &[f32], alpha: f32, beta: f32) {
        let mut pred = vec![0.0f32; self.d];
        self.write_gated_into(k, v, alpha, beta, &mut pred);
    }

    /// [`GdnState::write_gated`] with a caller-owned `pred` buffer (length
    /// `d`, any contents — it is overwritten), so the prefill path absorbs
    /// a whole prompt without one heap allocation per token.
    pub fn write_gated_into(
        &mut self,
        k: &[f32],
        v: &[f32],
        alpha: f32,
        beta: f32,
        pred: &mut [f32],
    ) {
        let d = self.d;
        // pred = k S  (length d)
        let pred = &mut pred[..d];
        pred.iter_mut().for_each(|p| *p = 0.0);
        for i in 0..d {
            let ki = k[i];
            if ki != 0.0 {
                let row = &self.s[i * d..(i + 1) * d];
                for (p, &sj) in pred.iter_mut().zip(row) {
                    *p += ki * sj;
                }
            }
        }
        for i in 0..d {
            let row = &mut self.s[i * d..(i + 1) * d];
            let ki = beta * k[i];
            for j in 0..d {
                row[j] = alpha * row[j] + ki * (v[j] - pred[j]);
            }
        }
        self.t += 1;
    }
}

impl SeqMixer for GdnState {
    fn kind_name(&self) -> &'static str {
        "gdn"
    }

    fn d_in(&self) -> usize {
        self.d
    }

    fn d_out(&self) -> usize {
        self.d
    }

    fn tokens(&self) -> usize {
        self.t
    }

    fn state_bytes(&self) -> usize {
        self.s.len() * 4
    }

    fn update_bytes_per_chunk(&self, l: usize) -> usize {
        l * self.d * self.d * 4
    }

    fn write(&mut self, k: &[f32], v: &[f32]) {
        let (a, b) = (self.alpha, self.beta);
        self.write_gated(k, v, a, b);
    }

    fn read(&self, q: &[f32], out: &mut [f32], _scratch: &mut Scratch) {
        let d = self.d;
        out.iter_mut().for_each(|o| *o = 0.0);
        for i in 0..d {
            let qi = q[i];
            if qi != 0.0 {
                let row = &self.s[i * d..(i + 1) * d];
                for (o, &sj) in out.iter_mut().zip(row) {
                    *o += qi * sj;
                }
            }
        }
    }

    /// Prompt ingestion. The delta-rule recurrence is dense and strictly
    /// sequential (S_t depends on S_{t-1} through the prediction term), so
    /// a chunk-parallel form would materialize the [L, d, d] ΔS tensor —
    /// the §3.4 cost this repo exists to avoid — AND reassociate the FP
    /// accumulation, breaking bit-identity with serial decode. What CAN
    /// batch safely: the per-token `pred` scratch comes from the shared
    /// [`Scratch`] instead of a fresh heap allocation per token.
    fn process_prefill(
        &mut self,
        queries: &[f32],
        keys: &[f32],
        values: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        let d = self.d;
        let len = keys.len() / d;
        debug_assert_eq!(queries.len(), len * d);
        debug_assert_eq!(values.len(), len * d);
        debug_assert_eq!(out.len(), len * d);
        if scratch.buf.len() < d {
            scratch.buf.resize(d, 0.0);
        }
        let (a, b) = (self.alpha, self.beta);
        for i in 0..len {
            {
                let pred = &mut scratch.buf[..d];
                let (k, v) = (&keys[i * d..(i + 1) * d], &values[i * d..(i + 1) * d]);
                self.write_gated_into(k, v, a, b, pred);
            }
            self.read(&queries[i * d..(i + 1) * d], &mut out[i * d..(i + 1) * d], scratch);
        }
    }

    fn snapshot(&self, w: &mut snapshot::Writer) {
        w.usize(self.d);
        w.usize(self.t);
        w.f32(self.alpha);
        w.f32(self.beta);
        w.f32s(&self.s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_rule_stores_association() {
        // after writing (k, v) with beta=1 into empty state, reading with
        // q=k (unit norm) returns v exactly
        let d = 8;
        let mut st = GdnState::new(d);
        let norm = (d as f32).sqrt().recip();
        let k: Vec<f32> = vec![norm; d];
        let v: Vec<f32> = (0..d).map(|i| i as f32).collect();
        st.write_gated(&k, &v, 1.0, 1.0);
        let mut out = vec![0.0; d];
        let mut scratch = Scratch::new();
        st.read(&k, &mut out, &mut scratch);
        for (o, &vi) in out.iter().zip(&v) {
            assert!((o - vi).abs() < 1e-4);
        }
    }

    #[test]
    fn rewrite_overwrites_not_accumulates() {
        // writing a new value under the same key replaces the old one —
        // the delta rule's advantage over plain linear attention. The
        // trait-level write uses the default gates alpha=1, beta=1.
        let d = 4;
        let mut st = GdnState::new(d);
        let k = vec![0.5; d];
        st.write(&k, &[1.0, 1.0, 1.0, 1.0]);
        st.write(&k, &[9.0, 9.0, 9.0, 9.0]);
        let mut out = vec![0.0; d];
        let mut scratch = Scratch::new();
        st.read(&k, &mut out, &mut scratch);
        for &o in &out {
            assert!((o - 9.0).abs() < 1e-3, "expected overwrite, got {o}");
        }
    }

    #[test]
    fn alpha_decays_memory() {
        let d = 4;
        let mut st = GdnState::new(d);
        let k = vec![0.5; d];
        st.write_gated(&k, &[4.0; 4], 1.0, 1.0);
        // decay-only steps (beta=0 write with zero k/v contribution)
        for _ in 0..10 {
            st.write_gated(&[0.0; 4], &[0.0; 4], 0.5, 0.0);
        }
        let mut out = vec![0.0; d];
        let mut scratch = Scratch::new();
        st.read(&k, &mut out, &mut scratch);
        assert!(out[0].abs() < 4.0 * 0.5f32.powi(9));
    }
}
