//! Blocked row-major microkernels shared by every [`super::mixer::SeqMixer`]
//! implementation: dot products with multi-accumulator ILP, dictionary ×
//! vector similarity (the eq. 6/15 logit matvec), weighted row reduction
//! (the softmax value gather), and the tiled nearest-centroid search that
//! replaces the seed's one-element-at-a-time scalar loops.
//!
//! Layout convention: all matrices are row-major `[rows, d]` f32 slices,
//! matching the dictionary storage in `ovq`/`vq` and the KV storage in
//! `kvcache`. Tiles are sized so a slot block (`SLOT_BLOCK` rows at
//! d <= 128) stays resident in L1 while it is swept by every query of a
//! chunk.

/// Rows per dictionary tile in [`nearest_rows`]; 64 rows x 128 dims x 4 B
/// = 32 KiB, the common L1 size.
pub const SLOT_BLOCK: usize = 64;

/// Dot product with four independent accumulators. The seed's
/// `iter().zip().map().sum()` chains the f32 adds serially (FP addition is
/// non-associative, so LLVM cannot reorder them); splitting the
/// accumulation into four lanes makes the reduction associative-by-
/// construction and lets the backend vectorize it.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (x, y) in (&mut ca).zip(&mut cb) {
        acc[0] += x[0] * y[0];
        acc[1] += x[1] * y[1];
        acc[2] += x[2] * y[2];
        acc[3] += x[3] * y[3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s += x * y;
    }
    s
}

/// `out[r] = dot(m[r], x)` for `r in 0..rows` — the dictionary-logit
/// matvec, blocked four rows at a time so each load of `x` feeds four
/// accumulating lanes.
pub fn matvec(m: &[f32], rows: usize, d: usize, x: &[f32], out: &mut [f32]) {
    debug_assert!(m.len() >= rows * d);
    debug_assert!(out.len() >= rows);
    debug_assert_eq!(x.len(), d);
    let x = &x[..d];
    let mut r = 0;
    while r + 4 <= rows {
        let m0 = &m[r * d..r * d + d];
        let m1 = &m[(r + 1) * d..(r + 1) * d + d];
        let m2 = &m[(r + 2) * d..(r + 2) * d + d];
        let m3 = &m[(r + 3) * d..(r + 3) * d + d];
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for j in 0..d {
            let xj = x[j];
            a0 += m0[j] * xj;
            a1 += m1[j] * xj;
            a2 += m2[j] * xj;
            a3 += m3[j] * xj;
        }
        out[r] = a0;
        out[r + 1] = a1;
        out[r + 2] = a2;
        out[r + 3] = a3;
        r += 4;
    }
    while r < rows {
        out[r] = dot(&m[r * d..r * d + d], x);
        r += 1;
    }
}

/// `acc[..d] += sum_r w[r] * m[r]`, skipping rows with zero weight — the
/// softmax value gather. Rows are walked in pairs so the two row streams
/// overlap loads.
pub fn axpy_rows(m: &[f32], rows: usize, d: usize, w: &[f32], acc: &mut [f32]) {
    debug_assert!(m.len() >= rows * d);
    debug_assert!(w.len() >= rows);
    debug_assert!(acc.len() >= d);
    let acc = &mut acc[..d];
    let mut r = 0;
    while r + 2 <= rows {
        let (w0, w1) = (w[r], w[r + 1]);
        if w0 != 0.0 || w1 != 0.0 {
            let m0 = &m[r * d..r * d + d];
            let m1 = &m[(r + 1) * d..(r + 1) * d + d];
            for j in 0..d {
                acc[j] += w0 * m0[j] + w1 * m1[j];
            }
        }
        r += 2;
    }
    if r < rows && w[r] != 0.0 {
        let m0 = &m[r * d..r * d + d];
        for j in 0..d {
            acc[j] += w[r] * m0[j];
        }
    }
}

/// Batched (prefill) form of [`matvec`]: `out[i * rows + r] = dot(m[r],
/// xs[i])` for every query `i in 0..len`. The matrix is swept in
/// [`SLOT_BLOCK`]-row tiles reused across every query, so a whole prompt
/// chunk streams the dictionary once per tile instead of once per token.
///
/// Bit-identity contract: for every (query, row) pair the accumulation
/// order is exactly [`matvec`]'s — tiles are [`SLOT_BLOCK`]-aligned
/// (a multiple of 4), so the 4-row groups and the `dot`-based tail fall
/// on the same row boundaries as a per-query `matvec` call over the full
/// matrix. The prefill golden tests (rust/tests/golden.rs) rely on this
/// to keep blocked prefill bit-identical to serial decode.
pub fn matmul_rows(m: &[f32], rows: usize, d: usize, xs: &[f32], len: usize, out: &mut [f32]) {
    debug_assert!(m.len() >= rows * d);
    debug_assert!(xs.len() >= len * d);
    debug_assert!(out.len() >= len * rows);
    let mut s0 = 0;
    while s0 < rows {
        let sn = (s0 + SLOT_BLOCK).min(rows);
        let block = &m[s0 * d..sn * d];
        let brows = sn - s0;
        for i in 0..len {
            let x = &xs[i * d..(i + 1) * d];
            let orow = &mut out[i * rows + s0..i * rows + sn];
            let mut r = 0;
            while r + 4 <= brows {
                let m0 = &block[r * d..r * d + d];
                let m1 = &block[(r + 1) * d..(r + 1) * d + d];
                let m2 = &block[(r + 2) * d..(r + 2) * d + d];
                let m3 = &block[(r + 3) * d..(r + 3) * d + d];
                let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                for j in 0..d {
                    let xj = x[j];
                    a0 += m0[j] * xj;
                    a1 += m1[j] * xj;
                    a2 += m2[j] * xj;
                    a3 += m3[j] * xj;
                }
                orow[r] = a0;
                orow[r + 1] = a1;
                orow[r + 2] = a2;
                orow[r + 3] = a3;
                r += 4;
            }
            while r < brows {
                orow[r] = dot(&block[r * d..r * d + d], x);
                r += 1;
            }
        }
        s0 = sn;
    }
}

/// Tiled nearest-row search: for each of `len` keys, the index and value
/// of the maximum inner product over `n` dictionary rows. The dictionary
/// is swept in [`SLOT_BLOCK`]-row tiles and each tile is reused by every
/// key before moving on, so the O(len * n * d) similarity matmul streams
/// the dictionary exactly once per [`SLOT_BLOCK`] keys instead of once
/// per key. `best_idx`/`best_sim` must hold `len` entries and arrive
/// initialized (NEG_INFINITY sims to search from scratch) — callers can
/// seed them to fold an external candidate in.
pub fn nearest_rows(
    dict: &[f32],
    n: usize,
    d: usize,
    keys: &[f32],
    len: usize,
    best_idx: &mut [usize],
    best_sim: &mut [f32],
) {
    debug_assert!(dict.len() >= n * d);
    debug_assert!(keys.len() >= len * d);
    debug_assert!(best_idx.len() >= len && best_sim.len() >= len);
    let mut s0 = 0;
    while s0 < n {
        let sn = (s0 + SLOT_BLOCK).min(n);
        let block = &dict[s0 * d..sn * d];
        let rows = sn - s0;
        for i in 0..len {
            let k = &keys[i * d..(i + 1) * d];
            let (mut bi, mut bv) = (best_idx[i], best_sim[i]);
            let mut r = 0;
            // four-row blocks: one pass of k feeds four similarity lanes
            while r + 4 <= rows {
                let m0 = &block[r * d..r * d + d];
                let m1 = &block[(r + 1) * d..(r + 1) * d + d];
                let m2 = &block[(r + 2) * d..(r + 2) * d + d];
                let m3 = &block[(r + 3) * d..(r + 3) * d + d];
                let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                for j in 0..d {
                    let kj = k[j];
                    a0 += m0[j] * kj;
                    a1 += m1[j] * kj;
                    a2 += m2[j] * kj;
                    a3 += m3[j] * kj;
                }
                for (off, a) in [a0, a1, a2, a3].into_iter().enumerate() {
                    if a > bv {
                        bv = a;
                        bi = s0 + r + off;
                    }
                }
                r += 4;
            }
            while r < rows {
                let a = dot(&block[r * d..r * d + d], k);
                if a > bv {
                    bv = a;
                    bi = s0 + r;
                }
                r += 1;
            }
            best_idx[i] = bi;
            best_sim[i] = bv;
        }
        s0 = sn;
    }
}

/// Index of the maximum element, first occurrence winning ties — the
/// greedy-sampling hot path (one pass, no allocation). Returns 0 for an
/// empty slice and for all-NEG_INFINITY input (callers treat token 0 as
/// the degenerate fallback, matching [`crate::util::rng::Rng::categorical`]
/// on zero mass).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

/// Value of the k-th largest element (k >= 1) via partial selection: a
/// sorted descending keep-buffer of at most k entries, each candidate
/// admitted by binary search — O(n log k) comparisons and O(k) state, so
/// the top-k sampling mask never sorts the whole vocab. `keep` is a
/// caller-owned scratch reused across calls. Returns NEG_INFINITY when
/// k == 0 or k >= len (nothing would be masked); ties at the threshold
/// are resolved by the caller keeping everything >= the returned value.
pub fn top_k_threshold(xs: &[f32], k: usize, keep: &mut Vec<f32>) -> f32 {
    if k == 0 || k >= xs.len() {
        return f32::NEG_INFINITY;
    }
    keep.clear();
    for &x in xs {
        if keep.len() < k {
            let pos = keep.partition_point(|&y| y > x);
            keep.insert(pos, x);
        } else if x > keep[k - 1] {
            let pos = keep.partition_point(|&y| y > x);
            keep.insert(pos, x);
            keep.pop();
        }
    }
    keep[k - 1]
}

/// Streaming-softmax combine over a logit slice and its value rows:
/// `out += sum_s exp(logits[s] - m) * values[s]`, returning the partial
/// normalizer. `NEG_INFINITY` logits are skipped. Weights are materialized
/// into `w_scratch` (len >= rows) so the value gather runs through the
/// blocked [`axpy_rows`].
pub fn softmax_accumulate(
    logits: &[f32],
    values: &[f32],
    rows: usize,
    d: usize,
    m: f32,
    w_scratch: &mut [f32],
    out: &mut [f32],
) -> f32 {
    debug_assert!(logits.len() >= rows);
    debug_assert!(w_scratch.len() >= rows);
    let mut z = 0.0f32;
    for s in 0..rows {
        let w = if logits[s] > f32::NEG_INFINITY { (logits[s] - m).exp() } else { 0.0 };
        w_scratch[s] = w;
        z += w;
    }
    axpy_rows(values, rows, d, w_scratch, out);
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn naive_dot(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(1);
        for n in [0usize, 1, 3, 4, 7, 64, 129] {
            let a = randv(&mut rng, n);
            let b = randv(&mut rng, n);
            let got = dot(&a, &b);
            let want = naive_dot(&a, &b);
            assert!((got - want).abs() < 1e-3 * (1.0 + want.abs()), "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn matvec_matches_naive() {
        let mut rng = Rng::new(2);
        for (rows, d) in [(1usize, 5usize), (4, 8), (7, 16), (130, 64)] {
            let m = randv(&mut rng, rows * d);
            let x = randv(&mut rng, d);
            let mut out = vec![0.0f32; rows];
            matvec(&m, rows, d, &x, &mut out);
            for r in 0..rows {
                let want = naive_dot(&m[r * d..(r + 1) * d], &x);
                assert!((out[r] - want).abs() < 1e-3 * (1.0 + want.abs()));
            }
        }
    }

    #[test]
    fn axpy_rows_matches_naive() {
        let mut rng = Rng::new(3);
        for (rows, d) in [(1usize, 3usize), (2, 8), (9, 16)] {
            let m = randv(&mut rng, rows * d);
            let w = randv(&mut rng, rows);
            let mut acc = vec![0.5f32; d];
            let mut want = acc.clone();
            axpy_rows(&m, rows, d, &w, &mut acc);
            for r in 0..rows {
                for j in 0..d {
                    want[j] += w[r] * m[r * d + j];
                }
            }
            for j in 0..d {
                assert!((acc[j] - want[j]).abs() < 1e-3 * (1.0 + want[j].abs()));
            }
        }
    }

    #[test]
    fn matmul_rows_is_bit_identical_to_per_query_matvec() {
        // the prefill contract: the batched form must not just be close,
        // it must reproduce matvec's bits for every (query, row) pair —
        // exercised across tile boundaries and 4-row tail remainders
        let mut rng = Rng::new(7);
        for (rows, d, len) in [(1usize, 4usize, 1usize), (7, 8, 3), (64, 16, 5), (131, 32, 9)] {
            let m = randv(&mut rng, rows * d);
            let xs = randv(&mut rng, len * d);
            let mut got = vec![0.0f32; len * rows];
            matmul_rows(&m, rows, d, &xs, len, &mut got);
            let mut want = vec![0.0f32; rows];
            for i in 0..len {
                matvec(&m, rows, d, &xs[i * d..(i + 1) * d], &mut want);
                for r in 0..rows {
                    assert_eq!(
                        got[i * rows + r].to_bits(),
                        want[r].to_bits(),
                        "rows={rows} d={d} query {i} row {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn nearest_matches_exhaustive() {
        let mut rng = Rng::new(4);
        for (n, d, len) in [(1usize, 4usize, 3usize), (63, 8, 5), (64, 16, 9), (257, 32, 17)] {
            let dict = randv(&mut rng, n * d);
            let keys = randv(&mut rng, len * d);
            let mut idx = vec![0usize; len];
            let mut sim = vec![f32::NEG_INFINITY; len];
            nearest_rows(&dict, n, d, &keys, len, &mut idx, &mut sim);
            for i in 0..len {
                let k = &keys[i * d..(i + 1) * d];
                let mut bv = f32::NEG_INFINITY;
                for s in 0..n {
                    bv = bv.max(naive_dot(&dict[s * d..(s + 1) * d], k));
                }
                // the chosen row must achieve the max similarity (argmax
                // compared by value, not index — blocked accumulation may
                // legitimately break FP near-ties differently)
                assert!(idx[i] < n);
                let chosen = naive_dot(&dict[idx[i] * d..(idx[i] + 1) * d], k);
                let tol = 1e-3 * (1.0 + bv.abs());
                assert!(chosen >= bv - tol, "key {i} (n={n} d={d}): {chosen} vs max {bv}");
                assert!((sim[i] - chosen).abs() < tol);
            }
        }
    }

    #[test]
    fn nearest_respects_seeded_candidate() {
        // a pre-seeded best_sim above every dictionary similarity survives
        let dict = vec![0.0f32; 8 * 4];
        let keys = vec![1.0f32; 4];
        let mut idx = vec![99usize];
        let mut sim = vec![1e9f32];
        nearest_rows(&dict, 8, 4, &keys, 1, &mut idx, &mut sim);
        assert_eq!(idx[0], 99);
        assert_eq!(sim[0], 1e9);
    }

    #[test]
    fn argmax_matches_naive_and_breaks_ties_low() {
        let mut rng = Rng::new(5);
        for n in [1usize, 2, 7, 64, 257] {
            let xs = randv(&mut rng, n);
            let got = argmax(&xs);
            let naive = xs
                .iter()
                .enumerate()
                .fold((0usize, f32::NEG_INFINITY), |(bi, bv), (i, &x)| {
                    if x > bv {
                        (i, x)
                    } else {
                        (bi, bv)
                    }
                })
                .0;
            assert_eq!(got, naive, "n={n}");
        }
        assert_eq!(argmax(&[]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY; 4]), 0);
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1, "first max wins ties");
    }

    #[test]
    fn top_k_threshold_matches_full_sort() {
        let mut rng = Rng::new(6);
        let mut keep = Vec::new();
        for n in [1usize, 5, 64, 300] {
            let xs = randv(&mut rng, n);
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            for k in [0usize, 1, 2, n / 2, n.saturating_sub(1), n, n + 5] {
                let got = top_k_threshold(&xs, k, &mut keep);
                if k == 0 || k >= n {
                    assert_eq!(got, f32::NEG_INFINITY, "n={n} k={k}: nothing to mask");
                } else {
                    assert_eq!(got.to_bits(), sorted[k - 1].to_bits(), "n={n} k={k}");
                    // masking below the threshold keeps at least k entries
                    let kept = xs.iter().filter(|&&x| x >= got).count();
                    assert!(kept >= k, "n={n} k={k}: kept {kept}");
                }
            }
        }
        // duplicates land on the duplicated value
        let xs = [2.0f32, 5.0, 5.0, 1.0, 5.0];
        assert_eq!(top_k_threshold(&xs, 2, &mut keep), 5.0);
        assert_eq!(top_k_threshold(&xs, 4, &mut keep), 2.0);
    }

    #[test]
    fn softmax_accumulate_normalizes() {
        let logits = [0.0f32, 0.0, f32::NEG_INFINITY];
        let values = [1.0f32, 2.0, 3.0, 4.0, 99.0, 99.0]; // d=2
        let mut w = [0.0f32; 3];
        let mut out = [0.0f32; 2];
        let z = softmax_accumulate(&logits, &values, 3, 2, 0.0, &mut w, &mut out);
        assert!((z - 2.0).abs() < 1e-6);
        // masked row contributes nothing; (1+3)/2, (2+4)/2 after /z
        assert!((out[0] / z - 2.0).abs() < 1e-6);
        assert!((out[1] / z - 3.0).abs() < 1e-6);
    }
}
