//! Blocked row-major microkernels shared by every [`super::mixer::SeqMixer`]
//! implementation: dot products with multi-accumulator ILP, dictionary ×
//! vector similarity (the eq. 6/15 logit matvec), weighted row reduction
//! (the softmax value gather), and the tiled nearest-centroid search that
//! replaces the seed's one-element-at-a-time scalar loops.
//!
//! Layout convention: all matrices are row-major `[rows, d]` f32 slices,
//! matching the dictionary storage in `ovq`/`vq` and the KV storage in
//! `kvcache`. Tiles are sized so a slot block (`SLOT_BLOCK` rows at
//! d <= 128) stays resident in L1 while it is swept by every query of a
//! chunk.
//!
//! Backends: the public entry points (`dot`, `matvec`, `matmul_rows`,
//! `axpy_rows`, `nearest_rows`, `dot_i8`) dispatch at runtime to an
//! AVX2/FMA implementation when the crate is built with the `simd` cargo
//! feature on x86_64 AND the CPU reports both features (cached
//! `is_x86_feature_detected!` probe). The [`scalar`] module is always
//! compiled and is both the fallback and the golden reference: the
//! default build's bit-exact golden/snapshot tests pin the scalar path,
//! while the SIMD path (FMA reassociates, so bits differ) is covered by
//! the tolerance-mode test family at the bottom of this file. Which path
//! is live is reported by [`backend`] and surfaced in serve/bench
//! telemetry.
//!
//! Within one process the backend never changes (the CPUID probe is
//! cached), so the [`matvec`] ↔ [`matmul_rows`] bit-identity contract the
//! prefill goldens rely on holds per-backend: both scalar tiles share
//! their 4-row accumulation groups, and both AVX2 paths share one
//! `dot_avx2` core per (row, query) pair.

/// Rows per dictionary tile in [`nearest_rows`]; 64 rows x 128 dims x 4 B
/// = 32 KiB, the common L1 size.
pub const SLOT_BLOCK: usize = 64;

/// Which kernel backend serves the dispatched entry points: `"avx2"` when
/// the `simd` feature is compiled in and the CPU reports AVX2+FMA,
/// `"scalar"` otherwise. Surfaced in serve and bench telemetry so a run's
/// numbers are attributable to the path that produced them.
pub fn backend() -> &'static str {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd::avx2_available() {
            return "avx2";
        }
    }
    "scalar"
}

/// Dot product. Dispatches to the AVX2 backend when live; the scalar tile
/// splits the accumulation into four independent lanes (see
/// [`scalar::dot`]).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd::avx2_available() {
            return simd::dot(a, b);
        }
    }
    scalar::dot(a, b)
}

/// Fused dequant-dot over one i8 row with a per-row scale:
/// `scale * sum_j row[j] * x[j]`, accumulated in f32. The i8 elements are
/// widened lane-by-lane inside the loop — no dequantized row is ever
/// materialized. This is the hot read path for `--quant i8` dictionaries
/// ([`super::quant::QuantTensor`]).
#[inline]
pub fn dot_i8(row: &[i8], scale: f32, x: &[f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd::avx2_available() {
            return simd::dot_i8(row, scale, x);
        }
    }
    scalar::dot_i8(row, scale, x)
}

/// `out[r] = dot(m[r], x)` for `r in 0..rows` — the dictionary-logit
/// matvec. Dispatches per-backend; see [`scalar::matvec`] for the
/// reference tile.
pub fn matvec(m: &[f32], rows: usize, d: usize, x: &[f32], out: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd::avx2_available() {
            simd::matvec(m, rows, d, x, out);
            return;
        }
    }
    scalar::matvec(m, rows, d, x, out)
}

/// `acc[..d] += sum_r w[r] * m[r]`, skipping rows with zero weight — the
/// softmax value gather. Dispatches per-backend; see [`scalar::axpy_rows`].
pub fn axpy_rows(m: &[f32], rows: usize, d: usize, w: &[f32], acc: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd::avx2_available() {
            simd::axpy_rows(m, rows, d, w, acc);
            return;
        }
    }
    scalar::axpy_rows(m, rows, d, w, acc)
}

/// Transposed matvec: `out[j] = sum_i x[i] * m[i * d + j]` — `out` is
/// OVERWRITTEN, and rows whose weight `x[i]` is exactly zero are skipped.
/// This is the `o = q S` read shape of the dense-state mixers (GDN fast
/// weights, linear-attention `S`), which walks the state row-major with a
/// per-row scalar weight — the opposite orientation from [`matvec`], so
/// it gets its own kernel. The scalar tile reproduces the historical
/// hand-rolled mixer read loops bit for bit (same row order, same
/// accumulation order, same zero skip); the AVX2 path broadcasts the row
/// weight and FMAs across columns in the same row order, so it lands
/// within the documented simd tolerance band.
pub fn vecmat(x: &[f32], m: &[f32], rows: usize, d: usize, out: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd::avx2_available() {
            simd::vecmat(x, m, rows, d, out);
            return;
        }
    }
    scalar::vecmat(x, m, rows, d, out)
}

/// Batched (prefill) form of [`matvec`]: `out[i * rows + r] = dot(m[r],
/// xs[i])` for every query `i in 0..len`. The matrix is swept in
/// [`SLOT_BLOCK`]-row tiles reused across every query, so a whole prompt
/// chunk streams the dictionary once per tile instead of once per token.
///
/// Bit-identity contract: for every (query, row) pair the accumulation
/// order is exactly [`matvec`]'s *on the same backend* — the scalar tiles
/// share their 4-row groups and `dot`-based tail (tiles are
/// [`SLOT_BLOCK`]-aligned, a multiple of 4), and the AVX2 paths compute
/// every (row, query) pair through one shared `dot_avx2` core. The
/// prefill golden tests (rust/tests/golden.rs) rely on this to keep
/// blocked prefill bit-identical to serial decode.
pub fn matmul_rows(m: &[f32], rows: usize, d: usize, xs: &[f32], len: usize, out: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd::avx2_available() {
            simd::matmul_rows(m, rows, d, xs, len, out);
            return;
        }
    }
    scalar::matmul_rows(m, rows, d, xs, len, out)
}

/// Tiled nearest-row search: for each of `len` keys, the index and value
/// of the maximum inner product over `n` dictionary rows. The dictionary
/// is swept in [`SLOT_BLOCK`]-row tiles and each tile is reused by every
/// key before moving on, so the O(len * n * d) similarity matmul streams
/// the dictionary exactly once per [`SLOT_BLOCK`] keys instead of once
/// per key. `best_idx`/`best_sim` must hold `len` entries and arrive
/// initialized (NEG_INFINITY sims to search from scratch) — callers can
/// seed them to fold an external candidate in.
pub fn nearest_rows(
    dict: &[f32],
    n: usize,
    d: usize,
    keys: &[f32],
    len: usize,
    best_idx: &mut [usize],
    best_sim: &mut [f32],
) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd::avx2_available() {
            simd::nearest_rows(dict, n, d, keys, len, best_idx, best_sim);
            return;
        }
    }
    scalar::nearest_rows(dict, n, d, keys, len, best_idx, best_sim)
}

/// The always-compiled scalar reference tiles. These are the exact
/// kernels the repo's bit-exact goldens were recorded against; the
/// dispatched entry points above fall back here whenever the AVX2
/// backend is compiled out or the CPU lacks it, and the bench harness
/// calls them directly to measure the scalar-vs-SIMD spread.
pub mod scalar {
    use super::SLOT_BLOCK;

    /// Dot product with four independent accumulators. The seed's
    /// `iter().zip().map().sum()` chains the f32 adds serially (FP
    /// addition is non-associative, so LLVM cannot reorder them);
    /// splitting the accumulation into four lanes makes the reduction
    /// associative-by-construction and lets the backend vectorize it.
    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0.0f32; 4];
        let mut ca = a.chunks_exact(4);
        let mut cb = b.chunks_exact(4);
        for (x, y) in (&mut ca).zip(&mut cb) {
            acc[0] += x[0] * y[0];
            acc[1] += x[1] * y[1];
            acc[2] += x[2] * y[2];
            acc[3] += x[3] * y[3];
        }
        let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
        for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
            s += x * y;
        }
        s
    }

    /// Scalar fused dequant-dot over an i8 row (see [`super::dot_i8`]);
    /// same four-lane accumulation shape as [`dot`].
    #[inline]
    pub fn dot_i8(row: &[i8], scale: f32, x: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), x.len());
        let mut acc = [0.0f32; 4];
        let mut ca = row.chunks_exact(4);
        let mut cb = x.chunks_exact(4);
        for (q, y) in (&mut ca).zip(&mut cb) {
            acc[0] += q[0] as f32 * y[0];
            acc[1] += q[1] as f32 * y[1];
            acc[2] += q[2] as f32 * y[2];
            acc[3] += q[3] as f32 * y[3];
        }
        let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
        for (q, y) in ca.remainder().iter().zip(cb.remainder()) {
            s += *q as f32 * y;
        }
        s * scale
    }

    /// `out[r] = dot(m[r], x)`, blocked four rows at a time so each load
    /// of `x` feeds four accumulating lanes.
    pub fn matvec(m: &[f32], rows: usize, d: usize, x: &[f32], out: &mut [f32]) {
        debug_assert!(m.len() >= rows * d);
        debug_assert!(out.len() >= rows);
        debug_assert_eq!(x.len(), d);
        let x = &x[..d];
        let mut r = 0;
        while r + 4 <= rows {
            let m0 = &m[r * d..r * d + d];
            let m1 = &m[(r + 1) * d..(r + 1) * d + d];
            let m2 = &m[(r + 2) * d..(r + 2) * d + d];
            let m3 = &m[(r + 3) * d..(r + 3) * d + d];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for j in 0..d {
                let xj = x[j];
                a0 += m0[j] * xj;
                a1 += m1[j] * xj;
                a2 += m2[j] * xj;
                a3 += m3[j] * xj;
            }
            out[r] = a0;
            out[r + 1] = a1;
            out[r + 2] = a2;
            out[r + 3] = a3;
            r += 4;
        }
        while r < rows {
            out[r] = dot(&m[r * d..r * d + d], x);
            r += 1;
        }
    }

    /// `acc[..d] += sum_r w[r] * m[r]`, skipping rows with zero weight.
    /// Rows are walked in pairs so the two row streams overlap loads.
    pub fn axpy_rows(m: &[f32], rows: usize, d: usize, w: &[f32], acc: &mut [f32]) {
        debug_assert!(m.len() >= rows * d);
        debug_assert!(w.len() >= rows);
        debug_assert!(acc.len() >= d);
        let acc = &mut acc[..d];
        let mut r = 0;
        while r + 2 <= rows {
            let (w0, w1) = (w[r], w[r + 1]);
            if w0 != 0.0 || w1 != 0.0 {
                let m0 = &m[r * d..r * d + d];
                let m1 = &m[(r + 1) * d..(r + 1) * d + d];
                for j in 0..d {
                    acc[j] += w0 * m0[j] + w1 * m1[j];
                }
            }
            r += 2;
        }
        if r < rows && w[r] != 0.0 {
            let m0 = &m[r * d..r * d + d];
            for j in 0..d {
                acc[j] += w[r] * m0[j];
            }
        }
    }

    /// Scalar tile of [`super::vecmat`]: the exact row-order /
    /// accumulation-order / zero-skip shape of the historical GDN and
    /// linear-attention read loops, so routing those reads here is
    /// bit-invisible on this backend.
    pub fn vecmat(x: &[f32], m: &[f32], rows: usize, d: usize, out: &mut [f32]) {
        debug_assert!(m.len() >= rows * d);
        debug_assert!(x.len() >= rows);
        debug_assert!(out.len() >= d);
        let out = &mut out[..d];
        out.iter_mut().for_each(|o| *o = 0.0);
        for i in 0..rows {
            let xi = x[i];
            if xi != 0.0 {
                let row = &m[i * d..(i + 1) * d];
                for (o, &mj) in out.iter_mut().zip(row) {
                    *o += xi * mj;
                }
            }
        }
    }

    /// Scalar tile of [`super::matmul_rows`]; see the bit-identity
    /// contract there.
    pub fn matmul_rows(m: &[f32], rows: usize, d: usize, xs: &[f32], len: usize, out: &mut [f32]) {
        debug_assert!(m.len() >= rows * d);
        debug_assert!(xs.len() >= len * d);
        debug_assert!(out.len() >= len * rows);
        let mut s0 = 0;
        while s0 < rows {
            let sn = (s0 + SLOT_BLOCK).min(rows);
            let block = &m[s0 * d..sn * d];
            let brows = sn - s0;
            for i in 0..len {
                let x = &xs[i * d..(i + 1) * d];
                let orow = &mut out[i * rows + s0..i * rows + sn];
                let mut r = 0;
                while r + 4 <= brows {
                    let m0 = &block[r * d..r * d + d];
                    let m1 = &block[(r + 1) * d..(r + 1) * d + d];
                    let m2 = &block[(r + 2) * d..(r + 2) * d + d];
                    let m3 = &block[(r + 3) * d..(r + 3) * d + d];
                    let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                    for j in 0..d {
                        let xj = x[j];
                        a0 += m0[j] * xj;
                        a1 += m1[j] * xj;
                        a2 += m2[j] * xj;
                        a3 += m3[j] * xj;
                    }
                    orow[r] = a0;
                    orow[r + 1] = a1;
                    orow[r + 2] = a2;
                    orow[r + 3] = a3;
                    r += 4;
                }
                while r < brows {
                    orow[r] = dot(&block[r * d..r * d + d], x);
                    r += 1;
                }
            }
            s0 = sn;
        }
    }

    /// Scalar tile of [`super::nearest_rows`]: four-row similarity blocks,
    /// strict-greater compare so the earliest row wins exact ties.
    pub fn nearest_rows(
        dict: &[f32],
        n: usize,
        d: usize,
        keys: &[f32],
        len: usize,
        best_idx: &mut [usize],
        best_sim: &mut [f32],
    ) {
        debug_assert!(dict.len() >= n * d);
        debug_assert!(keys.len() >= len * d);
        debug_assert!(best_idx.len() >= len && best_sim.len() >= len);
        let mut s0 = 0;
        while s0 < n {
            let sn = (s0 + SLOT_BLOCK).min(n);
            let block = &dict[s0 * d..sn * d];
            let rows = sn - s0;
            for i in 0..len {
                let k = &keys[i * d..(i + 1) * d];
                let (mut bi, mut bv) = (best_idx[i], best_sim[i]);
                let mut r = 0;
                // four-row blocks: one pass of k feeds four similarity lanes
                while r + 4 <= rows {
                    let m0 = &block[r * d..r * d + d];
                    let m1 = &block[(r + 1) * d..(r + 1) * d + d];
                    let m2 = &block[(r + 2) * d..(r + 2) * d + d];
                    let m3 = &block[(r + 3) * d..(r + 3) * d + d];
                    let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                    for j in 0..d {
                        let kj = k[j];
                        a0 += m0[j] * kj;
                        a1 += m1[j] * kj;
                        a2 += m2[j] * kj;
                        a3 += m3[j] * kj;
                    }
                    for (off, a) in [a0, a1, a2, a3].into_iter().enumerate() {
                        if a > bv {
                            bv = a;
                            bi = s0 + r + off;
                        }
                    }
                    r += 4;
                }
                while r < rows {
                    let a = dot(&block[r * d..r * d + d], k);
                    if a > bv {
                        bv = a;
                        bi = s0 + r;
                    }
                    r += 1;
                }
                best_idx[i] = bi;
                best_sim[i] = bv;
            }
            s0 = sn;
        }
    }
}

/// AVX2/FMA backend, compiled only with the `simd` feature on x86_64 and
/// entered only after the cached CPUID probe confirms both features. One
/// `dot_avx2` core (4 × 8-lane FMA accumulators, 8-lane remainder, scalar
/// tail) serves every per-row similarity/logit, which is what keeps
/// `matvec` and `matmul_rows` bit-identical to each other on this path.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub(crate) mod simd {
    use super::SLOT_BLOCK;
    use std::arch::x86_64::*;
    use std::sync::atomic::{AtomicU8, Ordering};

    /// 0 = unprobed, 1 = avx2+fma present, 2 = absent.
    static AVX2: AtomicU8 = AtomicU8::new(0);

    /// Cached runtime probe for AVX2 + FMA. The result is stable for the
    /// life of the process, so every kernel in a run uses one backend.
    #[inline]
    pub fn avx2_available() -> bool {
        match AVX2.load(Ordering::Relaxed) {
            1 => true,
            2 => false,
            _ => {
                let yes = is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma");
                AVX2.store(if yes { 1 } else { 2 }, Ordering::Relaxed);
                yes
            }
        }
    }

    /// Horizontal sum of one 8-lane register.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    /// The shared per-row core: 4 × 8-lane FMA accumulators (32 floats
    /// per iteration), an 8-lane remainder loop, then a scalar tail for
    /// the last `len % 8` elements.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 32 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 8)),
                _mm256_loadu_ps(pb.add(i + 8)),
                acc1,
            );
            acc2 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 16)),
                _mm256_loadu_ps(pb.add(i + 16)),
                acc2,
            );
            acc3 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 24)),
                _mm256_loadu_ps(pb.add(i + 24)),
                acc3,
            );
            i += 32;
        }
        while i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            i += 8;
        }
        let mut s = hsum(_mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3)));
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    /// Fused i8 dequant-dot: 8 quantized bytes widen to 8 f32 lanes
    /// (cvtepi8_epi32 → cvtepi32_ps) and FMA against `x`; the per-row
    /// scale is applied once to the f32 accumulator.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot_i8_avx2(row: &[i8], scale: f32, x: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), x.len());
        let n = row.len();
        let (pq, px) = (row.as_ptr(), x.as_ptr());
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            let q8 = _mm_loadl_epi64(pq.add(i) as *const __m128i);
            let qf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(q8));
            acc = _mm256_fmadd_ps(qf, _mm256_loadu_ps(px.add(i)), acc);
            i += 8;
        }
        let mut s = hsum(acc);
        while i < n {
            s += row[i] as f32 * x[i];
            i += 1;
        }
        s * scale
    }

    /// `acc += w * row`, 8 lanes per FMA with a scalar tail.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn axpy_row_avx2(row: &[f32], w: f32, acc: &mut [f32]) {
        debug_assert_eq!(row.len(), acc.len());
        let d = row.len();
        let wv = _mm256_set1_ps(w);
        let (pr, pa) = (row.as_ptr(), acc.as_mut_ptr());
        let mut j = 0usize;
        while j + 8 <= d {
            let a = _mm256_loadu_ps(pa.add(j));
            let r = _mm256_loadu_ps(pr.add(j));
            _mm256_storeu_ps(pa.add(j), _mm256_fmadd_ps(wv, r, a));
            j += 8;
        }
        while j < d {
            acc[j] += w * row[j];
            j += 1;
        }
    }

    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert!(avx2_available());
        // SAFETY: dispatchers only enter this module after avx2_available().
        unsafe { dot_avx2(a, b) }
    }

    pub fn dot_i8(row: &[i8], scale: f32, x: &[f32]) -> f32 {
        debug_assert!(avx2_available());
        // SAFETY: dispatchers only enter this module after avx2_available().
        unsafe { dot_i8_avx2(row, scale, x) }
    }

    pub fn matvec(m: &[f32], rows: usize, d: usize, x: &[f32], out: &mut [f32]) {
        debug_assert!(avx2_available());
        debug_assert!(m.len() >= rows * d);
        debug_assert_eq!(x.len(), d);
        let x = &x[..d];
        for (r, o) in out[..rows].iter_mut().enumerate() {
            // SAFETY: gated on avx2_available() above.
            *o = unsafe { dot_avx2(&m[r * d..r * d + d], x) };
        }
    }

    pub fn axpy_rows(m: &[f32], rows: usize, d: usize, w: &[f32], acc: &mut [f32]) {
        debug_assert!(avx2_available());
        debug_assert!(m.len() >= rows * d);
        debug_assert!(acc.len() >= d);
        let acc = &mut acc[..d];
        for (r, &wr) in w[..rows].iter().enumerate() {
            if wr != 0.0 {
                // SAFETY: gated on avx2_available() above.
                unsafe { axpy_row_avx2(&m[r * d..r * d + d], wr, acc) };
            }
        }
    }

    /// AVX2 [`super::vecmat`]: zero the accumulator, then one broadcast
    /// FMA sweep per nonzero-weight row, in the scalar path's row order.
    pub fn vecmat(x: &[f32], m: &[f32], rows: usize, d: usize, out: &mut [f32]) {
        debug_assert!(avx2_available());
        debug_assert!(m.len() >= rows * d);
        debug_assert!(x.len() >= rows);
        debug_assert!(out.len() >= d);
        let out = &mut out[..d];
        out.iter_mut().for_each(|o| *o = 0.0);
        for (i, &xi) in x[..rows].iter().enumerate() {
            if xi != 0.0 {
                // SAFETY: gated on avx2_available() above.
                unsafe { axpy_row_avx2(&m[i * d..i * d + d], xi, out) };
            }
        }
    }

    /// Same tiling as the scalar path; every (query, row) result is one
    /// `dot_avx2` call, so this is bit-identical to per-query
    /// [`matvec`] on this backend.
    pub fn matmul_rows(m: &[f32], rows: usize, d: usize, xs: &[f32], len: usize, out: &mut [f32]) {
        debug_assert!(avx2_available());
        debug_assert!(m.len() >= rows * d);
        debug_assert!(xs.len() >= len * d);
        debug_assert!(out.len() >= len * rows);
        let mut s0 = 0;
        while s0 < rows {
            let sn = (s0 + SLOT_BLOCK).min(rows);
            let block = &m[s0 * d..sn * d];
            let brows = sn - s0;
            for i in 0..len {
                let x = &xs[i * d..(i + 1) * d];
                let orow = &mut out[i * rows + s0..i * rows + sn];
                let mut r = 0;
                while r < brows {
                    // SAFETY: gated on avx2_available() above.
                    orow[r] = unsafe { dot_avx2(&block[r * d..r * d + d], x) };
                    r += 1;
                }
            }
            s0 = sn;
        }
    }

    pub fn nearest_rows(
        dict: &[f32],
        n: usize,
        d: usize,
        keys: &[f32],
        len: usize,
        best_idx: &mut [usize],
        best_sim: &mut [f32],
    ) {
        debug_assert!(avx2_available());
        debug_assert!(dict.len() >= n * d);
        debug_assert!(keys.len() >= len * d);
        debug_assert!(best_idx.len() >= len && best_sim.len() >= len);
        let mut s0 = 0;
        while s0 < n {
            let sn = (s0 + SLOT_BLOCK).min(n);
            let block = &dict[s0 * d..sn * d];
            let rows = sn - s0;
            for i in 0..len {
                let k = &keys[i * d..(i + 1) * d];
                let (mut bi, mut bv) = (best_idx[i], best_sim[i]);
                let mut r = 0;
                while r < rows {
                    // SAFETY: gated on avx2_available() above.
                    let a = unsafe { dot_avx2(&block[r * d..r * d + d], k) };
                    if a > bv {
                        bv = a;
                        bi = s0 + r;
                    }
                    r += 1;
                }
                best_idx[i] = bi;
                best_sim[i] = bv;
            }
            s0 = sn;
        }
    }
}

/// Index of the maximum element, first occurrence winning ties — the
/// greedy-sampling hot path (one pass, no allocation). Returns 0 for an
/// empty slice and for all-NEG_INFINITY input (callers treat token 0 as
/// the degenerate fallback, matching [`crate::util::rng::Rng::categorical`]
/// on zero mass).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

/// Value of the k-th largest element (k >= 1) via partial selection: a
/// sorted descending keep-buffer of at most k entries, each candidate
/// admitted by binary search — O(n log k) comparisons and O(k) state, so
/// the top-k sampling mask never sorts the whole vocab. `keep` is a
/// caller-owned scratch reused across calls. Returns NEG_INFINITY when
/// k == 0 or k >= len (nothing would be masked); ties at the threshold
/// are resolved by the caller keeping everything >= the returned value.
pub fn top_k_threshold(xs: &[f32], k: usize, keep: &mut Vec<f32>) -> f32 {
    if k == 0 || k >= xs.len() {
        return f32::NEG_INFINITY;
    }
    keep.clear();
    for &x in xs {
        if keep.len() < k {
            let pos = keep.partition_point(|&y| y > x);
            keep.insert(pos, x);
        } else if x > keep[k - 1] {
            let pos = keep.partition_point(|&y| y > x);
            keep.insert(pos, x);
            keep.pop();
        }
    }
    keep[k - 1]
}

/// Streaming-softmax combine over a logit slice and its value rows:
/// `out += sum_s exp(logits[s] - m) * values[s]`, returning the partial
/// normalizer. `NEG_INFINITY` logits are skipped. Weights are materialized
/// into `w_scratch` (len >= rows) so the value gather runs through the
/// blocked [`axpy_rows`] — which is also where this function picks up the
/// SIMD backend; the exp loop stays scalar on every path.
pub fn softmax_accumulate(
    logits: &[f32],
    values: &[f32],
    rows: usize,
    d: usize,
    m: f32,
    w_scratch: &mut [f32],
    out: &mut [f32],
) -> f32 {
    debug_assert!(logits.len() >= rows);
    debug_assert!(w_scratch.len() >= rows);
    let mut z = 0.0f32;
    for s in 0..rows {
        let w = if logits[s] > f32::NEG_INFINITY { (logits[s] - m).exp() } else { 0.0 };
        w_scratch[s] = w;
        z += w;
    }
    axpy_rows(values, rows, d, w_scratch, out);
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn naive_dot(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(1);
        for n in [0usize, 1, 3, 4, 7, 64, 129] {
            let a = randv(&mut rng, n);
            let b = randv(&mut rng, n);
            let got = dot(&a, &b);
            let want = naive_dot(&a, &b);
            assert!((got - want).abs() < 1e-3 * (1.0 + want.abs()), "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn dot_i8_matches_widened_naive() {
        let mut rng = Rng::new(11);
        for n in [0usize, 1, 3, 7, 8, 9, 31, 64, 129] {
            let row: Vec<i8> = (0..n).map(|_| (rng.normal() * 40.0) as i8).collect();
            let x = randv(&mut rng, n);
            let scale = 0.037f32;
            let got = dot_i8(&row, scale, &x);
            let want: f32 = row.iter().zip(&x).map(|(&q, y)| q as f32 * y).sum::<f32>() * scale;
            assert!((got - want).abs() < 1e-3 * (1.0 + want.abs()), "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn matvec_matches_naive() {
        let mut rng = Rng::new(2);
        for (rows, d) in [(1usize, 5usize), (4, 8), (7, 16), (130, 64)] {
            let m = randv(&mut rng, rows * d);
            let x = randv(&mut rng, d);
            let mut out = vec![0.0f32; rows];
            matvec(&m, rows, d, &x, &mut out);
            for r in 0..rows {
                let want = naive_dot(&m[r * d..(r + 1) * d], &x);
                assert!((out[r] - want).abs() < 1e-3 * (1.0 + want.abs()));
            }
        }
    }

    #[test]
    fn vecmat_matches_naive_and_overwrites() {
        let mut rng = Rng::new(12);
        for (rows, d) in [(1usize, 5usize), (4, 8), (7, 16), (65, 33)] {
            let m = randv(&mut rng, rows * d);
            let mut x = randv(&mut rng, rows);
            x[0] = 0.0; // exercise the zero-weight skip
            let mut out = vec![42.0f32; d]; // stale contents must vanish
            vecmat(&x, &m, rows, d, &mut out);
            for j in 0..d {
                let want: f32 = (0..rows).map(|i| x[i] * m[i * d + j]).sum();
                assert!(
                    (out[j] - want).abs() < 1e-3 * (1.0 + want.abs()),
                    "rows={rows} d={d} j={j}: {} vs {want}",
                    out[j]
                );
            }
        }
    }

    #[test]
    fn axpy_rows_matches_naive() {
        let mut rng = Rng::new(3);
        for (rows, d) in [(1usize, 3usize), (2, 8), (9, 16)] {
            let m = randv(&mut rng, rows * d);
            let w = randv(&mut rng, rows);
            let mut acc = vec![0.5f32; d];
            let mut want = acc.clone();
            axpy_rows(&m, rows, d, &w, &mut acc);
            for r in 0..rows {
                for j in 0..d {
                    want[j] += w[r] * m[r * d + j];
                }
            }
            for j in 0..d {
                assert!((acc[j] - want[j]).abs() < 1e-3 * (1.0 + want[j].abs()));
            }
        }
    }

    #[test]
    fn matmul_rows_is_bit_identical_to_per_query_matvec() {
        // the prefill contract: the batched form must not just be close,
        // it must reproduce matvec's bits for every (query, row) pair —
        // exercised across tile boundaries and 4-row tail remainders.
        // This runs against the dispatched entry points, so it pins the
        // contract on whichever backend is live (scalar or avx2).
        let mut rng = Rng::new(7);
        for (rows, d, len) in [(1usize, 4usize, 1usize), (7, 8, 3), (64, 16, 5), (131, 32, 9)] {
            let m = randv(&mut rng, rows * d);
            let xs = randv(&mut rng, len * d);
            let mut got = vec![0.0f32; len * rows];
            matmul_rows(&m, rows, d, &xs, len, &mut got);
            let mut want = vec![0.0f32; rows];
            for i in 0..len {
                matvec(&m, rows, d, &xs[i * d..(i + 1) * d], &mut want);
                for r in 0..rows {
                    assert_eq!(
                        got[i * rows + r].to_bits(),
                        want[r].to_bits(),
                        "rows={rows} d={d} query {i} row {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn nearest_matches_exhaustive() {
        let mut rng = Rng::new(4);
        for (n, d, len) in [(1usize, 4usize, 3usize), (63, 8, 5), (64, 16, 9), (257, 32, 17)] {
            let dict = randv(&mut rng, n * d);
            let keys = randv(&mut rng, len * d);
            let mut idx = vec![0usize; len];
            let mut sim = vec![f32::NEG_INFINITY; len];
            nearest_rows(&dict, n, d, &keys, len, &mut idx, &mut sim);
            for i in 0..len {
                let k = &keys[i * d..(i + 1) * d];
                let mut bv = f32::NEG_INFINITY;
                for s in 0..n {
                    bv = bv.max(naive_dot(&dict[s * d..(s + 1) * d], k));
                }
                // the chosen row must achieve the max similarity (argmax
                // compared by value, not index — blocked accumulation may
                // legitimately break FP near-ties differently)
                assert!(idx[i] < n);
                let chosen = naive_dot(&dict[idx[i] * d..(idx[i] + 1) * d], k);
                let tol = 1e-3 * (1.0 + bv.abs());
                assert!(chosen >= bv - tol, "key {i} (n={n} d={d}): {chosen} vs max {bv}");
                assert!((sim[i] - chosen).abs() < tol);
            }
        }
    }

    #[test]
    fn nearest_respects_seeded_candidate() {
        // a pre-seeded best_sim above every dictionary similarity survives
        let dict = vec![0.0f32; 8 * 4];
        let keys = vec![1.0f32; 4];
        let mut idx = vec![99usize];
        let mut sim = vec![1e9f32];
        nearest_rows(&dict, 8, 4, &keys, 1, &mut idx, &mut sim);
        assert_eq!(idx[0], 99);
        assert_eq!(sim[0], 1e9);
    }

    #[test]
    fn backend_report_is_consistent_with_build() {
        let b = backend();
        if cfg!(feature = "simd") {
            assert!(b == "avx2" || b == "scalar");
        } else {
            assert_eq!(b, "scalar");
        }
    }

    #[test]
    fn argmax_matches_naive_and_breaks_ties_low() {
        let mut rng = Rng::new(5);
        for n in [1usize, 2, 7, 64, 257] {
            let xs = randv(&mut rng, n);
            let got = argmax(&xs);
            let naive = xs
                .iter()
                .enumerate()
                .fold((0usize, f32::NEG_INFINITY), |(bi, bv), (i, &x)| {
                    if x > bv {
                        (i, x)
                    } else {
                        (bi, bv)
                    }
                })
                .0;
            assert_eq!(got, naive, "n={n}");
        }
        assert_eq!(argmax(&[]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY; 4]), 0);
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1, "first max wins ties");
    }

    #[test]
    fn top_k_threshold_matches_full_sort() {
        let mut rng = Rng::new(6);
        let mut keep = Vec::new();
        for n in [1usize, 5, 64, 300] {
            let xs = randv(&mut rng, n);
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            for k in [0usize, 1, 2, n / 2, n.saturating_sub(1), n, n + 5] {
                let got = top_k_threshold(&xs, k, &mut keep);
                if k == 0 || k >= n {
                    assert_eq!(got, f32::NEG_INFINITY, "n={n} k={k}: nothing to mask");
                } else {
                    assert_eq!(got.to_bits(), sorted[k - 1].to_bits(), "n={n} k={k}");
                    // masking below the threshold keeps at least k entries
                    let kept = xs.iter().filter(|&&x| x >= got).count();
                    assert!(kept >= k, "n={n} k={k}: kept {kept}");
                }
            }
        }
        // duplicates land on the duplicated value
        let xs = [2.0f32, 5.0, 5.0, 1.0, 5.0];
        assert_eq!(top_k_threshold(&xs, 2, &mut keep), 5.0);
        assert_eq!(top_k_threshold(&xs, 4, &mut keep), 2.0);
    }

    #[test]
    fn softmax_accumulate_normalizes() {
        let logits = [0.0f32, 0.0, f32::NEG_INFINITY];
        let values = [1.0f32, 2.0, 3.0, 4.0, 99.0, 99.0]; // d=2
        let mut w = [0.0f32; 3];
        let mut out = [0.0f32; 2];
        let z = softmax_accumulate(&logits, &values, 3, 2, 0.0, &mut w, &mut out);
        assert!((z - 2.0).abs() < 1e-6);
        // masked row contributes nothing; (1+3)/2, (2+4)/2 after /z
        assert!((out[0] / z - 2.0).abs() < 1e-6);
        assert!((out[1] / z - 3.0).abs() < 1e-6);
    }
}

/// Tolerance-mode test family for the SIMD backend. FMA contracts the
/// multiply-add rounding and the 8-lane reduction reassociates the sum,
/// so the AVX2 path is held to a documented epsilon against the scalar
/// reference instead of bit-equality:
///
/// ```text
/// |simd - scalar| <= EPS_REL * (1 + |scalar|),   EPS_REL = 1e-4
/// ```
///
/// Sizes deliberately include odd dims (d not a multiple of the 8-float
/// lane width or the 32-float unroll) and row counts below one
/// [`SLOT_BLOCK`] tile, so every remainder path is exercised. On a CPU
/// without AVX2 the dispatched calls fall back to scalar and these
/// assertions hold trivially.
#[cfg(all(test, feature = "simd", target_arch = "x86_64"))]
mod simd_tests {
    use super::*;
    use crate::util::rng::Rng;

    const EPS_REL: f32 = 1e-4;

    fn close(got: f32, want: f32) -> bool {
        (got - want).abs() <= EPS_REL * (1.0 + want.abs())
    }

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    const DIMS: [usize; 10] = [1, 3, 7, 8, 9, 17, 31, 33, 64, 100];
    const ROWS: [usize; 7] = [1, 2, 3, 5, 63, 64, 130];

    #[test]
    fn simd_dot_matches_scalar_within_eps() {
        let mut rng = Rng::new(21);
        for n in [0usize, 1, 3, 7, 8, 9, 31, 32, 33, 100, 257] {
            let a = randv(&mut rng, n);
            let b = randv(&mut rng, n);
            let (got, want) = (dot(&a, &b), scalar::dot(&a, &b));
            assert!(close(got, want), "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn simd_dot_i8_matches_scalar_within_eps() {
        let mut rng = Rng::new(22);
        for n in [1usize, 5, 7, 8, 9, 63, 64, 65, 129] {
            let row: Vec<i8> = (0..n).map(|_| (rng.normal() * 50.0) as i8).collect();
            let x = randv(&mut rng, n);
            let (got, want) = (dot_i8(&row, 0.021, &x), scalar::dot_i8(&row, 0.021, &x));
            assert!(close(got, want), "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn simd_matvec_matches_scalar_within_eps() {
        let mut rng = Rng::new(23);
        for &rows in &ROWS {
            for &d in &DIMS {
                let m = randv(&mut rng, rows * d);
                let x = randv(&mut rng, d);
                let mut got = vec![0.0f32; rows];
                let mut want = vec![0.0f32; rows];
                matvec(&m, rows, d, &x, &mut got);
                scalar::matvec(&m, rows, d, &x, &mut want);
                for r in 0..rows {
                    assert!(close(got[r], want[r]), "rows={rows} d={d} r={r}");
                }
            }
        }
    }

    #[test]
    fn simd_matmul_rows_matches_scalar_within_eps() {
        let mut rng = Rng::new(24);
        for (rows, d, len) in [(1usize, 3usize, 2usize), (7, 9, 3), (63, 17, 5), (130, 33, 4)] {
            let m = randv(&mut rng, rows * d);
            let xs = randv(&mut rng, len * d);
            let mut got = vec![0.0f32; len * rows];
            let mut want = vec![0.0f32; len * rows];
            matmul_rows(&m, rows, d, &xs, len, &mut got);
            scalar::matmul_rows(&m, rows, d, &xs, len, &mut want);
            for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                assert!(close(g, w), "rows={rows} d={d} flat={i}");
            }
        }
    }

    #[test]
    fn simd_vecmat_matches_scalar_within_eps() {
        let mut rng = Rng::new(27);
        for &rows in &ROWS {
            for &d in &DIMS {
                let m = randv(&mut rng, rows * d);
                let mut x = randv(&mut rng, rows);
                x[0] = 0.0; // the zero-skip path must agree across backends
                let mut got = vec![3.0f32; d];
                let mut want = vec![-7.0f32; d];
                vecmat(&x, &m, rows, d, &mut got);
                scalar::vecmat(&x, &m, rows, d, &mut want);
                for j in 0..d {
                    assert!(close(got[j], want[j]), "rows={rows} d={d} j={j}");
                }
            }
        }
    }

    #[test]
    fn simd_axpy_rows_matches_scalar_within_eps() {
        let mut rng = Rng::new(25);
        for &rows in &ROWS {
            for &d in &DIMS {
                let m = randv(&mut rng, rows * d);
                let mut w = randv(&mut rng, rows);
                w[0] = 0.0; // exercise the zero-weight skip
                let mut got = vec![0.25f32; d];
                let mut want = got.clone();
                axpy_rows(&m, rows, d, &w, &mut got);
                scalar::axpy_rows(&m, rows, d, &w, &mut want);
                for j in 0..d {
                    assert!(close(got[j], want[j]), "rows={rows} d={d} j={j}");
                }
            }
        }
    }

    #[test]
    fn simd_nearest_rows_matches_scalar_within_eps() {
        let mut rng = Rng::new(26);
        for (n, d, len) in [(1usize, 3usize, 2usize), (5, 9, 3), (63, 17, 7), (130, 33, 5)] {
            let dict = randv(&mut rng, n * d);
            let keys = randv(&mut rng, len * d);
            let mut idx = vec![0usize; len];
            let mut sim = vec![f32::NEG_INFINITY; len];
            let mut sidx = vec![0usize; len];
            let mut ssim = vec![f32::NEG_INFINITY; len];
            nearest_rows(&dict, n, d, &keys, len, &mut idx, &mut sim);
            scalar::nearest_rows(&dict, n, d, &keys, len, &mut sidx, &mut ssim);
            for i in 0..len {
                // indices may break FP near-ties differently; the chosen
                // similarity value must agree within epsilon
                assert!(idx[i] < n);
                assert!(close(sim[i], ssim[i]), "n={n} d={d} key={i}");
            }
        }
    }
}
