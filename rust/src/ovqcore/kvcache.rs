//! Full-attention KV cache — the memory-accounting baseline for
//! Fig. 4-right (kv-cache growth is linear in context length) and the
//! exact-softmax reference for the serving example. Optionally windowed
//! (sliding-window attention: keep only the last `window` positions),
//! which makes it the live counterpart of
//! [`super::memstate::MixerKind::SlidingWindow`]. Served through
//! [`SeqMixer`].

use anyhow::Result;

use super::mixer::{dict_softmax_read, Scratch, SeqMixer};
use super::snapshot;

#[derive(Debug, Clone)]
pub struct KvCache {
    pub d: usize,
    pub keys: Vec<f32>,
    pub values: Vec<f32>,
    pub beta: f32,
    /// None = full attention; Some(w) = sliding window of w positions
    pub window: Option<usize>,
    /// total tokens ever written (>= len() when windowed)
    pub t: usize,
}

impl KvCache {
    pub fn new(d: usize) -> KvCache {
        KvCache { d, keys: Vec::new(), values: Vec::new(), beta: 8.0, window: None, t: 0 }
    }

    pub fn with_window(d: usize, window: usize) -> KvCache {
        assert!(window > 0, "sliding window must be > 0");
        KvCache { window: Some(window), ..KvCache::new(d) }
    }

    /// Rebuild from a [`snapshot::save`] payload (full or windowed — the
    /// window is part of the blob).
    pub fn from_snapshot(r: &mut snapshot::Reader<'_>) -> Result<KvCache> {
        let d = r.usize()?;
        // d = 0 from a corrupt blob would divide-by-zero the shape checks
        // below (snapshot's no-panics-on-untrusted-bytes contract)
        anyhow::ensure!(d > 0, "kv_cache snapshot claims zero width");
        let mut c = KvCache::new(d);
        c.beta = r.f32()?;
        c.window = r.opt_usize()?;
        c.t = r.usize()?;
        c.keys = r.f32s()?;
        c.values = r.f32s()?;
        anyhow::ensure!(
            c.keys.len() % c.d == 0
                && c.values.len() == c.keys.len()
                && c.window.is_none_or(|w| w > 0 && c.len() <= w),
            "kv_cache snapshot has inconsistent shapes"
        );
        Ok(c)
    }

    /// Cached positions (<= window when windowed).
    pub fn len(&self) -> usize {
        self.keys.len() / self.d
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }
}

impl SeqMixer for KvCache {
    fn kind_name(&self) -> &'static str {
        if self.window.is_some() {
            "sliding_window"
        } else {
            "kv_cache"
        }
    }

    fn d_in(&self) -> usize {
        self.d
    }

    fn d_out(&self) -> usize {
        self.d
    }

    fn tokens(&self) -> usize {
        self.t
    }

    fn state_bytes(&self) -> usize {
        (self.keys.len() + self.values.len()) * 4
    }

    /// Appending l keys + values.
    fn update_bytes_per_chunk(&self, l: usize) -> usize {
        2 * l * self.d * 4
    }

    fn write(&mut self, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.d);
        if let Some(w) = self.window {
            if self.len() == w {
                // front drain is an O(w*d) memmove — same order as the
                // O(w*d) read every decode step already pays, and it keeps
                // state exactly 2*min(t,w)*d*4 bytes (the memstate
                // contract). A ring buffer would cut the constant but
                // split reads into two segments.
                self.keys.drain(..self.d);
                self.values.drain(..self.d);
            }
        }
        self.keys.extend_from_slice(k);
        self.values.extend_from_slice(v);
        self.t += 1;
    }

    /// Causal softmax read over everything cached (no count bias — every
    /// cached position is its own "slot" with count 1).
    fn read(&self, q: &[f32], out: &mut [f32], scratch: &mut Scratch) {
        let n = self.len();
        dict_softmax_read(
            q,
            &[],
            &[],
            &[],
            0,
            self.d,
            self.beta,
            &self.keys,
            &self.values,
            n,
            out,
            scratch,
        );
    }

    /// Blocked prompt ingestion: the whole block is appended in one bulk
    /// extend, each read runs over the exact sliding slice serial decode
    /// would have seen (`[max(0, i+1-w), i+1)` of the concatenated
    /// history), and the window invariant is restored with ONE front
    /// drain at the end — instead of one O(w*d) memmove per token.
    fn process_prefill(
        &mut self,
        queries: &[f32],
        keys: &[f32],
        values: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        let d = self.d;
        let len = keys.len() / d;
        debug_assert_eq!(queries.len(), len * d);
        debug_assert_eq!(values.len(), len * d);
        debug_assert_eq!(out.len(), len * d);
        let base = self.len();
        self.keys.extend_from_slice(keys);
        self.values.extend_from_slice(values);
        self.t += len;
        for i in 0..len {
            let end = base + i + 1;
            let start = match self.window {
                Some(w) => end.saturating_sub(w),
                None => 0,
            };
            dict_softmax_read(
                &queries[i * d..(i + 1) * d],
                &[],
                &[],
                &[],
                0,
                d,
                self.beta,
                &self.keys[start * d..end * d],
                &self.values[start * d..end * d],
                end - start,
                &mut out[i * d..(i + 1) * d],
                scratch,
            );
        }
        if let Some(w) = self.window {
            let drop = self.len().saturating_sub(w);
            if drop > 0 {
                self.keys.drain(..drop * d);
                self.values.drain(..drop * d);
            }
        }
    }

    /// Writes-only prefill: one bulk append and (when windowed) one front
    /// drain. No reads happen, so this is trivially bit-identical in state
    /// to [`Self::process_prefill`] and costs O(len*d) instead of the
    /// full O(len*w*d) attention sweep.
    fn prefill_writes(&mut self, keys: &[f32], values: &[f32], _scratch: &mut Scratch) {
        let d = self.d;
        let len = keys.len() / d;
        debug_assert_eq!(values.len(), len * d);
        self.keys.extend_from_slice(keys);
        self.values.extend_from_slice(values);
        self.t += len;
        if let Some(w) = self.window {
            let drop = self.len().saturating_sub(w);
            if drop > 0 {
                self.keys.drain(..drop * d);
                self.values.drain(..drop * d);
            }
        }
    }

    fn snapshot(&self, w: &mut snapshot::Writer) {
        w.usize(self.d);
        w.f32(self.beta);
        w.opt_usize(self.window);
        w.usize(self.t);
        w.f32s(&self.keys);
        w.f32s(&self.values);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_grows_linearly() {
        let mut c = KvCache::new(16);
        assert_eq!(c.state_bytes(), 0);
        for _ in 0..100 {
            c.write(&[0.1; 16], &[0.2; 16]);
        }
        assert_eq!(c.state_bytes(), 100 * 2 * 16 * 4);
        assert_eq!(c.len(), 100);
        assert_eq!(c.tokens(), 100);
    }

    #[test]
    fn sharp_read_returns_best_match() {
        let mut c = KvCache::new(4);
        c.beta = 50.0;
        c.write(&[1.0, 0.0, 0.0, 0.0], &[1.0; 4]);
        c.write(&[0.0, 1.0, 0.0, 0.0], &[5.0; 4]);
        let mut out = [0.0; 4];
        let mut scratch = Scratch::new();
        c.read(&[0.0, 1.0, 0.0, 0.0], &mut out, &mut scratch);
        for &o in &out {
            assert!((o - 5.0).abs() < 1e-3);
        }
    }

    #[test]
    fn window_caps_state_and_evicts_oldest() {
        let mut c = KvCache::with_window(4, 8);
        c.beta = 50.0;
        c.write(&[1.0, 0.0, 0.0, 0.0], &[7.0; 4]); // will be evicted
        for _ in 0..8 {
            c.write(&[0.0, 1.0, 0.0, 0.0], &[2.0; 4]);
        }
        assert_eq!(c.len(), 8);
        assert_eq!(c.tokens(), 9);
        assert_eq!(c.state_bytes(), 8 * 2 * 4 * 4);
        // the evicted key no longer matches anything sharp
        let mut out = [0.0; 4];
        let mut scratch = Scratch::new();
        c.read(&[1.0, 0.0, 0.0, 0.0], &mut out, &mut scratch);
        // all remaining values are 2.0, so any softmax mix returns 2.0
        for &o in &out {
            assert!((o - 2.0).abs() < 1e-3, "{o}");
        }
    }
}
