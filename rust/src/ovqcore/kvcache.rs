//! Full-attention KV cache — the memory-accounting baseline for
//! Fig. 4-right (kv-cache growth is linear in context length) and the
//! exact-softmax reference for the serving example.

#[derive(Debug, Clone)]
pub struct KvCache {
    pub d: usize,
    pub keys: Vec<f32>,
    pub values: Vec<f32>,
    pub beta: f32,
}

impl KvCache {
    pub fn new(d: usize) -> KvCache {
        KvCache { d, keys: Vec::new(), values: Vec::new(), beta: 8.0 }
    }

    pub fn len(&self) -> usize {
        self.keys.len() / self.d
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn state_bytes(&self) -> usize {
        (self.keys.len() + self.values.len()) * 4
    }

    pub fn write(&mut self, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.d);
        self.keys.extend_from_slice(k);
        self.values.extend_from_slice(v);
    }

    /// Causal softmax read over everything written so far.
    pub fn read(&self, q: &[f32], out: &mut [f32]) {
        let d = self.d;
        let n = self.len();
        out.iter_mut().for_each(|o| *o = 0.0);
        if n == 0 {
            return;
        }
        let mut logits = Vec::with_capacity(n);
        let mut m = f32::NEG_INFINITY;
        for i in 0..n {
            let l: f32 = self.beta
                * q.iter()
                    .zip(&self.keys[i * d..(i + 1) * d])
                    .map(|(a, b)| a * b)
                    .sum::<f32>();
            m = m.max(l);
            logits.push(l);
        }
        let mut z = 0.0;
        for i in 0..n {
            let w = (logits[i] - m).exp();
            z += w;
            for (o, &v) in out.iter_mut().zip(&self.values[i * d..(i + 1) * d]) {
                *o += w * v;
            }
        }
        out.iter_mut().for_each(|o| *o /= z);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_grows_linearly() {
        let mut c = KvCache::new(16);
        assert_eq!(c.state_bytes(), 0);
        for _ in 0..100 {
            c.write(&[0.1; 16], &[0.2; 16]);
        }
        assert_eq!(c.state_bytes(), 100 * 2 * 16 * 4);
        assert_eq!(c.len(), 100);
    }

    #[test]
    fn sharp_read_returns_best_match() {
        let mut c = KvCache::new(4);
        c.beta = 50.0;
        c.write(&[1.0, 0.0, 0.0, 0.0], &[1.0; 4]);
        c.write(&[0.0, 1.0, 0.0, 0.0], &[5.0; 4]);
        let mut out = [0.0; 4];
        c.read(&[0.0, 1.0, 0.0, 0.0], &mut out);
        for &o in &out {
            assert!((o - 5.0).abs() < 1e-3);
        }
    }
}
