//! Linear-attention state machine (the §3.4 / Fig. 3 contrast case):
//! dense state S [d_k, d_v], rank-1 update per token — every update writes
//! the WHOLE state, so the chunk update tensor is [L, d_k, d_v], growing
//! with state size, unlike OVQ's [L, 2, d]. Served through [`SeqMixer`].

use anyhow::Result;

use super::mixer::{Scratch, SeqMixer};
use super::snapshot;

#[derive(Debug, Clone)]
pub struct LinearAttnState {
    pub dk: usize,
    pub dv: usize,
    /// S = sum phi(k)^T v, row-major [dk, dv]
    pub s: Vec<f32>,
    /// z = sum phi(k)
    pub z: Vec<f32>,
    pub t: usize,
}

fn phi(x: f32) -> f32 {
    // elu(x) + 1
    if x >= 0.0 {
        x + 1.0
    } else {
        x.exp()
    }
}

impl LinearAttnState {
    pub fn new(dk: usize, dv: usize) -> LinearAttnState {
        LinearAttnState { dk, dv, s: vec![0.0; dk * dv], z: vec![0.0; dk], t: 0 }
    }

    /// Rebuild from a [`snapshot::save`] payload.
    pub fn from_snapshot(r: &mut snapshot::Reader<'_>) -> Result<LinearAttnState> {
        let (dk, dv) = (r.usize()?, r.usize()?);
        // bound the dims BEFORE the [dk, dv] state allocation — a corrupt
        // blob must err cleanly, never overflow dk * dv or demand a wild
        // allocation (snapshot's no-panics-on-untrusted-bytes contract)
        anyhow::ensure!(
            dk > 0 && dk <= (1 << 12) && dv > 0 && dv <= (1 << 12),
            "linear_attn snapshot claims an implausible shape (dk={dk} dv={dv})"
        );
        let mut st = LinearAttnState::new(dk, dv);
        st.t = r.usize()?;
        st.s = r.f32s()?;
        st.z = r.f32s()?;
        anyhow::ensure!(
            st.s.len() == st.dk * st.dv && st.z.len() == st.dk,
            "linear_attn snapshot has inconsistent shapes"
        );
        Ok(st)
    }
}

impl SeqMixer for LinearAttnState {
    fn kind_name(&self) -> &'static str {
        "linear_attn"
    }

    fn d_in(&self) -> usize {
        self.dk
    }

    fn d_out(&self) -> usize {
        self.dv
    }

    fn tokens(&self) -> usize {
        self.t
    }

    fn state_bytes(&self) -> usize {
        (self.s.len() + self.z.len()) * 4
    }

    /// Bytes materialized per chunk of length l in the standard
    /// chunk-parallel implementation (paper §3.4): ΔS is [L, dk, dv].
    fn update_bytes_per_chunk(&self, l: usize) -> usize {
        l * self.dk * self.dv * 4
    }

    fn write(&mut self, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.dk);
        debug_assert_eq!(v.len(), self.dv);
        for i in 0..self.dk {
            let ki = phi(k[i]);
            self.z[i] += ki;
            let row = &mut self.s[i * self.dv..(i + 1) * self.dv];
            for (sj, &vj) in row.iter_mut().zip(v) {
                *sj += ki * vj;
            }
        }
        self.t += 1;
    }

    fn read(&self, q: &[f32], out: &mut [f32], _scratch: &mut Scratch) {
        let mut den = 1e-6f32;
        out.iter_mut().for_each(|o| *o = 0.0);
        for i in 0..self.dk {
            let qi = phi(q[i]);
            den += qi * self.z[i];
            let row = &self.s[i * self.dv..(i + 1) * self.dv];
            for (o, &sj) in out.iter_mut().zip(row) {
                *o += qi * sj;
            }
        }
        out.iter_mut().for_each(|o| *o /= den);
    }

    /// Prompt ingestion. Like GDN, the state recurrence is dense: the
    /// standard chunk-parallel prefill materializes ΔS ∈ [L, d_k, d_v]
    /// (the paper's §3.4 contrast case) and reassociates the FP sums, so
    /// it cannot be bit-identical to serial decode. The override is the
    /// fused write-then-read loop — allocation-free already, since both
    /// `write` and `read` stream straight over S — kept explicit so the
    /// prefill path is first-class on every machine and the golden tests
    /// pin its equivalence.
    fn process_prefill(
        &mut self,
        queries: &[f32],
        keys: &[f32],
        values: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        let (dk, dv) = (self.dk, self.dv);
        let len = keys.len() / dk;
        debug_assert_eq!(queries.len(), len * dk);
        debug_assert_eq!(values.len(), len * dv);
        debug_assert_eq!(out.len(), len * dv);
        for i in 0..len {
            self.write(&keys[i * dk..(i + 1) * dk], &values[i * dv..(i + 1) * dv]);
            self.read(
                &queries[i * dk..(i + 1) * dk],
                &mut out[i * dv..(i + 1) * dv],
                scratch,
            );
        }
    }

    fn snapshot(&self, w: &mut snapshot::Writer) {
        w.usize(self.dk);
        w.usize(self.dv);
        w.usize(self.t);
        w.f32s(&self.s);
        w.f32s(&self.z);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn single_write_read_recovers_value() {
        // with one stored pair and q == k, the normalized read returns v
        let mut st = LinearAttnState::new(8, 4);
        let k: Vec<f32> = (0..8).map(|i| (i as f32) / 8.0).collect();
        let v = vec![1.0, -2.0, 3.0, 0.5];
        st.write(&k, &v);
        let mut out = vec![0.0; 4];
        let mut scratch = Scratch::new();
        st.read(&k, &mut out, &mut scratch);
        for (o, &vi) in out.iter().zip(&v) {
            assert!((o - vi).abs() < 1e-3, "{o} vs {vi}");
        }
    }

    #[test]
    fn state_size_independent_of_tokens() {
        let mut st = LinearAttnState::new(16, 16);
        let b0 = st.state_bytes();
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let k: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
            let v: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
            st.write(&k, &v);
        }
        assert_eq!(st.state_bytes(), b0);
        assert_eq!(st.t, 1000);
    }

    #[test]
    fn update_tensor_grows_with_state() {
        // the paper's §3.4 point, as arithmetic
        let small = LinearAttnState::new(64, 64);
        let big = LinearAttnState::new(128, 128);
        assert!(big.update_bytes_per_chunk(32) > small.update_bytes_per_chunk(32));
    }
}
