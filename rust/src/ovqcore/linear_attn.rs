//! Linear-attention state machine (the §3.4 / Fig. 3 contrast case):
//! dense state S [d_k, d_v], rank-1 update per token — every update writes
//! the WHOLE state, so the chunk update tensor is [L, d_k, d_v], growing
//! with state size, unlike OVQ's [L, 2, d]. Served through [`SeqMixer`].

use anyhow::Result;

use super::kernels;
use super::mixer::{PrefillMode, Scratch, SeqMixer};
use super::snapshot;

#[derive(Debug, Clone)]
pub struct LinearAttnState {
    pub dk: usize,
    pub dv: usize,
    /// S = sum phi(k)^T v, row-major [dk, dv]
    pub s: Vec<f32>,
    /// z = sum phi(k)
    pub z: Vec<f32>,
    pub t: usize,
    /// prefill policy (runtime-only — never serialized, snapshots thaw
    /// in `Exact` and the serving layer re-applies its configured mode)
    pub mode: PrefillMode,
}

/// Reusable per-prefill-call workspace for the chunkwise scan form.
#[derive(Default)]
struct ChunkWs {
    /// `[L, dk]` feature-mapped queries phi(q)
    phiq: Vec<f32>,
    /// `[L, dk]` feature-mapped keys phi(k)
    phik: Vec<f32>,
    /// `[dk, L]` transposed phi(k) (state-fold row weights)
    phikt: Vec<f32>,
    /// `[L, L]` intra-block similarities phi(q_i) . phi(k_j)
    a: Vec<f32>,
}

fn grow(buf: &mut Vec<f32>, n: usize) -> &mut [f32] {
    if buf.len() < n {
        buf.resize(n, 0.0);
    }
    &mut buf[..n]
}

fn phi(x: f32) -> f32 {
    // elu(x) + 1
    if x >= 0.0 {
        x + 1.0
    } else {
        x.exp()
    }
}

impl LinearAttnState {
    pub fn new(dk: usize, dv: usize) -> LinearAttnState {
        LinearAttnState {
            dk,
            dv,
            s: vec![0.0; dk * dv],
            z: vec![0.0; dk],
            t: 0,
            mode: PrefillMode::Exact,
        }
    }

    /// One chunkwise block of `l` tokens. Linear attention composes
    /// exactly across blocks (`S += Σ phi(k)ᵀ v`, `z += Σ phi(k)`), so the
    /// block form needs only the `[L, L]` intra-block similarity matrix —
    /// never the §3.4 `[L, dk, dv]` ΔS tensor:
    ///
    /// ```text
    ///   o_i = (phi(q_i) S₀ + Σ_{j≤i} A[i,j] v_j) / (1e-6 + phi(q_i)·z₀ + Σ_{j≤i} A[i,j])
    ///   S_L = S₀ + Σ_j phi(k_j)ᵀ v_j,   z_L = z₀ + Σ_j phi(k_j)
    /// ```
    ///
    /// with `A = phi(Q) phi(K)ᵀ` from one tiled [`kernels::matmul_rows`]
    /// sweep. The combination reassociates FP sums relative to the serial
    /// token loop, so this only runs in `Chunkwise` mode under the
    /// documented tolerance. `queries`/`out` are optional: `None` skips
    /// the output half (the fanned-out owner advance).
    fn chunkwise_block(
        &mut self,
        queries: Option<&[f32]>,
        keys: &[f32],
        values: &[f32],
        out: Option<&mut [f32]>,
        ws: &mut ChunkWs,
    ) {
        let (dk, dv) = (self.dk, self.dv);
        let l = keys.len() / dk;
        let phik = grow(&mut ws.phik, l * dk);
        for (pk, &kj) in phik.iter_mut().zip(&keys[..l * dk]) {
            *pk = phi(kj);
        }
        if let (Some(queries), Some(out)) = (queries, out) {
            let phiq = grow(&mut ws.phiq, l * dk);
            for (pq, &qj) in phiq.iter_mut().zip(&queries[..l * dk]) {
                *pq = phi(qj);
            }
            let a = grow(&mut ws.a, l * l);
            // a[i * l + j] = phi(q_i) . phi(k_j)
            kernels::matmul_rows(&ws.phik[..l * dk], l, dk, &ws.phiq[..l * dk], l, a);
            for i in 0..l {
                let phiq_i = &ws.phiq[i * dk..(i + 1) * dk];
                let oi = &mut out[i * dv..(i + 1) * dv];
                // carry: phi(q_i) S_0 and phi(q_i) . z_0 against the
                // pre-block state
                kernels::vecmat(phiq_i, &self.s, dk, dv, oi);
                let mut den = 1e-6f32;
                den += kernels::dot(phiq_i, &self.z);
                let arow = &ws.a[i * l..i * l + i + 1];
                kernels::axpy_rows(values, i + 1, dv, arow, oi);
                for &aij in arow {
                    den += aij;
                }
                oi.iter_mut().for_each(|o| *o /= den);
            }
        }
        // exact state fold: S += phi(K)^T V, z += column sums of phi(K)
        let phikt = grow(&mut ws.phikt, dk * l);
        for i in 0..l {
            for r in 0..dk {
                phikt[r * l + i] = ws.phik[i * dk + r];
            }
        }
        for r in 0..dk {
            let wrow = &ws.phikt[r * l..(r + 1) * l];
            for &w in wrow {
                self.z[r] += w;
            }
            kernels::axpy_rows(values, l, dv, wrow, &mut self.s[r * dv..(r + 1) * dv]);
        }
        self.t += l;
    }

    /// Cut a prompt slice into `chunk`-token blocks and run each through
    /// [`LinearAttnState::chunkwise_block`].
    fn chunkwise_prefill(
        &mut self,
        queries: Option<&[f32]>,
        keys: &[f32],
        values: &[f32],
        mut out: Option<&mut [f32]>,
        chunk: usize,
    ) {
        let (dk, dv) = (self.dk, self.dv);
        let len = keys.len() / dk;
        let c = chunk.max(1);
        let mut ws = ChunkWs::default();
        let mut i = 0;
        while i < len {
            let l = c.min(len - i);
            self.chunkwise_block(
                queries.map(|q| &q[i * dk..(i + l) * dk]),
                &keys[i * dk..(i + l) * dk],
                &values[i * dv..(i + l) * dv],
                out.as_deref_mut().map(|o| &mut o[i * dv..(i + l) * dv]),
                &mut ws,
            );
            i += l;
        }
    }

    /// Rebuild from a [`snapshot::save`] payload.
    pub fn from_snapshot(r: &mut snapshot::Reader<'_>) -> Result<LinearAttnState> {
        let (dk, dv) = (r.usize()?, r.usize()?);
        // bound the dims BEFORE the [dk, dv] state allocation — a corrupt
        // blob must err cleanly, never overflow dk * dv or demand a wild
        // allocation (snapshot's no-panics-on-untrusted-bytes contract)
        anyhow::ensure!(
            dk > 0 && dk <= (1 << 12) && dv > 0 && dv <= (1 << 12),
            "linear_attn snapshot claims an implausible shape (dk={dk} dv={dv})"
        );
        let mut st = LinearAttnState::new(dk, dv);
        st.t = r.usize()?;
        st.s = r.f32s()?;
        st.z = r.f32s()?;
        anyhow::ensure!(
            st.s.len() == st.dk * st.dv && st.z.len() == st.dk,
            "linear_attn snapshot has inconsistent shapes"
        );
        Ok(st)
    }
}

impl SeqMixer for LinearAttnState {
    fn kind_name(&self) -> &'static str {
        "linear_attn"
    }

    fn d_in(&self) -> usize {
        self.dk
    }

    fn d_out(&self) -> usize {
        self.dv
    }

    fn tokens(&self) -> usize {
        self.t
    }

    fn state_bytes(&self) -> usize {
        (self.s.len() + self.z.len()) * 4
    }

    /// Bytes materialized per chunk of length l in the standard
    /// chunk-parallel implementation (paper §3.4): ΔS is [L, dk, dv].
    fn update_bytes_per_chunk(&self, l: usize) -> usize {
        l * self.dk * self.dv * 4
    }

    fn write(&mut self, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.dk);
        debug_assert_eq!(v.len(), self.dv);
        for i in 0..self.dk {
            let ki = phi(k[i]);
            self.z[i] += ki;
            let row = &mut self.s[i * self.dv..(i + 1) * self.dv];
            for (sj, &vj) in row.iter_mut().zip(v) {
                *sj += ki * vj;
            }
        }
        self.t += 1;
    }

    fn read(&self, q: &[f32], out: &mut [f32], scratch: &mut Scratch) {
        // stage phi(q) once, accumulate the normalizer in the serial
        // order the historical loop used, then run the numerator through
        // the dispatched transpose-matvec (scalar tile bit-identical to
        // the historical loop; AVX2 applies when built)
        let (dk, dv) = (self.dk, self.dv);
        let phiq = scratch.f32_buf(dk);
        let mut den = 1e-6f32;
        for i in 0..dk {
            let qi = phi(q[i]);
            phiq[i] = qi;
            den += qi * self.z[i];
        }
        kernels::vecmat(phiq, &self.s, dk, dv, out);
        out.iter_mut().for_each(|o| *o /= den);
    }

    fn set_prefill_mode(&mut self, mode: PrefillMode) {
        self.mode = mode;
    }

    /// Prompt ingestion. The default `Exact` mode keeps the fused
    /// write-then-read token loop — bit-identical to serial decode, pinned
    /// by the goldens. Opting into `Chunkwise` mode switches to the
    /// blocked scan form ([`LinearAttnState::chunkwise_block`]): one
    /// `[L, L]` similarity sweep per block plus an exact state fold,
    /// instead of the §3.4 `[L, d_k, d_v]` ΔS tensor. That reassociates
    /// the FP sums, so chunkwise outputs are tolerance-tested, never
    /// golden-pinned.
    fn process_prefill(
        &mut self,
        queries: &[f32],
        keys: &[f32],
        values: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        let (dk, dv) = (self.dk, self.dv);
        let len = keys.len() / dk;
        debug_assert_eq!(queries.len(), len * dk);
        debug_assert_eq!(values.len(), len * dv);
        debug_assert_eq!(out.len(), len * dv);
        if let PrefillMode::Chunkwise { chunk } = self.mode {
            self.chunkwise_prefill(Some(queries), keys, values, Some(out), chunk);
            return;
        }
        for i in 0..len {
            self.write(&keys[i * dk..(i + 1) * dk], &values[i * dv..(i + 1) * dv]);
            self.read(
                &queries[i * dk..(i + 1) * dk],
                &mut out[i * dv..(i + 1) * dv],
                scratch,
            );
        }
    }

    /// State-only prompt advance (the owner half of fanned-out prefill):
    /// identical state evolution to `process_prefill` in both modes,
    /// without computing any output row.
    fn prefill_writes(&mut self, keys: &[f32], values: &[f32], scratch: &mut Scratch) {
        let _ = scratch;
        let (dk, dv) = (self.dk, self.dv);
        let len = keys.len() / dk;
        debug_assert_eq!(values.len(), len * dv);
        if let PrefillMode::Chunkwise { chunk } = self.mode {
            self.chunkwise_prefill(None, keys, values, None, chunk);
            return;
        }
        for i in 0..len {
            self.write(&keys[i * dk..(i + 1) * dk], &values[i * dv..(i + 1) * dv]);
        }
    }

    fn snapshot(&self, w: &mut snapshot::Writer) {
        w.usize(self.dk);
        w.usize(self.dv);
        w.usize(self.t);
        w.f32s(&self.s);
        w.f32s(&self.z);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn single_write_read_recovers_value() {
        // with one stored pair and q == k, the normalized read returns v
        let mut st = LinearAttnState::new(8, 4);
        let k: Vec<f32> = (0..8).map(|i| (i as f32) / 8.0).collect();
        let v = vec![1.0, -2.0, 3.0, 0.5];
        st.write(&k, &v);
        let mut out = vec![0.0; 4];
        let mut scratch = Scratch::new();
        st.read(&k, &mut out, &mut scratch);
        for (o, &vi) in out.iter().zip(&v) {
            assert!((o - vi).abs() < 1e-3, "{o} vs {vi}");
        }
    }

    #[test]
    fn state_size_independent_of_tokens() {
        let mut st = LinearAttnState::new(16, 16);
        let b0 = st.state_bytes();
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let k: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
            let v: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
            st.write(&k, &v);
        }
        assert_eq!(st.state_bytes(), b0);
        assert_eq!(st.t, 1000);
    }

    /// Tolerance band for the chunkwise scan form (documented FP
    /// reassociation — same idiom as the kernel `simd_tests`).
    const EPS_REL: f32 = 1e-3;

    fn close(got: f32, want: f32) -> bool {
        (got - want).abs() <= EPS_REL * (1.0 + want.abs())
    }

    fn stream(seed: u64, n: usize, d: usize) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn chunkwise_prefill_matches_serial_within_eps() {
        // the tolerance family: odd lengths, exact block multiples, and
        // lengths with a short tail block — dk != dv exercises the
        // rectangular state
        let (dk, dv) = (12usize, 8usize);
        for &(total, chunk) in
            &[(1usize, 4usize), (3, 4), (8, 4), (9, 4), (37, 8), (64, 16), (65, 16)]
        {
            let q = stream(400 + total as u64, total, dk);
            let k = stream(500 + total as u64, total, dk);
            let v = stream(600 + total as u64, total, dv);
            let mut scratch = Scratch::new();

            let mut serial = LinearAttnState::new(dk, dv);
            let mut par = LinearAttnState::new(dk, dv);
            par.set_prefill_mode(PrefillMode::Chunkwise { chunk });

            let mut want = vec![0.0f32; total * dv];
            serial.process_prefill(&q, &k, &v, &mut want, &mut scratch);
            let mut got = vec![0.0f32; total * dv];
            par.process_prefill(&q, &k, &v, &mut got, &mut scratch);
            for i in 0..total * dv {
                assert!(
                    close(got[i], want[i]),
                    "total={total} chunk={chunk} flat={i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
            for i in 0..dk * dv {
                assert!(close(par.s[i], serial.s[i]), "state total={total} chunk={chunk} i={i}");
            }
            for i in 0..dk {
                assert!(close(par.z[i], serial.z[i]), "z total={total} chunk={chunk} i={i}");
            }
            assert_eq!(par.t, serial.t);

            // writes-only advance leaves the chunkwise state bit-identical
            // to the full chunkwise prefill (the fan-out owner contract)
            let mut wr = LinearAttnState::new(dk, dv);
            wr.set_prefill_mode(PrefillMode::Chunkwise { chunk });
            wr.prefill_writes(&k, &v, &mut scratch);
            for i in 0..dk * dv {
                assert_eq!(wr.s[i].to_bits(), par.s[i].to_bits(), "writes state i={i}");
            }
            for i in 0..dk {
                assert_eq!(wr.z[i].to_bits(), par.z[i].to_bits(), "writes z i={i}");
            }
        }
    }

    #[test]
    fn chunkwise_mid_block_cuts_stay_within_eps() {
        // a prompt cut mid-block restarts the blocking at the cut — a
        // different (still valid) chunkwise order, same tolerance band
        let (dk, dv) = (8usize, 8usize);
        let (total, chunk, cut) = (29usize, 8usize, 13usize);
        let q = stream(11, total, dk);
        let k = stream(12, total, dk);
        let v = stream(13, total, dv);
        let mut scratch = Scratch::new();

        let mut serial = LinearAttnState::new(dk, dv);
        let mut par = LinearAttnState::new(dk, dv);
        par.set_prefill_mode(PrefillMode::Chunkwise { chunk });

        let mut want = vec![0.0f32; total * dv];
        serial.process_prefill(&q, &k, &v, &mut want, &mut scratch);
        let mut got = vec![0.0f32; total * dv];
        let (aq, av) = (cut * dk, cut * dv);
        par.process_prefill(&q[..aq], &k[..aq], &v[..av], &mut got[..av], &mut scratch);
        par.process_prefill(&q[aq..], &k[aq..], &v[av..], &mut got[av..], &mut scratch);
        for i in 0..total * dv {
            assert!(close(got[i], want[i]), "flat={i}: {} vs {}", got[i], want[i]);
        }
    }

    #[test]
    fn update_tensor_grows_with_state() {
        // the paper's §3.4 point, as arithmetic
        let small = LinearAttnState::new(64, 64);
        let big = LinearAttnState::new(128, 128);
        assert!(big.update_bytes_per_chunk(32) > small.update_bytes_per_chunk(32));
    }
}
