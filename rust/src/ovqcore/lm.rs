//! The language model over a [`LayerStack`] — the piece that closes the
//! generation loop. [`LmModel`] wraps a token-embedding table, a full
//! multi-layer stack, and a **tied** unembedding (logits = E·h, the same
//! matrix both ways), exposing the two calls a generation engine needs:
//!
//! - [`LmModel::prefill_tokens`]: ingest a prompt slice through the
//!   blocked stack prefill and return the logits of its last position;
//! - [`LmModel::step_token`]: absorb one (sampled) token and return the
//!   next-token logits — the self-feeding decode step.
//!
//! Contracts, inherited from the stack and load-bearing for the engine:
//!
//! - **Weights are f(seed).** The embedding table follows the stack's
//!   weights-are-deterministic-in-the-init-seed rule ([`init_matrix`]),
//!   so snapshots store config + seed only and an evicted session's blob
//!   stays proportional to its *dynamic* state.
//! - **Chunked prefill ≡ token-at-a-time steps, bitwise.** Both paths
//!   run the same stack ops ([`SeqMixer::process_prefill`] is golden-
//!   tested bit-identical to the serial token loop) and the same tied
//!   unembedding matvec, so the final logits cannot depend on how the
//!   prompt was delivered — rust/tests/golden.rs pins this.
//! - **Generation state snapshots with the model.** [`GenCore`] — the
//!   repetition-penalty history ring, the sampling RNG mid-stream, and
//!   the produced-token count — is part of the `"lm"` snapshot payload,
//!   so a session LRU-evicted *mid-generation* thaws and keeps sampling
//!   the exact same token stream (rust/tests/engine.rs pins this too).
//!
//! `LmModel` implements [`SeqMixer`] (kind `"lm"`, delegating the f32
//! row interface to the inner stack), so ShardBank admission, LRU
//! eviction, restore, and per-layer telemetry all serve LM sessions
//! unchanged; the token-level API is reached through
//! [`SeqMixer::as_lm_mut`].

use anyhow::{bail, Context, Result};

use super::mixer::{LayerStat, PrefillMode, Scratch, SeqMixer};
use super::quant::QuantTensor;
use super::snapshot;
use super::stack::{init_matrix, mixer_seed, LayerStack, StackConfig};
use crate::util::rng::Rng;

/// Vocabulary token id. u32 everywhere: prompts, histories, outputs.
pub type TokenId = u32;

/// Shape of an [`LmModel`]: a vocabulary over a full model stack.
#[derive(Debug, Clone)]
pub struct LmConfig {
    pub vocab: usize,
    pub stack: StackConfig,
}

impl LmConfig {
    pub fn new(vocab: usize, stack: StackConfig) -> LmConfig {
        LmConfig { vocab, stack }
    }

    pub fn validate(&self) -> Result<()> {
        self.stack.validate()?;
        if self.vocab < 2 {
            bail!("an LM needs a vocabulary of at least 2 tokens (got {})", self.vocab);
        }
        // far above any servable per-session table (sessions own their
        // weights in this design), while bounding what a corrupt-but-
        // in-bounds snapshot can make a restore allocate
        if self.vocab.saturating_mul(self.stack.d_model) > (1 << 24) {
            bail!(
                "embedding table {} x {} exceeds the 2^24-element cap",
                self.vocab,
                self.stack.d_model
            );
        }
        Ok(())
    }
}

/// Per-session generation state: the sampling RNG mid-stream, the
/// repetition-penalty history ring, and the produced-token count. Lives
/// inside the model (not the scheduler) precisely so it rides the `"lm"`
/// snapshot frame through eviction — sampler *parameters* (temperature,
/// top-k, ...) are request config and stay with the engine job.
#[derive(Debug, Clone)]
pub struct GenCore {
    pub rng: Rng,
    /// unordered recent-token ring, capacity `cap` (0 disables history)
    history: Vec<TokenId>,
    /// next overwrite position once the ring is full
    head: usize,
    cap: usize,
    /// tokens sampled so far in this generation
    pub produced: usize,
}

impl GenCore {
    pub fn new(seed: u64, history_cap: usize) -> GenCore {
        GenCore { rng: Rng::new(seed), history: Vec::new(), head: 0, cap: history_cap, produced: 0 }
    }

    /// Record one sampled token into the ring and the produced count.
    pub fn push(&mut self, tok: TokenId) {
        self.produced += 1;
        if self.cap == 0 {
            return;
        }
        if self.history.len() < self.cap {
            self.history.push(tok);
        } else {
            self.history[self.head] = tok;
            self.head = (self.head + 1) % self.cap;
        }
    }

    /// The retained recent tokens (unordered — the repetition penalty is
    /// order-blind).
    pub fn recent(&self) -> &[TokenId] {
        &self.history
    }

    /// Borrow the history and the RNG at once — the shape the sampler
    /// needs (`next_token(history, logits, rng)`) without fighting the
    /// borrow checker over one struct.
    pub fn split(&mut self) -> (&[TokenId], &mut Rng) {
        (&self.history, &mut self.rng)
    }

    fn state_bytes(&self) -> usize {
        32 + self.history.len() * 4 + 3 * 8
    }

    fn save(&self, w: &mut snapshot::Writer) {
        for word in self.rng.state() {
            w.u64(word);
        }
        w.usize(self.cap);
        w.usize(self.head);
        w.usize(self.produced);
        w.usize(self.history.len());
        for &t in &self.history {
            w.u32(t);
        }
    }

    fn load(r: &mut snapshot::Reader<'_>) -> Result<GenCore> {
        let mut state = [0u64; 4];
        for word in &mut state {
            *word = r.u64()?;
        }
        let cap = r.usize()?;
        let head = r.usize()?;
        let produced = r.usize()?;
        let hlen = r.usize()?;
        anyhow::ensure!(
            cap <= (1 << 20) && hlen <= cap && (head == 0 || head < cap),
            "lm snapshot has an implausible generation ring (cap={cap} len={hlen} head={head})"
        );
        let mut history = Vec::with_capacity(hlen);
        for _ in 0..hlen {
            history.push(r.u32()?);
        }
        Ok(GenCore { rng: Rng::from_state(state), history, head, cap, produced })
    }
}

/// A token-in, logits-out language model: embedding table + [`LayerStack`]
/// + tied unembedding, plus the optional in-flight [`GenCore`].
pub struct LmModel {
    cfg: LmConfig,
    init_seed: u64,
    /// `[vocab, d_model]` row-major — used for both embed and unembed.
    /// Stored in the stack's quant format (it is by far the largest cold
    /// tensor in an LM session); logits come out of the fused
    /// dequant-matvec with f32 accumulation.
    embed: QuantTensor,
    stack: LayerStack,
    gen: Option<GenCore>,
    /// prompt-slice activation staging, `[len, d_model]` (workspace, not
    /// state — grown on first use, never serialized)
    ws_x: Vec<f32>,
    ws_out: Vec<f32>,
    /// single-token stack output row, `[d_model]`
    ws_row: Vec<f32>,
    /// single-token dequantized embedding row, `[d_model]`
    ws_emb: Vec<f32>,
}

/// Embedding-table seed: derived through [`mixer_seed`] at a layer index
/// no real stack can occupy (layers are capped at 4096), so it never
/// collides with a per-(layer, head) mixer or weight seed.
fn embed_seed(init_seed: u64) -> u64 {
    mixer_seed(init_seed, 1 << 20, 0)
}

fn grow(buf: &mut Vec<f32>, n: usize) -> &mut [f32] {
    if buf.len() < n {
        buf.resize(n, 0.0);
    }
    &mut buf[..n]
}

impl LmModel {
    /// Build with deterministic seeded weights (embedding and stack).
    /// Panics on an invalid config — validate with [`LmConfig::validate`]
    /// first when the shape comes from user input.
    pub fn new(cfg: LmConfig, init_seed: u64) -> LmModel {
        cfg.validate().expect("invalid lm config");
        let d = cfg.stack.d_model;
        let embed = QuantTensor::from_f32(
            cfg.stack.quant,
            cfg.vocab,
            d,
            &init_matrix(embed_seed(init_seed), cfg.vocab, d),
        );
        let stack = LayerStack::new(cfg.stack.clone(), init_seed);
        LmModel {
            cfg,
            init_seed,
            embed,
            stack,
            gen: None,
            ws_x: Vec::new(),
            ws_out: Vec::new(),
            ws_row: Vec::new(),
            ws_emb: Vec::new(),
        }
    }

    pub fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    pub fn d_model(&self) -> usize {
        self.cfg.stack.d_model
    }

    pub fn cfg(&self) -> &LmConfig {
        &self.cfg
    }

    /// Weight bytes (embedding + stack) — model cost, not session state.
    /// Quantized builds count the stored (compressed) embedding bytes.
    pub fn param_bytes(&self) -> usize {
        self.embed.state_bytes() + self.stack.param_bytes()
    }

    /// Start a generation: fresh sampling RNG and history ring. Called by
    /// the engine exactly once per generate request, after the prompt is
    /// fully ingested (restores must NOT re-begin — the thawed core is
    /// the mid-stream one). The ring cap is clamped to the
    /// snapshot-restore bound so a live core can always freeze and thaw.
    pub fn begin_gen(&mut self, seed: u64, history_cap: usize) {
        self.gen = Some(GenCore::new(seed, history_cap.min(1 << 20)));
    }

    /// Drop the generation state (request complete) so the session's
    /// state bytes and snapshot blob shrink back to the mixer state.
    pub fn end_gen(&mut self) {
        self.gen = None;
    }

    pub fn gen(&self) -> Option<&GenCore> {
        self.gen.as_ref()
    }

    pub fn gen_mut(&mut self) -> Option<&mut GenCore> {
        self.gen.as_mut()
    }

    /// Ingest a prompt slice through the blocked stack prefill and write
    /// the logits of its LAST position into `logits` (`[vocab]`). Slicing
    /// is invisible: any quantum split of the same prompt yields the same
    /// final logits, bit for bit. `toks` must be non-empty.
    pub fn prefill_tokens(&mut self, toks: &[TokenId], logits: &mut [f32], scratch: &mut Scratch) {
        assert!(!toks.is_empty(), "prefill_tokens needs at least one token");
        let LmModel { cfg, embed, stack, ws_x, ws_out, .. } = self;
        let d = cfg.stack.d_model;
        let len = toks.len();
        let x = grow(ws_x, len * d);
        for (i, &t) in toks.iter().enumerate() {
            // sampled/prompt tokens are always < vocab; clamp rather than
            // panic so a corrupt replay degrades deterministically
            let t = (t as usize).min(cfg.vocab - 1);
            embed.read_row(t, &mut x[i * d..(i + 1) * d]);
        }
        let out = grow(ws_out, len * d);
        let x = &ws_x[..len * d];
        stack.process_prefill(x, x, x, out, scratch);
        embed.matvec(&ws_out[(len - 1) * d..len * d], logits);
    }

    /// Absorb one token (write-then-read through the stack) and write the
    /// next-token logits into `logits` (`[vocab]`).
    pub fn step_token(&mut self, tok: TokenId, logits: &mut [f32], scratch: &mut Scratch) {
        let LmModel { cfg, embed, stack, ws_row, ws_emb, .. } = self;
        let d = cfg.stack.d_model;
        let t = (tok as usize).min(cfg.vocab - 1);
        embed.read_row(t, grow(ws_emb, d));
        let row = &ws_emb[..d];
        stack.write(row, row);
        let out = grow(ws_row, d);
        stack.read(row, out, scratch);
        embed.matvec(&ws_row[..d], logits);
    }

    /// Rebuild from a [`snapshot::save`] payload: config + seed are read
    /// back, the embedding is regenerated from the seed, the stack thaws
    /// from its nested container frame, and any in-flight [`GenCore`]
    /// (RNG mid-stream, history ring, produced count) comes back exactly.
    pub fn from_snapshot(r: &mut snapshot::Reader<'_>) -> Result<LmModel> {
        let vocab = r.usize()?;
        let init_seed = r.u64()?;
        let gen = if r.bool()? { Some(GenCore::load(r)?) } else { None };
        let child = r.bytes()?;
        let kind = snapshot::peek_kind(child).context("lm stack frame")?;
        anyhow::ensure!(kind == "stack", "lm snapshot nests a {kind:?} frame, expected a stack");
        // strip the validated frame header, then thaw the concrete stack
        let mut rr = snapshot::Reader::new(child);
        let _ = rr.u32()?; // magic (checked by peek_kind)
        let _ = rr.u16()?; // version
        let _ = rr.str()?; // kind
        let stack = LayerStack::from_snapshot(&mut rr).context("lm stack frame")?;
        anyhow::ensure!(
            rr.remaining() == 0,
            "lm stack frame has {} trailing bytes",
            rr.remaining()
        );
        let cfg = LmConfig::new(vocab, stack.cfg().clone());
        // the embedding bound BEFORE the table is regenerated — a corrupt
        // vocab must err cleanly, never demand a wild allocation
        cfg.validate()?;
        // regenerated from the seed, then requantized into the stack's
        // quant mode — deterministic, so the refreeze stays byte-equal
        let embed = QuantTensor::from_f32(
            cfg.stack.quant,
            vocab,
            cfg.stack.d_model,
            &init_matrix(embed_seed(init_seed), vocab, cfg.stack.d_model),
        );
        Ok(LmModel {
            cfg,
            init_seed,
            embed,
            stack,
            gen,
            ws_x: Vec::new(),
            ws_out: Vec::new(),
            ws_row: Vec::new(),
            ws_emb: Vec::new(),
        })
    }
}

impl SeqMixer for LmModel {
    fn kind_name(&self) -> &'static str {
        "lm"
    }

    fn d_in(&self) -> usize {
        self.stack.d_in()
    }

    fn d_out(&self) -> usize {
        self.stack.d_out()
    }

    fn tokens(&self) -> usize {
        self.stack.tokens()
    }

    /// Dynamic state only: the stack's mixer state plus the in-flight
    /// generation core. The embedding is f(seed) — model cost
    /// ([`LmModel::param_bytes`]), not session state.
    fn state_bytes(&self) -> usize {
        self.stack.state_bytes() + self.gen.as_ref().map_or(0, |g| g.state_bytes())
    }

    fn update_bytes_per_chunk(&self, l: usize) -> usize {
        self.stack.update_bytes_per_chunk(l)
    }

    fn write(&mut self, k: &[f32], v: &[f32]) {
        self.stack.write(k, v);
    }

    fn read(&self, q: &[f32], out: &mut [f32], scratch: &mut Scratch) {
        self.stack.read(q, out, scratch);
    }

    fn process_chunk(
        &mut self,
        queries: &[f32],
        keys: &[f32],
        values: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        self.stack.process_chunk(queries, keys, values, out, scratch);
    }

    fn process_prefill(
        &mut self,
        queries: &[f32],
        keys: &[f32],
        values: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        self.stack.process_prefill(queries, keys, values, out, scratch);
    }

    fn set_prefill_mode(&mut self, mode: PrefillMode) {
        self.stack.set_prefill_mode(mode);
    }

    fn prefill_writes(&mut self, keys: &[f32], values: &[f32], scratch: &mut Scratch) {
        self.stack.prefill_writes(keys, values, scratch);
    }

    fn flush(&mut self) {
        self.stack.flush();
    }

    fn snapshot(&self, w: &mut snapshot::Writer) {
        w.usize(self.cfg.vocab);
        w.u64(self.init_seed);
        match &self.gen {
            Some(g) => {
                w.bool(true);
                g.save(w);
            }
            None => w.bool(false),
        }
        w.bytes(&snapshot::save(&self.stack));
    }

    fn layer_stats(&self) -> Vec<LayerStat> {
        self.stack.layer_stats()
    }

    fn as_lm_mut(&mut self) -> Option<&mut LmModel> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ovqcore::memstate::MixerKind;
    use crate::ovqcore::quant::QuantMode;

    fn small_cfg() -> LmConfig {
        LmConfig::new(
            24,
            StackConfig::hybrid(
                8,
                16,
                2,
                4,
                8,
                vec![MixerKind::Ovq { n_max: 16 }, MixerKind::SlidingWindow { window: 12 }],
            ),
        )
    }

    fn toks(seed: u64, n: usize, vocab: usize) -> Vec<TokenId> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.below(vocab as u64) as TokenId).collect()
    }

    #[test]
    fn config_validation() {
        assert!(small_cfg().validate().is_ok());
        let mut c = small_cfg();
        c.vocab = 1;
        assert!(c.validate().is_err(), "vocab of 1 cannot be sampled");
        let mut c = small_cfg();
        c.vocab = 1 << 30;
        assert!(c.validate().is_err(), "embedding cap");
    }

    #[test]
    fn logits_are_seed_deterministic() {
        let prompt = toks(1, 13, 24);
        let mut logits_a = vec![0.0f32; 24];
        let mut logits_b = vec![0.0f32; 24];
        let mut logits_c = vec![0.0f32; 24];
        let mut scratch = Scratch::new();
        LmModel::new(small_cfg(), 7).prefill_tokens(&prompt, &mut logits_a, &mut scratch);
        LmModel::new(small_cfg(), 7).prefill_tokens(&prompt, &mut logits_b, &mut scratch);
        LmModel::new(small_cfg(), 8).prefill_tokens(&prompt, &mut logits_c, &mut scratch);
        assert_eq!(logits_a, logits_b, "same seed must reproduce the same model");
        assert_ne!(logits_a, logits_c, "different seeds must differ");
        assert!(logits_a.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn chunked_prefill_equals_token_steps_bitwise() {
        // the golden generation contract (c): the same prompt through
        // (a) one prefill call, (b) misaligned quantum slices, and
        // (c) token-at-a-time step_token must yield the same final
        // logits, bit for bit
        let prompt = toks(2, 29, 24); // crosses chunk boundaries (chunk=8)
        let vocab = 24;
        let mut scratch = Scratch::new();

        let mut whole = LmModel::new(small_cfg(), 3);
        let mut l_whole = vec![0.0f32; vocab];
        whole.prefill_tokens(&prompt, &mut l_whole, &mut scratch);

        let mut sliced = LmModel::new(small_cfg(), 3);
        let mut l_sliced = vec![0.0f32; vocab];
        let mut i = 0;
        while i < prompt.len() {
            let len = 5.min(prompt.len() - i); // 5 is coprime to chunk=8
            sliced.prefill_tokens(&prompt[i..i + len], &mut l_sliced, &mut scratch);
            i += len;
        }

        let mut stepped = LmModel::new(small_cfg(), 3);
        let mut l_step = vec![0.0f32; vocab];
        for &t in &prompt {
            stepped.step_token(t, &mut l_step, &mut scratch);
        }

        for i in 0..vocab {
            assert_eq!(l_whole[i].to_bits(), l_sliced[i].to_bits(), "sliced diverged at {i}");
            assert_eq!(l_whole[i].to_bits(), l_step[i].to_bits(), "stepped diverged at {i}");
        }
        assert_eq!(whole.tokens(), prompt.len());
        assert_eq!(stepped.tokens(), prompt.len());
    }

    #[test]
    fn gen_core_ring_wraps_and_counts() {
        let mut g = GenCore::new(1, 3);
        for t in 0..5u32 {
            g.push(t);
        }
        assert_eq!(g.produced, 5);
        let mut recent: Vec<u32> = g.recent().to_vec();
        recent.sort_unstable();
        assert_eq!(recent, vec![2, 3, 4], "ring keeps the 3 most recent");
        // cap 0 disables history but still counts
        let mut g0 = GenCore::new(1, 0);
        g0.push(9);
        assert_eq!(g0.produced, 1);
        assert!(g0.recent().is_empty());
    }

    #[test]
    fn snapshot_round_trips_mid_generation_bit_exactly() {
        // freeze a model mid-generation — prompt ingested, RNG advanced,
        // history ring partially wrapped — and thaw: the refreeze must be
        // byte-equal and the continued stream (logits AND rng draws) must
        // match the uninterrupted run exactly
        let vocab = 24;
        let mut scratch = Scratch::new();
        let mut m = LmModel::new(small_cfg(), 5);
        let mut logits = vec![0.0f32; vocab];
        m.prefill_tokens(&toks(4, 11, vocab), &mut logits, &mut scratch);
        m.begin_gen(0xFACE, 4);
        for t in [3u32, 7, 7, 1, 9] {
            m.gen_mut().unwrap().push(t);
        }
        let _ = m.gen_mut().unwrap().rng.next_u64(); // rng mid-stream

        let blob = snapshot::save(&m);
        let mut thawed = snapshot::restore(&blob).expect("lm blob must thaw");
        assert_eq!(thawed.kind_name(), "lm");
        assert_eq!(thawed.tokens(), m.tokens());
        assert_eq!(thawed.state_bytes(), m.state_bytes());
        assert_eq!(snapshot::save(thawed.as_ref()), blob, "lm refreeze differs");

        let t = thawed.as_lm_mut().expect("lm downcast");
        assert_eq!(t.gen().unwrap().produced, 5);
        assert_eq!(t.gen().unwrap().recent(), m.gen().unwrap().recent());
        // continued sampling stream is identical
        for _ in 0..8 {
            assert_eq!(
                t.gen_mut().unwrap().rng.next_u64(),
                m.gen_mut().unwrap().rng.next_u64(),
                "thawed rng diverged"
            );
        }
        // continued decode is identical
        let mut la = vec![0.0f32; vocab];
        let mut lb = vec![0.0f32; vocab];
        m.step_token(3, &mut la, &mut scratch);
        t.step_token(3, &mut lb, &mut scratch);
        assert_eq!(la, lb, "thawed model diverged on the next step");

        // end_gen drops the sampler state from blob and accounting
        m.end_gen();
        assert!(m.gen().is_none());
        let lean = snapshot::save(&m);
        assert!(lean.len() < blob.len());
    }

    #[test]
    fn quantized_lm_runs_shrinks_and_refreezes_bit_exactly() {
        // lossy modes: the model stays usable (finite logits, both decode
        // paths agree bitwise since both read the same stored rows), the
        // param footprint shrinks, and the snapshot refreezes byte-equal
        // (weights regenerate + requantize deterministically from seed)
        let prompt = toks(6, 17, 24);
        let f32_params = LmModel::new(small_cfg(), 11).param_bytes();
        let mut scratch = Scratch::new();
        for quant in [QuantMode::F16, QuantMode::I8] {
            let mut cfg = small_cfg();
            cfg.stack = cfg.stack.with_quant(quant);
            let mut m = LmModel::new(cfg.clone(), 11);
            let mut logits = vec![0.0f32; 24];
            m.prefill_tokens(&prompt, &mut logits, &mut scratch);
            assert!(logits.iter().all(|l| l.is_finite()), "{quant:?}: non-finite logits");
            assert!(
                m.param_bytes() < f32_params,
                "{quant:?}: params did not shrink ({} vs {f32_params})",
                m.param_bytes()
            );

            // token-at-a-time matches prefill under quantization too
            let mut stepped = LmModel::new(cfg, 11);
            let mut l_step = vec![0.0f32; 24];
            for &t in &prompt {
                stepped.step_token(t, &mut l_step, &mut scratch);
            }
            for i in 0..24 {
                assert_eq!(
                    logits[i].to_bits(),
                    l_step[i].to_bits(),
                    "{quant:?}: stepped diverged at {i}"
                );
            }

            m.flush();
            let blob = snapshot::save(&m);
            let thawed = snapshot::restore(&blob).expect("quantized lm blob must thaw");
            assert_eq!(thawed.state_bytes(), m.state_bytes());
            assert_eq!(snapshot::save(thawed.as_ref()), blob, "{quant:?}: refreeze differs");
        }
    }

    #[test]
    fn non_lm_mixers_do_not_downcast() {
        let mut plain = MixerKind::Gdn.build(4, 8, 1);
        assert!(plain.as_lm_mut().is_none());
    }
}
