//! Memory-state accounting — reproduces Fig. 4 (right: kv-cache/state size
//! vs context length) and the §3.4 ΔS-footprint comparison (Fig. 3).
//!
//! All quantities are exact byte counts from the layer definitions; the
//! per-layer/per-head factors use the paper's architecture conventions
//! (state per head, H heads, f32). [`MixerKind::build`] instantiates the
//! live [`SeqMixer`] state machine each kind describes, and the tests
//! cross-check the analytical byte counts against the machines' actual
//! `state_bytes()` — the accounting and the serving path can no longer
//! drift apart.

use anyhow::{bail, Result};

use super::gdn::GdnState;
use super::kvcache::KvCache;
use super::linear_attn::LinearAttnState;
use super::mixer::SeqMixer;
use super::ovq::{OvqConfig, OvqState};
use super::quant::QuantMode;
use super::vq::VqState;
use crate::util::rng::Rng;

/// Memory state of one sequence-mixing layer, bytes, as a function of the
/// context length t.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MixerKind {
    /// full attention: K and V cached for every position
    FullAttention,
    /// sliding window w: K/V for the last w positions
    SlidingWindow { window: usize },
    /// OVQ: D_k, D_v [N_t, d] + counts, N_t = growth(t) -> N
    Ovq { n_max: usize },
    /// VQ (Lingle): static D_k + online D_v + counts (constant N)
    Vq { n: usize },
    /// linear attention / SSD: S [d, d] (+ `z [d]`)
    LinearAttention,
    /// gated delta net: S [d, d]
    Gdn,
}

#[derive(Debug, Clone, Copy)]
pub struct MixerGeom {
    pub heads: usize,
    pub d_head: usize,
}

impl MixerKind {
    /// Stable label matching the live machine's `kind_name()`.
    pub fn name(&self) -> &'static str {
        match *self {
            MixerKind::FullAttention => "kv_cache",
            MixerKind::SlidingWindow { .. } => "sliding_window",
            MixerKind::Ovq { .. } => "ovq",
            MixerKind::Vq { .. } => "vq",
            MixerKind::LinearAttention => "linear_attn",
            MixerKind::Gdn => "gdn",
        }
    }

    /// Parse one mixer-schedule entry — the CLI grammar for hybrid
    /// stacks: `ovq[:N]` (dictionary cap N, default 1024), `vq[:N]`
    /// (static dictionary, default 256), `kv` (full attention),
    /// `kv:winW` (sliding window of W), `lin`, `gdn`.
    pub fn parse(entry: &str) -> Result<MixerKind> {
        let entry = entry.trim();
        let (head, arg) = match entry.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (entry, None),
        };
        let num = |a: Option<&str>, default: usize, what: &str| -> Result<usize> {
            match a {
                None => Ok(default),
                Some(s) => match s.parse::<usize>() {
                    Ok(n) if n > 0 => Ok(n),
                    _ => bail!("mixer entry '{entry}': expected a positive {what}, got '{s}'"),
                },
            }
        };
        match head {
            "ovq" => Ok(MixerKind::Ovq { n_max: num(arg, 1024, "dictionary cap")? }),
            "vq" => Ok(MixerKind::Vq { n: num(arg, 256, "dictionary size")? }),
            "kv" => match arg {
                None => Ok(MixerKind::FullAttention),
                Some(a) => match a.strip_prefix("win") {
                    Some(w) => Ok(MixerKind::SlidingWindow {
                        window: num(Some(w), 0, "window length")?,
                    }),
                    None => bail!("mixer entry '{entry}': kv takes ':win<W>', got ':{a}'"),
                },
            },
            "lin" => Ok(MixerKind::LinearAttention),
            "gdn" => Ok(MixerKind::Gdn),
            other => bail!(
                "unknown mixer '{other}' in schedule entry '{entry}' \
                 (expected ovq[:N] | vq[:N] | kv | kv:winW | lin | gdn)"
            ),
        }
    }

    /// State bytes per layer at context length t (f32 storage).
    pub fn state_bytes(&self, g: MixerGeom, t: usize) -> usize {
        self.state_bytes_quant(g, t, QuantMode::None)
    }

    /// State bytes per layer at context length t with the cold dictionary
    /// tensors held in `quant` storage. Only the dictionary kinds (OVQ,
    /// VQ) have cold tensors; KV caches and the dense recurrent states
    /// are hot (rewritten every token) and stay f32 in every mode.
    pub fn state_bytes_quant(&self, g: MixerGeom, t: usize, quant: QuantMode) -> usize {
        let hd4 = g.heads * g.d_head * 4;
        match *self {
            MixerKind::FullAttention => 2 * t * hd4,
            MixerKind::SlidingWindow { window } => 2 * t.min(window) * hd4,
            MixerKind::Ovq { n_max } => {
                let n_t = super::growth_n_t(t, n_max);
                // D_k + D_v rows in stored format + f32 counts, per head
                g.heads * (2 * n_t * quant.row_bytes(g.d_head) + n_t * 4)
            }
            MixerKind::Vq { n } => g.heads * (2 * n * quant.row_bytes(g.d_head) + n * 4),
            MixerKind::LinearAttention => {
                g.heads * (g.d_head * g.d_head + g.d_head) * 4
            }
            MixerKind::Gdn => g.heads * g.d_head * g.d_head * 4,
        }
    }

    /// Bytes of the per-chunk state-update tensor ΔS (chunk length l) in
    /// the standard chunk-parallel implementation — the §3.4 comparison.
    pub fn update_bytes(&self, g: MixerGeom, l: usize) -> usize {
        let hd4 = g.heads * g.d_head * 4;
        match *self {
            // appending l keys+values
            MixerKind::FullAttention | MixerKind::SlidingWindow { .. } => 2 * l * hd4,
            // sparse: each token touches one row of D_k and one of D_v
            // (ΔS in R^{L x 2 x d}) — INDEPENDENT of N
            MixerKind::Ovq { .. } | MixerKind::Vq { .. } => 2 * l * hd4,
            // dense: each token materializes a full [d_k, d_v] update
            MixerKind::LinearAttention | MixerKind::Gdn => {
                l * g.heads * g.d_head * g.d_head * 4
            }
        }
    }

    /// Instantiate the single-head live state machine this kind accounts
    /// for, through the unified [`SeqMixer`] interface. `chunk` is the OVQ
    /// chunk length; `seed` seeds the VQ baseline's pretrained dictionary.
    pub fn build(&self, d_head: usize, chunk: usize, seed: u64) -> Box<dyn SeqMixer> {
        self.build_quant(d_head, chunk, seed, QuantMode::None)
    }

    /// [`MixerKind::build`] with the cold dictionary tensors held in
    /// `quant` storage (a no-op for the non-dictionary kinds).
    pub fn build_quant(
        &self,
        d_head: usize,
        chunk: usize,
        seed: u64,
        quant: QuantMode,
    ) -> Box<dyn SeqMixer> {
        match *self {
            MixerKind::FullAttention => Box::new(KvCache::new(d_head)),
            MixerKind::SlidingWindow { window } => {
                Box::new(KvCache::with_window(d_head, window))
            }
            MixerKind::Ovq { n_max } => {
                let mut cfg = OvqConfig::new(d_head, n_max, chunk);
                cfg.quant = quant;
                Box::new(OvqState::new(cfg))
            }
            MixerKind::Vq { n } => {
                // unit-norm pretrained key dictionary (the Lingle setup)
                let mut rng = Rng::new(seed);
                let mut dk = vec![0.0f32; n * d_head];
                for row in dk.chunks_mut(d_head) {
                    let mut norm = 0.0f32;
                    for x in row.iter_mut() {
                        *x = rng.normal() as f32;
                        norm += *x * *x;
                    }
                    let norm = norm.sqrt().max(1e-12);
                    row.iter_mut().for_each(|x| *x /= norm);
                }
                Box::new(VqState::with_quant(d_head, dk, quant))
            }
            MixerKind::LinearAttention => Box::new(LinearAttnState::new(d_head, d_head)),
            MixerKind::Gdn => Box::new(GdnState::new(d_head)),
        }
    }
}

/// Parse a per-layer mixer schedule: comma-separated [`MixerKind::parse`]
/// entries, cycled to fill `layers` (so `ovq:8,kv:win256` on a 4-layer
/// stack alternates ovq / windowed-kv / ovq / windowed-kv).
pub fn parse_schedule(schedule: &str, layers: usize) -> Result<Vec<MixerKind>> {
    anyhow::ensure!(layers > 0, "a stack needs at least one layer (--layers)");
    let entries: Vec<MixerKind> = schedule
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(MixerKind::parse)
        .collect::<Result<_>>()?;
    anyhow::ensure!(
        !entries.is_empty(),
        "empty mixer schedule '{schedule}' (expected e.g. 'ovq:1024' or 'ovq:8,kv:win256')"
    );
    Ok((0..layers).map(|l| entries[l % entries.len()]).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    const G: MixerGeom = MixerGeom { heads: 4, d_head: 32 };

    #[test]
    fn full_attention_grows_linearly() {
        let a = MixerKind::FullAttention.state_bytes(G, 1000);
        let b = MixerKind::FullAttention.state_bytes(G, 2000);
        assert_eq!(b, 2 * a);
    }

    #[test]
    fn ovq_state_plateaus() {
        let k = MixerKind::Ovq { n_max: 256 };
        let early = k.state_bytes(G, 256);
        let late = k.state_bytes(G, 1 << 20);
        let cap = k.state_bytes(G, usize::MAX / 2);
        assert!(early < late);
        assert!(late <= cap);
        // the asymptote approaches (but never exceeds) the N_max dictionary
        let bound = 2 * 256 * 4 * 32 * 4 + 256 * 4 * 4;
        assert!(cap <= bound && cap >= bound * 9 / 10, "cap {cap} vs bound {bound}");
    }

    #[test]
    fn ovq_update_independent_of_n() {
        let small = MixerKind::Ovq { n_max: 128 };
        let big = MixerKind::Ovq { n_max: 1 << 16 };
        assert_eq!(small.update_bytes(G, 32), big.update_bytes(G, 32));
    }

    #[test]
    fn linear_attention_update_grows_with_d() {
        let g2 = MixerGeom { heads: 4, d_head: 64 };
        assert!(
            MixerKind::LinearAttention.update_bytes(g2, 32)
                > MixerKind::LinearAttention.update_bytes(G, 32)
        );
        // and exceeds OVQ's for any realistic d
        assert!(
            MixerKind::LinearAttention.update_bytes(G, 32)
                > MixerKind::Ovq { n_max: 4096 }.update_bytes(G, 32)
        );
    }

    #[test]
    fn sliding_window_saturates() {
        let k = MixerKind::SlidingWindow { window: 128 };
        assert_eq!(k.state_bytes(G, 128), k.state_bytes(G, 10_000));
    }

    #[test]
    fn schedule_parsing_round_trips_and_cycles() {
        assert_eq!(MixerKind::parse("ovq:8").unwrap(), MixerKind::Ovq { n_max: 8 });
        assert_eq!(MixerKind::parse("ovq").unwrap(), MixerKind::Ovq { n_max: 1024 });
        assert_eq!(MixerKind::parse("vq:64").unwrap(), MixerKind::Vq { n: 64 });
        assert_eq!(MixerKind::parse("kv").unwrap(), MixerKind::FullAttention);
        assert_eq!(
            MixerKind::parse("kv:win256").unwrap(),
            MixerKind::SlidingWindow { window: 256 }
        );
        assert_eq!(MixerKind::parse("lin").unwrap(), MixerKind::LinearAttention);
        assert_eq!(MixerKind::parse("gdn").unwrap(), MixerKind::Gdn);
        for bad in ["", "ovq:0", "ovq:x", "kv:256", "kv:win0", "mamba"] {
            assert!(MixerKind::parse(bad).is_err(), "'{bad}' must not parse");
        }

        let sched = parse_schedule("ovq:8,kv:win256", 4).unwrap();
        assert_eq!(
            sched,
            vec![
                MixerKind::Ovq { n_max: 8 },
                MixerKind::SlidingWindow { window: 256 },
                MixerKind::Ovq { n_max: 8 },
                MixerKind::SlidingWindow { window: 256 },
            ]
        );
        assert_eq!(parse_schedule("gdn", 3).unwrap(), vec![MixerKind::Gdn; 3]);
        assert!(parse_schedule("", 2).is_err());
        assert!(parse_schedule("ovq", 0).is_err());
    }

    #[test]
    fn kind_names_match_live_machines() {
        let kinds = [
            MixerKind::FullAttention,
            MixerKind::SlidingWindow { window: 8 },
            MixerKind::Ovq { n_max: 16 },
            MixerKind::Vq { n: 8 },
            MixerKind::LinearAttention,
            MixerKind::Gdn,
        ];
        for kind in kinds {
            assert_eq!(kind.name(), kind.build(4, 8, 1).kind_name(), "{kind:?}");
        }
    }

    #[test]
    fn accounting_matches_live_mixers() {
        // the analytical per-head byte counts must equal the live state
        // machines' state_bytes() after absorbing t tokens — the invariant
        // that ties this accounting module to the serving path.
        use crate::util::rng::Rng;
        let (d, chunk, t) = (16usize, 32usize, 256usize);
        let g1 = MixerGeom { heads: 1, d_head: d };
        let kinds = [
            MixerKind::FullAttention,
            MixerKind::SlidingWindow { window: 64 },
            MixerKind::Ovq { n_max: 64 },
            MixerKind::Vq { n: 32 },
            MixerKind::LinearAttention,
            MixerKind::Gdn,
        ];
        let mut rng = Rng::new(11);
        for kind in kinds {
            let mut m = kind.build(d, chunk, 7);
            for _ in 0..t {
                let k: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                m.write(&k, &v);
            }
            m.flush(); // merge any buffered OVQ chunk tail
            assert_eq!(
                m.state_bytes(),
                kind.state_bytes(g1, t),
                "accounting drift for {:?} ({})",
                kind,
                m.kind_name()
            );
        }
    }

    #[test]
    fn quant_accounting_matches_live_mixers_and_i8_shrinks() {
        // same invariant, per quant mode: the analytic state_bytes_quant
        // formula must equal the live machine's state_bytes() EXACTLY for
        // every storage mode — and the i8 OVQ dictionary must come in at
        // least 3.5x smaller than f32 (the acceptance criterion; at
        // d_head=64 the exact ratio is 516/140 ≈ 3.69x).
        use crate::util::rng::Rng;
        let (d, chunk, t) = (64usize, 32usize, 512usize);
        let g1 = MixerGeom { heads: 1, d_head: d };
        let kinds = [MixerKind::Ovq { n_max: 128 }, MixerKind::Vq { n: 48 }];
        let modes = [QuantMode::None, QuantMode::F16, QuantMode::I8];
        for kind in kinds {
            let mut per_mode = Vec::new();
            for quant in modes {
                let mut rng = Rng::new(13);
                let mut m = kind.build_quant(d, chunk, 7, quant);
                for _ in 0..t {
                    let k: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                    let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                    m.write(&k, &v);
                }
                m.flush();
                assert_eq!(
                    m.state_bytes(),
                    kind.state_bytes_quant(g1, t, quant),
                    "quant accounting drift for {kind:?} / {quant:?}"
                );
                per_mode.push(m.state_bytes());
            }
            let shrink = per_mode[0] as f64 / per_mode[2] as f64;
            assert!(shrink >= 3.5, "{kind:?}: i8 shrink {shrink:.2}x < 3.5x");
            assert!(per_mode[1] < per_mode[0], "{kind:?}: f16 must shrink");
        }
        // the non-dictionary kinds are quant-invariant by definition
        for kind in [MixerKind::FullAttention, MixerKind::LinearAttention, MixerKind::Gdn] {
            for quant in modes {
                assert_eq!(
                    kind.state_bytes_quant(g1, 256, quant),
                    kind.state_bytes(g1, 256),
                    "{kind:?} must not depend on quant mode"
                );
            }
        }
    }
}
