//! Memory-state accounting — reproduces Fig. 4 (right: kv-cache/state size
//! vs context length) and the §3.4 ΔS-footprint comparison (Fig. 3).
//!
//! All quantities are exact byte counts from the layer definitions; the
//! per-layer/per-head factors use the paper's architecture conventions
//! (state per head, H heads, f32).

/// Memory state of one sequence-mixing layer, bytes, as a function of the
/// context length t.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MixerKind {
    /// full attention: K and V cached for every position
    FullAttention,
    /// sliding window w: K/V for the last w positions
    SlidingWindow { window: usize },
    /// OVQ: D_k, D_v [N_t, d] + counts, N_t = growth(t) -> N
    Ovq { n_max: usize },
    /// VQ (Lingle): static D_k + online D_v + counts (constant N)
    Vq { n: usize },
    /// linear attention / SSD: S [d, d] (+ z [d])
    LinearAttention,
    /// gated delta net: S [d, d]
    Gdn,
}

#[derive(Debug, Clone, Copy)]
pub struct MixerGeom {
    pub heads: usize,
    pub d_head: usize,
}

impl MixerKind {
    /// State bytes per layer at context length t.
    pub fn state_bytes(&self, g: MixerGeom, t: usize) -> usize {
        let hd4 = g.heads * g.d_head * 4;
        match *self {
            MixerKind::FullAttention => 2 * t * hd4,
            MixerKind::SlidingWindow { window } => 2 * t.min(window) * hd4,
            MixerKind::Ovq { n_max } => {
                let n_t = super::growth_n_t(t, n_max);
                2 * n_t * hd4 + n_t * g.heads * 4 // D_k + D_v + counts
            }
            MixerKind::Vq { n } => 2 * n * hd4 + n * g.heads * 4,
            MixerKind::LinearAttention => {
                g.heads * (g.d_head * g.d_head + g.d_head) * 4
            }
            MixerKind::Gdn => g.heads * g.d_head * g.d_head * 4,
        }
    }

    /// Bytes of the per-chunk state-update tensor ΔS (chunk length l) in
    /// the standard chunk-parallel implementation — the §3.4 comparison.
    pub fn update_bytes(&self, g: MixerGeom, l: usize) -> usize {
        let hd4 = g.heads * g.d_head * 4;
        match *self {
            // appending l keys+values
            MixerKind::FullAttention | MixerKind::SlidingWindow { .. } => 2 * l * hd4,
            // sparse: each token touches one row of D_k and one of D_v
            // (ΔS in R^{L x 2 x d}) — INDEPENDENT of N
            MixerKind::Ovq { .. } | MixerKind::Vq { .. } => 2 * l * hd4,
            // dense: each token materializes a full [d_k, d_v] update
            MixerKind::LinearAttention | MixerKind::Gdn => {
                l * g.heads * g.d_head * g.d_head * 4
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const G: MixerGeom = MixerGeom { heads: 4, d_head: 32 };

    #[test]
    fn full_attention_grows_linearly() {
        let a = MixerKind::FullAttention.state_bytes(G, 1000);
        let b = MixerKind::FullAttention.state_bytes(G, 2000);
        assert_eq!(b, 2 * a);
    }

    #[test]
    fn ovq_state_plateaus() {
        let k = MixerKind::Ovq { n_max: 256 };
        let early = k.state_bytes(G, 256);
        let late = k.state_bytes(G, 1 << 20);
        let cap = k.state_bytes(G, usize::MAX / 2);
        assert!(early < late);
        assert!(late <= cap);
        // the asymptote approaches (but never exceeds) the N_max dictionary
        let bound = 2 * 256 * 4 * 32 * 4 + 256 * 4 * 4;
        assert!(cap <= bound && cap >= bound * 9 / 10, "cap {cap} vs bound {bound}");
    }

    #[test]
    fn ovq_update_independent_of_n() {
        let small = MixerKind::Ovq { n_max: 128 };
        let big = MixerKind::Ovq { n_max: 1 << 16 };
        assert_eq!(small.update_bytes(G, 32), big.update_bytes(G, 32));
    }

    #[test]
    fn linear_attention_update_grows_with_d() {
        let g2 = MixerGeom { heads: 4, d_head: 64 };
        assert!(
            MixerKind::LinearAttention.update_bytes(g2, 32)
                > MixerKind::LinearAttention.update_bytes(G, 32)
        );
        // and exceeds OVQ's for any realistic d
        assert!(
            MixerKind::LinearAttention.update_bytes(G, 32)
                > MixerKind::Ovq { n_max: 4096 }.update_bytes(G, 32)
        );
    }

    #[test]
    fn sliding_window_saturates() {
        let k = MixerKind::SlidingWindow { window: 128 };
        assert_eq!(k.state_bytes(G, 128), k.state_bytes(G, 10_000));
    }
}
