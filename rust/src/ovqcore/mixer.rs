//! The unified sequence-mixer abstraction. Every state machine in this
//! module — OVQ, VQ, linear attention, gated delta net, the exact KV
//! cache — implements [`SeqMixer`], so the serving engine
//! ([`super::bank::MixerBank`]), the memory-accounting experiments
//! ([`super::memstate`]) and the benches all drive one interface instead
//! of five ad-hoc ones.
//!
//! Semantics: a mixer absorbs a causal stream of (k, v) rows and answers
//! queries against everything absorbed so far. The canonical per-token
//! order is write-then-read — the output for token t attends positions
//! <= t, matching softmax attention and the paper's eq. 15 (where the
//! in-chunk prefix is visible up to and including the current item).
//! [`SeqMixer::process_chunk`] must be equivalent to that token loop:
//! rust/tests/golden.rs holds the chunked-vs-streaming property test.

use super::kernels;
use super::quant::QuantTensor;

/// Reusable scratch for [`SeqMixer::read`]/[`SeqMixer::process_chunk`].
/// Callers allocate one and pass it to every call, eliminating the
/// per-query logits `Vec` the seed implementations allocated.
#[derive(Debug, Default, Clone)]
pub struct Scratch {
    /// logit buffer (dictionary slots + chunk prefix)
    pub logits: Vec<f32>,
    /// softmax weight buffer, same length as `logits`
    pub weights: Vec<f32>,
    /// general f32 temporary (nearest-neighbour sims, head staging, ...)
    pub buf: Vec<f32>,
    /// index temporary (chunk assignments)
    pub idx: Vec<usize>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Grow (never shrink) `logits` and `weights` to hold `n` entries and
    /// return them zero-initialized-free — callers overwrite every slot
    /// they read.
    pub fn logit_buffers(&mut self, n: usize) -> (&mut [f32], &mut [f32]) {
        if self.logits.len() < n {
            self.logits.resize(n, 0.0);
        }
        if self.weights.len() < n {
            self.weights.resize(n, 0.0);
        }
        (&mut self.logits[..n], &mut self.weights[..n])
    }

    pub fn f32_buf(&mut self, n: usize) -> &mut [f32] {
        if self.buf.len() < n {
            self.buf.resize(n, 0.0);
        }
        &mut self.buf[..n]
    }

    pub fn idx_buf(&mut self, n: usize) -> &mut [usize] {
        if self.idx.len() < n {
            self.idx.resize(n, 0);
        }
        &mut self.idx[..n]
    }
}

/// How [`SeqMixer::process_prefill`] treats a long prompt slice.
///
/// `Exact` (the default) is the bit-exact serial token order the golden
/// tests pin — every mixer, every backend. `Chunkwise { chunk }` opts a
/// dense-state scan mixer (GDN, linear attention) into its
/// chunkwise-parallel scan form: the slice is cut into `chunk`-token
/// blocks, intra-block terms come from tiled [`kernels::matmul_rows`]
/// sweeps, and block states compose left-to-right. That reassociates the
/// FP accumulation, so chunkwise outputs are held to the documented
/// tolerance (`|par - serial| <= eps * (1 + |serial|)`, the simd-test
/// idiom) instead of bit-equality — which is why it is opt-in (CLI
/// `--prefill-tolerance`). Mixers without a chunkwise form ignore the
/// mode entirely; the mode is runtime policy, never serialized into
/// snapshots (a blob thaws in `Exact` and the serving layer re-applies
/// its configured mode).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PrefillMode {
    /// Bit-exact serial token order (default; goldens pin it).
    #[default]
    Exact,
    /// Chunkwise-parallel scan form with `chunk`-token blocks
    /// (tolerance-mode; documented FP reassociation).
    Chunkwise {
        /// block length C; clamped to >= 1 by consumers
        chunk: usize,
    },
}

/// One row of a mixer's per-layer telemetry split. Plain mixers are their
/// own single layer; [`super::stack::LayerStack`] reports one row per
/// transformer layer so the serving engine can show where state bytes and
/// busy time actually live inside a deep model.
#[derive(Debug, Clone, Default)]
pub struct LayerStat {
    /// mixer kind serving this layer ("ovq", "sliding_window", ...)
    pub kind: String,
    /// live mixer state bytes of this layer (all heads)
    pub state_bytes: usize,
    /// processing time spent inside this layer, nanoseconds
    pub busy_ns: f64,
    /// tokens this layer has absorbed
    pub tokens: usize,
}

impl LayerStat {
    /// Fold another stat into this one (telemetry aggregation across
    /// sessions and shards; the kind label of the first contributor wins).
    pub fn merge(&mut self, other: &LayerStat) {
        if self.kind.is_empty() {
            self.kind = other.kind.clone();
        }
        self.state_bytes += other.state_bytes;
        self.busy_ns += other.busy_ns;
        self.tokens += other.tokens;
    }
}

/// Element-wise merge of per-layer stat vectors (pads to the longer one).
pub fn merge_layer_stats(acc: &mut Vec<LayerStat>, add: &[LayerStat]) {
    if acc.len() < add.len() {
        acc.resize(add.len(), LayerStat::default());
    }
    for (a, b) in acc.iter_mut().zip(add) {
        a.merge(b);
    }
}

/// Print the standard per-layer telemetry rows shared by the engine and
/// serve reports. `available` is the worker time the busy shares are
/// measured against (wall clock x shard count, so a saturated layer
/// reads 100% regardless of thread count). No-op for single-row
/// (bare-mixer) splits — there is no split to show.
pub fn print_layer_split(layers: &[LayerStat], available: std::time::Duration) {
    if layers.len() <= 1 {
        return;
    }
    let avail_ns = (available.as_nanos() as f64).max(1.0);
    for (l, st) in layers.iter().enumerate() {
        let tps = if st.busy_ns > 0.0 { st.tokens as f64 / (st.busy_ns / 1e9) } else { 0.0 };
        println!(
            "  layer {:>2} [{:>14}]: state {:>9.1} KiB  occupancy {:>5.1}%  \
             {:>9.0} tok/s-in-layer",
            l,
            st.kind,
            st.state_bytes as f64 / 1024.0,
            100.0 * st.busy_ns / avail_ns,
            tps,
        );
    }
}

/// A causal sequence mixer: constant-or-growing state, token writes,
/// query reads, chunked processing. `Send` is required so banks of mixers
/// can move across serving threads.
pub trait SeqMixer: Send {
    /// Short stable identifier ("ovq", "kv_cache", ...) for reports.
    fn kind_name(&self) -> &'static str;

    /// Query/key dimensionality.
    fn d_in(&self) -> usize;

    /// Value/output dimensionality (== `d_in` for all paper mixers except
    /// linear attention, which is configured with separate dk/dv).
    fn d_out(&self) -> usize;

    /// Tokens absorbed so far (including any buffered, not-yet-merged
    /// chunk tail).
    fn tokens(&self) -> usize;

    /// Exact bytes of live mixer state (dictionaries, fast weights,
    /// caches, pending buffers).
    fn state_bytes(&self) -> usize;

    /// Bytes of the per-chunk state-update tensor ΔS materialized by the
    /// standard chunk-parallel implementation for a chunk of length `l` —
    /// the paper's §3.4 comparison axis.
    fn update_bytes_per_chunk(&self, l: usize) -> usize;

    /// Absorb one (k, v) row.
    fn write(&mut self, k: &[f32], v: &[f32]);

    /// Answer one query against everything written so far.
    fn read(&self, q: &[f32], out: &mut [f32], scratch: &mut Scratch);

    /// Process `len` tokens: for each i, write (k_i, v_i) then read q_i
    /// into `out[i]`. `queries`/`keys` are `[len, d_in]`, `values`/`out`
    /// are `[len, d_out]`, all row-major. Implementations may override
    /// with an internally-batched path (e.g. a shared [len, N] logits
    /// matmul — none do yet) but must stay equivalent to the token loop.
    fn process_chunk(
        &mut self,
        queries: &[f32],
        keys: &[f32],
        values: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        let di = self.d_in();
        let dv = self.d_out();
        let len = keys.len() / di;
        debug_assert_eq!(queries.len(), len * di);
        debug_assert_eq!(values.len(), len * dv);
        debug_assert_eq!(out.len(), len * dv);
        for i in 0..len {
            self.write(&keys[i * di..(i + 1) * di], &values[i * dv..(i + 1) * dv]);
            let (head, tail) = out.split_at_mut(i * dv);
            let _ = head;
            self.read(&queries[i * di..(i + 1) * di], &mut tail[..dv], scratch);
        }
    }

    /// Ingest `len` prompt tokens in one call — the prefill path. The
    /// semantics are IDENTICAL to [`SeqMixer::process_chunk`] (write
    /// (k_i, v_i), then read q_i into `out[i]`, for each i in order), and
    /// implementations MUST stay bit-identical to that serial token loop:
    /// rust/tests/golden.rs compares the two paths with `to_bits`
    /// equality for every mixer. What overrides buy is batching — staging
    /// whole segments at once and amortizing the dictionary sweeps
    /// (tiled [`kernels::matmul_rows`] / [`kernels::nearest_rows`])
    /// across the block instead of dispatching per token.
    fn process_prefill(
        &mut self,
        queries: &[f32],
        keys: &[f32],
        values: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        self.process_chunk(queries, keys, values, out, scratch);
    }

    /// Select the prefill policy for subsequent [`SeqMixer::process_prefill`] /
    /// [`SeqMixer::prefill_writes`] calls. Default no-op: mixers without a
    /// chunkwise form (OVQ, VQ, KV cache — their blocked prefills are
    /// already bit-exact) ignore the mode. GDN and linear attention store
    /// it; [`super::stack::LayerStack`] / [`super::lm::LmModel`] forward it
    /// to every head. The mode is runtime policy — never serialized, so
    /// snapshot blobs stay byte-stable and the serving layer re-applies it
    /// after every admit/restore.
    fn set_prefill_mode(&mut self, _mode: PrefillMode) {}

    /// Advance the mixer state over `len` (k, v) rows WITHOUT producing
    /// outputs — the owner-side half of fanned-out prefill, where another
    /// worker computes the outputs from a snapshot of the pre-advance
    /// state. The post-call state MUST be bit-identical to what
    /// [`SeqMixer::process_prefill`] over the same slice leaves behind
    /// (writes never depend on reads, so the default serial write loop
    /// satisfies this for every mixer). Overrides only buy speed: skipping
    /// the read half is exactly the fan-out win — e.g. OVQ skips the
    /// per-token softmax reads, KV skips everything but the append.
    fn prefill_writes(&mut self, keys: &[f32], values: &[f32], scratch: &mut Scratch) {
        let _ = scratch;
        let di = self.d_in();
        let dv = self.d_out();
        let len = keys.len() / di;
        debug_assert_eq!(values.len(), len * dv);
        for i in 0..len {
            self.write(&keys[i * di..(i + 1) * di], &values[i * dv..(i + 1) * dv]);
        }
    }

    /// Flush any buffered chunk tail into the long-term state (no-op for
    /// mixers without chunk buffering). Reads already see buffered tokens;
    /// this only forces the merge, e.g. at end-of-sequence.
    fn flush(&mut self) {}

    /// Serialize the complete mixer state (config, tensors, buffered chunk
    /// tails — everything needed to continue bit-identically) into `w`.
    /// Callers use [`super::snapshot::save`], which adds the framing that
    /// lets [`super::snapshot::restore`] revive the machine from bytes;
    /// implementations only write their payload here.
    fn snapshot(&self, w: &mut super::snapshot::Writer);

    /// Token-level access for language-model sessions: [`super::lm::LmModel`]
    /// overrides with `Some(self)`, everything else stays `None`. The
    /// generation engine serves LM sessions through the same banks and
    /// snapshot machinery as every other mixer and reaches the
    /// prefill-tokens / step-token / sampler-state API through this hook
    /// (the one concession to the trait being f32-row-shaped).
    fn as_lm_mut(&mut self) -> Option<&mut super::lm::LmModel> {
        None
    }

    /// Per-layer telemetry split. A plain mixer is its own single layer;
    /// multi-layer composites ([`super::stack::LayerStack`]) override with
    /// one row per layer so serving reports can show where state and busy
    /// time live inside the model.
    fn layer_stats(&self) -> Vec<LayerStat> {
        vec![LayerStat {
            kind: self.kind_name().to_string(),
            state_bytes: self.state_bytes(),
            busy_ns: 0.0,
            tokens: self.tokens(),
        }]
    }
}

/// Masked-softmax read over a dictionary with count biasing — the shared
/// eq. 6 / eq. 15 read used by both `OvqState` and `VqState`:
/// `out = softmax(beta * q . Dk^T + ln(counts)) . Dv` over slots with
/// counts > 0, optionally extended by `extra` visible (k, v) rows (the
/// in-chunk prefix, bias-free). Returns nothing; `out` is normalized in
/// place. All heavy loops go through the blocked kernels; the
/// dictionaries arrive as [`QuantTensor`]s, whose `None` mode delegates
/// to the raw kernels verbatim (bit-identical to the pre-quant path) and
/// whose lossy modes run fused dequant-dot sweeps. The pending-tail
/// `extra` rows are always plain f32 — only the cold dictionary
/// quantizes.
#[allow(clippy::too_many_arguments)]
pub fn dict_softmax_read(
    q: &[f32],
    dk: &QuantTensor,
    dv: &QuantTensor,
    counts: &[f32],
    n: usize,
    d: usize,
    beta: f32,
    extra_k: &[f32],
    extra_v: &[f32],
    extra_len: usize,
    out: &mut [f32],
    scratch: &mut Scratch,
) {
    debug_assert!(dk.rows() == n && dk.d() == d);
    {
        let (logits, _) = scratch.logit_buffers(n + extra_len);
        // slot similarities: q . Dk^T (bias applied in the finish)
        dk.matvec(q, logits);
    }
    let (logits, weights) = scratch.logit_buffers(n + extra_len);
    dict_softmax_finish(
        q, dv, counts, n, d, beta, extra_k, extra_v, extra_len, logits, weights, out,
    );
}

/// The tail of [`dict_softmax_read`] for callers that already hold the
/// raw slot similarities `q . Dk^T` in `logits[..n]` — e.g. a prefill
/// path that computed them for a whole block with one tiled
/// [`kernels::matmul_rows`] sweep. Applies the count bias + masking,
/// computes the bias-free in-chunk prefix logits, and runs the streaming
/// softmax accumulation. Bit-identical to [`dict_softmax_read`] given
/// bit-identical similarities.
#[allow(clippy::too_many_arguments)]
pub fn dict_softmax_finish(
    q: &[f32],
    dv: &QuantTensor,
    counts: &[f32],
    n: usize,
    d: usize,
    beta: f32,
    extra_k: &[f32],
    extra_v: &[f32],
    extra_len: usize,
    logits: &mut [f32],
    weights: &mut [f32],
    out: &mut [f32],
) {
    debug_assert!(dv.rows() == n && dv.d() == d);
    let total = n + extra_len;
    out.iter_mut().for_each(|o| *o = 0.0);
    if total == 0 {
        return;
    }
    debug_assert!(logits.len() >= total && weights.len() >= total);
    let logits = &mut logits[..total];
    let weights = &mut weights[..total];

    // slot logits: beta * Dk q + ln(c), masked where c == 0
    let mut m = f32::NEG_INFINITY;
    for s in 0..n {
        if counts[s] > 0.0 {
            logits[s] = beta * logits[s] + counts[s].ln();
            m = m.max(logits[s]);
        } else {
            logits[s] = f32::NEG_INFINITY;
        }
    }
    // chunk-prefix logits: bias-free
    kernels::matvec(extra_k, extra_len, d, q, &mut logits[n..]);
    for l in logits[n..].iter_mut() {
        *l *= beta;
        m = m.max(*l);
    }
    if m == f32::NEG_INFINITY {
        return;
    }

    let mut z = dv.softmax_accumulate(&logits[..n], m, &mut weights[..n], out);
    z += kernels::softmax_accumulate(
        &logits[n..],
        extra_v,
        extra_len,
        d,
        m,
        &mut weights[n..],
        out,
    );
    if z > 0.0 {
        out.iter_mut().for_each(|o| *o /= z);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_buffers_grow_and_reuse() {
        let mut s = Scratch::new();
        {
            let (l, w) = s.logit_buffers(10);
            assert_eq!(l.len(), 10);
            assert_eq!(w.len(), 10);
        }
        {
            let (l, _) = s.logit_buffers(4);
            assert_eq!(l.len(), 4); // view shrinks, allocation does not
        }
        assert!(s.logits.capacity() >= 10);
        assert_eq!(s.f32_buf(7).len(), 7);
        assert_eq!(s.idx_buf(3).len(), 3);
    }

    use crate::ovqcore::quant::{QuantMode, QuantTensor};

    #[test]
    fn dict_read_is_convex_and_count_biased() {
        // two active slots with equal similarity: counts decide the mix —
        // and the invariant must hold in every dictionary storage mode
        // (the lossy modes represent 0/1/3 exactly)
        let d = 4;
        for mode in [QuantMode::None, QuantMode::F16, QuantMode::I8] {
            let dk = QuantTensor::new(mode, 2, d); // zero keys -> equal sims
            let mut dvf = vec![0.0f32; 2 * d];
            dvf[..d].iter_mut().for_each(|x| *x = 1.0);
            dvf[d..].iter_mut().for_each(|x| *x = 3.0);
            let dv = QuantTensor::from_f32(mode, 2, d, &dvf);
            let counts = [3.0f32, 1.0];
            let q = vec![1.0f32; d];
            let mut out = vec![0.0f32; d];
            let mut scratch = Scratch::new();
            dict_softmax_read(
                &q, &dk, &dv, &counts, 2, d, 8.0, &[], &[], 0, &mut out, &mut scratch,
            );
            // weights are 3/4 and 1/4 -> 0.75*1 + 0.25*3 = 1.5
            for &o in &out {
                assert!((o - 1.5).abs() < 1e-4, "{mode:?}: {o}");
            }
        }
    }

    #[test]
    fn dict_read_empty_state_is_zero() {
        let empty = QuantTensor::new(QuantMode::None, 0, 4);
        let mut out = vec![7.0f32; 4];
        let mut scratch = Scratch::new();
        dict_softmax_read(
            &[1.0; 4],
            &empty,
            &empty,
            &[],
            0,
            4,
            8.0,
            &[],
            &[],
            0,
            &mut out,
            &mut scratch,
        );
        assert!(out.iter().all(|&o| o == 0.0));
    }

    #[test]
    fn dict_read_sees_extra_rows() {
        // empty dictionary, one visible chunk row: output == that value
        let d = 4;
        let empty = QuantTensor::new(QuantMode::None, 0, d);
        let k = vec![0.5f32; d];
        let v = vec![2.0f32; d];
        let mut out = vec![0.0f32; d];
        let mut scratch = Scratch::new();
        dict_softmax_read(
            &[1.0; d],
            &empty,
            &empty,
            &[],
            0,
            d,
            8.0,
            &k,
            &v,
            1,
            &mut out,
            &mut scratch,
        );
        for &o in &out {
            assert!((o - 2.0).abs() < 1e-5);
        }
    }
}
