//! Pure-Rust implementations of the paper's sequence-mixing state machines.
//!
//! These mirror the L2 JAX semantics (same growth schedule, same merge
//! rule, same masking) in plain Rust. They serve three roles:
//!  1. the serving path of `examples/serve_ovq.rs` (single-token decode
//!     without re-running a whole HLO program),
//!  2. the §3.4 / Fig. 3 / Fig. 4-right memory-accounting experiments
//!     ([`memstate`]),
//!  3. criterion-style throughput benches of the state update — the
//!     paper's core systems claim that the OVQ update cost is independent
//!     of the dictionary size N while linear attention's is not.
//!
//! Layering (DESIGN.md): every state machine implements the
//! [`mixer::SeqMixer`] trait and runs its hot loops through the blocked
//! [`kernels`]; [`stack::LayerStack`] composes the machines into full
//! multi-layer model stacks (norms, q/k/v/output projections, gated MLP,
//! residuals) that are themselves `SeqMixer`s; [`lm::LmModel`] puts a
//! token embedding + tied unembedding around a stack, turning it into a
//! token-in/logits-out language model with in-snapshot generation state
//! (the autoregressive serving unit); [`snapshot`] freezes/thaws
//! any mixer — stacks included, via nested container frames — to a
//! bit-exact binary blob (the session-lifecycle persistence layer);
//! [`bank::MixerBank`] scales the trait to H heads x S concurrent decode
//! streams with round-robin scheduling, and [`bank::ShardBank`] adds the
//! session-keyed store (admission, LRU eviction to snapshots, restore)
//! that `coordinator::engine` runs one-per-worker-thread. Consumers
//! (memstate accounting, the coordinator's serving/eval paths, the
//! examples and benches) go through the trait or the banks only.

pub mod bank;
pub mod gdn;
pub mod kernels;
pub mod kvcache;
pub mod linear_attn;
pub mod lm;
pub mod memstate;
pub mod mixer;
pub mod ovq;
pub mod quant;
pub mod snapshot;
pub mod stack;
pub mod store;
pub mod vq;

/// Growth schedule (paper eqs. 17-18): N_t = floor(t*N / (t+N)).
pub fn growth_n_t(t: usize, n_max: usize) -> usize {
    if t == 0 {
        return 0;
    }
    // u128 intermediate: t * n_max overflows usize for large sweeps
    ((t as u128 * n_max as u128) / (t as u128 + n_max as u128)) as usize
}

/// Number of new centroids for chunk c (1-based end position = c*chunk).
pub fn growth_n_new(chunk_idx: usize, chunk_len: usize, n_max: usize) -> usize {
    growth_n_t((chunk_idx + 1) * chunk_len, n_max)
        - growth_n_t(chunk_idx * chunk_len, n_max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn growth_plateaus_at_n() {
        assert_eq!(growth_n_t(0, 128), 0);
        assert!(growth_n_t(1_000_000, 128) <= 128);
        assert_eq!(growth_n_t(1_000_000_000, 128), 127); // asymptote
        // monotone
        let mut prev = 0;
        for t in 0..10_000 {
            let n = growth_n_t(t, 128);
            assert!(n >= prev);
            prev = n;
        }
    }

    #[test]
    fn n_new_sums_to_n_t() {
        let (l, n) = (32, 256);
        let total: usize = (0..100).map(|c| growth_n_new(c, l, n)).sum();
        assert_eq!(total, growth_n_t(100 * l, n));
    }

    #[test]
    fn n_new_never_exceeds_chunk() {
        for c in 0..1000 {
            assert!(growth_n_new(c, 16, 4096) <= 16);
        }
    }
}
