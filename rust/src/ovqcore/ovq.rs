//! Pure-Rust OVQ-attention state machine (single head) — the paper's
//! §3.2 algorithm: chunk prediction (eq. 15), spread-maximizing growth
//! (eqs. 17-18), adaptive-lr online k-means merge (eq. 19).
//!
//! Semantics match python/compile/layers/ovq.py; the integration test
//! rust/tests/golden.rs cross-checks outputs against the HLO path.

use super::{growth_n_new};

#[derive(Debug, Clone)]
pub struct OvqConfig {
    pub d: usize,
    pub n_max: usize,
    pub chunk: usize,
    pub beta: f32,
    /// Fig. 7 ablations
    pub const_lr: Option<f32>,
    pub linear_growth: bool,
    pub rand_assign: bool,
    /// horizon used by the linear-growth ablation to spread centroids
    pub linear_growth_chunks: usize,
}

impl OvqConfig {
    pub fn new(d: usize, n_max: usize, chunk: usize) -> OvqConfig {
        OvqConfig {
            d,
            n_max,
            chunk,
            beta: 8.0,
            const_lr: None,
            linear_growth: false,
            rand_assign: false,
            linear_growth_chunks: 64,
        }
    }
}

/// The constant-size OVQ memory state.
#[derive(Debug, Clone)]
pub struct OvqState {
    pub cfg: OvqConfig,
    /// [n_max, d] row-major key centroids
    pub dk: Vec<f32>,
    /// [n_max, d] value centroids
    pub dv: Vec<f32>,
    /// per-slot assignment counts (0 = inactive)
    pub counts: Vec<f32>,
    pub n_active: usize,
    /// tokens absorbed so far
    pub t: usize,
    chunk_idx: usize,
}

impl OvqState {
    pub fn new(cfg: OvqConfig) -> OvqState {
        let n = cfg.n_max;
        let d = cfg.d;
        OvqState {
            cfg,
            dk: vec![0.0; n * d],
            dv: vec![0.0; n * d],
            counts: vec![0.0; n],
            n_active: 0,
            t: 0,
            chunk_idx: 0,
        }
    }

    pub fn state_bytes(&self) -> usize {
        (self.dk.len() + self.dv.len() + self.counts.len()) * 4
    }

    fn dot(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    /// Attention of one query over the current dictionary + an in-chunk
    /// prefix (keys[..upto], values[..upto]) — eq. 15 for a single row.
    pub fn attend(
        &self,
        q: &[f32],
        chunk_k: &[f32],
        chunk_v: &[f32],
        upto: usize,
        out: &mut [f32],
    ) {
        let d = self.cfg.d;
        let beta = self.cfg.beta;
        debug_assert_eq!(q.len(), d);
        let n = self.n_active;

        // logits over active slots + visible chunk items, streaming softmax
        let mut m = f32::NEG_INFINITY;
        let mut logits: Vec<f32> = Vec::with_capacity(n + upto);
        for s in 0..n {
            if self.counts[s] > 0.0 {
                let l = beta * Self::dot(q, &self.dk[s * d..(s + 1) * d])
                    + self.counts[s].ln();
                logits.push(l);
                m = m.max(l);
            } else {
                logits.push(f32::NEG_INFINITY);
            }
        }
        for j in 0..upto {
            let l = beta * Self::dot(q, &chunk_k[j * d..(j + 1) * d]);
            logits.push(l);
            m = m.max(l);
        }

        out.iter_mut().for_each(|o| *o = 0.0);
        let mut z = 0.0f32;
        for (s, &l) in logits.iter().enumerate().take(n) {
            if l > f32::NEG_INFINITY {
                let w = (l - m).exp();
                z += w;
                let row = &self.dv[s * d..(s + 1) * d];
                for (o, &v) in out.iter_mut().zip(row) {
                    *o += w * v;
                }
            }
        }
        for j in 0..upto {
            let w = (logits[n + j] - m).exp();
            z += w;
            let row = &chunk_v[j * d..(j + 1) * d];
            for (o, &v) in out.iter_mut().zip(row) {
                *o += w * v;
            }
        }
        if z > 0.0 {
            out.iter_mut().for_each(|o| *o /= z);
        }
    }

    /// Process one chunk: returns outputs [len, d] and performs the state
    /// update (grow + merge). keys/values are [len, d] row-major, len <=
    /// cfg.chunk (the last chunk may be short).
    pub fn process_chunk(&mut self, queries: &[f32], keys: &[f32], values: &[f32]) -> Vec<f32> {
        let d = self.cfg.d;
        let len = keys.len() / d;
        debug_assert!(len <= self.cfg.chunk);

        // 1. predict
        let mut out = vec![0.0f32; len * d];
        for i in 0..len {
            let (head, tail) = out.split_at_mut(i * d);
            let _ = head;
            self.attend(
                &queries[i * d..(i + 1) * d],
                keys,
                values,
                i + 1,
                &mut tail[..d],
            );
        }

        // 2. grow + 3. merge
        self.update_chunk(keys, values);
        out
    }

    /// The state update only (used by the benches to isolate update cost).
    pub fn update_chunk(&mut self, keys: &[f32], values: &[f32]) {
        let d = self.cfg.d;
        let len = keys.len() / d;

        // nearest active centroid per item
        let mut best_idx = vec![0usize; len];
        let mut best_sim = vec![f32::NEG_INFINITY; len];
        for i in 0..len {
            let k = &keys[i * d..(i + 1) * d];
            for s in 0..self.n_active {
                if self.counts[s] > 0.0 {
                    let sim = Self::dot(k, &self.dk[s * d..(s + 1) * d]);
                    if sim > best_sim[i] {
                        best_sim[i] = sim;
                        best_idx[i] = s;
                    }
                }
            }
        }

        // growth count for this chunk
        let n_new = if self.cfg.linear_growth {
            let per = self.cfg.n_max / self.cfg.linear_growth_chunks;
            per.min(self.cfg.n_max - self.n_active).min(len)
        } else {
            growth_n_new(self.chunk_idx, self.cfg.chunk, self.cfg.n_max)
                .min(self.cfg.n_max - self.n_active)
                .min(len)
        };

        // choose new centroids: lowest best-similarity (or pseudo-random)
        let mut order: Vec<usize> = (0..len).collect();
        if self.cfg.rand_assign {
            // deterministic pseudo-random priority from position + time
            order.sort_by_key(|&i| {
                (i as u64)
                    .wrapping_mul(0x9E3779B97F4A7C15)
                    .wrapping_add(self.t as u64)
                    .rotate_left(17)
            });
        } else {
            order.sort_by(|&a, &b| best_sim[a].partial_cmp(&best_sim[b]).unwrap());
        }
        let mut is_new = vec![false; len];
        for &i in order.iter().take(n_new) {
            is_new[i] = true;
        }

        // assignments: new items claim fresh slots in position order
        let mut next_slot = self.n_active;
        let mut assign = vec![0usize; len];
        for i in 0..len {
            if is_new[i] {
                assign[i] = next_slot;
                next_slot += 1;
            } else if self.n_active > 0 {
                assign[i] = best_idx[i];
            } else {
                assign[i] = 0; // degenerate cold start: merge into slot 0
            }
        }
        self.n_active = next_slot;

        // merge: exact count-weighted mean (eq. 19 batch form) or const-lr
        // accumulate per-slot chunk sums first
        let mut touched: Vec<usize> = assign.clone();
        touched.sort_unstable();
        touched.dedup();
        for &s in &touched {
            let mut cc = 0.0f32;
            let mut sum_k = vec![0.0f32; d];
            let mut sum_v = vec![0.0f32; d];
            for i in 0..len {
                if assign[i] == s {
                    cc += 1.0;
                    for j in 0..d {
                        sum_k[j] += keys[i * d + j];
                        sum_v[j] += values[i * d + j];
                    }
                }
            }
            let c_old = self.counts[s];
            match self.cfg.const_lr {
                Some(lr) if c_old > 0.0 => {
                    for j in 0..d {
                        self.dk[s * d + j] +=
                            lr * (sum_k[j] - cc * self.dk[s * d + j]);
                        self.dv[s * d + j] +=
                            lr * (sum_v[j] - cc * self.dv[s * d + j]);
                    }
                }
                _ => {
                    let denom = c_old + cc;
                    for j in 0..d {
                        self.dk[s * d + j] =
                            (c_old * self.dk[s * d + j] + sum_k[j]) / denom;
                        self.dv[s * d + j] =
                            (c_old * self.dv[s * d + j] + sum_v[j]) / denom;
                    }
                }
            }
            self.counts[s] = c_old + cc;
        }

        self.t += len;
        self.chunk_idx += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn counts_equal_tokens_processed() {
        let mut st = OvqState::new(OvqConfig::new(8, 64, 16));
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            let k = rand_vec(&mut rng, 16 * 8);
            let v = rand_vec(&mut rng, 16 * 8);
            let q = rand_vec(&mut rng, 16 * 8);
            st.process_chunk(&q, &k, &v);
        }
        assert_eq!(st.t, 160);
        let total: f32 = st.counts.iter().sum();
        assert_eq!(total as usize, 160);
        assert!(st.n_active <= 64);
        assert!(st.n_active > 0);
    }

    #[test]
    fn active_slots_track_growth_schedule() {
        let mut st = OvqState::new(OvqConfig::new(4, 128, 32));
        let mut rng = Rng::new(2);
        for c in 0..20 {
            let k = rand_vec(&mut rng, 32 * 4);
            let v = rand_vec(&mut rng, 32 * 4);
            st.update_chunk(&k, &v);
            assert_eq!(
                st.n_active,
                super::super::growth_n_t((c + 1) * 32, 128),
                "chunk {c}"
            );
        }
    }

    #[test]
    fn output_is_convex_combination() {
        // all values equal => output equals that value
        let mut st = OvqState::new(OvqConfig::new(4, 32, 8));
        let mut rng = Rng::new(3);
        for _ in 0..4 {
            let k = rand_vec(&mut rng, 8 * 4);
            let v = vec![2.5f32; 8 * 4];
            let q = rand_vec(&mut rng, 8 * 4);
            let out = st.process_chunk(&q, &k, &v);
            for &o in &out {
                assert!((o - 2.5).abs() < 1e-4, "o={o}");
            }
        }
    }

    #[test]
    fn centroid_is_mean_of_assigned() {
        // one chunk, everything forced into fresh slots or slot 0: the
        // count-weighted invariant sum(counts_s * mu_s) == sum(inputs)
        let mut st = OvqState::new(OvqConfig::new(2, 16, 8));
        let mut rng = Rng::new(4);
        let k = rand_vec(&mut rng, 8 * 2);
        let v = rand_vec(&mut rng, 8 * 2);
        st.update_chunk(&k, &v);
        let mut weighted = vec![0.0f32; 2];
        for s in 0..st.cfg.n_max {
            for j in 0..2 {
                weighted[j] += st.counts[s] * st.dk[s * 2 + j];
            }
        }
        let mut total = vec![0.0f32; 2];
        for i in 0..8 {
            for j in 0..2 {
                total[j] += k[i * 2 + j];
            }
        }
        for j in 0..2 {
            assert!((weighted[j] - total[j]).abs() < 1e-3);
        }
    }

    #[test]
    fn prop_mass_conservation_over_time() {
        // the count-weighted centroid sum always equals the running input
        // sum (exact-merge mode) — the EM/k-means invariant.
        Prop::new(5).cases(16).check(|c| {
            let d = 2 + c.rng.usize_below(6);
            let chunk = 4 + c.rng.usize_below(12);
            let n = 8 + c.rng.usize_below(64);
            let mut st = OvqState::new(OvqConfig::new(d, n, chunk));
            let mut run_sum = vec![0.0f64; d];
            for _ in 0..6 {
                let k: Vec<f32> =
                    (0..chunk * d).map(|_| c.rng.normal() as f32).collect();
                let v: Vec<f32> =
                    (0..chunk * d).map(|_| c.rng.normal() as f32).collect();
                for i in 0..chunk {
                    for j in 0..d {
                        run_sum[j] += k[i * d + j] as f64;
                    }
                }
                st.update_chunk(&k, &v);
                let mut w = vec![0.0f64; d];
                for s in 0..n {
                    for j in 0..d {
                        w[j] += (st.counts[s] * st.dk[s * d + j]) as f64;
                    }
                }
                for j in 0..d {
                    if (w[j] - run_sum[j]).abs() > 1e-2 {
                        return Err(format!(
                            "mass not conserved: {} vs {}",
                            w[j], run_sum[j]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn const_lr_differs_from_exact_merge() {
        let mut a = OvqState::new(OvqConfig::new(4, 16, 8));
        let mut cfg = OvqConfig::new(4, 16, 8);
        cfg.const_lr = Some(0.025);
        let mut b = OvqState::new(cfg);
        let mut rng = Rng::new(6);
        for _ in 0..6 {
            let k = rand_vec(&mut rng, 8 * 4);
            let v = rand_vec(&mut rng, 8 * 4);
            a.update_chunk(&k, &v);
            b.update_chunk(&k, &v);
        }
        // same growth, different centroids
        assert_eq!(a.n_active, b.n_active);
        let diff: f32 = a
            .dk
            .iter()
            .zip(&b.dk)
            .map(|(x, y)| (x - y).abs())
            .sum();
        assert!(diff > 1e-3, "ablation should change the state");
    }
}
