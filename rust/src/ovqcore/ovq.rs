//! Pure-Rust OVQ-attention state machine (single head) — the paper's
//! §3.2 algorithm: chunk prediction (eq. 15), spread-maximizing growth
//! (eqs. 17-18), adaptive-lr online k-means merge (eq. 19).
//!
//! Semantics match python/compile/layers/ovq.py. The streaming property
//! test rust/tests/golden.rs cross-checks that token-by-token decode
//! (arrival chunk 1) and chunked decode (arrival chunk 16) through the
//! [`SeqMixer`] interface produce identical outputs.
//!
//! Chunk buffering: tokens are staged in a pending buffer and merged into
//! the dictionary lazily, `cfg.chunk` at a time, the moment the chunk
//! *after* them begins — so the read for token i of a chunk always sees
//! the dictionary as of the previous chunk boundary plus the bias-free
//! in-chunk prefix 0..=i, exactly eq. 15, regardless of how tokens
//! arrive. Call [`SeqMixer::flush`] at end-of-sequence to force the final
//! partial merge.

use anyhow::Result;

use super::growth_n_new;
use super::mixer::{dict_softmax_finish, dict_softmax_read, Scratch, SeqMixer};
use super::quant::{QuantMode, QuantTensor};
use super::snapshot;

#[derive(Debug, Clone)]
pub struct OvqConfig {
    pub d: usize,
    pub n_max: usize,
    pub chunk: usize,
    pub beta: f32,
    /// Fig. 7 ablations
    pub const_lr: Option<f32>,
    pub linear_growth: bool,
    pub rand_assign: bool,
    /// horizon used by the linear-growth ablation to spread centroids
    pub linear_growth_chunks: usize,
    /// storage format for the cold dictionary tensors (dk/dv); the hot
    /// pending tail and counts stay f32
    pub quant: QuantMode,
}

impl OvqConfig {
    pub fn new(d: usize, n_max: usize, chunk: usize) -> OvqConfig {
        OvqConfig {
            d,
            n_max,
            chunk,
            beta: 8.0,
            const_lr: None,
            linear_growth: false,
            rand_assign: false,
            linear_growth_chunks: 64,
            quant: QuantMode::None,
        }
    }
}

/// Reusable per-chunk update workspace (no allocation on the steady-state
/// update path).
#[derive(Debug, Clone, Default)]
struct UpdateScratch {
    best_idx: Vec<usize>,
    best_sim: Vec<f32>,
    order: Vec<usize>,
    is_new: Vec<bool>,
    assign: Vec<usize>,
    slot_sums: Vec<f32>,
    touched: Vec<usize>,
    /// merge staging rows — centroids are dequantized here, updated in
    /// f32, then written back (one requant per touched slot per chunk)
    row_k: Vec<f32>,
    row_v: Vec<f32>,
}

/// The OVQ memory state. Dictionary storage is allocated lazily, growing
/// with the active slot count N_t up to the n_max cap — so
/// `state_bytes()` reports actual resident bytes and the paper's
/// grow-then-plateau state curve (Fig. 4-right) holds for real memory,
/// not just the accounting model.
#[derive(Debug, Clone)]
pub struct OvqState {
    pub cfg: OvqConfig,
    /// [n_active, d] row-major key centroids (grows to [n_max, d]),
    /// stored in `cfg.quant` format
    pub dk: QuantTensor,
    /// [n_active, d] value centroids, stored in `cfg.quant` format
    pub dv: QuantTensor,
    /// per-slot assignment counts, one per allocated slot
    pub counts: Vec<f32>,
    pub n_active: usize,
    /// tokens merged into the dictionary so far (excludes the pending tail)
    pub t: usize,
    chunk_idx: usize,
    /// staged (k, v) rows awaiting the next chunk merge, [pending_len, d]
    pending_k: Vec<f32>,
    pending_v: Vec<f32>,
    pending_len: usize,
    upd: UpdateScratch,
}

impl OvqState {
    pub fn new(cfg: OvqConfig) -> OvqState {
        let d = cfg.d;
        let chunk = cfg.chunk;
        let quant = cfg.quant;
        OvqState {
            cfg,
            dk: QuantTensor::new(quant, 0, d),
            dv: QuantTensor::new(quant, 0, d),
            counts: Vec::new(),
            n_active: 0,
            t: 0,
            chunk_idx: 0,
            pending_k: Vec::with_capacity(chunk * d),
            pending_v: Vec::with_capacity(chunk * d),
            pending_len: 0,
            upd: UpdateScratch::default(),
        }
    }

    /// Tokens staged but not yet merged.
    pub fn pending_len(&self) -> usize {
        self.pending_len
    }

    /// Rebuild from a [`snapshot::save`] payload — the inverse of
    /// [`SeqMixer::snapshot`]. The update scratch is transient (cleared at
    /// the top of every `update_chunk`) and is not part of the format.
    pub fn from_snapshot(r: &mut snapshot::Reader<'_>) -> Result<OvqState> {
        let (d, n_max, chunk) = (r.usize()?, r.usize()?, r.usize()?);
        // bound the dims BEFORE construction: OvqState::new reserves
        // chunk * d pending capacity, so a corrupt blob claiming 2^60
        // must err here, not overflow or demand a wild allocation (the
        // snapshot module's no-panics-on-untrusted-bytes contract)
        anyhow::ensure!(
            d > 0 && d <= (1 << 16) && chunk <= (1 << 20) && d.saturating_mul(chunk) <= (1 << 26),
            "ovq snapshot claims an implausible shape (d={d} n_max={n_max} chunk={chunk})"
        );
        let mut cfg = OvqConfig::new(d, n_max, chunk);
        cfg.beta = r.f32()?;
        cfg.const_lr = r.opt_f32()?;
        cfg.linear_growth = r.bool()?;
        cfg.rand_assign = r.bool()?;
        cfg.linear_growth_chunks = r.usize()?;
        cfg.quant = super::quant::QuantMode::from_tag(r.u8()?)?;
        let mut st = OvqState::new(cfg);
        st.n_active = r.usize()?;
        st.t = r.usize()?;
        st.chunk_idx = r.usize()?;
        // the dictionaries thaw in their stored form — a quantized
        // snapshot is never re-quantized on restore
        st.dk = QuantTensor::load(r)?;
        st.dv = QuantTensor::load(r)?;
        st.counts = r.f32s()?;
        st.pending_len = r.usize()?;
        st.pending_k = r.f32s()?;
        st.pending_v = r.f32s()?;
        // saturating: n_active/pending_len come from the blob, so the
        // consistency check itself must not overflow in debug builds
        anyhow::ensure!(
            st.dk.rows() == st.n_active
                && st.dk.d() == st.cfg.d
                && st.dk.mode() == st.cfg.quant
                && st.dv.rows() == st.n_active
                && st.dv.d() == st.cfg.d
                && st.dv.mode() == st.cfg.quant
                && st.counts.len() == st.n_active
                && st.pending_k.len() == st.pending_len.saturating_mul(st.cfg.d)
                && st.pending_v.len() == st.pending_len.saturating_mul(st.cfg.d),
            "ovq snapshot has inconsistent shapes"
        );
        Ok(st)
    }

    /// Attention of one query over the current dictionary + an in-chunk
    /// prefix (keys[..upto], values[..upto]) — eq. 15 for a single row.
    /// All heavy loops run through the blocked kernels with reusable
    /// scratch; nothing is allocated per query.
    pub fn attend(
        &self,
        q: &[f32],
        chunk_k: &[f32],
        chunk_v: &[f32],
        upto: usize,
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        let d = self.cfg.d;
        debug_assert_eq!(q.len(), d);
        let n = self.n_active;
        dict_softmax_read(
            q,
            &self.dk,
            &self.dv,
            &self.counts[..n],
            n,
            d,
            self.cfg.beta,
            &chunk_k[..upto * d],
            &chunk_v[..upto * d],
            upto,
            out,
            scratch,
        );
    }

    /// The state update only (used by the benches to isolate update cost).
    /// keys/values are [len, d] row-major, len <= cfg.chunk.
    pub fn update_chunk(&mut self, keys: &[f32], values: &[f32]) {
        let d = self.cfg.d;
        let len = keys.len() / d;
        debug_assert!(len <= self.cfg.chunk);
        if len == 0 {
            return;
        }

        // nearest active centroid per item — blocked O(len * N * d)
        // similarity matmul (kernels::nearest_rows) instead of the seed's
        // scalar one-slot-at-a-time loop. Every active slot has counts > 0
        // (slots are only claimed by merging at least one item).
        let upd = &mut self.upd;
        upd.best_idx.clear();
        upd.best_idx.resize(len, 0);
        upd.best_sim.clear();
        upd.best_sim.resize(len, f32::NEG_INFINITY);
        self.dk.nearest_rows(keys, len, &mut upd.best_idx, &mut upd.best_sim);

        // growth count for this chunk
        let n_new = if self.cfg.linear_growth {
            let per = self.cfg.n_max / self.cfg.linear_growth_chunks;
            per.min(self.cfg.n_max - self.n_active).min(len)
        } else {
            growth_n_new(self.chunk_idx, self.cfg.chunk, self.cfg.n_max)
                .min(self.cfg.n_max - self.n_active)
                .min(len)
        };

        // choose new centroids: lowest best-similarity (or pseudo-random)
        upd.order.clear();
        upd.order.extend(0..len);
        if self.cfg.rand_assign {
            // deterministic pseudo-random priority from position + time
            let t = self.t;
            upd.order.sort_by_key(|&i| {
                (i as u64)
                    .wrapping_mul(0x9E3779B97F4A7C15)
                    .wrapping_add(t as u64)
                    .rotate_left(17)
            });
        } else {
            let sims = &upd.best_sim;
            upd.order
                .sort_by(|&a, &b| sims[a].partial_cmp(&sims[b]).unwrap());
        }
        upd.is_new.clear();
        upd.is_new.resize(len, false);
        for &i in upd.order.iter().take(n_new) {
            upd.is_new[i] = true;
        }

        // allocate storage for the newly claimed slots (lazy growth: the
        // dictionary holds exactly the active rows, capped at n_max)
        let new_total = self.n_active + n_new;
        self.dk.resize_rows(new_total);
        self.dv.resize_rows(new_total);
        self.counts.resize(new_total, 0.0);

        // assignments: new items claim fresh slots in position order
        let mut next_slot = self.n_active;
        upd.assign.clear();
        upd.assign.resize(len, 0);
        for i in 0..len {
            if upd.is_new[i] {
                upd.assign[i] = next_slot;
                next_slot += 1;
            } else if self.n_active > 0 {
                upd.assign[i] = upd.best_idx[i];
            } else {
                upd.assign[i] = 0; // degenerate cold start: merge into slot 0
            }
        }
        self.n_active = next_slot;

        // merge: exact count-weighted mean (eq. 19 batch form) or const-lr.
        // One pass accumulates per-touched-slot (count, sum_k, sum_v) into
        // a dense workspace — O(len * d) instead of the seed's
        // O(touched * len * d) rescan.
        upd.touched.clear();
        upd.touched.extend_from_slice(&upd.assign);
        upd.touched.sort_unstable();
        upd.touched.dedup();
        let nt = upd.touched.len();
        // layout: [nt] counts, then [nt, d] key sums, then [nt, d] value sums
        upd.slot_sums.clear();
        upd.slot_sums.resize(nt * (2 * d + 1), 0.0);
        let (cc, sums) = upd.slot_sums.split_at_mut(nt);
        let (sum_k, sum_v) = sums.split_at_mut(nt * d);
        for i in 0..len {
            let ti = upd.touched.binary_search(&upd.assign[i]).unwrap();
            cc[ti] += 1.0;
            let sk = &mut sum_k[ti * d..(ti + 1) * d];
            let sv = &mut sum_v[ti * d..(ti + 1) * d];
            for j in 0..d {
                sk[j] += keys[i * d + j];
                sv[j] += values[i * d + j];
            }
        }
        // centroid rows are staged through f32 buffers: dequantize, merge
        // in f32, requantize on write-back. For the f32 passthrough mode
        // this is a copy-in/copy-out of the same arithmetic, bit-identical
        // to the in-place update it replaces.
        upd.row_k.resize(d, 0.0);
        upd.row_v.resize(d, 0.0);
        for (ti, &s) in upd.touched.iter().enumerate() {
            let c_old = self.counts[s];
            let cc = cc[ti];
            let sk = &sum_k[ti * d..(ti + 1) * d];
            let sv = &sum_v[ti * d..(ti + 1) * d];
            self.dk.read_row(s, &mut upd.row_k);
            self.dv.read_row(s, &mut upd.row_v);
            match self.cfg.const_lr {
                Some(lr) if c_old > 0.0 => {
                    for j in 0..d {
                        upd.row_k[j] += lr * (sk[j] - cc * upd.row_k[j]);
                        upd.row_v[j] += lr * (sv[j] - cc * upd.row_v[j]);
                    }
                }
                _ => {
                    let denom = c_old + cc;
                    for j in 0..d {
                        upd.row_k[j] = (c_old * upd.row_k[j] + sk[j]) / denom;
                        upd.row_v[j] = (c_old * upd.row_v[j] + sv[j]) / denom;
                    }
                }
            }
            self.dk.write_row(s, &upd.row_k);
            self.dv.write_row(s, &upd.row_v);
            self.counts[s] = c_old + cc;
        }

        self.t += len;
        self.chunk_idx += 1;
    }
}

impl SeqMixer for OvqState {
    fn kind_name(&self) -> &'static str {
        "ovq"
    }

    fn d_in(&self) -> usize {
        self.cfg.d
    }

    fn d_out(&self) -> usize {
        self.cfg.d
    }

    fn tokens(&self) -> usize {
        self.t + self.pending_len
    }

    /// Live state: active dictionary rows (in their stored format) +
    /// f32 counts + the staged f32 chunk tail.
    fn state_bytes(&self) -> usize {
        self.dk.state_bytes()
            + self.dv.state_bytes()
            + self.n_active * 4
            + 2 * self.pending_len * self.cfg.d * 4
    }

    /// ΔS is [L, 2, d] — one key row + one value row per token, independent
    /// of the dictionary size N (the paper's core systems claim).
    fn update_bytes_per_chunk(&self, l: usize) -> usize {
        2 * l * self.cfg.d * 4
    }

    fn write(&mut self, k: &[f32], v: &[f32]) {
        let d = self.cfg.d;
        debug_assert_eq!(k.len(), d);
        debug_assert_eq!(v.len(), d);
        // lazy merge: the *arrival* of chunk c+1 merges chunk c, so reads
        // inside a chunk always see the eq. 15 prefix, never a mid-chunk
        // dictionary.
        if self.pending_len == self.cfg.chunk {
            self.flush();
        }
        self.pending_k.extend_from_slice(k);
        self.pending_v.extend_from_slice(v);
        self.pending_len += 1;
    }

    fn read(&self, q: &[f32], out: &mut [f32], scratch: &mut Scratch) {
        // dictionary + the buffered in-chunk prefix (eq. 15)
        self.attend(q, &self.pending_k, &self.pending_v, self.pending_len, out, scratch);
    }

    /// Blocked prompt ingestion, bit-identical to the serial token loop.
    /// The block is cut into segments at the same lazy-merge boundaries
    /// `write` produces (a full pending buffer merges when the next token
    /// arrives), each segment is staged into the pending buffer in one
    /// bulk append, and the whole segment's dictionary similarities come
    /// from one tiled [`kernels::matmul_rows`] sweep instead of one
    /// matvec per token. Per-token work left is exactly the eq. 15
    /// bias/mask/softmax over a prefix no batch shape can share.
    fn process_prefill(
        &mut self,
        queries: &[f32],
        keys: &[f32],
        values: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        let d = self.cfg.d;
        let dlen = keys.len() / d;
        debug_assert_eq!(queries.len(), dlen * d);
        debug_assert_eq!(values.len(), dlen * d);
        debug_assert_eq!(out.len(), dlen * d);
        let mut i = 0;
        while i < dlen {
            // same trigger as write(): a full pending buffer merges the
            // moment the chunk after it begins
            if self.pending_len == self.cfg.chunk {
                self.flush();
            }
            let take = (self.cfg.chunk - self.pending_len).min(dlen - i);
            let base = self.pending_len;
            self.pending_k.extend_from_slice(&keys[i * d..(i + take) * d]);
            self.pending_v.extend_from_slice(&values[i * d..(i + take) * d]);
            self.pending_len += take;

            // one tiled dictionary sweep for every query in the segment
            let n = self.n_active;
            let Scratch { logits, weights, buf, .. } = scratch;
            if buf.len() < take * n {
                buf.resize(take * n, 0.0);
            }
            self.dk.matmul_rows(&queries[i * d..(i + take) * d], take, buf);
            for t in 0..take {
                let upto = base + t + 1;
                let total = n + upto;
                if logits.len() < total {
                    logits.resize(total, 0.0);
                }
                if weights.len() < total {
                    weights.resize(total, 0.0);
                }
                logits[..n].copy_from_slice(&buf[t * n..(t + 1) * n]);
                dict_softmax_finish(
                    &queries[(i + t) * d..(i + t + 1) * d],
                    &self.dv,
                    &self.counts[..n],
                    n,
                    d,
                    self.cfg.beta,
                    &self.pending_k[..upto * d],
                    &self.pending_v[..upto * d],
                    upto,
                    logits,
                    weights,
                    &mut out[(i + t) * d..(i + t + 1) * d],
                );
            }
            i += take;
        }
    }

    /// Writes-only prefill for the fan-out path: the exact staging +
    /// lazy-merge loop of [`Self::process_prefill`] minus the read sweep
    /// (no dictionary matmul, no per-token softmax). The post-call state
    /// is bit-identical to `process_prefill` over the same slice — merges
    /// fire at the same boundaries with the same segment contents — at
    /// roughly half the cost.
    fn prefill_writes(&mut self, keys: &[f32], values: &[f32], _scratch: &mut Scratch) {
        let d = self.cfg.d;
        let dlen = keys.len() / d;
        debug_assert_eq!(values.len(), dlen * d);
        let mut i = 0;
        while i < dlen {
            if self.pending_len == self.cfg.chunk {
                self.flush();
            }
            let take = (self.cfg.chunk - self.pending_len).min(dlen - i);
            self.pending_k.extend_from_slice(&keys[i * d..(i + take) * d]);
            self.pending_v.extend_from_slice(&values[i * d..(i + take) * d]);
            self.pending_len += take;
            i += take;
        }
    }

    fn flush(&mut self) {
        if self.pending_len == 0 {
            return;
        }
        let k = std::mem::take(&mut self.pending_k);
        let v = std::mem::take(&mut self.pending_v);
        self.update_chunk(&k, &v);
        self.pending_k = k;
        self.pending_v = v;
        self.pending_k.clear();
        self.pending_v.clear();
        self.pending_len = 0;
    }

    fn snapshot(&self, w: &mut snapshot::Writer) {
        w.usize(self.cfg.d);
        w.usize(self.cfg.n_max);
        w.usize(self.cfg.chunk);
        w.f32(self.cfg.beta);
        w.opt_f32(self.cfg.const_lr);
        w.bool(self.cfg.linear_growth);
        w.bool(self.cfg.rand_assign);
        w.usize(self.cfg.linear_growth_chunks);
        w.u8(self.cfg.quant.tag());
        w.usize(self.n_active);
        w.usize(self.t);
        w.usize(self.chunk_idx);
        self.dk.save(w);
        self.dv.save(w);
        w.f32s(&self.counts);
        w.usize(self.pending_len);
        w.f32s(&self.pending_k);
        w.f32s(&self.pending_v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn process_chunk_vec(st: &mut OvqState, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; q.len()];
        let mut scratch = Scratch::new();
        st.process_chunk(q, k, v, &mut out, &mut scratch);
        out
    }

    #[test]
    fn counts_equal_tokens_processed() {
        let mut st = OvqState::new(OvqConfig::new(8, 64, 16));
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            let k = rand_vec(&mut rng, 16 * 8);
            let v = rand_vec(&mut rng, 16 * 8);
            let q = rand_vec(&mut rng, 16 * 8);
            process_chunk_vec(&mut st, &q, &k, &v);
        }
        assert_eq!(st.tokens(), 160);
        st.flush();
        assert_eq!(st.t, 160);
        let total: f32 = st.counts.iter().sum();
        assert_eq!(total as usize, 160);
        assert!(st.n_active <= 64);
        assert!(st.n_active > 0);
    }

    #[test]
    fn active_slots_track_growth_schedule() {
        let mut st = OvqState::new(OvqConfig::new(4, 128, 32));
        let mut rng = Rng::new(2);
        for c in 0..20 {
            let k = rand_vec(&mut rng, 32 * 4);
            let v = rand_vec(&mut rng, 32 * 4);
            st.update_chunk(&k, &v);
            assert_eq!(
                st.n_active,
                super::super::growth_n_t((c + 1) * 32, 128),
                "chunk {c}"
            );
        }
    }

    #[test]
    fn output_is_convex_combination() {
        // all values equal => output equals that value. 2.5 is exactly
        // representable in every storage mode (f16 trivially; i8 as
        // q=127, scale=2.5/127), so the invariant holds quantized too.
        for quant in [QuantMode::None, QuantMode::F16, QuantMode::I8] {
            let mut cfg = OvqConfig::new(4, 32, 8);
            cfg.quant = quant;
            let mut st = OvqState::new(cfg);
            let mut rng = Rng::new(3);
            for _ in 0..4 {
                let k = rand_vec(&mut rng, 8 * 4);
                let v = vec![2.5f32; 8 * 4];
                let q = rand_vec(&mut rng, 8 * 4);
                let out = process_chunk_vec(&mut st, &q, &k, &v);
                for &o in &out {
                    assert!((o - 2.5).abs() < 1e-3, "{quant:?}: o={o}");
                }
            }
        }
    }

    #[test]
    fn centroid_is_mean_of_assigned() {
        // one chunk, everything forced into fresh slots or slot 0: the
        // count-weighted invariant sum(counts_s * mu_s) == sum(inputs)
        let mut st = OvqState::new(OvqConfig::new(2, 16, 8));
        let mut rng = Rng::new(4);
        let k = rand_vec(&mut rng, 8 * 2);
        let v = rand_vec(&mut rng, 8 * 2);
        st.update_chunk(&k, &v);
        let dk = st.dk.to_f32_vec();
        let mut weighted = vec![0.0f32; 2];
        for s in 0..st.n_active {
            for j in 0..2 {
                weighted[j] += st.counts[s] * dk[s * 2 + j];
            }
        }
        let mut total = vec![0.0f32; 2];
        for i in 0..8 {
            for j in 0..2 {
                total[j] += k[i * 2 + j];
            }
        }
        for j in 0..2 {
            assert!((weighted[j] - total[j]).abs() < 1e-3);
        }
    }

    #[test]
    fn prop_mass_conservation_over_time() {
        // the count-weighted centroid sum always equals the running input
        // sum (exact-merge mode) — the EM/k-means invariant.
        Prop::new(5).cases(16).check(|c| {
            let d = 2 + c.rng.usize_below(6);
            let chunk = 4 + c.rng.usize_below(12);
            let n = 8 + c.rng.usize_below(64);
            let mut st = OvqState::new(OvqConfig::new(d, n, chunk));
            let mut run_sum = vec![0.0f64; d];
            for _ in 0..6 {
                let k: Vec<f32> =
                    (0..chunk * d).map(|_| c.rng.normal() as f32).collect();
                let v: Vec<f32> =
                    (0..chunk * d).map(|_| c.rng.normal() as f32).collect();
                for i in 0..chunk {
                    for j in 0..d {
                        run_sum[j] += k[i * d + j] as f64;
                    }
                }
                st.update_chunk(&k, &v);
                let dk = st.dk.to_f32_vec();
                let mut w = vec![0.0f64; d];
                for s in 0..st.n_active {
                    for j in 0..d {
                        w[j] += (st.counts[s] * dk[s * d + j]) as f64;
                    }
                }
                for j in 0..d {
                    if (w[j] - run_sum[j]).abs() > 1e-2 {
                        return Err(format!(
                            "mass not conserved: {} vs {}",
                            w[j], run_sum[j]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn const_lr_differs_from_exact_merge() {
        let mut a = OvqState::new(OvqConfig::new(4, 16, 8));
        let mut cfg = OvqConfig::new(4, 16, 8);
        cfg.const_lr = Some(0.025);
        let mut b = OvqState::new(cfg);
        let mut rng = Rng::new(6);
        for _ in 0..6 {
            let k = rand_vec(&mut rng, 8 * 4);
            let v = rand_vec(&mut rng, 8 * 4);
            a.update_chunk(&k, &v);
            b.update_chunk(&k, &v);
        }
        // same growth, different centroids
        assert_eq!(a.n_active, b.n_active);
        let (adk, bdk) = (a.dk.to_f32_vec(), b.dk.to_f32_vec());
        let diff: f32 = adk.iter().zip(&bdk).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1e-3, "ablation should change the state");
    }

    #[test]
    fn quantized_snapshot_refreezes_bit_exactly_and_shrinks() {
        // every storage mode: save -> restore -> save is byte-identical
        // (restore never requantizes), and at d=64 the i8 dictionary
        // state is >= 3.5x smaller than f32
        let mut per_mode_bytes = Vec::new();
        for quant in [QuantMode::None, QuantMode::F16, QuantMode::I8] {
            let mut cfg = OvqConfig::new(64, 64, 16);
            cfg.quant = quant;
            let mut st = OvqState::new(cfg);
            let mut rng = Rng::new(11);
            for _ in 0..8 {
                let k = rand_vec(&mut rng, 16 * 64);
                let v = rand_vec(&mut rng, 16 * 64);
                st.update_chunk(&k, &v);
            }
            let mut w = snapshot::Writer::new();
            st.snapshot(&mut w);
            let blob = w.into_bytes();
            let mut r = snapshot::Reader::new(&blob);
            let back = OvqState::from_snapshot(&mut r).unwrap();
            assert_eq!(r.remaining(), 0, "{quant:?}: trailing bytes");
            let mut w2 = snapshot::Writer::new();
            back.snapshot(&mut w2);
            assert_eq!(w2.into_bytes(), blob, "{quant:?}: refreeze differs");
            assert_eq!(back.state_bytes(), st.state_bytes());
            per_mode_bytes.push(st.state_bytes());
        }
        assert!(per_mode_bytes[0] as f64 / per_mode_bytes[2] as f64 >= 3.5);
        assert!(per_mode_bytes[1] < per_mode_bytes[0]);
    }

    #[test]
    fn state_bytes_plateau_with_pending_tail() {
        let mut st = OvqState::new(OvqConfig::new(8, 32, 16));
        let mut rng = Rng::new(7);
        assert_eq!(st.state_bytes(), 0);
        let mut last = 0;
        for _ in 0..40 {
            let k = rand_vec(&mut rng, 16 * 8);
            let v = rand_vec(&mut rng, 16 * 8);
            st.update_chunk(&k, &v);
            last = st.state_bytes();
        }
        // saturated: n_active pinned at the N-1 asymptote, state flat
        let k = rand_vec(&mut rng, 16 * 8);
        let v = rand_vec(&mut rng, 16 * 8);
        st.update_chunk(&k, &v);
        assert_eq!(st.state_bytes(), last);
        // a buffered token adds exactly one (k, v) row
        st.write(&[0.0; 8], &[0.0; 8]);
        assert_eq!(st.state_bytes(), last + 2 * 8 * 4);
        st.flush();
        assert_eq!(st.state_bytes(), last);
    }
}
