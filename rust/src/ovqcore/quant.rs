//! Quantized cold-tensor storage (modeled on mistral.rs's `QuantMethod`
//! abstraction): one [`QuantTensor`] type behind which a row-major
//! `[rows, d]` matrix is held either as plain f32 (the `UnquantF32`
//! passthrough), as IEEE binary16 bit patterns, or as i8 with one f32
//! scale per row. The big *cold* tensors — OVQ/VQ dictionaries,
//! [`super::stack::StackLayer`] weight matrices, the
//! [`super::lm::LmModel`] embedding/unembedding table — are the targets:
//! they are read every token but rewritten rarely (dictionaries: one
//! row per absorbed token; weights: never), so shrinking them ~4x
//! directly raises resident-sessions-per-shard before eviction.
//!
//! Compute contract: accumulation is always f32, through fused
//! dequant-dot paths (`kernels::dot_i8`, the f16 dot below) — a
//! dequantized copy of the matrix is never materialized on the hot path.
//! The `QuantMode::None` variant delegates verbatim to the [`kernels`]
//! entry points with the same slices, so `--quant none` is bit-identical
//! to the pre-quant code by construction; the lossy modes are covered by
//! the round-trip error-bound tests at the bottom of this file.
//!
//! Snapshot contract: a tensor serializes self-describingly (mode tag,
//! dims, payload) in its stored form — a quantized dictionary freezes as
//! its quantized bytes, so save → restore → save is byte-identical and
//! restore never re-quantizes (which would compound the loss).

use anyhow::Result;

use super::kernels;
use super::snapshot;

/// Which storage format a [`QuantTensor`] (and, via config plumbing, a
/// whole model's cold tensors) uses. Parsed from CLI `--quant {none,f16,i8}`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QuantMode {
    /// f32 passthrough — bit-identical to the unquantized code path.
    #[default]
    None,
    /// IEEE binary16, 2 B/elem; ~2x shrink, ~2^-11 relative error.
    F16,
    /// i8 with one f32 scale per row (`scale = max_abs / 127`), 1 B/elem
    /// + 4 B/row; ~4x shrink, absolute error <= scale/2 per element.
    I8,
}

impl QuantMode {
    pub fn name(&self) -> &'static str {
        match self {
            QuantMode::None => "none",
            QuantMode::F16 => "f16",
            QuantMode::I8 => "i8",
        }
    }

    pub fn parse(s: &str) -> Result<QuantMode> {
        match s {
            "none" => Ok(QuantMode::None),
            "f16" => Ok(QuantMode::F16),
            "i8" => Ok(QuantMode::I8),
            other => anyhow::bail!("unknown quant mode {other:?} (expected none|f16|i8)"),
        }
    }

    /// Snapshot tag byte.
    pub fn tag(&self) -> u8 {
        match self {
            QuantMode::None => 0,
            QuantMode::F16 => 1,
            QuantMode::I8 => 2,
        }
    }

    pub fn from_tag(t: u8) -> Result<QuantMode> {
        match t {
            0 => Ok(QuantMode::None),
            1 => Ok(QuantMode::F16),
            2 => Ok(QuantMode::I8),
            other => anyhow::bail!("unknown quant mode tag {other}"),
        }
    }

    /// Stored bytes for one `[d]` row — the unit the analytic accounting
    /// in `memstate`/`analysis::memory` is built from. i8 includes the
    /// per-row f32 scale.
    pub fn row_bytes(&self, d: usize) -> usize {
        match self {
            QuantMode::None => 4 * d,
            QuantMode::F16 => 2 * d,
            QuantMode::I8 => d + 4,
        }
    }
}

// ------------------------------------------------------------- f16 bits
// Manual f32 <-> binary16 bit conversion (no stable `f16` primitive on
// the pinned toolchain, and the F16C extension is not assumed): round to
// nearest even on narrowing, exact widening.

/// Widen one binary16 bit pattern to f32 (exact).
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    let bits = if exp == 0x1F {
        // inf / nan
        sign | 0x7F80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign // signed zero
        } else {
            // subnormal: normalize into an f32 exponent
            let mut e: i32 = 113;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | ((e as u32) << 23) | ((m & 0x3FF) << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Narrow one f32 to a binary16 bit pattern, round-to-nearest-even.
/// Overflow saturates to infinity; underflow denormalizes then flushes
/// to signed zero.
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x7F_FFFF;
    if exp == 255 {
        // inf / nan (keep a quiet-ish payload bit so NaN stays NaN)
        return sign | 0x7C00 | if mant != 0 { 0x200 } else { 0 };
    }
    let unbiased = exp - 127;
    if unbiased >= 16 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // normal half
        let half_exp = (unbiased + 15) as u32;
        let mant13 = mant >> 13;
        let rest = mant & 0x1FFF;
        let mut h = (half_exp << 10) | mant13;
        if rest > 0x1000 || (rest == 0x1000 && (mant13 & 1) == 1) {
            h += 1; // RNE; a carry into the exponent is naturally correct
        }
        return sign | h as u16;
    }
    // subnormal half: value = m * 2^-24 with m in 0..=1023
    let s = -1 - unbiased; // shift of the 24-bit significand
    if s > 24 {
        return sign; // underflow to signed zero
    }
    let full = 0x80_0000u32 | mant;
    let s = s as u32;
    let mut m = full >> s;
    let rest = full & ((1u32 << s) - 1);
    let halfway = 1u32 << (s - 1);
    if rest > halfway || (rest == halfway && (m & 1) == 1) {
        m += 1; // may round up into the smallest normal — still correct
    }
    sign | m as u16
}

/// Fused f16 dequant-dot with the same four-lane accumulation shape as
/// [`kernels::scalar::dot`]. Stays scalar on every backend (F16C is not
/// assumed); accumulation is f32.
#[inline]
fn dot_f16(row: &[u16], x: &[f32]) -> f32 {
    debug_assert_eq!(row.len(), x.len());
    let mut acc = [0.0f32; 4];
    let mut ca = row.chunks_exact(4);
    let mut cb = x.chunks_exact(4);
    for (h, y) in (&mut ca).zip(&mut cb) {
        acc[0] += f16_to_f32(h[0]) * y[0];
        acc[1] += f16_to_f32(h[1]) * y[1];
        acc[2] += f16_to_f32(h[2]) * y[2];
        acc[3] += f16_to_f32(h[3]) * y[3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (h, y) in ca.remainder().iter().zip(cb.remainder()) {
        s += f16_to_f32(*h) * y;
    }
    s
}

/// Quantize one f32 row into i8 in place; returns the row scale
/// (`max_abs / 127`, 0.0 for an all-zero or non-finite row).
fn quantize_row_i8(row: &[f32], q: &mut [i8]) -> f32 {
    debug_assert_eq!(row.len(), q.len());
    let mut max_abs = 0.0f32;
    for &x in row {
        max_abs = max_abs.max(x.abs());
    }
    let scale = max_abs / 127.0;
    if scale == 0.0 || !scale.is_finite() {
        q.fill(0);
        return 0.0;
    }
    let inv = 127.0 / max_abs;
    for (qq, &x) in q.iter_mut().zip(row) {
        *qq = (x * inv).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

// ----------------------------------------------------------- QuantTensor

#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    F16(Vec<u16>),
    I8 { q: Vec<i8>, scales: Vec<f32> },
}

/// A row-major `[rows, d]` matrix stored in one of the [`QuantMode`]
/// formats, with fused-dequant kernel entry points mirroring the
/// [`kernels`] API. The `None` variant calls those kernels verbatim with
/// the same slices (bit-identity by construction); the lossy variants
/// run per-row fused dots with f32 accumulation.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantTensor {
    rows: usize,
    d: usize,
    data: Data,
}

impl QuantTensor {
    /// Zero-filled tensor.
    pub fn new(mode: QuantMode, rows: usize, d: usize) -> QuantTensor {
        let data = match mode {
            QuantMode::None => Data::F32(vec![0.0; rows * d]),
            QuantMode::F16 => Data::F16(vec![0; rows * d]),
            QuantMode::I8 => Data::I8 { q: vec![0; rows * d], scales: vec![0.0; rows] },
        };
        QuantTensor { rows, d, data }
    }

    /// Quantize `xs` (len == rows * d) into the given mode.
    pub fn from_f32(mode: QuantMode, rows: usize, d: usize, xs: &[f32]) -> QuantTensor {
        assert_eq!(xs.len(), rows * d, "QuantTensor::from_f32 shape mismatch");
        let mut t = QuantTensor::new(mode, rows, d);
        match &mut t.data {
            Data::F32(v) => v.copy_from_slice(xs),
            Data::F16(h) => {
                for (hh, &x) in h.iter_mut().zip(xs) {
                    *hh = f32_to_f16(x);
                }
            }
            Data::I8 { q, scales } => {
                for r in 0..rows {
                    scales[r] = quantize_row_i8(&xs[r * d..(r + 1) * d], &mut q[r * d..(r + 1) * d]);
                }
            }
        }
        t
    }

    pub fn mode(&self) -> QuantMode {
        match self.data {
            Data::F32(_) => QuantMode::None,
            Data::F16(_) => QuantMode::F16,
            Data::I8 { .. } => QuantMode::I8,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn len(&self) -> usize {
        self.rows * self.d
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stored bytes: `rows * mode.row_bytes(d)` — the figure
    /// `state_bytes`/`param_bytes` accounting reports.
    pub fn state_bytes(&self) -> usize {
        self.mode().row_bytes(self.d) * self.rows
    }

    /// Direct f32 view — `Some` only for the `None` passthrough mode.
    pub fn as_f32(&self) -> Option<&[f32]> {
        match &self.data {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }

    /// Full dequantized copy. Diagnostics/tests only — never on the
    /// serving hot path (that is what the fused kernels are for).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.d];
        for r in 0..self.rows {
            self.read_row(r, &mut out[r * self.d..(r + 1) * self.d]);
        }
        out
    }

    /// Grow (zero rows) or shrink to `rows`.
    pub fn resize_rows(&mut self, rows: usize) {
        let d = self.d;
        match &mut self.data {
            Data::F32(v) => v.resize(rows * d, 0.0),
            Data::F16(h) => h.resize(rows * d, 0),
            Data::I8 { q, scales } => {
                q.resize(rows * d, 0);
                scales.resize(rows, 0.0);
            }
        }
        self.rows = rows;
    }

    /// Dequantize row `r` into `out[..d]`.
    pub fn read_row(&self, r: usize, out: &mut [f32]) {
        let d = self.d;
        debug_assert!(r < self.rows && out.len() >= d);
        match &self.data {
            Data::F32(v) => out[..d].copy_from_slice(&v[r * d..r * d + d]),
            Data::F16(h) => {
                for (o, &hh) in out[..d].iter_mut().zip(&h[r * d..r * d + d]) {
                    *o = f16_to_f32(hh);
                }
            }
            Data::I8 { q, scales } => {
                let s = scales[r];
                for (o, &qq) in out[..d].iter_mut().zip(&q[r * d..r * d + d]) {
                    *o = s * qq as f32;
                }
            }
        }
    }

    /// Quantize `row` into row `r` (re-deriving the i8 row scale).
    pub fn write_row(&mut self, r: usize, row: &[f32]) {
        let d = self.d;
        debug_assert!(r < self.rows && row.len() == d);
        match &mut self.data {
            Data::F32(v) => v[r * d..r * d + d].copy_from_slice(row),
            Data::F16(h) => {
                for (hh, &x) in h[r * d..r * d + d].iter_mut().zip(row) {
                    *hh = f32_to_f16(x);
                }
            }
            Data::I8 { q, scales } => {
                scales[r] = quantize_row_i8(row, &mut q[r * d..r * d + d]);
            }
        }
    }

    /// `out[r] = dot(row_r, x)` — fused dequant matvec, f32 accumulation.
    pub fn matvec(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.d);
        debug_assert!(out.len() >= self.rows);
        let d = self.d;
        match &self.data {
            Data::F32(v) => kernels::matvec(v, self.rows, d, x, out),
            Data::F16(h) => {
                for (r, o) in out[..self.rows].iter_mut().enumerate() {
                    *o = dot_f16(&h[r * d..r * d + d], x);
                }
            }
            Data::I8 { q, scales } => {
                for (r, o) in out[..self.rows].iter_mut().enumerate() {
                    *o = kernels::dot_i8(&q[r * d..r * d + d], scales[r], x);
                }
            }
        }
    }

    /// Batched matvec (`out[i * rows + r]`), mirroring
    /// [`kernels::matmul_rows`]. The `None` variant delegates to it
    /// verbatim, preserving the prefill ≡ decode bit-identity contract;
    /// the lossy variants run the fused per-row dots per query.
    pub fn matmul_rows(&self, xs: &[f32], len: usize, out: &mut [f32]) {
        debug_assert!(xs.len() >= len * self.d);
        debug_assert!(out.len() >= len * self.rows);
        if let Data::F32(v) = &self.data {
            kernels::matmul_rows(v, self.rows, self.d, xs, len, out);
            return;
        }
        let (rows, d) = (self.rows, self.d);
        for i in 0..len {
            self.matvec(&xs[i * d..(i + 1) * d], &mut out[i * rows..(i + 1) * rows]);
        }
    }

    /// Nearest-row search mirroring [`kernels::nearest_rows`] (seeded
    /// `best_idx`/`best_sim`, strict-greater compare).
    pub fn nearest_rows(
        &self,
        keys: &[f32],
        len: usize,
        best_idx: &mut [usize],
        best_sim: &mut [f32],
    ) {
        let (n, d) = (self.rows, self.d);
        debug_assert!(keys.len() >= len * d);
        debug_assert!(best_idx.len() >= len && best_sim.len() >= len);
        match &self.data {
            Data::F32(v) => kernels::nearest_rows(v, n, d, keys, len, best_idx, best_sim),
            Data::F16(h) => {
                for i in 0..len {
                    let k = &keys[i * d..(i + 1) * d];
                    let (mut bi, mut bv) = (best_idx[i], best_sim[i]);
                    for r in 0..n {
                        let a = dot_f16(&h[r * d..r * d + d], k);
                        if a > bv {
                            bv = a;
                            bi = r;
                        }
                    }
                    best_idx[i] = bi;
                    best_sim[i] = bv;
                }
            }
            Data::I8 { q, scales } => {
                for i in 0..len {
                    let k = &keys[i * d..(i + 1) * d];
                    let (mut bi, mut bv) = (best_idx[i], best_sim[i]);
                    for r in 0..n {
                        let a = kernels::dot_i8(&q[r * d..r * d + d], scales[r], k);
                        if a > bv {
                            bv = a;
                            bi = r;
                        }
                    }
                    best_idx[i] = bi;
                    best_sim[i] = bv;
                }
            }
        }
    }

    /// `acc += w * row_r` with fused dequant (the quantized softmax value
    /// gather's inner step).
    fn axpy_row(&self, r: usize, w: f32, acc: &mut [f32]) {
        let d = self.d;
        match &self.data {
            Data::F32(v) => {
                for (a, &m) in acc[..d].iter_mut().zip(&v[r * d..r * d + d]) {
                    *a += w * m;
                }
            }
            Data::F16(h) => {
                for (a, &hh) in acc[..d].iter_mut().zip(&h[r * d..r * d + d]) {
                    *a += w * f16_to_f32(hh);
                }
            }
            Data::I8 { q, scales } => {
                let ws = w * scales[r];
                for (a, &qq) in acc[..d].iter_mut().zip(&q[r * d..r * d + d]) {
                    *a += ws * qq as f32;
                }
            }
        }
    }

    /// Streaming-softmax combine over this tensor's rows as the values —
    /// the [`kernels::softmax_accumulate`] shape. The `None` variant
    /// delegates verbatim (bit-identity); the lossy variants fuse the
    /// dequant into the row gather and skip zero weights the same way.
    pub fn softmax_accumulate(
        &self,
        logits: &[f32],
        m: f32,
        w_scratch: &mut [f32],
        out: &mut [f32],
    ) -> f32 {
        let rows = self.rows;
        debug_assert!(logits.len() >= rows);
        debug_assert!(w_scratch.len() >= rows);
        if let Data::F32(v) = &self.data {
            return kernels::softmax_accumulate(logits, v, rows, self.d, m, w_scratch, out);
        }
        let mut z = 0.0f32;
        for s in 0..rows {
            let w = if logits[s] > f32::NEG_INFINITY { (logits[s] - m).exp() } else { 0.0 };
            w_scratch[s] = w;
            z += w;
        }
        for s in 0..rows {
            if w_scratch[s] != 0.0 {
                self.axpy_row(s, w_scratch[s], out);
            }
        }
        z
    }

    /// Self-describing serialization: mode tag, dims, payload bytes in
    /// stored form (no dequant, no requant).
    pub fn save(&self, w: &mut snapshot::Writer) {
        w.u8(self.mode().tag());
        w.usize(self.rows);
        w.usize(self.d);
        match &self.data {
            Data::F32(v) => w.f32s(v),
            Data::F16(h) => {
                let mut raw = Vec::with_capacity(h.len() * 2);
                for &x in h {
                    raw.extend_from_slice(&x.to_le_bytes());
                }
                w.bytes(&raw);
            }
            Data::I8 { q, scales } => {
                let raw: Vec<u8> = q.iter().map(|&v| v as u8).collect();
                w.bytes(&raw);
                w.f32s(scales);
            }
        }
    }

    /// Inverse of [`QuantTensor::save`]; every structural defect errs
    /// cleanly (the snapshot fuzz corpus routes bit flips through here).
    pub fn load(r: &mut snapshot::Reader<'_>) -> Result<QuantTensor> {
        let mode = QuantMode::from_tag(r.u8()?)?;
        let rows = r.usize()?;
        let d = r.usize()?;
        let elems = rows
            .checked_mul(d)
            .ok_or_else(|| anyhow::anyhow!("quant tensor dims overflow: {rows} x {d}"))?;
        let data = match mode {
            QuantMode::None => {
                let v = r.f32s()?;
                anyhow::ensure!(v.len() == elems, "quant tensor f32 payload length mismatch");
                Data::F32(v)
            }
            QuantMode::F16 => {
                let raw = r.bytes()?;
                anyhow::ensure!(
                    elems.checked_mul(2) == Some(raw.len()),
                    "quant tensor f16 payload length mismatch"
                );
                Data::F16(raw.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect())
            }
            QuantMode::I8 => {
                let raw = r.bytes()?;
                anyhow::ensure!(raw.len() == elems, "quant tensor i8 payload length mismatch");
                let q = raw.iter().map(|&b| b as i8).collect();
                let scales = r.f32s()?;
                anyhow::ensure!(scales.len() == rows, "quant tensor scale length mismatch");
                Data::I8 { q, scales }
            }
        };
        Ok(QuantTensor { rows, d, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn unquant_f32_mode_is_bit_identical_to_kernels() {
        // the --quant none acceptance criterion at the kernel level: the
        // passthrough variant must reproduce the raw kernels' bits for
        // every entry point, because it calls them with the same slices
        let mut rng = Rng::new(41);
        let (rows, d, len) = (67usize, 24usize, 5usize);
        let m = randv(&mut rng, rows * d);
        let t = QuantTensor::from_f32(QuantMode::None, rows, d, &m);
        assert_eq!(t.as_f32().unwrap(), &m[..]);

        let x = randv(&mut rng, d);
        let (mut a, mut b) = (vec![0.0f32; rows], vec![0.0f32; rows]);
        t.matvec(&x, &mut a);
        kernels::matvec(&m, rows, d, &x, &mut b);
        assert!(a.iter().zip(&b).all(|(p, q)| p.to_bits() == q.to_bits()));

        let xs = randv(&mut rng, len * d);
        let (mut a, mut b) = (vec![0.0f32; len * rows], vec![0.0f32; len * rows]);
        t.matmul_rows(&xs, len, &mut a);
        kernels::matmul_rows(&m, rows, d, &xs, len, &mut b);
        assert!(a.iter().zip(&b).all(|(p, q)| p.to_bits() == q.to_bits()));

        let keys = randv(&mut rng, len * d);
        let (mut ia, mut sa) = (vec![0usize; len], vec![f32::NEG_INFINITY; len]);
        let (mut ib, mut sb) = (vec![0usize; len], vec![f32::NEG_INFINITY; len]);
        t.nearest_rows(&keys, len, &mut ia, &mut sa);
        kernels::nearest_rows(&m, rows, d, &keys, len, &mut ib, &mut sb);
        assert_eq!(ia, ib);
        assert!(sa.iter().zip(&sb).all(|(p, q)| p.to_bits() == q.to_bits()));

        let logits = randv(&mut rng, rows);
        let mut w = vec![0.0f32; rows];
        let (mut oa, mut ob) = (vec![0.0f32; d], vec![0.0f32; d]);
        let za = t.softmax_accumulate(&logits, 0.5, &mut w, &mut oa);
        let zb = kernels::softmax_accumulate(&logits, &m, rows, d, 0.5, &mut w, &mut ob);
        assert_eq!(za.to_bits(), zb.to_bits());
        assert!(oa.iter().zip(&ob).all(|(p, q)| p.to_bits() == q.to_bits()));
    }

    #[test]
    fn f16_conversion_exact_and_bounded() {
        // exactly-representable values round trip to the same bits
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 1024.0, -0.25, 65504.0] {
            let rt = f16_to_f32(f32_to_f16(x));
            assert_eq!(rt.to_bits(), x.to_bits(), "{x} -> {rt}");
        }
        // overflow saturates, nan survives
        assert!(f16_to_f32(f32_to_f16(1e6)).is_infinite());
        assert!(f16_to_f32(f32_to_f16(-1e6)).is_infinite());
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // documented bound for normals: 2^-11 relative (use 2^-10 slack),
        // plus a subnormal absolute floor of 2^-24
        let mut rng = Rng::new(42);
        for _ in 0..2000 {
            let x = (rng.normal() as f32) * 8.0;
            let rt = f16_to_f32(f32_to_f16(x));
            let bound = x.abs() * (1.0 / 1024.0) + 6e-8;
            assert!((rt - x).abs() <= bound, "{x} -> {rt}");
        }
    }

    #[test]
    fn i8_row_round_trip_error_bound() {
        // per-element error <= scale / 2 (round-to-nearest on x / scale)
        let mut rng = Rng::new(43);
        let (rows, d) = (9usize, 33usize);
        let m = randv(&mut rng, rows * d);
        let t = QuantTensor::from_f32(QuantMode::I8, rows, d, &m);
        let rt = t.to_f32_vec();
        for r in 0..rows {
            let row = &m[r * d..(r + 1) * d];
            let max_abs = row.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            let half_step = 0.505 * max_abs / 127.0; // scale/2 + f32 slack
            for j in 0..d {
                let err = (rt[r * d + j] - row[j]).abs();
                assert!(err <= half_step + 1e-6, "row {r} col {j}: err {err} > {half_step}");
            }
        }
        // all-zero rows quantize to scale 0 and read back as exact zeros
        let z = QuantTensor::from_f32(QuantMode::I8, 2, 4, &[0.0; 8]);
        assert!(z.to_f32_vec().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn quant_dict_read_logits_stay_within_analytic_bound() {
        // the satellite criterion: i8/f16 error bounds ON THE READ LOGITS
        // (dot products against a query), not just per element — the
        // quantity the dictionary softmax actually consumes
        let mut rng = Rng::new(44);
        let (rows, d) = (70usize, 64usize);
        let m = randv(&mut rng, rows * d);
        let x = randv(&mut rng, d);
        let mut exact = vec![0.0f32; rows];
        kernels::matvec(&m, rows, d, &x, &mut exact);

        let ti = QuantTensor::from_f32(QuantMode::I8, rows, d, &m);
        let mut li = vec![0.0f32; rows];
        ti.matvec(&x, &mut li);
        let l1x: f32 = x.iter().map(|v| v.abs()).sum();
        for r in 0..rows {
            let row = &m[r * d..(r + 1) * d];
            let max_abs = row.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            // per-element |err| <= scale/2, so |logit err| <= scale/2 * l1(x)
            let bound = 0.505 * (max_abs / 127.0) * l1x + 1e-3;
            assert!((li[r] - exact[r]).abs() <= bound, "i8 row {r}");
        }

        let th = QuantTensor::from_f32(QuantMode::F16, rows, d, &m);
        let mut lh = vec![0.0f32; rows];
        th.matvec(&x, &mut lh);
        for r in 0..rows {
            let row = &m[r * d..(r + 1) * d];
            // per-element relative error 2^-11 -> weighted l1 bound
            let bound: f32 =
                row.iter().zip(&x).map(|(&mm, &xx)| (mm * xx).abs()).sum::<f32>() * 6e-4 + 1e-4;
            assert!((lh[r] - exact[r]).abs() <= bound, "f16 row {r}");
        }
    }

    #[test]
    fn quant_softmax_read_tracks_f32_read() {
        // end-to-end: a count-free dictionary softmax read over quantized
        // values lands close to the f32 read (loose tolerance — this is
        // the lossy mode working as intended, not a bit contract)
        let mut rng = Rng::new(45);
        let (rows, d) = (32usize, 16usize);
        let m = randv(&mut rng, rows * d);
        let logits = randv(&mut rng, rows);
        let mut w = vec![0.0f32; rows];
        for mode in [QuantMode::F16, QuantMode::I8] {
            let t = QuantTensor::from_f32(mode, rows, d, &m);
            let (mut oq, mut of) = (vec![0.0f32; d], vec![0.0f32; d]);
            let zq = t.softmax_accumulate(&logits, 0.0, &mut w, &mut oq);
            let zf = kernels::softmax_accumulate(&logits, &m, rows, d, 0.0, &mut w, &mut of);
            assert!((zq - zf).abs() <= 1e-3 * (1.0 + zf.abs()), "{mode:?} normalizer");
            for j in 0..d {
                let (a, b) = (oq[j] / zq, of[j] / zf);
                assert!((a - b).abs() <= 0.02 * (1.0 + b.abs()), "{mode:?} j={j}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn state_bytes_match_mode_formulas_and_i8_shrinks_4x() {
        let (rows, d) = (128usize, 64usize);
        let m = vec![0.5f32; rows * d];
        let f32b = QuantTensor::from_f32(QuantMode::None, rows, d, &m).state_bytes();
        let f16b = QuantTensor::from_f32(QuantMode::F16, rows, d, &m).state_bytes();
        let i8b = QuantTensor::from_f32(QuantMode::I8, rows, d, &m).state_bytes();
        assert_eq!(f32b, rows * d * 4);
        assert_eq!(f16b, rows * d * 2);
        assert_eq!(i8b, rows * d + rows * 4);
        // at d=64 the i8 tensor shrink is 256/68 ≈ 3.76x
        assert!(f32b as f64 / i8b as f64 >= 3.5);
    }

    #[test]
    fn save_load_round_trips_every_mode_bit_exactly() {
        let mut rng = Rng::new(46);
        let (rows, d) = (13usize, 10usize);
        let m = randv(&mut rng, rows * d);
        for mode in [QuantMode::None, QuantMode::F16, QuantMode::I8] {
            let t = QuantTensor::from_f32(mode, rows, d, &m);
            let mut w = snapshot::Writer::new();
            t.save(&mut w);
            let blob = w.into_bytes();
            let mut r = snapshot::Reader::new(&blob);
            let back = QuantTensor::load(&mut r).unwrap();
            assert_eq!(r.remaining(), 0);
            assert_eq!(back, t, "{mode:?}");
            // refreeze: stored-form serialization is deterministic
            let mut w2 = snapshot::Writer::new();
            back.save(&mut w2);
            assert_eq!(w2.into_bytes(), blob, "{mode:?} refreeze differs");
        }
        // corrupt tags / lengths err cleanly
        let mut r = snapshot::Reader::new(&[9u8]);
        assert!(QuantTensor::load(&mut r).is_err());
    }

    #[test]
    fn resize_and_row_io() {
        let mut rng = Rng::new(47);
        let d = 12usize;
        for mode in [QuantMode::None, QuantMode::F16, QuantMode::I8] {
            let mut t = QuantTensor::new(mode, 0, d);
            assert!(t.is_empty());
            t.resize_rows(3);
            assert_eq!((t.rows(), t.len()), (3, 3 * d));
            let mut row = vec![0.0f32; d];
            t.read_row(2, &mut row);
            assert!(row.iter().all(|&x| x == 0.0), "{mode:?}: fresh rows must be zero");
            let src = randv(&mut rng, d);
            t.write_row(1, &src);
            t.read_row(1, &mut row);
            let tol = match mode {
                QuantMode::None => 0.0,
                QuantMode::F16 => 4.0 * (1.0 / 1024.0),
                QuantMode::I8 => 4.0 / 63.0,
            };
            for j in 0..d {
                assert!((row[j] - src[j]).abs() <= tol + 1e-7, "{mode:?} j={j}");
            }
            t.resize_rows(1);
            assert_eq!(t.rows(), 1);
            assert_eq!(t.state_bytes(), mode.row_bytes(d));
        }
    }

    #[test]
    fn quant_mode_parse_and_tags_round_trip() {
        for mode in [QuantMode::None, QuantMode::F16, QuantMode::I8] {
            assert_eq!(QuantMode::parse(mode.name()).unwrap(), mode);
            assert_eq!(QuantMode::from_tag(mode.tag()).unwrap(), mode);
        }
        assert!(QuantMode::parse("int4").is_err());
        assert!(QuantMode::from_tag(7).is_err());
        assert_eq!(QuantMode::default(), QuantMode::None);
    }
}
