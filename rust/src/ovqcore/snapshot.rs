//! Compact binary snapshot format for [`SeqMixer`] state — the session
//! lifecycle's persistence layer. A decode session evicted from a shard
//! is serialized to a byte blob via [`save`] and revived later with
//! [`restore`]; the round trip is **bit-exact** (f32 payloads are stored
//! as raw little-endian bit patterns, never reformatted), so a restored
//! session continues token-identically to one that was never evicted.
//! rust/tests/golden.rs property-tests that contract for every mixer at
//! random interruption points.
//!
//! Framing: `MAGIC (u32) | VERSION (u16) | kind_name (str) | payload`.
//! The payload is written by each mixer's [`SeqMixer::snapshot`] and read
//! back by its `from_snapshot` constructor; [`restore`] dispatches on the
//! kind name, so a blob is self-describing — the reviver does not need to
//! know what kind of session it is thawing. Container kinds nest: a
//! `"stack"` blob holds one full child frame per (layer, head) mixer, and
//! an `"lm"` blob holds generation state (sampling RNG, history ring)
//! plus a nested stack frame — so a whole language-model session, mid-
//! generation, freezes into one self-describing byte string.
//!
//! Failure model: nothing in this module panics on untrusted bytes. Every
//! structural defect — truncation, bad magic, an unsupported version, an
//! unknown kind, trailing garbage, a corrupt length field — surfaces as a
//! typed [`SnapshotError`], which converts into `anyhow::Error` at the
//! `?` boundary so callers keep their ergonomic `Result`s.

use std::fmt;

use anyhow::{Context, Result};

use super::gdn::GdnState;
use super::kvcache::KvCache;
use super::linear_attn::LinearAttnState;
use super::lm::LmModel;
use super::mixer::SeqMixer;
use super::ovq::OvqState;
use super::stack::LayerStack;
use super::vq::VqState;

/// `b"OVQS"` little-endian.
pub const MAGIC: u32 = 0x5351_564F;
/// Format version in the header. v2 added the `"stack"` container frame
/// (nested per-(layer, head) child blobs); v3 stores OVQ/VQ dictionaries
/// as self-describing [`super::quant::QuantTensor`] payloads (quantized
/// dictionaries serialize in their quantized form) and adds the quant
/// mode to the stack config. Older blobs are not accepted — snapshots are
/// transient session state, never a durable archive.
pub const VERSION: u16 = 3;

/// Typed snapshot failure — the reasons a blob cannot be thawed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SnapshotError {
    /// fewer bytes remain than a field needs
    Truncated { offset: usize, need: usize, have: usize },
    /// the blob does not start with [`MAGIC`]
    BadMagic(u32),
    /// header version is not [`VERSION`]
    BadVersion { got: u16 },
    /// the kind name is none of the registered machines
    UnknownKind(String),
    /// bytes left over after the payload was fully consumed
    TrailingBytes { kind: String, extra: usize },
    /// a length field claims more elements than the blob could hold
    BadLength { claimed: usize, remaining: usize },
    /// a string field is not UTF-8
    NotUtf8,
    /// a disk-tier frame's payload checksum does not match its header
    BadChecksum { expect: u64, got: u64 },
    /// the disk tier could not read a blob file at all
    Io(String),
}

impl fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SnapshotError::Truncated { offset, need, have } => write!(
                f,
                "snapshot truncated: need {need} bytes at offset {offset}, have {have}"
            ),
            SnapshotError::BadMagic(m) => {
                write!(f, "not a mixer snapshot (magic {m:#x})")
            }
            SnapshotError::BadVersion { got } => {
                write!(f, "unsupported snapshot version {got} (this build reads {VERSION})")
            }
            SnapshotError::UnknownKind(k) => {
                write!(f, "unknown mixer kind in snapshot: {k:?}")
            }
            SnapshotError::TrailingBytes { kind, extra } => {
                write!(f, "snapshot has {extra} trailing bytes after {kind} payload")
            }
            SnapshotError::BadLength { claimed, remaining } => write!(
                f,
                "snapshot array length {claimed} exceeds remaining {remaining} bytes"
            ),
            SnapshotError::NotUtf8 => write!(f, "snapshot kind name is not utf8"),
            SnapshotError::BadChecksum { expect, got } => write!(
                f,
                "spilled blob checksum mismatch: header says {expect:#018x}, payload hashes to {got:#018x}"
            ),
            SnapshotError::Io(what) => {
                write!(f, "spilled blob unreadable: {what}")
            }
        }
    }
}

impl std::error::Error for SnapshotError {}

// ------------------------------------------------------------------ writer

/// Append-only little-endian byte sink.
#[derive(Debug, Default)]
pub struct Writer {
    pub buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }

    pub fn u16(&mut self, x: u16) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn usize(&mut self, x: usize) {
        self.u64(x as u64);
    }

    pub fn bool(&mut self, x: bool) {
        self.u8(x as u8);
    }

    /// f32 stored as its raw bit pattern — exact, never a decimal round trip.
    pub fn f32(&mut self, x: f32) {
        self.u32(x.to_bits());
    }

    pub fn f64(&mut self, x: f64) {
        self.u64(x.to_bits());
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed f32 slice, raw LE bits.
    pub fn f32s(&mut self, xs: &[f32]) {
        self.u64(xs.len() as u64);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }

    pub fn opt_f32(&mut self, x: Option<f32>) {
        match x {
            Some(v) => {
                self.bool(true);
                self.f32(v);
            }
            None => self.bool(false),
        }
    }

    pub fn opt_usize(&mut self, x: Option<usize>) {
        match x {
            Some(v) => {
                self.bool(true);
                self.usize(v);
            }
            None => self.bool(false),
        }
    }

    /// Length-prefixed nested byte blob (used to pack per-head snapshots
    /// into one session blob).
    pub fn bytes(&mut self, b: &[u8]) {
        self.u64(b.len() as u64);
        self.buf.extend_from_slice(b);
    }
}

// ------------------------------------------------------------------ reader

/// Cursor over a snapshot blob; every accessor checks bounds and returns
/// a typed [`SnapshotError`] (which `?`-converts into `anyhow::Error` in
/// the mixers' `from_snapshot` constructors) instead of panicking.
pub struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    pub fn new(b: &'a [u8]) -> Reader<'a> {
        Reader { b, i: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], SnapshotError> {
        if self.remaining() < n {
            return Err(SnapshotError::Truncated {
                offset: self.i,
                need: n,
                have: self.remaining(),
            });
        }
        let whole: &'a [u8] = self.b; // copy the 'a reference out of self
        let s = &whole[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, SnapshotError> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16, SnapshotError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32, SnapshotError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, SnapshotError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn usize(&mut self) -> Result<usize, SnapshotError> {
        Ok(self.u64()? as usize)
    }

    pub fn bool(&mut self) -> Result<bool, SnapshotError> {
        Ok(self.u8()? != 0)
    }

    pub fn f32(&mut self) -> Result<f32, SnapshotError> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f64(&mut self) -> Result<f64, SnapshotError> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn str(&mut self) -> Result<String, SnapshotError> {
        let n = self.u32()? as usize;
        Ok(std::str::from_utf8(self.take(n)?)
            .map_err(|_| SnapshotError::NotUtf8)?
            .to_string())
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>, SnapshotError> {
        let n = self.u64()? as usize;
        // checked: a corrupt length field must Err, not wrap the multiply
        // (release) or panic (debug) — the bounds contract of this reader
        let nbytes = n
            .checked_mul(4)
            .filter(|&b| b <= self.remaining())
            .ok_or(SnapshotError::BadLength { claimed: n, remaining: self.remaining() })?;
        let raw = self.take(nbytes)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    pub fn opt_f32(&mut self) -> Result<Option<f32>, SnapshotError> {
        Ok(if self.bool()? { Some(self.f32()?) } else { None })
    }

    pub fn opt_usize(&mut self) -> Result<Option<usize>, SnapshotError> {
        Ok(if self.bool()? { Some(self.usize()?) } else { None })
    }

    pub fn bytes(&mut self) -> Result<&'a [u8], SnapshotError> {
        let n = self.u64()? as usize;
        if n > self.remaining() {
            return Err(SnapshotError::BadLength { claimed: n, remaining: self.remaining() });
        }
        self.take(n)
    }
}

// ----------------------------------------------------------- save / restore

/// Read just the header of a blob and return its kind name — validation
/// without payload work. Container restores use this to reject malformed
/// nesting (e.g. a stack inside a stack) *before* recursing.
pub fn peek_kind(bytes: &[u8]) -> Result<String, SnapshotError> {
    let mut r = Reader::new(bytes);
    let magic = r.u32()?;
    if magic != MAGIC {
        return Err(SnapshotError::BadMagic(magic));
    }
    let version = r.u16()?;
    if version != VERSION {
        return Err(SnapshotError::BadVersion { got: version });
    }
    r.str()
}

/// Serialize a mixer (any kind) into a self-describing blob.
pub fn save(m: &dyn SeqMixer) -> Vec<u8> {
    let mut w = Writer::new();
    w.u32(MAGIC);
    w.u16(VERSION);
    w.str(m.kind_name());
    m.snapshot(&mut w);
    w.into_bytes()
}

/// Revive a mixer from a [`save`] blob. The restored machine continues
/// bit-identically to the one that was snapshotted. Dispatches on the
/// self-describing kind name — including the `"stack"` container frame,
/// whose payload nests one full child blob per (layer, head) mixer.
pub fn restore(bytes: &[u8]) -> Result<Box<dyn SeqMixer>> {
    let mut r = Reader::new(bytes);
    let magic = r.u32()?;
    if magic != MAGIC {
        return Err(SnapshotError::BadMagic(magic).into());
    }
    let version = r.u16()?;
    if version != VERSION {
        return Err(SnapshotError::BadVersion { got: version }.into());
    }
    let kind = r.str()?;
    let m: Box<dyn SeqMixer> = match kind.as_str() {
        "ovq" => Box::new(OvqState::from_snapshot(&mut r)?),
        "vq" => Box::new(VqState::from_snapshot(&mut r)?),
        "linear_attn" => Box::new(LinearAttnState::from_snapshot(&mut r)?),
        "gdn" => Box::new(GdnState::from_snapshot(&mut r)?),
        "kv_cache" | "sliding_window" => Box::new(KvCache::from_snapshot(&mut r)?),
        "stack" => Box::new(LayerStack::from_snapshot(&mut r).context("stack container")?),
        "lm" => Box::new(LmModel::from_snapshot(&mut r).context("lm container")?),
        other => return Err(SnapshotError::UnknownKind(other.to_string()).into()),
    };
    if r.remaining() != 0 {
        return Err(SnapshotError::TrailingBytes { kind, extra: r.remaining() }.into());
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ovqcore::memstate::MixerKind;
    use crate::ovqcore::mixer::Scratch;
    use crate::util::rng::Rng;

    #[test]
    fn writer_reader_round_trip_primitives() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEADBEEF);
        w.u64(u64::MAX - 3);
        w.f32(-0.0); // sign bit must survive
        w.f32(f32::NAN);
        w.bool(true);
        w.str("sliding_window");
        w.f32s(&[1.5, -2.25, 3e-9]);
        w.opt_f32(None);
        w.opt_usize(Some(42));
        let buf = w.into_bytes();

        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert!(r.f32().unwrap().is_nan());
        assert!(r.bool().unwrap());
        assert_eq!(r.str().unwrap(), "sliding_window");
        assert_eq!(r.f32s().unwrap(), vec![1.5, -2.25, 3e-9]);
        assert_eq!(r.opt_f32().unwrap(), None);
        assert_eq!(r.opt_usize().unwrap(), Some(42));
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn reader_rejects_truncation() {
        let mut w = Writer::new();
        w.f32s(&[1.0, 2.0, 3.0]);
        let buf = w.into_bytes();
        let mut r = Reader::new(&buf[..buf.len() - 2]);
        assert!(r.f32s().is_err());
    }

    #[test]
    fn restore_rejects_garbage_with_typed_errors() {
        // truncated header
        let e = restore(b"ovq").unwrap_err();
        assert!(format!("{e}").contains("truncated"), "{e}");
        // wrong magic
        let e = restore(b"not a snapshot").unwrap_err();
        assert!(format!("{e}").contains("magic"), "{e}");
        // version mismatch (e.g. a pre-stack v1 blob)
        for version in [1u16, 99] {
            let mut w = Writer::new();
            w.u32(MAGIC);
            w.u16(version);
            w.str("ovq");
            let e = restore(&w.into_bytes()).unwrap_err();
            assert!(format!("{e}").contains("version"), "v{version}: {e}");
        }
        // unknown kind
        let mut w = Writer::new();
        w.u32(MAGIC);
        w.u16(VERSION);
        w.str("mamba");
        let e = restore(&w.into_bytes()).unwrap_err();
        assert!(format!("{e}").contains("unknown mixer kind"), "{e}");
        // trailing bytes after a valid payload
        let probe = MixerKind::Ovq { n_max: 8 }.build(4, 8, 1);
        let mut blob = save(probe.as_ref());
        blob.push(0xFF);
        let e = restore(&blob).unwrap_err();
        assert!(format!("{e}").contains("trailing"), "{e}");
    }

    #[test]
    fn peek_kind_reads_headers_only() {
        let probe = MixerKind::Gdn.build(4, 8, 1);
        let blob = save(probe.as_ref());
        assert_eq!(peek_kind(&blob).unwrap(), "gdn");
        assert!(peek_kind(b"junk").is_err());
        let mut w = Writer::new();
        w.u32(MAGIC);
        w.u16(1); // stale version
        w.str("gdn");
        assert_eq!(peek_kind(&w.into_bytes()), Err(SnapshotError::BadVersion { got: 1 }));
    }

    #[test]
    fn snapshot_error_variants_format_distinctly() {
        let variants: Vec<SnapshotError> = vec![
            SnapshotError::Truncated { offset: 3, need: 8, have: 1 },
            SnapshotError::BadMagic(7),
            SnapshotError::BadVersion { got: 1 },
            SnapshotError::UnknownKind("x".into()),
            SnapshotError::TrailingBytes { kind: "ovq".into(), extra: 2 },
            SnapshotError::BadLength { claimed: 1 << 60, remaining: 4 },
            SnapshotError::NotUtf8,
            SnapshotError::BadChecksum { expect: 0xAB, got: 0xCD },
            SnapshotError::Io("gone.blob: no such file".into()),
        ];
        let msgs: Vec<String> = variants.iter().map(|v| v.to_string()).collect();
        for (i, a) in msgs.iter().enumerate() {
            for b in &msgs[i + 1..] {
                assert_ne!(a, b);
            }
        }
        // and they convert into anyhow at the ? boundary
        let e: anyhow::Error = SnapshotError::BadMagic(7).into();
        assert!(format!("{e}").contains("magic"));
    }

    #[test]
    fn stack_container_round_trips_bit_exactly() {
        use crate::ovqcore::stack::{LayerStack, StackConfig};
        let kinds = vec![
            MixerKind::Ovq { n_max: 16 },
            MixerKind::SlidingWindow { window: 12 },
            MixerKind::Gdn,
        ];
        let cfg = StackConfig::hybrid(8, 16, 2, 4, 8, kinds);
        let mut st = LayerStack::new(cfg, 0xFEED);
        let mut rng = Rng::new(0xBEE);
        let mut scratch = Scratch::new();
        // 21 tokens: the OVQ layers keep a pending tail mid-chunk
        let x: Vec<f32> = (0..21 * 8).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0.0f32; 21 * 8];
        st.process_chunk(&x, &x, &x, &mut out, &mut scratch);

        let blob = save(&st);
        let thawed = restore(&blob).expect("stack blob must thaw");
        assert_eq!(thawed.kind_name(), "stack");
        assert_eq!(thawed.tokens(), st.tokens());
        assert_eq!(thawed.state_bytes(), st.state_bytes());
        assert_eq!(save(thawed.as_ref()), blob, "stack refreeze differs");
        let stats = thawed.layer_stats();
        assert_eq!(stats.len(), 3);
        assert_eq!(stats[2].kind, "gdn");

        // a corrupt nested frame fails cleanly, never panics
        let mut bad = blob.clone();
        let n = bad.len();
        bad.truncate(n - 3);
        assert!(restore(&bad).is_err());
    }

    /// One populated blob per registered kind — every bare mixer
    /// mid-pending-tail, a hybrid stack, and an LM session frozen
    /// mid-generation — the corpus the fuzz tests mutate.
    fn fuzz_corpus() -> Vec<(String, Vec<u8>)> {
        use crate::ovqcore::lm::{LmConfig, LmModel};
        use crate::ovqcore::stack::{LayerStack, StackConfig};
        let (d, chunk) = (8usize, 16usize);
        let mut rng = Rng::new(0xF022);
        let mut blobs = Vec::new();
        for kind in [
            MixerKind::Ovq { n_max: 32 },
            MixerKind::Vq { n: 16 },
            MixerKind::LinearAttention,
            MixerKind::Gdn,
            MixerKind::FullAttention,
            MixerKind::SlidingWindow { window: 24 },
        ] {
            let mut m = kind.build(d, chunk, 3);
            for _ in 0..(chunk + 5) {
                let k: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                m.write(&k, &v);
            }
            blobs.push((format!("{kind:?}"), save(m.as_ref())));
        }
        let scfg = StackConfig::hybrid(
            8,
            16,
            2,
            4,
            8,
            vec![MixerKind::Ovq { n_max: 16 }, MixerKind::SlidingWindow { window: 12 }],
        );
        let mut st = LayerStack::new(scfg.clone(), 0xFE);
        let mut scratch = Scratch::new();
        let x: Vec<f32> = (0..13 * 8).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0.0f32; 13 * 8];
        st.process_chunk(&x, &x, &x, &mut out, &mut scratch);
        blobs.push(("stack".to_string(), save(&st)));
        let mut lm = LmModel::new(LmConfig::new(24, scfg), 0xFE);
        lm.prefill_tokens(&[1, 5, 9, 2, 17, 3, 3], &mut vec![0.0f32; 24], &mut scratch);
        lm.begin_gen(0xD1CE, 4);
        for t in [2u32, 19, 2] {
            lm.gen_mut().unwrap().push(t);
        }
        blobs.push(("lm".to_string(), save(&lm)));
        blobs
    }

    #[test]
    fn fuzz_truncated_blobs_always_err_never_panic() {
        // cut every corpus blob at random offsets (plus the all-prefix
        // sweep near the header): restore must return a clean Err — the
        // typed-SnapshotError / ensure! failure model — and never panic,
        // whatever structure the cut lands inside (nested frames included)
        let mut rng = Rng::new(0x7C);
        for (name, blob) in fuzz_corpus() {
            for cut in 0..blob.len().min(16) {
                assert!(restore(&blob[..cut]).is_err(), "{name}: {cut}-byte prefix thawed");
            }
            for _ in 0..48 {
                let cut = rng.usize_below(blob.len());
                assert!(restore(&blob[..cut]).is_err(), "{name}: truncation at {cut} thawed");
            }
        }
    }

    #[test]
    fn fuzz_bit_flips_never_panic() {
        // flip random single bits in every corpus blob: a flip may yield
        // a clean typed error (corrupt framing/lengths/dims) or a valid
        // blob encoding a different state (a payload-f32 flip) — both are
        // fine; what must NEVER happen is a panic, an arithmetic
        // overflow, or a wild allocation. Running under the test harness
        // is the panic assertion.
        let mut rng = Rng::new(0xB17);
        for (name, blob) in fuzz_corpus() {
            for _ in 0..96 {
                let mut bad = blob.clone();
                let at = rng.usize_below(bad.len());
                bad[at] ^= 1 << rng.usize_below(8);
                match restore(&bad) {
                    // a surviving blob must still be internally coherent
                    Ok(m) => {
                        let _ = m.state_bytes();
                        let _ = m.tokens();
                    }
                    Err(e) => {
                        let msg = format!("{e}");
                        assert!(!msg.is_empty(), "{name}: empty error");
                    }
                }
            }
        }
    }

    #[test]
    fn lm_blob_with_corrupt_generation_ring_errs_cleanly() {
        // targeted (not random) corruption of the lm frame's generation
        // fields: an implausible ring cap must surface as a typed error
        use crate::ovqcore::lm::{LmConfig, LmModel};
        use crate::ovqcore::stack::StackConfig;
        let scfg = StackConfig::hybrid(8, 16, 2, 4, 8, vec![MixerKind::Gdn]);
        let mut lm = LmModel::new(LmConfig::new(24, scfg), 1);
        lm.begin_gen(9, 4);
        let blob = save(&lm);
        // payload layout after the frame header: vocab u64 | seed u64 |
        // has_gen u8 | rng 4*u64 | cap u64 | ...  — poke the cap field
        let header = 4 + 2 + 4 + "lm".len();
        let cap_off = header + 8 + 8 + 1 + 32;
        let mut bad = blob;
        bad[cap_off..cap_off + 8].copy_from_slice(&(u64::MAX / 2).to_le_bytes());
        let e = restore(&bad).unwrap_err();
        assert!(format!("{e}").contains("generation ring"), "{e}");
    }

    #[test]
    fn huge_length_field_errs_instead_of_wrapping() {
        // a corrupt f32s length near u64::MAX must not wrap `n * 4` into a
        // small take() — it must surface as a clean error
        let mut w = Writer::new();
        w.u64(u64::MAX / 2); // claims ~2^63 floats
        w.u32(0); // a few real bytes
        let buf = w.into_bytes();
        let mut r = Reader::new(&buf);
        assert!(r.f32s().is_err());
    }

    #[test]
    fn save_restore_save_is_identical_for_every_kind() {
        // determinism of the format itself: thaw + refreeze must produce
        // the same bytes, for every mixer kind, mid-chunk state included
        let (d, chunk) = (8usize, 16usize);
        let kinds = [
            MixerKind::Ovq { n_max: 32 },
            MixerKind::Vq { n: 16 },
            MixerKind::LinearAttention,
            MixerKind::Gdn,
            MixerKind::FullAttention,
            MixerKind::SlidingWindow { window: 24 },
        ];
        let mut rng = Rng::new(0x5AFE);
        for kind in kinds {
            let mut m = kind.build(d, chunk, 3);
            // leave a partial OVQ chunk buffered on purpose
            for _ in 0..(3 * chunk + 5) {
                let k: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                m.write(&k, &v);
            }
            let blob = save(m.as_ref());
            let thawed = restore(&blob).unwrap_or_else(|e| panic!("{kind:?}: {e}"));
            assert_eq!(thawed.kind_name(), m.kind_name());
            assert_eq!(thawed.tokens(), m.tokens(), "{kind:?}");
            assert_eq!(thawed.state_bytes(), m.state_bytes(), "{kind:?}");
            assert_eq!(save(thawed.as_ref()), blob, "{kind:?}: refreeze differs");
            // and it still answers queries identically
            let q: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let mut scratch = Scratch::new();
            let (mut a, mut b) = (vec![0.0f32; d], vec![0.0f32; d]);
            m.read(&q, &mut a, &mut scratch);
            thawed.read(&q, &mut b, &mut scratch);
            assert_eq!(a, b, "{kind:?}: reads diverge after restore");
        }
    }
}
