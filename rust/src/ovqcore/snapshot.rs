//! Compact binary snapshot format for [`SeqMixer`] state — the session
//! lifecycle's persistence layer. A decode session evicted from a shard
//! is serialized to a byte blob via [`save`] and revived later with
//! [`restore`]; the round trip is **bit-exact** (f32 payloads are stored
//! as raw little-endian bit patterns, never reformatted), so a restored
//! session continues token-identically to one that was never evicted.
//! rust/tests/golden.rs property-tests that contract for every mixer at
//! random interruption points.
//!
//! Framing: `MAGIC (u32) | VERSION (u16) | kind_name (str) | payload`.
//! The payload is written by each mixer's [`SeqMixer::snapshot`] and read
//! back by its `from_snapshot` constructor; [`restore`] dispatches on the
//! kind name, so a blob is self-describing — the reviver does not need to
//! know what kind of session it is thawing.

use anyhow::{bail, Context, Result};

use super::gdn::GdnState;
use super::kvcache::KvCache;
use super::linear_attn::LinearAttnState;
use super::mixer::SeqMixer;
use super::ovq::OvqState;
use super::vq::VqState;

/// `b"OVQS"` little-endian.
pub const MAGIC: u32 = 0x5351_564F;
pub const VERSION: u16 = 1;

// ------------------------------------------------------------------ writer

/// Append-only little-endian byte sink.
#[derive(Debug, Default)]
pub struct Writer {
    pub buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }

    pub fn u16(&mut self, x: u16) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn usize(&mut self, x: usize) {
        self.u64(x as u64);
    }

    pub fn bool(&mut self, x: bool) {
        self.u8(x as u8);
    }

    /// f32 stored as its raw bit pattern — exact, never a decimal round trip.
    pub fn f32(&mut self, x: f32) {
        self.u32(x.to_bits());
    }

    pub fn f64(&mut self, x: f64) {
        self.u64(x.to_bits());
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed f32 slice, raw LE bits.
    pub fn f32s(&mut self, xs: &[f32]) {
        self.u64(xs.len() as u64);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }

    pub fn opt_f32(&mut self, x: Option<f32>) {
        match x {
            Some(v) => {
                self.bool(true);
                self.f32(v);
            }
            None => self.bool(false),
        }
    }

    pub fn opt_usize(&mut self, x: Option<usize>) {
        match x {
            Some(v) => {
                self.bool(true);
                self.usize(v);
            }
            None => self.bool(false),
        }
    }

    /// Length-prefixed nested byte blob (used to pack per-head snapshots
    /// into one session blob).
    pub fn bytes(&mut self, b: &[u8]) {
        self.u64(b.len() as u64);
        self.buf.extend_from_slice(b);
    }
}

// ------------------------------------------------------------------ reader

/// Cursor over a snapshot blob; every accessor checks bounds.
pub struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    pub fn new(b: &'a [u8]) -> Reader<'a> {
        Reader { b, i: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "snapshot truncated: need {n} bytes at offset {}, have {}",
                self.i,
                self.remaining()
            );
        }
        let whole: &'a [u8] = self.b; // copy the 'a reference out of self
        let s = &whole[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn usize(&mut self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }

    pub fn bool(&mut self) -> Result<bool> {
        Ok(self.u8()? != 0)
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(std::str::from_utf8(self.take(n)?)
            .context("snapshot kind name is not utf8")?
            .to_string())
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u64()? as usize;
        // checked: a corrupt length field must Err, not wrap the multiply
        // (release) or panic (debug) — the bounds contract of this reader
        let nbytes = n
            .checked_mul(4)
            .filter(|&b| b <= self.remaining())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "snapshot f32 array length {n} exceeds remaining {} bytes",
                    self.remaining()
                )
            })?;
        let raw = self.take(nbytes)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    pub fn opt_f32(&mut self) -> Result<Option<f32>> {
        Ok(if self.bool()? { Some(self.f32()?) } else { None })
    }

    pub fn opt_usize(&mut self) -> Result<Option<usize>> {
        Ok(if self.bool()? { Some(self.usize()?) } else { None })
    }

    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.u64()? as usize;
        self.take(n)
    }
}

// ----------------------------------------------------------- save / restore

/// Serialize a mixer (any kind) into a self-describing blob.
pub fn save(m: &dyn SeqMixer) -> Vec<u8> {
    let mut w = Writer::new();
    w.u32(MAGIC);
    w.u16(VERSION);
    w.str(m.kind_name());
    m.snapshot(&mut w);
    w.into_bytes()
}

/// Revive a mixer from a [`save`] blob. The restored machine continues
/// bit-identically to the one that was snapshotted.
pub fn restore(bytes: &[u8]) -> Result<Box<dyn SeqMixer>> {
    let mut r = Reader::new(bytes);
    let magic = r.u32()?;
    if magic != MAGIC {
        bail!("not a mixer snapshot (magic {magic:#x})");
    }
    let version = r.u16()?;
    if version != VERSION {
        bail!("unsupported snapshot version {version}");
    }
    let kind = r.str()?;
    let m: Box<dyn SeqMixer> = match kind.as_str() {
        "ovq" => Box::new(OvqState::from_snapshot(&mut r)?),
        "vq" => Box::new(VqState::from_snapshot(&mut r)?),
        "linear_attn" => Box::new(LinearAttnState::from_snapshot(&mut r)?),
        "gdn" => Box::new(GdnState::from_snapshot(&mut r)?),
        "kv_cache" | "sliding_window" => Box::new(KvCache::from_snapshot(&mut r)?),
        other => bail!("unknown mixer kind in snapshot: {other:?}"),
    };
    if r.remaining() != 0 {
        bail!("snapshot has {} trailing bytes after {kind} payload", r.remaining());
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ovqcore::memstate::MixerKind;
    use crate::ovqcore::mixer::Scratch;
    use crate::util::rng::Rng;

    #[test]
    fn writer_reader_round_trip_primitives() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEADBEEF);
        w.u64(u64::MAX - 3);
        w.f32(-0.0); // sign bit must survive
        w.f32(f32::NAN);
        w.bool(true);
        w.str("sliding_window");
        w.f32s(&[1.5, -2.25, 3e-9]);
        w.opt_f32(None);
        w.opt_usize(Some(42));
        let buf = w.into_bytes();

        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert!(r.f32().unwrap().is_nan());
        assert!(r.bool().unwrap());
        assert_eq!(r.str().unwrap(), "sliding_window");
        assert_eq!(r.f32s().unwrap(), vec![1.5, -2.25, 3e-9]);
        assert_eq!(r.opt_f32().unwrap(), None);
        assert_eq!(r.opt_usize().unwrap(), Some(42));
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn reader_rejects_truncation() {
        let mut w = Writer::new();
        w.f32s(&[1.0, 2.0, 3.0]);
        let buf = w.into_bytes();
        let mut r = Reader::new(&buf[..buf.len() - 2]);
        assert!(r.f32s().is_err());
    }

    #[test]
    fn restore_rejects_garbage() {
        assert!(restore(b"not a snapshot").is_err());
        let mut w = Writer::new();
        w.u32(MAGIC);
        w.u16(99); // bad version
        w.str("ovq");
        assert!(restore(&w.into_bytes()).is_err());
    }

    #[test]
    fn huge_length_field_errs_instead_of_wrapping() {
        // a corrupt f32s length near u64::MAX must not wrap `n * 4` into a
        // small take() — it must surface as a clean error
        let mut w = Writer::new();
        w.u64(u64::MAX / 2); // claims ~2^63 floats
        w.u32(0); // a few real bytes
        let buf = w.into_bytes();
        let mut r = Reader::new(&buf);
        assert!(r.f32s().is_err());
    }

    #[test]
    fn save_restore_save_is_identical_for_every_kind() {
        // determinism of the format itself: thaw + refreeze must produce
        // the same bytes, for every mixer kind, mid-chunk state included
        let (d, chunk) = (8usize, 16usize);
        let kinds = [
            MixerKind::Ovq { n_max: 32 },
            MixerKind::Vq { n: 16 },
            MixerKind::LinearAttention,
            MixerKind::Gdn,
            MixerKind::FullAttention,
            MixerKind::SlidingWindow { window: 24 },
        ];
        let mut rng = Rng::new(0x5AFE);
        for kind in kinds {
            let mut m = kind.build(d, chunk, 3);
            // leave a partial OVQ chunk buffered on purpose
            for _ in 0..(3 * chunk + 5) {
                let k: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                m.write(&k, &v);
            }
            let blob = save(m.as_ref());
            let thawed = restore(&blob).unwrap_or_else(|e| panic!("{kind:?}: {e}"));
            assert_eq!(thawed.kind_name(), m.kind_name());
            assert_eq!(thawed.tokens(), m.tokens(), "{kind:?}");
            assert_eq!(thawed.state_bytes(), m.state_bytes(), "{kind:?}");
            assert_eq!(save(thawed.as_ref()), blob, "{kind:?}: refreeze differs");
            // and it still answers queries identically
            let q: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let mut scratch = Scratch::new();
            let (mut a, mut b) = (vec![0.0f32; d], vec![0.0f32; d]);
            m.read(&q, &mut a, &mut scratch);
            thawed.read(&q, &mut b, &mut scratch);
            assert_eq!(a, b, "{kind:?}: reads diverge after restore");
        }
    }
}
